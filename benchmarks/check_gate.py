"""Performance gate over a benchmark JSON document (CI smoke job).

Fails (exit 1) when the Pallas fwd+bwd mesh path is slower than reference
autodiff at N=16 — the regression this repo's kernels exist to prevent.
The reference timing rides in each row's derived column as
``ref_autodiff_us=...``.

    PYTHONPATH=src python -m benchmarks.check_gate BENCH_kernels.json
"""

from __future__ import annotations

import json
import re
import sys

GATED_ROWS = ("mesh_fwd_bwd_n16",)


def check(doc: dict) -> list[str]:
    problems = []
    rows = {r["name"]: r for r in doc.get("rows", [])}
    for name in GATED_ROWS:
        r = rows.get(name)
        if r is None:
            problems.append(f"{name}: gated row missing from document")
            continue
        us = r.get("us_per_call")
        m = re.search(r"ref_autodiff_us=([0-9.]+)", r.get("derived", ""))
        if us is None or m is None:
            problems.append(f"{name}: no kernel/reference timing pair")
            continue
        ref_us = float(m.group(1))
        if us > ref_us:
            problems.append(
                f"{name}: Pallas fwd+bwd {us:.1f}us slower than "
                f"reference autodiff {ref_us:.1f}us")
    if doc.get("failures"):
        problems.append(f"benchmark run recorded {doc['failures']} failures")
    return problems


def main(argv=None) -> int:
    argv = sys.argv[1:] if argv is None else argv
    if len(argv) != 1:
        print(__doc__, file=sys.stderr)
        return 2
    with open(argv[0]) as f:
        doc = json.load(f)
    problems = check(doc)
    for p in problems:
        print(f"GATE FAIL: {p}", file=sys.stderr)
    if not problems:
        print("benchmark gate passed: kernel fwd+bwd beats reference "
              "autodiff on every gated row")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main())
