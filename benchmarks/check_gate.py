"""Performance gate over a benchmark JSON document (CI smoke job).

Fails (exit 1) when a fused Pallas path is slower than its unfused
baseline — the regressions this repo's kernels exist to prevent:

* ``mesh_fwd_bwd_n16`` — the kernel custom-VJP mesh path must beat
  reference autodiff (``ref_autodiff_us`` in the derived column);
* ``net_fwd_bwd_n16_b1024`` — the whole-network megakernel (one
  pallas_call per direction for the 4-layer RFNN) must beat the
  per-layer kernel composition (``per_layer_us``);
* ``compile_apply_n16`` — a compiled analog program
  (``repro.compile.lower``, pre-packed megakernel tensors) must beat
  the retired pure-jnp ``SynthesizedMatrix.apply`` reference chain
  (``ref_apply_us``);
* ``tiled_apply_n64`` — the tile-grid megakernel (one pallas_call per
  direction for a 64x64 matmul on a 4x4 grid of 16x16 analog tiles)
  must beat the double-vmapped per-tile composition (``per_tile_us``);
* ``deepgrid_fwd_bwd_n64_l4`` — the deep tiled-network megakernel (one
  pallas_call per direction for a 4-layer 64x64 cascade, inter-layer
  detection in VMEM) must beat the per-layer tile-grid composition
  (``per_layer_us``);
* ``serving_qps_n64`` — the slot-batched serving engine's per-request
  time under a dynamic request stream must beat serial per-request
  megakernel calls (``serial_us``) — the continuous-batching win.

With ``--prev PREV.json`` it additionally diffs each timed row against a
previous run (the committed ``BENCH_kernels.json`` trajectory).  For the
hard-gated rows above this diff **fails** when the row's *speedup ratio*
(baseline_us / fused_us, both timed in the same run on the same machine)
degrades beyond ``--prev-threshold`` (default 50%) vs the previous run's
ratio.  Comparing ratios — not absolute microseconds — makes the hard
gate machine-independent: the committed trajectory may come from any
box, a slower CI runner scales numerator and denominator together, and
what the gate actually pins is the fusion *win*, which is the contract.
Every other row only *warns* on absolute drift beyond
``--warn-threshold`` (default 20%), because absolute cross-machine
timings ARE noisy — the explicit ``NOISY_ROWS`` allowlist documents why
each advisory-only row stays advisory.

    PYTHONPATH=src python -m benchmarks.check_gate BENCH_kernels.json \
        [--prev BENCH_prev.json] [--warn-threshold 0.2] \
        [--prev-threshold 0.5]
"""

from __future__ import annotations

import argparse
import json
import re
import sys

#: gated row -> the derived-column field holding the unfused baseline
GATED_ROWS = {
    "mesh_fwd_bwd_n16": "ref_autodiff_us",
    "net_fwd_bwd_n16_b1024": "per_layer_us",
    "compile_apply_n16": "ref_apply_us",
    "tiled_apply_n64": "per_tile_us",
    "deepgrid_fwd_bwd_n64_l4": "per_layer_us",
    "serving_qps_n64": "serial_us",
}

#: rows exempt from the hard --prev gate even if they ever join
#: GATED_ROWS: their timings are dominated by effects outside the kernels'
#: control (python-loop MC driver, one-shot eager timing), so absolute
#: drift on a shared CI runner is expected and stays advisory-only.
NOISY_ROWS = frozenset({
    "mc_yield_n8",          # eager python loop over draws, timed once
    "flash_attention",      # interpret-mode softmax dominated, high variance
    "tiled_apply_sharded_n64",  # forced host-device collectives over shared
                                # memory: scheduling noise dwarfs the kernels
    "serving_qps_n64",      # python tick loop + request objects + thread
                            # wakeups dominate the absolute microseconds;
                            # the engine-vs-serial win itself is still
                            # asserted by the primary gate above
})

#: the hard --prev contract: every differentially-gated row that is not
#: explicitly allowlisted as noisy fails CI when its fused-vs-baseline
#: speedup ratio degrades beyond --prev-threshold vs the committed
#: trajectory.
PREV_HARD_ROWS = frozenset(GATED_ROWS) - NOISY_ROWS


def _speedup(row: dict) -> float | None:
    """baseline_us / fused_us for a gated row (None when unparseable).

    Both numbers come from the same benchmark run on the same machine
    (min-of-N), so the ratio is machine-independent — the quantity the
    hard --prev gate diffs across runs.
    """
    us = row.get("us_per_call")
    field = GATED_ROWS.get(row.get("name"))
    if not us or field is None:
        return None
    m = re.search(rf"{field}=([0-9.]+)", row.get("derived", ""))
    return float(m.group(1)) / us if m else None


def check(doc: dict) -> list[str]:
    problems = []
    rows = {r["name"]: r for r in doc.get("rows", [])}
    for name, baseline_field in GATED_ROWS.items():
        r = rows.get(name)
        if r is None:
            problems.append(f"{name}: gated row missing from document")
            continue
        us = r.get("us_per_call")
        m = re.search(rf"{baseline_field}=([0-9.]+)", r.get("derived", ""))
        if us is None or m is None:
            problems.append(f"{name}: no kernel/baseline timing pair")
            continue
        baseline_us = float(m.group(1))
        if us > baseline_us:
            problems.append(
                f"{name}: fused path {us:.1f}us slower than "
                f"{baseline_field} baseline {baseline_us:.1f}us")
    if doc.get("failures"):
        problems.append(f"benchmark run recorded {doc['failures']} failures")
    return problems


def diff_previous(doc: dict, prev: dict, warn_threshold: float,
                  prev_threshold: float) -> tuple[list[str], list[str]]:
    """Diff against the previous run.

    Returns ``(problems, warnings)``.  Hard-gated rows
    (``PREV_HARD_ROWS``) whose fused-vs-baseline speedup ratio drops
    beyond ``prev_threshold`` vs the previous run are problems (CI
    failure) — the ratio is machine-independent, so the committed
    trajectory need not come from the CI runner.  Every other row
    regressing in absolute time beyond ``warn_threshold`` is an advisory
    warning.  Rows missing from the previous document are skipped (the
    first run after a row is added establishes its trajectory).
    """
    problems, warnings = [], []
    prev_rows = {r["name"]: r for r in prev.get("rows", [])}
    for r in doc.get("rows", []):
        us = r.get("us_per_call")
        p = prev_rows.get(r["name"])
        if us is None or p is None or not p.get("us_per_call"):
            continue
        prev_us = p["us_per_call"]
        if r["name"] in PREV_HARD_ROWS:
            ratio, prev_ratio = _speedup(r), _speedup(p)
            if ratio is None or prev_ratio is None:
                warnings.append(f"{r['name']}: cannot compare speedup "
                                "ratios vs previous run")
            elif ratio < prev_ratio * (1.0 - prev_threshold):
                problems.append(
                    f"{r['name']}: fused speedup {ratio:.2f}x vs previous "
                    f"{prev_ratio:.2f}x "
                    f"(-{(1 - ratio / prev_ratio) * 100:.0f}%)")
        elif us > prev_us * (1.0 + warn_threshold):
            warnings.append(
                f"{r['name']}: {us:.1f}us vs previous {prev_us:.1f}us "
                f"(+{(us / prev_us - 1) * 100:.0f}%)")
    return problems, warnings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("json_path", help="benchmark JSON document to gate")
    ap.add_argument("--prev", default=None,
                    help="previous run to diff against (hard-fails gated "
                         "rows, warns on the rest)")
    ap.add_argument("--warn-threshold", type=float, default=0.2,
                    help="relative slowdown vs --prev that triggers a "
                         "warning on non-gated rows (default 0.2 = 20%%)")
    ap.add_argument("--prev-threshold", type=float, default=0.5,
                    help="relative drop in a hard-gated row's "
                         "fused-vs-baseline speedup ratio vs --prev that "
                         "FAILS CI (default 0.5 = 50%%)")
    args = ap.parse_args(argv)
    with open(args.json_path) as f:
        doc = json.load(f)

    prev_problems: list[str] = []
    if args.prev:
        try:
            with open(args.prev) as f:
                prev = json.load(f)
        except OSError as e:
            print(f"GATE WARN: cannot read previous run: {e}",
                  file=sys.stderr)
        else:
            prev_problems, warnings = diff_previous(
                doc, prev, args.warn_threshold, args.prev_threshold)
            for w in warnings:
                print(f"GATE WARN: {w}", file=sys.stderr)

    problems = check(doc) + prev_problems
    for p in problems:
        print(f"GATE FAIL: {p}", file=sys.stderr)
    if not problems:
        print("benchmark gate passed: every fused path beats its unfused "
              "baseline on the gated rows")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main())
