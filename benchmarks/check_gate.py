"""Performance gate over a benchmark JSON document (CI smoke job).

Fails (exit 1) when a fused Pallas path is slower than its unfused
baseline — the regressions this repo's kernels exist to prevent:

* ``mesh_fwd_bwd_n16`` — the kernel custom-VJP mesh path must beat
  reference autodiff (``ref_autodiff_us`` in the derived column);
* ``net_fwd_bwd_n16_b1024`` — the whole-network megakernel (one
  pallas_call per direction for the 4-layer RFNN) must beat the
  per-layer kernel composition (``per_layer_us``);
* ``compile_apply_n16`` — a compiled analog program
  (``repro.compile.lower``, pre-packed megakernel tensors) must beat
  the retired pure-jnp ``SynthesizedMatrix.apply`` reference chain
  (``ref_apply_us``).

With ``--prev PREV.json`` it additionally diffs each timed row against a
previous run (the committed ``BENCH_kernels.json`` trajectory) and
*warns* — without failing — on regressions beyond ``--warn-threshold``
(default 20%).  Warnings stay advisory because absolute CI-runner timings
are noisy; the differential gates above are the hard contract.

    PYTHONPATH=src python -m benchmarks.check_gate BENCH_kernels.json \
        [--prev BENCH_prev.json] [--warn-threshold 0.2]
"""

from __future__ import annotations

import argparse
import json
import re
import sys

#: gated row -> the derived-column field holding the unfused baseline
GATED_ROWS = {
    "mesh_fwd_bwd_n16": "ref_autodiff_us",
    "net_fwd_bwd_n16_b1024": "per_layer_us",
    "compile_apply_n16": "ref_apply_us",
}


def check(doc: dict) -> list[str]:
    problems = []
    rows = {r["name"]: r for r in doc.get("rows", [])}
    for name, baseline_field in GATED_ROWS.items():
        r = rows.get(name)
        if r is None:
            problems.append(f"{name}: gated row missing from document")
            continue
        us = r.get("us_per_call")
        m = re.search(rf"{baseline_field}=([0-9.]+)", r.get("derived", ""))
        if us is None or m is None:
            problems.append(f"{name}: no kernel/baseline timing pair")
            continue
        baseline_us = float(m.group(1))
        if us > baseline_us:
            problems.append(
                f"{name}: fused path {us:.1f}us slower than "
                f"{baseline_field} baseline {baseline_us:.1f}us")
    if doc.get("failures"):
        problems.append(f"benchmark run recorded {doc['failures']} failures")
    return problems


def diff_previous(doc: dict, prev: dict, threshold: float) -> list[str]:
    """Advisory warnings for rows slower than the previous run."""
    warnings = []
    prev_rows = {r["name"]: r for r in prev.get("rows", [])}
    for r in doc.get("rows", []):
        us = r.get("us_per_call")
        p = prev_rows.get(r["name"])
        if us is None or p is None or not p.get("us_per_call"):
            continue
        prev_us = p["us_per_call"]
        if us > prev_us * (1.0 + threshold):
            warnings.append(
                f"{r['name']}: {us:.1f}us vs previous {prev_us:.1f}us "
                f"(+{(us / prev_us - 1) * 100:.0f}%)")
    return warnings


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("json_path", help="benchmark JSON document to gate")
    ap.add_argument("--prev", default=None,
                    help="previous run to diff against (warnings only)")
    ap.add_argument("--warn-threshold", type=float, default=0.2,
                    help="relative slowdown vs --prev that triggers a "
                         "warning (default 0.2 = 20%%)")
    args = ap.parse_args(argv)
    with open(args.json_path) as f:
        doc = json.load(f)

    if args.prev:
        try:
            with open(args.prev) as f:
                prev = json.load(f)
        except OSError as e:
            print(f"GATE WARN: cannot read previous run: {e}",
                  file=sys.stderr)
        else:
            for w in diff_previous(doc, prev, args.warn_threshold):
                print(f"GATE WARN: {w}", file=sys.stderr)

    problems = check(doc)
    for p in problems:
        print(f"GATE FAIL: {p}", file=sys.stderr)
    if not problems:
        print("benchmark gate passed: every fused path beats its unfused "
              "baseline on the gated rows")
    return 1 if problems else 0


if __name__ == "__main__":
    raise SystemExit(main())
