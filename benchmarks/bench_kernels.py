"""Mesh-kernel benchmarks: Pallas (interpret on CPU) vs pure-jnp reference.

On this CPU container the timing is indicative only (interpret mode runs the
kernel body op-by-op); the derived column also reports the kernel's analytic
VMEM working set and FLOPs — the numbers that matter for the TPU target.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from benchmarks.common import SMOKE, row, time_call, time_pair
from repro.core import mesh as mesh_lib
from repro.kernels import ops, ref


def mesh_kernel_sweep(sizes=None, batch=None) -> list[str]:
    sizes = sizes or ((16,) if SMOKE else (16, 64, 256))
    batch = batch or (32 if SMOKE else 256)
    rows = []
    for n in sizes:
        plan = mesh_lib.clements_plan(n)
        params = mesh_lib.init_mesh_params(jax.random.PRNGKey(n), plan)
        x = jax.random.normal(jax.random.PRNGKey(0), (batch, n),
                              jnp.float32).astype(jnp.complex64)
        k_fn = jax.jit(lambda p, xx: ops.mesh_apply(p, xx, n=n, block_b=64))
        r_fn = jax.jit(lambda p, xx: ref.mesh_apply_ref(p, xx, n))
        us_k = time_call(k_fn, params, x, iters=5)
        us_r = time_call(r_fn, params, x, iters=5)
        flops = 2 * plan.n_cells * batch * 16
        vmem_kb = (8 * 64 * (n // 2) * 4 + n * 8 * (n // 2) * 4) / 1024
        rows.append(row(f"mesh_kernel_n{n}", us_k,
                        f"ref_us={us_r:.1f};cells={plan.n_cells};"
                        f"flops={flops};vmem_kb={vmem_kb:.0f}"))
    return rows


def fused_rfnn_linear(n=None, batch=None) -> list[str]:
    n = n or (16 if SMOKE else 64)
    batch = batch or (32 if SMOKE else 256)
    plan = mesh_lib.clements_plan(n)
    vp = mesh_lib.init_mesh_params(jax.random.PRNGKey(0), plan)
    up = mesh_lib.init_mesh_params(jax.random.PRNGKey(1), plan)
    atten = jax.random.uniform(jax.random.PRNGKey(2), (n,))
    x = jax.random.normal(jax.random.PRNGKey(3), (batch, n))
    fused = jax.jit(lambda v, a, u, xx: ops.rfnn_linear(
        v, a, u, xx, n=n, block_b=64))
    unfused = jax.jit(lambda v, a, u, xx: ref.rfnn_linear_ref(
        v, a, u, xx.astype(jnp.complex64), n))
    us_f = time_call(fused, vp, atten, up, x, iters=5)
    us_u = time_call(unfused, vp, atten, up, x, iters=5)
    # fused kernel does 1 HBM round-trip instead of 3 (V out, D out, U out)
    hbm_unfused = 3 * 2 * batch * n * 8
    hbm_fused = 2 * batch * n * 8
    return [row("rfnn_linear_fused", us_f,
                f"unfused_us={us_u:.1f};"
                f"hbm_bytes {hbm_fused} vs {hbm_unfused} (3x saved)")]


def mesh_kernel_fwd_bwd(sizes=None, batch=None) -> list[str]:
    """fwd+bwd through the mesh: kernel custom-VJP vs reference autodiff.

    The kernel backward is one reversed-column Pallas sweep
    (inverse/adjoint, DESIGN.md) instead of lax.scan's stored-intermediate
    transpose; the derived column reports the residual HBM bytes autodiff
    would have stored per column and the max grad deviation between the
    two paths.
    """
    sizes = sizes or ((16,) if SMOKE else (16, 64))
    batch = batch or (64 if SMOKE else 128)
    rows = []
    for n in sizes:
        plan = mesh_lib.clements_plan(n)
        params = mesh_lib.init_mesh_params(jax.random.PRNGKey(n), plan)
        k = jax.random.PRNGKey(0)
        x = (jax.random.normal(k, (batch, n))
             + 1j * jax.random.normal(jax.random.fold_in(k, 1),
                                      (batch, n))).astype(jnp.complex64)

        def loss_k(p, xx, n=n):
            return jnp.sum(jnp.abs(ops.mesh_apply(p, xx, n=n, block_b=64)))

        def loss_r(p, xx, n=n):
            return jnp.sum(jnp.abs(ref.mesh_apply_ref(p, xx, n)))

        k_fn = jax.jit(jax.grad(loss_k))
        r_fn = jax.jit(jax.grad(loss_r))
        us_k = time_call(k_fn, params, x, iters=3)
        us_r = time_call(r_fn, params, x, iters=3)
        gk, gr = k_fn(params, x), r_fn(params, x)
        err = max(float(jnp.max(jnp.abs(a - b)))
                  for a, b in zip(jax.tree.leaves(gk), jax.tree.leaves(gr)))
        saved_bytes = n * batch * n * 8  # autodiff: one complex panel/column
        rows.append(row(f"mesh_fwd_bwd_n{n}", us_k,
                        f"ref_autodiff_us={us_r:.1f};max_grad_err={err:.1e};"
                        f"residual_hbm_bytes_saved={saved_bytes}"))
    return rows


def mesh_fwd_bwd_nonideal(sizes=None, batch=None) -> list[str]:
    """fwd+bwd with the hardware model and a Reck layout, both paths.

    The paper-faithful configurations (imperfect hybrids, per-cell
    insertion loss, triangular analytic programs) used to fall back to the
    reference path; these rows benchmark them *through the generalized
    kernel* (inverse/adjoint backward) against reference autodiff of
    ``apply_mesh_hw`` / ``apply_mesh``.
    """
    from repro.core import decompose
    from repro.core import hardware as hw_lib

    sizes = sizes or ((8,) if SMOKE else (8, 16))
    batch = batch or (64 if SMOKE else 128)
    hw = hw_lib.HardwareModel(phase_sigma=0.0, detector_sigma=0.0)
    rows = []
    for n in sizes:
        k = jax.random.PRNGKey(0)
        x = (jax.random.normal(k, (batch, n))
             + 1j * jax.random.normal(jax.random.fold_in(k, 1),
                                      (batch, n))).astype(jnp.complex64)
        cplan = mesh_lib.clements_plan(n)
        cparams = mesh_lib.init_mesh_params(jax.random.PRNGKey(n), cplan)
        rplan, rparams = decompose.reck_program(
            decompose.random_unitary(n, seed=n))
        for tag, plan, params, hmodel in [
                ("hw", cplan, cparams, hw),
                ("reck", rplan, rparams, None)]:
            def loss_k(p, xx, plan=plan, hmodel=hmodel, n=n):
                return jnp.sum(jnp.abs(ops.mesh_apply(
                    p, xx, n=n, plan=plan, hardware=hmodel, block_b=64)))

            def loss_r(p, xx, plan=plan, hmodel=hmodel):
                if hmodel is not None:
                    y = hw_lib.apply_mesh_hw(plan, p, xx, hmodel)
                else:
                    y = mesh_lib.apply_mesh(plan, p, xx)
                return jnp.sum(jnp.abs(y))

            k_fn = jax.jit(jax.grad(loss_k))
            r_fn = jax.jit(jax.grad(loss_r))
            us_k = time_call(k_fn, params, x, iters=3)
            us_r = time_call(r_fn, params, x, iters=3)
            gk, gr = k_fn(params, x), r_fn(params, x)
            err = max(float(jnp.max(jnp.abs(a - b)))
                      for a, b in zip(jax.tree.leaves(gk),
                                      jax.tree.leaves(gr)))
            rows.append(row(f"mesh_fwd_bwd_{tag}_n{n}", us_k,
                            f"ref_autodiff_us={us_r:.1f};"
                            f"max_grad_err={err:.1e}"))
    return rows


def mc_yield_sweep() -> list[str]:
    """Monte-Carlo hardware-yield sweep, vmapped over the Pallas kernel."""
    from repro.paper.efficiency import monte_carlo_yield

    n_draws = 8 if SMOKE else 32
    import time as _time
    monte_carlo_yield(n=8, n_draws=n_draws, backend="pallas")  # warm caches
    t0 = _time.perf_counter()
    res = monte_carlo_yield(n=8, n_draws=n_draws, backend="pallas")
    us = (_time.perf_counter() - t0) * 1e6
    return [row("mc_yield_n8", us,
                f"yield={res['yield']:.2f};draws={n_draws};"
                f"mean_err={res['mean_error']:.3f};"
                f"worst_err={res['worst_error']:.3f}")]


def rfnn_linear_fwd_bwd(n=16, batch=None) -> list[str]:
    """fwd+bwd through the fused analog linear layer, both paths."""
    batch = batch or (64 if SMOKE else 128)
    plan = mesh_lib.clements_plan(n)
    vp = mesh_lib.init_mesh_params(jax.random.PRNGKey(0), plan)
    up = mesh_lib.init_mesh_params(jax.random.PRNGKey(1), plan)
    atten = jax.random.uniform(jax.random.PRNGKey(2), (n,), minval=0.1,
                               maxval=0.9)
    x = jax.random.normal(jax.random.PRNGKey(3), (batch, n))

    def loss_k(v, a, u, xx):
        return jnp.sum(ops.rfnn_linear(v, a, u, xx, n=n, block_b=64))

    def loss_r(v, a, u, xx):
        return jnp.sum(ref.rfnn_linear_ref(v, a, u,
                                           xx.astype(jnp.complex64), n))

    k_fn = jax.jit(jax.grad(loss_k, argnums=(0, 1, 2)))
    r_fn = jax.jit(jax.grad(loss_r, argnums=(0, 1, 2)))
    us_k = time_call(k_fn, vp, atten, up, x, iters=3)
    us_r = time_call(r_fn, vp, atten, up, x, iters=3)
    gk, gr = k_fn(vp, atten, up, x), r_fn(vp, atten, up, x)
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(gk), jax.tree.leaves(gr)))
    # bwd residuals: 2 stage boundaries vs one complex panel per column
    hbm_kernel = 2 * 4 * batch * (n // 2) * 4
    hbm_autodiff = 2 * n * batch * n * 8
    return [row("rfnn_linear_fwd_bwd", us_k,
                f"ref_autodiff_us={us_r:.1f};max_grad_err={err:.1e};"
                f"residual_hbm_bytes {hbm_kernel} vs {hbm_autodiff}")]


def net_fwd_bwd(configs=None, n_layers=4) -> list[str]:
    """fwd+bwd through the whole L-layer RFNN: megakernel vs per-layer.

    The per-layer baseline composes L fused ``rfnn_linear`` kernels (each
    already one pallas_call per direction); the megakernel runs the entire
    network in ONE pallas_call per direction, keeping inter-layer
    activations VMEM-resident and saving only the L-1 boundary magnitudes
    as residuals.  The derived column reports the per-layer composition's
    timing, the residual-plane count each path stores, and the max grad
    deviation.  ``net_fwd_bwd_n16_b1024`` is the CI fusion gate row.
    """
    from repro.kernels.ops import rfnn_network

    configs = configs or (((16, 1024),) if SMOKE
                          else ((8, 256), (16, 256), (16, 1024), (16, 2048)))
    rows = []
    for n, batch in configs:
        plan = mesh_lib.clements_plan(n)
        layers = []
        for l in range(n_layers):
            kv, ku, ka = jax.random.split(jax.random.PRNGKey(100 + l), 3)
            layers.append({
                "v": mesh_lib.init_mesh_params(kv, plan),
                "u": mesh_lib.init_mesh_params(ku, plan),
                "atten": jax.random.uniform(ka, (n,), minval=0.2,
                                            maxval=0.9),
                "scale": 1.0,
            })
        layers = tuple(layers)
        x = jax.random.normal(jax.random.PRNGKey(0), (batch, n))
        w = 1.0 + jnp.arange(n, dtype=jnp.float32)  # break |.|-degeneracy

        def per_layer(ls, xx):
            h = xx
            for la in ls:
                h = ops.rfnn_linear(la["v"], la["atten"], la["u"], h, n=n,
                                    scale=la["scale"])
            return h

        def loss_net(ls, xx):
            return jnp.sum(rfnn_network(ls, xx, n=n) * w)

        def loss_pl(ls, xx):
            return jnp.sum(per_layer(ls, xx) * w)

        net_fn = jax.jit(jax.grad(loss_net))
        pl_fn = jax.jit(jax.grad(loss_pl))
        # min-of-N: this row is a differential CI gate on a shared runner,
        # so use the noise-robust estimator for both sides
        us_net = time_call(net_fn, layers, x, iters=5, reduce="min")
        us_pl = time_call(pl_fn, layers, x, iters=5, reduce="min")
        gn, gp = net_fn(layers, x), pl_fn(layers, x)
        scale_ref = max(float(jnp.max(jnp.abs(g)))
                        for g in jax.tree.leaves(gp))
        err = max(float(jnp.max(jnp.abs(a - b)))
                  for a, b in zip(jax.tree.leaves(gn), jax.tree.leaves(gp)))
        rel = err / (scale_ref + 1e-30)
        # both paths save 8 stage-residual planes per layer; the fusion win
        # is the inter-layer activation round trips (write + fwd read +
        # bwd read per boundary) and 2L-2 fewer kernel launches/direction
        interlayer = 3 * (n_layers - 1) * batch * n * 4
        rows.append(row(f"net_fwd_bwd_n{n}_b{batch}", us_net,
                        f"per_layer_us={us_pl:.1f};layers={n_layers};"
                        f"max_grad_rel_err={rel:.1e};"
                        f"interlayer_hbm_bytes 0 vs {interlayer};"
                        f"pallas_calls 2 vs {2 * n_layers}"))
    return rows


def tiled_apply_grid(n=64, tile=16, batch=256) -> list[str]:
    """Tile-grid megakernel vs the double-vmapped per-tile composition.

    The baseline is what ``TiledAnalogLinear(backend="pallas")`` used to
    run before the tile-grid kernel: vmap over the input-tile axis, then
    the output-tile axis, of a single-tile V -> diag -> U -> scale chain —
    To*Ti separate mesh applications with per-tile packing and HBM
    round-trips between tile rows.  The megakernel runs the whole grid in
    ONE pallas_call per direction.  ``tiled_apply_n64`` is a CI gate row
    (64x64, tile=16, B=256 — the first genuinely >8x8 analog workload),
    so the configuration does NOT shrink under BENCH_SMOKE.
    """
    import numpy as np

    from repro.kernels.ops import tiled_apply

    to, ti = n // tile, n // tile
    plan = mesh_lib.clements_plan(tile)
    tiles = []
    for o in range(to):
        trow = []
        for i in range(ti):
            kv, ku, ka = jax.random.split(
                jax.random.fold_in(jax.random.PRNGKey(7), o * ti + i), 3)
            trow.append({
                "v": mesh_lib.init_mesh_params(kv, plan),
                "u": mesh_lib.init_mesh_params(ku, plan),
                "atten": jax.random.uniform(ka, (tile,), minval=0.2,
                                            maxval=0.9),
                "scale": 1.0 + 0.05 * (o + i),
            })
        tiles.append(tuple(trow))
    tiles = tuple(tiles)
    # the vmapped baseline consumes the same parameters stacked [To, Ti, .]
    stacked = jax.tree.map(lambda *rows: jnp.stack(rows), *[
        jax.tree.map(lambda *ts: jnp.stack(ts), *row) for row in tiles])
    x = jax.random.normal(jax.random.PRNGKey(0), (batch, n))
    w = 1.0 + jnp.arange(n, dtype=jnp.float32)  # break |.|-degeneracy

    def vmapped(ps, xx):
        xt = xx.astype(jnp.complex64).reshape(xx.shape[:-1] + (ti, tile))

        def one_tile(p, xin):
            h = ops.mesh_apply(p["v"], xin, n=tile)
            h = h * p["atten"].astype(jnp.complex64)
            y = ops.mesh_apply(p["u"], h, n=tile)
            return p["scale"].astype(jnp.complex64) * y

        def row_f(prow):
            ys = jax.vmap(one_tile, in_axes=(0, -2), out_axes=-2)(prow, xt)
            return jnp.sum(ys, axis=-2)

        y = jax.vmap(row_f, in_axes=0, out_axes=-2)(ps)
        return y.reshape(y.shape[:-2] + (n,))

    def loss_k(ts, xx):
        return jnp.sum(jnp.abs(tiled_apply(ts, xx, n=tile)) * w)

    def loss_v(ps, xx):
        return jnp.sum(jnp.abs(vmapped(ps, xx)) * w)

    k_fn = jax.jit(jax.grad(loss_k))
    v_fn = jax.jit(jax.grad(loss_v))
    # min-of-N: this row is a differential CI gate on a shared runner
    us_k = time_call(k_fn, tiles, x, iters=3, reduce="min")
    us_v = time_call(v_fn, stacked, x, iters=3, reduce="min")
    g_tiles = k_fn(tiles, x)
    g_stack = v_fn(stacked, x)
    # kernel grads come back per-tile; compare tile-for-tile with the
    # vmapped baseline's stacked gradient (same dict structure per tile)
    scale_ref = max(float(jnp.max(jnp.abs(g)))
                    for g in jax.tree.leaves(g_stack))
    err = max(
        float(jnp.max(jnp.abs(a - b)))
        for o in range(to) for i in range(ti)
        for a, b in zip(
            jax.tree.leaves(g_tiles[o][i]),
            jax.tree.leaves(jax.tree.map(
                lambda g, o=o, i=i: g[o, i], g_stack))))
    rel = err / (scale_ref + 1e-30)
    # the fusion win: 2 pallas_calls/direction vs 2*To*Ti, and no HBM
    # round trip of the [B, tile] panel between V and U of every tile
    intertile = 2 * to * ti * batch * tile * 8
    return [row(f"tiled_apply_n{n}", us_k,
                f"per_tile_us={us_v:.1f};grid={to}x{ti};tile={tile};"
                f"max_grad_rel_err={rel:.1e};"
                f"intertile_hbm_bytes 0 vs {intertile};"
                f"pallas_calls 2 vs {2 * to * ti}")]


def deepgrid_fwd_bwd(n=64, tile=16, n_layers=4, batches=None) -> list[str]:
    """Deep tiled-network megakernel vs the per-layer tile-grid composition.

    The baseline composes L tile-grid megakernels (each already ONE
    pallas_call per direction) with the inter-layer power detection in
    plain JAX — L-1 activation round trips through HBM plus 2L kernel
    launches per direction.  The deep kernel runs the whole L x To x Ti
    cascade in ONE pallas_call per direction, detecting and re-injecting
    between layers inside VMEM; only the per-layer stage planes leave the
    kernel (as VJP residuals — the same count the composition stores).
    ``deepgrid_fwd_bwd_n64_l4`` (B=1024) is a CI gate row, so that
    configuration does NOT shrink under BENCH_SMOKE.
    """
    from repro.kernels.ops import deep_apply, tiled_apply

    batches = batches or ((1024,) if SMOKE else (256, 1024))
    g = n // tile
    plan = mesh_lib.clements_plan(tile)
    layers = []
    for l in range(n_layers):
        lrows = []
        for o in range(g):
            trow = []
            for i in range(g):
                kv, ku, ka = jax.random.split(jax.random.fold_in(
                    jax.random.PRNGKey(7), (l * g + o) * g + i), 3)
                trow.append({
                    "v": mesh_lib.init_mesh_params(kv, plan),
                    "u": mesh_lib.init_mesh_params(ku, plan),
                    "atten": jax.random.uniform(ka, (tile,), minval=0.2,
                                                maxval=0.9),
                    "scale": 1.0 + 0.05 * (o + i + l),
                })
            lrows.append(tuple(trow))
        layers.append(tuple(lrows))
    layers = tuple(layers)
    w = 1.0 + jnp.arange(n, dtype=jnp.float32)  # break |.|-degeneracy

    def per_layer(ls, xx):
        h = xx
        for tiles in ls:
            h = jnp.abs(tiled_apply(tiles, h, n=tile))
        return h

    def loss_deep(ls, xx):
        return jnp.sum(deep_apply(ls, xx, n=tile) * w)

    def loss_pl(ls, xx):
        return jnp.sum(per_layer(ls, xx) * w)

    deep_fn = jax.jit(jax.grad(loss_deep))
    pl_fn = jax.jit(jax.grad(loss_pl))
    rows = []
    for batch in batches:
        x = jax.random.normal(jax.random.PRNGKey(0), (batch, n))
        # interleaved min-of-7: the B=1024 row is a differential CI gate
        # on a shared runner, so both sides must sample the same load
        us_d, us_p = time_pair(deep_fn, pl_fn, layers, x)
        gd, gp = deep_fn(layers, x), pl_fn(layers, x)
        scale_ref = max(float(jnp.max(jnp.abs(gr)))
                        for gr in jax.tree.leaves(gp))
        err = max(float(jnp.max(jnp.abs(a - b)))
                  for a, b in zip(jax.tree.leaves(gd), jax.tree.leaves(gp)))
        rel = err / (scale_ref + 1e-30)
        # fusion win: boundary activations (write + fwd read + bwd read
        # per boundary) never touch HBM, and 2L-2 fewer launches/direction
        boundary = 3 * (n_layers - 1) * batch * n * 4
        name = (f"deepgrid_fwd_bwd_n{n}_l{n_layers}" if batch == 1024
                else f"deepgrid_fwd_bwd_n{n}_l{n_layers}_b{batch}")
        rows.append(row(name, us_d,
                        f"per_layer_us={us_p:.1f};layers={n_layers};"
                        f"grid={g}x{g};tile={tile};batch={batch};"
                        f"max_grad_rel_err={rel:.1e};"
                        f"interlayer_hbm_bytes 0 vs {boundary};"
                        f"pallas_calls 2 vs {2 * n_layers}"))
    return rows


def tiled_apply_sharded(n=64, tile=16, batch=256) -> list[str]:
    """shard_map scale-out of the tile-grid megakernel vs single-device.

    Runs the same 64x64 fwd+bwd workload as ``tiled_apply_n64`` through
    ``tiled_apply(mesh=...)`` — tile rows sharded over ``rows``, batch
    over ``data`` — and reports the single-device megakernel as the
    baseline.  Skipped (returns no rows) on a 1-device host: launch with
    ``BENCH_HOST_DEVICES=8`` to force a host-device mesh.  On forced CPU
    host devices the collectives go through shared memory, so the timing
    only sanity-checks overhead; the row is allowlisted as noisy in the
    gate (``check_gate.NOISY_ROWS``).
    """
    import numpy as np

    from jax.sharding import Mesh
    from repro.kernels.ops import tiled_apply

    to, ti = n // tile, n // tile
    n_dev = len(jax.devices())
    nr = max(d for d in range(1, to + 1) if to % d == 0 and d <= n_dev)
    nd = max(d for d in (1, 2, 4) if nr * d <= n_dev)
    if nr * nd < 2:
        return []
    mesh = Mesh(np.array(jax.devices()[: nr * nd]).reshape(nr, nd),
                ("rows", "data"))
    plan = mesh_lib.clements_plan(tile)
    tiles = []
    for o in range(to):
        trow = []
        for i in range(ti):
            kv, ku, ka = jax.random.split(
                jax.random.fold_in(jax.random.PRNGKey(7), o * ti + i), 3)
            trow.append({
                "v": mesh_lib.init_mesh_params(kv, plan),
                "u": mesh_lib.init_mesh_params(ku, plan),
                "atten": jax.random.uniform(ka, (tile,), minval=0.2,
                                            maxval=0.9),
                "scale": 1.0 + 0.05 * (o + i),
            })
        tiles.append(tuple(trow))
    tiles = tuple(tiles)
    x = jax.random.normal(jax.random.PRNGKey(0), (batch, n))
    w = 1.0 + jnp.arange(n, dtype=jnp.float32)

    def loss(ts, xx, mesh=None):
        return jnp.sum(jnp.abs(tiled_apply(ts, xx, n=tile, mesh=mesh)) * w)

    sh_fn = jax.jit(jax.grad(lambda ts, xx: loss(ts, xx, mesh=mesh)))
    sd_fn = jax.jit(jax.grad(loss))
    us_sh = time_call(sh_fn, tiles, x, iters=3, reduce="min")
    us_sd = time_call(sd_fn, tiles, x, iters=3, reduce="min")
    g_sh, g_sd = sh_fn(tiles, x), sd_fn(tiles, x)
    scale_ref = max(float(jnp.max(jnp.abs(g)))
                    for g in jax.tree.leaves(g_sd))
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(g_sh), jax.tree.leaves(g_sd)))
    return [row(f"tiled_apply_sharded_n{n}", us_sh,
                f"single_device_us={us_sd:.1f};mesh={nr}x{nd};"
                f"grid={to}x{ti};tile={tile};"
                f"max_grad_rel_err={err / (scale_ref + 1e-30):.1e}")]


def compile_apply(n=16, batch=None) -> list[str]:
    """Compiled-program apply vs the retired reference synthesis chain.

    The compiler's ``lower`` pass emits megakernel tensors once; ``apply``
    is then a single fused ``pallas_call``.  The baseline is what
    ``SynthesizedMatrix.apply`` used to run before the repoint: two
    pure-jnp ``apply_mesh`` column scans (V, U) with the diagonal and
    digital scale between them.  ``compile_apply_n16`` is a CI gate row.
    """
    import numpy as np

    from repro import compile as compile_mod

    batch = batch or (64 if SMOKE else 256)
    m = np.random.default_rng(0).normal(size=(n, n))
    prog = compile_mod.program(compile_mod.synthesize(m), method="reck")
    compiled = compile_mod.lower(prog, block_b=64)
    la = prog.layers[0]
    atten = la.attenuation.astype(jnp.complex64)
    scale = jnp.asarray(la.scale, jnp.complex64)

    def ref_apply(xx):
        h = mesh_lib.apply_mesh(la.v_plan, la.v_params,
                                xx.astype(jnp.complex64))
        h = h * atten
        h = mesh_lib.apply_mesh(la.u_plan, la.u_params, h)
        return jnp.abs(scale * h)

    x = jax.random.normal(jax.random.PRNGKey(0), (batch, n), jnp.float32)
    k_fn = compiled.apply
    r_fn = jax.jit(ref_apply)
    err = float(jnp.max(jnp.abs(k_fn(x) - r_fn(x))))
    # min-of-N: this row is a differential CI gate on a shared runner
    us_k = time_call(k_fn, x, iters=5, reduce="min")
    us_r = time_call(r_fn, x, iters=5, reduce="min")
    # reference: one HBM round-trip per mesh column (2 x (2n-3) columns)
    hbm_ref = 2 * (2 * n - 3) * batch * n * 8
    hbm_kernel = 2 * batch * n * 8
    return [row(f"compile_apply_n{n}", us_k,
                f"ref_apply_us={us_r:.1f};max_err={err:.1e};"
                f"hbm_bytes {hbm_kernel} vs {hbm_ref}")]


def flash_attention_kernel(s=None, hd=64, h=4, b=2) -> list[str]:
    """Flash attention kernel vs dense-softmax reference (interpret mode)."""
    s = s or (256 if SMOKE else 512)
    from repro.kernels.flash_attention import flash_attention
    from repro.kernels.ref import flash_attention_ref

    k1, k2, k3 = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(k1, (b, h, s, hd), jnp.float32)
    k = jax.random.normal(k2, (b, h, s, hd), jnp.float32)
    v = jax.random.normal(k3, (b, h, s, hd), jnp.float32)
    f_fn = jax.jit(lambda q, k, v: flash_attention(q, k, v, bq=128, bk=128))
    r_fn = jax.jit(lambda q, k, v: flash_attention_ref(q, k, v))
    us_f = time_call(f_fn, q, k, v, iters=3)
    us_r = time_call(r_fn, q, k, v, iters=3)
    err = float(jnp.abs(f_fn(q, k, v) - r_fn(q, k, v)).max())
    assert err < 2e-5
    # HBM score traffic eliminated by the kernel (the §Perf memory term)
    score_bytes = b * h * s * s * 4
    return [row("flash_attention", us_f,
                f"dense_us={us_r:.1f};err={err:.1e};"
                f"score_hbm_bytes_saved={score_bytes}")]


def serving_qps(n=64, tile=16, batches=(256, 1024, 4096)) -> list[str]:
    """Sustained serving QPS of the unified engine under a request stream.

    Drives :class:`repro.serving.ServingEngine` over a compiled 64x64
    tile-grid program with a dynamic Poisson-ish arrival stream (mean
    ~1.3 x slots new requests per tick) and reports requests/sec plus
    p50/p99 tick latency at B (= slots) in {256, 1024, 4096}.  The
    baseline is serial per-request serving — one megakernel call per
    request on a [1, n] panel — which is what the slot-batched engine
    exists to beat; ``serving_qps_n64`` gates that win in CI
    (``check_gate.GATED_ROWS``), allowlisted as noisy for absolute
    timings (the python tick loop and thread scheduling dominate the
    microseconds, not the kernels).  The gate configuration does NOT
    shrink under BENCH_SMOKE (only the stream length does).
    """
    import time as time_lib

    import numpy as np

    from repro import compile as compile_mod
    from repro.serving import Request, ServingEngine

    m = np.random.default_rng(0).normal(size=(n, n)) / np.sqrt(n)
    comp = compile_mod.lower_tiled(compile_mod.program_tiled(
        compile_mod.synthesize_tiled(m, tile=tile), method="reck"),
        block_b=64)

    feats = np.random.default_rng(1).normal(
        size=(256, n)).astype(np.float32)
    # serial baseline: one request per megakernel call, no batching win
    one = jnp.asarray(feats[:1])
    serial_us = time_call(comp.apply, one, warmup=2, iters=5, reduce="min")

    rounds = 2 if SMOKE else 3
    us_gate = None
    parts = [f"serial_us={serial_us:.1f}"]
    for b in batches:
        engine = ServingEngine(comp, slots=b)
        jax.block_until_ready(
            comp.apply(jnp.zeros((b, n), jnp.float32)))  # warm panel shape
        rng = np.random.default_rng(b)
        total = rounds * b
        rid = 0
        t0 = time_lib.perf_counter()
        while rid < total:
            burst = min(int(rng.poisson(1.3 * b)), total - rid)
            for _ in range(burst):
                engine.submit(Request(rid=rid, features=feats[rid % 256]))
                rid += 1
            engine.tick()
        engine.run()            # drain the tail of the stream
        elapsed = time_lib.perf_counter() - t0
        assert engine.stats["served"] == total
        if b == batches[0]:
            us_gate = elapsed / total * 1e6
        parts.append(
            f"b{b}_qps={total / elapsed:.0f};"
            f"b{b}_p50_tick_us={engine.slo.percentile_us(50):.0f};"
            f"b{b}_p99_tick_us={engine.slo.percentile_us(99):.0f}")
    return [row(f"serving_qps_n{n}", us_gate, ";".join(parts))]


ALL = [mesh_kernel_sweep, fused_rfnn_linear, mesh_kernel_fwd_bwd,
       mesh_fwd_bwd_nonideal, mc_yield_sweep, rfnn_linear_fwd_bwd,
       net_fwd_bwd, tiled_apply_grid, deepgrid_fwd_bwd,
       tiled_apply_sharded, compile_apply, flash_attention_kernel,
       serving_qps]
