import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Roofline analysis per (arch x shape) on the single-pod production mesh.

For each cell the compiled dry-run artifact yields per-device:
  * HLO_FLOPs        — trip-count-corrected dot FLOPs (hlo_analysis walker;
                       XLA's cost_analysis counts while bodies once and is
                       reported alongside as a cross-check);
  * HLO_bytes        — dot operand/result bytes (HBM-traffic proxy: XLA
                       fuses elementwise chains into dot producers/consumers);
  * collective_bytes — output bytes of all collective ops, loop-scaled.

Terms (TPU v5e): compute = FLOPs / 197e12, memory = bytes / 819e9,
collective = coll_bytes / 50e9 (per-chip ICI).  The roofline fraction is
useful model FLOPs per chip / (peak * dominant term) — the score to push
toward 1.0.

    PYTHONPATH=src:. python -m benchmarks.roofline --all
    PYTHONPATH=src:. python -m benchmarks.roofline --arch gemma-2b --shape train_4k
"""

import argparse
import json
import sys
import time
from pathlib import Path

import jax

PEAK_FLOPS = 197e12     # bf16 per chip
HBM_BW = 819e9          # bytes/s per chip
ICI_BW = 50e9           # bytes/s per link per chip


def model_flops_global(cfg, shape) -> float:
    """Useful model FLOPs per step: 6*N_active*tokens train, 2*N*tokens
    inference (+ the causal-attention term)."""
    n_active = cfg.active_param_count()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        base = 6.0 * n_active * tokens
        attn_mult = 3.0  # fwd + bwd(2x) for attention scores/values
    elif shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        base = 2.0 * n_active * tokens
        attn_mult = 1.0
    else:  # decode: one token per sequence
        tokens = shape.global_batch
        base = 2.0 * n_active * tokens
        attn_mult = 1.0
    # causal attention flops (dense/moe/vlm/encdec attention layers)
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        h, hd, s = cfg.n_heads, cfg.head_dim, shape.seq_len
        if shape.kind == "decode":
            per_layer = 4.0 * shape.global_batch * s * h * hd
        else:
            per_layer = 2.0 * shape.global_batch * s * s * h * hd  # causal half x2 einsums
        layers = cfg.n_layers + (cfg.n_enc_layers if cfg.family == "encdec" else 0)
        base += attn_mult * layers * per_layer
    return base


def bytes_floor_per_dev(cfg, shape, cell, dp=16, tp=16) -> float:
    """Minimal HBM traffic per device per step (perfect fusion/reuse):
    weights streamed once per use, activations round-tripping HBM once per
    layer, the KV cache (decode), and logits."""
    params_local = cfg.param_count() * 2 / tp          # bf16 copy, TP-sharded
    b_loc = max(1, shape.global_batch // dp)
    layer_act = cfg.n_layers * b_loc * shape.seq_len * cfg.d_model * 2 * 2
    logits = b_loc * shape.seq_len * cfg.vocab_size * 2 / tp
    if shape.kind == "train":
        # fwd + bwd(+remat) activation passes; f32 master/moment update
        opt = cfg.param_count() * 4 * 4 / tp
        return 2 * params_local + opt + 3 * layer_act + 2 * logits
    if shape.kind == "prefill":
        return params_local + layer_act + logits
    # decode: weights (active experts only) + full cache read per token
    act_local = cfg.active_param_count() * 2 / tp
    kv = 0.0
    if cfg.family in ("dense", "moe", "vlm", "encdec"):
        kv = (2 * shape.global_batch * shape.seq_len * cfg.n_kv_heads
              * cfg.head_dim * 2) * cfg.n_layers / (dp * tp)
    return act_local + kv


def coll_floor_per_dev(cfg, shape, prof, dp=16, tp=16) -> float:
    """Minimal collective bytes per device per step, per parallelism plan."""
    b_loc = max(1, shape.global_batch // dp)
    if shape.kind == "train":
        # DP gradient all-reduce: ring moves ~2x the (possibly compressed)
        # gradient shard; with FSDP params the grads are already sharded.
        gbytes = 2 if prof.grad_compression else 4
        shard = cfg.param_count() * gbytes / (16 if prof.fsdp_params else 1)
        floor = 2.0 * shard / (1 if prof.pure_dp_train else tp)
        if not prof.pure_dp_train:
            # Megatron TP: 2 activation ARs fwd + 2 bwd per layer (2x ring)
            floor += cfg.n_layers * 4 * 2 * b_loc * shape.seq_len * cfg.d_model * 2
            if cfg.n_experts:
                # EP: fwd+bwd all-to-all of the slot buffer + its TP psums
                slots = (b_loc * shape.seq_len * cfg.top_k
                         * cfg.capacity_factor)
                n_moe = sum(cfg.is_moe_layer)
                floor += n_moe * slots * cfg.d_model * 2 * (2 + 4 * 2)
        return floor
    # inference: TP activation reduce per layer ~ B*S_step*d per layer
    tok = shape.global_batch * (1 if shape.kind == "decode" else shape.seq_len)
    floor = 2.0 * cfg.n_layers * tok * cfg.d_model * 2 / dp
    if cfg.n_experts:
        slots = tok / dp * cfg.top_k * cfg.capacity_factor
        floor += sum(cfg.is_moe_layer) * slots * cfg.d_model * 2 * 4
    return floor


def run_cell(arch, shape_name, mesh, out_dir: Path, verbose=True):
    from benchmarks import hlo_analysis
    from repro import configs
    from repro.launch import specs as specs_lib

    t0 = time.time()
    cell = specs_lib.build_cell(arch, shape_name, mesh, multi_pod=False)
    compiled = cell.lower().compile()
    res = hlo_analysis.analyze(compiled.as_text())
    ca = compiled.cost_analysis() or {}
    mem = compiled.memory_analysis()

    chips = 256
    shape = configs.SHAPES[shape_name]
    prof = specs_lib.profile_for(arch)
    t_compute = res["flops"] / PEAK_FLOPS
    t_memory = res["dot_bytes"] / HBM_BW
    t_coll = res["collective_bytes"] / ICI_BW
    terms = {"compute": t_compute, "memory": t_memory,
             "collective": t_coll}
    dominant = max(terms, key=terms.get)
    mf = model_flops_global(cell.cfg, shape)
    useful_t = mf / chips / PEAK_FLOPS
    # per-term floors -> the roofline fraction is measured against the
    # dominant term's own floor (MFU-style for compute-bound, bandwidth
    # utilization for memory-bound, minimal-AR for collective-bound)
    floors = {
        "compute": useful_t,
        "memory": bytes_floor_per_dev(cell.cfg, shape, cell) / HBM_BW,
        "collective": coll_floor_per_dev(cell.cfg, shape, prof) / ICI_BW,
    }
    frac = floors[dominant] / max(terms[dominant], 1e-30)
    ratio = (mf / chips) / max(res["flops"], 1.0)

    row = {
        "arch": arch, "shape": shape_name, "chips": chips,
        "hlo_flops_per_dev": res["flops"],
        "hlo_dot_bytes_per_dev": res["dot_bytes"],
        "collective_bytes_per_dev": res["collective_bytes"],
        "coll_breakdown": res["coll"],
        "coll_ops": res["coll_ops"],
        "xla_cost_flops_scan_once": ca.get("flops", 0.0),
        "t_compute_s": t_compute, "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "model_flops_global": mf,
        "useful_ratio": ratio,
        "roofline_fraction": frac,
        "floors_s": floors,
        "peak_bytes_per_dev": (getattr(mem, "argument_size_in_bytes", 0)
                               + getattr(mem, "temp_size_in_bytes", 0)),
        "compile_s": round(time.time() - t0, 1),
    }
    if verbose:
        print(f"{arch:28s} {shape_name:12s} comp {t_compute*1e3:8.3f}ms "
              f"mem {t_memory*1e3:8.3f}ms coll {t_coll*1e3:8.3f}ms "
              f"-> {dominant:10s} frac {frac*100:5.1f}% "
              f"useful {ratio*100:5.1f}%", flush=True)
    out_dir.mkdir(parents=True, exist_ok=True)
    (out_dir / f"{arch}__{shape_name}.json").write_text(
        json.dumps(row, indent=1))
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default="experiments/roofline")
    args = ap.parse_args(argv)

    from repro import configs
    from repro.launch.mesh import make_production_mesh

    if args.all:
        cells = configs.grid()
    else:
        shapes = [args.shape] if args.shape else configs.shapes_for(args.arch)
        cells = [(args.arch, s) for s in shapes]

    mesh = make_production_mesh(multi_pod=False)
    rows = []
    for arch, shape in cells:
        try:
            rows.append(run_cell(arch, shape, mesh, Path(args.out)))
        except Exception as e:  # noqa: BLE001
            print(f"FAIL {arch} {shape}: {e!r}", flush=True)
    return 0


if __name__ == "__main__":
    sys.exit(main())
