"""Render the roofline JSON directory as the EXPERIMENTS.md markdown table.

    PYTHONPATH=src:. python -m benchmarks.summarize [--dir experiments/roofline]
"""

from __future__ import annotations

import argparse
import json
from pathlib import Path


def fmt_ms(s: float) -> str:
    ms = s * 1e3
    return f"{ms:.3f}ms" if ms < 1 else f"{ms:.0f}ms"


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--dir", default="experiments/roofline")
    args = ap.parse_args(argv)

    rows = []
    for f in sorted(Path(args.dir).glob("*.json")):
        rows.append(json.loads(f.read_text()))
    rows.sort(key=lambda r: (r["arch"], r["shape"]))

    print("| arch | shape | compute | memory | collective | dominant "
          "| frac | useful |")
    print("|---|---|---:|---:|---:|---|---:|---:|")
    for r in rows:
        frac = min(r["roofline_fraction"], 1.0)
        useful = min(r["useful_ratio"], 1.3)
        print(f"| {r['arch']} | {r['shape']} | {fmt_ms(r['t_compute_s'])} "
              f"| {fmt_ms(r['t_memory_s'])} | {fmt_ms(r['t_collective_s'])} "
              f"| {r['dominant']} | {frac*100:.0f}% | {useful*100:.0f}% |")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
