"""Trip-count-aware analysis of partitioned HLO.

``compiled.cost_analysis()`` counts while-loop bodies **once**, which
undercounts scanned-layer programs by ~n_layers x.  This walker parses the
partitioned HLO text into computation blocks, extracts while-loop trip
counts from their condition computations, and accumulates dot FLOPs,
dot/collective byte traffic and collective ops with the correct loop
multipliers.  Shapes in the partitioned module are per-device, so
replication and padding waste (e.g. 24 heads on a 16-way axis) are captured
exactly.
"""

from __future__ import annotations

import dataclasses
import re

_DTYPE_BYTES = {"f64": 8, "f32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "pred": 1, "s8": 1,
                "u8": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(
    r"(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)"
    r"\[([0-9,]*)\]")

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

_HEADER_RE = re.compile(r"^(?:ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->\s*.+\{\s*$")
_INSTR_RE = re.compile(r"^(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(.+)$")


def _first_shape(text: str):
    m = _SHAPE_RE.search(text)
    if not m:
        return None
    dims = [int(d) for d in m.group(2).split(",") if d]
    n = 1
    for d in dims:
        n *= d
    return dims, n, n * _DTYPE_BYTES[m.group(1)]


def _all_shapes_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        n = 1
        for d in m.group(2).split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[m.group(1)]
    return total


@dataclasses.dataclass
class Computation:
    name: str
    lines: list
    flops: float = 0.0
    dot_bytes: float = 0.0
    coll_ops: int = 0
    coll_bytes: dict = dataclasses.field(
        default_factory=lambda: {k: 0.0 for k in _COLLECTIVES})
    calls: list = dataclasses.field(default_factory=list)


def split_computations(hlo: str):
    """-> (computations, symbol table of instruction/param shapes)."""
    comps: dict[str, Computation] = {}
    symbols: dict[str, str] = {}
    current = None
    for raw in hlo.splitlines():
        s = raw.strip()
        if not s or s.startswith("//"):
            continue
        if s == "}" or s.startswith("} "):
            current = None
            continue
        hm = _HEADER_RE.match(s)
        if hm and " = " not in s.split("->")[0]:
            current = Computation(name=hm.group(1), lines=[])
            comps[current.name] = current
            # header params: "name: type" pairs
            for pm in re.finditer(r"([\w.\-]+):\s*((?:\([^)]*\))|"
                                  r"(?:[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?))",
                                  s):
                symbols.setdefault(pm.group(1), pm.group(2))
            continue
        im = _INSTR_RE.match(s)
        if im:
            symbols[im.group(1)] = im.group(2)
            if current is not None:
                current.lines.append(s)
    return comps, symbols


_PASSTHRU_RE = re.compile(
    r"^[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?\s+"
    r"(?:fusion|convert|copy|bitcast|transpose|reshape|broadcast)"
    r"\(\s*%?([\w.\-]+)\s*\)")

_GTE_RE = re.compile(
    r"get-tuple-element\(\s*%?([\w.\-]+)\s*\),\s*index=(\d+)")


def _source_dtype_bytes(name: str, symbols: dict, body_env: dict,
                        comp_name: str, hops: int = 8) -> int | None:
    """Per-element bytes of the value actually streamed from memory.

    Follows single-arg passthrough chains (fusion/convert/copy/...) and
    while-loop plumbing (get-tuple-element of a loop parameter -> the loop's
    init tuple element).  This undoes the CPU backend's bf16->f32 hoisting:
    a bf16 weight converted to f32 *outside* the loop is still streamed as
    bf16 on the TPU target."""
    cur = name
    for _ in range(hops):
        sym = symbols.get(cur, "")
        m = _PASSTHRU_RE.match(sym)
        if m:
            cur = m.group(1)
            continue
        # multi-operand elementwise fusion: follow the largest operand
        mf = re.match(r"^[a-z0-9]+\[[0-9,]*\](?:\{[^}]*\})?\s+fusion\(([^)]*)\)",
                      sym)
        if mf:
            best, best_elems = None, -1
            for part in mf.group(1).split(","):
                cand = part.strip().lstrip("%")
                sh = _first_shape(symbols.get(cand, ""))
                if sh and sh[1] > best_elems:
                    best, best_elems = cand, sh[1]
            if best is not None:
                cur = best
                continue
            break
        g = _GTE_RE.search(sym)
        if g:
            src, idx = g.group(1), int(g.group(2))
            src_sym = symbols.get(src, "")
            if "parameter(" in src_sym:          # loop body parameter
                elems = body_env.get(comp_name)
                if elems and idx < len(elems):
                    cur = elems[idx]
                    continue
            mw = re.search(r"while\(\s*%?([\w.\-]+)\s*\)", src_sym)
            if mw:                                # GTE of a while result
                tup = symbols.get(mw.group(1), "")
                mt = re.search(r"tuple\((.*)\)", tup)
                if mt:
                    parts = [p.strip().lstrip("%")
                             for p in mt.group(1).split(",")]
                    if idx < len(parts):
                        cur = parts[idx]
                        continue
            break
        break
    dm = _SHAPE_RE.search(symbols.get(cur, ""))
    return _DTYPE_BYTES[dm.group(1)] if dm else None


def _dot_stats(rhs: str, symbols: dict, body_env: dict | None = None,
               comp_name: str = ""):
    """(flops, bytes) for one dot instruction rhs."""
    out = _first_shape(rhs)
    if out is None:
        return 0.0, 0.0
    _, out_elems, out_bytes = out
    m = re.search(r"\bdot\(\s*%?([\w.\-]+)\s*,\s*%?([\w.\-]+)", rhs)
    k = 1
    op_bytes = 0
    if m:
        for gi, side in ((1, "lhs"), (2, "rhs")):
            sym = symbols.get(m.group(gi), "")
            shape = _first_shape(sym)
            if not shape:
                continue
            dims, elems, nominal_bytes = shape
            src = _source_dtype_bytes(m.group(gi), symbols, body_env or {},
                                      comp_name)
            op_bytes += elems * src if src else nominal_bytes
            if side == "lhs":
                mc = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", rhs)
                if mc:
                    for ci in (int(c) for c in mc.group(1).split(",") if c):
                        if ci < len(dims):
                            k *= dims[ci]
    return 2.0 * out_elems * max(k, 1), float(op_bytes + out_bytes)


def _while_trip_count(cond: Computation, symbols: dict) -> int:
    """Trip count from the condition computation (compare vs constant)."""
    for line in cond.lines:
        m = re.search(r"compare\(\s*%?([\w.\-]+)\s*,\s*%?([\w.\-]+)\s*\)",
                      line)
        if m and ("direction=LT" in line or "direction=GT" in line):
            for operand in (m.group(2), m.group(1)):
                sym = symbols.get(operand, "")
                mm = re.search(r"constant\((\d+)\)", sym)
                if mm:
                    return max(1, int(mm.group(1)))
    best = 1
    for line in cond.lines:
        mm = re.search(r"s32\[\]\s+constant\((\d+)\)", line)
        if mm:
            best = max(best, int(mm.group(1)))
    return best


def analyze(hlo: str) -> dict:
    """Trip-count-corrected per-device flops / bytes / collectives."""
    comps, symbols = split_computations(hlo)

    # map while-loop body computations to their init tuple element names so
    # loop-invariant operand dtypes resolve through the loop plumbing
    body_env: dict[str, list] = {}
    for c in comps.values():
        for line in c.lines:
            im = _INSTR_RE.match(line)
            if not im or " while(" not in im.group(2):
                continue
            rhs = im.group(2)
            mbody = re.search(r"body=%?([\w.\-]+)", rhs)
            mop = re.search(r"while\(\s*%?([\w.\-]+)\s*\)", rhs)
            if mbody and mop:
                tup = symbols.get(mop.group(1), "")
                mt = re.search(r"tuple\((.*)\)", tup)
                if mt:
                    body_env[mbody.group(1)] = [
                        p.strip().lstrip("%") for p in mt.group(1).split(",")]

    for c in comps.values():
        for line in c.lines:
            im = _INSTR_RE.match(line)
            if not im:
                continue
            rhs = im.group(2)
            if re.search(r"\bdot\(", rhs):
                f, b = _dot_stats(rhs, symbols, body_env, c.name)
                c.flops += f
                c.dot_bytes += b
            for kind in _COLLECTIVES:
                if re.search(rf"\b{kind}(?:-start)?\(", rhs):
                    if re.search(rf"\b{kind}-done\(", rhs):
                        continue
                    sh = _first_shape(rhs)
                    c.coll_bytes[kind] += sh[2] if sh else 0
                    c.coll_ops += 1
            # call edges
            mcond = re.search(r"condition=%?([\w.\-]+)", rhs)
            mbody = re.search(r"body=%?([\w.\-]+)", rhs)
            if " while(" in rhs and mbody:
                trips = (_while_trip_count(comps[mcond.group(1)], symbols)
                         if mcond and mcond.group(1) in comps else 1)
                c.calls.append((trips, mbody.group(1)))
                continue
            for mcall in re.finditer(
                    r"(?:calls|to_apply|branch_computations)="
                    r"\{?%?([\w.\-]+(?:,\s*%?[\w.\-]+)*)\}?", rhs):
                for callee in re.split(r",\s*%?", mcall.group(1)):
                    if callee in comps:
                        c.calls.append((1, callee))

    memo: dict[str, dict] = {}

    def total(name: str, depth=0) -> dict:
        if name in memo:
            return memo[name]
        c = comps[name]
        agg = {"flops": c.flops, "dot_bytes": c.dot_bytes,
               "coll_ops": c.coll_ops, "coll": dict(c.coll_bytes)}
        if depth < 50:
            for mult, callee in c.calls:
                if callee == name or callee not in comps:
                    continue
                sub = total(callee, depth + 1)
                agg["flops"] += mult * sub["flops"]
                agg["dot_bytes"] += mult * sub["dot_bytes"]
                agg["coll_ops"] += mult * sub["coll_ops"]
                for k in _COLLECTIVES:
                    agg["coll"][k] += mult * sub["coll"][k]
        memo[name] = agg
        return agg

    entry = None
    for name in comps:
        if "main" in name:
            entry = name
            break
    if entry is None:
        entry = next(iter(comps))
    res = total(entry)
    res["collective_bytes"] = sum(res["coll"].values())
    res["entry"] = entry
    res["n_computations"] = len(comps)
    return res
