"""Shared benchmark utilities: timing + CSV rows."""

from __future__ import annotations

import os
import time

import jax

#: Tiny-configuration mode for CI smoke runs: benchmarks shrink sizes /
#: iteration counts so the whole sweep finishes in minutes on a shared
#: runner while still exercising every code path.  Set BENCH_SMOKE=1.
SMOKE = os.environ.get("BENCH_SMOKE", "") == "1"


def time_call(fn, *args, warmup: int = 2, iters: int = 10,
              reduce: str = "median") -> float:
    """Wall time of a (jitted) call in microseconds.

    ``reduce="median"`` (default) or ``"min"`` — min is the conventional
    noise-robust estimator for differential comparisons on shared/loaded
    hosts (both sides lose the same scheduler noise).
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return (times[0] if reduce == "min" else times[len(times) // 2]) * 1e6


def time_pair(fn_a, fn_b, *args, warmup: int = 1,
              iters: int = 7) -> tuple[float, float]:
    """Interleaved differential timing: min-of-``iters`` for two calls.

    Alternating A/B reps inside one loop makes the two estimates sample
    the same machine-load trajectory, so slow drift on a shared runner
    cancels out of the A/B ratio — the property the hard perf gates
    (``benchmarks.check_gate``) actually test.  Non-interleaved min-of-3
    was observed to flip a ~10% true margin on a loaded host.
    """
    for _ in range(warmup):
        jax.block_until_ready(fn_a(*args))
        jax.block_until_ready(fn_b(*args))
    t_a, t_b = [], []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn_a(*args))
        t_a.append(time.perf_counter() - t0)
        t0 = time.perf_counter()
        jax.block_until_ready(fn_b(*args))
        t_b.append(time.perf_counter() - t0)
    return min(t_a) * 1e6, min(t_b) * 1e6


def row(name: str, us_per_call: float | None, derived: str) -> str:
    us = "" if us_per_call is None else f"{us_per_call:.1f}"
    return f"{name},{us},{derived}"
