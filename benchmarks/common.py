"""Shared benchmark utilities: timing + CSV rows."""

from __future__ import annotations

import os
import time

import jax

#: Tiny-configuration mode for CI smoke runs: benchmarks shrink sizes /
#: iteration counts so the whole sweep finishes in minutes on a shared
#: runner while still exercising every code path.  Set BENCH_SMOKE=1.
SMOKE = os.environ.get("BENCH_SMOKE", "") == "1"


def time_call(fn, *args, warmup: int = 2, iters: int = 10,
              reduce: str = "median") -> float:
    """Wall time of a (jitted) call in microseconds.

    ``reduce="median"`` (default) or ``"min"`` — min is the conventional
    noise-robust estimator for differential comparisons on shared/loaded
    hosts (both sides lose the same scheduler noise).
    """
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args))
        times.append(time.perf_counter() - t0)
    times.sort()
    return (times[0] if reduce == "min" else times[len(times) // 2]) * 1e6


def row(name: str, us_per_call: float | None, derived: str) -> str:
    us = "" if us_per_call is None else f"{us_per_call:.1f}"
    return f"{name},{us},{derived}"
