"""Benchmark harness entry point: one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows and writes the same rows as
a JSON document for trajectory tracking: by default (kernel suites) to
``BENCH_kernels.json`` at the repo root — the committed copy is the
previous run the CI smoke job diffs fresh numbers against
(``benchmarks.check_gate --prev``) before uploading the new document.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run --only fig15
    BENCH_SMOKE=1 PYTHONPATH=src python -m benchmarks.run \
        --only kernel --json BENCH_new.json            # CI tiny config
    PYTHONPATH=src python -m benchmarks.run --suite kernels --json -  # no file
"""

from __future__ import annotations

import argparse
import json
import os
import platform
import sys
import time

# BENCH_HOST_DEVICES=8 forces a multi-device host platform so the sharded
# benchmark rows (tiled_apply_sharded_n64) get a real mesh; must be set
# before jax initializes its backends
if os.environ.get("BENCH_HOST_DEVICES"):
    os.environ["XLA_FLAGS"] = (
        os.environ.get("XLA_FLAGS", "") + " --xla_force_host_platform_"
        f"device_count={int(os.environ['BENCH_HOST_DEVICES'])}").strip()

import jax

jax.config.update("jax_platform_name", "cpu")

_REPO_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_JSON = os.path.join(_REPO_ROOT, "BENCH_kernels.json")


def _git_sha() -> str | None:
    """Commit the benchmarked tree came from, so uploaded ``BENCH_*.json``
    artifacts are traceable in the trajectory diff.  Prefers the CI-pinned
    ``GITHUB_SHA`` (checkouts can be detached/shallow), falls back to
    ``git rev-parse``; ``None`` when neither is available (tarball)."""
    sha = os.environ.get("GITHUB_SHA")
    if sha:
        return sha
    try:
        import subprocess

        out = subprocess.run(
            ["git", "rev-parse", "HEAD"], cwd=_REPO_ROOT,
            capture_output=True, text=True, timeout=10)
        return out.stdout.strip() or None if out.returncode == 0 else None
    except (OSError, subprocess.SubprocessError):
        return None


def _run_context() -> dict:
    """CI / pytest provenance for the JSON header (empty values dropped)."""
    ctx = {
        "ci": os.environ.get("CI"),
        "github_run_id": os.environ.get("GITHUB_RUN_ID"),
        "github_run_attempt": os.environ.get("GITHUB_RUN_ATTEMPT"),
        "github_workflow": os.environ.get("GITHUB_WORKFLOW"),
        "github_job": os.environ.get("GITHUB_JOB"),
        "github_ref": os.environ.get("GITHUB_REF"),
        "pytest": os.environ.get("PYTEST_CURRENT_TEST"),
    }
    return {k: v for k, v in ctx.items() if v}


def _parse_row(line: str) -> dict:
    name, us, derived = line.split(",", 2)
    entry: dict = {"name": name, "derived": derived}
    entry["us_per_call"] = float(us) if us else None
    return entry


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark function names")
    ap.add_argument("--suite", default="all",
                    choices=("all", "paper", "kernels"),
                    help="benchmark module to run")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the rows as a JSON document; defaults to "
                         "BENCH_kernels.json at the repo root when the "
                         "kernels suite runs; '-' disables the file")
    args = ap.parse_args(argv)
    if args.json is None and args.suite in ("all", "kernels"):
        args.json = DEFAULT_JSON
    if args.json == "-":
        args.json = None

    from benchmarks import bench_kernels, bench_paper
    from benchmarks.common import SMOKE

    benches = []
    if args.suite in ("all", "paper"):
        benches += list(bench_paper.ALL)
    if args.suite in ("all", "kernels"):
        benches += list(bench_kernels.ALL)
    if args.only:
        benches = [b for b in benches if args.only in b.__name__]
        if not benches:
            print(f"no benchmark matches {args.only!r}", file=sys.stderr)
            return 1

    print("name,us_per_call,derived")
    failures = 0
    entries: list[dict] = []
    for bench in benches:
        t0 = time.time()
        try:
            for line in bench():
                print(line, flush=True)
                entries.append(_parse_row(line))
        except AssertionError as e:
            failures += 1
            print(f"{bench.__name__},,FAILED_ASSERT:{e}", flush=True)
        except Exception as e:  # noqa: BLE001 - report and continue
            failures += 1
            print(f"{bench.__name__},,ERROR:{type(e).__name__}:{e}",
                  flush=True)
        dt = time.time() - t0
        print(f"# {bench.__name__} done in {dt:.1f}s", file=sys.stderr)

    if args.json:
        doc = {
            "schema": 1,
            "smoke": SMOKE,
            "git_sha": _git_sha(),
            "context": _run_context(),
            "timestamp": time.strftime("%Y-%m-%dT%H:%M:%SZ", time.gmtime()),
            "platform": {
                "python": platform.python_version(),
                "machine": platform.machine(),
                "jax": jax.__version__,
                "backend": jax.default_backend(),
            },
            "failures": failures,
            "rows": entries,
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=2)
        print(f"# wrote {len(entries)} rows to {args.json}", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
