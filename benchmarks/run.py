"""Benchmark harness entry point: one benchmark per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run            # all
    PYTHONPATH=src python -m benchmarks.run --only fig15
"""

from __future__ import annotations

import argparse
import sys
import time

import jax

jax.config.update("jax_platform_name", "cpu")


def main(argv=None) -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="substring filter on benchmark function names")
    args = ap.parse_args(argv)

    from benchmarks import bench_kernels, bench_paper

    benches = list(bench_paper.ALL) + list(bench_kernels.ALL)
    if args.only:
        benches = [b for b in benches if args.only in b.__name__]
        if not benches:
            print(f"no benchmark matches {args.only!r}", file=sys.stderr)
            return 1

    print("name,us_per_call,derived")
    failures = 0
    for bench in benches:
        t0 = time.time()
        try:
            for line in bench():
                print(line, flush=True)
        except AssertionError as e:
            failures += 1
            print(f"{bench.__name__},,FAILED_ASSERT:{e}", flush=True)
        except Exception as e:  # noqa: BLE001 - report and continue
            failures += 1
            print(f"{bench.__name__},,ERROR:{type(e).__name__}:{e}",
                  flush=True)
        dt = time.time() - t0
        print(f"# {bench.__name__} done in {dt:.1f}s", file=sys.stderr)
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(main())
