"""Benchmarks reproducing every paper table/figure (Secs. II-V).

One function per artifact; each returns CSV rows ``name,us_per_call,derived``
and asserts the headline number is in the expected band.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import row, time_call
from repro.core import cell as cell_lib
from repro.core.hardware import imperfect_cell_matrix
from repro.data.digits import load_digits
from repro.data.toys import make_toy_dataset, train_test_split
from repro.paper.efficiency import (
    rfnn_delay_ns,
    rfnn_energy_per_flop_fj,
    rfnn_length_cm,
    rfnn_reconfig_power_mw,
    table2_rows,
)
from repro.paper.mnist_rfnn import confusion_matrix, train_mnist
from repro.paper.prototype import IDEAL_CELL, PROTOTYPE
from repro.paper.rfnn2x2 import accuracy, train_rfnn2x2


def fig3_transfer_curves() -> list[str]:
    """Fig. 3(c)(d): voltage/power transfer vs theta; conservation check."""
    th = jnp.linspace(0, 2 * np.pi, 361)
    fn = jax.jit(lambda t: cell_lib.output_powers(t, 0.0, 0.5e-3, 1.5e-3))
    us = time_call(fn, th)
    p2, p3 = fn(th)
    p2c, p3c = cell_lib.output_powers_closed_form(th, 0.5e-3, 1.5e-3)
    err = float(jnp.abs(p2 - p2c).max() + jnp.abs(p3 - p3c).max())
    cons = float(jnp.abs(p2 + p3 - 2e-3).max())
    assert err < 1e-8 and cons < 1e-8
    return [row("fig3_transfer", us,
                f"closed_form_err={err:.2e};conservation_err={cons:.2e}")]


def fig5_fig6_sparams() -> list[str]:
    """Figs. 5-6: |S| at the six theta states, theory vs prototype model."""
    rows = []
    th = jnp.asarray(cell_lib.TABLE_I_PHASES_RAD)
    phi = jnp.full_like(th, cell_lib.TABLE_I_PHASES_RAD[0])
    t_ideal = imperfect_cell_matrix(th, phi, IDEAL_CELL)
    t_hw = imperfect_cell_matrix(th, phi, PROTOTYPE)
    s21_i = np.abs(np.asarray(t_ideal[..., 0, 0]))
    s21_h = np.abs(np.asarray(t_hw[..., 0, 0]))
    peak_i, peak_h = s21_i.max(), s21_h.max()
    # theory peak is sin(154/2 deg)/sqrt2-normalized <= 0.707; measured lower
    assert peak_h < peak_i <= np.sin(np.deg2rad(154 / 2)) + 1e-6
    loss_db = 20 * np.log10(peak_h / peak_i)
    rows.append(row("fig6_sparams", None,
                    f"peak_s21_theory={peak_i:.3f};peak_s21_hw={peak_h:.3f};"
                    f"excess_loss_db={loss_db:.2f}"))
    # monotone |S21| growth with state index (paper Fig. 6 trend)
    assert (np.diff(s21_i) > 0).all()
    return rows


def fig9_fig10_six_classifiers() -> list[str]:
    """Figs. 9-10: one trained network acts as 6 wedge classifiers via theta.

    For each theta state we generate a wedge dataset oriented at that state's
    boundary and verify the post-processing trains to high accuracy — the
    reconfigurability claim."""
    from repro.paper.rfnn2x2 import RFNN2x2, _train_post

    net = RFNN2x2()
    rows, accs = [], []
    rng = np.random.default_rng(0)
    x = rng.uniform(0, 30, size=(300, 2)).astype(np.float32)
    for tc, th_deg in enumerate(cell_lib.TABLE_I_PHASES_DEG):
        half = np.deg2rad(th_deg) / 2
        # wedge along the state's own orientation: |V2| thresholding region
        feat = np.sin(half) * x[:, 1] + np.cos(half) * x[:, 0]
        y = (feat > np.median(feat)).astype(np.int32)
        params, _ = _train_post(net, tc, 5, x, y, steps=400, seed=tc)
        acc = accuracy(net, params, tc, 5, x, y)
        accs.append(acc)
        rows.append(row(f"fig9_state_L{tc+1}", None, f"acc={acc*100:.1f}%"))
    assert min(accs) > 0.9, accs
    return rows


def fig12_four_datasets() -> list[str]:
    """Fig. 12: four toy classification cases vs the paper's accuracies."""
    targets = {"corner": 94, "diag_up": 98, "diag_down": 96, "ring": 74}
    rows = []
    for case, tgt in targets.items():
        x, y = make_toy_dataset(case, n=400, seed=1)
        xtr, ytr, xte, yte = train_test_split(x, y)
        net, params, codes, info = train_rfnn2x2(xtr, ytr, steps=800, seed=0)
        te = accuracy(net, params, codes["theta"], codes["phi"], xte, yte)
        rows.append(row(f"fig12_{case}", None,
                        f"test_acc={te*100:.1f}%;paper~{tgt}%;"
                        f"state=L{codes['theta']+1}L{codes['phi']+1}"))
        assert te > tgt / 100 - 0.06, (case, te)
    return rows


def fig15_fig16_mnist(n_train=2000, n_test=500, epochs=60) -> list[str]:
    """Figs. 15-16: analog vs digital accuracy + gap, confusion matrix."""
    data = load_digits(n_train=n_train, n_test=n_test, seed=0)
    digital = train_mnist(*data, analog=False, epochs=epochs)
    analog = train_mnist(*data, analog=True, epochs=epochs,
                         schedule="algorithm1")
    gap = digital["test_acc"] - analog["test_acc"]
    cm = confusion_matrix(analog["model"], analog["params"], data[2], data[3])
    diag_frac = np.trace(cm) / cm.sum()
    rows = [
        row("fig15_digital", None,
            f"train={digital['train_acc']*100:.1f}%;"
            f"test={digital['test_acc']*100:.1f}%"),
        row("fig15_analog", None,
            f"train={analog['train_acc']*100:.1f}%;"
            f"test={analog['test_acc']*100:.1f}%"),
        row("fig15_gap", None,
            f"gap={gap*100:.1f}pts;paper_gap=1.5pts"),
        row("fig16_confusion", None,
            f"diag_mass={diag_frac*100:.1f}%"),
    ]
    assert analog["test_acc"] > 0.85
    assert gap < 0.08
    return rows


def table2_efficiency() -> list[str]:
    rows = []
    for r in table2_rows(n=20):
        rows.append(row(f"table2_{r['platform'].split()[0]}", None,
                        f"fj_per_flop={r['fj_per_flop']:.3g};"
                        f"length_cm={r['length_cm']:.1f};delay={r['delay']}"))
    e = rfnn_energy_per_flop_fj(20)
    assert abs(e - 0.025) < 1e-3  # paper: 1/(2N) fJ at N=20
    rows.append(row("table2_scaling", None,
                    f"power_mw_N20={rfnn_reconfig_power_mw(20):.1f};"
                    f"delay_ns_N20={rfnn_delay_ns(20):.2f};"
                    f"length_cm_N20={rfnn_length_cm(20):.1f}"))
    return rows


ALL = [fig3_transfer_curves, fig5_fig6_sparams, fig9_fig10_six_classifiers,
       fig12_four_datasets, fig15_fig16_mnist, table2_efficiency]
