"""Serve a small LM through the unified serving engine.

Demonstrates the `repro.serving` API on the LM decode path: one
`ServingEngine` with a background dispatch thread, `Request(prompt=...)`
futures submitted from the caller's thread, continuous batching onto
fixed decode slots, and the per-request SLO stats.

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch granite-3-2b]
"""

import argparse

import jax
import numpy as np

from repro import configs
from repro.models import Model
from repro.serving import Request, ServingEngine

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="granite-3-2b")
ap.add_argument("--gen", type=int, default=16)
ap.add_argument("--requests", type=int, default=8)
ap.add_argument("--slots", type=int, default=4)
args = ap.parse_args()

for arch in dict.fromkeys([args.arch, "mamba2-780m"]):
    print(f"\n=== serving {arch} (reduced) ===")
    cfg = configs.get_reduced(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))

    engine = ServingEngine(model, params, slots=args.slots, max_len=64,
                           max_queue=2 * args.slots, admission="block")
    rng = np.random.default_rng(0)
    reqs = [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=8)
                    .astype(np.int32),
                    max_new=args.gen)
            for i in range(args.requests)]
    with engine:                       # background dispatch thread
        for r in reqs:
            engine.submit(r)
        for r in reqs:
            r.wait()
    assert all(len(r.output) <= args.gen for r in reqs)
    s = engine.stats
    print(f"served {s['served']}/{s['submitted']} requests over "
          f"{s['ticks']} ticks on {args.slots} slots; "
          f"p50 tick {s['p50_tick_us']:.0f} us, "
          f"p99 tick {s['p99_tick_us']:.0f} us")
    print(f"first completion: rid={reqs[0].rid} "
          f"tokens={reqs[0].result[:8]}...")
print("\nserve_lm example OK")
