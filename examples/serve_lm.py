"""Serve a small LM: prefill + batched KV-cache decode with latency stats.

The same step functions are what the multi-pod dry-run lowers at full scale
(decode_32k / long_500k cells).

Run:  PYTHONPATH=src python examples/serve_lm.py [--arch zamba2-1.2b]
"""

import argparse

from repro.launch import serve as serve_cli

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="granite-3-2b")
ap.add_argument("--gen", type=int, default=24)
args = ap.parse_args()

for arch in dict.fromkeys([args.arch, "mamba2-780m"]):
    print(f"\n=== serving {arch} (reduced) ===")
    serve_cli.main(["--arch", arch, "--reduced", "--batch", "4",
                    "--prompt-len", "32", "--gen", str(args.gen)])
print("\nserve_lm example OK")
