"""Digital->analog transfer with the analog program compiler.

Walks the paper's Fig. 11 workflow end to end on the compiler IR:

  1. synthesize  — SVD-factor trained weight matrices (Eq. 31);
  2. program     — realize both unitary factors on cell meshes
                   (analytic Reck, or the kernel-backed gradient fit);
  3. quantize    — snap phases to the Table-I / uniform codebooks;
  4. calibrate   — hardware-in-the-loop residual trim against the
                   measured-prototype imperfection model;
  5. lower       — emit the network-megakernel tensors (packed once);
  6. serve       — fixed-slot ticks through the ServingEngine with zero
                   steady-state packing work.

Run:  PYTHONPATH=src python examples/compile_transfer.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro import compile as compile_mod
from repro.data import load_digits
from repro.kernels import ops
from repro.paper.mnist_rfnn import digital_to_analog_transfer
from repro.paper.prototype import PROTOTYPE
from repro.serving import Request, ServingEngine

print("== 1-2. synthesize + program a 2-layer 8x8 stack ==")
rng = np.random.default_rng(0)
mats = [rng.normal(size=(8, 8)) * 0.4 for _ in range(2)]
prog = compile_mod.program(compile_mod.synthesize(mats), method="reck")
print(f"programmed {prog.depth} layers, {prog.n_cells()} cells, "
      f"synthesis err {compile_mod.program_error(prog):.2e}")

print("\n== 3. quantize to the Table-I codebook (6 phases/shifter) ==")
quant = compile_mod.quantize(prog, "table1", mode="ste")
print(f"table1 synthesis err {compile_mod.program_error(quant):.3f}")

print("\n== 4. calibrate against the measured prototype ==")
key = jax.random.PRNGKey(0)
bound = compile_mod.calibrate(quant, PROTOTYPE, key=key, steps=0)
cal = compile_mod.calibrate(quant, PROTOTYPE, key=key, steps=200)
print(f"on hardware: uncalibrated err "
      f"{compile_mod.program_error(bound):.3f} -> calibrated "
      f"{compile_mod.program_error(cal):.3f}")

print("\n== 5. lower onto the network megakernel ==")
compiled = compile_mod.lower(cal)
x = rng.normal(size=(4, 8)).astype(np.float32)
y = compiled.apply(jnp.asarray(x))
print(f"compiled.apply: one fused pallas_call, out shape {y.shape}")

print("\n== 6. serve the compiled program (zero steady-state packing) ==")
engine = ServingEngine(compiled, slots=4)
packs = ops.PACK_EVENTS["rfnn_network"]
for i in range(10):
    engine.submit(Request(rid=i,
                          features=rng.normal(size=8).astype(np.float32)))
engine.run()
stats = engine.stats
print(f"served {stats['served']} requests in {stats['ticks']} ticks "
      f"(p50 tick {stats['p50_tick_us']:.0f} us); packing events during "
      f"serving: {ops.PACK_EVENTS['rfnn_network'] - packs}")

print("\n== 7. MNIST digital->analog transfer (4-layer 8x8 stack) ==")
x_tr, y_tr, x_te, y_te = load_digits(n_train=600, n_test=200, seed=0)
res = digital_to_analog_transfer(
    x_tr, y_tr, x_te, y_te, depth=4, epochs=15,
    settings=("float", "table1", "uniform6", "hardware",
              "hardware+calibrated"))
print(f"digital test acc: {res['digital_test_acc']:.3f}")
for setting, r in res["settings"].items():
    print(f"  {setting:>20s}: acc {r['test_acc']:.3f} "
          f"(drop {r['acc_drop']:+.3f}, synth err "
          f"{r['synthesis_error']:.3f})")
