"""The 2x2 RFNN as a reconfigurable binary classifier (paper Sec. IV-A).

Trains the four Fig.-12 toy cases end to end: analog device (discrete
Table-I phases, prototype hardware model) + digital post-processing,
reporting the selected device state and accuracies; then shows the DSPSA
(Algorithm I) path on one case.

Run:  PYTHONPATH=src python examples/classify_rf.py
"""

import numpy as np

from repro.data.toys import make_toy_dataset, train_test_split
from repro.paper.rfnn2x2 import accuracy, decision_map, train_rfnn2x2

PAPER = {"corner": 94, "diag_up": 98, "diag_down": 96, "ring": 74}

print("== Fig. 12: four toy datasets, exhaustive theta-state search ==")
for case, target in PAPER.items():
    x, y = make_toy_dataset(case, n=400, seed=1)
    xtr, ytr, xte, yte = train_test_split(x, y)
    net, params, codes, info = train_rfnn2x2(xtr, ytr, steps=800, seed=0)
    te = accuracy(net, params, codes["theta"], codes["phi"], xte, yte)
    print(f"{case:10s} state=L{codes['theta']+1}L{codes['phi']+1} "
          f"train {info['train_acc']*100:5.1f}%  test {te*100:5.1f}%  "
          f"(paper ~{target}%)")

print("\n== Algorithm I with DSPSA over the device codes (corner case) ==")
x, y = make_toy_dataset("corner", n=300, seed=2)
net, params, codes, info = train_rfnn2x2(x, y, method="dspsa", steps=500,
                                         seed=0)
print(f"DSPSA selected state L{codes['theta']+1}L{codes['phi']+1}; "
      f"train acc {info['train_acc']*100:.1f}%")

print("\n== decision map (ASCII, Fig. 9-style) ==")
_, z = decision_map(net, params, codes["theta"], codes["phi"], n=24)
for row in z[::-1]:
    print("".join("#" if v >= 0.5 else "." for v in row))
