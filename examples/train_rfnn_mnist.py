"""End-to-end reproduction of the paper's Sec. IV-B experiment (Figs. 14-16).

Trains the 4-layer handwriting-recognition RFNN — 784 -> 8 (leaky-ReLU) ->
8x8 *analog* mesh (28 cells, Table-I discrete phases, measured-prototype
hardware model, abs detection) -> 8 -> 10 (softmax) — with the paper's
hyperparameters (minibatch 10, lr 0.005), against the digital baseline, and
prints the Fig. 15 accuracy comparison and Fig. 16 confusion matrix.

Run:  PYTHONPATH=src python examples/train_rfnn_mnist.py [--fast]
"""

import argparse

import numpy as np

from repro.data.digits import load_digits
from repro.paper.mnist_rfnn import confusion_matrix, train_mnist

ap = argparse.ArgumentParser()
ap.add_argument("--fast", action="store_true", help="reduced size for CI")
ap.add_argument("--epochs", type=int, default=None)
args = ap.parse_args()

n_train, n_test = (800, 300) if args.fast else (5000, 1000)
epochs = args.epochs or (20 if args.fast else 100)

print(f"rendering digits dataset ({n_train} train / {n_test} test)...")
data = load_digits(n_train=n_train, n_test=n_test, seed=0)

print(f"\n== digital baseline ({epochs} epochs, batch 10, lr 0.005) ==")
digital = train_mnist(*data, analog=False, epochs=epochs)
print(f"train {digital['train_acc']*100:.1f}%  "
      f"test {digital['test_acc']*100:.1f}%   (paper: 94.1 / 93.1)")

print("\n== analog RFNN (Algorithm I: hw-aware SGD + Table-I programming"
      " + DSPSA refinement) ==")
analog = train_mnist(*data, analog=True, epochs=epochs,
                     schedule="algorithm1")
print(f"train {analog['train_acc']*100:.1f}%  "
      f"test {analog['test_acc']*100:.1f}%   (paper: 91.7 / 91.6)")

gap = (digital["test_acc"] - analog["test_acc"]) * 100
print(f"\nanalog-vs-digital gap: {gap:.1f} points (paper: 1.5)")

print("\nconfusion matrix (analog, test):")
cm = confusion_matrix(analog["model"], analog["params"], data[2], data[3])
with np.printoptions(linewidth=140):
    print(cm)
print(f"diagonal mass: {np.trace(cm)/cm.sum()*100:.1f}%")
