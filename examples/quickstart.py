"""Quickstart: the RF analog processor as a composable JAX library.

Covers the paper's core objects in one script:
  1. the 2x2 unit cell t(theta, phi) and its power transfer;
  2. programming an 8x8 mesh (28 cells) to realize a target unitary;
  3. synthesizing an arbitrary matrix via SVD (Eq. 31);
  4. a trainable analog linear layer with Table-I discrete phases and the
     measured-prototype hardware model;
  5. the Pallas TPU kernel path (interpret mode on CPU).

Run:  PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import (
    AnalogUnitary,
    cell_matrix,
    mesh_matrix,
    output_powers,
    random_unitary,
    reck_program,
    synthesize,
)
from repro.kernels import ops
from repro.paper.prototype import PROTOTYPE

print("== 1. the 2x2 unit cell (paper Eq. 5) ==")
t = cell_matrix(jnp.float32(np.deg2rad(104)), jnp.float32(np.deg2rad(29)))
print("t(104deg, 29deg) =\n", np.asarray(t).round(3))
p2, p3 = output_powers(jnp.float32(1.2), 0.0, 0.5e-3, 1.5e-3)
print(f"P2={float(p2)*1e3:.3f} mW, P3={float(p3)*1e3:.3f} mW, "
      f"sum={float(p2+p3)*1e3:.3f} mW (conserved)")

print("\n== 2. program an 8x8 mesh to a target unitary ==")
u = random_unitary(8, seed=42)
plan, params = reck_program(u)
err = np.abs(np.asarray(mesh_matrix(plan, params)) - u).max()
print(f"28-cell mesh reconstruction error: {err:.2e}")

print("\n== 3. synthesize an arbitrary matrix (SVD, Eq. 31) ==")
m = np.random.default_rng(0).normal(size=(3, 5))
syn = synthesize(m)
print(f"realized 3x5 matrix with {syn.n_cells} cells + attenuators; "
      f"max err {np.abs(syn.matrix() - m).max():.2e}")

print("\n== 4. trainable analog layer (Table-I phases + prototype hw) ==")
layer = AnalogUnitary(n=8, quantize="table1", hardware=PROTOTYPE,
                      output="abs")
p = layer.init(jax.random.PRNGKey(0))
y = layer.apply(p, jnp.ones((2, 8)))
print("detected |V| =", np.asarray(y[0]).round(3))

print("\n== 5. Pallas kernel path (interpret on CPU, Mosaic on TPU) ==")
from repro.core import clements_plan, init_mesh_params
plan8 = clements_plan(8)
mp = init_mesh_params(jax.random.PRNGKey(1), plan8)
x = jnp.ones((4, 8), jnp.complex64)
y_kernel = ops.mesh_apply(mp, x, n=8, block_b=4)
from repro.core.mesh import apply_mesh
y_ref = apply_mesh(plan8, mp, x)
print(f"kernel vs core max err: {float(jnp.abs(y_kernel-y_ref).max()):.2e}")
print("\nquickstart OK")
