"""Train a small LM end to end with the full production substrate.

Uses the same driver the cluster runs (repro.launch.train): deterministic
resumable data stream, AdamW, async checkpointing, straggler monitor —
demonstrating checkpoint/restart mid-run.

Run:  PYTHONPATH=src python examples/train_lm.py [--arch granite-3-2b]
      (reduced config; a few hundred steps on CPU)
"""

import argparse
import shutil
import tempfile

from repro.launch import train as train_cli

ap = argparse.ArgumentParser()
ap.add_argument("--arch", default="tinyllama-1.1b")
ap.add_argument("--steps", type=int, default=300)
ap.add_argument("--fast", action="store_true")
args = ap.parse_args()

steps = 60 if args.fast else args.steps
ckpt = tempfile.mkdtemp(prefix="repro_lm_")
try:
    print(f"=== phase 1: train to step {steps//2}, checkpointing ===")
    train_cli.main(["--arch", args.arch, "--reduced",
                    "--steps", str(steps // 2), "--batch", "8",
                    "--seq", "128", "--ckpt-dir", ckpt,
                    "--ckpt-every", "20"])
    print("\n=== phase 2: 'crash' + resume from checkpoint ===")
    train_cli.main(["--arch", args.arch, "--reduced",
                    "--steps", str(steps), "--batch", "8",
                    "--seq", "128", "--ckpt-dir", ckpt, "--resume",
                    "--ckpt-every", "20"])
finally:
    shutil.rmtree(ckpt, ignore_errors=True)
print("\ntrain_lm example OK")
