"""Procedural 28x28 ten-class digit dataset — the offline MNIST stand-in.

The real MNIST files are not available in this container (DESIGN.md), so we
render digit glyphs on a 7x5 seed bitmap, upsample to 28x28, and apply
random affine jitter (shift/rotation/scale), stroke-thickness variation and
pixel noise.  Deterministic in (split, index); labels are balanced.

The paper's MNIST experiment (Sec. IV-B) is reproduced on this dataset with
the *analog-vs-digital accuracy gap* as the validation target.
"""

from __future__ import annotations

import numpy as np
from scipy import ndimage

# 7x5 seed glyphs for digits 0-9
_GLYPHS = {
    0: ["01110", "10001", "10011", "10101", "11001", "10001", "01110"],
    1: ["00100", "01100", "00100", "00100", "00100", "00100", "01110"],
    2: ["01110", "10001", "00001", "00110", "01000", "10000", "11111"],
    3: ["11110", "00001", "00001", "01110", "00001", "00001", "11110"],
    4: ["00010", "00110", "01010", "10010", "11111", "00010", "00010"],
    5: ["11111", "10000", "11110", "00001", "00001", "10001", "01110"],
    6: ["00110", "01000", "10000", "11110", "10001", "10001", "01110"],
    7: ["11111", "00001", "00010", "00100", "01000", "01000", "01000"],
    8: ["01110", "10001", "10001", "01110", "10001", "10001", "01110"],
    9: ["01110", "10001", "10001", "01111", "00001", "00010", "01100"],
}


def _base_image(digit: int) -> np.ndarray:
    g = np.array([[float(c) for c in row] for row in _GLYPHS[digit]])
    img = np.kron(g, np.ones((3, 4)))              # 21 x 20
    out = np.zeros((28, 28))
    out[3:24, 4:24] = img
    return out


def _render(digit: int, rng: np.random.Generator) -> np.ndarray:
    img = _base_image(digit)
    # stroke thickness
    if rng.random() < 0.5:
        img = ndimage.grey_dilation(img, size=(2, 2))
    # affine jitter
    angle = rng.uniform(-18, 18)
    img = ndimage.rotate(img, angle, reshape=False, order=1)
    zoom = rng.uniform(0.85, 1.15)
    zoomed = ndimage.zoom(img, zoom, order=1)
    canvas = np.zeros((28, 28))
    h, w = zoomed.shape
    if h >= 28:
        o = (h - 28) // 2
        canvas = zoomed[o:o + 28, o:o + 28]
    else:
        o = (28 - h) // 2
        canvas[o:o + h, o:o + w] = zoomed
    shift = rng.integers(-2, 3, size=2)
    canvas = np.roll(canvas, shift, axis=(0, 1))
    # blur + noise
    canvas = ndimage.gaussian_filter(canvas, rng.uniform(0.4, 0.9))
    canvas = canvas + rng.normal(0, 0.08, canvas.shape)
    return np.clip(canvas, 0.0, 1.0)


def load_digits(n_train: int = 5000, n_test: int = 1000, seed: int = 0):
    """Returns (x_train [N,784], y_train [N], x_test, y_test) in [0,1]."""
    def make(n, salt):
        xs = np.empty((n, 784), np.float32)
        ys = np.empty((n,), np.int32)
        for i in range(n):
            d = i % 10
            rng = np.random.default_rng((seed, salt, i))
            xs[i] = _render(d, rng).reshape(-1)
            ys[i] = d
        perm = np.random.default_rng((seed, salt, 999)).permutation(n)
        return xs[perm], ys[perm]

    x_tr, y_tr = make(n_train, 1)
    x_te, y_te = make(n_test, 2)
    return x_tr, y_tr, x_te, y_te
