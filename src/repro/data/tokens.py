"""Deterministic synthetic LM token stream.

Design goals for production parity:
  * **stateless addressing** — batch ``i`` is a pure function of (seed, i),
    so restart-from-checkpoint is exact: resume at ``step`` and the stream
    continues as if never interrupted;
  * **host sharding** — each host materializes only its slice of the global
    batch (``host_id``/``num_hosts``);
  * **structured, learnable content** — a tiny hidden Markov generator (not
    iid noise) so a few hundred training steps show a real loss curve.
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class TokenStream:
    vocab_size: int
    seq_len: int
    global_batch: int
    seed: int = 0
    host_id: int = 0
    num_hosts: int = 1
    n_states: int = 8

    def __post_init__(self):
        if self.global_batch % self.num_hosts:
            raise ValueError("global_batch must divide evenly across hosts")
        rng = np.random.default_rng(self.seed)
        v, k = self.vocab_size, self.n_states
        # sticky-state HMM over vocab blocks: learnable bigram structure
        self.trans = 0.85 * np.eye(k) + 0.15 / k
        self.trans /= self.trans.sum(1, keepdims=True)
        block = max(1, v // k)
        self.state_lo = np.arange(k) * block % v
        self.state_hi = np.minimum(self.state_lo + block, v)
        self.cum_trans = np.cumsum(self.trans, axis=1)

    @property
    def local_batch(self) -> int:
        return self.global_batch // self.num_hosts

    def _gen_row(self, rng: np.random.Generator) -> np.ndarray:
        s = int(rng.integers(self.n_states))
        out = np.empty(self.seq_len + 1, np.int32)
        u = rng.random(self.seq_len + 1)
        pick = rng.random(self.seq_len + 1)
        for t in range(self.seq_len + 1):
            s = int(np.searchsorted(self.cum_trans[s], u[t]))
            lo, hi = self.state_lo[s], self.state_hi[s]
            out[t] = lo + int(pick[t] * (hi - lo))
        return out

    def batch(self, step: int) -> dict:
        """The local shard of global batch ``step`` (tokens + shifted labels)."""
        rows = []
        base = step * self.global_batch + self.host_id * self.local_batch
        for r in range(self.local_batch):
            rng = np.random.default_rng((self.seed, base + r))
            rows.append(self._gen_row(rng))
        arr = np.stack(rows)
        return {"tokens": arr[:, :-1].astype(np.int32),
                "labels": arr[:, 1:].astype(np.int32)}

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1
