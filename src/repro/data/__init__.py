"""Data pipelines: synthetic LM token streams (resumable, shardable),
procedural digits (MNIST stand-in), and the paper's 2x2 toy datasets."""

from repro.data.tokens import TokenStream
from repro.data.digits import load_digits
from repro.data.toys import make_toy_dataset

__all__ = ["TokenStream", "load_digits", "make_toy_dataset"]
