"""The paper's 2x2 binary-classification toy datasets (Fig. 12).

Four cases over the input space [0, 30]^2 (scaled by gamma=1/100 before
feeding the device, exactly as in the paper):
  a) 'corner'    — label 1 concentrated in the upper-right corner (~94%)
  b) 'diag_up'   — two diagonal bands toward the upper-right       (~98%)
  c) 'diag_down' — bands toward the lower-right                    (~96%)
  d) 'ring'      — label 1 surrounded by label 0 (hard for 2 cuts, ~74%)
"""

from __future__ import annotations

import numpy as np

GAMMA = 1.0 / 100.0  # the paper's pre-scaling factor


def make_toy_dataset(case: str, n: int = 400, seed: int = 0):
    """Returns (x [N,2] in [0,30]^2, y [N] in {0,1})."""
    rng = np.random.default_rng(seed)
    x = rng.uniform(0, 30, size=(n, 2))
    if case == "corner":
        y = ((x[:, 0] > 18) & (x[:, 1] > 18)).astype(np.int32)
    elif case == "diag_up":
        # two bands along the up-right diagonal, slight overlap (Fig. 12b)
        d = x[:, 1] - x[:, 0]
        y = (d + rng.normal(0, 0.8, n) > 0).astype(np.int32)
    elif case == "diag_down":
        d = x[:, 1] + x[:, 0] - 30
        y = (d + rng.normal(0, 0.8, n) > 0).astype(np.int32)
    elif case == "ring":
        r = np.linalg.norm(x - 15.0, axis=1)
        y = (r < 8.0).astype(np.int32)
    else:
        raise ValueError(f"unknown case {case!r}")
    return x.astype(np.float32), y


def train_test_split(x, y, frac=0.75, seed=0):
    n = len(x)
    perm = np.random.default_rng(seed).permutation(n)
    k = int(n * frac)
    tr, te = perm[:k], perm[k:]
    return x[tr], y[tr], x[te], y[te]
