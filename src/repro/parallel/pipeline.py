"""Pipeline parallelism: GPipe-style microbatch pipeline over a mesh axis.

Maps the paper-era GPipe schedule onto jax-native constructs: the layer
stack is sharded over the ``stage`` mesh axis (one contiguous group of
layers per stage), microbatches flow stage-to-stage with
``lax.ppermute`` inside ``shard_map``.  The multi-pod profile uses the
"pod" axis as the stage axis (2 stages); the mechanism is
axis-count-generic and unit-tested with placeholder devices.

Schedule: standard GPipe fill-drain over M microbatches and S stages
(bubble fraction (S-1)/(M+S-1)); each tick every stage runs its layer
group on its current microbatch, then activations rotate one stage down.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.parallel.sharding import shard_map_compat as _shard_map

Array = jax.Array


def pipeline_forward(block_fn: Callable, mesh: Mesh, axis: str,
                     stage_params, x_microbatches: Array) -> Array:
    """Run a GPipe forward over ``axis``.

    block_fn(params, x) -> x : one stage's layer group.
    stage_params: pytree with a leading stage axis (sharded over ``axis``).
    x_microbatches: [M, mb, ...] microbatches (replicated).
    Returns [M, mb, ...] outputs after all stages.
    """
    n_stages = mesh.shape[axis]
    m = x_microbatches.shape[0]
    n_ticks = m + n_stages - 1
    perm = [(i, (i + 1) % n_stages) for i in range(n_stages)]

    def stage_program(params, xs):
        # params: this stage's shard (leading axis 1); xs: all microbatches
        params = jax.tree.map(lambda p: p[0], params)
        stage = jax.lax.axis_index(axis)
        buf = jnp.zeros_like(xs[0])          # activation held by this stage
        outs = jnp.zeros_like(xs)

        def tick(t, carry):
            buf, outs = carry
            # stage 0 ingests microbatch t (when available)
            mb_idx = jnp.clip(t, 0, m - 1)
            incoming = jax.lax.dynamic_index_in_dim(xs, mb_idx, 0,
                                                    keepdims=False)
            buf = jnp.where(stage == 0, incoming, buf)
            buf = block_fn(params, buf)
            # last stage retires microbatch t - (S-1)
            out_idx = jnp.clip(t - (n_stages - 1), 0, m - 1)
            valid = (t >= n_stages - 1) & (stage == n_stages - 1)
            outs = jax.lax.cond(
                valid,
                lambda o: jax.lax.dynamic_update_index_in_dim(
                    o, buf, out_idx, 0),
                lambda o: o, outs)
            # rotate activations downstream
            buf = jax.lax.ppermute(buf, axis, perm)
            return buf, outs

        _, outs = jax.lax.fori_loop(0, n_ticks, tick, (buf, outs))
        # the retired outputs live on stage S-1; psum broadcasts (other
        # stages contribute zeros)
        outs = jnp.where(stage == n_stages - 1, outs, jnp.zeros_like(outs))
        return jax.lax.psum(outs, axis)

    spec_params = jax.tree.map(lambda _: P(axis), stage_params)
    fn = _shard_map(stage_program, mesh=mesh,
                    in_specs=(spec_params, P()), out_specs=P())
    return fn(stage_params, x_microbatches)


def bubble_fraction(n_stages: int, n_microbatches: int) -> float:
    return (n_stages - 1) / (n_microbatches + n_stages - 1)
