"""Logical-axis sharding rules (MaxText-style) for pjit/GSPMD.

Model code annotates activations/params with *logical* axis names
(``batch``, ``heads``, ``ffn`` ...).  A :class:`ShardingRules` table maps
logical names onto physical mesh axes; the launcher installs the mesh and
rules for the duration of a step function.  Outside any mesh context all
annotations are no-ops, so the same model code runs in single-device smoke
tests and 512-chip dry-runs.

Parallelism encoded by the default rules:
  * DP: ``batch`` over ("pod", "data")
  * TP: ``heads`` / ``kv_heads`` / ``ffn`` / ``vocab`` over "model"
    (Megatron column/row pairs emerge from GSPMD on the matmul chains)
  * EP: ``experts`` over "data" with expert FFN dim over "model"
    (dispatch reshard = GSPMD all-to-all)
  * SP: ``kv_seq`` over "data" for long-context decode (flash-decode style
    partial-softmax combine inserted by GSPMD on the reduction)
  * ZeRO-1: optimizer-state ``fsdp`` axis over ("data",)
"""

from __future__ import annotations

import contextlib
import dataclasses
import functools
import threading

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

# shard_map moved (and renamed its replication-check kwarg) across jax
# releases; every shard_map user in the repo goes through this shim.
if getattr(jax, "shard_map", None) is not None:  # jax >= 0.6 top-level API
    shard_map_compat = functools.partial(jax.shard_map, check_vma=False)
else:  # the experimental location (and arg name) of older releases
    from jax.experimental.shard_map import shard_map as _shard_map_experimental

    shard_map_compat = functools.partial(_shard_map_experimental,
                                         check_rep=False)


@dataclasses.dataclass(frozen=True)
class ShardingRules:
    """Mapping logical axis name -> mesh axis (str/tuple) or None."""

    rules: dict

    def spec(self, *names: str | None) -> P:
        axes = []
        used: set[str] = set()
        for nm in names:
            if nm is None:
                axes.append(None)
                continue
            ax = self.rules.get(nm)
            members = set(ax) if isinstance(ax, tuple) else {ax}
            # a mesh axis may appear at most once in a PartitionSpec;
            # earlier logical names win (e.g. batch over kv_seq on "data")
            if ax is None or (members & used):
                axes.append(None)
            else:
                axes.append(ax)
                used |= members
        return P(*axes)


def default_rules(multi_pod: bool = False) -> ShardingRules:
    batch_axes = ("pod", "data") if multi_pod else ("data",)
    return ShardingRules(rules={
        "batch": batch_axes,
        "expert_group": batch_axes,
        "seq": None,
        "kv_seq": "data",          # long-context decode: shard cache length
        "embed": None,
        "mlp_embed": None,
        "heads": "model",
        "kv_heads": "model",
        "head_dim": None,
        "ffn": "model",
        "vocab": "model",
        "experts": "data",
        "expert_ffn": "model",
        "ssm_heads": "model",
        "ssm_state": None,
        "conv_dim": "model",
        "tp": "model",             # generic TP annotation (e.g. MoE out D)
        "layers": None,
        "fsdp": "data",            # optimizer-state (ZeRO-1) sharding axis
        "stage": "pod",            # pipeline stages (optional profile)
    })


class _Ctx(threading.local):
    def __init__(self):
        self.mesh: Mesh | None = None
        self.rules: ShardingRules | None = None


_CTX = _Ctx()


@contextlib.contextmanager
def use_mesh_and_rules(mesh: Mesh, rules: ShardingRules):
    """Install mesh + rules; model annotations become real constraints.

    No ambient-mesh context is required: ``constrain`` builds explicit
    NamedShardings, which carry the mesh into the jaxpr on their own.
    """
    prev = (_CTX.mesh, _CTX.rules)
    _CTX.mesh, _CTX.rules = mesh, rules
    try:
        yield
    finally:
        _CTX.mesh, _CTX.rules = prev


def set_rules(rules: ShardingRules):
    _CTX.rules = rules


def active_mesh() -> Mesh | None:
    return _CTX.mesh


def logical_spec(*names: str | None) -> P:
    rules = _CTX.rules
    if rules is None:
        return P(*([None] * len(names)))
    return rules.spec(*names)


def constrain(x: jax.Array, *names: str | None) -> jax.Array:
    """Annotate ``x`` with logical axes; no-op without an active mesh."""
    mesh, rules = _CTX.mesh, _CTX.rules
    if mesh is None or rules is None:
        return x
    if x.ndim != len(names):
        raise ValueError(f"rank {x.ndim} vs {len(names)} logical names {names}")
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, rules.spec(*names)))


def named_sharding(mesh: Mesh, rules: ShardingRules, *names) -> NamedSharding:
    return NamedSharding(mesh, rules.spec(*names))


# ---------------------------------------------------------------------------
# Tile-grid scale-out: (tile-row x batch) sharding specs for the megakernel
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TileGridShardSpecs:
    """PartitionSpecs of every tile-grid megakernel operand/output class.

    The tile-grid kernel's pallas grid is (tile rows x batch blocks); the
    distributed layout shards the *work* over both mesh axes:

      * ``coef`` — the ``[L, To, Ti, C, 8, P]`` coefficient stacks (and
        the ``[L, To, Ti, C, 1]`` parities / ``[L, To, Ti, 12, P]``
        gains) of the deep-grid layout: REPLICATED.  Each device slices
        its own tile-row slab (axis 1) in-body (``axis_index`` over the
        row axis).  They are small, and feeding them row-partitioned
        trips a GSPMD mis-partitioning bug on this jax version when the
        stacks are traced (built by concatenate under an enclosing jit,
        e.g. ``jit(grad(...))`` over unpacked tiles) — see the note in
        ``repro.kernels.ops``;
      * ``x_plane`` — the ``[B, Ti, P]`` input planes: batch-split,
        replicated over tile rows (every row sweeps the whole input);
      * ``o_plane`` — the ``[B, To, P]`` combined row outputs: split on
        both axes (each device owns its rows' outputs for its batch);
      * ``stage`` — the ``[L, B, To, Ti, P]`` VJP stage residuals (the
        stacked-sweep layout, batch-block axis second): batch and tile
        rows both split, layer and input-tile axes whole;
      * ``dx_plane`` — the ``[B, Ti, P]`` input cotangent *after* the
        cross-device ``psum`` over the row axis (the matched-line
        combiner's transpose): batch-split, replicated over rows.

    ``coef`` is also the out_spec of the VJP's coefficient grads: the
    backward psums them over the batch axis and all-gathers over the row
    axis, so they leave the shard_map replicated too.
    """

    coef: P
    x_plane: P
    o_plane: P
    stage: P
    dx_plane: P


def tile_grid_shard_specs(row_axis: str = "rows",
                          data_axis: str = "data") -> TileGridShardSpecs:
    """The canonical (tile-row x batch) sharding of the tile-grid kernel."""
    return TileGridShardSpecs(
        coef=P(),
        x_plane=P(data_axis),
        o_plane=P(data_axis, row_axis),
        stage=P(None, data_axis, row_axis),
        dx_plane=P(data_axis),
    )


# ---------------------------------------------------------------------------
# Data-parallel wrapper over the batch grid
# ---------------------------------------------------------------------------

def data_parallel(apply_fn, mesh: Mesh, *, axis_name: str = "data"):
    """Shard-map a batched ``apply_fn(params, x)`` over ``mesh[axis_name]``.

    Parameters are replicated; ``x`` is split on its leading (batch) axis;
    each device runs the *same* program — e.g. the fused RFNN network
    megakernel — on its batch shard, and outputs are re-concatenated along
    the batch axis.  Ragged batches are zero-padded up to a multiple of the
    axis size and sliced back, so any request count works (serving ticks
    don't have to align with the device count).
    """
    n_dev = mesh.shape[axis_name]
    # jit the shard_map: without it every call re-traces the body, and
    # trace-time tracers defeat the megakernel's coefficient-pack cache —
    # steady-state serving ticks must stay zero-packing-work when sharded
    fn = jax.jit(shard_map_compat(apply_fn, mesh=mesh,
                                  in_specs=(P(), P(axis_name)),
                                  out_specs=P(axis_name)))

    def call(params, x):
        b = x.shape[0]
        pad = (-b) % n_dev
        if pad:
            x = jnp.concatenate(
                [x, jnp.zeros((pad,) + x.shape[1:], x.dtype)], axis=0)
        return fn(params, x)[:b]

    return call
