"""Distribution utilities: logical-axis sharding rules, mesh context."""

from repro.parallel.sharding import (
    ShardingRules,
    TileGridShardSpecs,
    active_mesh,
    constrain,
    data_parallel,
    logical_spec,
    set_rules,
    shard_map_compat,
    tile_grid_shard_specs,
    use_mesh_and_rules,
)

__all__ = [
    "ShardingRules", "TileGridShardSpecs", "active_mesh", "constrain",
    "data_parallel", "logical_spec", "set_rules", "shard_map_compat",
    "tile_grid_shard_specs", "use_mesh_and_rules",
]
