"""repro: RF analog processor (RFNN) reproduction + multi-pod JAX framework."""

__version__ = "1.0.0"
