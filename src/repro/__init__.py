"""repro: RF analog processor (RFNN) reproduction + multi-pod JAX framework.

The serving entry points are re-exported here so user code can write
``from repro import ServingEngine, Request``; everything else lives in
the subpackages (``repro.compile``, ``repro.kernels``, ``repro.models``,
...), loaded lazily so importing ``repro`` stays cheap.
"""

__version__ = "1.0.0"

__all__ = [
    "Request",
    "ServableProgram",
    "ServingEngine",
    "as_servable",
    "__version__",
]

_SERVING_EXPORTS = {"Request", "ServableProgram", "ServingEngine",
                    "as_servable"}
_SUBPACKAGES = {"checkpoint", "compile", "configs", "core", "data",
                "kernels", "launch", "models", "optim", "paper",
                "parallel", "runtime", "serving", "train"}


def __getattr__(name):
    if name in _SERVING_EXPORTS:
        from repro import serving

        return getattr(serving, name)
    if name in _SUBPACKAGES:
        import importlib

        return importlib.import_module(f"repro.{name}")
    raise AttributeError(f"module 'repro' has no attribute {name!r}")


def __dir__():
    return sorted(set(__all__) | _SUBPACKAGES)
