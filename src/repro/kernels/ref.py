"""Pure-jnp oracles for the Pallas kernels.

The oracles re-express the mesh semantics in the kernels' de-interleaved
(even/odd channel) layout so the kernels can be validated value-for-value,
and are themselves validated against :func:`repro.core.mesh.apply_mesh` in
the test suite (two independent implementations of the same physics).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mesh as mesh_lib
from repro.core.cell import cell_matrix

Array = jax.Array


def clements_coefficients(theta: Array, phi: Array, n: int) -> Array:
    """Pack cell matrices of a Clements-layout mesh into kernel coefficients.

    theta/phi: [C, P] with C == n columns, P == n//2 pair slots.
    Returns coef [C, 8, P] float32 with rows
    (t00r, t00i, t01r, t01i, t10r, t10i, t11r, t11i) per pair slot.
    Inactive slots (the wrap slot of odd columns) are forced to identity.
    """
    c, p = theta.shape
    if c != n or p != n // 2:
        raise ValueError(f"expected params [{n},{n//2}], got [{c},{p}]")
    t = cell_matrix(theta, phi)  # [C, P, 2, 2] complex
    plan = mesh_lib.clements_plan(n)
    active = jnp.asarray(plan.active)[..., None, None]
    t = jnp.where(active, t, jnp.eye(2, dtype=t.dtype))
    coef = jnp.stack(
        [jnp.real(t[..., 0, 0]), jnp.imag(t[..., 0, 0]),
         jnp.real(t[..., 0, 1]), jnp.imag(t[..., 0, 1]),
         jnp.real(t[..., 1, 0]), jnp.imag(t[..., 1, 0]),
         jnp.real(t[..., 1, 1]), jnp.imag(t[..., 1, 1])],
        axis=1,
    )  # [C, 8, P]
    return coef.astype(jnp.float32)


def split_channels(x: Array) -> tuple[Array, Array, Array, Array]:
    """Complex [B, N] -> (xer, xei, xor, xoi) float32 [B, N//2] planes."""
    xe, xo = x[..., 0::2], x[..., 1::2]
    return (jnp.real(xe).astype(jnp.float32), jnp.imag(xe).astype(jnp.float32),
            jnp.real(xo).astype(jnp.float32), jnp.imag(xo).astype(jnp.float32))


def merge_channels(xer: Array, xei: Array, xor: Array, xoi: Array) -> Array:
    """Inverse of :func:`split_channels`."""
    xe = xer + 1j * xei
    xo = xor + 1j * xoi
    b, p = xe.shape[:-1], xe.shape[-1]
    out = jnp.stack([xe, xo], axis=-1).reshape(b + (2 * p,))
    return out.astype(jnp.complex64)


def _cmul(ar, ai, br, bi):
    return ar * br - ai * bi, ar * bi + ai * br


def _rotate_pair(coef_slice, ar, ai, br, bi):
    """(a', b') = t @ (a, b) with t given by an 8-row coefficient slice."""
    t00r, t00i, t01r, t01i, t10r, t10i, t11r, t11i = [coef_slice[k] for k in range(8)]
    xr, xi = _cmul(t00r, t00i, ar, ai)
    yr, yi = _cmul(t01r, t01i, br, bi)
    a2r, a2i = xr + yr, xi + yi
    xr, xi = _cmul(t10r, t10i, ar, ai)
    yr, yi = _cmul(t11r, t11i, br, bi)
    b2r, b2i = xr + yr, xi + yi
    return a2r, a2i, b2r, b2i


def mesh_apply_planes(coef: Array, xer: Array, xei: Array, xor: Array,
                      xoi: Array) -> tuple[Array, Array, Array, Array]:
    """Oracle for the kernel inner loop, in the de-interleaved layout.

    coef: [C, 8, P]; planes: [..., P].  Even columns rotate (even_i, odd_i);
    odd columns rotate (odd_i, even_{i+1}) with the wrap slot inactive.
    """
    n_cols = coef.shape[0]
    state = (xer, xei, xor, xoi)
    for c in range(n_cols):  # oracle: plain python loop, clarity first
        er, ei, orr, oi = state
        cc = coef[c]
        if c % 2 == 0:
            a2r, a2i, b2r, b2i = _rotate_pair(cc, er, ei, orr, oi)
            state = (a2r, a2i, b2r, b2i)
        else:
            ar, ai = orr[..., :-1], oi[..., :-1]
            br, bi = er[..., 1:], ei[..., 1:]
            cs = cc[:, :-1]
            a2r, a2i, b2r, b2i = _rotate_pair(cs, ar, ai, br, bi)
            orr = jnp.concatenate([a2r, orr[..., -1:]], axis=-1)
            oi = jnp.concatenate([a2i, oi[..., -1:]], axis=-1)
            er = jnp.concatenate([er[..., :1], b2r], axis=-1)
            ei = jnp.concatenate([ei[..., :1], b2i], axis=-1)
            state = (er, ei, orr, oi)
    return state


def mesh_apply_ref(params: dict, x: Array, n: int) -> Array:
    """Reference mesh apply in the kernel layout (complex in/out)."""
    coef = clements_coefficients(params["theta"], params["phi"], n)
    planes = split_channels(x.astype(jnp.complex64))
    planes = mesh_apply_planes(coef, *planes)
    y = merge_channels(*planes)
    alpha = params.get("alpha")
    if alpha is not None:
        y = y * jnp.exp(-1j * alpha.astype(jnp.complex64))
    return y


def rfnn_linear_ref(v_params: dict, atten: Array, u_params: dict, x: Array,
                    n: int, scale: Array | float = 1.0) -> Array:
    """Oracle for the fused analog linear kernel: |scale * U (D (V x))|."""
    h = mesh_apply_ref(v_params, x, n)
    h = h * atten.astype(jnp.complex64)
    y = mesh_apply_ref(u_params, h, n)
    return jnp.abs(scale * y)


def flash_attention_ref(q: Array, k: Array, v: Array,
                        causal: bool = True) -> Array:
    """Dense-softmax oracle for the flash attention kernel.

    q, k, v: [B, H, S, hd] -> [B, H, S, hd], f32 math.
    """
    import numpy as np
    hd = q.shape[-1]
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(hd)
    if causal:
        sq, sk = s.shape[-2], s.shape[-1]
        mask = jnp.arange(sk)[None, :] > jnp.arange(sq)[:, None]
        s = jnp.where(mask, -1e30, s)
    p = jax.nn.softmax(s, axis=-1)
    out = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return out.astype(q.dtype)
