"""Pallas TPU flash attention — the designed fix for the score-traffic
memory term (§Perf cells B/tinyllama and the prefill cells).

The pure-XLA flash path (models/attention.py) materializes each score block
[Sq, chunk] to HBM between the QK and PV dots; this kernel keeps the block
in VMEM with the canonical TPU pattern:

  grid = (batch*heads, q_blocks, kv_blocks)   # kv fastest, sequential
  scratch (VMEM, carried across kv iterations): acc [BQ,hd] f32, m/l [BQ]

Causality is handled at two levels: whole kv-blocks strictly above the
diagonal are skipped with ``pl.when`` (no FLOPs, no traffic — the kernel
analogue of the causal q-block skipping in the XLA path), and the diagonal
block applies the element mask.

HBM traffic: q, k, v read once per (q-block, kv-block) pair in the causal
prefix, o written once — no score bytes, vs O(S^2 H) f32 score bytes in the
XLA lowering.  Validated against ``ref.flash_attention_ref`` in interpret
mode (CPU container; TPU is the target).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(q_ref, k_ref, v_ref, o_ref, acc_ref, m_ref, l_ref, *,
            bq: int, bk: int, causal: bool, scale: float, n_kv: int):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)

    # skip kv blocks strictly above the diagonal
    run = (not causal) or (ki * bk <= qi * bq + bq - 1)

    @pl.when(run)
    def _block():
        q = q_ref[0].astype(jnp.float32)          # [BQ, hd]
        k = k_ref[0].astype(jnp.float32)          # [BK, hd]
        v = v_ref[0].astype(jnp.float32)
        s = jax.lax.dot_general(
            q, k, (((1,), (1,)), ((), ()))) * scale   # [BQ, BK]
        if causal:
            q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
            k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
            s = jnp.where(k_pos > q_pos, NEG_INF, s)
        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_ref[...] = l_ref[...] * corr + p.sum(axis=1)
        acc_ref[...] = (acc_ref[...] * corr[:, None]
                        + jax.lax.dot_general(
                            p, v, (((1,), (0,)), ((), ()))))
        m_ref[...] = m_new

    @pl.when(ki == n_kv - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0] = (acc_ref[...] / denom[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("causal", "bq", "bk",
                                             "interpret"))
def flash_attention(q, k, v, *, causal: bool = True, bq: int = 128,
                    bk: int = 128, interpret: bool | None = None):
    """q, k, v: [B, H, S, hd] (KV heads pre-expanded to H).  -> [B, H, S, hd].

    Blocks default to 128x128 (MXU-aligned); the whole working set per grid
    step is q/k/v/o blocks + f32 accumulators ~ (3*bk + 2*bq)*hd*4 bytes +
    bq*bk*4 — well inside VMEM for hd <= 256.
    """
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, h, sq, hd = q.shape
    skv = k.shape[2]
    bq_ = min(bq, sq)
    bk_ = min(bk, skv)
    if sq % bq_ or skv % bk_:
        raise ValueError(f"seq lens ({sq},{skv}) must divide blocks "
                         f"({bq_},{bk_})")
    n_q, n_kv = sq // bq_, skv // bk_
    qf = q.reshape(b * h, sq, hd)
    kf = k.reshape(b * h, skv, hd)
    vf = v.reshape(b * h, skv, hd)

    kernel = functools.partial(_kernel, bq=bq_, bk=bk_, causal=causal,
                               scale=1.0 / np.sqrt(hd), n_kv=n_kv)
    out = pl.pallas_call(
        kernel,
        grid=(b * h, n_q, n_kv),
        in_specs=[
            pl.BlockSpec((1, bq_, hd), lambda bh, qi, ki: (bh, qi, 0)),
            pl.BlockSpec((1, bk_, hd), lambda bh, qi, ki: (bh, ki, 0)),
            pl.BlockSpec((1, bk_, hd), lambda bh, qi, ki: (bh, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq_, hd), lambda bh, qi, ki: (bh, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b * h, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq_, hd), jnp.float32),
            pltpu.VMEM((bq_,), jnp.float32),
            pltpu.VMEM((bq_,), jnp.float32),
        ],
        interpret=interpret,
        cost_estimate=pl.CostEstimate(
            flops=int(4 * b * h * sq * skv * hd * (0.5 if causal else 1.0)),
            bytes_accessed=int(qf.size + kf.size + vf.size + qf.size) * 2,
            transcendentals=int(b * h * sq * skv),
        ),
    )(qf, kf, vf)
    return out.reshape(b, h, sq, hd)
