"""Pallas TPU kernels for the perf-critical compute layers.

givens_mesh      — the paper's mesh MVM (columns of arbitrary 2x2 complex
                   cells — ideal or hardware-imperfect), forward and
                   backward (custom-VJP kernels, DESIGN.md), up to the
                   deep tiled-network megakernel (an L-layer cascade of
                   (To x Ti) tile grids in one pallas_call per direction)
schedule         — static parity-column schedules lowering any adjacent-pair
                   MeshPlan (Clements, Reck, packed) onto the kernels;
                   DeepGridSchedule stacks the [L][To][Ti] grid of (V, U)
                   pairs for the megakernel
flash_attention  — fused attention (motivated by the roofline's memory term)
ops              — jitted, differentiable public wrappers
ref              — pure-jnp oracles (the allclose ground truth)
EXAMPLE.md       — scaffold notes
"""

from repro.kernels import ops, ref, schedule
from repro.kernels.flash_attention import flash_attention

__all__ = ["ops", "ref", "schedule", "flash_attention"]
