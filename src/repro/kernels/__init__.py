"""Pallas TPU kernels for the perf-critical compute layers.

givens_mesh      — the paper's mesh MVM (columns of 2x2 complex rotations)
flash_attention  — fused attention (motivated by the roofline's memory term)
ops              — jitted public wrappers
ref              — pure-jnp oracles (the allclose ground truth)
EXAMPLE.md       — scaffold notes
"""

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention

__all__ = ["ops", "ref", "flash_attention"]
