"""Pallas TPU kernels for the perf-critical compute layers.

givens_mesh      — the paper's mesh MVM (columns of 2x2 complex rotations),
                   forward and backward (custom-VJP kernels, DESIGN.md)
flash_attention  — fused attention (motivated by the roofline's memory term)
ops              — jitted, differentiable public wrappers
ref              — pure-jnp oracles (the allclose ground truth)
EXAMPLE.md       — scaffold notes
"""

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention

__all__ = ["ops", "ref", "flash_attention"]
