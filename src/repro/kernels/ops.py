"""Jitted, differentiable public wrappers around the Pallas mesh kernels.

``interpret`` defaults to True off-TPU so the same call sites run in this
CPU container (kernel body executed op-by-op) and compile to Mosaic on TPU.

Both ``mesh_apply`` and ``rfnn_linear`` carry custom VJPs: the backward
pass is itself a fused Pallas kernel that re-runs the mesh columns in
reverse, rebuilding states with the per-cell analytic 2x2 **inverse** and
propagating the cotangent with the **adjoint** (see DESIGN.md) — so
training keeps the same VMEM-resident hot loop as inference for ideal
*and* hardware-imperfect cells, on Clements *and* Reck layouts.  There is
no reference fallback: ``backend="pallas"`` means the kernel path, always.

Everything outside the pallas_call boundary — coefficient packing from
theta/phi (ideal or via the hardware model, including ``key``-driven
phase-noise sampling), channel split/merge, phase screens, gains — is
ordinary JAX and differentiates natively, which is how gradients reach
the mesh phases, attenuations and the digital scale.  Detector noise and
the sensitivity floor also stay outside (``hardware.detect_magnitude``
composes on the returned magnitudes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import hardware as hw_lib
from repro.core import mesh as mesh_lib
from repro.core.cell import cell_matrix
from repro.kernels import givens_mesh, ref
from repro.kernels.schedule import (
    MeshSchedule,
    clements_schedule,
    network_parity_arrays,
    network_schedule,
    pack_cells,
    pad_columns,
    parity_array,
    schedule_from_plan,
    tile_grid_parity_arrays,
    tile_grid_schedule,
)

Array = jax.Array

#: Instrumentation: per-entry-point invocation counts of the kernel path.
#: Tests use this to assert the Pallas path is actually taken (there is no
#: silent reference fallback left to fall into).  Counts tick on every
#: public-wrapper call (trace time under an outer jit).
KERNEL_PATH_CALLS = {"mesh_apply": 0, "rfnn_linear": 0, "mesh_apply_cells": 0,
                     "rfnn_network": 0, "tiled_apply": 0,
                     "tiled_apply_sharded": 0}

#: Instrumentation: number of times each jitted impl was actually *traced*.
#: Regression tests use this to pin the schedule/trace-cache memoization —
#: structurally equal plans must not re-trigger traces.
TRACE_COUNTS = {"mesh_apply": 0, "rfnn_linear": 0, "rfnn_network": 0,
                "tiled_apply": 0, "tiled_apply_sharded": 0}

#: Instrumentation: number of coefficient-pack builds actually executed by
#: :func:`rfnn_network` (cache misses / tracer bypasses).  Steady-state
#: serving must not tick this.
PACK_EVENTS = {"rfnn_network": 0, "tiled_apply": 0}


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _auto_block(b: int, block_b: int) -> int:
    """Shrink the batch block for small batches (never grow past block_b)."""
    return max(1, min(block_b, -(-b // 8) * 8))


def _pad_batch(x2d: Array, block: int) -> tuple[Array, int]:
    b = x2d.shape[0]
    pad = (-b) % block
    if pad:
        # jnp.pad, not concatenate-with-zeros: GSPMD mis-partitions a
        # concatenate feeding shard_map on a multi-axis mesh (the row-axis
        # shards get summed instead of replicated); the pad HLO shards
        # correctly and is semantically identical here
        x2d = jnp.pad(x2d, ((0, pad),) + ((0, 0),) * (x2d.ndim - 1))
    return x2d, b


# ---------------------------------------------------------------------------
# custom-VJP boundary: de-interleaved planes in, planes out
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _mesh_planes(sched, block_b, nb, interpret, coef, xer, xei, xor, xoi):
    call = givens_mesh.mesh_pallas_call(
        sched.n, sched.n_columns, block_b, nb, interpret)
    return tuple(call(coef, parity_array(sched), xer, xei, xor, xoi))


def _mesh_planes_fwd(sched, block_b, nb, interpret, coef, xer, xei, xor, xoi):
    outs = _mesh_planes(sched, block_b, nb, interpret, coef,
                        xer, xei, xor, xoi)
    # the output planes are the only state residual needed: the backward
    # sweep rebuilds every intermediate via the per-cell inverse
    return outs, (coef, outs)


def _mesh_planes_bwd(sched, block_b, nb, interpret, res, cot):
    coef, outs = res
    coef_inv = givens_mesh.inverse_coefficients(coef)
    coef_adj = givens_mesh.adjoint_coefficients(coef)
    call = givens_mesh.mesh_bwd_pallas_call(
        sched.n, sched.n_columns, block_b, nb, interpret)
    dcoef, dxer, dxei, dxor, dxoi = call(
        coef_inv, coef_adj, parity_array(sched), *outs, *cot)
    return dcoef, dxer, dxei, dxor, dxoi


_mesh_planes.defvjp(_mesh_planes_fwd, _mesh_planes_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4))
def _rfnn_planes(sched_v, sched_u, block_b, nb, interpret, coef_v, coef_u,
                 gains, xer, xei, xor, xoi):
    call = givens_mesh.rfnn_linear_pallas_call(
        sched_v.n, sched_v.n_columns, sched_u.n_columns, block_b, nb,
        interpret)
    return tuple(call(coef_v, parity_array(sched_v),
                      coef_u, parity_array(sched_u), gains,
                      xer, xei, xor, xoi))


def _rfnn_planes_fwd(sched_v, sched_u, block_b, nb, interpret, coef_v,
                     coef_u, gains, xer, xei, xor, xoi):
    call = givens_mesh.rfnn_linear_fwd_pallas_call(
        sched_v.n, sched_v.n_columns, sched_u.n_columns, block_b, nb,
        interpret)
    oe, oo, *stage = call(coef_v, parity_array(sched_v),
                          coef_u, parity_array(sched_u), gains,
                          xer, xei, xor, xoi)
    return (oe, oo), (coef_v, coef_u, gains, tuple(stage))


def _rfnn_planes_bwd(sched_v, sched_u, block_b, nb, interpret, res, cot):
    coef_v, coef_u, gains, stage = res
    call = givens_mesh.rfnn_linear_bwd_pallas_call(
        sched_v.n, sched_v.n_columns, sched_u.n_columns, block_b, nb,
        interpret)
    dcv, dcu, dgains, dxer, dxei, dxor, dxoi = call(
        givens_mesh.inverse_coefficients(coef_v),
        givens_mesh.adjoint_coefficients(coef_v), parity_array(sched_v),
        givens_mesh.inverse_coefficients(coef_u),
        givens_mesh.adjoint_coefficients(coef_u), parity_array(sched_u),
        gains, *stage, *cot)
    return dcv, dcu, dgains, dxer, dxei, dxor, dxoi


_rfnn_planes.defvjp(_rfnn_planes_fwd, _rfnn_planes_bwd)


# ---------------------------------------------------------------------------
# coefficient construction (ideal cells or the hardware model)
# ---------------------------------------------------------------------------

def _mesh_coefficients(sched: MeshSchedule, params: dict,
                       hardware: hw_lib.HardwareModel | None,
                       key: Array | None) -> Array:
    """Packed [C', 8, P] coefficients from mesh params.

    With a hardware model, cells come from ``imperfect_cell_matrix`` —
    the same function (and the same ``key`` consumption) as the reference
    ``apply_mesh_hw`` path, so the two backends see identical draws.
    """
    theta, phi = params["theta"], params["phi"]
    if hardware is None:
        t_all = cell_matrix(theta, phi)
    else:
        t_all = hw_lib.imperfect_cell_matrix(theta, phi, hardware, key)
    return pack_cells(sched, t_all)


def _run_mesh_planes(sched, x2, coef, block_b, interpret):
    bb = _auto_block(x2.shape[0], block_b)
    x2, b_orig = _pad_batch(x2, bb)
    nb = x2.shape[0] // bb
    planes = ref.split_channels(x2)
    planes = _mesh_planes(sched, bb, nb, interpret, coef, *planes)
    return ref.merge_channels(*planes)[:b_orig]


# ---------------------------------------------------------------------------
# Public wrappers
# ---------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnums=(0, 1, 2, 3))
def _mesh_apply_impl(sched, hardware, block_b, interpret, params, x, key):
    TRACE_COUNTS["mesh_apply"] += 1  # python side effect: runs at trace only
    batch_shape = x.shape[:-1]
    x2 = x.reshape((-1, sched.n)).astype(jnp.complex64)
    alpha_in = params.get("alpha_in")
    if alpha_in is not None:
        x2 = x2 * jnp.exp(-1j * alpha_in.astype(jnp.complex64))
    coef = _mesh_coefficients(sched, params, hardware, key)
    y = _run_mesh_planes(sched, x2, coef, block_b, interpret)
    alpha = params.get("alpha")
    if alpha is not None:
        y = y * jnp.exp(-1j * alpha.astype(jnp.complex64))
    return y.reshape(batch_shape + (sched.n,))


def mesh_apply(params: dict, x: Array, *, n: int,
               plan: mesh_lib.MeshPlan | None = None,
               hardware: hw_lib.HardwareModel | None = None,
               key: Array | None = None, block_b: int = 128,
               interpret: bool | None = None) -> Array:
    """Apply a mesh to ``x[..., n]`` via the Pallas kernel.

    Semantics match ``repro.core.mesh.apply_mesh`` on the given plan
    (``None`` = the Clements rectangle), including the optional phase
    screens ``alpha_in`` / ``alpha``; with ``hardware`` they match
    ``repro.core.hardware.apply_mesh_hw`` (imperfect hybrids, per-cell
    insertion loss, and ``key``-sampled phase-shifter noise).
    Differentiable w.r.t. ``params`` and ``x`` through the kernel VJP.
    """
    if interpret is None:
        interpret = _default_interpret()
    sched = clements_schedule(n) if plan is None else schedule_from_plan(plan)
    KERNEL_PATH_CALLS["mesh_apply"] += 1
    return _mesh_apply_impl(sched, hardware, block_b, interpret,
                            params, x, key)


@functools.partial(jax.jit, static_argnums=(0, 1, 2))
def _mesh_apply_cells_impl(sched, block_b, interpret, t_all, x, alpha_in,
                           alpha):
    batch_shape = x.shape[:-1]
    x2 = x.reshape((-1, sched.n)).astype(jnp.complex64)
    if alpha_in is not None:
        x2 = x2 * jnp.exp(-1j * alpha_in.astype(jnp.complex64))
    coef = pack_cells(sched, t_all)
    y = _run_mesh_planes(sched, x2, coef, block_b, interpret)
    if alpha is not None:
        y = y * jnp.exp(-1j * alpha.astype(jnp.complex64))
    return y.reshape(batch_shape + (sched.n,))


def mesh_apply_cells(t_all: Array, x: Array, *, plan: mesh_lib.MeshPlan,
                     alpha_in: Array | None = None,
                     alpha: Array | None = None, block_b: int = 128,
                     interpret: bool | None = None) -> Array:
    """Kernel mesh apply from explicit per-cell 2x2 matrices ``[C, P, 2, 2]``.

    The cells-level entry point: callers that build transfer matrices
    directly — e.g. Monte-Carlo yield sweeps vmapping over sampled
    ``HardwareModel`` draws — hit the same fused sweep without going
    through (theta, phi).  ``vmap``-compatible over ``t_all`` and ``x``.
    """
    if interpret is None:
        interpret = _default_interpret()
    sched = schedule_from_plan(plan)
    KERNEL_PATH_CALLS["mesh_apply_cells"] += 1
    return _mesh_apply_cells_impl(sched, block_b, interpret, t_all, x,
                                  alpha_in, alpha)


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3, 4))
def _rfnn_linear_impl(sched_v, sched_u, hardware, block_b, interpret,
                      v_params, atten, u_params, x, scale, key_v, key_u):
    TRACE_COUNTS["rfnn_linear"] += 1  # python side effect: trace time only
    n = sched_v.n
    batch_shape = x.shape[:-1]
    x2 = x.reshape((-1, n)).astype(jnp.complex64)
    if v_params.get("alpha_in") is not None:
        x2 = x2 * jnp.exp(-1j * v_params["alpha_in"].astype(jnp.complex64))

    coef_v = _mesh_coefficients(sched_v, v_params, hardware, key_v)
    coef_u = _mesh_coefficients(sched_u, u_params, hardware, key_u)

    # fold V's output screen (and U's input screen) into the mid-gain and
    # U's output screen into the post-gain — all diagonal, so they commute
    g1 = atten.astype(jnp.complex64)
    if v_params.get("alpha") is not None:
        g1 = g1 * jnp.exp(-1j * v_params["alpha"].astype(jnp.complex64))
    if u_params.get("alpha_in") is not None:
        g1 = g1 * jnp.exp(-1j * u_params["alpha_in"].astype(jnp.complex64))
    g2 = jnp.full((n,), jnp.asarray(scale, jnp.complex64))
    if u_params.get("alpha") is not None:
        g2 = g2 * jnp.exp(-1j * u_params["alpha"].astype(jnp.complex64))
    gains = jnp.stack([
        jnp.real(g1[0::2]), jnp.imag(g1[0::2]),
        jnp.real(g1[1::2]), jnp.imag(g1[1::2]),
        jnp.real(g2[0::2]), jnp.imag(g2[0::2]),
        jnp.real(g2[1::2]), jnp.imag(g2[1::2]),
    ]).astype(jnp.float32)

    bb = _auto_block(x2.shape[0], block_b)
    x2, b_orig = _pad_batch(x2, bb)
    nb = x2.shape[0] // bb
    planes = ref.split_channels(x2)
    oe, oo = _rfnn_planes(sched_v, sched_u, bb, nb, interpret,
                          coef_v, coef_u, gains, *planes)
    out = jnp.stack([oe, oo], axis=-1).reshape((-1, n))[:b_orig]
    return out.reshape(batch_shape + (n,))


def rfnn_linear(v_params: dict, atten: Array, u_params: dict, x: Array, *,
                n: int, scale: Array | float = 1.0,
                v_plan: mesh_lib.MeshPlan | None = None,
                u_plan: mesh_lib.MeshPlan | None = None,
                hardware: hw_lib.HardwareModel | None = None,
                key_v: Array | None = None, key_u: Array | None = None,
                block_b: int = 128,
                interpret: bool | None = None) -> Array:
    """Fused analog linear layer |scale * U(D(V x))| via the Pallas kernel.

    ``atten``: [n] attenuation (paper's diagonal D / sigma_max);
    ``scale``: the digital gamma.  Output is the detected magnitude [.., n]
    (apply ``hardware.detect_magnitude`` on top for the detector's noise
    and sensitivity floor).  ``v_plan``/``u_plan`` default to the Clements
    rectangle; analytic Reck programs run in the same fused sweep.  With
    ``hardware``, cell coefficients come from the imperfection model, with
    phase noise drawn from ``key_v``/``key_u`` exactly like the reference
    path.  Differentiable w.r.t. both mesh params, ``atten``, ``scale``
    and ``x`` through the fused kernel VJP.
    """
    if interpret is None:
        interpret = _default_interpret()
    sched_v = (clements_schedule(n) if v_plan is None
               else schedule_from_plan(v_plan))
    sched_u = (clements_schedule(n) if u_plan is None
               else schedule_from_plan(u_plan))
    KERNEL_PATH_CALLS["rfnn_linear"] += 1
    return _rfnn_linear_impl(sched_v, sched_u, hardware, block_b, interpret,
                             v_params, atten, u_params, x,
                             jnp.asarray(scale, jnp.float32), key_v, key_u)


# ---------------------------------------------------------------------------
# Network megakernel: the whole L-layer RFNN in one fused sweep
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _network_planes(net, block_b, nb, interpret, coef_v, coef_u, gains,
                    xer, xei, xor, xoi):
    call = givens_mesh.network_pallas_call(
        net.n, net.n_layers, net.n_columns, block_b, nb, interpret)
    pv, pu = network_parity_arrays(net)
    return tuple(call(coef_v, pv, coef_u, pu, gains, xer, xei, xor, xoi))


def _network_planes_fwd(net, block_b, nb, interpret, coef_v, coef_u, gains,
                        xer, xei, xor, xoi):
    call = givens_mesh.network_fwd_pallas_call(
        net.n, net.n_layers, net.n_columns, block_b, nb, interpret)
    pv, pu = network_parity_arrays(net)
    oe, oo, *stages = call(coef_v, pv, coef_u, pu, gains,
                           xer, xei, xor, xoi)
    # residuals: coefficients/gains + the network input + every layer's
    # two pre-gain stage boundaries — everything inside a mesh is
    # recomputed by the reversed inverse sweep
    return (oe, oo), (coef_v, coef_u, gains, (xer, xei, xor, xoi),
                      tuple(stages))


def _network_planes_bwd(net, block_b, nb, interpret, res, cot):
    coef_v, coef_u, gains, xplanes, stages = res
    call = givens_mesh.network_bwd_pallas_call(
        net.n, net.n_layers, net.n_columns, block_b, nb, interpret)
    pv, pu = network_parity_arrays(net)
    dcv, dcu, dg, dxer, dxei, dxor, dxoi = call(
        givens_mesh.inverse_coefficients(coef_v),
        givens_mesh.adjoint_coefficients(coef_v), pv,
        givens_mesh.inverse_coefficients(coef_u),
        givens_mesh.adjoint_coefficients(coef_u), pu,
        gains, *xplanes, *stages, *cot)
    return dcv, dcu, dg, dxer, dxei, dxor, dxoi


_network_planes.defvjp(_network_planes_fwd, _network_planes_bwd)


def _layer_gains(n: int, la: dict) -> Array:
    """One layer's 12-row gain stack: g0 (input screens), g1 (attenuation +
    folded mid screens), g2 (digital scale + output screen)."""
    v_params, u_params = la["v"], la["u"]
    g0 = jnp.ones((n,), jnp.complex64)
    if v_params.get("alpha_in") is not None:
        g0 = g0 * jnp.exp(-1j * v_params["alpha_in"].astype(jnp.complex64))
    g1 = la["atten"].astype(jnp.complex64)
    if v_params.get("alpha") is not None:
        g1 = g1 * jnp.exp(-1j * v_params["alpha"].astype(jnp.complex64))
    if u_params.get("alpha_in") is not None:
        g1 = g1 * jnp.exp(-1j * u_params["alpha_in"].astype(jnp.complex64))
    g2 = jnp.full((n,), jnp.asarray(la.get("scale", 1.0), jnp.complex64))
    if u_params.get("alpha") is not None:
        g2 = g2 * jnp.exp(-1j * u_params["alpha"].astype(jnp.complex64))
    rows = []
    for g in (g0, g1, g2):
        rows += [jnp.real(g[0::2]), jnp.imag(g[0::2]),
                 jnp.real(g[1::2]), jnp.imag(g[1::2])]
    return jnp.stack(rows).astype(jnp.float32)  # [12, P]


@functools.partial(jax.jit, static_argnums=(0, 1))
def _pack_network_impl(net, hardware, layers):
    """Stacked [L, C, 8, P] coefficients + [L, 12, P] gains for the
    megakernel, identity-padded to the schedule's common column count."""
    c = net.n_columns
    coef_v, coef_u, gains = [], [], []
    for (sv, su), la in zip(net.layers, layers):
        coef_v.append(pad_columns(
            _mesh_coefficients(sv, la["v"], hardware, la.get("key_v")), c))
        coef_u.append(pad_columns(
            _mesh_coefficients(su, la["u"], hardware, la.get("key_u")), c))
        gains.append(_layer_gains(net.n, la))
    return (jnp.stack(coef_v), jnp.stack(coef_u), jnp.stack(gains))


#: VMEM working-set target for the fused network sweep (well under the
#: ~16 MB/core budget: the backward also holds 2 coefficient tensors per
#: mesh plus the gradient accumulators).
_NETWORK_VMEM_TARGET = 4 * 1024 * 1024


def _network_auto_block(b: int, block_b: int | None, n: int,
                        n_layers: int) -> int:
    """Pick the batch block for the megakernel.

    ``None`` sizes the block so the resident planes — 8 stage-residual
    planes per layer plus ~12 working planes — fit the VMEM target: small
    networks get large blocks (fewer grid revisits of the coefficient
    accumulators), deep/wide ones shrink toward the classic 128.
    """
    if block_b is None:
        per_row = (8 * n_layers + 12) * (n // 2) * 4
        block_b = max(8, min(1024, _NETWORK_VMEM_TARGET // per_row))
    return _auto_block(b, block_b)


@functools.partial(jax.jit, static_argnums=(0, 1, 2))
def _rfnn_network_apply_impl(net, block_b, interpret, coef_v, coef_u, gains,
                             x):
    TRACE_COUNTS["rfnn_network"] += 1  # python side effect: trace time only
    n = net.n
    batch_shape = x.shape[:-1]
    x2 = x.reshape((-1, n)).astype(jnp.complex64)
    bb = _network_auto_block(x2.shape[0], block_b, n, net.n_layers)
    x2, b_orig = _pad_batch(x2, bb)
    nb = x2.shape[0] // bb
    planes = ref.split_channels(x2)
    oe, oo = _network_planes(net, bb, nb, interpret, coef_v, coef_u, gains,
                             *planes)
    out = jnp.stack([oe, oo], axis=-1).reshape((-1, n))[:b_orig]
    return out.reshape(batch_shape + (n,))


def _contains_tracer(tree) -> bool:
    return any(isinstance(leaf, jax.core.Tracer)
               for leaf in jax.tree.leaves(tree))


class _LeafIdCache:
    """Small LRU keyed on (static key, id of every pytree leaf).

    Holding strong references to the keyed leaves keeps their ids from
    being recycled, so a hit is exact: same schedule, same (immutable)
    parameter arrays -> same packed coefficients, with zero packing work.
    Tracer leaves must bypass this cache (they are trace-local).
    """

    def __init__(self, maxsize: int = 8):
        self._maxsize = maxsize
        self._entries: dict[tuple, tuple] = {}  # key -> (leaves, value)

    def get_or_build(self, static_key, tree, builder):
        key = (static_key,) + tuple(id(l) for l in jax.tree.leaves(tree))
        hit = self._entries.get(key)
        if hit is not None:
            return hit[1]
        value = builder()
        while len(self._entries) >= self._maxsize:
            self._entries.pop(next(iter(self._entries)))
        self._entries[key] = (jax.tree.leaves(tree), value)
        return value

    def clear(self):
        self._entries.clear()


_NETWORK_PACK_CACHE = _LeafIdCache(maxsize=8)

_SHARED_LEAF_CACHES: dict = {}


def memoize_by_leaf_ids(static_key, tree, builder):
    """Leaf-identity memoization for derived-parameter pipelines.

    Callers (e.g. ``AnalogSequence``) use this to keep *derived* arrays
    (sigmoid'd attenuations, quantized phases, packed coefficients) stable
    across eager calls with the same underlying parameters, which is what
    lets the downstream pack cache hit.  Tracer leaves bypass (trace-local
    values must never be cached); the per-static-key LRU is small and
    holds strong leaf references so ids cannot be recycled.
    """
    if _contains_tracer(tree):
        return builder()
    cache = _SHARED_LEAF_CACHES.setdefault(static_key, _LeafIdCache())
    return cache.get_or_build(static_key, tree, builder)


def pack_network(layers, *, n: int, plans=None,
                 hardware: hw_lib.HardwareModel | None = None):
    """Emit the megakernel inputs for an L-layer RFNN program.

    Returns ``(net, (coef_v, coef_u, gains))``: the static
    :class:`~repro.kernels.schedule.NetworkSchedule` plus the stacked
    ``[L, C, 8, P]`` coefficient tensors and ``[L, 12, P]`` gain rows,
    identity-padded to the schedule's common column count.  This is the
    packing step of :func:`rfnn_network`, exposed so offline compilation
    (``repro.compile.lower``) can emit — and pre-warm — the exact tensors
    the serving path consumes.  Results go through the leaf-identity pack
    cache: a later :func:`rfnn_network` call with the same (immutable)
    layer arrays reuses them with zero packing work.  Tracer leaves
    bypass the cache so gradients flow through packing.
    """
    layers = tuple(layers)
    net = network_schedule(n, len(layers), plans)

    def build():
        PACK_EVENTS["rfnn_network"] += 1
        return _pack_network_impl(net, hardware, layers)

    if _contains_tracer(layers):
        return net, build()
    return net, _NETWORK_PACK_CACHE.get_or_build(
        (net, hardware), layers, build)


def rfnn_network(layers, x: Array, *, n: int,
                 plans=None,
                 hardware: hw_lib.HardwareModel | None = None,
                 block_b: int | None = None,
                 interpret: bool | None = None,
                 packed=None) -> Array:
    """The fused L-layer RFNN |.. |scale_l * U_l(D_l(V_l ..))| .. | sweep.

    ``layers``: per-layer dicts with keys ``v``/``u`` (mesh params,
    optional ``alpha_in``/``alpha`` screens), ``atten`` ([n] diagonal),
    optional ``scale`` (digital gamma, default 1) and, with ``hardware``,
    optional ``key_v``/``key_u`` phase-noise keys — the same split an
    :class:`repro.core.analog_linear.AnalogLinear` layer consumes, so the
    megakernel is draw-for-draw comparable with the per-layer paths.
    ``plans``: per-layer ``(v_plan, u_plan)`` pairs (default Clements).

    One ``pallas_call`` forward and one backward for the whole network:
    inter-layer activations never leave VMEM, and the backward saves only
    the layer-boundary magnitudes (DESIGN.md, "Network megakernel").

    Packed coefficients are cached per (schedule, param identity): repeat
    calls with the same (immutable) arrays — the serving steady state — do
    zero packing work.  Tracers bypass the cache, so gradients flow
    through packing exactly as in the per-layer path.  ``block_b=None``
    sizes the batch block to the kernel's VMEM target (large blocks for
    small networks, shrinking with n and L).

    ``packed``: an explicit ``pack_network`` result ``(net, tensors)`` —
    callers that emitted their coefficients offline (compiled analog
    programs) hand them back here and skip the pack/cache lookup
    entirely, so their zero-packing guarantee cannot be evicted out from
    under them by other users of the shared cache.
    """
    if interpret is None:
        interpret = _default_interpret()
    KERNEL_PATH_CALLS["rfnn_network"] += 1
    if packed is None:
        packed = pack_network(layers, n=n, plans=plans, hardware=hardware)
    net, tensors = packed
    return _rfnn_network_apply_impl(net, block_b, interpret, *tensors, x)


# ---------------------------------------------------------------------------
# Tile-grid megakernel: a (To x Ti) grid of analog tiles in one fused sweep
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _tilegrid_planes(grid, block_b, nb, interpret, coef_v, coef_u, gains,
                     xer, xei, xor, xoi):
    call = givens_mesh.tilegrid_pallas_call(
        grid.n, grid.to, grid.ti, grid.n_columns, block_b, nb, interpret)
    pv, pu = tile_grid_parity_arrays(grid)
    return tuple(call(coef_v, pv, coef_u, pu, gains, xer, xei, xor, xoi))


def _tilegrid_planes_fwd(grid, block_b, nb, interpret, coef_v, coef_u, gains,
                         xer, xei, xor, xoi):
    call = givens_mesh.tilegrid_fwd_pallas_call(
        grid.n, grid.to, grid.ti, grid.n_columns, block_b, nb, interpret)
    pv, pu = tile_grid_parity_arrays(grid)
    oer, oei, oor, ooi, *stages = call(coef_v, pv, coef_u, pu, gains,
                                       xer, xei, xor, xoi)
    # residuals: coefficients/gains + the input planes + every tile's two
    # pre-gain stage boundaries — everything inside a mesh is recomputed
    # by the reversed inverse sweep, same rule as the other kernels
    return (oer, oei, oor, ooi), (coef_v, coef_u, gains,
                                  (xer, xei, xor, xoi), tuple(stages))


def _tilegrid_planes_bwd(grid, block_b, nb, interpret, res, cot):
    coef_v, coef_u, gains, xplanes, stages = res
    call = givens_mesh.tilegrid_bwd_pallas_call(
        grid.n, grid.to, grid.ti, grid.n_columns, block_b, nb, interpret)
    pv, pu = tile_grid_parity_arrays(grid)
    dcv, dcu, dg, dxer, dxei, dxor, dxoi = call(
        givens_mesh.inverse_coefficients(coef_v),
        givens_mesh.adjoint_coefficients(coef_v), pv,
        givens_mesh.inverse_coefficients(coef_u),
        givens_mesh.adjoint_coefficients(coef_u), pu,
        gains, *xplanes, *stages, *cot)
    # dx arrives as per-row partials [To, B, Ti, P] (each grid step writes
    # its own slab); the sum over rows is the transpose of the combine
    return (dcv, dcu, dg, jnp.sum(dxer, axis=0), jnp.sum(dxei, axis=0),
            jnp.sum(dxor, axis=0), jnp.sum(dxoi, axis=0))


_tilegrid_planes.defvjp(_tilegrid_planes_fwd, _tilegrid_planes_bwd)


@functools.partial(jax.jit, static_argnums=(0, 1))
def _pack_tile_grid_impl(grid, hardware, tiles):
    """Stacked [To, Ti, C, 8, P] coefficients + [To, Ti, 12, P] gains for
    the tile-grid kernel, identity-padded to the grid's common column
    count.  Per-tile gains reuse the network layer layout (g0 input
    screens, g1 attenuation + folded mid screens, g2 digital scale +
    output screen)."""
    c = grid.n_columns
    coef_v, coef_u, gains = [], [], []
    for srow, trow in zip(grid.tiles, tiles):
        cv_row, cu_row, g_row = [], [], []
        for (sv, su), ta in zip(srow, trow):
            cv_row.append(pad_columns(
                _mesh_coefficients(sv, ta["v"], hardware, ta.get("key_v")),
                c))
            cu_row.append(pad_columns(
                _mesh_coefficients(su, ta["u"], hardware, ta.get("key_u")),
                c))
            g_row.append(_layer_gains(grid.n, ta))
        coef_v.append(jnp.stack(cv_row))
        coef_u.append(jnp.stack(cu_row))
        gains.append(jnp.stack(g_row))
    return (jnp.stack(coef_v), jnp.stack(coef_u), jnp.stack(gains))


_TILEGRID_PACK_CACHE = _LeafIdCache(maxsize=8)


def pack_tile_grid(tiles, *, n: int, plans=None,
                   hardware: hw_lib.HardwareModel | None = None):
    """Emit the tile-grid kernel inputs for a (To x Ti) grid of tiles.

    ``tiles``: nested ``[To][Ti]`` sequence of per-tile dicts with keys
    ``v``/``u`` (mesh params, optional ``alpha_in``/``alpha`` screens),
    ``atten`` ([n] diagonal), optional ``scale`` (digital gamma) and, with
    ``hardware``, optional ``key_v``/``key_u`` phase-noise keys — the same
    argument shape one :func:`rfnn_network` layer consumes.  Returns
    ``(grid, (coef_v, coef_u, gains))`` ready for :func:`tiled_apply`'s
    ``packed=``.  Results go through the tile-grid leaf-identity pack
    cache (``PACK_EVENTS["tiled_apply"]``): repeat calls with the same
    (immutable) tile arrays do zero packing work; tracers bypass so
    gradients flow through packing.
    """
    tiles = tuple(tuple(row) for row in tiles)
    grid = tile_grid_schedule(n, len(tiles), len(tiles[0]), plans)

    def build():
        PACK_EVENTS["tiled_apply"] += 1
        return _pack_tile_grid_impl(grid, hardware, tiles)

    if _contains_tracer(tiles):
        return grid, build()
    return grid, _TILEGRID_PACK_CACHE.get_or_build(
        (grid, hardware), tiles, build)


def _tilegrid_auto_block(b: int, block_b: int | None, n: int,
                         ti: int) -> int:
    """Batch block for the tile-grid kernel: ``None`` sizes the block so
    the resident planes — 8 stage-residual planes per input tile plus the
    4 x Ti input and working planes — fit the VMEM target, like the
    network kernel's auto-blocking."""
    if block_b is None:
        per_row = (12 * ti + 8) * (n // 2) * 4
        block_b = max(8, min(1024, _NETWORK_VMEM_TARGET // per_row))
    return _auto_block(b, block_b)


@functools.partial(jax.jit, static_argnums=(0, 1, 2))
def _tiled_apply_impl(grid, block_b, interpret, coef_v, coef_u, gains, x):
    TRACE_COUNTS["tiled_apply"] += 1  # python side effect: trace time only
    n, to, ti = grid.n, grid.to, grid.ti
    batch_shape = x.shape[:-1]
    xt = x.reshape((-1, ti, n)).astype(jnp.complex64)
    bb = _tilegrid_auto_block(xt.shape[0], block_b, n, ti)
    xt, b_orig = _pad_batch(xt, bb)
    nb = xt.shape[0] // bb
    xe, xo = xt[..., 0::2], xt[..., 1::2]          # [B, Ti, P] per plane
    planes = (jnp.real(xe).astype(jnp.float32),
              jnp.imag(xe).astype(jnp.float32),
              jnp.real(xo).astype(jnp.float32),
              jnp.imag(xo).astype(jnp.float32))
    oer, oei, oor, ooi = _tilegrid_planes(grid, bb, nb, interpret,
                                          coef_v, coef_u, gains, *planes)
    ye = oer + 1j * oei                            # [B, To, P]
    yo = oor + 1j * ooi
    y = jnp.stack([ye, yo], axis=-1).reshape((-1, to * n))[:b_orig]
    return y.astype(jnp.complex64).reshape(batch_shape + (to * n,))


# ---------------------------------------------------------------------------
# Sharded tile-grid megakernel: (tile-row x batch) grid over a jax.Mesh
# ---------------------------------------------------------------------------
#
# The tile-grid kernel's pallas grid is (To x batch blocks); past one
# device's VMEM, the same grid shards over a 2-axis ``jax.Mesh`` via
# shard_map: each device runs the *identical* pallas call on its
# (To/rows)-row slab with its batch shard.  The forward needs no
# collective — every row's combine is local to the device holding that
# row.  The backward's input cotangent is the transpose of the row
# combine: each device sums its local per-row partials, and a ``psum``
# over the row axis finishes the reduction — the matched-line power
# combiner's exact distributed analog.  The pallas calls take only
# dimensions as statics (all per-tile structure rides in the
# parity/coefficient *operands*), so the row-local call is the same
# program on every device and needs no per-shard statics.
#
# Coefficient operands enter the shard_map REPLICATED (in_spec P()) and
# each device slices its own row slab in-body by ``axis_index``; the
# backward all-gathers the coefficient grads back to replicated.  They
# are small (To*Ti*C*8*P floats), and splitting them on the row axis
# instead trips a GSPMD bug on this jax version: under an enclosing jit
# on a multi-axis mesh, concatenate/stack-built values (exactly what
# ``pack_tile_grid`` emits when traced, e.g. under ``jit(grad(...))``)
# feeding a shard_map along a partitioned axis get mis-partitioned —
# row shards arrive summed, corrupting forward and backward alike.
# Replicated operands take the all-gather path, which is sound (the
# batch planes are safe either way: they are built with ``jnp.pad`` +
# strided slices — see ``_pad_batch``).


def _shard_specs(row_axis: str, data_axis: str):
    from repro.parallel.sharding import tile_grid_shard_specs

    return tile_grid_shard_specs(row_axis, data_axis)


def _shard_map(body, mesh, in_specs, out_specs):
    from repro.parallel.sharding import shard_map_compat

    return shard_map_compat(body, mesh=mesh, in_specs=in_specs,
                            out_specs=out_specs)


def _row_slab(row_axis, to_local):
    """In-body slice of a device's tile-row slab from a replicated
    ``[To, ...]`` operand."""
    def sl(a):
        r = jax.lax.axis_index(row_axis)
        return jax.lax.dynamic_slice_in_dim(a, r * to_local, to_local, 0)
    return sl


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4, 5, 6))
def _tilegrid_planes_sharded(grid, mesh, row_axis, data_axis, block_b, nb,
                             interpret, coef_v, coef_u, gains,
                             xer, xei, xor, xoi):
    specs = _shard_specs(row_axis, data_axis)
    to_local = grid.to // mesh.shape[row_axis]
    pv, pu = tile_grid_parity_arrays(grid)

    def body(cv, pv, cu, pu, g, xer, xei, xor, xoi):
        sl = _row_slab(row_axis, to_local)
        call = givens_mesh.tilegrid_pallas_call(
            grid.n, to_local, grid.ti, grid.n_columns, block_b, nb,
            interpret)
        return tuple(call(sl(cv), sl(pv), sl(cu), sl(pu), sl(g),
                          xer, xei, xor, xoi))

    fn = _shard_map(body, mesh,
                    (specs.coef,) * 5 + (specs.x_plane,) * 4,
                    (specs.o_plane,) * 4)
    return fn(coef_v, pv, coef_u, pu, gains, xer, xei, xor, xoi)


def _tilegrid_planes_sharded_fwd(grid, mesh, row_axis, data_axis, block_b,
                                 nb, interpret, coef_v, coef_u, gains,
                                 xer, xei, xor, xoi):
    specs = _shard_specs(row_axis, data_axis)
    to_local = grid.to // mesh.shape[row_axis]
    pv, pu = tile_grid_parity_arrays(grid)

    def body(cv, pv, cu, pu, g, xer, xei, xor, xoi):
        sl = _row_slab(row_axis, to_local)
        call = givens_mesh.tilegrid_fwd_pallas_call(
            grid.n, to_local, grid.ti, grid.n_columns, block_b, nb,
            interpret)
        return tuple(call(sl(cv), sl(pv), sl(cu), sl(pu), sl(g),
                          xer, xei, xor, xoi))

    fn = _shard_map(body, mesh,
                    (specs.coef,) * 5 + (specs.x_plane,) * 4,
                    (specs.o_plane,) * 4 + (specs.stage,) * 8)
    oer, oei, oor, ooi, *stages = fn(coef_v, pv, coef_u, pu, gains,
                                     xer, xei, xor, xoi)
    # residuals keep their shardings inside the enclosing jit: coefficient
    # stacks stay row-split, stage planes stay (row x batch)-split, so the
    # backward's shard_map consumes them without any resharding
    return (oer, oei, oor, ooi), (coef_v, coef_u, gains,
                                  (xer, xei, xor, xoi), tuple(stages))


def _tilegrid_planes_sharded_bwd(grid, mesh, row_axis, data_axis, block_b,
                                 nb, interpret, res, cot):
    coef_v, coef_u, gains, xplanes, stages = res
    specs = _shard_specs(row_axis, data_axis)
    to_local = grid.to // mesh.shape[row_axis]
    pv, pu = tile_grid_parity_arrays(grid)

    def body(cv, pv, cu, pu, g, xer, xei, xor, xoi, *rest):
        sl = _row_slab(row_axis, to_local)
        cv, pv, cu, pu, g = sl(cv), sl(pv), sl(cu), sl(pu), sl(g)
        call = givens_mesh.tilegrid_bwd_pallas_call(
            grid.n, to_local, grid.ti, grid.n_columns, block_b, nb,
            interpret)
        dcv, dcu, dg, dxer, dxei, dxor, dxoi = call(
            givens_mesh.inverse_coefficients(cv),
            givens_mesh.adjoint_coefficients(cv), pv,
            givens_mesh.inverse_coefficients(cu),
            givens_mesh.adjoint_coefficients(cu), pu,
            g, xer, xei, xor, xoi, *rest)
        # dx arrives as per-row partials [To_local, B, Ti, P]: the local
        # sum over this device's rows, then the psum over the row axis,
        # complete the transpose of the (now distributed) row combine
        dx = tuple(jax.lax.psum(jnp.sum(d, axis=0), row_axis)
                   for d in (dxer, dxei, dxor, dxoi))
        # coefficient grads: psum over the batch axis (the usual DP
        # gradient reduction of per-shard partials), then an all-gather
        # over the row axis hands every device the full replicated grad
        # — matching the replicated primal operands, so the packing
        # transpose outside never consumes a row-partitioned value
        dcv, dcu, dg = (
            jax.lax.all_gather(jax.lax.psum(d, data_axis), row_axis,
                               axis=0, tiled=True)
            for d in (dcv, dcu, dg))
        return (dcv, dcu, dg) + dx

    fn = _shard_map(
        body, mesh,
        (specs.coef,) * 5 + (specs.x_plane,) * 4 + (specs.stage,) * 8
        + (specs.o_plane,) * 4,
        (specs.coef,) * 3 + (specs.dx_plane,) * 4)
    return tuple(fn(coef_v, pv, coef_u, pu, gains,
                    *xplanes, *stages, *cot))


_tilegrid_planes_sharded.defvjp(_tilegrid_planes_sharded_fwd,
                                _tilegrid_planes_sharded_bwd)


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3, 4, 5))
def _tiled_apply_sharded_impl(grid, mesh, row_axis, data_axis, block_b,
                              interpret, coef_v, coef_u, gains, x):
    TRACE_COUNTS["tiled_apply_sharded"] += 1  # python side effect: trace only
    n, to, ti = grid.n, grid.to, grid.ti
    batch_shape = x.shape[:-1]
    xt = x.reshape((-1, ti, n)).astype(jnp.complex64)
    n_data = mesh.shape[data_axis]
    bb = _tilegrid_auto_block(max(1, -(-xt.shape[0] // n_data)), block_b,
                              n, ti)
    # every device's batch shard must tile into whole blocks
    xt, b_orig = _pad_batch(xt, bb * n_data)
    nb = xt.shape[0] // n_data // bb
    xe, xo = xt[..., 0::2], xt[..., 1::2]          # [B, Ti, P] per plane
    planes = (jnp.real(xe).astype(jnp.float32),
              jnp.imag(xe).astype(jnp.float32),
              jnp.real(xo).astype(jnp.float32),
              jnp.imag(xo).astype(jnp.float32))
    oer, oei, oor, ooi = _tilegrid_planes_sharded(
        grid, mesh, row_axis, data_axis, bb, nb, interpret,
        coef_v, coef_u, gains, *planes)
    ye = oer + 1j * oei                            # [B, To, P]
    yo = oor + 1j * ooi
    y = jnp.stack([ye, yo], axis=-1).reshape((-1, to * n))[:b_orig]
    return y.astype(jnp.complex64).reshape(batch_shape + (to * n,))


def tiled_apply(tiles, x: Array, *, n: int, plans=None,
                hardware: hw_lib.HardwareModel | None = None,
                block_b: int | None = None,
                interpret: bool | None = None, packed=None,
                mesh=None, row_axis: str = "rows",
                data_axis: str = "data") -> Array:
    """A (To x Ti) tile-grid matmul ``sum_i gamma U(D(V x_i))`` per row,
    in ONE ``pallas_call`` per direction.

    ``tiles``/``plans``/``hardware``: see :func:`pack_tile_grid`.  ``x``
    is ``[..., Ti*n]`` and the result is the **complex** combined row
    output ``[..., To*n]`` — the matched-line power combiner sums the Ti
    tile outputs of each row coherently in VMEM, and the readout mode
    (|.| detection, real part) plus detector noise compose on top,
    outside the kernel (they are ordinary JAX and differentiate
    natively).  The custom VJP unwinds every tile from the same saved
    stage boundaries the per-tile composition stores (post-V/post-U per
    tile), so training matches the per-tile path gradient-for-gradient
    with zero per-tile kernel launches.

    ``packed``: an explicit :func:`pack_tile_grid` result — offline
    compilation (``repro.compile.lower_tiled``) hands it back here and
    skips the pack/cache lookup entirely.

    ``mesh``: an optional 2-axis ``jax.sharding.Mesh`` — the same grid
    then shards over ``(row_axis, data_axis)`` via shard_map: tile rows
    split over ``row_axis`` (To no longer has to fit one device), batch
    over ``data_axis``, each device running the identical row-local
    pallas call.  Forward needs no collective (each row's combine is
    device-local); the backward's input cotangent finishes with a
    ``psum`` over ``row_axis`` — the distributed transpose of the
    matched-line row combine.  Semantics (fwd and VJP) match the
    single-device call to float tolerance; requires
    ``To % mesh.shape[row_axis] == 0``.
    """
    if interpret is None:
        interpret = _default_interpret()
    KERNEL_PATH_CALLS["tiled_apply"] += 1
    if packed is None:
        packed = pack_tile_grid(tiles, n=n, plans=plans, hardware=hardware)
    grid, tensors = packed
    if x.shape[-1] != grid.ti * grid.n:
        raise ValueError(
            f"expected trailing dim {grid.ti * grid.n} "
            f"(Ti={grid.ti} tiles of n={grid.n}), got {x.shape}")
    if mesh is None:
        return _tiled_apply_impl(grid, block_b, interpret, *tensors, x)
    KERNEL_PATH_CALLS["tiled_apply_sharded"] += 1
    for ax in (row_axis, data_axis):
        if ax not in mesh.shape:
            raise ValueError(f"mesh has no axis {ax!r}: {dict(mesh.shape)}")
    if grid.to % mesh.shape[row_axis]:
        raise ValueError(
            f"To={grid.to} tile rows do not shard over "
            f"{mesh.shape[row_axis]} devices on axis {row_axis!r}")
    return _tiled_apply_sharded_impl(grid, mesh, row_axis, data_axis,
                                     block_b, interpret, *tensors, x)
