"""Jitted, differentiable public wrappers around the Pallas mesh kernels.

``interpret`` defaults to True off-TPU so the same call sites run in this
CPU container (kernel body executed op-by-op) and compile to Mosaic on TPU.

Both ``mesh_apply`` and ``rfnn_linear`` carry custom VJPs: the backward
pass is itself a fused Pallas kernel that re-runs the mesh columns in
reverse, rebuilding states with the per-cell analytic 2x2 **inverse** and
propagating the cotangent with the **adjoint** (see DESIGN.md) — so
training keeps the same VMEM-resident hot loop as inference for ideal
*and* hardware-imperfect cells, on Clements *and* Reck layouts.  There is
no reference fallback: ``backend="pallas"`` means the kernel path, always.

Everything outside the pallas_call boundary — coefficient packing from
theta/phi (ideal or via the hardware model, including ``key``-driven
phase-noise sampling), channel split/merge, phase screens, gains — is
ordinary JAX and differentiates natively, which is how gradients reach
the mesh phases, attenuations and the digital scale.  Detector noise and
the sensitivity floor also stay outside (``hardware.detect_magnitude``
composes on the returned magnitudes).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core import hardware as hw_lib
from repro.core import mesh as mesh_lib
from repro.core.cell import cell_matrix
from repro.kernels import givens_mesh, ref
from repro.kernels.schedule import (
    MeshSchedule,
    clements_schedule,
    deep_grid_parity_arrays,
    deep_grid_schedule,
    pack_cells,
    pad_columns,
    parity_array,
    schedule_from_plan,
)

Array = jax.Array

#: Instrumentation: per-entry-point invocation counts of the kernel path.
#: Tests use this to assert the Pallas path is actually taken (there is no
#: silent reference fallback left to fall into).  Counts tick on every
#: public-wrapper call (trace time under an outer jit).
KERNEL_PATH_CALLS = {"mesh_apply": 0, "rfnn_linear": 0, "mesh_apply_cells": 0,
                     "rfnn_network": 0, "tiled_apply": 0,
                     "tiled_apply_sharded": 0, "deep_apply": 0,
                     "deep_apply_sharded": 0}

#: Instrumentation: number of times each jitted impl was actually *traced*.
#: Regression tests use this to pin the schedule/trace-cache memoization —
#: structurally equal plans must not re-trigger traces.
TRACE_COUNTS = {"mesh_apply": 0, "rfnn_linear": 0, "rfnn_network": 0,
                "tiled_apply": 0, "tiled_apply_sharded": 0, "deep_apply": 0,
                "deep_apply_sharded": 0}

#: Instrumentation: number of coefficient-pack builds actually executed by
#: :func:`pack_deep_grid` (cache misses / tracer bypasses), keyed by the
#: entry point that requested the pack.  Steady-state serving must not
#: tick this.
PACK_EVENTS = {"rfnn_network": 0, "tiled_apply": 0, "deep_apply": 0}


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _auto_block(b: int, block_b: int) -> int:
    """Shrink ``block_b`` to divide the batch evenly (never grow past it).

    ``block_b`` is a working-set ceiling, not a quantum: padding the
    batch up to a multiple of the raw ceiling can waste most of the last
    block (e.g. 256 rows in 232-row blocks -> 464 padded rows).
    Spreading the same rows over ``ceil(b / block_b)`` equal blocks keeps
    every block under the ceiling with at most 7 pad rows per block."""
    if b <= 0:
        return 1
    block_b = max(1, block_b)
    if b <= block_b + block_b // 8:
        # anti-fragmentation: a single block may overshoot the ceiling by
        # <= 1/8 (the target itself sits well under the physical budget)
        return max(1, -(-b // 8) * 8)
    n_blocks = -(-b // block_b)
    even = -(-b // n_blocks)                       # ceil(b / n_blocks)
    return max(1, min(block_b, -(-even // 8) * 8))


def _pad_batch(x2d: Array, block: int) -> tuple[Array, int]:
    b = x2d.shape[0]
    pad = (-b) % block
    if pad:
        # jnp.pad, not concatenate-with-zeros: GSPMD mis-partitions a
        # concatenate feeding shard_map on a multi-axis mesh (the row-axis
        # shards get summed instead of replicated); the pad HLO shards
        # correctly and is semantically identical here
        x2d = jnp.pad(x2d, ((0, pad),) + ((0, 0),) * (x2d.ndim - 1))
    return x2d, b


# ---------------------------------------------------------------------------
# custom-VJP boundary: de-interleaved planes in, planes out
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _mesh_planes(sched, block_b, nb, interpret, coef, xer, xei, xor, xoi):
    call = givens_mesh.mesh_pallas_call(
        sched.n, sched.n_columns, block_b, nb, interpret)
    return tuple(call(coef, parity_array(sched), xer, xei, xor, xoi))


def _mesh_planes_fwd(sched, block_b, nb, interpret, coef, xer, xei, xor, xoi):
    outs = _mesh_planes(sched, block_b, nb, interpret, coef,
                        xer, xei, xor, xoi)
    # the output planes are the only state residual needed: the backward
    # sweep rebuilds every intermediate via the per-cell inverse
    return outs, (coef, outs)


def _mesh_planes_bwd(sched, block_b, nb, interpret, res, cot):
    coef, outs = res
    coef_inv = givens_mesh.inverse_coefficients(coef)
    coef_adj = givens_mesh.adjoint_coefficients(coef)
    call = givens_mesh.mesh_bwd_pallas_call(
        sched.n, sched.n_columns, block_b, nb, interpret)
    dcoef, dxer, dxei, dxor, dxoi = call(
        coef_inv, coef_adj, parity_array(sched), *outs, *cot)
    return dcoef, dxer, dxei, dxor, dxoi


_mesh_planes.defvjp(_mesh_planes_fwd, _mesh_planes_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4))
def _rfnn_planes(sched_v, sched_u, block_b, nb, interpret, coef_v, coef_u,
                 gains, xer, xei, xor, xoi):
    call = givens_mesh.rfnn_linear_pallas_call(
        sched_v.n, sched_v.n_columns, sched_u.n_columns, block_b, nb,
        interpret)
    return tuple(call(coef_v, parity_array(sched_v),
                      coef_u, parity_array(sched_u), gains,
                      xer, xei, xor, xoi))


def _rfnn_planes_fwd(sched_v, sched_u, block_b, nb, interpret, coef_v,
                     coef_u, gains, xer, xei, xor, xoi):
    call = givens_mesh.rfnn_linear_fwd_pallas_call(
        sched_v.n, sched_v.n_columns, sched_u.n_columns, block_b, nb,
        interpret)
    oe, oo, *stage = call(coef_v, parity_array(sched_v),
                          coef_u, parity_array(sched_u), gains,
                          xer, xei, xor, xoi)
    return (oe, oo), (coef_v, coef_u, gains, tuple(stage))


def _rfnn_planes_bwd(sched_v, sched_u, block_b, nb, interpret, res, cot):
    coef_v, coef_u, gains, stage = res
    call = givens_mesh.rfnn_linear_bwd_pallas_call(
        sched_v.n, sched_v.n_columns, sched_u.n_columns, block_b, nb,
        interpret)
    dcv, dcu, dgains, dxer, dxei, dxor, dxoi = call(
        givens_mesh.inverse_coefficients(coef_v),
        givens_mesh.adjoint_coefficients(coef_v), parity_array(sched_v),
        givens_mesh.inverse_coefficients(coef_u),
        givens_mesh.adjoint_coefficients(coef_u), parity_array(sched_u),
        gains, *stage, *cot)
    return dcv, dcu, dgains, dxer, dxei, dxor, dxoi


_rfnn_planes.defvjp(_rfnn_planes_fwd, _rfnn_planes_bwd)


# ---------------------------------------------------------------------------
# coefficient construction (ideal cells or the hardware model)
# ---------------------------------------------------------------------------

def _mesh_coefficients(sched: MeshSchedule, params: dict,
                       hardware: hw_lib.HardwareModel | None,
                       key: Array | None) -> Array:
    """Packed [C', 8, P] coefficients from mesh params.

    With a hardware model, cells come from ``imperfect_cell_matrix`` —
    the same function (and the same ``key`` consumption) as the reference
    ``apply_mesh_hw`` path, so the two backends see identical draws.
    """
    theta, phi = params["theta"], params["phi"]
    if hardware is None:
        t_all = cell_matrix(theta, phi)
    else:
        t_all = hw_lib.imperfect_cell_matrix(theta, phi, hardware, key)
    return pack_cells(sched, t_all)


def _run_mesh_planes(sched, x2, coef, block_b, interpret):
    bb = _auto_block(x2.shape[0], block_b)
    x2, b_orig = _pad_batch(x2, bb)
    nb = x2.shape[0] // bb
    planes = ref.split_channels(x2)
    planes = _mesh_planes(sched, bb, nb, interpret, coef, *planes)
    return ref.merge_channels(*planes)[:b_orig]


# ---------------------------------------------------------------------------
# Public wrappers
# ---------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnums=(0, 1, 2, 3))
def _mesh_apply_impl(sched, hardware, block_b, interpret, params, x, key):
    TRACE_COUNTS["mesh_apply"] += 1  # python side effect: runs at trace only
    batch_shape = x.shape[:-1]
    x2 = x.reshape((-1, sched.n)).astype(jnp.complex64)
    alpha_in = params.get("alpha_in")
    if alpha_in is not None:
        x2 = x2 * jnp.exp(-1j * alpha_in.astype(jnp.complex64))
    coef = _mesh_coefficients(sched, params, hardware, key)
    y = _run_mesh_planes(sched, x2, coef, block_b, interpret)
    alpha = params.get("alpha")
    if alpha is not None:
        y = y * jnp.exp(-1j * alpha.astype(jnp.complex64))
    return y.reshape(batch_shape + (sched.n,))


def mesh_apply(params: dict, x: Array, *, n: int,
               plan: mesh_lib.MeshPlan | None = None,
               hardware: hw_lib.HardwareModel | None = None,
               key: Array | None = None, block_b: int = 128,
               interpret: bool | None = None) -> Array:
    """Apply a mesh to ``x[..., n]`` via the Pallas kernel.

    Semantics match ``repro.core.mesh.apply_mesh`` on the given plan
    (``None`` = the Clements rectangle), including the optional phase
    screens ``alpha_in`` / ``alpha``; with ``hardware`` they match
    ``repro.core.hardware.apply_mesh_hw`` (imperfect hybrids, per-cell
    insertion loss, and ``key``-sampled phase-shifter noise).
    Differentiable w.r.t. ``params`` and ``x`` through the kernel VJP.
    """
    if interpret is None:
        interpret = _default_interpret()
    sched = clements_schedule(n) if plan is None else schedule_from_plan(plan)
    KERNEL_PATH_CALLS["mesh_apply"] += 1
    return _mesh_apply_impl(sched, hardware, block_b, interpret,
                            params, x, key)


@functools.partial(jax.jit, static_argnums=(0, 1, 2))
def _mesh_apply_cells_impl(sched, block_b, interpret, t_all, x, alpha_in,
                           alpha):
    batch_shape = x.shape[:-1]
    x2 = x.reshape((-1, sched.n)).astype(jnp.complex64)
    if alpha_in is not None:
        x2 = x2 * jnp.exp(-1j * alpha_in.astype(jnp.complex64))
    coef = pack_cells(sched, t_all)
    y = _run_mesh_planes(sched, x2, coef, block_b, interpret)
    if alpha is not None:
        y = y * jnp.exp(-1j * alpha.astype(jnp.complex64))
    return y.reshape(batch_shape + (sched.n,))


def mesh_apply_cells(t_all: Array, x: Array, *, plan: mesh_lib.MeshPlan,
                     alpha_in: Array | None = None,
                     alpha: Array | None = None, block_b: int = 128,
                     interpret: bool | None = None) -> Array:
    """Kernel mesh apply from explicit per-cell 2x2 matrices ``[C, P, 2, 2]``.

    The cells-level entry point: callers that build transfer matrices
    directly — e.g. Monte-Carlo yield sweeps vmapping over sampled
    ``HardwareModel`` draws — hit the same fused sweep without going
    through (theta, phi).  ``vmap``-compatible over ``t_all`` and ``x``.
    """
    if interpret is None:
        interpret = _default_interpret()
    sched = schedule_from_plan(plan)
    KERNEL_PATH_CALLS["mesh_apply_cells"] += 1
    return _mesh_apply_cells_impl(sched, block_b, interpret, t_all, x,
                                  alpha_in, alpha)


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3, 4))
def _rfnn_linear_impl(sched_v, sched_u, hardware, block_b, interpret,
                      v_params, atten, u_params, x, scale, key_v, key_u):
    TRACE_COUNTS["rfnn_linear"] += 1  # python side effect: trace time only
    n = sched_v.n
    batch_shape = x.shape[:-1]
    x2 = x.reshape((-1, n)).astype(jnp.complex64)
    if v_params.get("alpha_in") is not None:
        x2 = x2 * jnp.exp(-1j * v_params["alpha_in"].astype(jnp.complex64))

    coef_v = _mesh_coefficients(sched_v, v_params, hardware, key_v)
    coef_u = _mesh_coefficients(sched_u, u_params, hardware, key_u)

    # fold V's output screen (and U's input screen) into the mid-gain and
    # U's output screen into the post-gain — all diagonal, so they commute
    g1 = atten.astype(jnp.complex64)
    if v_params.get("alpha") is not None:
        g1 = g1 * jnp.exp(-1j * v_params["alpha"].astype(jnp.complex64))
    if u_params.get("alpha_in") is not None:
        g1 = g1 * jnp.exp(-1j * u_params["alpha_in"].astype(jnp.complex64))
    g2 = jnp.full((n,), jnp.asarray(scale, jnp.complex64))
    if u_params.get("alpha") is not None:
        g2 = g2 * jnp.exp(-1j * u_params["alpha"].astype(jnp.complex64))
    gains = jnp.stack([
        jnp.real(g1[0::2]), jnp.imag(g1[0::2]),
        jnp.real(g1[1::2]), jnp.imag(g1[1::2]),
        jnp.real(g2[0::2]), jnp.imag(g2[0::2]),
        jnp.real(g2[1::2]), jnp.imag(g2[1::2]),
    ]).astype(jnp.float32)

    bb = _auto_block(x2.shape[0], block_b)
    x2, b_orig = _pad_batch(x2, bb)
    nb = x2.shape[0] // bb
    planes = ref.split_channels(x2)
    oe, oo = _rfnn_planes(sched_v, sched_u, bb, nb, interpret,
                          coef_v, coef_u, gains, *planes)
    out = jnp.stack([oe, oo], axis=-1).reshape((-1, n))[:b_orig]
    return out.reshape(batch_shape + (n,))


def rfnn_linear(v_params: dict, atten: Array, u_params: dict, x: Array, *,
                n: int, scale: Array | float = 1.0,
                v_plan: mesh_lib.MeshPlan | None = None,
                u_plan: mesh_lib.MeshPlan | None = None,
                hardware: hw_lib.HardwareModel | None = None,
                key_v: Array | None = None, key_u: Array | None = None,
                block_b: int = 128,
                interpret: bool | None = None) -> Array:
    """Fused analog linear layer |scale * U(D(V x))| via the Pallas kernel.

    ``atten``: [n] attenuation (paper's diagonal D / sigma_max);
    ``scale``: the digital gamma.  Output is the detected magnitude [.., n]
    (apply ``hardware.detect_magnitude`` on top for the detector's noise
    and sensitivity floor).  ``v_plan``/``u_plan`` default to the Clements
    rectangle; analytic Reck programs run in the same fused sweep.  With
    ``hardware``, cell coefficients come from the imperfection model, with
    phase noise drawn from ``key_v``/``key_u`` exactly like the reference
    path.  Differentiable w.r.t. both mesh params, ``atten``, ``scale``
    and ``x`` through the fused kernel VJP.
    """
    if interpret is None:
        interpret = _default_interpret()
    sched_v = (clements_schedule(n) if v_plan is None
               else schedule_from_plan(v_plan))
    sched_u = (clements_schedule(n) if u_plan is None
               else schedule_from_plan(u_plan))
    KERNEL_PATH_CALLS["rfnn_linear"] += 1
    return _rfnn_linear_impl(sched_v, sched_u, hardware, block_b, interpret,
                             v_params, atten, u_params, x,
                             jnp.asarray(scale, jnp.float32), key_v, key_u)


# ---------------------------------------------------------------------------
# Deep tiled-network megakernel: L layers x (To x Ti) tiles, one pallas_call
# per direction
# ---------------------------------------------------------------------------
#
# Everything deeper than a single mesh pair routes through here.  An
# L-layer single-mesh RFNN is the To=Ti=1 degenerate case
# (``rfnn_network``); a one-layer (To x Ti) tile grid is the L=1 case
# (``tiled_apply``); the general case is a whole deep tiled network —
# e.g. the paper's 4-layer 64x64 MNIST scale-up — in ONE kernel launch
# per direction, with the inter-layer re-detection done in VMEM (zero
# inter-layer HBM traffic).


def _layer_gains(n: int, la: dict) -> Array:
    """One layer's 12-row gain stack: g0 (input screens), g1 (attenuation +
    folded mid screens), g2 (digital scale + output screen)."""
    v_params, u_params = la["v"], la["u"]
    g0 = jnp.ones((n,), jnp.complex64)
    if v_params.get("alpha_in") is not None:
        g0 = g0 * jnp.exp(-1j * v_params["alpha_in"].astype(jnp.complex64))
    g1 = la["atten"].astype(jnp.complex64)
    if v_params.get("alpha") is not None:
        g1 = g1 * jnp.exp(-1j * v_params["alpha"].astype(jnp.complex64))
    if u_params.get("alpha_in") is not None:
        g1 = g1 * jnp.exp(-1j * u_params["alpha_in"].astype(jnp.complex64))
    g2 = jnp.full((n,), jnp.asarray(la.get("scale", 1.0), jnp.complex64))
    if u_params.get("alpha") is not None:
        g2 = g2 * jnp.exp(-1j * u_params["alpha"].astype(jnp.complex64))
    rows = []
    for g in (g0, g1, g2):
        rows += [jnp.real(g[0::2]), jnp.imag(g[0::2]),
                 jnp.real(g[1::2]), jnp.imag(g[1::2])]
    return jnp.stack(rows).astype(jnp.float32)  # [12, P]


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3, 4))
def _deepgrid_planes(deep, block_b, nb, interpret, detect_last,
                     coef_v, coef_u, gains, xer, xei, xor, xoi):
    call = givens_mesh.deepgrid_pallas_call(
        deep.n, deep.n_layers, deep.to, deep.ti, deep.n_columns,
        block_b, nb, detect_last, interpret)
    pv, pu = deep_grid_parity_arrays(deep)
    return tuple(call(coef_v, pv, coef_u, pu, gains, xer, xei, xor, xoi))


def _deepgrid_planes_fwd(deep, block_b, nb, interpret, detect_last,
                         coef_v, coef_u, gains, xer, xei, xor, xoi):
    call = givens_mesh.deepgrid_fwd_pallas_call(
        deep.n, deep.n_layers, deep.to, deep.ti, deep.n_columns,
        block_b, nb, detect_last, interpret)
    pv, pu = deep_grid_parity_arrays(deep)
    n_out = 2 if detect_last else 4
    outs = call(coef_v, pv, coef_u, pu, gains, xer, xei, xor, xoi)
    # residuals: coefficients/gains + the input planes + every tile's two
    # pre-gain stage boundaries — everything inside a mesh is recomputed
    # by the reversed inverse sweep, and every layer-boundary state is an
    # elementwise function of the saved post-U stages
    return tuple(outs[:n_out]), (coef_v, coef_u, gains,
                                 (xer, xei, xor, xoi), tuple(outs[n_out:]))


def _deepgrid_planes_bwd(deep, block_b, nb, interpret, detect_last, res,
                         cot):
    coef_v, coef_u, gains, xplanes, stages = res
    call = givens_mesh.deepgrid_bwd_pallas_call(
        deep.n, deep.n_layers, deep.to, deep.ti, deep.n_columns,
        block_b, nb, detect_last, interpret)
    pv, pu = deep_grid_parity_arrays(deep)
    dcv, dcu, dg, dxer, dxei, dxor, dxoi = call(
        givens_mesh.inverse_coefficients(coef_v),
        givens_mesh.adjoint_coefficients(coef_v), pv,
        givens_mesh.inverse_coefficients(coef_u),
        givens_mesh.adjoint_coefficients(coef_u), pu,
        gains, *xplanes, *stages, *cot)
    # the combine's transpose (sum of each input tile's cotangent over the
    # To rows) already ran inside the kernel — dx comes back as [B, Ti, P]
    return dcv, dcu, dg, dxer, dxei, dxor, dxoi


_deepgrid_planes.defvjp(_deepgrid_planes_fwd, _deepgrid_planes_bwd)


@functools.partial(jax.jit, static_argnums=(0, 1))
def _pack_deep_grid_impl(deep, hardware, layers):
    """Stacked [L, To, Ti, C, 8, P] coefficients + [L, To, Ti, 12, P]
    gains for the deep megakernel, identity-padded to the network-wide
    column count.  Per-tile gains use the layer layout (g0 input screens,
    g1 attenuation + folded mid screens, g2 digital scale + output
    screen)."""
    c = deep.n_columns
    coef_v, coef_u, gains = [], [], []
    for slayer, tlayer in zip(deep.layers, layers):
        cv_l, cu_l, g_l = [], [], []
        for srow, trow in zip(slayer, tlayer):
            cv_row, cu_row, g_row = [], [], []
            for (sv, su), ta in zip(srow, trow):
                cv_row.append(pad_columns(
                    _mesh_coefficients(sv, ta["v"], hardware,
                                       ta.get("key_v")), c))
                cu_row.append(pad_columns(
                    _mesh_coefficients(su, ta["u"], hardware,
                                       ta.get("key_u")), c))
                g_row.append(_layer_gains(deep.n, ta))
            cv_l.append(jnp.stack(cv_row))
            cu_l.append(jnp.stack(cu_row))
            g_l.append(jnp.stack(g_row))
        coef_v.append(jnp.stack(cv_l))
        coef_u.append(jnp.stack(cu_l))
        gains.append(jnp.stack(g_l))
    return (jnp.stack(coef_v), jnp.stack(coef_u), jnp.stack(gains))


def _contains_tracer(tree) -> bool:
    return any(isinstance(leaf, jax.core.Tracer)
               for leaf in jax.tree.leaves(tree))


class _LeafIdCache:
    """Small LRU keyed on (static key, id of every pytree leaf).

    Holding strong references to the keyed leaves keeps their ids from
    being recycled, so a hit is exact: same schedule, same (immutable)
    parameter arrays -> same packed coefficients, with zero packing work.
    Tracer leaves must bypass this cache (they are trace-local).
    """

    def __init__(self, maxsize: int = 8):
        self._maxsize = maxsize
        self._entries: dict[tuple, tuple] = {}  # key -> (leaves, value)

    def get_or_build(self, static_key, tree, builder):
        key = (static_key,) + tuple(id(l) for l in jax.tree.leaves(tree))
        hit = self._entries.get(key)
        if hit is not None:
            return hit[1]
        value = builder()
        while len(self._entries) >= self._maxsize:
            self._entries.pop(next(iter(self._entries)))
        self._entries[key] = (jax.tree.leaves(tree), value)
        return value

    def clear(self):
        self._entries.clear()


_DEEPGRID_PACK_CACHE = _LeafIdCache(maxsize=8)

_SHARED_LEAF_CACHES: dict = {}


def memoize_by_leaf_ids(static_key, tree, builder):
    """Leaf-identity memoization for derived-parameter pipelines.

    Callers (e.g. ``AnalogSequence``) use this to keep *derived* arrays
    (sigmoid'd attenuations, quantized phases, packed coefficients) stable
    across eager calls with the same underlying parameters, which is what
    lets the downstream pack cache hit.  Tracer leaves bypass (trace-local
    values must never be cached); the per-static-key LRU is small and
    holds strong leaf references so ids cannot be recycled.
    """
    if _contains_tracer(tree):
        return builder()
    cache = _SHARED_LEAF_CACHES.setdefault(static_key, _LeafIdCache())
    return cache.get_or_build(static_key, tree, builder)


def pack_deep_grid(layers, *, n: int, plans=None,
                   hardware: hw_lib.HardwareModel | None = None,
                   _event: str = "deep_apply"):
    """Emit the deep megakernel inputs for L layers of (To x Ti) tiles.

    ``layers``: nested ``[L][To][Ti]`` sequence of per-tile dicts with
    keys ``v``/``u`` (mesh params, optional ``alpha_in``/``alpha``
    screens), ``atten`` ([n] diagonal), optional ``scale`` (digital
    gamma) and, with ``hardware``, optional ``key_v``/``key_u``
    phase-noise keys.  ``plans``: matching ``[L][To][Ti]`` nesting of
    ``(v_plan, u_plan)`` pairs (or ``None`` entries for Clements).

    Returns ``(deep, (coef_v, coef_u, gains))``: the static
    :class:`~repro.kernels.schedule.DeepGridSchedule` plus the stacked
    ``[L, To, Ti, C, 8, P]`` coefficient tensors and
    ``[L, To, Ti, 12, P]`` gain rows, identity-padded to the
    network-wide column count — ready for :func:`deep_apply`'s
    ``packed=``.  Results go through the leaf-identity pack cache
    (``PACK_EVENTS``): repeat calls with the same (immutable) tile
    arrays do zero packing work; tracer leaves bypass so gradients flow
    through packing.
    """
    layers = tuple(tuple(tuple(row) for row in layer) for layer in layers)
    deep = deep_grid_schedule(n, len(layers), len(layers[0]),
                              len(layers[0][0]), plans)

    def build():
        PACK_EVENTS[_event] += 1
        return _pack_deep_grid_impl(deep, hardware, layers)

    if _contains_tracer(layers):
        return deep, build()
    return deep, _DEEPGRID_PACK_CACHE.get_or_build(
        (deep, hardware), layers, build)


#: VMEM working-set target for the fused sweeps (well under the ~16
#: MB/core budget: the backward also holds 2 coefficient tensors per mesh
#: plus the gradient accumulators).
_VMEM_TARGET = 4 * 1024 * 1024


def _vmem_auto_block(b: int, block_b: int | None, n: int,
                     planes_per_row: int) -> int:
    """The one VMEM-budget batch-block helper (every kernel's auto-block
    is this function with its own plane count).

    ``None`` sizes the block so ``planes_per_row`` resident [block, P]
    f32 planes fit the VMEM target — small problems get large blocks
    (fewer grid revisits of the coefficient accumulators), deep/wide
    ones shrink toward the classic 128 — then shrinks for small batches.

    The same target applies in interpret mode: the stacked-sweep bodies
    make grid steps cheap (one fori_loop per mesh regardless of the tile
    count), and a VMEM-sized block is also a host-cache-sized block, so
    a deep grid's batch blocks ride through all L layers while hot —
    the locality the fused kernel exists to buy.
    """
    if block_b is None:
        per_row = planes_per_row * (n // 2) * 4
        block_b = max(8, min(1024, _VMEM_TARGET // per_row // 8 * 8))
    return _auto_block(b, block_b)


def _deep_planes_per_row(deep) -> int:
    """Resident [block, P] planes per batch row for the deep kernel: 8
    stage-residual planes per tile per layer, 4 input and 4 output planes
    per tile column / row slot, ~4 working planes.  Reduces to the
    network kernel's ``8 L + 12`` at To = Ti = 1."""
    return 8 * deep.n_layers * deep.to * deep.ti + 4 * deep.ti \
        + 4 * deep.to + 4


def _split_tile_planes(xt):
    """[B, Ti, n] complex -> 4 de-interleaved [B, Ti, P] f32 planes."""
    xe, xo = xt[..., 0::2], xt[..., 1::2]
    return (jnp.real(xe).astype(jnp.float32),
            jnp.imag(xe).astype(jnp.float32),
            jnp.real(xo).astype(jnp.float32),
            jnp.imag(xo).astype(jnp.float32))


def _merge_deep_out(outs, detect_last, to, n, b_orig, batch_shape):
    """Kernel output planes -> [..., To*n] (real magnitudes or complex)."""
    if detect_last:
        oe, oo = outs                              # [B, To, P] real
        y = jnp.stack([oe, oo], axis=-1).reshape((-1, to * n))[:b_orig]
        return y.reshape(batch_shape + (to * n,))
    oer, oei, oor, ooi = outs
    ye = oer + 1j * oei                            # [B, To, P]
    yo = oor + 1j * ooi
    y = jnp.stack([ye, yo], axis=-1).reshape((-1, to * n))[:b_orig]
    return y.astype(jnp.complex64).reshape(batch_shape + (to * n,))


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3, 4))
def _deep_apply_impl(deep, block_b, interpret, detect_last, trace_key,
                     coef_v, coef_u, gains, x):
    TRACE_COUNTS[trace_key] += 1  # python side effect: trace time only
    n, to, ti = deep.n, deep.to, deep.ti
    batch_shape = x.shape[:-1]
    xt = x.reshape((-1, ti, n)).astype(jnp.complex64)
    bb = _vmem_auto_block(xt.shape[0], block_b, n,
                          _deep_planes_per_row(deep))
    xt, b_orig = _pad_batch(xt, bb)
    nb = xt.shape[0] // bb
    outs = _deepgrid_planes(deep, bb, nb, interpret, detect_last,
                            coef_v, coef_u, gains, *_split_tile_planes(xt))
    return _merge_deep_out(outs, detect_last, to, n, b_orig, batch_shape)


# ---------------------------------------------------------------------------
# Sharded deep megakernel: (tile-row x batch) grid over a jax.Mesh
# ---------------------------------------------------------------------------
#
# Past one device's VMEM, each layer's (To x Ti) grid shards over a
# 2-axis ``jax.Mesh`` via shard_map: every device runs the *identical*
# single-layer pallas call on its (To/rows)-row slab with its batch
# shard.  The forward needs no collective — every row's combine is local
# to the device holding that row.  The backward's input cotangent is the
# transpose of the row combine: the kernel sums its local rows' partials
# in VMEM, and a ``psum`` over the row axis finishes the reduction — the
# matched-line power combiner's exact distributed analog.  Depth does NOT
# fuse across devices: a layer's re-detected outputs are each next
# layer's *full* input, so L > 1 runs as a python chain of single-layer
# sharded calls (one resharding row->replicated per boundary, inserted by
# GSPMD), with the boundary |detect| taken inside the kernel
# (``detect_last=True``) so its zero-guarded backward keeps padded batch
# rows grad-exact.
#
# Coefficient operands enter the shard_map REPLICATED (in_spec P()) and
# each device slices its own row slab (axis 1 of [L, To, Ti, ...])
# in-body by ``axis_index``; the backward all-gathers the coefficient
# grads back to replicated.  They are small (L*To*Ti*C*8*P floats), and
# splitting them on the row axis instead trips a GSPMD bug on this jax
# version: under an enclosing jit on a multi-axis mesh, concatenate/
# stack-built values (exactly what ``pack_deep_grid`` emits when traced,
# e.g. under ``jit(grad(...))``) feeding a shard_map along a partitioned
# axis get mis-partitioned — row shards arrive summed, corrupting forward
# and backward alike.  Replicated operands take the all-gather path,
# which is sound (the batch planes are safe either way: they are built
# with ``jnp.pad`` + strided slices — see ``_pad_batch``).


def _shard_specs(row_axis: str, data_axis: str):
    from repro.parallel.sharding import tile_grid_shard_specs

    return tile_grid_shard_specs(row_axis, data_axis)


def _shard_map(body, mesh, in_specs, out_specs):
    from repro.parallel.sharding import shard_map_compat

    return shard_map_compat(body, mesh=mesh, in_specs=in_specs,
                            out_specs=out_specs)


def _row_slab(row_axis, to_local, axis):
    """In-body slice of a device's tile-row slab from a replicated
    operand whose ``axis`` is the To axis."""
    def sl(a):
        r = jax.lax.axis_index(row_axis)
        return jax.lax.dynamic_slice_in_dim(a, r * to_local, to_local, axis)
    return sl


@functools.partial(jax.custom_vjp,
                   nondiff_argnums=(0, 1, 2, 3, 4, 5, 6, 7, 8))
def _deepgrid_planes_sharded(deep, layer, mesh, row_axis, data_axis,
                             block_b, nb, interpret, detect_last,
                             coef_v, coef_u, gains, xer, xei, xor, xoi):
    specs = _shard_specs(row_axis, data_axis)
    to_local = deep.to // mesh.shape[row_axis]
    pv, pu = deep_grid_parity_arrays(deep)
    pv, pu = pv[layer:layer + 1], pu[layer:layer + 1]

    def body(cv, pv, cu, pu, g, xer, xei, xor, xoi):
        sl = _row_slab(row_axis, to_local, 1)
        call = givens_mesh.deepgrid_pallas_call(
            deep.n, 1, to_local, deep.ti, deep.n_columns, block_b, nb,
            detect_last, interpret)
        return tuple(call(sl(cv), sl(pv), sl(cu), sl(pu), sl(g),
                          xer, xei, xor, xoi))

    n_out = 2 if detect_last else 4
    fn = _shard_map(body, mesh,
                    (specs.coef,) * 5 + (specs.x_plane,) * 4,
                    (specs.o_plane,) * n_out)
    return fn(coef_v, pv, coef_u, pu, gains, xer, xei, xor, xoi)


def _deepgrid_planes_sharded_fwd(deep, layer, mesh, row_axis, data_axis,
                                 block_b, nb, interpret, detect_last,
                                 coef_v, coef_u, gains, xer, xei, xor, xoi):
    specs = _shard_specs(row_axis, data_axis)
    to_local = deep.to // mesh.shape[row_axis]
    pv, pu = deep_grid_parity_arrays(deep)
    pv, pu = pv[layer:layer + 1], pu[layer:layer + 1]

    def body(cv, pv, cu, pu, g, xer, xei, xor, xoi):
        sl = _row_slab(row_axis, to_local, 1)
        call = givens_mesh.deepgrid_fwd_pallas_call(
            deep.n, 1, to_local, deep.ti, deep.n_columns, block_b, nb,
            detect_last, interpret)
        return tuple(call(sl(cv), sl(pv), sl(cu), sl(pu), sl(g),
                          xer, xei, xor, xoi))

    n_out = 2 if detect_last else 4
    fn = _shard_map(body, mesh,
                    (specs.coef,) * 5 + (specs.x_plane,) * 4,
                    (specs.o_plane,) * n_out + (specs.stage,) * 8)
    outs = fn(coef_v, pv, coef_u, pu, gains, xer, xei, xor, xoi)
    # residuals keep their shardings inside the enclosing jit: coefficient
    # stacks stay replicated, stage planes stay (row x batch)-split, so
    # the backward's shard_map consumes them without any resharding
    return tuple(outs[:n_out]), (coef_v, coef_u, gains,
                                 (xer, xei, xor, xoi), tuple(outs[n_out:]))


def _deepgrid_planes_sharded_bwd(deep, layer, mesh, row_axis, data_axis,
                                 block_b, nb, interpret, detect_last, res,
                                 cot):
    coef_v, coef_u, gains, xplanes, stages = res
    specs = _shard_specs(row_axis, data_axis)
    to_local = deep.to // mesh.shape[row_axis]
    pv, pu = deep_grid_parity_arrays(deep)
    pv, pu = pv[layer:layer + 1], pu[layer:layer + 1]

    def body(cv, pv, cu, pu, g, xer, xei, xor, xoi, *rest):
        sl = _row_slab(row_axis, to_local, 1)
        cv, pv, cu, pu, g = sl(cv), sl(pv), sl(cu), sl(pu), sl(g)
        call = givens_mesh.deepgrid_bwd_pallas_call(
            deep.n, 1, to_local, deep.ti, deep.n_columns, block_b, nb,
            detect_last, interpret)
        dcv, dcu, dg, dxer, dxei, dxor, dxoi = call(
            givens_mesh.inverse_coefficients(cv),
            givens_mesh.adjoint_coefficients(cv), pv,
            givens_mesh.inverse_coefficients(cu),
            givens_mesh.adjoint_coefficients(cu), pu,
            g, xer, xei, xor, xoi, *rest)
        # the kernel already summed its local rows' input-cotangent
        # partials; the psum over the row axis completes the transpose of
        # the (now distributed) row combine
        dx = tuple(jax.lax.psum(d, row_axis)
                   for d in (dxer, dxei, dxor, dxoi))
        # coefficient grads: psum over the batch axis (the usual DP
        # gradient reduction of per-shard partials), then an all-gather
        # over the row axis (axis 1 = To of the [L, To, Ti, ...] stacks)
        # hands every device the full replicated grad — matching the
        # replicated primal operands, so the packing transpose outside
        # never consumes a row-partitioned value
        dcv, dcu, dg = (
            jax.lax.all_gather(jax.lax.psum(d, data_axis), row_axis,
                               axis=1, tiled=True)
            for d in (dcv, dcu, dg))
        return (dcv, dcu, dg) + dx

    n_cot = 2 if detect_last else 4
    fn = _shard_map(
        body, mesh,
        (specs.coef,) * 5 + (specs.x_plane,) * 4 + (specs.stage,) * 8
        + (specs.o_plane,) * n_cot,
        (specs.coef,) * 3 + (specs.dx_plane,) * 4)
    return tuple(fn(coef_v, pv, coef_u, pu, gains,
                    *xplanes, *stages, *cot))


_deepgrid_planes_sharded.defvjp(_deepgrid_planes_sharded_fwd,
                                _deepgrid_planes_sharded_bwd)


@functools.partial(jax.jit, static_argnums=(0, 1, 2, 3, 4, 5, 6, 7))
def _deep_apply_sharded_impl(deep, mesh, row_axis, data_axis, block_b,
                             interpret, detect_last, trace_key,
                             coef_v, coef_u, gains, x):
    TRACE_COUNTS[trace_key] += 1  # python side effect: trace time only
    n, to, ti = deep.n, deep.to, deep.ti
    batch_shape = x.shape[:-1]
    xt = x.reshape((-1, ti, n)).astype(jnp.complex64)
    n_data = mesh.shape[data_axis]
    bb = _vmem_auto_block(max(1, -(-xt.shape[0] // n_data)), block_b, n,
                          _deep_planes_per_row(deep))
    # every device's batch shard must tile into whole blocks
    xt, b_orig = _pad_batch(xt, bb * n_data)
    nb = xt.shape[0] // n_data // bb
    planes = _split_tile_planes(xt)
    outs = None
    for l in range(deep.n_layers):
        last = l == deep.n_layers - 1
        outs = _deepgrid_planes_sharded(
            deep, l, mesh, row_axis, data_axis, bb, nb, interpret,
            detect_last if last else True,
            coef_v[l:l + 1], coef_u[l:l + 1], gains[l:l + 1], *planes)
        if not last:
            # layer boundary: the re-detected To rows are the next
            # layer's Ti real inputs (To == Ti whenever L > 1); the
            # boundary |detect| ran inside the kernel, so its backward is
            # the zero-guarded z/|z| — exact zeros on padded batch rows
            oe, oo = outs
            zero = jnp.zeros_like(oe)
            planes = (oe, zero, oo, zero)
    return _merge_deep_out(outs, detect_last, to, n, b_orig, batch_shape)


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def deep_apply(layers, x: Array, *, n: int, plans=None,
               hardware: hw_lib.HardwareModel | None = None,
               block_b: int | None = None,
               interpret: bool | None = None, packed=None,
               readout: str = "magnitude",
               mesh=None, row_axis: str = "rows",
               data_axis: str = "data",
               _trace_key: str = "deep_apply") -> Array:
    """A whole deep tiled network — L layers of a (To x Ti) analog tile
    grid — in ONE ``pallas_call`` per direction.

    ``layers``/``plans``/``hardware``: see :func:`pack_deep_grid`.  ``x``
    is ``[..., Ti*n]``; each layer's rows combine their Ti tile outputs
    coherently in VMEM (the matched-line power combiner) and the
    re-detected row magnitudes feed the next layer *inside the kernel* —
    inter-layer activations never touch HBM.  ``readout`` picks the last
    layer's output: ``"magnitude"`` (default) applies the |detect| in
    kernel and returns the real ``[..., To*n]`` magnitudes;
    ``"complex"`` returns the combined complex row states so digital
    readout modes (real part, detector noise) compose on top, outside
    the kernel.  The custom VJP unwinds all layers in reverse inside one
    backward kernel from the saved per-tile stage boundaries, with the
    zero-guarded |detect| backward at every layer boundary.

    ``packed``: an explicit :func:`pack_deep_grid` result — offline
    compilation (``repro.compile.lower_deep``) hands it back here and
    skips the pack/cache lookup entirely, so its zero-packing guarantee
    cannot be evicted out from under it by other users of the shared
    cache.  ``block_b=None`` sizes the batch block to the kernel's VMEM
    target (large blocks for small grids, shrinking with n, L, To, Ti).

    ``mesh``: an optional 2-axis ``jax.sharding.Mesh`` — each layer's
    grid then shards over ``(row_axis, data_axis)`` via shard_map: tile
    rows split over ``row_axis`` (To no longer has to fit one device),
    batch over ``data_axis``, each device running the identical
    row-local pallas call; depth runs as a chain of single-layer sharded
    launches (a layer's outputs are the next layer's full input, so
    depth cannot fuse across devices).  Semantics (fwd and VJP) match
    the single-device call to float tolerance; requires
    ``To % mesh.shape[row_axis] == 0``.
    """
    if interpret is None:
        interpret = _default_interpret()
    if readout not in ("magnitude", "complex"):
        raise ValueError(f"readout must be 'magnitude' or 'complex', "
                         f"got {readout!r}")
    KERNEL_PATH_CALLS["deep_apply"] += 1
    if packed is None:
        packed = pack_deep_grid(layers, n=n, plans=plans, hardware=hardware)
    deep, tensors = packed
    detect_last = readout == "magnitude"
    if x.shape[-1] != deep.ti * deep.n:
        raise ValueError(
            f"expected trailing dim {deep.ti * deep.n} "
            f"(Ti={deep.ti} tiles of n={deep.n}), got {x.shape}")
    if mesh is None:
        return _deep_apply_impl(deep, block_b, interpret, detect_last,
                                _trace_key, *tensors, x)
    KERNEL_PATH_CALLS["deep_apply_sharded"] += 1
    for ax in (row_axis, data_axis):
        if ax not in mesh.shape:
            raise ValueError(f"mesh has no axis {ax!r}: {dict(mesh.shape)}")
    if deep.to % mesh.shape[row_axis]:
        raise ValueError(
            f"To={deep.to} tile rows do not shard over "
            f"{mesh.shape[row_axis]} devices on axis {row_axis!r}")
    return _deep_apply_sharded_impl(deep, mesh, row_axis, data_axis,
                                    block_b, interpret, detect_last,
                                    _trace_key + "_sharded", *tensors, x)


# ---------------------------------------------------------------------------
# Degenerate-case wrappers: the network (To=Ti=1) and one-layer tile grid
# ---------------------------------------------------------------------------

def pack_network(layers, *, n: int, plans=None,
                 hardware: hw_lib.HardwareModel | None = None):
    """Emit the megakernel inputs for an L-layer RFNN program.

    The To=Ti=1 degenerate case of :func:`pack_deep_grid`: ``layers`` is
    a flat per-layer sequence of dicts (keys ``v``/``u``, ``atten``,
    optional ``scale``/``key_v``/``key_u``) and ``plans`` a flat
    per-layer sequence of ``(v_plan, u_plan)`` pairs.  Returns
    ``(deep, (coef_v, coef_u, gains))`` in the deep-grid layout
    (``[L, 1, 1, C, 8, P]`` coefficients), ready for
    :func:`rfnn_network`'s ``packed=``.  Pack-cache semantics are
    :func:`pack_deep_grid`'s, ticking ``PACK_EVENTS["rfnn_network"]``.
    """
    deep_layers = tuple(((la,),) for la in layers)
    deep_plans = (None if plans is None
                  else tuple(((p,),) for p in plans))
    return pack_deep_grid(deep_layers, n=n, plans=deep_plans,
                          hardware=hardware, _event="rfnn_network")


def rfnn_network(layers, x: Array, *, n: int,
                 plans=None,
                 hardware: hw_lib.HardwareModel | None = None,
                 block_b: int | None = None,
                 interpret: bool | None = None,
                 packed=None) -> Array:
    """The fused L-layer RFNN |.. |scale_l * U_l(D_l(V_l ..))| .. | sweep.

    A thin To=Ti=1 wrapper over :func:`deep_apply` with the in-kernel
    |detect| readout — numerics, argument shapes (see
    :func:`pack_network`) and pack-cache behavior are unchanged from the
    dedicated network megakernel this path replaced: one ``pallas_call``
    forward and one backward for the whole network, inter-layer
    activations never leaving VMEM, packing cached per (schedule, param
    identity) so serving steady state does zero packing work.
    """
    KERNEL_PATH_CALLS["rfnn_network"] += 1
    if packed is None:
        packed = pack_network(layers, n=n, plans=plans, hardware=hardware)
    return deep_apply(None, x, n=n, block_b=block_b, interpret=interpret,
                      packed=packed, readout="magnitude",
                      _trace_key="rfnn_network")


def pack_tile_grid(tiles, *, n: int, plans=None,
                   hardware: hw_lib.HardwareModel | None = None):
    """Emit the kernel inputs for a one-layer (To x Ti) grid of tiles.

    The L=1 degenerate case of :func:`pack_deep_grid`: ``tiles`` is a
    nested ``[To][Ti]`` sequence of per-tile dicts and ``plans`` a
    matching nesting of ``(v_plan, u_plan)`` pairs.  Returns
    ``(deep, (coef_v, coef_u, gains))`` in the deep-grid layout
    (``[1, To, Ti, C, 8, P]`` coefficients), ready for
    :func:`tiled_apply`'s ``packed=``.  Pack-cache semantics are
    :func:`pack_deep_grid`'s, ticking ``PACK_EVENTS["tiled_apply"]``.
    """
    deep_layers = (tuple(tuple(row) for row in tiles),)
    deep_plans = (None if plans is None
                  else (tuple(tuple(row) for row in plans),))
    return pack_deep_grid(deep_layers, n=n, plans=deep_plans,
                          hardware=hardware, _event="tiled_apply")


def tiled_apply(tiles, x: Array, *, n: int, plans=None,
                hardware: hw_lib.HardwareModel | None = None,
                block_b: int | None = None,
                interpret: bool | None = None, packed=None,
                mesh=None, row_axis: str = "rows",
                data_axis: str = "data") -> Array:
    """A (To x Ti) tile-grid matmul ``sum_i gamma U(D(V x_i))`` per row,
    in ONE ``pallas_call`` per direction.

    A thin L=1 wrapper over :func:`deep_apply` with the ``"complex"``
    readout — numerics, argument shapes (see :func:`pack_tile_grid`),
    pack-cache behavior and the ``mesh=`` sharded path are unchanged
    from the dedicated tile-grid megakernel this path replaced.  ``x``
    is ``[..., Ti*n]`` and the result is the **complex** combined row
    output ``[..., To*n]`` — the matched-line power combiner sums the Ti
    tile outputs of each row coherently in VMEM, and the readout mode
    (|.| detection, real part) plus detector noise compose on top,
    outside the kernel.
    """
    KERNEL_PATH_CALLS["tiled_apply"] += 1
    if packed is None:
        packed = pack_tile_grid(tiles, n=n, plans=plans, hardware=hardware)
    if mesh is not None:
        KERNEL_PATH_CALLS["tiled_apply_sharded"] += 1
    return deep_apply(None, x, n=n, block_b=block_b, interpret=interpret,
                      packed=packed, readout="complex", mesh=mesh,
                      row_axis=row_axis, data_axis=data_axis,
                      _trace_key="tiled_apply")


