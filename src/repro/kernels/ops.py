"""Jitted, differentiable public wrappers around the Pallas mesh kernels.

``interpret`` defaults to True off-TPU so the same call sites run in this
CPU container (kernel body executed op-by-op) and compile to Mosaic on TPU.

Both ``mesh_apply`` and ``rfnn_linear`` carry custom VJPs: the backward
pass is itself a fused Pallas kernel that re-runs the mesh columns in
reverse with conjugate-transposed coefficients (unitarity trick — see
DESIGN.md), so training keeps the same VMEM-resident hot loop as
inference.  Everything outside the pallas_call boundary (coefficient
packing from theta/phi, channel split/merge, phase screens, gains) is
ordinary JAX and differentiates natively, which is how gradients reach
the mesh phases, attenuations and the digital scale.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import givens_mesh, ref

Array = jax.Array


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _auto_block(b: int, block_b: int) -> int:
    """Shrink the batch block for small batches (never grow past block_b)."""
    return max(1, min(block_b, -(-b // 8) * 8))


def _pad_batch(x2d: Array, block: int) -> tuple[Array, int]:
    b = x2d.shape[0]
    pad = (-b) % block
    if pad:
        x2d = jnp.concatenate(
            [x2d, jnp.zeros((pad,) + x2d.shape[1:], x2d.dtype)], axis=0)
    return x2d, b


# ---------------------------------------------------------------------------
# custom-VJP boundary: de-interleaved planes in, planes out
# ---------------------------------------------------------------------------

@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _mesh_planes(n, block_b, nb, interpret, coef, xer, xei, xor, xoi):
    call = givens_mesh.mesh_pallas_call(n, block_b, nb, interpret)
    return tuple(call(coef, xer, xei, xor, xoi))


def _mesh_planes_fwd(n, block_b, nb, interpret, coef, xer, xei, xor, xoi):
    outs = _mesh_planes(n, block_b, nb, interpret, coef, xer, xei, xor, xoi)
    # unitarity: the output planes are the only state residual needed
    return outs, (coef, outs)


def _mesh_planes_bwd(n, block_b, nb, interpret, res, cot):
    coef, outs = res
    coef_adj = givens_mesh.adjoint_coefficients(coef)
    call = givens_mesh.mesh_bwd_pallas_call(n, block_b, nb, interpret)
    dcoef, dxer, dxei, dxor, dxoi = call(coef_adj, *outs, *cot)
    return dcoef, dxer, dxei, dxor, dxoi


_mesh_planes.defvjp(_mesh_planes_fwd, _mesh_planes_bwd)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0, 1, 2, 3))
def _rfnn_planes(n, block_b, nb, interpret, coef_v, coef_u, gains,
                 xer, xei, xor, xoi):
    call = givens_mesh.rfnn_linear_pallas_call(n, block_b, nb, interpret)
    return tuple(call(coef_v, coef_u, gains, xer, xei, xor, xoi))


def _rfnn_planes_fwd(n, block_b, nb, interpret, coef_v, coef_u, gains,
                     xer, xei, xor, xoi):
    call = givens_mesh.rfnn_linear_fwd_pallas_call(n, block_b, nb, interpret)
    oe, oo, *stage = call(coef_v, coef_u, gains, xer, xei, xor, xoi)
    return (oe, oo), (coef_v, coef_u, gains, tuple(stage))


def _rfnn_planes_bwd(n, block_b, nb, interpret, res, cot):
    coef_v, coef_u, gains, stage = res
    cva = givens_mesh.adjoint_coefficients(coef_v)
    cua = givens_mesh.adjoint_coefficients(coef_u)
    call = givens_mesh.rfnn_linear_bwd_pallas_call(n, block_b, nb, interpret)
    dcv, dcu, dgains, dxer, dxei, dxor, dxoi = call(
        cva, cua, gains, *stage, *cot)
    return dcv, dcu, dgains, dxer, dxei, dxor, dxoi


_rfnn_planes.defvjp(_rfnn_planes_fwd, _rfnn_planes_bwd)


# ---------------------------------------------------------------------------
# Public wrappers
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("n", "block_b", "interpret"))
def mesh_apply(params: dict, x: Array, *, n: int, block_b: int = 128,
               interpret: bool | None = None) -> Array:
    """Apply a Clements-layout mesh to ``x[..., n]`` via the Pallas kernel.

    Semantics match ``repro.core.mesh.apply_mesh`` on a clements plan
    (including the optional phase screens ``alpha_in`` / ``alpha``).
    Differentiable w.r.t. ``params`` and ``x`` through the kernel VJP.
    """
    if interpret is None:
        interpret = _default_interpret()
    batch_shape = x.shape[:-1]
    x2 = x.reshape((-1, n)).astype(jnp.complex64)
    alpha_in = params.get("alpha_in")
    if alpha_in is not None:
        x2 = x2 * jnp.exp(-1j * alpha_in.astype(jnp.complex64))
    bb = _auto_block(x2.shape[0], block_b)
    x2, b_orig = _pad_batch(x2, bb)
    nb = x2.shape[0] // bb

    coef = ref.clements_coefficients(params["theta"], params["phi"], n)
    planes = ref.split_channels(x2)
    planes = _mesh_planes(n, bb, nb, interpret, coef, *planes)
    y = ref.merge_channels(*planes)[:b_orig]
    alpha = params.get("alpha")
    if alpha is not None:
        y = y * jnp.exp(-1j * alpha.astype(jnp.complex64))
    return y.reshape(batch_shape + (n,))


@functools.partial(jax.jit, static_argnames=("n", "block_b", "interpret"))
def rfnn_linear(v_params: dict, atten: Array, u_params: dict, x: Array, *,
                n: int, scale: Array | float = 1.0, block_b: int = 128,
                interpret: bool | None = None) -> Array:
    """Fused analog linear layer |scale * U(D(V x))| via the Pallas kernel.

    ``atten``: [n] real attenuation (paper's diagonal D / sigma_max);
    ``scale``: the digital gamma.  Output is the detected magnitude [.., n].
    Differentiable w.r.t. both mesh params, ``atten``, ``scale`` and ``x``
    through the fused kernel VJP.
    """
    if interpret is None:
        interpret = _default_interpret()
    batch_shape = x.shape[:-1]
    x2 = x.reshape((-1, n)).astype(jnp.complex64)
    if v_params.get("alpha_in") is not None:
        x2 = x2 * jnp.exp(-1j * v_params["alpha_in"].astype(jnp.complex64))
    bb = _auto_block(x2.shape[0], block_b)
    x2, b_orig = _pad_batch(x2, bb)
    nb = x2.shape[0] // bb

    coef_v = ref.clements_coefficients(v_params["theta"], v_params["phi"], n)
    coef_u = ref.clements_coefficients(u_params["theta"], u_params["phi"], n)

    # fold V's output screen (and U's input screen) into the mid-gain and
    # U's output screen into the post-gain — all diagonal, so they commute
    g1 = atten.astype(jnp.complex64)
    if v_params.get("alpha") is not None:
        g1 = g1 * jnp.exp(-1j * v_params["alpha"].astype(jnp.complex64))
    if u_params.get("alpha_in") is not None:
        g1 = g1 * jnp.exp(-1j * u_params["alpha_in"].astype(jnp.complex64))
    g2 = jnp.full((n,), jnp.asarray(scale, jnp.complex64))
    if u_params.get("alpha") is not None:
        g2 = g2 * jnp.exp(-1j * u_params["alpha"].astype(jnp.complex64))
    gains = jnp.stack([
        jnp.real(g1[0::2]), jnp.imag(g1[0::2]),
        jnp.real(g1[1::2]), jnp.imag(g1[1::2]),
        jnp.real(g2[0::2]), jnp.imag(g2[0::2]),
        jnp.real(g2[1::2]), jnp.imag(g2[1::2]),
    ]).astype(jnp.float32)

    planes = ref.split_channels(x2)
    oe, oo = _rfnn_planes(n, bb, nb, interpret, coef_v, coef_u, gains,
                          *planes)
    out = jnp.stack([oe, oo], axis=-1).reshape((-1, n))[:b_orig]
    return out.reshape(batch_shape + (n,))
