"""Jitted public wrappers around the Pallas mesh kernels.

``interpret`` defaults to True off-TPU so the same call sites run in this
CPU container (kernel body executed op-by-op) and compile to Mosaic on TPU.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import givens_mesh, ref

Array = jax.Array


def _default_interpret() -> bool:
    return jax.default_backend() != "tpu"


def _pad_batch(x2d: Array, block: int) -> tuple[Array, int]:
    b = x2d.shape[0]
    pad = (-b) % block
    if pad:
        x2d = jnp.concatenate(
            [x2d, jnp.zeros((pad,) + x2d.shape[1:], x2d.dtype)], axis=0)
    return x2d, b


@functools.partial(jax.jit, static_argnames=("n", "block_b", "interpret"))
def mesh_apply(params: dict, x: Array, *, n: int, block_b: int = 128,
               interpret: bool | None = None) -> Array:
    """Apply a Clements-layout mesh to ``x[..., n]`` via the Pallas kernel.

    Semantics match ``repro.core.mesh.apply_mesh`` on a clements plan
    (including the optional output phase screen ``alpha``).
    """
    if interpret is None:
        interpret = _default_interpret()
    batch_shape = x.shape[:-1]
    x2 = x.reshape((-1, n)).astype(jnp.complex64)
    x2, b_orig = _pad_batch(x2, block_b)
    nb = x2.shape[0] // block_b

    coef = ref.clements_coefficients(params["theta"], params["phi"], n)
    planes = ref.split_channels(x2)
    call = givens_mesh.mesh_pallas_call(n, block_b, nb, interpret)
    planes = call(coef, *planes)
    y = ref.merge_channels(*planes)[:b_orig]
    alpha = params.get("alpha")
    if alpha is not None:
        y = y * jnp.exp(-1j * alpha.astype(jnp.complex64))
    return y.reshape(batch_shape + (n,))


@functools.partial(jax.jit, static_argnames=("n", "block_b", "interpret"))
def rfnn_linear(v_params: dict, atten: Array, u_params: dict, x: Array, *,
                n: int, scale: Array | float = 1.0, block_b: int = 128,
                interpret: bool | None = None) -> Array:
    """Fused analog linear layer |scale * U(D(V x))| via the Pallas kernel.

    ``atten``: [n] real attenuation (paper's diagonal D / sigma_max);
    ``scale``: the digital gamma.  Output is the detected magnitude [.., n].
    """
    if interpret is None:
        interpret = _default_interpret()
    batch_shape = x.shape[:-1]
    x2 = x.reshape((-1, n)).astype(jnp.complex64)
    x2, b_orig = _pad_batch(x2, block_b)
    nb = x2.shape[0] // block_b

    coef_v = ref.clements_coefficients(v_params["theta"], v_params["phi"], n)
    coef_u = ref.clements_coefficients(u_params["theta"], u_params["phi"], n)

    # fold V's output screen into the mid-gain and U's into the post-gain
    g1 = atten.astype(jnp.complex64)
    if v_params.get("alpha") is not None:
        g1 = g1 * jnp.exp(-1j * v_params["alpha"].astype(jnp.complex64))
    g2 = jnp.full((n,), jnp.asarray(scale, jnp.complex64))
    if u_params.get("alpha") is not None:
        g2 = g2 * jnp.exp(-1j * u_params["alpha"].astype(jnp.complex64))
    gains = jnp.stack([
        jnp.real(g1[0::2]), jnp.imag(g1[0::2]),
        jnp.real(g1[1::2]), jnp.imag(g1[1::2]),
        jnp.real(g2[0::2]), jnp.imag(g2[0::2]),
        jnp.real(g2[1::2]), jnp.imag(g2[1::2]),
    ]).astype(jnp.float32)

    planes = ref.split_channels(x2)
    call = givens_mesh.rfnn_linear_pallas_call(n, block_b, nb, interpret)
    oe, oo = call(coef_v, coef_u, gains, *planes)
    out = jnp.stack([oe, oo], axis=-1).reshape((-1, n))[:b_orig]
    return out.reshape(batch_shape + (n,))
