"""Pallas TPU kernels for the RF mesh apply — the paper's MVM hot spot.

TPU adaptation of the analog propagation: one mesh column is a set of
independent 2x2 complex rotations on channel pairs — pure VPU elementwise
work once channels are de-interleaved into even/odd (re, im) planes of shape
[batch, N/2].  The kernels keep a batch panel **resident in VMEM** and run
all N columns in-register/VMEM, the TPU analogue of the RF signal passing
through all S = N(N-1)/2 cells without intermediate storage (HBM traffic is
2 reads + 2 writes of the panel total, instead of per-column round trips).

Layout choices (see DESIGN.md):
  * planes [B, P] with P = N/2 on the lane dimension (128-aligned for N>=256);
  * coefficients [C, 8, P]: 8 rows = (t00, t01, t10, t11) x (re, im) per pair
    slot, broadcast over the batch sublanes;
  * odd columns act on (odd_i, even_{i+1}) via shifted slices — static
    slicing only, no gathers.

Kernels:
  * ``mesh_kernel`` — one mesh (the unitary T(N) of paper Eq. 28).
  * ``rfnn_linear_kernel`` — fused analog linear layer
    V-mesh -> diag gain -> U-mesh -> |detect| (paper Eq. 31 + Fig. 14),
    one VMEM residency for the whole layer.

Validated against ``ref.py`` in interpret mode (this container is CPU-only;
TPU is the compilation target).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cmul(ar, ai, br, bi):
    return ar * br - ai * bi, ar * bi + ai * br


def _rotate(cc, ar, ai, br, bi):
    """Apply the 2x2 complex rotations in an 8-row coefficient slice."""
    xr, xi = _cmul(cc[0], cc[1], ar, ai)
    yr, yi = _cmul(cc[2], cc[3], br, bi)
    a2r, a2i = xr + yr, xi + yi
    xr, xi = _cmul(cc[4], cc[5], ar, ai)
    yr, yi = _cmul(cc[6], cc[7], br, bi)
    return a2r, a2i, xr + yr, xi + yi


def _column_body(coef_ref, c, state):
    """One mesh column on the de-interleaved planes."""
    er, ei, orr, oi = state
    cc = coef_ref[c]  # [8, P] dynamic-sliced from VMEM

    def even(_):
        a2r, a2i, b2r, b2i = _rotate(cc, er, ei, orr, oi)
        return a2r, a2i, b2r, b2i

    def odd(_):
        ar, ai = orr[:, :-1], oi[:, :-1]
        br, bi = er[:, 1:], ei[:, 1:]
        a2r, a2i, b2r, b2i = _rotate(cc[:, :-1], ar, ai, br, bi)
        ner = jnp.concatenate([er[:, :1], b2r], axis=1)
        nei = jnp.concatenate([ei[:, :1], b2i], axis=1)
        nor = jnp.concatenate([a2r, orr[:, -1:]], axis=1)
        noi = jnp.concatenate([a2i, oi[:, -1:]], axis=1)
        return ner, nei, nor, noi

    return jax.lax.cond(c % 2 == 0, even, odd, None)


def _run_columns(coef_ref, state):
    n_cols = coef_ref.shape[0]
    return jax.lax.fori_loop(
        0, n_cols, functools.partial(_column_body, coef_ref), state)


# ---------------------------------------------------------------------------
# Kernel 1: single mesh
# ---------------------------------------------------------------------------

def mesh_kernel(coef_ref, xer_ref, xei_ref, xor_ref, xoi_ref,
                oer_ref, oei_ref, oor_ref, ooi_ref):
    state = (xer_ref[...], xei_ref[...], xor_ref[...], xoi_ref[...])
    er, ei, orr, oi = _run_columns(coef_ref, state)
    oer_ref[...] = er
    oei_ref[...] = ei
    oor_ref[...] = orr
    ooi_ref[...] = oi


def mesh_pallas_call(n: int, batch_block: int, n_batch_blocks: int,
                     interpret: bool):
    p = n // 2
    plane = pl.BlockSpec((batch_block, p), lambda i: (i, 0))
    coef = pl.BlockSpec((n, 8, p), lambda i: (0, 0, 0))
    out_shape = [jax.ShapeDtypeStruct((n_batch_blocks * batch_block, p),
                                      jnp.float32)] * 4
    flops_per_block = 2 * (n * (n - 1) // 2) * batch_block * 16
    return pl.pallas_call(
        mesh_kernel,
        grid=(n_batch_blocks,),
        in_specs=[coef, plane, plane, plane, plane],
        out_specs=[plane] * 4,
        out_shape=out_shape,
        interpret=interpret,
        cost_estimate=pl.CostEstimate(
            flops=flops_per_block * n_batch_blocks,
            bytes_accessed=(8 * batch_block * p * 4 + n * 8 * p * 4)
            * n_batch_blocks,
            transcendentals=0,
        ),
    )


# ---------------------------------------------------------------------------
# Kernel 2: fused analog linear  (V-mesh -> diag -> U-mesh -> |detect|)
# ---------------------------------------------------------------------------

def rfnn_linear_kernel(coef_v_ref, coef_u_ref, gains_ref,
                       xer_ref, xei_ref, xor_ref, xoi_ref,
                       oe_ref, oo_ref):
    state = (xer_ref[...], xei_ref[...], xor_ref[...], xoi_ref[...])
    er, ei, orr, oi = _run_columns(coef_v_ref, state)
    g = gains_ref[...]  # [8, P]: g1 (even re/im, odd re/im), g2 (...)
    er, ei = _cmul(er, ei, g[0], g[1])
    orr, oi = _cmul(orr, oi, g[2], g[3])
    er, ei, orr, oi = _run_columns(coef_u_ref, (er, ei, orr, oi))
    er, ei = _cmul(er, ei, g[4], g[5])
    orr, oi = _cmul(orr, oi, g[6], g[7])
    oe_ref[...] = jnp.sqrt(er * er + ei * ei)   # |detect| on even channels
    oo_ref[...] = jnp.sqrt(orr * orr + oi * oi)


def rfnn_linear_pallas_call(n: int, batch_block: int, n_batch_blocks: int,
                            interpret: bool):
    p = n // 2
    plane = pl.BlockSpec((batch_block, p), lambda i: (i, 0))
    coef = pl.BlockSpec((n, 8, p), lambda i: (0, 0, 0))
    gains = pl.BlockSpec((8, p), lambda i: (0, 0))
    out_shape = [jax.ShapeDtypeStruct((n_batch_blocks * batch_block, p),
                                      jnp.float32)] * 2
    flops_per_block = 2 * (2 * (n * (n - 1) // 2) * 16 + 3 * n) * batch_block
    return pl.pallas_call(
        rfnn_linear_kernel,
        grid=(n_batch_blocks,),
        in_specs=[coef, coef, gains, plane, plane, plane, plane],
        out_specs=[plane] * 2,
        out_shape=out_shape,
        interpret=interpret,
        cost_estimate=pl.CostEstimate(
            flops=flops_per_block * n_batch_blocks,
            bytes_accessed=(6 * batch_block * p * 4 + 2 * n * 8 * p * 4
                            + 8 * p * 4) * n_batch_blocks,
            transcendentals=batch_block * p * 2 * n_batch_blocks,
        ),
    )
