"""Pallas TPU kernels for the RF mesh apply — the paper's MVM hot spot.

TPU adaptation of the analog propagation: one mesh column is a set of
independent 2x2 complex rotations on channel pairs — pure VPU elementwise
work once channels are de-interleaved into even/odd (re, im) planes of shape
[batch, N/2].  The kernels keep a batch panel **resident in VMEM** and run
all C columns in-register/VMEM, the TPU analogue of the RF signal passing
through all S = N(N-1)/2 cells without intermediate storage (HBM traffic is
2 reads + 2 writes of the panel total, instead of per-column round trips).

Layout choices (see DESIGN.md):
  * planes [B, P] with P = N/2 on the lane dimension (128-aligned for N>=256);
  * coefficients [C, 8, P]: 8 rows = (t00, t01, t10, t11) x (re, im) per pair
    slot, broadcast over the batch sublanes.  The 2x2 cells are **arbitrary
    complex matrices** — ideal unitary rotations and the hardware model's
    lossy/imbalanced cells share the same layout and the same sweep;
  * a [C, 1] int32 parity input selects each column's pairing: parity 0
    rotates (even_i, odd_i), parity 1 rotates (odd_i, even_{i+1}) via
    shifted slices — static slicing only, no gathers.  Any adjacent-pair
    layout (Clements rectangle, triangular Reck programs, greedy-packed
    schedules) lowers to a parity sequence (see ``repro.kernels.schedule``).

Kernels:
  * ``mesh_kernel`` — one mesh (the paper's T(N), Eq. 28, ideal or not).
  * ``rfnn_linear_kernel`` — fused analog linear layer
    V-mesh -> diag gain -> U-mesh -> |detect| (paper Eq. 31 + Fig. 14),
    one VMEM residency for the whole layer.
  * ``deepgrid_kernel`` — the general deep tiled network: L layers, each
    a (To x Ti) grid of analog tile processors, in ONE VMEM residency.
    Every layer sweeps all input tiles through their meshes, coherently
    combines each tile row's outputs (matched-line power combiner) and
    re-detects the combined rows in VMEM to feed the next layer — zero
    inter-layer HBM traffic, the TPU analogue of the paper's end-to-end
    analog signal path (Sec. V, incl. the 4-layer MNIST scale-up).  The
    L-layer single-mesh RFNN (L x 1 x 1) and the one-layer tile grid
    (1 x To x Ti) are its degenerate cases — there are no separate
    network/tile-grid kernels.
  * ``mesh_bwd_kernel`` / ``rfnn_linear_bwd_kernel`` — the custom VJPs.
    The backward pass re-runs the column sequence *in reverse*, carrying
    two coefficient tensors: the per-cell analytic **2x2 inverse** rebuilds
    each column's input state from the saved forward output (for unitary
    cells this degenerates to the PR-1 conjugate-transpose trick), while
    the **adjoint** (conjugate transpose) propagates the cotangent — the
    transpose of the real-representation Jacobian of ``y = T x`` is ``T^H``
    for *any* complex ``T``, unitary or not.  Per-column coefficient
    gradients are accumulated into a [C, 8, P] output revisited across
    batch-grid steps.  See DESIGN.md ("Backward pass").

Validated against ``ref.py`` and the hardware-model reference in interpret
mode (this container is CPU-only; TPU is the compilation target).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cmul(ar, ai, br, bi):
    return ar * br - ai * bi, ar * bi + ai * br


def _rotate(cc, ar, ai, br, bi):
    """Apply the 2x2 complex rotations in an 8-row coefficient slice."""
    xr, xi = _cmul(cc[0], cc[1], ar, ai)
    yr, yi = _cmul(cc[2], cc[3], br, bi)
    a2r, a2i = xr + yr, xi + yi
    xr, xi = _cmul(cc[4], cc[5], ar, ai)
    yr, yi = _cmul(cc[6], cc[7], br, bi)
    return a2r, a2i, xr + yr, xi + yi


def _column_body(coef_ref, parity_ref, c, state):
    """One mesh column on the de-interleaved planes."""
    er, ei, orr, oi = state
    cc = coef_ref[c]  # [8, P] dynamic-sliced from VMEM

    def even(_):
        a2r, a2i, b2r, b2i = _rotate(cc, er, ei, orr, oi)
        return a2r, a2i, b2r, b2i

    def odd(_):
        ar, ai = orr[:, :-1], oi[:, :-1]
        br, bi = er[:, 1:], ei[:, 1:]
        a2r, a2i, b2r, b2i = _rotate(cc[:, :-1], ar, ai, br, bi)
        ner = jnp.concatenate([er[:, :1], b2r], axis=1)
        nei = jnp.concatenate([ei[:, :1], b2i], axis=1)
        nor = jnp.concatenate([a2r, orr[:, -1:]], axis=1)
        noi = jnp.concatenate([a2i, oi[:, -1:]], axis=1)
        return ner, nei, nor, noi

    return jax.lax.cond(parity_ref[c, 0] == 0, even, odd, None)


def _run_columns(coef_ref, parity_ref, state):
    n_cols = coef_ref.shape[0]
    return jax.lax.fori_loop(
        0, n_cols,
        functools.partial(_column_body, coef_ref, parity_ref), state)


# ---------------------------------------------------------------------------
# Kernel 1: single mesh
# ---------------------------------------------------------------------------

def mesh_kernel(coef_ref, parity_ref, xer_ref, xei_ref, xor_ref, xoi_ref,
                oer_ref, oei_ref, oor_ref, ooi_ref):
    state = (xer_ref[...], xei_ref[...], xor_ref[...], xoi_ref[...])
    er, ei, orr, oi = _run_columns(coef_ref, parity_ref, state)
    oer_ref[...] = er
    oei_ref[...] = ei
    oor_ref[...] = orr
    ooi_ref[...] = oi


def _coef_spec(n_cols: int, p: int):
    return pl.BlockSpec((n_cols, 8, p), lambda i: (0, 0, 0))


def _parity_spec(n_cols: int):
    return pl.BlockSpec((n_cols, 1), lambda i: (0, 0))


def mesh_pallas_call(n: int, n_cols: int, batch_block: int,
                     n_batch_blocks: int, interpret: bool):
    p = n // 2
    plane = pl.BlockSpec((batch_block, p), lambda i: (i, 0))
    out_shape = [jax.ShapeDtypeStruct((n_batch_blocks * batch_block, p),
                                      jnp.float32)] * 4
    flops_per_block = 2 * n_cols * p * batch_block * 16
    return pl.pallas_call(
        mesh_kernel,
        grid=(n_batch_blocks,),
        in_specs=[_coef_spec(n_cols, p), _parity_spec(n_cols),
                  plane, plane, plane, plane],
        out_specs=[plane] * 4,
        out_shape=out_shape,
        interpret=interpret,
        cost_estimate=pl.CostEstimate(
            flops=flops_per_block * n_batch_blocks,
            bytes_accessed=(8 * batch_block * p * 4 + n_cols * 8 * p * 4)
            * n_batch_blocks,
            transcendentals=0,
        ),
    )


# ---------------------------------------------------------------------------
# Kernel 2: fused analog linear  (V-mesh -> diag -> U-mesh -> |detect|)
# ---------------------------------------------------------------------------

def _rfnn_forward(coef_v_ref, par_v_ref, coef_u_ref, par_u_ref, gains_ref,
                  state):
    """The fused layer body: V -> g1 -> U -> g2 -> |detect|.

    Returns detected magnitudes plus the two pre-gain stage boundaries
    (the VJP forward's residuals); the inference kernel discards them.
    """
    v = _run_columns(coef_v_ref, par_v_ref, state)
    g = gains_ref[...]  # [8, P]: g1 (even re/im, odd re/im), g2 (...)
    er, ei = _cmul(v[0], v[1], g[0], g[1])
    orr, oi = _cmul(v[2], v[3], g[2], g[3])
    u = _run_columns(coef_u_ref, par_u_ref, (er, ei, orr, oi))
    zer, zei = _cmul(u[0], u[1], g[4], g[5])
    zor, zoi = _cmul(u[2], u[3], g[6], g[7])
    oe = jnp.sqrt(zer * zer + zei * zei)   # |detect| on even channels
    oo = jnp.sqrt(zor * zor + zoi * zoi)
    return oe, oo, v, u


def rfnn_linear_kernel(coef_v_ref, par_v_ref, coef_u_ref, par_u_ref,
                       gains_ref, xer_ref, xei_ref, xor_ref, xoi_ref,
                       oe_ref, oo_ref):
    state = (xer_ref[...], xei_ref[...], xor_ref[...], xoi_ref[...])
    oe, oo, _, _ = _rfnn_forward(coef_v_ref, par_v_ref, coef_u_ref,
                                 par_u_ref, gains_ref, state)
    oe_ref[...] = oe
    oo_ref[...] = oo


def rfnn_linear_pallas_call(n: int, n_cols_v: int, n_cols_u: int,
                            batch_block: int, n_batch_blocks: int,
                            interpret: bool):
    p = n // 2
    plane = pl.BlockSpec((batch_block, p), lambda i: (i, 0))
    gains = pl.BlockSpec((8, p), lambda i: (0, 0))
    out_shape = [jax.ShapeDtypeStruct((n_batch_blocks * batch_block, p),
                                      jnp.float32)] * 2
    flops_per_block = 2 * ((n_cols_v + n_cols_u) * p * 16 + 3 * n) \
        * batch_block
    return pl.pallas_call(
        rfnn_linear_kernel,
        grid=(n_batch_blocks,),
        in_specs=[_coef_spec(n_cols_v, p), _parity_spec(n_cols_v),
                  _coef_spec(n_cols_u, p), _parity_spec(n_cols_u),
                  gains, plane, plane, plane, plane],
        out_specs=[plane] * 2,
        out_shape=out_shape,
        interpret=interpret,
        cost_estimate=pl.CostEstimate(
            flops=flops_per_block * n_batch_blocks,
            bytes_accessed=(6 * batch_block * p * 4
                            + (n_cols_v + n_cols_u) * 8 * p * 4
                            + 8 * p * 4) * n_batch_blocks,
            transcendentals=batch_block * p * 2 * n_batch_blocks,
        ),
    )


# ---------------------------------------------------------------------------
# Backward pass building blocks (the custom VJPs)
# ---------------------------------------------------------------------------

def adjoint_coefficients(coef: jax.Array) -> jax.Array:
    """Conjugate-transpose each packed 2x2 cell, column layout preserved.

    Rows (t00, t01, t10, t11) x (re, im) -> (t00*, t10*, t01*, t11*).  The
    adjoint propagates the cotangent in the reversed sweep: the transpose
    of the real-representation Jacobian of ``y = T x`` is ``T^H`` for any
    complex ``T``.  For unitary columns it is also the exact inverse, which
    is the PR-1 state-recompute trick as a special case.  Rows live on axis
    -2, so both per-mesh ``[C, 8, P]`` and stacked network ``[L, C, 8, P]``
    layouts transform in place.
    """
    idx = jnp.asarray([0, 1, 4, 5, 2, 3, 6, 7])
    sign = jnp.asarray([1.0, -1.0] * 4, coef.dtype)
    return jnp.take(coef, idx, axis=-2) * sign[:, None]


def inverse_coefficients(coef: jax.Array, eps: float = 1e-12) -> jax.Array:
    """Analytic per-cell 2x2 inverse in the packed coefficient layout.

    ``inv(t) = adj(t) / det(t)`` with ``det = t00 t11 - t01 t10``.  This is
    what lets the backward sweep rebuild intermediate states for
    **non-unitary** cells (hybrid imbalance, per-cell insertion loss) with
    no per-column residuals: ``s_c = T_c^{-1} s_{c+1}``.  Hardware cells
    are well-conditioned (|det| ~ cell_gain^2); ``eps`` guards the
    identity-padded slots' neighbourhood against exact zeros.  Like
    :func:`adjoint_coefficients`, rows live on axis -2 (works on ``[C, 8,
    P]`` and ``[L, C, 8, P]`` alike).
    """
    t00 = coef[..., 0, :] + 1j * coef[..., 1, :]
    t01 = coef[..., 2, :] + 1j * coef[..., 3, :]
    t10 = coef[..., 4, :] + 1j * coef[..., 5, :]
    t11 = coef[..., 6, :] + 1j * coef[..., 7, :]
    det = t00 * t11 - t01 * t10
    inv_det = jnp.conj(det) / jnp.maximum(jnp.abs(det) ** 2, eps)
    i00, i01 = t11 * inv_det, -t01 * inv_det
    i10, i11 = -t10 * inv_det, t00 * inv_det
    out = jnp.stack(
        [jnp.real(i00), jnp.imag(i00), jnp.real(i01), jnp.imag(i01),
         jnp.real(i10), jnp.imag(i10), jnp.real(i11), jnp.imag(i11)],
        axis=-2,
    )
    return out.astype(coef.dtype)


def _conj_dot(xr, xi, gr, gi):
    """Batch-summed conj(x) * g — one complex coefficient gradient entry."""
    return (jnp.sum(xr * gr + xi * gi, axis=0, keepdims=True),
            jnp.sum(xr * gi - xi * gr, axis=0, keepdims=True))


def _pair_grad_rows(ar, ai, br, bi, gar, gai, gbr, gbi):
    """d loss / d t for (a2, b2) = t (a, b): rows (00, 01, 10, 11)(re, im)."""
    r0, r1 = _conj_dot(ar, ai, gar, gai)
    r2, r3 = _conj_dot(br, bi, gar, gai)
    r4, r5 = _conj_dot(ar, ai, gbr, gbi)
    r6, r7 = _conj_dot(br, bi, gbr, gbi)
    return jnp.concatenate([r0, r1, r2, r3, r4, r5, r6, r7], axis=0)  # [8, P]


def _coef_grad(parity_ref, c, s_in, g_out):
    """Coefficient gradient of column ``c`` from its input state and the
    cotangent at its output, in the column's own pairing."""
    er, ei, orr, oi = s_in
    ger, gei, gor, goi = g_out

    def even(_):
        return _pair_grad_rows(er, ei, orr, oi, ger, gei, gor, goi)

    def odd(_):
        rows = _pair_grad_rows(
            orr[:, :-1], oi[:, :-1], er[:, 1:], ei[:, 1:],
            gor[:, :-1], goi[:, :-1], ger[:, 1:], gei[:, 1:])
        # wrap slot of odd columns holds no cell
        return jnp.concatenate([rows, jnp.zeros((8, 1), rows.dtype)], axis=1)

    return jax.lax.cond(parity_ref[c, 0] == 0, even, odd, None)


def _run_columns_bwd(coef_inv_ref, coef_adj_ref, parity_ref, dcoef_ref,
                     state, cot, layer=None):
    """Reversed column sweep: recompute states via the per-cell inverse,
    accumulate coefficient gradients, propagate the cotangent via the
    adjoint.  ``state`` starts at the mesh *output*.  ``layer`` (a static
    int, or a static tuple for grid layouts) selects the leading indices
    of a stacked ``[L, C, 8, P]`` / ``[To, Ti, C, 8, P]`` gradient
    accumulator — the network kernel's per-layer slot and the tile-grid
    kernel's per-tile slot."""
    n_cols = coef_inv_ref.shape[0]
    lead = (() if layer is None
            else layer if isinstance(layer, tuple) else (layer,))

    def body(k, carry):
        c = n_cols - 1 - k
        s, g = carry[0:4], carry[4:8]
        s_in = _column_body(coef_inv_ref, parity_ref, c, s)   # T_c^{-1} s_{c+1}
        grad = _coef_grad(parity_ref, c, s_in, g)
        dcoef_ref[lead + (c,)] = dcoef_ref[lead + (c,)] + grad
        g_in = _column_body(coef_adj_ref, parity_ref, c, g)   # T_c^H g_{c+1}
        return (*s_in, *g_in)

    out = jax.lax.fori_loop(0, n_cols, body, (*state, *cot))
    return out[0:4], out[4:8]


def mesh_bwd_kernel(coef_inv_ref, coef_adj_ref, parity_ref,
                    yer_ref, yei_ref, yor_ref, yoi_ref,
                    ger_ref, gei_ref, gor_ref, goi_ref,
                    dcoef_ref, dxer_ref, dxei_ref, dxor_ref, dxoi_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        dcoef_ref[...] = jnp.zeros(dcoef_ref.shape, dcoef_ref.dtype)

    y = (yer_ref[...], yei_ref[...], yor_ref[...], yoi_ref[...])
    g = (ger_ref[...], gei_ref[...], gor_ref[...], goi_ref[...])
    _, gx = _run_columns_bwd(coef_inv_ref, coef_adj_ref, parity_ref,
                             dcoef_ref, y, g)
    dxer_ref[...] = gx[0]
    dxei_ref[...] = gx[1]
    dxor_ref[...] = gx[2]
    dxoi_ref[...] = gx[3]


def mesh_bwd_pallas_call(n: int, n_cols: int, batch_block: int,
                         n_batch_blocks: int, interpret: bool):
    p = n // 2
    plane = pl.BlockSpec((batch_block, p), lambda i: (i, 0))
    out_shape = (
        [jax.ShapeDtypeStruct((n_cols, 8, p), jnp.float32)]
        + [jax.ShapeDtypeStruct((n_batch_blocks * batch_block, p),
                                jnp.float32)] * 4)
    # state recompute + cotangent propagation + coefficient grads ~ 3x fwd
    flops_per_block = 3 * 2 * n_cols * p * batch_block * 16
    return pl.pallas_call(
        mesh_bwd_kernel,
        grid=(n_batch_blocks,),
        in_specs=[_coef_spec(n_cols, p), _coef_spec(n_cols, p),
                  _parity_spec(n_cols)] + [plane] * 8,
        out_specs=[_coef_spec(n_cols, p)] + [plane] * 4,
        out_shape=out_shape,
        interpret=interpret,
        cost_estimate=pl.CostEstimate(
            flops=flops_per_block * n_batch_blocks,
            bytes_accessed=(12 * batch_block * p * 4 + 3 * n_cols * 8 * p * 4)
            * n_batch_blocks,
            transcendentals=0,
        ),
    )


# ---------------------------------------------------------------------------
# Fused analog linear: forward-with-residuals and backward
# ---------------------------------------------------------------------------

def rfnn_linear_fwd_kernel(coef_v_ref, par_v_ref, coef_u_ref, par_u_ref,
                           gains_ref, xer_ref, xei_ref, xor_ref, xoi_ref,
                           oe_ref, oo_ref,
                           ver_ref, vei_ref, vor_ref, voi_ref,
                           uer_ref, uei_ref, uor_ref, uoi_ref):
    """Forward identical to ``rfnn_linear_kernel`` (same ``_rfnn_forward``
    body) but additionally writes the two stage boundaries (post-V and
    post-U, both pre-gain) — the only residuals the backward pass needs."""
    state = (xer_ref[...], xei_ref[...], xor_ref[...], xoi_ref[...])
    oe, oo, v, u = _rfnn_forward(coef_v_ref, par_v_ref, coef_u_ref,
                                 par_u_ref, gains_ref, state)
    oe_ref[...] = oe
    oo_ref[...] = oo
    ver_ref[...], vei_ref[...], vor_ref[...], voi_ref[...] = v
    uer_ref[...], uei_ref[...], uor_ref[...], uoi_ref[...] = u


def rfnn_linear_fwd_pallas_call(n: int, n_cols_v: int, n_cols_u: int,
                                batch_block: int, n_batch_blocks: int,
                                interpret: bool):
    p = n // 2
    plane = pl.BlockSpec((batch_block, p), lambda i: (i, 0))
    gains = pl.BlockSpec((8, p), lambda i: (0, 0))
    out_shape = [jax.ShapeDtypeStruct((n_batch_blocks * batch_block, p),
                                      jnp.float32)] * 10
    flops_per_block = 2 * ((n_cols_v + n_cols_u) * p * 16 + 3 * n) \
        * batch_block
    return pl.pallas_call(
        rfnn_linear_fwd_kernel,
        grid=(n_batch_blocks,),
        in_specs=[_coef_spec(n_cols_v, p), _parity_spec(n_cols_v),
                  _coef_spec(n_cols_u, p), _parity_spec(n_cols_u),
                  gains, plane, plane, plane, plane],
        out_specs=[plane] * 10,
        out_shape=out_shape,
        interpret=interpret,
        cost_estimate=pl.CostEstimate(
            flops=flops_per_block * n_batch_blocks,
            bytes_accessed=(14 * batch_block * p * 4
                            + (n_cols_v + n_cols_u) * 8 * p * 4
                            + 8 * p * 4) * n_batch_blocks,
            transcendentals=batch_block * p * 2 * n_batch_blocks,
        ),
    )


def rfnn_linear_bwd_kernel(cv_inv_ref, cv_adj_ref, par_v_ref,
                           cu_inv_ref, cu_adj_ref, par_u_ref, gains_ref,
                           ver_ref, vei_ref, vor_ref, voi_ref,
                           uer_ref, uei_ref, uor_ref, uoi_ref,
                           goe_ref, goo_ref,
                           dcv_ref, dcu_ref, dg_ref,
                           dxer_ref, dxei_ref, dxor_ref, dxoi_ref):
    """Unwind |detect| -> g2 -> U-mesh -> g1 -> V-mesh in one VMEM residency.

    Saved residuals are only the two stage boundaries; everything inside a
    mesh is recomputed by the reversed inverse/adjoint column sweep.
    """
    @pl.when(pl.program_id(0) == 0)
    def _init():
        dcv_ref[...] = jnp.zeros(dcv_ref.shape, dcv_ref.dtype)
        dcu_ref[...] = jnp.zeros(dcu_ref.shape, dcu_ref.dtype)
        dg_ref[...] = jnp.zeros(dg_ref.shape, dg_ref.dtype)

    g = gains_ref[...]
    v = (ver_ref[...], vei_ref[...], vor_ref[...], voi_ref[...])
    u = (uer_ref[...], uei_ref[...], uor_ref[...], uoi_ref[...])
    goe, goo = goe_ref[...], goo_ref[...]

    # |detect| backward: d|z|/dz = z / |z| (0 at the non-smooth origin,
    # which also kills the padded batch rows).
    zer, zei = _cmul(u[0], u[1], g[4], g[5])
    zor, zoi = _cmul(u[2], u[3], g[6], g[7])
    me = jnp.sqrt(zer * zer + zei * zei)
    mo = jnp.sqrt(zor * zor + zoi * zoi)
    inv_e = jnp.where(me > 0, goe / jnp.where(me > 0, me, 1.0), 0.0)
    inv_o = jnp.where(mo > 0, goo / jnp.where(mo > 0, mo, 1.0), 0.0)
    gzer, gzei = inv_e * zer, inv_e * zei
    gzor, gzoi = inv_o * zor, inv_o * zoi

    # post-gain g2: gradient rows 4..7 and cotangent of the U output
    dg2 = (_conj_dot(u[0], u[1], gzer, gzei)
           + _conj_dot(u[2], u[3], gzor, gzoi))
    guer, guei = _cmul(g[4], -g[5], gzer, gzei)
    guor, guoi = _cmul(g[6], -g[7], gzor, gzoi)

    # U mesh: reversed inverse/adjoint sweep from the saved post-U boundary
    _, gh = _run_columns_bwd(cu_inv_ref, cu_adj_ref, par_u_ref, dcu_ref, u,
                             (guer, guei, guor, guoi))

    # mid gain g1: gradient rows 0..3 and cotangent of the V output
    dg1 = (_conj_dot(v[0], v[1], gh[0], gh[1])
           + _conj_dot(v[2], v[3], gh[2], gh[3]))
    gver, gvei = _cmul(g[0], -g[1], gh[0], gh[1])
    gvor, gvoi = _cmul(g[2], -g[3], gh[2], gh[3])

    dg_ref[...] = dg_ref[...] + jnp.concatenate(list(dg1) + list(dg2), axis=0)

    # V mesh: reversed inverse/adjoint sweep from the saved post-V boundary
    _, gx = _run_columns_bwd(cv_inv_ref, cv_adj_ref, par_v_ref, dcv_ref, v,
                             (gver, gvei, gvor, gvoi))
    dxer_ref[...] = gx[0]
    dxei_ref[...] = gx[1]
    dxor_ref[...] = gx[2]
    dxoi_ref[...] = gx[3]


def rfnn_linear_bwd_pallas_call(n: int, n_cols_v: int, n_cols_u: int,
                                batch_block: int, n_batch_blocks: int,
                                interpret: bool):
    p = n // 2
    plane = pl.BlockSpec((batch_block, p), lambda i: (i, 0))
    gains = pl.BlockSpec((8, p), lambda i: (0, 0))
    out_shape = (
        [jax.ShapeDtypeStruct((n_cols_v, 8, p), jnp.float32),
         jax.ShapeDtypeStruct((n_cols_u, 8, p), jnp.float32),
         jax.ShapeDtypeStruct((8, p), jnp.float32)]
        + [jax.ShapeDtypeStruct((n_batch_blocks * batch_block, p),
                                jnp.float32)] * 4)
    flops_per_block = 3 * 2 * ((n_cols_v + n_cols_u) * p * 16 + 6 * n) \
        * batch_block
    return pl.pallas_call(
        rfnn_linear_bwd_kernel,
        grid=(n_batch_blocks,),
        in_specs=[_coef_spec(n_cols_v, p), _coef_spec(n_cols_v, p),
                  _parity_spec(n_cols_v),
                  _coef_spec(n_cols_u, p), _coef_spec(n_cols_u, p),
                  _parity_spec(n_cols_u), gains] + [plane] * 10,
        out_specs=[_coef_spec(n_cols_v, p), _coef_spec(n_cols_u, p), gains]
        + [plane] * 4,
        out_shape=out_shape,
        interpret=interpret,
        cost_estimate=pl.CostEstimate(
            flops=flops_per_block * n_batch_blocks,
            bytes_accessed=(14 * batch_block * p * 4
                            + 3 * (n_cols_v + n_cols_u) * 8 * p * 4
                            + 2 * 8 * p * 4) * n_batch_blocks,
            transcendentals=batch_block * p * 2 * n_batch_blocks,
        ),
    )


# ---------------------------------------------------------------------------
# Deep tiled-network megakernel: L layers of a (To x Ti) tile grid in one
# VMEM residency
# ---------------------------------------------------------------------------
#
# The general form of the paper's Sec. V scale-up: a deep network whose
# every layer is a (To x Ti) grid of analog tile processors realizing a
# large blocked matmul.  Per tile: pre-gain g0 (input phase screens) ->
# V-mesh -> mid gain g1 (attenuation + folded screens) -> U-mesh -> post
# gain g2 (digital scale + output screen); the Ti complex outputs of each
# tile row are summed in VMEM (matched-line power combiner) and the
# combined row magnitudes are re-detected *inside the kernel* to feed the
# next layer as a real signal (zero imaginary planes) — inter-layer
# activations never touch HBM.  Gains are [L, To, Ti, 12, P]: rows 0-3
# g0, 4-7 g1, 8-11 g2, each as (even re, even im, odd re, odd im).
# Coefficients/parities stack to [L, To, Ti, C, 8, P] / [L, To, Ti, C, 1]
# with identity-column padding to the network-wide C (see
# ``repro.kernels.schedule.DeepGridSchedule``).  The pallas grid is the
# batch alone — inter-layer re-detection needs every row of a layer, so
# one grid step carries one batch block through the entire network.
#
# The grid's To*Ti tiles are NOT unrolled: a layer's tiles are
# independent sweeps over the same (padded) column count, so the body
# stacks them into [B, To, Ti, P] planes and runs ONE column sweep per
# mesh stage with [To, Ti, 8, P] coefficient slabs broadcast over the
# batch.  The per-column even/odd pairing becomes a branch-free
# parity-masked select (the rotation math runs once per lane; parity
# only reroutes operands and results), so tiles with *different* column
# parities — mixed Reck/Clements grids — coexist in one stacked sweep.
# Only the L layer steps unroll (each depends on the previous layer's
# detected rows), keeping the emitted program O(L * C) vector ops
# instead of O(L * To * Ti * C) scalar-tile ops.
#
# In-kernel re-detection between layers is *exact*, not an approximation:
# the combined row output z is held coherently in VMEM, so |z| computed
# in-kernel is the same value the per-layer composition computes after
# its HBM round trip — same op order (multiply, add, sqrt), same floats.
#
# The last layer's readout is a static kernel variant (``detect_last``):
# True emits the detected magnitudes (the network/MNIST readout), False
# emits the combined complex planes (the tile-grid readout, where |.|,
# Re, and detector noise compose outside).  Both share the same sweep.
#
# Residuals follow the single-layer kernel's rule: everything inside a
# mesh is recomputed by the reversed inverse/adjoint sweep (no per-column
# state), but |z| is not invertible, so each tile saves its two pre-gain
# stage boundaries (post-V, post-U) — 8 stacked [L, B, To, Ti, P] planes
# total (batch-block axis second, so the stacked sweep saves whole
# slabs), identical to what the per-layer / per-tile composition would
# have stored, minus all the inter-layer HBM round trips and per-layer
# kernel launches.  The layer-boundary activations themselves are NOT
# stored: a layer's input is re-detected from the *previous* layer's
# saved post-U states (one cheap elementwise |sum_i g2 u_i| per row — no
# sweep), and the backward unwinds layers in reverse, converting the
# row-combine's transpose (every tile of a row sees the row's cotangent;
# each input tile sums its cotangent over rows) entirely in VMEM.


def _vshift_down(x):
    """x[..., p] <- x[..., p+1] (zero into the last lane)."""
    return jnp.concatenate([x[..., 1:], jnp.zeros_like(x[..., :1])],
                           axis=-1)


def _vshift_up(x, first):
    """x[..., p] <- x[..., p-1], lane 0 taken from ``first``."""
    return jnp.concatenate([first[..., :1], x[..., :-1]], axis=-1)


def _vlast(x, last):
    """x with its last lane replaced from ``last``."""
    return jnp.concatenate([x[..., :-1], last[..., -1:]], axis=-1)


def _vcolumn_even(cc, state):
    """Even column over stacked tile planes: rotate (e_p, o_p) in place."""
    er, ei, orr, oi = state
    c = [cc[..., k, :] for k in range(8)]
    return _rotate(c, er, ei, orr, oi)


def _vcolumn_odd(cc, state):
    """Odd column: rotate (o_p, e_{p+1}); the two wrap lanes pass
    through (odd columns hold no cell in the wrap-around pair)."""
    er, ei, orr, oi = state
    c = [cc[..., k, :] for k in range(8)]
    a2r, a2i, b2r, b2i = _rotate(c, orr, oi,
                                 _vshift_down(er), _vshift_down(ei))
    return (_vshift_up(b2r, er), _vshift_up(b2i, ei),
            _vlast(a2r, orr), _vlast(a2i, oi))


def _vcolumn_mixed(cc, odd, state):
    """Parity-masked column for grids whose tiles disagree on this
    column's pairing (e.g. Reck next to Clements).  Branch-free: the
    rotation math runs exactly once per lane; the [To, Ti, 1] ``odd``
    mask only reroutes operands and results, so both pairings coexist
    in one stacked sweep."""
    er, ei, orr, oi = state
    c = [cc[..., k, :] for k in range(8)]
    ar = jnp.where(odd, orr, er)
    ai = jnp.where(odd, oi, ei)
    br = jnp.where(odd, _vshift_down(er), orr)
    bi = jnp.where(odd, _vshift_down(ei), oi)
    a2r, a2i, b2r, b2i = _rotate(c, ar, ai, br, bi)
    ner = jnp.where(odd, _vshift_up(b2r, er), a2r)
    nei = jnp.where(odd, _vshift_up(b2i, ei), a2i)
    nor = jnp.where(odd, _vlast(a2r, orr), b2r)
    noi = jnp.where(odd, _vlast(a2i, oi), b2i)
    return ner, nei, nor, noi


def _parity_code(par_c):
    """0 = all tiles even, 1 = all odd, 2 = mixed, for one [To, Ti, 1]
    parity column."""
    odd = par_c != 0
    return jnp.where(jnp.all(odd), jnp.int32(1),
                     jnp.any(odd).astype(jnp.int32) * 2)


def _vcolumn(cc, par_c, state):
    """One mesh column over stacked tile planes [B, To, Ti, P]: ``cc``
    the column's [To, Ti, 8, P] coefficient slab (broadcast over the
    batch), ``par_c`` its [To, Ti, 1] parity column.  Uniform columns —
    the only kind single-plan grids ever see — dispatch to the mask-free
    even/odd bodies; the masked select only runs when tiles disagree."""
    return jax.lax.switch(
        _parity_code(par_c),
        [lambda s: _vcolumn_even(cc, s),
         lambda s: _vcolumn_odd(cc, s),
         lambda s: _vcolumn_mixed(cc, par_c != 0, s)],
        state)


def _vrun_columns(coef, parity, state):
    """Stacked-tile column sweep: ``coef`` [To, Ti, C, 8, P], ``parity``
    [To, Ti, C, 1], state planes [B, To, Ti, P] (batch-materialized —
    fori_loop carries must be full-shape)."""
    coef = jnp.moveaxis(coef, 2, 0)       # [C, To, Ti, 8, P]
    parity = jnp.moveaxis(parity, 2, 0)   # [C, To, Ti, 1]

    def body(c, s):
        return _vcolumn(coef[c], parity[c], s)

    return jax.lax.fori_loop(0, coef.shape[0], body, state)


def _vconj_dot(xr, xi, gr, gi):
    """Batch-summed conj(x) * g over stacked planes -> [To, Ti, P] pair."""
    return (jnp.sum(xr * gr + xi * gi, axis=0),
            jnp.sum(xr * gi - xi * gr, axis=0))


def _vrows_from_pairs(a, ga, b, gb):
    """The 8 per-coefficient conj-dot gradient rows, stacked [..., 8, P]."""
    r0, r1 = _vconj_dot(a[0], a[1], ga[0], ga[1])
    r2, r3 = _vconj_dot(b[0], b[1], ga[0], ga[1])
    r4, r5 = _vconj_dot(a[0], a[1], gb[0], gb[1])
    r6, r7 = _vconj_dot(b[0], b[1], gb[0], gb[1])
    return jnp.stack([r0, r1, r2, r3, r4, r5, r6, r7], axis=-2)


def _vcoef_grad_even(s_in, g_out):
    er, ei, orr, oi = s_in
    ger, gei, gor, goi = g_out
    return _vrows_from_pairs((er, ei), (ger, gei), (orr, oi), (gor, goi))


def _vcoef_grad_odd(s_in, g_out):
    """Odd pairing: (a, b) = (o_p, e_{p+1}); the wrap lane holds no cell
    so its gradient rows are zeroed."""
    er, ei, orr, oi = s_in
    ger, gei, gor, goi = g_out
    rows = _vrows_from_pairs(
        (orr, oi), (gor, goi),
        (_vshift_down(er), _vshift_down(ei)),
        (_vshift_down(ger), _vshift_down(gei)))
    p = rows.shape[-1]
    return jnp.where(jnp.arange(p) == p - 1, 0.0, rows)


def _vcoef_grad_mixed(odd, s_in, g_out):
    """Masked-pairing coefficient gradient for mixed-parity columns (the
    same operand rerouting as :func:`_vcolumn_mixed`; odd tiles hold no
    cell in the wrap lane, so it is zeroed)."""
    er, ei, orr, oi = s_in
    ger, gei, gor, goi = g_out
    ar = jnp.where(odd, orr, er)
    ai = jnp.where(odd, oi, ei)
    br = jnp.where(odd, _vshift_down(er), orr)
    bi = jnp.where(odd, _vshift_down(ei), oi)
    gar = jnp.where(odd, gor, ger)
    gai = jnp.where(odd, goi, gei)
    gbr = jnp.where(odd, _vshift_down(ger), gor)
    gbi = jnp.where(odd, _vshift_down(gei), goi)
    rows = _vrows_from_pairs((ar, ai), (gar, gai), (br, bi), (gbr, gbi))
    p = rows.shape[-1]
    wrap = odd[..., None, :] & (jnp.arange(p) == p - 1)
    return jnp.where(wrap, 0.0, rows)


def _vbwd_column(ci_c, ca_c, par_c, s, g):
    """One reversed column: reconstruct the column input via the inverse
    slab, take its coefficient gradient, propagate the cotangent via the
    adjoint slab — dispatched once per column on the parity code, so
    uniform columns never pay the mixed path's masking."""
    def make(step, coef_grad):
        def branch(sg):
            s_, g_ = sg[0:4], sg[4:8]
            s_in = step(ci_c, s_)             # T_c^{-1} s_{c+1}
            grad = coef_grad(s_in, g_)
            g_in = step(ca_c, g_)             # T_c^H g_{c+1}
            return (*s_in, grad, *g_in)
        return branch

    odd = par_c != 0
    out = jax.lax.switch(
        _parity_code(par_c),
        [make(_vcolumn_even, _vcoef_grad_even),
         make(_vcolumn_odd, _vcoef_grad_odd),
         make(lambda cc, st: _vcolumn_mixed(cc, odd, st),
              lambda s_in, g_: _vcoef_grad_mixed(odd, s_in, g_))],
        (*s, *g))
    return out[0:4], out[4], out[5:9]


def _vrun_columns_bwd(coef_inv, coef_adj, parity, state, cot):
    """Reversed stacked-tile sweep: recompute states via the per-cell
    inverse, accumulate per-column coefficient gradients into a fresh
    [To, Ti, C, 8, P] value (the caller folds it into the revisited
    accumulator ref), propagate the cotangent via the adjoint."""
    n_cols = coef_inv.shape[2]
    ci = jnp.moveaxis(coef_inv, 2, 0)
    ca = jnp.moveaxis(coef_adj, 2, 0)
    par = jnp.moveaxis(parity, 2, 0)
    dco = jnp.zeros(coef_inv.shape, coef_inv.dtype)

    def body(k, carry):
        c = n_cols - 1 - k
        s, g, acc = carry[0:4], carry[4:8], carry[8]
        s_in, grad, g_in = _vbwd_column(ci[c], ca[c], par[c], s, g)
        acc = jax.lax.dynamic_update_slice_in_dim(
            acc, grad[:, :, None], c, axis=2)
        return (*s_in, *g_in, acc)

    out = jax.lax.fori_loop(0, n_cols, body, (*state, *cot, dco))
    return out[0:4], out[4:8], out[8]


def _net_layer_stages(coef_v, par_v, coef_u, par_u, g, state):
    """g0 -> V -> g1 -> U for one stacked layer; returns (v, u) states.

    ``g`` is the layer's 12 gain planes ([To, Ti, P] each), ``state``
    the stacked [B, To, Ti, P] input planes."""
    er, ei = _cmul(state[0], state[1], g[0], g[1])
    orr, oi = _cmul(state[2], state[3], g[2], g[3])
    v = _vrun_columns(coef_v, par_v, (er, ei, orr, oi))
    er, ei = _cmul(v[0], v[1], g[4], g[5])
    orr, oi = _cmul(v[2], v[3], g[6], g[7])
    u = _vrun_columns(coef_u, par_u, (er, ei, orr, oi))
    return v, u


def _tile_z(u, g):
    """g2 on a tile's U-stage output: the post-g2 complex planes the row
    combiner sums."""
    zer, zei = _cmul(u[0], u[1], g[8], g[9])
    zor, zoi = _cmul(u[2], u[3], g[10], g[11])
    return zer, zei, zor, zoi


def _detect_z(z):
    """|detect| on a combined post-g2 state (4 planes -> 2 magnitudes)."""
    oe = jnp.sqrt(z[0] * z[0] + z[1] * z[1])
    oo = jnp.sqrt(z[2] * z[2] + z[3] * z[3])
    return oe, oo


def _detect_bwd_z(z, goe, goo):
    """|detect| backward: d|z|/dz = z/|z| (0 at the origin, which also
    kills zero-padded batch rows).  ``z`` is the combined post-g2 complex
    state of a tile row; returns its cotangent."""
    zer, zei, zor, zoi = z
    me = jnp.sqrt(zer * zer + zei * zei)
    mo = jnp.sqrt(zor * zor + zoi * zoi)
    inv_e = jnp.where(me > 0, goe / jnp.where(me > 0, me, 1.0), 0.0)
    inv_o = jnp.where(mo > 0, goo / jnp.where(mo > 0, mo, 1.0), 0.0)
    return inv_e * zer, inv_e * zei, inv_o * zor, inv_o * zoi


def _layer_linear_bwd(cv_inv, cv_adj, par_v, cu_inv, cu_adj, par_u, g,
                      x_in, v, u, gz):
    """Unwind the linear stages g2 -> U -> g1 -> V -> g0 of one stacked
    layer — every (To, Ti) tile at once.

    ``gz`` is the cotangent of the post-g2 complex state as [B, To, 1, P]
    row planes broadcast to every tile (the row combine is a sum, so each
    tile of a row sees its row's cotangent).  ``x_in``/``v``/``u`` are
    the stacked layer input and stage states.  Returns the layer's
    gradient slabs ``(dcv, dcu [To, Ti, C, 8, P], dg [To, Ti, 12, P])``
    and the per-tile input cotangent planes [B, To, Ti, P] (NOT yet
    summed over rows — the caller applies the combine's transpose).
    """
    gzer, gzei, gzor, gzoi = gz
    dg2 = (_vconj_dot(u[0], u[1], gzer, gzei)
           + _vconj_dot(u[2], u[3], gzor, gzoi))
    guer, guei = _cmul(g[8], -g[9], gzer, gzei)
    guor, guoi = _cmul(g[10], -g[11], gzor, gzoi)

    _, gh, dcu = _vrun_columns_bwd(cu_inv, cu_adj, par_u, u,
                                   (guer, guei, guor, guoi))

    dg1 = (_vconj_dot(v[0], v[1], gh[0], gh[1])
           + _vconj_dot(v[2], v[3], gh[2], gh[3]))
    gver, gvei = _cmul(g[4], -g[5], gh[0], gh[1])
    gvor, gvoi = _cmul(g[6], -g[7], gh[2], gh[3])

    _, gs0, dcv = _vrun_columns_bwd(cv_inv, cv_adj, par_v, v,
                                    (gver, gvei, gvor, gvoi))

    # pre-gain g0: s0 = g0 * x_in
    dg0 = (_vconj_dot(x_in[0], x_in[1], gs0[0], gs0[1])
           + _vconj_dot(x_in[2], x_in[3], gs0[2], gs0[3]))
    gxer, gxei = _cmul(g[0], -g[1], gs0[0], gs0[1])
    gxor, gxoi = _cmul(g[2], -g[3], gs0[2], gs0[3])

    dg = jnp.stack(list(dg0) + list(dg1) + list(dg2), axis=-2)
    return dcv, dcu, dg, (gxer, gxei, gxor, gxoi)


def _layer_gain_planes(gains_ref, l):
    """Layer ``l``'s 12 gain planes, [To, Ti, P] each."""
    g = gains_ref[l]
    return [g[:, :, k] for k in range(12)]


def _broadcast_tiles(planes, to):
    """[B, Ti, P] input planes -> stacked [B, To, Ti, P] (every tile row
    sweeps the whole input), batch-materialized for the fori carries."""
    b, ti, p = planes[0].shape
    return tuple(jnp.broadcast_to(t[:, None], (b, to, ti, p))
                 for t in planes)


def _deep_forward(coef_v_ref, par_v_ref, coef_u_ref, par_u_ref, gains_ref,
                  xer_ref, xei_ref, xor_ref, xoi_ref, stage_refs=None):
    """All L layers of the (To x Ti) grid on one batch block, every
    layer's To*Ti tiles swept together as stacked [B, To, Ti, P] planes.

    Input planes are [B, Ti, P]; returns the *last* layer's combined
    post-g2 row planes ([B, To, P] x 4 — the caller applies the
    readout).  With ``stage_refs`` (the 8 ``[L, B, To, Ti, P]`` residual
    refs of the VJP forward) every tile's two pre-gain stage boundaries
    are saved as whole slabs; inference passes ``None``.
    """
    n_layers, to = coef_v_ref.shape[0], coef_v_ref.shape[1]
    state_in = _broadcast_tiles(
        (xer_ref[...], xei_ref[...], xor_ref[...], xoi_ref[...]), to)
    z_row = None
    for l in range(n_layers):
        if l > 0:
            # in-VMEM re-detection: the previous layer's To combined rows
            # become this layer's Ti real input tiles (To == Ti for L > 1)
            oe, oo = _detect_z(z_row)
            zero = jnp.zeros_like(oe)
            state_in = _broadcast_tiles((oe, zero, oo, zero), to)
        g = _layer_gain_planes(gains_ref, l)
        v, u = _net_layer_stages(coef_v_ref[l], par_v_ref[l],
                                 coef_u_ref[l], par_u_ref[l], g, state_in)
        if stage_refs is not None:
            (sver, svei, svor, svoi, suer, suei, suor, suoi) = stage_refs
            sver[l], svei[l] = v[0], v[1]
            svor[l], svoi[l] = v[2], v[3]
            suer[l], suei[l] = u[0], u[1]
            suor[l], suoi[l] = u[2], u[3]
        z = _tile_z(u, g)
        # matched-line row combine: sum each row's Ti tile outputs
        z_row = tuple(t.sum(axis=2) for t in z)
    return z_row


def deepgrid_kernel(coef_v_ref, par_v_ref, coef_u_ref, par_u_ref, gains_ref,
                    xer_ref, xei_ref, xor_ref, xoi_ref, *out_refs,
                    detect_last: bool):
    """Inference megakernel: the whole deep tiled network, one residency.

    ``detect_last`` (static) picks the readout: True writes the detected
    row magnitudes (2 output planes), False the combined complex row
    states (4 planes).
    """
    z = _deep_forward(coef_v_ref, par_v_ref, coef_u_ref, par_u_ref,
                      gains_ref, xer_ref, xei_ref, xor_ref, xoi_ref)
    if detect_last:
        oe_ref, oo_ref = out_refs
        oe, oo = _detect_z(z)
        oe_ref[...], oo_ref[...] = oe, oo
    else:
        oer_ref, oei_ref, oor_ref, ooi_ref = out_refs
        oer_ref[...], oei_ref[...] = z[0], z[1]
        oor_ref[...], ooi_ref[...] = z[2], z[3]


def deepgrid_fwd_kernel(coef_v_ref, par_v_ref, coef_u_ref, par_u_ref,
                        gains_ref, xer_ref, xei_ref, xor_ref, xoi_ref,
                        *out_refs, detect_last: bool):
    """VJP forward: identical sweep, plus every tile's two pre-gain stage
    boundaries (post-V, post-U) into [L, B, To, Ti, P] residual planes."""
    n_out = 2 if detect_last else 4
    stage_refs = out_refs[n_out:]
    z = _deep_forward(coef_v_ref, par_v_ref, coef_u_ref, par_u_ref,
                      gains_ref, xer_ref, xei_ref, xor_ref, xoi_ref,
                      stage_refs=stage_refs)
    if detect_last:
        oe_ref, oo_ref = out_refs[:2]
        oe, oo = _detect_z(z)
        oe_ref[...], oo_ref[...] = oe, oo
    else:
        oer_ref, oei_ref, oor_ref, ooi_ref = out_refs[:4]
        oer_ref[...], oei_ref[...] = z[0], z[1]
        oor_ref[...], ooi_ref[...] = z[2], z[3]


def deepgrid_bwd_kernel(cv_inv_ref, cv_adj_ref, par_v_ref,
                        cu_inv_ref, cu_adj_ref, par_u_ref, gains_ref,
                        xer_ref, xei_ref, xor_ref, xoi_ref,
                        sver_ref, svei_ref, svor_ref, svoi_ref,
                        suer_ref, suei_ref, suor_ref, suoi_ref,
                        *cot_and_out_refs, detect_last: bool):
    """Unwind the whole deep grid in one residency, layers in reverse.

    Every tile unwinds g2 -> U -> g1 -> V -> g0 from its saved stage
    boundaries with the inverse/adjoint sweeps, accumulating into its
    (layer, row, tile) slot of the stacked coefficient/gain accumulators
    (revisited across the batch grid).  The row combine is a sum, so all
    Ti tiles of a row see the row's cotangent; the combine's transpose —
    each input tile's cotangent summed over the To rows — runs in VMEM,
    and crossing a layer boundary re-detects the previous layer's rows
    from their saved post-U states and converts the (real) cotangent
    through the |detect| backward.  Layer 0 writes the input cotangent
    planes [B, Ti, P].
    """
    n_cot = 2 if detect_last else 4
    cot_refs = cot_and_out_refs[:n_cot]
    (dcv_ref, dcu_ref, dg_ref,
     dxer_ref, dxei_ref, dxor_ref, dxoi_ref) = cot_and_out_refs[n_cot:]

    @pl.when(pl.program_id(0) == 0)
    def _init():
        dcv_ref[...] = jnp.zeros(dcv_ref.shape, dcv_ref.dtype)
        dcu_ref[...] = jnp.zeros(dcu_ref.shape, dcu_ref.dtype)
        dg_ref[...] = jnp.zeros(dg_ref.shape, dg_ref.dtype)

    n_layers, to = cv_inv_ref.shape[0], cv_inv_ref.shape[1]

    def saved_v(l):
        return (sver_ref[l], svei_ref[l], svor_ref[l], svoi_ref[l])

    def saved_u(l):
        return (suer_ref[l], suei_ref[l], suor_ref[l], suoi_ref[l])

    def row_z(l):
        """Recompute layer l's combined post-g2 row planes [B, To, P]
        from the saved post-U stages (elementwise — no sweep)."""
        z = _tile_z(saved_u(l), _layer_gain_planes(gains_ref, l))
        return tuple(t.sum(axis=2) for t in z)

    if detect_last:
        goe_ref, goo_ref = cot_refs
        gz = _detect_bwd_z(row_z(n_layers - 1), goe_ref[...], goo_ref[...])
    else:
        gz = tuple(r[...] for r in cot_refs)              # [B, To, P]

    for l in range(n_layers - 1, -1, -1):
        if l == 0:
            z_prev = None
            state_in = _broadcast_tiles(
                (xer_ref[...], xei_ref[...], xor_ref[...], xoi_ref[...]),
                to)
        else:
            # layer l's input tiles: re-detected previous-layer rows
            # (To == Ti whenever L > 1, so indices line up)
            z_prev = row_z(l - 1)
            be, bo = _detect_z(z_prev)
            zero = jnp.zeros_like(be)
            state_in = _broadcast_tiles((be, zero, bo, zero), to)
        # the row combine is a sum: every tile of a row sees the row's
        # cotangent ([B, To, 1, P] broadcast across the stacked sweep)
        gz_t = tuple(t[:, :, None] for t in gz)
        dcv, dcu, dg, gx = _layer_linear_bwd(
            cv_inv_ref[l], cv_adj_ref[l], par_v_ref[l],
            cu_inv_ref[l], cu_adj_ref[l], par_u_ref[l],
            _layer_gain_planes(gains_ref, l),
            state_in, saved_v(l), saved_u(l), gz_t)
        dcv_ref[l] = dcv_ref[l] + dcv
        dcu_ref[l] = dcu_ref[l] + dcu
        dg_ref[l] = dg_ref[l] + dg
        # combine's transpose: each input tile sums its cotangent over
        # the To rows
        dx = tuple(t.sum(axis=1) for t in gx)             # [B, Ti, P]
        if l > 0:
            # boundary crossing keeps only the real cotangent planes (the
            # imaginary planes of an inter-layer input are structurally
            # zero) and converts through the |detect| backward
            gz = _detect_bwd_z(z_prev, dx[0], dx[2])
        else:
            dxer_ref[...], dxei_ref[...] = dx[0], dx[1]
            dxor_ref[...], dxoi_ref[...] = dx[2], dx[3]


def _deep_coef_spec(n_layers: int, to: int, ti: int, n_cols: int, p: int):
    return pl.BlockSpec((n_layers, to, ti, n_cols, 8, p),
                        lambda b: (0, 0, 0, 0, 0, 0))


def _deep_parity_spec(n_layers: int, to: int, ti: int, n_cols: int):
    return pl.BlockSpec((n_layers, to, ti, n_cols, 1),
                        lambda b: (0, 0, 0, 0, 0))


def _deep_gains_spec(n_layers: int, to: int, ti: int, p: int):
    return pl.BlockSpec((n_layers, to, ti, 12, p),
                        lambda b: (0, 0, 0, 0, 0))


def _deep_flops_per_block(n: int, n_layers: int, to: int, ti: int,
                          n_cols: int, batch_block: int) -> int:
    p = n // 2
    return 2 * n_layers * to * ti * (2 * n_cols * p * 16 + 9 * n) \
        * batch_block


def _deep_coef_bytes(n_layers: int, to: int, ti: int, n_cols: int,
                     p: int) -> int:
    return n_layers * to * ti * (n_cols * 8 + 12) * p * 4


def deepgrid_pallas_call(n: int, n_layers: int, to: int, ti: int,
                         n_cols: int, batch_block: int, n_batch_blocks: int,
                         detect_last: bool, interpret: bool):
    p = n // 2
    b_total = n_batch_blocks * batch_block
    x_plane = pl.BlockSpec((batch_block, ti, p), lambda b: (b, 0, 0))
    o_plane = pl.BlockSpec((batch_block, to, p), lambda b: (b, 0, 0))
    n_out = 2 if detect_last else 4
    out_shape = [jax.ShapeDtypeStruct((b_total, to, p), jnp.float32)] * n_out
    flops = _deep_flops_per_block(n, n_layers, to, ti, n_cols, batch_block)
    return pl.pallas_call(
        functools.partial(deepgrid_kernel, detect_last=detect_last),
        grid=(n_batch_blocks,),
        in_specs=[_deep_coef_spec(n_layers, to, ti, n_cols, p),
                  _deep_parity_spec(n_layers, to, ti, n_cols),
                  _deep_coef_spec(n_layers, to, ti, n_cols, p),
                  _deep_parity_spec(n_layers, to, ti, n_cols),
                  _deep_gains_spec(n_layers, to, ti, p),
                  x_plane, x_plane, x_plane, x_plane],
        out_specs=[o_plane] * n_out,
        out_shape=out_shape,
        interpret=interpret,
        cost_estimate=pl.CostEstimate(
            flops=flops * n_batch_blocks,
            bytes_accessed=((4 * ti + n_out * to) * batch_block * p * 4
                            + _deep_coef_bytes(n_layers, to, ti, n_cols, p))
            * n_batch_blocks,
            transcendentals=n_layers * to * batch_block * p * 2
            * n_batch_blocks,
        ),
    )


def deepgrid_fwd_pallas_call(n: int, n_layers: int, to: int, ti: int,
                             n_cols: int, batch_block: int,
                             n_batch_blocks: int, detect_last: bool,
                             interpret: bool):
    p = n // 2
    b_total = n_batch_blocks * batch_block
    x_plane = pl.BlockSpec((batch_block, ti, p), lambda b: (b, 0, 0))
    o_plane = pl.BlockSpec((batch_block, to, p), lambda b: (b, 0, 0))
    stage = pl.BlockSpec((n_layers, batch_block, to, ti, p),
                         lambda b: (0, b, 0, 0, 0))
    n_out = 2 if detect_last else 4
    out_shape = (
        [jax.ShapeDtypeStruct((b_total, to, p), jnp.float32)] * n_out
        + [jax.ShapeDtypeStruct((n_layers, b_total, to, ti, p),
                                jnp.float32)] * 8)
    flops = _deep_flops_per_block(n, n_layers, to, ti, n_cols, batch_block)
    return pl.pallas_call(
        functools.partial(deepgrid_fwd_kernel, detect_last=detect_last),
        grid=(n_batch_blocks,),
        in_specs=[_deep_coef_spec(n_layers, to, ti, n_cols, p),
                  _deep_parity_spec(n_layers, to, ti, n_cols),
                  _deep_coef_spec(n_layers, to, ti, n_cols, p),
                  _deep_parity_spec(n_layers, to, ti, n_cols),
                  _deep_gains_spec(n_layers, to, ti, p),
                  x_plane, x_plane, x_plane, x_plane],
        out_specs=[o_plane] * n_out + [stage] * 8,
        out_shape=out_shape,
        interpret=interpret,
        cost_estimate=pl.CostEstimate(
            flops=flops * n_batch_blocks,
            bytes_accessed=(((4 + 8 * n_layers * to) * ti + n_out * to)
                            * batch_block * p * 4
                            + _deep_coef_bytes(n_layers, to, ti, n_cols, p))
            * n_batch_blocks,
            transcendentals=n_layers * to * batch_block * p * 2
            * n_batch_blocks,
        ),
    )


def deepgrid_bwd_pallas_call(n: int, n_layers: int, to: int, ti: int,
                             n_cols: int, batch_block: int,
                             n_batch_blocks: int, detect_last: bool,
                             interpret: bool):
    p = n // 2
    b_total = n_batch_blocks * batch_block
    x_plane = pl.BlockSpec((batch_block, ti, p), lambda b: (b, 0, 0))
    o_plane = pl.BlockSpec((batch_block, to, p), lambda b: (b, 0, 0))
    stage = pl.BlockSpec((n_layers, batch_block, to, ti, p),
                         lambda b: (0, b, 0, 0, 0))
    n_cot = 2 if detect_last else 4
    out_shape = (
        [jax.ShapeDtypeStruct((n_layers, to, ti, n_cols, 8, p),
                              jnp.float32)] * 2
        + [jax.ShapeDtypeStruct((n_layers, to, ti, 12, p), jnp.float32)]
        + [jax.ShapeDtypeStruct((b_total, ti, p), jnp.float32)] * 4)
    # inverse state recompute + adjoint cotangent + coefficient grads
    flops = 3 * _deep_flops_per_block(n, n_layers, to, ti, n_cols,
                                      batch_block)
    return pl.pallas_call(
        functools.partial(deepgrid_bwd_kernel, detect_last=detect_last),
        grid=(n_batch_blocks,),
        in_specs=[_deep_coef_spec(n_layers, to, ti, n_cols, p)] * 2
        + [_deep_parity_spec(n_layers, to, ti, n_cols)]
        + [_deep_coef_spec(n_layers, to, ti, n_cols, p)] * 2
        + [_deep_parity_spec(n_layers, to, ti, n_cols),
           _deep_gains_spec(n_layers, to, ti, p),
           x_plane, x_plane, x_plane, x_plane]
        + [stage] * 8 + [o_plane] * n_cot,
        out_specs=[_deep_coef_spec(n_layers, to, ti, n_cols, p)] * 2
        + [_deep_gains_spec(n_layers, to, ti, p)] + [x_plane] * 4,
        out_shape=out_shape,
        interpret=interpret,
        cost_estimate=pl.CostEstimate(
            flops=flops * n_batch_blocks,
            bytes_accessed=(((8 + 8 * n_layers * to) * ti + n_cot * to)
                            * batch_block * p * 4
                            + 3 * _deep_coef_bytes(n_layers, to, ti, n_cols,
                                                   p)) * n_batch_blocks,
            transcendentals=3 * n_layers * to * batch_block * p * 2
            * n_batch_blocks,
        ),
    )
