"""Pallas TPU kernels for the RF mesh apply — the paper's MVM hot spot.

TPU adaptation of the analog propagation: one mesh column is a set of
independent 2x2 complex rotations on channel pairs — pure VPU elementwise
work once channels are de-interleaved into even/odd (re, im) planes of shape
[batch, N/2].  The kernels keep a batch panel **resident in VMEM** and run
all C columns in-register/VMEM, the TPU analogue of the RF signal passing
through all S = N(N-1)/2 cells without intermediate storage (HBM traffic is
2 reads + 2 writes of the panel total, instead of per-column round trips).

Layout choices (see DESIGN.md):
  * planes [B, P] with P = N/2 on the lane dimension (128-aligned for N>=256);
  * coefficients [C, 8, P]: 8 rows = (t00, t01, t10, t11) x (re, im) per pair
    slot, broadcast over the batch sublanes.  The 2x2 cells are **arbitrary
    complex matrices** — ideal unitary rotations and the hardware model's
    lossy/imbalanced cells share the same layout and the same sweep;
  * a [C, 1] int32 parity input selects each column's pairing: parity 0
    rotates (even_i, odd_i), parity 1 rotates (odd_i, even_{i+1}) via
    shifted slices — static slicing only, no gathers.  Any adjacent-pair
    layout (Clements rectangle, triangular Reck programs, greedy-packed
    schedules) lowers to a parity sequence (see ``repro.kernels.schedule``).

Kernels:
  * ``mesh_kernel`` — one mesh (the paper's T(N), Eq. 28, ideal or not).
  * ``rfnn_linear_kernel`` — fused analog linear layer
    V-mesh -> diag gain -> U-mesh -> |detect| (paper Eq. 31 + Fig. 14),
    one VMEM residency for the whole layer.
  * ``network_kernel`` — the whole L-layer RFNN (stacked per-layer
    coefficient/parity/gain tensors) in one VMEM residency: inter-layer
    activations never touch HBM, the TPU analogue of the paper's
    end-to-end analog signal path (Sec. V).
  * ``tilegrid_kernel`` — a (To x Ti) grid of analog tile processors
    realizing a large blocked matmul (Sec. V scale-up): per grid step one
    tile row sweeps every input tile and coherently combines the row's
    outputs in VMEM (matched-line power combiner).
  * ``mesh_bwd_kernel`` / ``rfnn_linear_bwd_kernel`` — the custom VJPs.
    The backward pass re-runs the column sequence *in reverse*, carrying
    two coefficient tensors: the per-cell analytic **2x2 inverse** rebuilds
    each column's input state from the saved forward output (for unitary
    cells this degenerates to the PR-1 conjugate-transpose trick), while
    the **adjoint** (conjugate transpose) propagates the cotangent — the
    transpose of the real-representation Jacobian of ``y = T x`` is ``T^H``
    for *any* complex ``T``, unitary or not.  Per-column coefficient
    gradients are accumulated into a [C, 8, P] output revisited across
    batch-grid steps.  See DESIGN.md ("Backward pass").

Validated against ``ref.py`` and the hardware-model reference in interpret
mode (this container is CPU-only; TPU is the compilation target).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _cmul(ar, ai, br, bi):
    return ar * br - ai * bi, ar * bi + ai * br


def _rotate(cc, ar, ai, br, bi):
    """Apply the 2x2 complex rotations in an 8-row coefficient slice."""
    xr, xi = _cmul(cc[0], cc[1], ar, ai)
    yr, yi = _cmul(cc[2], cc[3], br, bi)
    a2r, a2i = xr + yr, xi + yi
    xr, xi = _cmul(cc[4], cc[5], ar, ai)
    yr, yi = _cmul(cc[6], cc[7], br, bi)
    return a2r, a2i, xr + yr, xi + yi


def _column_body(coef_ref, parity_ref, c, state):
    """One mesh column on the de-interleaved planes."""
    er, ei, orr, oi = state
    cc = coef_ref[c]  # [8, P] dynamic-sliced from VMEM

    def even(_):
        a2r, a2i, b2r, b2i = _rotate(cc, er, ei, orr, oi)
        return a2r, a2i, b2r, b2i

    def odd(_):
        ar, ai = orr[:, :-1], oi[:, :-1]
        br, bi = er[:, 1:], ei[:, 1:]
        a2r, a2i, b2r, b2i = _rotate(cc[:, :-1], ar, ai, br, bi)
        ner = jnp.concatenate([er[:, :1], b2r], axis=1)
        nei = jnp.concatenate([ei[:, :1], b2i], axis=1)
        nor = jnp.concatenate([a2r, orr[:, -1:]], axis=1)
        noi = jnp.concatenate([a2i, oi[:, -1:]], axis=1)
        return ner, nei, nor, noi

    return jax.lax.cond(parity_ref[c, 0] == 0, even, odd, None)


def _run_columns(coef_ref, parity_ref, state):
    n_cols = coef_ref.shape[0]
    return jax.lax.fori_loop(
        0, n_cols,
        functools.partial(_column_body, coef_ref, parity_ref), state)


# ---------------------------------------------------------------------------
# Kernel 1: single mesh
# ---------------------------------------------------------------------------

def mesh_kernel(coef_ref, parity_ref, xer_ref, xei_ref, xor_ref, xoi_ref,
                oer_ref, oei_ref, oor_ref, ooi_ref):
    state = (xer_ref[...], xei_ref[...], xor_ref[...], xoi_ref[...])
    er, ei, orr, oi = _run_columns(coef_ref, parity_ref, state)
    oer_ref[...] = er
    oei_ref[...] = ei
    oor_ref[...] = orr
    ooi_ref[...] = oi


def _coef_spec(n_cols: int, p: int):
    return pl.BlockSpec((n_cols, 8, p), lambda i: (0, 0, 0))


def _parity_spec(n_cols: int):
    return pl.BlockSpec((n_cols, 1), lambda i: (0, 0))


def mesh_pallas_call(n: int, n_cols: int, batch_block: int,
                     n_batch_blocks: int, interpret: bool):
    p = n // 2
    plane = pl.BlockSpec((batch_block, p), lambda i: (i, 0))
    out_shape = [jax.ShapeDtypeStruct((n_batch_blocks * batch_block, p),
                                      jnp.float32)] * 4
    flops_per_block = 2 * n_cols * p * batch_block * 16
    return pl.pallas_call(
        mesh_kernel,
        grid=(n_batch_blocks,),
        in_specs=[_coef_spec(n_cols, p), _parity_spec(n_cols),
                  plane, plane, plane, plane],
        out_specs=[plane] * 4,
        out_shape=out_shape,
        interpret=interpret,
        cost_estimate=pl.CostEstimate(
            flops=flops_per_block * n_batch_blocks,
            bytes_accessed=(8 * batch_block * p * 4 + n_cols * 8 * p * 4)
            * n_batch_blocks,
            transcendentals=0,
        ),
    )


# ---------------------------------------------------------------------------
# Kernel 2: fused analog linear  (V-mesh -> diag -> U-mesh -> |detect|)
# ---------------------------------------------------------------------------

def _rfnn_forward(coef_v_ref, par_v_ref, coef_u_ref, par_u_ref, gains_ref,
                  state):
    """The fused layer body: V -> g1 -> U -> g2 -> |detect|.

    Returns detected magnitudes plus the two pre-gain stage boundaries
    (the VJP forward's residuals); the inference kernel discards them.
    """
    v = _run_columns(coef_v_ref, par_v_ref, state)
    g = gains_ref[...]  # [8, P]: g1 (even re/im, odd re/im), g2 (...)
    er, ei = _cmul(v[0], v[1], g[0], g[1])
    orr, oi = _cmul(v[2], v[3], g[2], g[3])
    u = _run_columns(coef_u_ref, par_u_ref, (er, ei, orr, oi))
    zer, zei = _cmul(u[0], u[1], g[4], g[5])
    zor, zoi = _cmul(u[2], u[3], g[6], g[7])
    oe = jnp.sqrt(zer * zer + zei * zei)   # |detect| on even channels
    oo = jnp.sqrt(zor * zor + zoi * zoi)
    return oe, oo, v, u


def rfnn_linear_kernel(coef_v_ref, par_v_ref, coef_u_ref, par_u_ref,
                       gains_ref, xer_ref, xei_ref, xor_ref, xoi_ref,
                       oe_ref, oo_ref):
    state = (xer_ref[...], xei_ref[...], xor_ref[...], xoi_ref[...])
    oe, oo, _, _ = _rfnn_forward(coef_v_ref, par_v_ref, coef_u_ref,
                                 par_u_ref, gains_ref, state)
    oe_ref[...] = oe
    oo_ref[...] = oo


def rfnn_linear_pallas_call(n: int, n_cols_v: int, n_cols_u: int,
                            batch_block: int, n_batch_blocks: int,
                            interpret: bool):
    p = n // 2
    plane = pl.BlockSpec((batch_block, p), lambda i: (i, 0))
    gains = pl.BlockSpec((8, p), lambda i: (0, 0))
    out_shape = [jax.ShapeDtypeStruct((n_batch_blocks * batch_block, p),
                                      jnp.float32)] * 2
    flops_per_block = 2 * ((n_cols_v + n_cols_u) * p * 16 + 3 * n) \
        * batch_block
    return pl.pallas_call(
        rfnn_linear_kernel,
        grid=(n_batch_blocks,),
        in_specs=[_coef_spec(n_cols_v, p), _parity_spec(n_cols_v),
                  _coef_spec(n_cols_u, p), _parity_spec(n_cols_u),
                  gains, plane, plane, plane, plane],
        out_specs=[plane] * 2,
        out_shape=out_shape,
        interpret=interpret,
        cost_estimate=pl.CostEstimate(
            flops=flops_per_block * n_batch_blocks,
            bytes_accessed=(6 * batch_block * p * 4
                            + (n_cols_v + n_cols_u) * 8 * p * 4
                            + 8 * p * 4) * n_batch_blocks,
            transcendentals=batch_block * p * 2 * n_batch_blocks,
        ),
    )


# ---------------------------------------------------------------------------
# Backward pass building blocks (the custom VJPs)
# ---------------------------------------------------------------------------

def adjoint_coefficients(coef: jax.Array) -> jax.Array:
    """Conjugate-transpose each packed 2x2 cell, column layout preserved.

    Rows (t00, t01, t10, t11) x (re, im) -> (t00*, t10*, t01*, t11*).  The
    adjoint propagates the cotangent in the reversed sweep: the transpose
    of the real-representation Jacobian of ``y = T x`` is ``T^H`` for any
    complex ``T``.  For unitary columns it is also the exact inverse, which
    is the PR-1 state-recompute trick as a special case.  Rows live on axis
    -2, so both per-mesh ``[C, 8, P]`` and stacked network ``[L, C, 8, P]``
    layouts transform in place.
    """
    idx = jnp.asarray([0, 1, 4, 5, 2, 3, 6, 7])
    sign = jnp.asarray([1.0, -1.0] * 4, coef.dtype)
    return jnp.take(coef, idx, axis=-2) * sign[:, None]


def inverse_coefficients(coef: jax.Array, eps: float = 1e-12) -> jax.Array:
    """Analytic per-cell 2x2 inverse in the packed coefficient layout.

    ``inv(t) = adj(t) / det(t)`` with ``det = t00 t11 - t01 t10``.  This is
    what lets the backward sweep rebuild intermediate states for
    **non-unitary** cells (hybrid imbalance, per-cell insertion loss) with
    no per-column residuals: ``s_c = T_c^{-1} s_{c+1}``.  Hardware cells
    are well-conditioned (|det| ~ cell_gain^2); ``eps`` guards the
    identity-padded slots' neighbourhood against exact zeros.  Like
    :func:`adjoint_coefficients`, rows live on axis -2 (works on ``[C, 8,
    P]`` and ``[L, C, 8, P]`` alike).
    """
    t00 = coef[..., 0, :] + 1j * coef[..., 1, :]
    t01 = coef[..., 2, :] + 1j * coef[..., 3, :]
    t10 = coef[..., 4, :] + 1j * coef[..., 5, :]
    t11 = coef[..., 6, :] + 1j * coef[..., 7, :]
    det = t00 * t11 - t01 * t10
    inv_det = jnp.conj(det) / jnp.maximum(jnp.abs(det) ** 2, eps)
    i00, i01 = t11 * inv_det, -t01 * inv_det
    i10, i11 = -t10 * inv_det, t00 * inv_det
    out = jnp.stack(
        [jnp.real(i00), jnp.imag(i00), jnp.real(i01), jnp.imag(i01),
         jnp.real(i10), jnp.imag(i10), jnp.real(i11), jnp.imag(i11)],
        axis=-2,
    )
    return out.astype(coef.dtype)


def _conj_dot(xr, xi, gr, gi):
    """Batch-summed conj(x) * g — one complex coefficient gradient entry."""
    return (jnp.sum(xr * gr + xi * gi, axis=0, keepdims=True),
            jnp.sum(xr * gi - xi * gr, axis=0, keepdims=True))


def _pair_grad_rows(ar, ai, br, bi, gar, gai, gbr, gbi):
    """d loss / d t for (a2, b2) = t (a, b): rows (00, 01, 10, 11)(re, im)."""
    r0, r1 = _conj_dot(ar, ai, gar, gai)
    r2, r3 = _conj_dot(br, bi, gar, gai)
    r4, r5 = _conj_dot(ar, ai, gbr, gbi)
    r6, r7 = _conj_dot(br, bi, gbr, gbi)
    return jnp.concatenate([r0, r1, r2, r3, r4, r5, r6, r7], axis=0)  # [8, P]


def _coef_grad(parity_ref, c, s_in, g_out):
    """Coefficient gradient of column ``c`` from its input state and the
    cotangent at its output, in the column's own pairing."""
    er, ei, orr, oi = s_in
    ger, gei, gor, goi = g_out

    def even(_):
        return _pair_grad_rows(er, ei, orr, oi, ger, gei, gor, goi)

    def odd(_):
        rows = _pair_grad_rows(
            orr[:, :-1], oi[:, :-1], er[:, 1:], ei[:, 1:],
            gor[:, :-1], goi[:, :-1], ger[:, 1:], gei[:, 1:])
        # wrap slot of odd columns holds no cell
        return jnp.concatenate([rows, jnp.zeros((8, 1), rows.dtype)], axis=1)

    return jax.lax.cond(parity_ref[c, 0] == 0, even, odd, None)


def _run_columns_bwd(coef_inv_ref, coef_adj_ref, parity_ref, dcoef_ref,
                     state, cot, layer=None):
    """Reversed column sweep: recompute states via the per-cell inverse,
    accumulate coefficient gradients, propagate the cotangent via the
    adjoint.  ``state`` starts at the mesh *output*.  ``layer`` (a static
    int, or a static tuple for grid layouts) selects the leading indices
    of a stacked ``[L, C, 8, P]`` / ``[To, Ti, C, 8, P]`` gradient
    accumulator — the network kernel's per-layer slot and the tile-grid
    kernel's per-tile slot."""
    n_cols = coef_inv_ref.shape[0]
    lead = (() if layer is None
            else layer if isinstance(layer, tuple) else (layer,))

    def body(k, carry):
        c = n_cols - 1 - k
        s, g = carry[0:4], carry[4:8]
        s_in = _column_body(coef_inv_ref, parity_ref, c, s)   # T_c^{-1} s_{c+1}
        grad = _coef_grad(parity_ref, c, s_in, g)
        dcoef_ref[lead + (c,)] = dcoef_ref[lead + (c,)] + grad
        g_in = _column_body(coef_adj_ref, parity_ref, c, g)   # T_c^H g_{c+1}
        return (*s_in, *g_in)

    out = jax.lax.fori_loop(0, n_cols, body, (*state, *cot))
    return out[0:4], out[4:8]


def mesh_bwd_kernel(coef_inv_ref, coef_adj_ref, parity_ref,
                    yer_ref, yei_ref, yor_ref, yoi_ref,
                    ger_ref, gei_ref, gor_ref, goi_ref,
                    dcoef_ref, dxer_ref, dxei_ref, dxor_ref, dxoi_ref):
    @pl.when(pl.program_id(0) == 0)
    def _init():
        dcoef_ref[...] = jnp.zeros(dcoef_ref.shape, dcoef_ref.dtype)

    y = (yer_ref[...], yei_ref[...], yor_ref[...], yoi_ref[...])
    g = (ger_ref[...], gei_ref[...], gor_ref[...], goi_ref[...])
    _, gx = _run_columns_bwd(coef_inv_ref, coef_adj_ref, parity_ref,
                             dcoef_ref, y, g)
    dxer_ref[...] = gx[0]
    dxei_ref[...] = gx[1]
    dxor_ref[...] = gx[2]
    dxoi_ref[...] = gx[3]


def mesh_bwd_pallas_call(n: int, n_cols: int, batch_block: int,
                         n_batch_blocks: int, interpret: bool):
    p = n // 2
    plane = pl.BlockSpec((batch_block, p), lambda i: (i, 0))
    out_shape = (
        [jax.ShapeDtypeStruct((n_cols, 8, p), jnp.float32)]
        + [jax.ShapeDtypeStruct((n_batch_blocks * batch_block, p),
                                jnp.float32)] * 4)
    # state recompute + cotangent propagation + coefficient grads ~ 3x fwd
    flops_per_block = 3 * 2 * n_cols * p * batch_block * 16
    return pl.pallas_call(
        mesh_bwd_kernel,
        grid=(n_batch_blocks,),
        in_specs=[_coef_spec(n_cols, p), _coef_spec(n_cols, p),
                  _parity_spec(n_cols)] + [plane] * 8,
        out_specs=[_coef_spec(n_cols, p)] + [plane] * 4,
        out_shape=out_shape,
        interpret=interpret,
        cost_estimate=pl.CostEstimate(
            flops=flops_per_block * n_batch_blocks,
            bytes_accessed=(12 * batch_block * p * 4 + 3 * n_cols * 8 * p * 4)
            * n_batch_blocks,
            transcendentals=0,
        ),
    )


# ---------------------------------------------------------------------------
# Fused analog linear: forward-with-residuals and backward
# ---------------------------------------------------------------------------

def rfnn_linear_fwd_kernel(coef_v_ref, par_v_ref, coef_u_ref, par_u_ref,
                           gains_ref, xer_ref, xei_ref, xor_ref, xoi_ref,
                           oe_ref, oo_ref,
                           ver_ref, vei_ref, vor_ref, voi_ref,
                           uer_ref, uei_ref, uor_ref, uoi_ref):
    """Forward identical to ``rfnn_linear_kernel`` (same ``_rfnn_forward``
    body) but additionally writes the two stage boundaries (post-V and
    post-U, both pre-gain) — the only residuals the backward pass needs."""
    state = (xer_ref[...], xei_ref[...], xor_ref[...], xoi_ref[...])
    oe, oo, v, u = _rfnn_forward(coef_v_ref, par_v_ref, coef_u_ref,
                                 par_u_ref, gains_ref, state)
    oe_ref[...] = oe
    oo_ref[...] = oo
    ver_ref[...], vei_ref[...], vor_ref[...], voi_ref[...] = v
    uer_ref[...], uei_ref[...], uor_ref[...], uoi_ref[...] = u


def rfnn_linear_fwd_pallas_call(n: int, n_cols_v: int, n_cols_u: int,
                                batch_block: int, n_batch_blocks: int,
                                interpret: bool):
    p = n // 2
    plane = pl.BlockSpec((batch_block, p), lambda i: (i, 0))
    gains = pl.BlockSpec((8, p), lambda i: (0, 0))
    out_shape = [jax.ShapeDtypeStruct((n_batch_blocks * batch_block, p),
                                      jnp.float32)] * 10
    flops_per_block = 2 * ((n_cols_v + n_cols_u) * p * 16 + 3 * n) \
        * batch_block
    return pl.pallas_call(
        rfnn_linear_fwd_kernel,
        grid=(n_batch_blocks,),
        in_specs=[_coef_spec(n_cols_v, p), _parity_spec(n_cols_v),
                  _coef_spec(n_cols_u, p), _parity_spec(n_cols_u),
                  gains, plane, plane, plane, plane],
        out_specs=[plane] * 10,
        out_shape=out_shape,
        interpret=interpret,
        cost_estimate=pl.CostEstimate(
            flops=flops_per_block * n_batch_blocks,
            bytes_accessed=(14 * batch_block * p * 4
                            + (n_cols_v + n_cols_u) * 8 * p * 4
                            + 8 * p * 4) * n_batch_blocks,
            transcendentals=batch_block * p * 2 * n_batch_blocks,
        ),
    )


def rfnn_linear_bwd_kernel(cv_inv_ref, cv_adj_ref, par_v_ref,
                           cu_inv_ref, cu_adj_ref, par_u_ref, gains_ref,
                           ver_ref, vei_ref, vor_ref, voi_ref,
                           uer_ref, uei_ref, uor_ref, uoi_ref,
                           goe_ref, goo_ref,
                           dcv_ref, dcu_ref, dg_ref,
                           dxer_ref, dxei_ref, dxor_ref, dxoi_ref):
    """Unwind |detect| -> g2 -> U-mesh -> g1 -> V-mesh in one VMEM residency.

    Saved residuals are only the two stage boundaries; everything inside a
    mesh is recomputed by the reversed inverse/adjoint column sweep.
    """
    @pl.when(pl.program_id(0) == 0)
    def _init():
        dcv_ref[...] = jnp.zeros(dcv_ref.shape, dcv_ref.dtype)
        dcu_ref[...] = jnp.zeros(dcu_ref.shape, dcu_ref.dtype)
        dg_ref[...] = jnp.zeros(dg_ref.shape, dg_ref.dtype)

    g = gains_ref[...]
    v = (ver_ref[...], vei_ref[...], vor_ref[...], voi_ref[...])
    u = (uer_ref[...], uei_ref[...], uor_ref[...], uoi_ref[...])
    goe, goo = goe_ref[...], goo_ref[...]

    # |detect| backward: d|z|/dz = z / |z| (0 at the non-smooth origin,
    # which also kills the padded batch rows).
    zer, zei = _cmul(u[0], u[1], g[4], g[5])
    zor, zoi = _cmul(u[2], u[3], g[6], g[7])
    me = jnp.sqrt(zer * zer + zei * zei)
    mo = jnp.sqrt(zor * zor + zoi * zoi)
    inv_e = jnp.where(me > 0, goe / jnp.where(me > 0, me, 1.0), 0.0)
    inv_o = jnp.where(mo > 0, goo / jnp.where(mo > 0, mo, 1.0), 0.0)
    gzer, gzei = inv_e * zer, inv_e * zei
    gzor, gzoi = inv_o * zor, inv_o * zoi

    # post-gain g2: gradient rows 4..7 and cotangent of the U output
    dg2 = (_conj_dot(u[0], u[1], gzer, gzei)
           + _conj_dot(u[2], u[3], gzor, gzoi))
    guer, guei = _cmul(g[4], -g[5], gzer, gzei)
    guor, guoi = _cmul(g[6], -g[7], gzor, gzoi)

    # U mesh: reversed inverse/adjoint sweep from the saved post-U boundary
    _, gh = _run_columns_bwd(cu_inv_ref, cu_adj_ref, par_u_ref, dcu_ref, u,
                             (guer, guei, guor, guoi))

    # mid gain g1: gradient rows 0..3 and cotangent of the V output
    dg1 = (_conj_dot(v[0], v[1], gh[0], gh[1])
           + _conj_dot(v[2], v[3], gh[2], gh[3]))
    gver, gvei = _cmul(g[0], -g[1], gh[0], gh[1])
    gvor, gvoi = _cmul(g[2], -g[3], gh[2], gh[3])

    dg_ref[...] = dg_ref[...] + jnp.concatenate(list(dg1) + list(dg2), axis=0)

    # V mesh: reversed inverse/adjoint sweep from the saved post-V boundary
    _, gx = _run_columns_bwd(cv_inv_ref, cv_adj_ref, par_v_ref, dcv_ref, v,
                             (gver, gvei, gvor, gvoi))
    dxer_ref[...] = gx[0]
    dxei_ref[...] = gx[1]
    dxor_ref[...] = gx[2]
    dxoi_ref[...] = gx[3]


def rfnn_linear_bwd_pallas_call(n: int, n_cols_v: int, n_cols_u: int,
                                batch_block: int, n_batch_blocks: int,
                                interpret: bool):
    p = n // 2
    plane = pl.BlockSpec((batch_block, p), lambda i: (i, 0))
    gains = pl.BlockSpec((8, p), lambda i: (0, 0))
    out_shape = (
        [jax.ShapeDtypeStruct((n_cols_v, 8, p), jnp.float32),
         jax.ShapeDtypeStruct((n_cols_u, 8, p), jnp.float32),
         jax.ShapeDtypeStruct((8, p), jnp.float32)]
        + [jax.ShapeDtypeStruct((n_batch_blocks * batch_block, p),
                                jnp.float32)] * 4)
    flops_per_block = 3 * 2 * ((n_cols_v + n_cols_u) * p * 16 + 6 * n) \
        * batch_block
    return pl.pallas_call(
        rfnn_linear_bwd_kernel,
        grid=(n_batch_blocks,),
        in_specs=[_coef_spec(n_cols_v, p), _coef_spec(n_cols_v, p),
                  _parity_spec(n_cols_v),
                  _coef_spec(n_cols_u, p), _coef_spec(n_cols_u, p),
                  _parity_spec(n_cols_u), gains] + [plane] * 10,
        out_specs=[_coef_spec(n_cols_v, p), _coef_spec(n_cols_u, p), gains]
        + [plane] * 4,
        out_shape=out_shape,
        interpret=interpret,
        cost_estimate=pl.CostEstimate(
            flops=flops_per_block * n_batch_blocks,
            bytes_accessed=(14 * batch_block * p * 4
                            + 3 * (n_cols_v + n_cols_u) * 8 * p * 4
                            + 2 * 8 * p * 4) * n_batch_blocks,
            transcendentals=batch_block * p * 2 * n_batch_blocks,
        ),
    )


# ---------------------------------------------------------------------------
# Network megakernel: the whole L-layer RFNN in one VMEM residency
# ---------------------------------------------------------------------------
#
# Per layer: pre-gain g0 (input phase screens) -> V-mesh -> mid gain g1
# (attenuation + folded screens) -> U-mesh -> post gain g2 (digital scale +
# output screen) -> |detect|; the detected magnitudes re-enter the next
# layer as a real signal (zero imaginary planes) without ever leaving VMEM —
# the TPU analogue of the paper's end-to-end analog signal path (Sec. V,
# Fig. 14).  Gains are [L, 12, P]: rows 0-3 g0, 4-7 g1, 8-11 g2, each as
# (even re, even im, odd re, odd im).  Coefficients/parities are stacked
# [L, C, 8, P] / [L, C, 1] with identity-column padding (see
# ``repro.kernels.schedule.NetworkSchedule``).
#
# Residuals follow the single-layer kernel's rule: everything inside a
# mesh is recomputed by the reversed inverse/adjoint sweep (no per-column
# state), but |z| is not invertible, so each layer saves its two pre-gain
# stage boundaries (post-V, post-U) — 8 stacked [L, B, P] planes total,
# identical to what the per-layer composition would have stored, minus all
# the inter-layer HBM round trips and per-layer kernel launches.  The
# layer-boundary activations themselves are NOT stored: a layer's input is
# re-detected from the *previous* layer's saved post-U state (one cheap
# elementwise |g2 u| — no sweep), so the megakernel adds zero residual
# traffic over the per-layer path while fusing L layers into one call.


def _net_layer_stages(coef_v, par_v, coef_u, par_u, g, state):
    """g0 -> V -> g1 -> U for one layer; returns (v, u) stage states."""
    er, ei = _cmul(state[0], state[1], g[0], g[1])
    orr, oi = _cmul(state[2], state[3], g[2], g[3])
    v = _run_columns(coef_v, par_v, (er, ei, orr, oi))
    er, ei = _cmul(v[0], v[1], g[4], g[5])
    orr, oi = _cmul(v[2], v[3], g[6], g[7])
    u = _run_columns(coef_u, par_u, (er, ei, orr, oi))
    return v, u


def _net_layer_detect(u, g):
    """g2 -> |detect| on a layer's U-stage output."""
    zer, zei = _cmul(u[0], u[1], g[8], g[9])
    zor, zoi = _cmul(u[2], u[3], g[10], g[11])
    oe = jnp.sqrt(zer * zer + zei * zei)
    oo = jnp.sqrt(zor * zor + zoi * zoi)
    return oe, oo


def network_kernel(coef_v_ref, par_v_ref, coef_u_ref, par_u_ref, gains_ref,
                   xer_ref, xei_ref, xor_ref, xoi_ref, oe_ref, oo_ref):
    """Inference megakernel: all L layers, one batch block, one residency."""
    n_layers = coef_v_ref.shape[0]
    state = (xer_ref[...], xei_ref[...], xor_ref[...], xoi_ref[...])
    for l in range(n_layers):
        v, u = _net_layer_stages(coef_v_ref[l], par_v_ref[l],
                                 coef_u_ref[l], par_u_ref[l],
                                 gains_ref[l], state)
        oe, oo = _net_layer_detect(u, gains_ref[l])
        zero = jnp.zeros_like(oe)
        state = (oe, zero, oo, zero)
    oe_ref[...] = state[0]
    oo_ref[...] = state[2]


def network_fwd_kernel(coef_v_ref, par_v_ref, coef_u_ref, par_u_ref,
                       gains_ref, xer_ref, xei_ref, xor_ref, xoi_ref,
                       oe_ref, oo_ref,
                       sver_ref, svei_ref, svor_ref, svoi_ref,
                       suer_ref, suei_ref, suor_ref, suoi_ref):
    """VJP forward: identical sweep, plus every layer's two pre-gain stage
    boundaries (post-V, post-U) into stacked [L, B, P] residuals."""
    n_layers = coef_v_ref.shape[0]
    state = (xer_ref[...], xei_ref[...], xor_ref[...], xoi_ref[...])
    for l in range(n_layers):
        v, u = _net_layer_stages(coef_v_ref[l], par_v_ref[l],
                                 coef_u_ref[l], par_u_ref[l],
                                 gains_ref[l], state)
        sver_ref[l], svei_ref[l], svor_ref[l], svoi_ref[l] = v
        suer_ref[l], suei_ref[l], suor_ref[l], suoi_ref[l] = u
        oe, oo = _net_layer_detect(u, gains_ref[l])
        zero = jnp.zeros_like(oe)
        state = (oe, zero, oo, zero)
    oe_ref[...] = state[0]
    oo_ref[...] = state[2]


def _detect_bwd(u, g, goe, goo):
    """|detect| backward: d|z|/dz = z/|z| (0 at the origin, which also
    kills zero-padded batch rows).  Returns the cotangent of the post-g2
    complex state ``z = g2 * u``."""
    zer, zei = _cmul(u[0], u[1], g[8], g[9])
    zor, zoi = _cmul(u[2], u[3], g[10], g[11])
    me = jnp.sqrt(zer * zer + zei * zei)
    mo = jnp.sqrt(zor * zor + zoi * zoi)
    inv_e = jnp.where(me > 0, goe / jnp.where(me > 0, me, 1.0), 0.0)
    inv_o = jnp.where(mo > 0, goo / jnp.where(mo > 0, mo, 1.0), 0.0)
    return inv_e * zer, inv_e * zei, inv_o * zor, inv_o * zoi


def _layer_linear_bwd(cv_inv, cv_adj, par_v, cu_inv, cu_adj, par_u, g,
                      x_in, v, u, gz, dcv_ref, dcu_ref, layer):
    """Unwind the linear stages g2 -> U -> g1 -> V -> g0 of one layer/tile.

    ``gz`` is the cotangent of the post-g2 complex state (after |detect|
    backward for the network kernel; the row-sum cotangent directly for
    the tile-grid kernel, whose combine is linear).  ``x_in``/``v``/``u``
    are the layer input and stage states; accumulates coefficient
    gradients into slot ``layer`` (int or tuple) of the stacked
    accumulators and returns ``(dgains [12, P], gx planes)``.
    """
    gzer, gzei, gzor, gzoi = gz
    dg2 = (_conj_dot(u[0], u[1], gzer, gzei)
           + _conj_dot(u[2], u[3], gzor, gzoi))
    guer, guei = _cmul(g[8], -g[9], gzer, gzei)
    guor, guoi = _cmul(g[10], -g[11], gzor, gzoi)

    _, gh = _run_columns_bwd(cu_inv, cu_adj, par_u, dcu_ref, u,
                             (guer, guei, guor, guoi), layer=layer)

    dg1 = (_conj_dot(v[0], v[1], gh[0], gh[1])
           + _conj_dot(v[2], v[3], gh[2], gh[3]))
    gver, gvei = _cmul(g[4], -g[5], gh[0], gh[1])
    gvor, gvoi = _cmul(g[6], -g[7], gh[2], gh[3])

    _, gs0 = _run_columns_bwd(cv_inv, cv_adj, par_v, dcv_ref, v,
                              (gver, gvei, gvor, gvoi), layer=layer)

    # pre-gain g0: s0 = g0 * x_in
    dg0 = (_conj_dot(x_in[0], x_in[1], gs0[0], gs0[1])
           + _conj_dot(x_in[2], x_in[3], gs0[2], gs0[3]))
    gxer, gxei = _cmul(g[0], -g[1], gs0[0], gs0[1])
    gxor, gxoi = _cmul(g[2], -g[3], gs0[2], gs0[3])

    dg = jnp.concatenate(list(dg0) + list(dg1) + list(dg2), axis=0)
    return dg, (gxer, gxei, gxor, gxoi)


def _net_layer_bwd(cv_inv, cv_adj, par_v, cu_inv, cu_adj, par_u, g,
                   x_in, v, u, goe, goo, dcv_ref, dcu_ref, layer):
    """Unwind one network layer: |detect| -> linear stages (see above)."""
    gz = _detect_bwd(u, g, goe, goo)
    return _layer_linear_bwd(cv_inv, cv_adj, par_v, cu_inv, cu_adj, par_u,
                             g, x_in, v, u, gz, dcv_ref, dcu_ref, layer)


def network_bwd_kernel(cv_inv_ref, cv_adj_ref, par_v_ref,
                       cu_inv_ref, cu_adj_ref, par_u_ref, gains_ref,
                       xer_ref, xei_ref, xor_ref, xoi_ref,
                       sver_ref, svei_ref, svor_ref, svoi_ref,
                       suer_ref, suei_ref, suor_ref, suoi_ref,
                       goe_ref, goo_ref,
                       dcv_ref, dcu_ref, dg_ref,
                       dxer_ref, dxei_ref, dxor_ref, dxoi_ref):
    """Unwind the whole network in one residency, layers in reverse.

    Each layer unwinds from its saved stage boundaries with the
    inverse/adjoint sweeps (no forward recompute); its *input* activation
    — needed only for the g0 gradient — is re-detected from the previous
    layer's saved post-U state (one elementwise |g2 u|).  Crossing a
    boundary keeps only the real cotangent planes — the imaginary planes
    of an inter-layer input are structurally zero.
    """
    @pl.when(pl.program_id(0) == 0)
    def _init():
        dcv_ref[...] = jnp.zeros(dcv_ref.shape, dcv_ref.dtype)
        dcu_ref[...] = jnp.zeros(dcu_ref.shape, dcu_ref.dtype)
        dg_ref[...] = jnp.zeros(dg_ref.shape, dg_ref.dtype)

    n_layers = cv_inv_ref.shape[0]
    goe, goo = goe_ref[...], goo_ref[...]
    for l in range(n_layers - 1, -1, -1):
        if l == 0:
            x_in = (xer_ref[...], xei_ref[...], xor_ref[...], xoi_ref[...])
        else:
            u_prev = (suer_ref[l - 1], suei_ref[l - 1],
                      suor_ref[l - 1], suoi_ref[l - 1])
            be, bo = _net_layer_detect(u_prev, gains_ref[l - 1])
            zero = jnp.zeros_like(be)
            x_in = (be, zero, bo, zero)
        g = gains_ref[l]
        v = (sver_ref[l], svei_ref[l], svor_ref[l], svoi_ref[l])
        u = (suer_ref[l], suei_ref[l], suor_ref[l], suoi_ref[l])
        dg, gx = _net_layer_bwd(
            cv_inv_ref[l], cv_adj_ref[l], par_v_ref[l],
            cu_inv_ref[l], cu_adj_ref[l], par_u_ref[l],
            g, x_in, v, u, goe, goo, dcv_ref, dcu_ref, l)
        dg_ref[l] = dg_ref[l] + dg
        if l > 0:
            goe, goo = gx[0], gx[2]
        else:
            dxer_ref[...] = gx[0]
            dxei_ref[...] = gx[1]
            dxor_ref[...] = gx[2]
            dxoi_ref[...] = gx[3]


def _net_coef_spec(n_layers: int, n_cols: int, p: int):
    return pl.BlockSpec((n_layers, n_cols, 8, p), lambda i: (0, 0, 0, 0))


def _net_parity_spec(n_layers: int, n_cols: int):
    return pl.BlockSpec((n_layers, n_cols, 1), lambda i: (0, 0, 0))


def _net_gains_spec(n_layers: int, p: int):
    return pl.BlockSpec((n_layers, 12, p), lambda i: (0, 0, 0))


def _net_flops_per_block(n: int, n_layers: int, n_cols: int,
                         batch_block: int) -> int:
    p = n // 2
    return 2 * n_layers * (2 * n_cols * p * 16 + 9 * n) * batch_block


def network_pallas_call(n: int, n_layers: int, n_cols: int, batch_block: int,
                        n_batch_blocks: int, interpret: bool):
    p = n // 2
    plane = pl.BlockSpec((batch_block, p), lambda i: (i, 0))
    out_shape = [jax.ShapeDtypeStruct((n_batch_blocks * batch_block, p),
                                      jnp.float32)] * 2
    flops = _net_flops_per_block(n, n_layers, n_cols, batch_block)
    return pl.pallas_call(
        network_kernel,
        grid=(n_batch_blocks,),
        in_specs=[_net_coef_spec(n_layers, n_cols, p),
                  _net_parity_spec(n_layers, n_cols),
                  _net_coef_spec(n_layers, n_cols, p),
                  _net_parity_spec(n_layers, n_cols),
                  _net_gains_spec(n_layers, p),
                  plane, plane, plane, plane],
        out_specs=[plane] * 2,
        out_shape=out_shape,
        interpret=interpret,
        cost_estimate=pl.CostEstimate(
            flops=flops * n_batch_blocks,
            bytes_accessed=(6 * batch_block * p * 4
                            + 2 * n_layers * n_cols * 8 * p * 4
                            + n_layers * 12 * p * 4) * n_batch_blocks,
            transcendentals=n_layers * batch_block * p * 2 * n_batch_blocks,
        ),
    )


def network_fwd_pallas_call(n: int, n_layers: int, n_cols: int,
                            batch_block: int, n_batch_blocks: int,
                            interpret: bool):
    p = n // 2
    plane = pl.BlockSpec((batch_block, p), lambda i: (i, 0))
    stage = pl.BlockSpec((n_layers, batch_block, p), lambda i: (0, i, 0))
    b_total = n_batch_blocks * batch_block
    out_shape = (
        [jax.ShapeDtypeStruct((b_total, p), jnp.float32)] * 2
        + [jax.ShapeDtypeStruct((n_layers, b_total, p), jnp.float32)] * 8)
    flops = _net_flops_per_block(n, n_layers, n_cols, batch_block)
    return pl.pallas_call(
        network_fwd_kernel,
        grid=(n_batch_blocks,),
        in_specs=[_net_coef_spec(n_layers, n_cols, p),
                  _net_parity_spec(n_layers, n_cols),
                  _net_coef_spec(n_layers, n_cols, p),
                  _net_parity_spec(n_layers, n_cols),
                  _net_gains_spec(n_layers, p),
                  plane, plane, plane, plane],
        out_specs=[plane, plane] + [stage] * 8,
        out_shape=out_shape,
        interpret=interpret,
        cost_estimate=pl.CostEstimate(
            flops=flops * n_batch_blocks,
            bytes_accessed=((6 + 8 * n_layers) * batch_block * p * 4
                            + 2 * n_layers * n_cols * 8 * p * 4
                            + n_layers * 12 * p * 4) * n_batch_blocks,
            transcendentals=n_layers * batch_block * p * 2 * n_batch_blocks,
        ),
    )


# ---------------------------------------------------------------------------
# Tile-grid megakernel: a (To x Ti) grid of analog tiles in one pallas_call
# ---------------------------------------------------------------------------
#
# A large (To*n) x (Ti*n) matmul as block sums over tile processors: input
# tile i sweeps through tile (r, i)'s meshes (g0 -> V -> g1 -> U -> g2, the
# same 12-row gain layout as one network layer, no |detect| — the combine
# is coherent) and the Ti complex outputs of tile row r are summed in VMEM
# (matched-line power combiner).  The readout mode (|.|, Re, complex) and
# detector noise apply *after* combination, outside the kernel.
#
# Grid is (To, batch blocks) — batch innermost: one grid step computes one
# (tile row, batch block) output panel, so a row's coefficient-gradient
# accumulators are revisited on *consecutive* steps (the same property the
# 1-D batch grid gives the other kernels).  Planes are [B, Ti, P] in /
# [B, To, P] out; coefficients/parities/gains stack to [To, Ti, C, 8, P] /
# [To, Ti, C, 1] / [To, Ti, 12, P] with identity-column padding to the
# grid-wide C (see ``repro.kernels.schedule.TileGridSchedule``).
#
# Residuals follow the per-tile rule: each tile saves its two pre-gain
# stage boundaries (post-V, post-U) into [To, Ti, B, P] planes — exactly
# the 8 planes per tile the per-tile composition would have stored — and
# the backward unwinds every tile from them with the inverse/adjoint
# sweeps.  The input cotangent is emitted as per-row partials
# [To, B, Ti, P] (each written once per grid step) and summed outside the
# kernel: dx_i = sum_r gx_{r,i}, the transpose of the row combine.


def _tile_row_fwd(coef_v_ref, par_v_ref, coef_u_ref, par_u_ref, gains_ref,
                  xer_ref, xei_ref, xor_ref, xoi_ref):
    """One tile row: sweep every input tile, combine coherently.

    Returns the combined post-g2 planes plus the per-tile (v, u) stage
    states (the VJP forward's residuals; inference discards them).
    """
    n_in = coef_v_ref.shape[1]
    acc = None
    stages = []
    for i in range(n_in):
        state = (xer_ref[:, i], xei_ref[:, i], xor_ref[:, i], xoi_ref[:, i])
        g = gains_ref[0, i]
        v, u = _net_layer_stages(coef_v_ref[0, i], par_v_ref[0, i],
                                 coef_u_ref[0, i], par_u_ref[0, i], g, state)
        stages.append((v, u))
        zer, zei = _cmul(u[0], u[1], g[8], g[9])
        zor, zoi = _cmul(u[2], u[3], g[10], g[11])
        z = (zer, zei, zor, zoi)
        acc = z if acc is None else tuple(a + b for a, b in zip(acc, z))
    return acc, stages


def tilegrid_kernel(coef_v_ref, par_v_ref, coef_u_ref, par_u_ref, gains_ref,
                    xer_ref, xei_ref, xor_ref, xoi_ref,
                    oer_ref, oei_ref, oor_ref, ooi_ref):
    """Inference: one (tile row, batch block) combined output per step."""
    acc, _ = _tile_row_fwd(coef_v_ref, par_v_ref, coef_u_ref, par_u_ref,
                           gains_ref, xer_ref, xei_ref, xor_ref, xoi_ref)
    oer_ref[:, 0], oei_ref[:, 0] = acc[0], acc[1]
    oor_ref[:, 0], ooi_ref[:, 0] = acc[2], acc[3]


def tilegrid_fwd_kernel(coef_v_ref, par_v_ref, coef_u_ref, par_u_ref,
                        gains_ref, xer_ref, xei_ref, xor_ref, xoi_ref,
                        oer_ref, oei_ref, oor_ref, ooi_ref,
                        sver_ref, svei_ref, svor_ref, svoi_ref,
                        suer_ref, suei_ref, suor_ref, suoi_ref):
    """VJP forward: identical sweep, plus every tile's two pre-gain stage
    boundaries (post-V, post-U) into [To, Ti, B, P] residual planes."""
    acc, stages = _tile_row_fwd(coef_v_ref, par_v_ref, coef_u_ref,
                                par_u_ref, gains_ref,
                                xer_ref, xei_ref, xor_ref, xoi_ref)
    for i, (v, u) in enumerate(stages):
        sver_ref[0, i], svei_ref[0, i] = v[0], v[1]
        svor_ref[0, i], svoi_ref[0, i] = v[2], v[3]
        suer_ref[0, i], suei_ref[0, i] = u[0], u[1]
        suor_ref[0, i], suoi_ref[0, i] = u[2], u[3]
    oer_ref[:, 0], oei_ref[:, 0] = acc[0], acc[1]
    oor_ref[:, 0], ooi_ref[:, 0] = acc[2], acc[3]


def tilegrid_bwd_kernel(cv_inv_ref, cv_adj_ref, par_v_ref,
                        cu_inv_ref, cu_adj_ref, par_u_ref, gains_ref,
                        xer_ref, xei_ref, xor_ref, xoi_ref,
                        sver_ref, svei_ref, svor_ref, svoi_ref,
                        suer_ref, suei_ref, suor_ref, suoi_ref,
                        goer_ref, goei_ref, goor_ref, gooi_ref,
                        dcv_ref, dcu_ref, dg_ref,
                        dxer_ref, dxei_ref, dxor_ref, dxoi_ref):
    """Unwind one tile row from the saved stage boundaries.

    The row combine is a sum, so every tile of the row sees the same
    output cotangent; each tile unwinds g2 -> U -> g1 -> V -> g0 with the
    inverse/adjoint sweeps, accumulating into its (row, tile) slot of the
    stacked coefficient/gain accumulators (revisited across the inner
    batch grid).  Input cotangents land in the per-row partial planes.
    """
    @pl.when(pl.program_id(1) == 0)
    def _init():
        dcv_ref[...] = jnp.zeros(dcv_ref.shape, dcv_ref.dtype)
        dcu_ref[...] = jnp.zeros(dcu_ref.shape, dcu_ref.dtype)
        dg_ref[...] = jnp.zeros(dg_ref.shape, dg_ref.dtype)

    gz = (goer_ref[:, 0], goei_ref[:, 0], goor_ref[:, 0], gooi_ref[:, 0])
    n_in = cv_inv_ref.shape[1]
    for i in range(n_in):
        g = gains_ref[0, i]
        x_in = (xer_ref[:, i], xei_ref[:, i], xor_ref[:, i], xoi_ref[:, i])
        v = (sver_ref[0, i], svei_ref[0, i], svor_ref[0, i], svoi_ref[0, i])
        u = (suer_ref[0, i], suei_ref[0, i], suor_ref[0, i], suoi_ref[0, i])
        dg, gx = _layer_linear_bwd(
            cv_inv_ref[0, i], cv_adj_ref[0, i], par_v_ref[0, i],
            cu_inv_ref[0, i], cu_adj_ref[0, i], par_u_ref[0, i],
            g, x_in, v, u, gz, dcv_ref, dcu_ref, (0, i))
        dg_ref[0, i] = dg_ref[0, i] + dg
        dxer_ref[0, :, i], dxei_ref[0, :, i] = gx[0], gx[1]
        dxor_ref[0, :, i], dxoi_ref[0, :, i] = gx[2], gx[3]


def _grid_coef_spec(ti: int, n_cols: int, p: int):
    return pl.BlockSpec((1, ti, n_cols, 8, p), lambda r, b: (r, 0, 0, 0, 0))


def _grid_parity_spec(ti: int, n_cols: int):
    return pl.BlockSpec((1, ti, n_cols, 1), lambda r, b: (r, 0, 0, 0))


def _grid_gains_spec(ti: int, p: int):
    return pl.BlockSpec((1, ti, 12, p), lambda r, b: (r, 0, 0, 0))


def _grid_flops_per_block(n: int, ti: int, n_cols: int,
                          batch_block: int) -> int:
    p = n // 2
    return 2 * ti * (2 * n_cols * p * 16 + 9 * n) * batch_block


def tilegrid_pallas_call(n: int, to: int, ti: int, n_cols: int,
                         batch_block: int, n_batch_blocks: int,
                         interpret: bool):
    p = n // 2
    b_total = n_batch_blocks * batch_block
    x_plane = pl.BlockSpec((batch_block, ti, p), lambda r, b: (b, 0, 0))
    o_plane = pl.BlockSpec((batch_block, 1, p), lambda r, b: (b, r, 0))
    out_shape = [jax.ShapeDtypeStruct((b_total, to, p), jnp.float32)] * 4
    flops = _grid_flops_per_block(n, ti, n_cols, batch_block)
    return pl.pallas_call(
        tilegrid_kernel,
        grid=(to, n_batch_blocks),
        in_specs=[_grid_coef_spec(ti, n_cols, p),
                  _grid_parity_spec(ti, n_cols),
                  _grid_coef_spec(ti, n_cols, p),
                  _grid_parity_spec(ti, n_cols),
                  _grid_gains_spec(ti, p),
                  x_plane, x_plane, x_plane, x_plane],
        out_specs=[o_plane] * 4,
        out_shape=out_shape,
        interpret=interpret,
        cost_estimate=pl.CostEstimate(
            flops=flops * to * n_batch_blocks,
            bytes_accessed=((4 * ti + 4) * batch_block * p * 4
                            + 2 * ti * n_cols * 8 * p * 4
                            + ti * 12 * p * 4) * to * n_batch_blocks,
            transcendentals=0,
        ),
    )


def tilegrid_fwd_pallas_call(n: int, to: int, ti: int, n_cols: int,
                             batch_block: int, n_batch_blocks: int,
                             interpret: bool):
    p = n // 2
    b_total = n_batch_blocks * batch_block
    x_plane = pl.BlockSpec((batch_block, ti, p), lambda r, b: (b, 0, 0))
    o_plane = pl.BlockSpec((batch_block, 1, p), lambda r, b: (b, r, 0))
    stage = pl.BlockSpec((1, ti, batch_block, p), lambda r, b: (r, 0, b, 0))
    out_shape = (
        [jax.ShapeDtypeStruct((b_total, to, p), jnp.float32)] * 4
        + [jax.ShapeDtypeStruct((to, ti, b_total, p), jnp.float32)] * 8)
    flops = _grid_flops_per_block(n, ti, n_cols, batch_block)
    return pl.pallas_call(
        tilegrid_fwd_kernel,
        grid=(to, n_batch_blocks),
        in_specs=[_grid_coef_spec(ti, n_cols, p),
                  _grid_parity_spec(ti, n_cols),
                  _grid_coef_spec(ti, n_cols, p),
                  _grid_parity_spec(ti, n_cols),
                  _grid_gains_spec(ti, p),
                  x_plane, x_plane, x_plane, x_plane],
        out_specs=[o_plane] * 4 + [stage] * 8,
        out_shape=out_shape,
        interpret=interpret,
        cost_estimate=pl.CostEstimate(
            flops=flops * to * n_batch_blocks,
            bytes_accessed=((12 * ti + 4) * batch_block * p * 4
                            + 2 * ti * n_cols * 8 * p * 4
                            + ti * 12 * p * 4) * to * n_batch_blocks,
            transcendentals=0,
        ),
    )


def tilegrid_bwd_pallas_call(n: int, to: int, ti: int, n_cols: int,
                             batch_block: int, n_batch_blocks: int,
                             interpret: bool):
    p = n // 2
    b_total = n_batch_blocks * batch_block
    x_plane = pl.BlockSpec((batch_block, ti, p), lambda r, b: (b, 0, 0))
    o_plane = pl.BlockSpec((batch_block, 1, p), lambda r, b: (b, r, 0))
    stage = pl.BlockSpec((1, ti, batch_block, p), lambda r, b: (r, 0, b, 0))
    dx_part = pl.BlockSpec((1, batch_block, ti, p), lambda r, b: (r, b, 0, 0))
    out_shape = (
        [jax.ShapeDtypeStruct((to, ti, n_cols, 8, p), jnp.float32)] * 2
        + [jax.ShapeDtypeStruct((to, ti, 12, p), jnp.float32)]
        + [jax.ShapeDtypeStruct((to, b_total, ti, p), jnp.float32)] * 4)
    # inverse state recompute + adjoint cotangent + coefficient grads
    flops = 3 * _grid_flops_per_block(n, ti, n_cols, batch_block)
    return pl.pallas_call(
        tilegrid_bwd_kernel,
        grid=(to, n_batch_blocks),
        in_specs=[_grid_coef_spec(ti, n_cols, p)] * 2
        + [_grid_parity_spec(ti, n_cols)]
        + [_grid_coef_spec(ti, n_cols, p)] * 2
        + [_grid_parity_spec(ti, n_cols), _grid_gains_spec(ti, p),
           x_plane, x_plane, x_plane, x_plane]
        + [stage] * 8 + [o_plane] * 4,
        out_specs=[_grid_coef_spec(ti, n_cols, p)] * 2
        + [_grid_gains_spec(ti, p)] + [dx_part] * 4,
        out_shape=out_shape,
        interpret=interpret,
        cost_estimate=pl.CostEstimate(
            flops=flops * to * n_batch_blocks,
            bytes_accessed=((16 * ti + 4) * batch_block * p * 4
                            + 6 * ti * n_cols * 8 * p * 4
                            + 2 * ti * 12 * p * 4) * to * n_batch_blocks,
            transcendentals=0,
        ),
    )


def network_bwd_pallas_call(n: int, n_layers: int, n_cols: int,
                            batch_block: int, n_batch_blocks: int,
                            interpret: bool):
    p = n // 2
    plane = pl.BlockSpec((batch_block, p), lambda i: (i, 0))
    stage = pl.BlockSpec((n_layers, batch_block, p), lambda i: (0, i, 0))
    out_shape = (
        [jax.ShapeDtypeStruct((n_layers, n_cols, 8, p), jnp.float32)] * 2
        + [jax.ShapeDtypeStruct((n_layers, 12, p), jnp.float32)]
        + [jax.ShapeDtypeStruct((n_batch_blocks * batch_block, p),
                                jnp.float32)] * 4)
    # inverse state recompute + adjoint cotangent + coefficient grads
    flops = 3 * _net_flops_per_block(n, n_layers, n_cols, batch_block)
    return pl.pallas_call(
        network_bwd_kernel,
        grid=(n_batch_blocks,),
        in_specs=[_net_coef_spec(n_layers, n_cols, p)] * 2
        + [_net_parity_spec(n_layers, n_cols)]
        + [_net_coef_spec(n_layers, n_cols, p)] * 2
        + [_net_parity_spec(n_layers, n_cols),
           _net_gains_spec(n_layers, p),
           plane, plane, plane, plane]
        + [stage] * 8 + [plane, plane],
        out_specs=[_net_coef_spec(n_layers, n_cols, p)] * 2
        + [_net_gains_spec(n_layers, p)] + [plane] * 4,
        out_shape=out_shape,
        interpret=interpret,
        cost_estimate=pl.CostEstimate(
            flops=flops * n_batch_blocks,
            bytes_accessed=((10 + 8 * n_layers) * batch_block * p * 4
                            + 6 * n_layers * n_cols * 8 * p * 4
                            + 2 * n_layers * 12 * p * 4) * n_batch_blocks,
            transcendentals=n_layers * batch_block * p * 2 * n_batch_blocks,
        ),
    )
