"""Static kernel schedules for arbitrary adjacent-pair mesh layouts.

The Pallas mesh kernels operate on de-interleaved even/odd channel planes,
so a kernel column can only pair channels in one of two ways:

  * parity 0 — ``(2i, 2i+1)``: pair slot ``i`` rotates ``(even_i, odd_i)``;
  * parity 1 — ``(2i+1, 2i+2)``: slot ``i`` rotates ``(odd_i, even_{i+1})``
    (the wrap slot ``P-1`` never holds a cell).

A :class:`repro.core.mesh.MeshPlan` column, however, may mix both parities
(``pack_cells_to_columns`` packs greedily — e.g. Reck programs from the
analytic synthesizer).  :func:`schedule_from_plan` re-schedules any plan
into parity-homogeneous kernel columns: each plan column splits into at
most one parity-0 and one parity-1 sub-column (exact, because cells within
a plan column never overlap and cells of different parity in the same
column therefore commute).  The rectangular Clements layout maps 1:1 —
its columns are already parity-pure and alternate 0/1 — so the ideal path
is the degenerate case and pays nothing for the generality.

The resulting :class:`MeshSchedule` is a hashable, purely static object
(tuples of ints), usable as a jit/static and ``custom_vjp`` nondiff
argument; :func:`pack_cells` is the differentiable bridge that gathers
per-cell 2x2 transfer matrices (ideal *or* hardware-imperfect) into the
kernels' ``[C', 8, P]`` coefficient layout.
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mesh as mesh_lib

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class MeshSchedule:
    """Parity-homogeneous column schedule of an adjacent-pair mesh.

    Attributes:
      n: number of channels (even).
      parity: per kernel column, 0 (pairs ``(2i, 2i+1)``) or 1
        (pairs ``(2i+1, 2i+2)``).
      source: per kernel column, ``n//2`` entries mapping each kernel pair
        slot to a flat plan-cell index ``col * P + slot`` (or -1 for an
        identity slot).
    """

    n: int
    parity: tuple[int, ...]
    source: tuple[tuple[int, ...], ...]

    @property
    def n_columns(self) -> int:
        return len(self.parity)

    @property
    def pairs(self) -> int:
        return self.n // 2


# Bounded memoization (plans hash by content, see MeshPlan.__hash__):
# dynamically synthesized Reck programs mint a fresh plan object per
# reprogramming, and each distinct schedule is also a distinct jit static —
# returning the *same* MeshSchedule for equal plans keeps repeated
# ``mesh_apply(plan=...)`` calls from rebuilding parity tensors or
# re-triggering jit trace-cache misses, while the LRU bound keeps a
# long-lived sweep over many target matrices from accumulating schedules.
@functools.lru_cache(maxsize=128)
def schedule_from_plan(plan: mesh_lib.MeshPlan) -> MeshSchedule:
    """Re-schedule an arbitrary MeshPlan into kernel parity columns."""
    pk = plan.n // 2
    parity: list[int] = []
    source: list[tuple[int, ...]] = []
    for c in range(plan.n_columns):
        for par in (0, 1):
            row = [-1] * pk
            found = False
            for s in range(plan.pairs_per_column):
                if not plan.active[c, s]:
                    continue
                p = int(plan.top[c, s])
                if p % 2 != par:
                    continue
                row[p // 2] = c * plan.pairs_per_column + s
                found = True
            if found:
                parity.append(par)
                source.append(tuple(row))
    if not parity:  # cell-free mesh: one identity column keeps shapes valid
        parity = [0]
        source = [tuple([-1] * pk)]
    return MeshSchedule(n=plan.n, parity=tuple(parity), source=tuple(source))


def clements_schedule(n: int) -> MeshSchedule:
    """The rectangular Clements schedule (1:1 with its plan columns)."""
    return schedule_from_plan(mesh_lib.clements_plan(n))


@functools.lru_cache(maxsize=256)
def _parity_np(sched: MeshSchedule) -> np.ndarray:
    # cache the *numpy* array: jnp conversion must happen per trace (a
    # jnp constant built inside a jit trace is a trace-local tracer)
    return np.asarray(sched.parity, np.int32).reshape(-1, 1)


def parity_array(sched: MeshSchedule) -> Array:
    """The per-column parity as the kernels' ``[C', 1]`` int32 input."""
    return jnp.asarray(_parity_np(sched))


@functools.lru_cache(maxsize=256)
def _pack_indices(sched: MeshSchedule, c: int, p: int) -> np.ndarray:
    """Memoized gather map for :func:`pack_cells` (host work per schedule,
    not per call/trace): flat plan-cell index per kernel slot, with -1
    redirected to the appended identity cell at ``c * p``."""
    idx = np.asarray(sched.source, np.int64)
    return np.where(idx < 0, c * p, idx)


def pack_cells(sched: MeshSchedule, t_all: Array) -> Array:
    """Gather per-cell 2x2 matrices into kernel coefficients ``[C', 8, P]``.

    ``t_all``: complex ``[..., C, P, 2, 2]`` cell transfer matrices in plan
    layout (ideal :func:`repro.core.cell.cell_matrix` or the hardware
    model's :func:`repro.core.hardware.imperfect_cell_matrix`).  Inactive
    plan slots are never referenced by the schedule, so parked parameters
    cannot leak in; identity fills the unused kernel slots.  Differentiable
    (a gather), and batch dims vmap through.
    """
    c, p = t_all.shape[-4], t_all.shape[-3]
    if p != sched.pairs:
        raise ValueError(
            f"cell tensor has {p} pair slots per column, schedule expects "
            f"{sched.pairs} (n={sched.n})")
    max_src = max((s for row in sched.source for s in row), default=-1)
    if max_src >= c * p:
        raise ValueError(
            f"schedule references cell {max_src} but tensor holds only "
            f"{c * p} — t_all built from a different plan?")
    lead = t_all.shape[:-4]
    flat = t_all.reshape(lead + (c * p, 2, 2)).astype(jnp.complex64)
    eye = jnp.broadcast_to(jnp.eye(2, dtype=jnp.complex64),
                           lead + (1, 2, 2))
    flat = jnp.concatenate([flat, eye], axis=-3)
    idx = _pack_indices(sched, c, p)  # -1 -> the appended identity
    cells = jnp.take(flat, jnp.asarray(idx), axis=-3)  # [..., C', P, 2, 2]
    coef = jnp.stack(
        [jnp.real(cells[..., 0, 0]), jnp.imag(cells[..., 0, 0]),
         jnp.real(cells[..., 0, 1]), jnp.imag(cells[..., 0, 1]),
         jnp.real(cells[..., 1, 0]), jnp.imag(cells[..., 1, 0]),
         jnp.real(cells[..., 1, 1]), jnp.imag(cells[..., 1, 1])],
        axis=-2,
    )  # [..., C', 8, P]
    return coef.astype(jnp.float32)


# ---------------------------------------------------------------------------
# Deep-grid schedules: L layers of (To x Ti) grids of (V, U) schedules for
# the deep tiled-network megakernel — the general form; network (L x 1 x 1)
# and tile-grid (1 x To x Ti) schedules are its degenerate cases.
# ---------------------------------------------------------------------------

#: Coefficient rows of an identity 2x2 cell (t00 = t11 = 1): the padding
#: column appended to short layers so every layer shares one column count.
_IDENTITY_ROWS = (1.0, 0.0, 0.0, 0.0, 0.0, 0.0, 1.0, 0.0)


@dataclasses.dataclass(frozen=True)
class DeepGridSchedule:
    """Static schedule of an L-layer network of (To x Ti) tile grids.

    ``layers[l][o][i]`` is the ``(V, U)`` pair of :class:`MeshSchedule`\\ s
    of tile ``(o, i)`` in layer ``l``.  The deep megakernel runs the whole
    network in one VMEM residency with coefficient/parity/gain tensors
    stacked to ``[L, To, Ti, C, 8, P]`` / ``[L, To, Ti, C, 1]`` /
    ``[L, To, Ti, 12, P]``, where ``C = n_columns`` is the max column
    count over every mesh in the network (shorter meshes pad with
    identity columns — exact no-ops in the sweep).  Between layers the
    kernel re-detects the combined row outputs in VMEM, so layer ``l``'s
    ``To`` rows feed layer ``l+1``'s ``Ti`` input tiles without touching
    HBM; chaining under one uniform stacked tensor therefore requires
    ``To == Ti`` whenever ``L > 1``.  Hashable and purely static — a
    jit/static and ``custom_vjp`` nondiff argument like
    :class:`MeshSchedule`.
    """

    layers: tuple[tuple[tuple[tuple[MeshSchedule, MeshSchedule], ...],
                        ...], ...]

    def __post_init__(self):
        if not self.layers:
            raise ValueError("deep grid schedule needs at least one layer")
        to = len(self.layers[0])
        if not to or not self.layers[0][0]:
            raise ValueError("deep grid needs at least one tile")
        ti = len(self.layers[0][0])
        n = self.layers[0][0][0][0].n
        for grid in self.layers:
            if len(grid) != to or any(len(row) != ti for row in grid):
                raise ValueError(
                    "every layer's tile grid must be the same rectangular "
                    f"{to}x{ti} shape")
            for row in grid:
                for sv, su in row:
                    if sv.n != n or su.n != n:
                        raise ValueError(
                            f"all tile meshes must share n={n}, got "
                            f"({sv.n}, {su.n})")
        if len(self.layers) > 1 and to != ti:
            raise ValueError(
                f"a deep ({len(self.layers)}-layer) grid chains each "
                f"layer's To={to} row outputs into the next layer's "
                f"Ti={ti} input tiles, so To must equal Ti")

    @property
    def n(self) -> int:
        return self.layers[0][0][0][0].n

    @property
    def pairs(self) -> int:
        return self.n // 2

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    @property
    def to(self) -> int:
        return len(self.layers[0])

    @property
    def ti(self) -> int:
        return len(self.layers[0][0])

    @property
    def n_columns(self) -> int:
        return max(max(sv.n_columns, su.n_columns)
                   for grid in self.layers for row in grid for sv, su in row)

    def layer(self, l: int) -> "DeepGridSchedule":
        """The single-layer (1 x To x Ti) schedule of layer ``l`` — the
        row-sharded deep path runs one such slice per pallas call."""
        return DeepGridSchedule(layers=(self.layers[l],))


def deep_grid_schedule(n: int, depth: int, to: int, ti: int,
                       plans=None) -> DeepGridSchedule:
    """Build a DeepGridSchedule: ``depth`` layers of (to x ti) tile grids.

    ``plans``: optional ``[depth][to][ti]`` nested sequence of per-tile
    ``(v_plan, u_plan)`` pairs (``None`` entries fall back to the Clements
    rectangle); ``None`` uses Clements everywhere — the trainable default.
    """
    if plans is None:
        plans = (((None,) * ti,) * to,) * depth
    if len(plans) != depth:
        raise ValueError(f"{len(plans)} plan grids for depth {depth}")
    layers = []
    for lgrid in plans:
        if lgrid is None:
            lgrid = ((None,) * ti,) * to
        if len(lgrid) != to or any(len(row) != ti for row in lgrid):
            raise ValueError(f"each layer's plans grid must be {to}x{ti}")
        rows = []
        for prow in lgrid:
            row = []
            for pair in prow:
                v_plan, u_plan = (None, None) if pair is None else pair
                sv = (clements_schedule(n) if v_plan is None
                      else schedule_from_plan(v_plan))
                su = (clements_schedule(n) if u_plan is None
                      else schedule_from_plan(u_plan))
                row.append((sv, su))
            rows.append(tuple(row))
        layers.append(tuple(rows))
    return DeepGridSchedule(layers=tuple(layers))


@functools.lru_cache(maxsize=64)
def _deep_grid_parity_np(deep: DeepGridSchedule) -> tuple[np.ndarray,
                                                          np.ndarray]:
    c = deep.n_columns
    shape = (deep.n_layers, deep.to, deep.ti, c, 1)
    pv = np.zeros(shape, np.int32)
    pu = np.zeros(shape, np.int32)
    for l, grid in enumerate(deep.layers):
        for o, row in enumerate(grid):
            for i, (sv, su) in enumerate(row):
                pv[l, o, i, : sv.n_columns, 0] = sv.parity
                pu[l, o, i, : su.n_columns, 0] = su.parity
    return pv, pu


def deep_grid_parity_arrays(deep: DeepGridSchedule) -> tuple[Array, Array]:
    """Stacked ``[L, To, Ti, C, 1]`` int32 parity inputs for the V/U meshes.

    Identity-padded columns get parity 0 (their coefficient is the
    identity cell, so the pairing is irrelevant).  Host-side build is
    memoized per schedule (numpy, nothing trace-local cached), keyed by
    content — structurally equal deep grids share it.
    """
    pv, pu = _deep_grid_parity_np(deep)
    return jnp.asarray(pv), jnp.asarray(pu)


@dataclasses.dataclass(frozen=True)
class NetworkSchedule:
    """Static schedule of an L-layer RFNN for the fused network kernel.

    Each layer is a ``(V, U)`` pair of :class:`MeshSchedule`\\ s over the
    same channel count; the kernel runs all layers in one VMEM residency
    with coefficient/parity tensors stacked to ``[L, C, 8, P]`` /
    ``[L, C, 1]``, where ``C = n_columns`` is the max column count over
    every mesh (shorter meshes are padded with identity columns, which the
    sweep applies as exact no-ops).  Hashable and purely static, so it is
    a jit/static and ``custom_vjp`` nondiff argument like
    :class:`MeshSchedule`.
    """

    layers: tuple[tuple[MeshSchedule, MeshSchedule], ...]

    def __post_init__(self):
        if not self.layers:
            raise ValueError("network schedule needs at least one layer")
        n = self.layers[0][0].n
        for sv, su in self.layers:
            if sv.n != n or su.n != n:
                raise ValueError(
                    f"all layer meshes must share n={n}, got "
                    f"{[(sv.n, su.n) for sv, su in self.layers]}")

    @property
    def n(self) -> int:
        return self.layers[0][0].n

    @property
    def pairs(self) -> int:
        return self.n // 2

    @property
    def n_layers(self) -> int:
        return len(self.layers)

    @property
    def n_columns(self) -> int:
        return max(max(sv.n_columns, su.n_columns) for sv, su in self.layers)

    @property
    def deep(self) -> DeepGridSchedule:
        """The equivalent L x 1 x 1 :class:`DeepGridSchedule` — the form
        the deep megakernel actually consumes."""
        return DeepGridSchedule(
            layers=tuple((((sv, su),),) for sv, su in self.layers))


def network_schedule(n: int, depth: int,
                     plans=None) -> NetworkSchedule:
    """Build a NetworkSchedule for ``depth`` layers of n-channel meshes.

    ``plans``: optional per-layer ``(v_plan, u_plan)`` pairs (``None``
    entries fall back to the Clements rectangle); ``None`` uses Clements
    everywhere — the trainable default.
    """
    if plans is None:
        plans = ((None, None),) * depth
    if len(plans) != depth:
        raise ValueError(f"{len(plans)} plan pairs for depth {depth}")
    layers = []
    for v_plan, u_plan in plans:
        sv = (clements_schedule(n) if v_plan is None
              else schedule_from_plan(v_plan))
        su = (clements_schedule(n) if u_plan is None
              else schedule_from_plan(u_plan))
        layers.append((sv, su))
    return NetworkSchedule(layers=tuple(layers))


@functools.lru_cache(maxsize=64)
def _network_parity_np(net: NetworkSchedule) -> tuple[np.ndarray, np.ndarray]:
    c = net.n_columns
    pv = np.zeros((net.n_layers, c, 1), np.int32)
    pu = np.zeros((net.n_layers, c, 1), np.int32)
    for l, (sv, su) in enumerate(net.layers):
        pv[l, : sv.n_columns, 0] = sv.parity
        pu[l, : su.n_columns, 0] = su.parity
    return pv, pu


def network_parity_arrays(net: NetworkSchedule) -> tuple[Array, Array]:
    """Stacked ``[L, C, 1]`` int32 parity inputs for the V and U meshes.

    Identity-padded columns get parity 0 (the padding coefficient is the
    identity cell, so the pairing is irrelevant).  The host-side build is
    memoized per schedule (numpy, so nothing trace-local is cached):
    steady-state steps rebuild nothing host-side.
    """
    pv, pu = _network_parity_np(net)
    return jnp.asarray(pv), jnp.asarray(pu)


# ---------------------------------------------------------------------------
# Tile-grid schedules: a (To x Ti) grid of per-tile (V, U) schedules for the
# tile-grid megakernel (one pallas_call for a large blocked matmul)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class TileGridSchedule:
    """Static schedule of a (To x Ti) grid of analog tile processors.

    Each grid entry is a ``(V, U)`` pair of :class:`MeshSchedule`\\ s over
    the tile channel count ``n`` — one tile realizes one ``n x n`` block
    of a large matrix in SVD mesh form (V-mesh -> diag -> U-mesh -> digital
    scale).  The tile-grid kernel runs an entire tile *row* per grid step:
    every input tile is swept through its meshes and the row's outputs are
    coherently summed in VMEM (the matched-line power-combiner), so a
    ``(To*n) x (Ti*n)`` matmul is one ``pallas_call`` instead of ``To*Ti``
    separate mesh applications.  Coefficient/parity tensors are stacked to
    ``[To, Ti, C, 8, P]`` / ``[To, Ti, C, 1]`` with ``C`` the max column
    count over every mesh in the grid (identity-column padding, exact
    no-ops in the sweep).  Hashable and purely static — a jit/static and
    ``custom_vjp`` nondiff argument like :class:`NetworkSchedule`.
    """

    tiles: tuple[tuple[tuple[MeshSchedule, MeshSchedule], ...], ...]

    def __post_init__(self):
        if not self.tiles or not self.tiles[0]:
            raise ValueError("tile grid needs at least one tile")
        ti = len(self.tiles[0])
        if any(len(row) != ti for row in self.tiles):
            raise ValueError("tile grid must be rectangular")
        n = self.tiles[0][0][0].n
        for row in self.tiles:
            for sv, su in row:
                if sv.n != n or su.n != n:
                    raise ValueError(
                        f"all tile meshes must share n={n}, got "
                        f"({sv.n}, {su.n})")

    @property
    def n(self) -> int:
        return self.tiles[0][0][0].n

    @property
    def pairs(self) -> int:
        return self.n // 2

    @property
    def to(self) -> int:
        return len(self.tiles)

    @property
    def ti(self) -> int:
        return len(self.tiles[0])

    @property
    def n_columns(self) -> int:
        return max(max(sv.n_columns, su.n_columns)
                   for row in self.tiles for sv, su in row)

    @property
    def deep(self) -> DeepGridSchedule:
        """The equivalent 1 x To x Ti :class:`DeepGridSchedule` — the form
        the deep megakernel actually consumes."""
        return DeepGridSchedule(layers=(self.tiles,))


def tile_grid_schedule(n: int, to: int, ti: int,
                       plans=None) -> TileGridSchedule:
    """Build a TileGridSchedule for a (to x ti) grid of n-channel tiles.

    ``plans``: optional ``[to][ti]`` nested sequence of per-tile
    ``(v_plan, u_plan)`` pairs (``None`` entries fall back to the Clements
    rectangle); ``None`` uses Clements everywhere — the trainable default.
    Per-tile Reck programs (the compiled per-tile-SVD path) mix freely with
    Clements tiles; shorter meshes pad with identity columns.
    """
    if plans is None:
        plans = ((None,) * ti,) * to
    if len(plans) != to or any(len(row) != ti for row in plans):
        raise ValueError(f"plans grid must be {to}x{ti}")
    rows = []
    for prow in plans:
        row = []
        for pair in prow:
            v_plan, u_plan = (None, None) if pair is None else pair
            sv = (clements_schedule(n) if v_plan is None
                  else schedule_from_plan(v_plan))
            su = (clements_schedule(n) if u_plan is None
                  else schedule_from_plan(u_plan))
            row.append((sv, su))
        rows.append(tuple(row))
    return TileGridSchedule(tiles=tuple(rows))


@functools.lru_cache(maxsize=64)
def _tile_grid_parity_np(grid: TileGridSchedule) -> tuple[np.ndarray,
                                                          np.ndarray]:
    c = grid.n_columns
    pv = np.zeros((grid.to, grid.ti, c, 1), np.int32)
    pu = np.zeros((grid.to, grid.ti, c, 1), np.int32)
    for o, row in enumerate(grid.tiles):
        for i, (sv, su) in enumerate(row):
            pv[o, i, : sv.n_columns, 0] = sv.parity
            pu[o, i, : su.n_columns, 0] = su.parity
    return pv, pu


def tile_grid_parity_arrays(grid: TileGridSchedule) -> tuple[Array, Array]:
    """Stacked ``[To, Ti, C, 1]`` int32 parity inputs for the V/U meshes.

    Identity-padded columns get parity 0 (their coefficient is the
    identity cell, so the pairing is irrelevant).  Host-side build is
    memoized per schedule (numpy, nothing trace-local cached), keyed by
    content like the network variant — structurally equal grids share it.
    """
    pv, pu = _tile_grid_parity_np(grid)
    return jnp.asarray(pv), jnp.asarray(pu)


def pad_columns(coef: Array, n_columns: int) -> Array:
    """Pad ``[..., C, 8, P]`` coefficients to ``n_columns`` with identity
    cells (exact no-op columns in the sweep)."""
    c = coef.shape[-3]
    if c > n_columns:
        raise ValueError(f"coefficients have {c} columns > pad {n_columns}")
    if c == n_columns:
        return coef
    p = coef.shape[-1]
    rows = jnp.asarray(_IDENTITY_ROWS, coef.dtype)[:, None]  # [8, 1]
    ident = jnp.broadcast_to(rows, coef.shape[:-3] + (n_columns - c, 8, p))
    return jnp.concatenate([coef, ident], axis=-3)
