"""Static kernel schedules for arbitrary adjacent-pair mesh layouts.

The Pallas mesh kernels operate on de-interleaved even/odd channel planes,
so a kernel column can only pair channels in one of two ways:

  * parity 0 — ``(2i, 2i+1)``: pair slot ``i`` rotates ``(even_i, odd_i)``;
  * parity 1 — ``(2i+1, 2i+2)``: slot ``i`` rotates ``(odd_i, even_{i+1})``
    (the wrap slot ``P-1`` never holds a cell).

A :class:`repro.core.mesh.MeshPlan` column, however, may mix both parities
(``pack_cells_to_columns`` packs greedily — e.g. Reck programs from the
analytic synthesizer).  :func:`schedule_from_plan` re-schedules any plan
into parity-homogeneous kernel columns: each plan column splits into at
most one parity-0 and one parity-1 sub-column (exact, because cells within
a plan column never overlap and cells of different parity in the same
column therefore commute).  The rectangular Clements layout maps 1:1 —
its columns are already parity-pure and alternate 0/1 — so the ideal path
is the degenerate case and pays nothing for the generality.

The resulting :class:`MeshSchedule` is a hashable, purely static object
(tuples of ints), usable as a jit/static and ``custom_vjp`` nondiff
argument; :func:`pack_cells` is the differentiable bridge that gathers
per-cell 2x2 transfer matrices (ideal *or* hardware-imperfect) into the
kernels' ``[C', 8, P]`` coefficient layout.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mesh as mesh_lib

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class MeshSchedule:
    """Parity-homogeneous column schedule of an adjacent-pair mesh.

    Attributes:
      n: number of channels (even).
      parity: per kernel column, 0 (pairs ``(2i, 2i+1)``) or 1
        (pairs ``(2i+1, 2i+2)``).
      source: per kernel column, ``n//2`` entries mapping each kernel pair
        slot to a flat plan-cell index ``col * P + slot`` (or -1 for an
        identity slot).
    """

    n: int
    parity: tuple[int, ...]
    source: tuple[tuple[int, ...], ...]

    @property
    def n_columns(self) -> int:
        return len(self.parity)

    @property
    def pairs(self) -> int:
        return self.n // 2


# Bounded: dynamically synthesized Reck programs mint a fresh plan per
# reprogramming, and each distinct schedule is also a distinct jit static —
# evicting oldest keeps a long-lived sweep over many target matrices from
# accumulating schedules without bound.
_SCHEDULE_CACHE: dict[tuple, MeshSchedule] = {}
_SCHEDULE_CACHE_MAX = 128


def schedule_from_plan(plan: mesh_lib.MeshPlan) -> MeshSchedule:
    """Re-schedule an arbitrary MeshPlan into kernel parity columns."""
    key = (plan.n, plan.top.tobytes(), plan.active.tobytes())
    hit = _SCHEDULE_CACHE.get(key)
    if hit is not None:
        return hit

    pk = plan.n // 2
    parity: list[int] = []
    source: list[tuple[int, ...]] = []
    for c in range(plan.n_columns):
        for par in (0, 1):
            row = [-1] * pk
            found = False
            for s in range(plan.pairs_per_column):
                if not plan.active[c, s]:
                    continue
                p = int(plan.top[c, s])
                if p % 2 != par:
                    continue
                row[p // 2] = c * plan.pairs_per_column + s
                found = True
            if found:
                parity.append(par)
                source.append(tuple(row))
    if not parity:  # cell-free mesh: one identity column keeps shapes valid
        parity = [0]
        source = [tuple([-1] * pk)]
    sched = MeshSchedule(n=plan.n, parity=tuple(parity), source=tuple(source))
    while len(_SCHEDULE_CACHE) >= _SCHEDULE_CACHE_MAX:
        _SCHEDULE_CACHE.pop(next(iter(_SCHEDULE_CACHE)))
    _SCHEDULE_CACHE[key] = sched
    return sched


def clements_schedule(n: int) -> MeshSchedule:
    """The rectangular Clements schedule (1:1 with its plan columns)."""
    return schedule_from_plan(mesh_lib.clements_plan(n))


def parity_array(sched: MeshSchedule) -> Array:
    """The per-column parity as the kernels' ``[C', 1]`` int32 input."""
    return jnp.asarray(sched.parity, jnp.int32).reshape(-1, 1)


def pack_cells(sched: MeshSchedule, t_all: Array) -> Array:
    """Gather per-cell 2x2 matrices into kernel coefficients ``[C', 8, P]``.

    ``t_all``: complex ``[..., C, P, 2, 2]`` cell transfer matrices in plan
    layout (ideal :func:`repro.core.cell.cell_matrix` or the hardware
    model's :func:`repro.core.hardware.imperfect_cell_matrix`).  Inactive
    plan slots are never referenced by the schedule, so parked parameters
    cannot leak in; identity fills the unused kernel slots.  Differentiable
    (a gather), and batch dims vmap through.
    """
    c, p = t_all.shape[-4], t_all.shape[-3]
    if p != sched.pairs:
        raise ValueError(
            f"cell tensor has {p} pair slots per column, schedule expects "
            f"{sched.pairs} (n={sched.n})")
    max_src = max((s for row in sched.source for s in row), default=-1)
    if max_src >= c * p:
        raise ValueError(
            f"schedule references cell {max_src} but tensor holds only "
            f"{c * p} — t_all built from a different plan?")
    lead = t_all.shape[:-4]
    flat = t_all.reshape(lead + (c * p, 2, 2)).astype(jnp.complex64)
    eye = jnp.broadcast_to(jnp.eye(2, dtype=jnp.complex64),
                           lead + (1, 2, 2))
    flat = jnp.concatenate([flat, eye], axis=-3)
    idx = np.asarray(sched.source, np.int64)
    idx = np.where(idx < 0, c * p, idx)  # -1 -> the appended identity
    cells = jnp.take(flat, jnp.asarray(idx), axis=-3)  # [..., C', P, 2, 2]
    coef = jnp.stack(
        [jnp.real(cells[..., 0, 0]), jnp.imag(cells[..., 0, 0]),
         jnp.real(cells[..., 0, 1]), jnp.imag(cells[..., 0, 1]),
         jnp.real(cells[..., 1, 0]), jnp.imag(cells[..., 1, 0]),
         jnp.real(cells[..., 1, 1]), jnp.imag(cells[..., 1, 1])],
        axis=-2,
    )  # [..., C', 8, P]
    return coef.astype(jnp.float32)
