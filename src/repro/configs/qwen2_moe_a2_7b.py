"""qwen2-moe-a2.7b [moe] — 4 shared + 60 routed top-4
[hf:Qwen/Qwen1.5-MoE-A2.7B; hf].

24L d_model=2048 16H (GQA kv=16) vocab=151936; per-expert FFN 1408, top-4 of
60 routed experts (padded to 64 for even EP sharding; the 4 pad experts are
masked out of routing) + 4 shared experts of 1408 each (= the HF
shared_expert_intermediate_size of 5632 in aggregate).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    head_dim=128,
    d_ff=5632,                 # unused: every layer is MoE (interleave=1)
    vocab_size=151936,
    n_experts=64,
    n_experts_active=60,
    top_k=4,
    d_ff_expert=1408,
    n_shared_experts=4,
    d_ff_shared=1408,
    moe_interleave=1,
    capacity_factor=1.25,
)

REDUCED = ModelConfig(
    name="qwen2-moe-a2.7b-reduced",
    family="moe",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=4,
    head_dim=32,
    d_ff=128,
    vocab_size=512,
    n_experts=8,
    n_experts_active=6,
    top_k=4,
    d_ff_expert=32,
    n_shared_experts=2,
    d_ff_shared=32,
    moe_interleave=1,
    attn_chunk=32,
)
