"""whisper-large-v3 [audio] — enc-dec, conv frontend stub
[arXiv:2212.04356; unverified].

32 encoder + 32 decoder layers, d_model=1280 20H (MHA kv=20) d_ff=5120
vocab=51866.  The mel/conv frontend is a stub: ``input_specs`` supplies
1500 precomputed frame embeddings to the encoder.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="whisper-large-v3",
    family="encdec",
    n_layers=32,               # decoder layers
    n_enc_layers=32,
    d_model=1280,
    n_heads=20,
    n_kv_heads=20,
    head_dim=64,
    d_ff=5120,
    mlp_variant="gelu",
    vocab_size=51866,
    enc_seq=1500,
    tie_embeddings=True,  # whisper ties decoder embed and output proj
)

REDUCED = ModelConfig(
    name="whisper-large-v3-reduced",
    family="encdec",
    n_layers=2,
    n_enc_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    enc_seq=24,
    attn_chunk=32,
)
