"""zamba2-1.2b [hybrid] — Mamba2 backbone + shared attention blocks
[arXiv:2411.15242; hf].

38L d_model=2048 32H (kv=32) d_ff=8192, ssm_state=64.  A single shared
attention+MLP block (weights tied) is invoked after every 2 mamba layers.
Runs long_500k (state decode + data-sharded KV for the shared blocks).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-1.2b",
    family="hybrid",
    n_layers=38,
    d_model=2048,
    n_heads=32,
    n_kv_heads=32,
    head_dim=64,
    d_ff=8192,
    vocab_size=32000,
    ssm_state=64,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_chunk=128,
    attn_every=2,
)

REDUCED = ModelConfig(
    name="zamba2-1.2b-reduced",
    family="hybrid",
    n_layers=4,
    d_model=64,
    n_heads=4,
    n_kv_heads=4,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    ssm_state=16,
    ssm_headdim=16,
    ssm_chunk=8,
    attn_every=2,
    attn_chunk=32,
)
