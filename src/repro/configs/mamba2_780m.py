"""mamba2-780m [ssm] — SSD, attention-free [arXiv:2405.21060; unverified].

48L d_model=1536, d_state=128, headdim=64, expand=2 (d_inner=3072, 48 ssm
heads), vocab=50280.  Runs long_500k (O(1)-state decode).
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-780m",
    family="ssm",
    n_layers=48,
    d_model=1536,
    n_heads=1,                 # unused (attention-free)
    n_kv_heads=1,
    head_dim=64,
    d_ff=8,                    # unused
    vocab_size=50280,
    ssm_state=128,
    ssm_headdim=64,
    ssm_expand=2,
    ssm_chunk=128,
)

REDUCED = ModelConfig(
    name="mamba2-780m-reduced",
    family="ssm",
    n_layers=2,
    d_model=64,
    n_heads=1,
    n_kv_heads=1,
    head_dim=16,
    d_ff=8,
    vocab_size=512,
    ssm_state=16,
    ssm_headdim=16,
    ssm_chunk=8,
)
