"""Architecture registry: ``--arch <id>`` -> ModelConfig, plus shape grid.

Ten assigned architectures (full + reduced smoke configs) and the four
assigned input shapes.  ``long_500k`` requires sub-quadratic attention and
runs only for the SSM/hybrid archs (see DESIGN.md shape-grid skips).
"""

from __future__ import annotations

import dataclasses

from repro.configs import (
    gemma_2b,
    granite_3_2b,
    internvl2_2b,
    llama3_2_3b,
    llama4_maverick_400b_a17b,
    mamba2_780m,
    qwen2_moe_a2_7b,
    tinyllama_1_1b,
    whisper_large_v3,
    zamba2_1_2b,
)
from repro.models.config import ModelConfig

_MODULES = {
    "internvl2-2b": internvl2_2b,
    "llama4-maverick-400b-a17b": llama4_maverick_400b_a17b,
    "qwen2-moe-a2.7b": qwen2_moe_a2_7b,
    "mamba2-780m": mamba2_780m,
    "whisper-large-v3": whisper_large_v3,
    "zamba2-1.2b": zamba2_1_2b,
    "granite-3-2b": granite_3_2b,
    "llama3.2-3b": llama3_2_3b,
    "gemma-2b": gemma_2b,
    "tinyllama-1.1b": tinyllama_1_1b,
}


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES: dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524288, 1, "decode"),
}

#: archs whose attention is sub-quadratic-capable (SSM state / hybrid).
LONG_CONTEXT_ARCHS = ("mamba2-780m", "zamba2-1.2b")


def list_archs() -> list[str]:
    return sorted(_MODULES)


def get_config(name: str) -> ModelConfig:
    try:
        return _MODULES[name].CONFIG
    except KeyError as e:
        raise KeyError(f"unknown arch {name!r}; have {list_archs()}") from e


def get_reduced(name: str) -> ModelConfig:
    return _MODULES[name].REDUCED


def shapes_for(name: str) -> list[str]:
    """The live shape cells for an arch (documented skips applied)."""
    get_config(name)  # raises on unknown arch
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if name in LONG_CONTEXT_ARCHS:
        shapes.append("long_500k")
    return shapes


def grid() -> list[tuple[str, str]]:
    """All live (arch, shape) cells."""
    return [(a, s) for a in list_archs() for s in shapes_for(a)]
