"""internvl2-2b [vlm] — InternViT + InternLM2 backbone [arXiv:2404.16821; hf].

24L d_model=2048 16H (GQA kv=8) d_ff=8192 vocab=92553.  The ViT frontend is
a stub per the brief: ``input_specs`` supplies precomputed patch embeddings
(256 tokens) prepended to the text stream.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-2b",
    family="vlm",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=92553,
    n_vis_tokens=256,
    rope_theta=1_000_000.0,
)

REDUCED = ModelConfig(
    name="internvl2-2b-reduced",
    family="vlm",
    n_layers=2,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    n_vis_tokens=8,
    attn_chunk=32,
)
