"""gemma-2b [dense] — GeGLU, head_dim=256, MQA [arXiv:2403.08295; hf].

18L d_model=2048 8H (MQA kv=1) d_ff=16384 vocab=256000; embeddings scaled by
sqrt(d_model) and tied with the output projection.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma-2b",
    family="dense",
    n_layers=18,
    d_model=2048,
    n_heads=8,
    n_kv_heads=1,
    head_dim=256,
    d_ff=16384,
    vocab_size=256000,
    mlp_variant="geglu",
    embed_scale=True,
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="gemma-2b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=1,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    mlp_variant="geglu",
    embed_scale=True,
    tie_embeddings=True,
    attn_chunk=32,
)
