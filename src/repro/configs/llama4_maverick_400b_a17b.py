"""llama4-maverick-400b-a17b [moe] — MoE with early fusion
[hf:meta-llama/Llama-4-Scout-17B-16E; unverified].

48L d_model=5120 40H (GQA kv=8) d_ff=8192 vocab=202048; MoE 128 routed
experts top-1 + 1 shared expert, interleaved every 2nd layer
(interleave_moe_layer_step=2 on the HF config), which lands the total at
~400B params with ~17B active — matching the name.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    n_layers=48,
    d_model=5120,
    n_heads=40,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,                 # dense (non-MoE) layers
    vocab_size=202048,
    n_experts=128,
    top_k=1,
    d_ff_expert=8192,
    n_shared_experts=1,
    d_ff_shared=8192,
    moe_interleave=2,
    capacity_factor=1.25,
    rope_theta=500_000.0,
)

REDUCED = ModelConfig(
    name="llama4-maverick-400b-a17b-reduced",
    family="moe",
    n_layers=4,
    d_model=128,
    n_heads=4,
    n_kv_heads=2,
    head_dim=32,
    d_ff=256,
    vocab_size=512,
    n_experts=8,
    top_k=1,
    d_ff_expert=64,
    n_shared_experts=1,
    d_ff_shared=64,
    moe_interleave=2,
    attn_chunk=32,
)
