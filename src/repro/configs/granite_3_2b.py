"""granite-3-2b [dense] — GQA [hf:ibm-granite/granite-3.0-2b-base; hf].

40L d_model=2048 32H (GQA kv=8) d_ff=8192 vocab=49155.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="granite-3-2b",
    family="dense",
    n_layers=40,
    d_model=2048,
    n_heads=32,
    n_kv_heads=8,
    head_dim=64,
    d_ff=8192,
    vocab_size=49155,
    tie_embeddings=True,
)

REDUCED = ModelConfig(
    name="granite-3-2b-reduced",
    family="dense",
    n_layers=2,
    d_model=64,
    n_heads=4,
    n_kv_heads=2,
    head_dim=16,
    d_ff=128,
    vocab_size=512,
    tie_embeddings=True,
    attn_chunk=32,
)
