"""llama3.2-3b [dense] — small llama3 [hf:meta-llama/Llama-3.2-1B; unverified].

28L d_model=3072 24H (GQA kv=8) d_ff=8192 vocab=128256.
"""

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="llama3.2-3b",
    family="dense",
    n_layers=28,
    d_model=3072,
    n_heads=24,
    n_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=128256,
    rope_theta=500_000.0,
)

REDUCED = ModelConfig(
    name="llama3.2-3b-reduced",
    family="dense",
    n_layers=2,
    d_model=96,
    n_heads=6,
    n_kv_heads=2,
    head_dim=16,
    d_ff=256,
    vocab_size=512,
    attn_chunk=32,
)
