"""Grouped-query attention: flash-style chunked train/prefill + cached decode.

Train/prefill use an online-softmax scan over KV chunks (memory O(S*chunk)
instead of O(S^2)).  Decode consumes a KV cache; with the cache length
sharded over the data axis (long-context profile) the score/softmax/value
chain lowers to a GSPMD flash-decode: partial max/sum reductions plus a
final psum — no code change needed.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import apply_rope, rope_frequencies, truncated_normal
from repro.parallel.sharding import constrain

Array = jax.Array

NEG_INF = -1e30


def _h_tot(cfg: ModelConfig) -> int:
    return cfg.n_heads + cfg.head_pad


def _head_mask(cfg: ModelConfig):
    """1 for real heads, 0 for padding heads (kept dead at use sites)."""
    if not cfg.head_pad:
        return None
    return (jnp.arange(_h_tot(cfg)) < cfg.n_heads)


def head_to_kv_map(cfg: ModelConfig) -> jnp.ndarray:
    """Which KV head each (possibly padded) q head attends with.

    Real heads keep the *original* GQA grouping (h // (H/KV)) so padding is
    semantics-preserving; dead pad heads read kv 0 (their output is masked).
    """
    g = cfg.n_heads // cfg.n_kv_heads
    real = jnp.arange(cfg.n_heads) // g
    if not cfg.head_pad:
        return real.astype(jnp.int32)
    pad = jnp.zeros((cfg.head_pad,), real.dtype)
    return jnp.concatenate([real, pad]).astype(jnp.int32)


def init_attention(key, cfg: ModelConfig, cross: bool = False) -> dict:
    d, h, kv, hd = cfg.d_model, _h_tot(cfg), cfg.n_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 4)
    s = 1.0 / np.sqrt(d)
    wq = truncated_normal(ks[0], (d, h, hd), s)
    wo = truncated_normal(ks[3], (h, hd, d), 1.0 / np.sqrt(h * hd))
    if cfg.head_pad:  # dead heads start (and are masked) at zero
        dead = jnp.arange(h) >= cfg.n_heads
        wq = jnp.where(dead[None, :, None], 0.0, wq)
        wo = jnp.where(dead[:, None, None], 0.0, wo)
    return {
        "wq": wq,
        "wk": truncated_normal(ks[1], (d, kv, hd), s),
        "wv": truncated_normal(ks[2], (d, kv, hd), s),
        "wo": wo,
    }


def spec_attention() -> dict:
    return {
        "wq": ("embed", "heads", "head_dim"),
        "wk": ("embed", "kv_heads", "head_dim"),
        "wv": ("embed", "kv_heads", "head_dim"),
        "wo": ("heads", "head_dim", "embed"),
    }


def _project_qkv(params, cfg: ModelConfig, xq: Array, xkv: Array):
    dt = xq.dtype
    q = jnp.einsum("bsd,dhk->bshk", xq, params["wq"].astype(dt))
    k = jnp.einsum("bsd,dhk->bshk", xkv, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", xkv, params["wv"].astype(dt))
    return q, k, v


def _flash_over_kv(q_blk, kc, vc, pc, q_pos_blk, causal, scale):
    """Online-softmax scan of one q block over a stack of KV chunks.

    q_blk: [B,Sq,H,hd]; kc/vc: [n_chunks,B,chunk,H,hd]; pc: [n_chunks,chunk].
    """
    b, sq, h, hd = q_blk.shape

    def step(carry, inp):
        acc, m, l = carry
        kci, vci, pci = inp
        s = jnp.einsum("bqhd,bchd->bhqc", q_blk, kci).astype(jnp.float32)
        s = s * scale
        mask = pci[None, :] > q_pos_blk[:, None] if causal else (
            pci[None, :] >= 2**30)
        s = jnp.where(mask[None, None], NEG_INF, s)
        s = constrain(s, "batch", "heads", None, None)
        m_new = jnp.maximum(m, s.max(axis=-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(axis=-1)
        pv = jnp.einsum("bhqc,bchd->bhqd", p.astype(q_blk.dtype), vci)
        acc_new = acc * corr[..., None].astype(acc.dtype) + pv
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((b, h, sq, hd), q_blk.dtype)
    m0 = jnp.full((b, h, sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, h, sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(step, (acc0, m0, l0), (kc, vc, pc))
    return acc / jnp.maximum(l, 1e-30)[..., None].astype(acc.dtype)


def _flash_chunks(q, k, v, q_pos, kv_pos, cfg: ModelConfig, causal: bool):
    """Online-softmax attention over KV chunks with causal block skipping.

    q: [B,Sq,H,hd]; k,v: [B,Skv,H,hd] (KV heads pre-expanded to H so every
    tensor carries the same model-sharded head axis — grouped layouts
    fragment GSPMD's sharding propagation); positions int32 [Sq]/[Skv].

    For causal self-attention (Sq == Skv) the q axis is blocked and each q
    block only visits its KV prefix, halving attention FLOPs and score
    traffic vs the naive full-rectangle scan (§Perf cell B iteration 2).
    """
    b, sq, h, hd = q.shape
    skv = k.shape[1]
    chunk = min(cfg.attn_chunk, skv)
    n_chunks = -(-skv // chunk)
    pad = n_chunks * chunk - skv
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kv_pos = jnp.pad(kv_pos, (0, pad), constant_values=2**30)
    kc = k.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    vc = v.reshape(b, n_chunks, chunk, h, hd).transpose(1, 0, 2, 3, 4)
    pc = kv_pos.reshape(n_chunks, chunk)
    scale = 1.0 / np.sqrt(hd)

    # causal q blocking: at most 8 statically unrolled q blocks, each a
    # multiple of the kv chunk so block boundaries align
    blockable = (causal and sq == skv and n_chunks > 1 and pad == 0)
    if not blockable:
        out = _flash_over_kv(q, kc, vc, pc, q_pos, causal, scale)
        return out.transpose(0, 2, 1, 3)

    chunks_per_block = max(1, -(-n_chunks // 8))
    q_block = chunks_per_block * chunk
    n_blocks = -(-sq // q_block)
    outs = []
    for i in range(n_blocks):
        lo, hi = i * q_block, min((i + 1) * q_block, sq)
        kv_hi = -(-hi // chunk)  # KV prefix covering this block
        out_i = _flash_over_kv(q[:, lo:hi], kc[:kv_hi], vc[:kv_hi],
                               pc[:kv_hi], q_pos[lo:hi], True, scale)
        outs.append(out_i)
    out = jnp.concatenate(outs, axis=2)  # [B,H,Sq,hd]
    return out.transpose(0, 2, 1, 3)  # [B,Sq,H,hd]


def attention(params, cfg: ModelConfig, x: Array, positions: Array, *,
              causal: bool = True, rope: bool = True,
              kv_override: tuple[Array, Array] | None = None,
              return_kv: bool = False):
    """Full-sequence attention (train / prefill / encoder / cross)."""
    b, s, _ = x.shape
    if kv_override is None:
        q, k, v = _project_qkv(params, cfg, x, x)
        kv_pos = positions
    else:
        dt = x.dtype
        q = jnp.einsum("bsd,dhk->bshk", x, params["wq"].astype(dt))
        k, v = kv_override
        kv_pos = jnp.arange(k.shape[1], dtype=jnp.int32)
    if rope and kv_override is None:
        cos, sin = rope_frequencies(cfg, positions)
        q = apply_rope(q, cos, sin)
        k = apply_rope(k, cos, sin)
    q = constrain(q, "batch", "seq", "heads", "head_dim")
    k = constrain(k, "batch", "seq", "kv_heads", "head_dim")
    v = constrain(v, "batch", "seq", "kv_heads", "head_dim")
    kv_out = (k, v)
    if _h_tot(cfg) != cfg.n_kv_heads:
        # expand KV per q-head (original GQA grouping preserved under head
        # padding) so all flash tensors share the model-sharded H axis
        hmap = head_to_kv_map(cfg)
        k = jnp.take(k, hmap, axis=2)
        v = jnp.take(v, hmap, axis=2)
        k = constrain(k, "batch", "seq", "heads", "head_dim")
        v = constrain(v, "batch", "seq", "heads", "head_dim")
    if (cfg.attn_impl == "pallas" and k.shape[1] == s
            and s % min(cfg.attn_chunk, s) == 0):
        # fused Pallas flash kernel (TPU target; interpret on CPU): score
        # blocks stay in VMEM — zero score HBM traffic (§Perf cell B)
        from repro.kernels.flash_attention import flash_attention
        blk = min(cfg.attn_chunk, s, 128)
        out = flash_attention(q.transpose(0, 2, 1, 3),
                              k.transpose(0, 2, 1, 3),
                              v.transpose(0, 2, 1, 3),
                              causal=causal, bq=blk, bk=blk)
        out = out.transpose(0, 2, 1, 3)
    else:
        out = _flash_chunks(q, k, v, positions, kv_pos, cfg, causal)
    wo = params["wo"].astype(x.dtype)
    mask = _head_mask(cfg)
    if mask is not None:
        wo = wo * mask[:, None, None].astype(wo.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, wo)
    if return_kv:
        return y, kv_out
    return y


def project_cross_kv(params, cfg: ModelConfig, memory: Array):
    """Precompute cross-attention K/V from encoder memory (whisper)."""
    dt = memory.dtype
    k = jnp.einsum("bsd,dhk->bshk", memory, params["wk"].astype(dt))
    v = jnp.einsum("bsd,dhk->bshk", memory, params["wv"].astype(dt))
    return k, v


# ---------------------------------------------------------------------------
# cached decode
# ---------------------------------------------------------------------------

def init_cache(cfg: ModelConfig, batch: int, max_len: int, dtype) -> dict:
    kv, hd = cfg.n_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, max_len, kv, hd), dtype),
        "v": jnp.zeros((batch, max_len, kv, hd), dtype),
    }


def spec_cache() -> dict:
    return {"k": ("batch", "kv_seq", "kv_heads", "head_dim"),
            "v": ("batch", "kv_seq", "kv_heads", "head_dim")}


def prefill_into_cache(cache: dict, k: Array, v: Array) -> dict:
    s = k.shape[1]
    cache = dict(cache)
    cache["k"] = jax.lax.dynamic_update_slice(
        cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
    cache["v"] = jax.lax.dynamic_update_slice(
        cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
    return cache


def decode_attention(params, cfg: ModelConfig, x: Array, cache: dict,
                     pos: Array, *, rope: bool = True,
                     update_cache: bool = True) -> tuple[Array, dict]:
    """One-token attention against the cache.

    x: [B, 1, D]; pos: scalar int32 (current position).  With ``kv_seq``
    sharded, the softmax/value reductions lower to a distributed
    flash-decode.  ``update_cache=False`` reads without writing (cross-attn).
    """
    b = x.shape[0]
    g = cfg.n_heads // cfg.n_kv_heads
    q, k_new, v_new = _project_qkv(params, cfg, x, x)
    # decode activations are tiny: pin the projection output to the weight
    # sharding (so GSPMD computes it sharded instead of all-gathering the
    # weights), then explicitly all-gather the small q for the cache einsums
    q = constrain(q, "batch", None, "heads", "head_dim")
    q = constrain(q, "batch", None, None, None)
    if rope:
        posv = jnp.full((1,), pos, jnp.int32)
        cos, sin = rope_frequencies(cfg, posv)
        q = apply_rope(q, cos, sin)
        k_new = apply_rope(k_new, cos, sin)
    if update_cache:
        cache = dict(cache)
        cache["k"] = jax.lax.dynamic_update_slice(
            cache["k"], k_new.astype(cache["k"].dtype), (0, pos, 0, 0))
        cache["v"] = jax.lax.dynamic_update_slice(
            cache["v"], v_new.astype(cache["v"].dtype), (0, pos, 0, 0))
        cache["k"] = constrain(cache["k"], "batch", "kv_seq", "kv_heads",
                               "head_dim")
        cache["v"] = constrain(cache["v"], "batch", "kv_seq", "kv_heads",
                               "head_dim")
    k, v = cache["k"], cache["v"]
    s_len = k.shape[1]
    # decode keeps the grouped form over *real* heads only (pad heads are
    # dead; slicing avoids expanding the cache reads by the group factor)
    q = q[..., : cfg.n_heads, :]
    qg = q.reshape(b, 1, cfg.n_kv_heads, g, cfg.head_dim)
    scores = jnp.einsum("bqhgd,bshd->bhgqs", qg, k.astype(qg.dtype))
    scores = scores.astype(jnp.float32) / np.sqrt(cfg.head_dim)
    valid = jnp.arange(s_len, dtype=jnp.int32)[None] <= pos
    scores = jnp.where(valid[None, None, None], scores, NEG_INF)
    probs = jax.nn.softmax(scores, axis=-1).astype(qg.dtype)
    out = jnp.einsum("bhgqs,bshd->bqhgd", probs, v.astype(qg.dtype))
    out = out.reshape(b, 1, cfg.n_heads, cfg.head_dim)
    wo = params["wo"][: cfg.n_heads].astype(x.dtype)
    y = jnp.einsum("bshk,hkd->bsd", out, wo)
    return y, cache
