"""Shared transformer layers: norms, embeddings, RoPE, MLP variants.

Every ``init_*`` has a mirrored ``spec_*`` returning the same pytree
structure with logical-axis tuples (converted to PartitionSpec by the
launcher); tests assert the mirror stays in sync.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.parallel.sharding import constrain

Array = jax.Array


def truncated_normal(key, shape, scale, dtype=jnp.float32):
    return scale * jax.random.truncated_normal(key, -2.0, 2.0, shape, dtype)


# ---------------------------------------------------------------------------
# RMSNorm
# ---------------------------------------------------------------------------

def init_rmsnorm(d: int) -> dict:
    return {"scale": jnp.ones((d,), jnp.float32)}


def spec_rmsnorm() -> dict:
    return {"scale": ("embed",)}


def rmsnorm(params: dict, x: Array, eps: float) -> Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    x = x * jax.lax.rsqrt(var + eps)
    return (x * params["scale"]).astype(dt)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------

def init_embed(key, cfg: ModelConfig) -> dict:
    p = {"table": truncated_normal(key, (cfg.vocab_size, cfg.d_model),
                                   1.0 / np.sqrt(cfg.d_model))}
    if not cfg.tie_embeddings:
        p["unembed"] = truncated_normal(
            jax.random.fold_in(key, 1), (cfg.vocab_size, cfg.d_model),
            1.0 / np.sqrt(cfg.d_model))
    return p


def spec_embed(cfg: ModelConfig) -> dict:
    p = {"table": ("vocab", "embed")}
    if not cfg.tie_embeddings:
        p["unembed"] = ("vocab", "embed")
    return p


def embed_tokens(params: dict, cfg: ModelConfig, tokens: Array) -> Array:
    x = jnp.take(params["table"], tokens, axis=0)
    if cfg.embed_scale:
        x = x * np.sqrt(cfg.d_model)
    return x.astype(cfg.activation_dtype())


def unembed(params: dict, cfg: ModelConfig, x: Array) -> Array:
    table = params["table"] if cfg.tie_embeddings else params["unembed"]
    logits = jnp.einsum("...d,vd->...v", x, table.astype(x.dtype))
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = c * jnp.tanh(logits / c)
    if cfg.vocab_real and cfg.vocab_real < cfg.vocab_size:
        pad = jnp.arange(cfg.vocab_size) >= cfg.vocab_real
        logits = jnp.where(pad, jnp.float32(-1e30).astype(logits.dtype), logits)
    return logits


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------

def rope_frequencies(cfg: ModelConfig, positions: Array) -> tuple[Array, Array]:
    """cos/sin tables [..., head_dim/2] for integer positions."""
    half = cfg.head_dim // 2
    freqs = cfg.rope_theta ** (-jnp.arange(0, half, dtype=jnp.float32) / half)
    ang = positions[..., None].astype(jnp.float32) * freqs
    return jnp.cos(ang), jnp.sin(ang)


def apply_rope(x: Array, cos: Array, sin: Array) -> Array:
    """x: [..., seq, heads, head_dim]; cos/sin: [..., seq, head_dim/2]."""
    half = x.shape[-1] // 2
    x1, x2 = x[..., :half], x[..., half:]
    cos = cos[..., None, :]
    sin = sin[..., None, :]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU) — digital matmuls or the paper's analog processor
# ---------------------------------------------------------------------------

def _analog_layers(cfg: ModelConfig, d: int, f: int):
    """The MLP's three projections as tiled RF analog processors.

    With ``cfg.rfnn_backend="pallas"`` each projection's whole (To x Ti)
    tile grid runs as one fused tile-grid megakernel per direction
    (``repro.kernels.ops.tiled_apply``) instead of To*Ti separate mesh
    launches; the modules here are frozen dataclasses, so re-creating
    them per call still hits the kernel's schedule/pack caches.
    """
    from repro.core.analog_linear import TiledAnalogLinear
    mk = lambda i, o: TiledAnalogLinear(
        in_dim=i, out_dim=o, tile_size=cfg.rfnn_tile,
        quantize=cfg.rfnn_quantize, output="real",
        backend=cfg.rfnn_backend)
    return {"wi": mk(d, f), "wg": mk(d, f), "wo": mk(f, d)}


def init_mlp(key, cfg: ModelConfig, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.linear_impl == "rfnn":
        # paper integration: projections realized by tiled analog SVD
        # meshes (phases + attenuations are the trainable params)
        layers = _analog_layers(cfg, d, f)
        p = {"wi": layers["wi"].init(k1), "wo": layers["wo"].init(k3)}
        if cfg.mlp_variant in ("swiglu", "geglu"):
            p["wg"] = layers["wg"].init(k2)
        return p
    s_in, s_out = 1.0 / np.sqrt(d), 1.0 / np.sqrt(f)
    p = {
        "wi": truncated_normal(k1, (d, f), s_in),
        "wo": truncated_normal(k3, (f, d), s_out),
    }
    if cfg.mlp_variant in ("swiglu", "geglu"):
        p["wg"] = truncated_normal(k2, (d, f), s_in)
    return p


def _replicated_like(tree):
    return jax.tree.map(lambda x: (None,) * jnp.ndim(x), tree)


def spec_mlp(cfg: ModelConfig | None = None) -> dict:
    if cfg is not None and cfg.linear_impl == "rfnn":
        shapes = jax.eval_shape(
            lambda k: init_mlp(k, cfg), jax.random.PRNGKey(0))
        return jax.tree.map(lambda s: (None,) * len(s.shape), shapes)
    p = {"wi": ("embed", "ffn"), "wo": ("ffn", "embed")}
    if cfg is None or cfg.mlp_variant in ("swiglu", "geglu"):
        p["wg"] = ("embed", "ffn")
    return p


def mlp(params: dict, cfg: ModelConfig, x: Array) -> Array:
    dt = x.dtype
    if cfg.linear_impl == "rfnn":
        d = x.shape[-1]
        layers = _analog_layers(cfg, cfg.d_model, cfg.d_ff)
        xf = x.astype(jnp.float32)
        h = layers["wi"].apply(params["wi"], xf)
        if cfg.mlp_variant in ("swiglu", "geglu"):
            act = jax.nn.gelu if cfg.mlp_variant == "geglu" else jax.nn.silu
            h = h * act(layers["wg"].apply(params["wg"], xf))
        else:
            h = jax.nn.gelu(h)
        return layers["wo"].apply(params["wo"], h).astype(dt)
    h = jnp.einsum("...d,df->...f", x, params["wi"].astype(dt))
    if cfg.mlp_variant in ("swiglu", "geglu"):
        act = jax.nn.gelu if cfg.mlp_variant == "geglu" else jax.nn.silu
        g = jnp.einsum("...d,df->...f", x, params["wg"].astype(dt))
        h = h * act(g)
    else:  # plain gelu (whisper)
        h = jax.nn.gelu(h)
    if h.ndim == 3:
        h = constrain(h, "batch", "seq", "ffn")
    return jnp.einsum("...f,fd->...d", h, params["wo"].astype(dt))
