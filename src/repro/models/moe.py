"""Mixture-of-Experts block: top-k routing, capacity dispatch, EP sharding.

GShard-style dropping MoE, formulated so GSPMD produces the canonical
expert-parallel schedule:

  1. tokens are grouped [G, T, D] with G sharded over the DP axes;
  2. dispatch is a *local* scatter into a per-group expert buffer
     [G, E, C, D] (same sharding as the tokens — no communication);
  3. a sharding-constraint flips the buffer from G-sharded to E-sharded —
     GSPMD lowers this reshard to the expert-parallel **all-to-all**;
  4. expert FFNs run with experts sharded over the DP axes and the expert
     FFN dim sharded over "model" (TP inside experts);
  5. the output buffer is resharded back (second all-to-all) and combined
     with the top-k gates; dropped tokens fall through on the residual.

Padded experts (e.g. qwen's 60 -> 64 for even sharding) are masked out of
the router, so routing behaves exactly like the unpadded model.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import truncated_normal
from repro.parallel.sharding import constrain

Array = jax.Array


def init_moe(key, cfg: ModelConfig) -> dict:
    d, e, fe = cfg.d_model, cfg.n_experts, cfg.d_ff_expert
    ks = jax.random.split(key, 5)
    s_in, s_out = 1.0 / np.sqrt(d), 1.0 / np.sqrt(fe)
    p = {
        "router": truncated_normal(ks[0], (d, e), s_in),
        "wi": truncated_normal(ks[1], (e, d, fe), s_in),
        "wg": truncated_normal(ks[2], (e, d, fe), s_in),
        "wo": truncated_normal(ks[3], (e, fe, d), s_out),
    }
    if cfg.n_shared_experts:
        f_sh = cfg.n_shared_experts * cfg.d_ff_shared
        k1, k2, k3 = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wi": truncated_normal(k1, (d, f_sh), s_in),
            "wg": truncated_normal(k2, (d, f_sh), s_in),
            "wo": truncated_normal(k3, (f_sh, d), 1.0 / np.sqrt(f_sh)),
        }
    return p


def spec_moe(cfg: ModelConfig) -> dict:
    p = {
        "router": ("embed", None),
        "wi": ("experts", "mlp_embed", "expert_ffn"),
        "wg": ("experts", "mlp_embed", "expert_ffn"),
        "wo": ("experts", "expert_ffn", "mlp_embed"),
    }
    if cfg.n_shared_experts:
        p["shared"] = {"wi": ("embed", "ffn"), "wg": ("embed", "ffn"),
                       "wo": ("ffn", "embed")}
    return p


def _capacity(cfg: ModelConfig, tokens_per_group: int) -> int:
    c = int(np.ceil(tokens_per_group * cfg.top_k * cfg.capacity_factor
                    / cfg.n_experts))
    return max(4, -(-c // 4) * 4)  # round up to a multiple of 4


# ---------------------------------------------------------------------------
# gather-only dispatch/combine
#
# A scatter of [G,T*k,D] values makes GSPMD replicate the destination and
# all-reduce partial scatters (it cannot prove shard-locality), and the same
# happens to the *backward* of a gather.  With the slot<->choice index maps
# precomputed (tiny int32 scatters), both dispatch and combine — and their
# transposes — become batched gathers that stay local to the G-sharded
# batch dim (§Perf cell A).
# ---------------------------------------------------------------------------

def _gather_rows(x_pad: jax.Array, idx: jax.Array) -> jax.Array:
    """x_pad: [G, N+1, D] (last row zero); idx: [G, M] -> [G, M, D]."""
    return jnp.take_along_axis(x_pad, idx[..., None], axis=1)


def _pad_zero_row(x: jax.Array) -> jax.Array:
    return jnp.concatenate(
        [x, jnp.zeros(x.shape[:1] + (1,) + x.shape[2:], x.dtype)], axis=1)


@jax.custom_vjp
def _dispatch(xg, tok_of_slot, slot_of_choice):
    """tokens [G,T,D] -> slots [G,E*C,D] (sentinel slots produce zeros)."""
    return _gather_rows(_pad_zero_row(xg), tok_of_slot)


def _dispatch_fwd(xg, tok_of_slot, slot_of_choice):
    return _dispatch(xg, tok_of_slot, slot_of_choice), (
        slot_of_choice, xg.shape[1])


def _dispatch_bwd(res, d_buf):
    slot_of_choice, t = res
    g, tk = slot_of_choice.shape
    picked = _gather_rows(_pad_zero_row(d_buf), slot_of_choice)  # [G,T*k,D]
    d_xg = picked.reshape(g, t, tk // t, -1).sum(axis=2)
    return d_xg, None, None


_dispatch.defvjp(_dispatch_fwd, _dispatch_bwd)


@jax.custom_vjp
def _combine(out, slot_of_choice, choice_of_slot):
    """slots [G,E*C,D] -> per-choice rows [G,T*k,D] (dropped -> zeros)."""
    return _gather_rows(_pad_zero_row(out), slot_of_choice)


def _combine_fwd(out, slot_of_choice, choice_of_slot):
    return _combine(out, slot_of_choice, choice_of_slot), (choice_of_slot,)


def _combine_bwd(res, d_picked):
    (choice_of_slot,) = res
    d_out = _gather_rows(_pad_zero_row(d_picked), choice_of_slot)
    return d_out, None, None


_combine.defvjp(_combine_fwd, _combine_bwd)


def moe_block(params: dict, cfg: ModelConfig, x: Array) -> tuple[Array, Array]:
    """x: [B, S, D] -> (y, aux_loss)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    g = min(cfg.expert_groups, b)
    t = (b // g) * s
    cap = _capacity(cfg, t)
    dt = x.dtype

    xg = x.reshape(g, t, d)
    xg = constrain(xg, "expert_group", None, None)

    # ---- router (f32 for numerics) ----
    logits = jnp.einsum("gtd,de->gte", xg.astype(jnp.float32),
                        params["router"].astype(jnp.float32))
    if cfg.n_experts_active < e:  # padded experts never receive tokens
        pad_mask = jnp.arange(e) >= cfg.n_experts_active
        logits = jnp.where(pad_mask, -1e30, logits)
    probs = jax.nn.softmax(logits, axis=-1)
    gate_vals, expert_idx = jax.lax.top_k(probs, k)     # [G,T,k]
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9)

    # ---- positions within each expert queue (dropping beyond capacity) ----
    flat_idx = expert_idx.reshape(g, t * k)
    onehot = jax.nn.one_hot(flat_idx, e, dtype=jnp.float32)   # [G,T*k,E]
    onehot = constrain(onehot, "expert_group", None, None)
    pos_in_e = (jnp.cumsum(onehot, axis=1) - 1.0)
    pos_in_e = constrain(pos_in_e, "expert_group", None, None)
    pos = jnp.einsum("gxe,gxe->gx", pos_in_e, onehot).astype(jnp.int32)
    keep = pos < cap

    # ---- dispatch by gather ----
    # Scattering the [G,T*k,D] token values into the buffer makes GSPMD
    # replicate the full buffer and all-reduce partial scatters (it cannot
    # prove the writes are shard-local), costing ~50x the ideal all-to-all —
    # and the same happens to the backward of a plain gather.  So: scatter
    # only tiny int32 slot<->choice maps, and route values (fwd AND bwd)
    # exclusively through batched gathers (§Perf cell A).
    tok_of_choice = jnp.repeat(jnp.arange(t), k)          # [T*k]
    g_ids = jnp.arange(g)[:, None] * jnp.ones((1, t * k), jnp.int32)
    slot_of_choice = jnp.where(keep, flat_idx * cap + pos, e * cap)
    tok_of_slot = jnp.full((g, e * cap), t, jnp.int32).at[
        g_ids, slot_of_choice].set(jnp.broadcast_to(tok_of_choice, (g, t * k)))
    choice_of_slot = jnp.full((g, e * cap), t * k, jnp.int32).at[
        g_ids, slot_of_choice].set(
        jnp.broadcast_to(jnp.arange(t * k), (g, t * k)))
    tok_of_slot = constrain(tok_of_slot, "expert_group", None)
    choice_of_slot = constrain(choice_of_slot, "expert_group", None)

    buf = _dispatch(xg, tok_of_slot, slot_of_choice).reshape(g, e, cap, d)
    buf = constrain(buf, "expert_group", None, None, None)

    # ---- all-to-all: G-sharded -> E-sharded ----
    buf = constrain(buf, None, "experts", None, None)

    # ---- expert FFNs (TP over expert_ffn) ----
    h = jnp.einsum("gecd,edf->gecf", buf, params["wi"].astype(dt))
    gt = jnp.einsum("gecd,edf->gecf", buf, params["wg"].astype(dt))
    h = h * jax.nn.silu(gt)
    h = constrain(h, None, "experts", None, "expert_ffn")
    out = jnp.einsum("gecf,efd->gecd", h, params["wo"].astype(dt))
    # (keeping D model-sharded here to force a reduce-scatter was tried and
    # REFUTED: GSPMD inserted extra reshards, +42% collective bytes —
    # see EXPERIMENTS.md §Perf cell A iteration 4)
    out = constrain(out, None, "experts", None, None)

    # ---- all-to-all back: E-sharded -> G-sharded ----
    out = constrain(out, "expert_group", None, None, None)

    # ---- combine with gates ----
    # gather each choice's slot (dropped choices hit the zero sentinel row),
    # then sum the k choices per token — a pure reshape+sum, no scatter.
    picked = _combine(out.reshape(g, e * cap, d), slot_of_choice,
                      choice_of_slot)                     # [G,T*k,D]
    w = (gate_vals.reshape(g, t * k) * keep).astype(dt)
    picked = picked * w[..., None]
    yg = picked.reshape(g, t, k, d).sum(axis=2)
    y = yg.reshape(b, s, d)
    y = constrain(y, "batch", "seq", None)

    # ---- shared experts (plain dense MLP path) ----
    if cfg.n_shared_experts:
        sh = params["shared"]
        hs = jnp.einsum("bsd,df->bsf", x, sh["wi"].astype(dt))
        gs = jnp.einsum("bsd,df->bsf", x, sh["wg"].astype(dt))
        hs = hs * jax.nn.silu(gs)
        hs = constrain(hs, "batch", "seq", "ffn")
        y = y + jnp.einsum("bsf,fd->bsd", hs, sh["wo"].astype(dt))

    # ---- load-balance aux (switch-style), over real experts only ----
    frac = jnp.mean(onehot[..., : cfg.n_experts_active], axis=(0, 1))
    prob = jnp.mean(probs[..., : cfg.n_experts_active], axis=(0, 1))
    aux = cfg.n_experts_active * jnp.sum(frac * prob)
    return y, aux.astype(jnp.float32)
