"""Model stack: configs, layers, families, unified facade."""

from repro.models.api import Model, cross_entropy
from repro.models.config import ModelConfig

__all__ = ["Model", "ModelConfig", "cross_entropy"]
