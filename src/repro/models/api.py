"""Unified model facade: one object per architecture config.

Dispatches to the decoder-LM stack or the encoder-decoder stack per family
and owns the loss so train/serve steps are family-agnostic:

    model = Model(cfg)
    params = model.init(key)
    loss, metrics = model.loss(params, batch)
    logits, cache = model.prefill(params, batch)
    logits, cache = model.decode_step(params, token, cache, pos)

``batch`` keys: tokens [B,S] int32; labels [B,S] int32 (-1 = masked,
already shifted by the data pipeline); vis_embed [B,n_vis,D] (vlm);
frames [B,enc_seq,D] (encdec).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp

from repro.models import encdec as encdec_lib
from repro.models import lm as lm_lib
from repro.models.config import ModelConfig

Array = jax.Array


def cross_entropy(logits: Array, labels: Array) -> tuple[Array, Array]:
    """Masked next-token CE in f32.  labels == -1 are masked."""
    mask = labels >= 0
    lab = jnp.maximum(labels, 0)
    logits = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
    nll = (lse - gold) * mask
    denom = jnp.maximum(mask.sum(), 1)
    loss = nll.sum() / denom
    acc = ((jnp.argmax(logits, -1) == lab) * mask).sum() / denom
    return loss, acc


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig

    # ---------------- init / specs ----------------
    def init(self, key) -> dict:
        if self.cfg.family == "encdec":
            return encdec_lib.init_encdec(key, self.cfg)
        return lm_lib.init_lm(key, self.cfg)

    def param_specs(self) -> dict:
        if self.cfg.family == "encdec":
            return encdec_lib.spec_encdec(self.cfg)
        return lm_lib.spec_lm(self.cfg)

    # ---------------- train ----------------
    def forward(self, params, batch) -> tuple[Array, Array]:
        if self.cfg.family == "encdec":
            return encdec_lib.forward(params, self.cfg, batch["tokens"],
                                      batch["frames"])
        return lm_lib.forward(params, self.cfg, batch["tokens"],
                              batch.get("vis_embed"))

    def loss(self, params, batch) -> tuple[Array, dict]:
        logits, aux = self.forward(params, batch)
        if self.cfg.family == "vlm":
            logits = logits[:, self.cfg.n_vis_tokens:]
        ce, acc = cross_entropy(logits, batch["labels"])
        total = ce + self.cfg.router_aux_weight * aux
        return total, {"ce": ce, "acc": acc, "moe_aux": aux}

    # ---------------- serve ----------------
    def prefill(self, params, batch, max_len: int | None = None):
        if self.cfg.family == "encdec":
            return encdec_lib.prefill(params, self.cfg, batch["tokens"],
                                      batch["frames"], max_len)
        return lm_lib.prefill(params, self.cfg, batch["tokens"],
                              batch.get("vis_embed"), max_len)

    def decode_step(self, params, token, cache, pos):
        if self.cfg.family == "encdec":
            return encdec_lib.decode_step(params, self.cfg, token, cache, pos)
        return lm_lib.decode_step(params, self.cfg, token, cache, pos)

    def bind_decode(self, params):
        """A jitted decode closure for the serving engine's tick loop:
        ``step(tokens, cache, pos) -> (logits, cache)``.

        Params are passed as a jit argument (not closed over), so donated
        caches and later param swaps keep a single compiled executable.
        """
        step = jax.jit(lambda p, t, c, pos: self.decode_step(p, t, c, pos))

        def run(tokens, cache, pos):
            return step(params, tokens, cache, pos)

        return run

    def init_cache(self, batch: int, max_len: int) -> dict:
        if self.cfg.family == "encdec":
            return encdec_lib.init_dec_cache(self.cfg, batch, max_len)
        return lm_lib.init_lm_cache(self.cfg, batch, max_len)

    def cache_specs(self) -> dict:
        if self.cfg.family == "encdec":
            return encdec_lib.spec_dec_cache(self.cfg)
        return lm_lib.spec_lm_cache(self.cfg)

    # ---------------- info ----------------
    def param_count(self) -> int:
        return self.cfg.param_count()

    def active_param_count(self) -> int:
        return self.cfg.active_param_count()
