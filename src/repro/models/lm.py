"""Decoder-only language models: dense / MoE / SSM / hybrid / VLM.

Layers are *scanned*: parameters are stacked over a leading super-block axis
and the forward pass is a single ``lax.scan``, so the HLO (and multi-pod
compile time) is O(1) in depth.  A super-block is the family's repeating
pattern:

  dense/vlm    1 layer  (attn + mlp)
  moe          ``moe_interleave`` layers (dense..., moe)
  ssm          1 mamba2 layer
  hybrid       ``attn_every`` mamba2 layers + one invocation of the *shared*
               attention block (weights live outside the scan and are reused
               by every invocation — zamba2's weight tying)

Modes: ``forward`` (train/eval over full seq), ``prefill`` (forward + cache),
``decode_step`` (one token).  Caches are pytrees stacked over the same
super-block axis so the same scan drives them.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn_lib
from repro.models import layers as L
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.config import ModelConfig
from repro.parallel.sharding import constrain

Array = jax.Array


# ---------------------------------------------------------------------------
# per-family super-block init/spec
# ---------------------------------------------------------------------------

def _init_dense_layer(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"ln1": L.init_rmsnorm(cfg.d_model),
            "attn": attn_lib.init_attention(k1, cfg),
            "ln2": L.init_rmsnorm(cfg.d_model),
            "mlp": L.init_mlp(k2, cfg)}


def _spec_dense_layer(cfg):
    return {"ln1": L.spec_rmsnorm(), "attn": attn_lib.spec_attention(),
            "ln2": L.spec_rmsnorm(), "mlp": L.spec_mlp(cfg)}


def _init_moe_layer(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"ln1": L.init_rmsnorm(cfg.d_model),
            "attn": attn_lib.init_attention(k1, cfg),
            "ln2": L.init_rmsnorm(cfg.d_model),
            "moe": moe_lib.init_moe(k2, cfg)}


def _spec_moe_layer(cfg):
    return {"ln1": L.spec_rmsnorm(), "attn": attn_lib.spec_attention(),
            "ln2": L.spec_rmsnorm(), "moe": moe_lib.spec_moe(cfg)}


def _init_ssm_layer(key, cfg):
    return {"ln": L.init_rmsnorm(cfg.d_model),
            "ssm": ssm_lib.init_ssm(key, cfg)}


def _spec_ssm_layer(cfg):
    return {"ln": L.spec_rmsnorm(), "ssm": ssm_lib.spec_ssm()}


def superblock_layout(cfg: ModelConfig) -> tuple[int, list[str]]:
    """(number of super-blocks, layer kinds inside one super-block)."""
    if cfg.family in ("dense", "vlm"):
        return cfg.n_layers, ["dense"]
    if cfg.family == "moe":
        il = cfg.moe_interleave
        assert cfg.n_layers % il == 0
        return cfg.n_layers // il, ["dense"] * (il - 1) + ["moe"]
    if cfg.family == "ssm":
        return cfg.n_layers, ["ssm"]
    if cfg.family == "hybrid":
        k = cfg.attn_every
        assert cfg.n_layers % k == 0
        return cfg.n_layers // k, ["ssm"] * k + ["shared_attn"]
    raise ValueError(cfg.family)


_LAYER_INIT = {"dense": _init_dense_layer, "moe": _init_moe_layer,
               "ssm": _init_ssm_layer}
_LAYER_SPEC = {"dense": _spec_dense_layer, "moe": _spec_moe_layer,
               "ssm": _spec_ssm_layer}


def _init_superblock(key, cfg):
    kinds = superblock_layout(cfg)[1]
    p = {}
    for i, kind in enumerate(kinds):
        if kind == "shared_attn":
            continue  # lives outside the scan
        p[f"l{i}_{kind}"] = _LAYER_INIT[kind](jax.random.fold_in(key, i), cfg)
    return p


def _spec_superblock(cfg):
    kinds = superblock_layout(cfg)[1]
    return {f"l{i}_{kind}": _LAYER_SPEC[kind](cfg)
            for i, kind in enumerate(kinds) if kind != "shared_attn"}


# ---------------------------------------------------------------------------
# model init / specs
# ---------------------------------------------------------------------------

def init_lm(key, cfg: ModelConfig) -> dict:
    n_super = superblock_layout(cfg)[0]
    ke, kb, ks = jax.random.split(key, 3)
    block_keys = jax.random.split(kb, n_super)
    blocks = jax.vmap(lambda k: _init_superblock(k, cfg))(block_keys)
    params = {
        "embed": L.init_embed(ke, cfg),
        "final_norm": L.init_rmsnorm(cfg.d_model),
        "blocks": blocks,
    }
    if cfg.family == "hybrid":
        k1, k2 = jax.random.split(ks)
        params["shared_attn"] = {
            "ln1": L.init_rmsnorm(cfg.d_model),
            "attn": attn_lib.init_attention(k1, cfg),
            "ln2": L.init_rmsnorm(cfg.d_model),
            "mlp": L.init_mlp(k2, cfg),
        }
    return params


def spec_lm(cfg: ModelConfig) -> dict:
    def stack(tree):  # prepend the scanned super-block axis
        return jax.tree.map(lambda t: ("layers",) + t, tree,
                            is_leaf=lambda t: isinstance(t, tuple))
    specs = {
        "embed": L.spec_embed(cfg),
        "final_norm": L.spec_rmsnorm(),
        "blocks": stack(_spec_superblock(cfg)),
    }
    if cfg.family == "hybrid":
        specs["shared_attn"] = {
            "ln1": L.spec_rmsnorm(), "attn": attn_lib.spec_attention(),
            "ln2": L.spec_rmsnorm(), "mlp": L.spec_mlp(cfg),
        }
    return specs


# ---------------------------------------------------------------------------
# forward (train / eval)
# ---------------------------------------------------------------------------

from jax.ad_checkpoint import checkpoint_name as _checkpoint_name


def _ckpt_name(x, name):
    return _checkpoint_name(x, name)


def _run_layer_full(kind, lp, cfg, x, positions, shared, aux):
    if kind in ("dense", "moe"):
        h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
        x = x + _ckpt_name(
            attn_lib.attention(lp["attn"], cfg, h, positions), "attn_out")
        h = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
        if kind == "moe":
            y, a = moe_lib.moe_block(lp["moe"], cfg, h)
            aux = aux + a
        else:
            y = L.mlp(lp["mlp"], cfg, h)
        x = x + _ckpt_name(y, "mlp_out")
    elif kind == "ssm":
        h = L.rmsnorm(lp["ln"], x, cfg.norm_eps)
        x = x + _ckpt_name(ssm_lib.ssm_block(lp["ssm"], cfg, h), "ssm_out")
    elif kind == "shared_attn":
        sp = shared
        h = L.rmsnorm(sp["ln1"], x, cfg.norm_eps)
        x = x + _ckpt_name(
            attn_lib.attention(sp["attn"], cfg, h, positions), "attn_out")
        h = L.rmsnorm(sp["ln2"], x, cfg.norm_eps)
        x = x + _ckpt_name(L.mlp(sp["mlp"], cfg, h), "mlp_out")
    else:
        raise ValueError(kind)
    return x, aux


def _superblock_full(cfg, kinds, shared, carry, block_params, positions):
    x, aux = carry
    for i, kind in enumerate(kinds):
        lp = block_params.get(f"l{i}_{kind}")
        x, aux = _run_layer_full(kind, lp, cfg, x, positions, shared, aux)
        x = constrain(x, "batch", "seq", "embed")
    return (x, aux), None


def _embed_input(params, cfg, tokens, vis_embed):
    x = L.embed_tokens(params["embed"], cfg, tokens)
    if cfg.family == "vlm":
        if vis_embed is None:
            raise ValueError("vlm family requires vis_embed")
        x = jnp.concatenate([vis_embed.astype(x.dtype), x], axis=1)
    return constrain(x, "batch", "seq", "embed")


def forward(params: dict, cfg: ModelConfig, tokens: Array,
            vis_embed: Array | None = None) -> tuple[Array, Array]:
    """Full-sequence forward.  Returns (logits, moe_aux_loss)."""
    x = _embed_input(params, cfg, tokens, vis_embed)
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    kinds = superblock_layout(cfg)[1]
    shared = params.get("shared_attn")

    step = functools.partial(_superblock_full, cfg, kinds, shared,
                             positions=positions)
    if cfg.remat == "full":
        step = jax.checkpoint(step, prevent_cse=False)
    elif cfg.remat == "outputs":
        # save each sub-layer's output: backward never re-runs the attention
        # forward (its score traffic is the memory-bound term; §Perf cell B)
        step = jax.checkpoint(
            step, prevent_cse=False,
            policy=jax.checkpoint_policies.save_only_these_names(
                "attn_out", "mlp_out", "ssm_out"))
    elif cfg.remat == "dots":
        step = jax.checkpoint(
            step, prevent_cse=False,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims)
    aux0 = jnp.zeros((), jnp.float32)
    (x, aux), _ = jax.lax.scan(step, (x, aux0), params["blocks"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], cfg, x)
    logits = constrain(logits, "batch", "seq", "vocab")
    return logits, aux


# ---------------------------------------------------------------------------
# caches
# ---------------------------------------------------------------------------

def init_lm_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    """Decode cache stacked over super-blocks (mirrors params['blocks'])."""
    dt = cfg.activation_dtype()
    n_super, kinds = superblock_layout(cfg)

    def one_super():
        c = {}
        for i, kind in enumerate(kinds):
            if kind in ("dense", "moe"):
                c[f"l{i}_{kind}"] = attn_lib.init_cache(cfg, batch, max_len, dt)
            elif kind == "ssm":
                c[f"l{i}_{kind}"] = ssm_lib.init_ssm_cache(cfg, batch, dt)
            elif kind == "shared_attn":
                c[f"l{i}_{kind}"] = attn_lib.init_cache(cfg, batch, max_len, dt)
        return c

    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (n_super,) + x.shape), one_super())


def spec_lm_cache(cfg: ModelConfig) -> dict:
    _, kinds = superblock_layout(cfg)
    c = {}
    for i, kind in enumerate(kinds):
        if kind in ("dense", "moe", "shared_attn"):
            s = attn_lib.spec_cache()
        else:
            s = ssm_lib.spec_ssm_cache()
        c[f"l{i}_{kind}"] = s
    return jax.tree.map(lambda t: ("layers",) + t, c,
                        is_leaf=lambda t: isinstance(t, tuple))


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def _superblock_decode(cfg, kinds, shared, pos, carry, xs):
    x = carry
    block_params, cache = xs
    new_cache = {}
    for i, kind in enumerate(kinds):
        name = f"l{i}_{kind}"
        lp = block_params.get(name)
        lc = cache[name]
        if kind in ("dense", "moe"):
            h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
            y, lc = attn_lib.decode_attention(lp["attn"], cfg, h, lc, pos)
            x = x + y
            h = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
            if kind == "moe":
                y, _ = moe_lib.moe_block(lp["moe"], cfg, h)
            else:
                y = L.mlp(lp["mlp"], cfg, h)
            x = x + y
        elif kind == "ssm":
            h = L.rmsnorm(lp["ln"], x, cfg.norm_eps)
            y, lc = ssm_lib.ssm_decode_step(lp["ssm"], cfg, h, lc)
            x = x + y
        elif kind == "shared_attn":
            h = L.rmsnorm(shared["ln1"], x, cfg.norm_eps)
            y, lc = attn_lib.decode_attention(shared["attn"], cfg, h, lc, pos)
            x = x + y
            h = L.rmsnorm(shared["ln2"], x, cfg.norm_eps)
            x = x + L.mlp(shared["mlp"], cfg, h)
        new_cache[name] = lc
    return x, new_cache


def decode_step(params: dict, cfg: ModelConfig, token: Array, cache: dict,
                pos: Array) -> tuple[Array, dict]:
    """One decode step.  token: [B] int32; pos: scalar.  -> (logits, cache)."""
    x = L.embed_tokens(params["embed"], cfg, token[:, None])
    x = constrain(x, "batch", None, "embed")
    kinds = superblock_layout(cfg)[1]
    shared = params.get("shared_attn")
    step = functools.partial(_superblock_decode, cfg, kinds, shared, pos)
    x, new_cache = jax.lax.scan(step, x, (params["blocks"], cache))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], cfg, x)[:, 0]
    return constrain(logits, "batch", "vocab"), new_cache


def _superblock_prefill(cfg, kinds, shared, positions, max_len, carry,
                        block_params):
    x, aux = carry
    dt = cfg.activation_dtype()
    cache_out = {}
    for i, kind in enumerate(kinds):
        name = f"l{i}_{kind}"
        lp = shared if kind == "shared_attn" else block_params.get(name)
        if kind in ("dense", "moe", "shared_attn"):
            h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
            y, (k, v) = attn_lib.attention(lp["attn"], cfg, h, positions,
                                           return_kv=True)
            x = x + y
            entry = attn_lib.init_cache(cfg, x.shape[0], max_len, dt)
            cache_out[name] = attn_lib.prefill_into_cache(entry, k, v)
            h = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
            if kind == "moe":
                y, a = moe_lib.moe_block(lp["moe"], cfg, h)
                aux = aux + a
            else:
                y = L.mlp(lp["mlp"], cfg, h)
            x = x + y
        elif kind == "ssm":
            h = L.rmsnorm(lp["ln"], x, cfg.norm_eps)
            y, c = ssm_lib.ssm_block(lp["ssm"], cfg, h, return_cache=True)
            x = x + y
            cache_out[name] = {"state": c["state"],
                               "conv_x": c["conv_x"].astype(dt),
                               "conv_b": c["conv_b"].astype(dt),
                               "conv_c": c["conv_c"].astype(dt)}
        x = constrain(x, "batch", "seq", "embed")
    return (x, aux), cache_out


def prefill(params: dict, cfg: ModelConfig, tokens: Array,
            vis_embed: Array | None = None,
            max_len: int | None = None) -> tuple[Array, dict]:
    """Prefill: full-sequence pass producing last-position logits + cache."""
    x = _embed_input(params, cfg, tokens, vis_embed)
    s = x.shape[1]
    max_len = max_len or cfg.max_cache_len or s
    positions = jnp.arange(s, dtype=jnp.int32)
    kinds = superblock_layout(cfg)[1]
    shared = params.get("shared_attn")
    step = functools.partial(_superblock_prefill, cfg, kinds, shared,
                             positions, max_len)
    aux0 = jnp.zeros((), jnp.float32)
    (x, _), cache = jax.lax.scan(step, (x, aux0), params["blocks"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], cfg, x[:, -1:])[:, 0]
    return constrain(logits, "batch", "vocab"), cache
