"""Model configuration for all assigned architectures.

One frozen dataclass covers the five families (dense / moe / ssm / hybrid /
encdec / vlm); family-specific fields default to "off".  Exact per-arch
values live in ``repro.configs``.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"          # dense | moe | ssm | hybrid | encdec | vlm

    # --- transformer backbone ---
    n_layers: int = 2
    d_model: int = 256
    n_heads: int = 4
    n_kv_heads: int = 4
    head_dim: int = 0              # 0 -> d_model // n_heads
    d_ff: int = 1024
    vocab_size: int = 1000
    mlp_variant: str = "swiglu"    # swiglu | geglu
    rope_theta: float = 10000.0
    norm_eps: float = 1e-5
    tie_embeddings: bool = False
    embed_scale: bool = False      # gemma: embeddings scaled by sqrt(d_model)
    attn_chunk: int = 1024         # flash-style kv chunk in train/prefill
    attn_impl: str = "xla"         # xla (chunked scan) | pallas (flash kernel)
    logit_softcap: float = 0.0
    vocab_real: int = 0            # >0: vocab_size is padded; mask the rest
    head_pad: int = 0              # dead (masked) q-heads appended so the
                                   # head axis divides the TP degree

    # --- MoE ---
    n_experts: int = 0             # routed experts (0 = dense)
    n_experts_active: int = 0      # real experts if padded (qwen 60 -> 64)
    top_k: int = 1
    d_ff_expert: int = 0
    n_shared_experts: int = 0
    d_ff_shared: int = 0
    moe_interleave: int = 1        # every k-th layer is MoE (llama4: 2)
    capacity_factor: float = 1.25
    expert_groups: int = 1         # dispatch groups (set >= DP shards at scale)
    router_aux_weight: float = 0.01

    # --- SSM (mamba2) ---
    ssm_state: int = 0             # d_state (0 = no ssm)
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 128
    ssm_conv: int = 4

    # --- hybrid (zamba2) ---
    attn_every: int = 0            # shared attn block every k ssm layers

    # --- encoder-decoder (whisper) ---
    n_enc_layers: int = 0
    enc_seq: int = 0               # whisper: 1500 precomputed frames

    # --- vlm (internvl) ---
    n_vis_tokens: int = 0          # precomputed patch embeddings prepended

    # --- paper integration ---
    linear_impl: str = "digital"   # digital | rfnn (analog tiled projections)
    rfnn_tile: int = 16
    rfnn_quantize: str | None = None
    rfnn_backend: str = "reference"  # reference | pallas (fused mesh kernels)

    # --- training/runtime ---
    dtype: str = "bfloat16"
    remat: str = "none"            # none | full | dots
    max_cache_len: int = 0         # decode KV cache length (0 -> seq)

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.n_experts and not self.n_experts_active:
            object.__setattr__(self, "n_experts_active", self.n_experts)
        if self.n_heads % self.n_kv_heads:
            raise ValueError("n_heads must be a multiple of n_kv_heads")
        if self.family == "moe" and not self.n_experts:
            raise ValueError("moe family needs n_experts")
        if self.family in ("ssm", "hybrid") and not self.ssm_state:
            raise ValueError(f"{self.family} family needs ssm_state")
        if self.family == "encdec" and not self.n_enc_layers:
            raise ValueError("encdec family needs n_enc_layers")

    # ------------------------------------------------------------------
    @property
    def d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_headdim

    @property
    def is_moe_layer(self):
        """Vector of per-layer booleans: which layers carry the MoE block."""
        if not self.n_experts:
            return [False] * self.n_layers
        return [(i % self.moe_interleave) == (self.moe_interleave - 1)
                for i in range(self.n_layers)]

    def activation_dtype(self):
        import jax.numpy as jnp
        return {"bfloat16": jnp.bfloat16, "float32": jnp.float32}[self.dtype]

    def param_count(self) -> int:
        """Analytic parameter count (embeddings + blocks), for roofline."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        hd, h, kv = self.head_dim, self.n_heads, self.n_kv_heads
        attn = d * hd * (h + 2 * kv) + h * hd * d
        gates = 3 if self.mlp_variant in ("swiglu", "geglu") else 2
        mlp = gates * d * f
        total = v * d * (1 if self.tie_embeddings else 2)
        if self.family in ("ssm", "hybrid"):
            di, n, hs = self.d_inner, self.ssm_state, self.ssm_heads
            ssm = (d * di * 2            # z, x projections
                   + 2 * d * n + d * hs  # B, C, dt projections
                   + self.ssm_conv * (di + 2 * n)
                   + 3 * hs + di        # A_log, D, dt_bias, norm
                   + di * d)            # out_proj
            total += self.n_layers * (ssm + d)
            if self.family == "hybrid" and self.attn_every:
                total += attn + mlp + 2 * d  # one shared block
            return total
        n_moe = sum(self.is_moe_layer)
        n_dense = self.n_layers - n_moe
        total += n_dense * (attn + mlp + 2 * d)
        if n_moe:
            fe = self.d_ff_expert
            moe = (d * self.n_experts_active
                   + self.n_experts_active * gates * d * fe
                   + self.n_shared_experts * gates * d * self.d_ff_shared)
            total += n_moe * (attn + moe + 2 * d)
        if self.family == "encdec":
            total += self.n_enc_layers * (attn + mlp + 2 * d)
            total += self.n_layers * (attn + d)  # cross-attention per dec layer
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE top-k; weight-tied blocks counted
        per *invocation*), for the 6ND model-FLOPs."""
        if self.family == "hybrid" and self.attn_every:
            d, f = self.d_model, self.d_ff
            hd, h, kv = self.head_dim, self.n_heads, self.n_kv_heads
            attn = d * hd * (h + 2 * kv) + h * hd * d
            gates = 3 if self.mlp_variant in ("swiglu", "geglu") else 2
            shared = attn + gates * d * f + 2 * d
            reuse = self.n_layers // self.attn_every - 1
            return self.param_count() + reuse * shared
        if not self.n_experts:
            return self.param_count()
        d, fe = self.d_model, self.d_ff_expert
        gates = 3 if self.mlp_variant in ("swiglu", "geglu") else 2
        n_moe = sum(self.is_moe_layer)
        inactive = n_moe * (self.n_experts_active - self.top_k) * gates * d * fe
        return self.param_count() - inactive
