"""Mamba2 — state-space duality (SSD) blocks (arXiv:2405.21060).

Training/prefill use the chunked dual form: within-chunk attention-like
scores (C B^T masked by the decay kernel) plus an inter-chunk state
recurrence (``lax.scan`` over chunks).  Decode is the O(1) recurrent update
on a [B, H, state, headdim] carry.  Heads shard over "model"; the state is
tiny and stays replicated within a shard.

Single B/C group (n_groups=1) as in the mamba2-780m config; the causal
depthwise conv (window 4) is applied to x, B and C as in the reference
implementation.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.config import ModelConfig
from repro.models.layers import truncated_normal
from repro.parallel.sharding import constrain

Array = jax.Array


def init_ssm(key, cfg: ModelConfig) -> dict:
    d, di, n, h = cfg.d_model, cfg.d_inner, cfg.ssm_state, cfg.ssm_heads
    ks = jax.random.split(key, 8)
    s = 1.0 / np.sqrt(d)
    # A initialized in [1, 16) as in mamba2; dt bias via inverse softplus of
    # dt ~ U[1e-3, 1e-1]
    a_init = jnp.exp(jax.random.uniform(ks[5], (h,), minval=0.0,
                                        maxval=np.log(16.0)))
    dt = jnp.exp(jax.random.uniform(ks[6], (h,),
                                    minval=np.log(1e-3), maxval=np.log(1e-1)))
    return {
        "w_z": truncated_normal(ks[0], (d, di), s),
        "w_x": truncated_normal(ks[1], (d, di), s),
        "w_b": truncated_normal(ks[2], (d, n), s),
        "w_c": truncated_normal(ks[3], (d, n), s),
        "w_dt": truncated_normal(ks[4], (d, h), s),
        "conv_x": truncated_normal(ks[7], (cfg.ssm_conv, di), 1.0 / np.sqrt(cfg.ssm_conv)),
        "conv_b": truncated_normal(jax.random.fold_in(ks[7], 1),
                                   (cfg.ssm_conv, n), 1.0 / np.sqrt(cfg.ssm_conv)),
        "conv_c": truncated_normal(jax.random.fold_in(ks[7], 2),
                                   (cfg.ssm_conv, n), 1.0 / np.sqrt(cfg.ssm_conv)),
        "a_log": jnp.log(a_init),
        "d_skip": jnp.ones((h,), jnp.float32),
        "dt_bias": jnp.log(jnp.expm1(dt)),
        "norm": jnp.ones((di,), jnp.float32),
        "w_out": truncated_normal(jax.random.fold_in(ks[0], 9), (di, d),
                                  1.0 / np.sqrt(di)),
    }


def spec_ssm() -> dict:
    return {
        "w_z": ("embed", "conv_dim"), "w_x": ("embed", "conv_dim"),
        "w_b": ("embed", None), "w_c": ("embed", None),
        "w_dt": ("embed", "ssm_heads"),
        "conv_x": (None, "conv_dim"), "conv_b": (None, None),
        "conv_c": (None, None),
        "a_log": ("ssm_heads",), "d_skip": ("ssm_heads",),
        "dt_bias": ("ssm_heads",), "norm": ("conv_dim",),
        "w_out": ("conv_dim", "embed"),
    }


def _causal_conv(seq: Array, w: Array, state: Array | None = None):
    """Depthwise causal conv along axis 1.  seq [B,S,C]; w [K,C]."""
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros((seq.shape[0], k - 1, seq.shape[2]), seq.dtype)
    else:
        pad = state.astype(seq.dtype)  # [B, K-1, C] history
    full = jnp.concatenate([pad, seq], axis=1)
    out = sum(full[:, i:i + seq.shape[1]] * w[i].astype(seq.dtype)
              for i in range(k))
    new_state = full[:, -(k - 1):] if k > 1 else None
    return jax.nn.silu(out), new_state


def _project(params, cfg: ModelConfig, u: Array):
    dt_ = u.dtype
    z = jnp.einsum("bsd,de->bse", u, params["w_z"].astype(dt_))
    x = jnp.einsum("bsd,de->bse", u, params["w_x"].astype(dt_))
    bb = jnp.einsum("bsd,dn->bsn", u, params["w_b"].astype(dt_))
    cc = jnp.einsum("bsd,dn->bsn", u, params["w_c"].astype(dt_))
    dt = jnp.einsum("bsd,dh->bsh", u, params["w_dt"].astype(dt_))
    dt = jax.nn.softplus(dt.astype(jnp.float32)
                         + params["dt_bias"].astype(jnp.float32))
    return z, x, bb, cc, dt


def _gated_out(params, cfg: ModelConfig, y: Array, z: Array) -> Array:
    di = cfg.d_inner
    y = y * jax.nn.silu(z)
    yf = y.astype(jnp.float32)
    var = jnp.mean(yf * yf, axis=-1, keepdims=True)
    y = (yf * jax.lax.rsqrt(var + 1e-5) * params["norm"]).astype(z.dtype)
    return jnp.einsum("bse,ed->bsd", y, params["w_out"].astype(z.dtype))


def ssm_block(params: dict, cfg: ModelConfig, u: Array,
              return_cache: bool = False):
    """Chunked SSD scan over the full sequence.  u: [B, S, D]."""
    b, s, _ = u.shape
    h, p, n = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    q = min(cfg.ssm_chunk, s)
    if s % q:
        raise ValueError(f"seq {s} not divisible by ssm_chunk {q}")
    nc = s // q

    z, x, bb, cc, dt = _project(params, cfg, u)
    kc = cfg.ssm_conv
    conv_tails = {"conv_x": x[:, -(kc - 1):], "conv_b": bb[:, -(kc - 1):],
                  "conv_c": cc[:, -(kc - 1):]}
    x, _ = _causal_conv(x, params["conv_x"])
    bb, _ = _causal_conv(bb, params["conv_b"])
    cc, _ = _causal_conv(cc, params["conv_c"])

    a = -jnp.exp(params["a_log"].astype(jnp.float32))        # [H]
    da = dt * a                                               # [B,S,H] (<=0)
    xh = x.reshape(b, nc, q, h, p)
    xh = constrain(xh, "batch", None, None, "ssm_heads", None)
    bc = bb.reshape(b, nc, q, n)
    ccc = cc.reshape(b, nc, q, n)
    dac = da.reshape(b, nc, q, h)
    dtc = dt.reshape(b, nc, q, h)

    cum = jnp.cumsum(dac, axis=2)                             # [B,nc,Q,H]
    seg_sum = cum[:, :, -1]                                   # [B,nc,H]

    # ---- within-chunk (dual / attention-like) term ----
    rel = cum[:, :, :, None, :] - cum[:, :, None, :, :]       # [B,nc,Qi,Qj,H]
    tri = jnp.tril(jnp.ones((q, q), bool))
    l_kernel = jnp.where(tri[None, None, :, :, None], jnp.exp(rel), 0.0)
    scores = jnp.einsum("bcin,bcjn->bcij", ccc, bc).astype(jnp.float32)
    w = scores[..., None] * l_kernel * dtc[:, :, None]        # [B,nc,Qi,Qj,H]
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w.astype(u.dtype), xh)

    # ---- chunk boundary states + inter-chunk recurrence ----
    decay_to_end = jnp.exp(seg_sum[:, :, None] - cum)         # [B,nc,Q,H]
    chunk_states = jnp.einsum(
        "bcqn,bcqh,bcqhp->bchnp",
        bc.astype(jnp.float32), (dtc * decay_to_end), xh.astype(jnp.float32))

    def scan_fn(state, inp):
        cs, seg = inp                                         # [B,H,N,P], [B,H]
        out_state = state                                      # state BEFORE chunk
        new_state = state * jnp.exp(seg)[..., None, None] + cs
        return new_state, out_state

    init = jnp.zeros((b, h, n, p), jnp.float32)
    final_state, prev_states = jax.lax.scan(
        scan_fn, init,
        (chunk_states.transpose(1, 0, 2, 3, 4), seg_sum.transpose(1, 0, 2)))
    prev_states = prev_states.transpose(1, 0, 2, 3, 4)        # [B,nc,H,N,P]

    decay_from_start = jnp.exp(cum)                           # [B,nc,Q,H]
    y_inter = jnp.einsum(
        "bcqn,bchnp->bcqhp", ccc.astype(jnp.float32), prev_states)
    y_inter = y_inter * decay_from_start[..., None]

    y = y_intra.astype(jnp.float32) + y_inter
    y = y + xh.astype(jnp.float32) * params["d_skip"][:, None]
    y = y.reshape(b, s, h * p).astype(u.dtype)
    out = _gated_out(params, cfg, y, z)
    if return_cache:
        return out, {"state": final_state, **conv_tails}
    return out


# ---------------------------------------------------------------------------
# decode
# ---------------------------------------------------------------------------

def init_ssm_cache(cfg: ModelConfig, batch: int, dtype) -> dict:
    h, p, n = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    k = cfg.ssm_conv
    return {
        "state": jnp.zeros((batch, h, n, p), jnp.float32),
        "conv_x": jnp.zeros((batch, k - 1, cfg.d_inner), dtype),
        "conv_b": jnp.zeros((batch, k - 1, n), dtype),
        "conv_c": jnp.zeros((batch, k - 1, n), dtype),
    }


def spec_ssm_cache() -> dict:
    return {"state": ("batch", "ssm_heads", None, None),
            "conv_x": ("batch", None, "conv_dim"),
            "conv_b": ("batch", None, None),
            "conv_c": ("batch", None, None)}


def ssm_decode_step(params: dict, cfg: ModelConfig, u: Array,
                    cache: dict) -> tuple[Array, dict]:
    """One-token recurrent update.  u: [B, 1, D]."""
    b = u.shape[0]
    h, p, n = cfg.ssm_heads, cfg.ssm_headdim, cfg.ssm_state
    z, x, bb, cc, dt = _project(params, cfg, u)
    x, ncx = _causal_conv(x, params["conv_x"], cache["conv_x"])
    bb, ncb = _causal_conv(bb, params["conv_b"], cache["conv_b"])
    cc, ncc = _causal_conv(cc, params["conv_c"], cache["conv_c"])

    a = -jnp.exp(params["a_log"].astype(jnp.float32))
    da = jnp.exp(dt[:, 0] * a)                                # [B,H]
    xh = x.reshape(b, h, p).astype(jnp.float32)
    binp = bb[:, 0].astype(jnp.float32)                       # [B,N]
    state = cache["state"] * da[..., None, None] + jnp.einsum(
        "bn,bh,bhp->bhnp", binp, dt[:, 0], xh)
    y = jnp.einsum("bn,bhnp->bhp", cc[:, 0].astype(jnp.float32), state)
    y = y + xh * params["d_skip"][:, None]
    y = y.reshape(b, 1, h * p).astype(u.dtype)
    out = _gated_out(params, cfg, y, z)
    new_cache = {"state": state, "conv_x": ncx, "conv_b": ncb, "conv_c": ncc}
    return out, new_cache
