"""Encoder-decoder transformer (whisper-large-v3 backbone).

Per the assignment brief the conv/mel frontend is a **stub**: the encoder
consumes precomputed frame embeddings [B, enc_seq, d_model] supplied by
``input_specs``.  Sinusoidal position encodings are added to the frames
(as in whisper); the decoder uses RoPE self-attention (documented deviation
from whisper's learned positions — see DESIGN.md) plus cross-attention to
the encoder memory.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import attention as attn_lib
from repro.models import layers as L
from repro.models.config import ModelConfig
from repro.parallel.sharding import constrain

Array = jax.Array


def sinusoids(length: int, channels: int) -> np.ndarray:
    half = channels // 2
    t = np.log(10000.0) / (half - 1)
    inv = np.exp(-t * np.arange(half))
    ang = np.arange(length)[:, None] * inv[None, :]
    return np.concatenate([np.sin(ang), np.cos(ang)], axis=1).astype(np.float32)


# ---------------------------------------------------------------------------
# init / specs
# ---------------------------------------------------------------------------

def _init_enc_layer(key, cfg):
    k1, k2 = jax.random.split(key)
    return {"ln1": L.init_rmsnorm(cfg.d_model),
            "attn": attn_lib.init_attention(k1, cfg),
            "ln2": L.init_rmsnorm(cfg.d_model),
            "mlp": L.init_mlp(k2, cfg)}


def _init_dec_layer(key, cfg):
    k1, k2, k3 = jax.random.split(key, 3)
    return {"ln1": L.init_rmsnorm(cfg.d_model),
            "self_attn": attn_lib.init_attention(k1, cfg),
            "ln_x": L.init_rmsnorm(cfg.d_model),
            "cross_attn": attn_lib.init_attention(k2, cfg),
            "ln2": L.init_rmsnorm(cfg.d_model),
            "mlp": L.init_mlp(k3, cfg)}


def _spec_enc_layer(cfg):
    return {"ln1": L.spec_rmsnorm(), "attn": attn_lib.spec_attention(),
            "ln2": L.spec_rmsnorm(), "mlp": L.spec_mlp(cfg)}


def _spec_dec_layer(cfg):
    return {"ln1": L.spec_rmsnorm(), "self_attn": attn_lib.spec_attention(),
            "ln_x": L.spec_rmsnorm(), "cross_attn": attn_lib.spec_attention(),
            "ln2": L.spec_rmsnorm(), "mlp": L.spec_mlp(cfg)}


def init_encdec(key, cfg: ModelConfig) -> dict:
    ke, kenc, kdec = jax.random.split(key, 3)
    enc_keys = jax.random.split(kenc, cfg.n_enc_layers)
    dec_keys = jax.random.split(kdec, cfg.n_layers)
    return {
        "embed": L.init_embed(ke, cfg),
        "enc_blocks": jax.vmap(lambda k: _init_enc_layer(k, cfg))(enc_keys),
        "enc_norm": L.init_rmsnorm(cfg.d_model),
        "dec_blocks": jax.vmap(lambda k: _init_dec_layer(k, cfg))(dec_keys),
        "final_norm": L.init_rmsnorm(cfg.d_model),
    }


def spec_encdec(cfg: ModelConfig) -> dict:
    def stack(tree):
        return jax.tree.map(lambda t: ("layers",) + t, tree,
                            is_leaf=lambda t: isinstance(t, tuple))
    return {
        "embed": L.spec_embed(cfg),
        "enc_blocks": stack(_spec_enc_layer(cfg)),
        "enc_norm": L.spec_rmsnorm(),
        "dec_blocks": stack(_spec_dec_layer(cfg)),
        "final_norm": L.spec_rmsnorm(),
    }


# ---------------------------------------------------------------------------
# forward
# ---------------------------------------------------------------------------

def encode(params: dict, cfg: ModelConfig, frames: Array) -> Array:
    """frames: [B, enc_seq, D] precomputed embeddings (frontend stub)."""
    x = frames.astype(cfg.activation_dtype())
    x = x + jnp.asarray(sinusoids(x.shape[1], cfg.d_model)).astype(x.dtype)
    x = constrain(x, "batch", "seq", "embed")
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)

    def step(carry, lp):
        h = L.rmsnorm(lp["ln1"], carry, cfg.norm_eps)
        carry = carry + attn_lib.attention(lp["attn"], cfg, h, positions,
                                           causal=False, rope=False)
        h = L.rmsnorm(lp["ln2"], carry, cfg.norm_eps)
        carry = carry + L.mlp(lp["mlp"], cfg, h)
        return constrain(carry, "batch", "seq", "embed"), None

    x, _ = jax.lax.scan(step, x, params["enc_blocks"])
    return L.rmsnorm(params["enc_norm"], x, cfg.norm_eps)


def _dec_superblock(cfg, positions, memory, carry, lp):
    x = carry
    h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
    x = x + attn_lib.attention(lp["self_attn"], cfg, h, positions)
    h = L.rmsnorm(lp["ln_x"], x, cfg.norm_eps)
    kv = attn_lib.project_cross_kv(lp["cross_attn"], cfg, memory)
    x = x + attn_lib.attention(lp["cross_attn"], cfg, h, positions,
                               causal=False, rope=False, kv_override=kv)
    h = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
    x = x + L.mlp(lp["mlp"], cfg, h)
    return constrain(x, "batch", "seq", "embed"), None


def forward(params: dict, cfg: ModelConfig, tokens: Array,
            frames: Array) -> tuple[Array, Array]:
    """Teacher-forced decode over the full target sequence."""
    memory = encode(params, cfg, frames)
    x = L.embed_tokens(params["embed"], cfg, tokens)
    x = constrain(x, "batch", "seq", "embed")
    positions = jnp.arange(x.shape[1], dtype=jnp.int32)
    step = functools.partial(_dec_superblock, cfg, positions, memory)
    if cfg.remat in ("full", "dots"):
        step = jax.checkpoint(step, prevent_cse=False)
    x, _ = jax.lax.scan(step, x, params["dec_blocks"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], cfg, x)
    return constrain(logits, "batch", "seq", "vocab"), jnp.zeros((), jnp.float32)


# ---------------------------------------------------------------------------
# serving: prefill + decode with self-attn cache and precomputed cross-KV
# ---------------------------------------------------------------------------

def init_dec_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    dt = cfg.activation_dtype()
    one = {"self": attn_lib.init_cache(cfg, batch, max_len, dt),
           "cross_k": jnp.zeros((batch, cfg.enc_seq, cfg.n_kv_heads,
                                 cfg.head_dim), dt),
           "cross_v": jnp.zeros((batch, cfg.enc_seq, cfg.n_kv_heads,
                                 cfg.head_dim), dt)}
    return jax.tree.map(
        lambda x: jnp.broadcast_to(x, (cfg.n_layers,) + x.shape), one)


def spec_dec_cache(cfg: ModelConfig) -> dict:
    c = {"self": attn_lib.spec_cache(),
         "cross_k": ("batch", None, "kv_heads", "head_dim"),
         "cross_v": ("batch", None, "kv_heads", "head_dim")}
    return jax.tree.map(lambda t: ("layers",) + t, c,
                        is_leaf=lambda t: isinstance(t, tuple))


def prefill(params: dict, cfg: ModelConfig, tokens: Array, frames: Array,
            max_len: int | None = None) -> tuple[Array, dict]:
    """Encode + teacher-forced pass building self/cross caches."""
    memory = encode(params, cfg, frames)
    x = L.embed_tokens(params["embed"], cfg, tokens)
    x = constrain(x, "batch", "seq", "embed")
    s = x.shape[1]
    max_len = max_len or cfg.max_cache_len or s
    positions = jnp.arange(s, dtype=jnp.int32)
    dt = cfg.activation_dtype()

    def step(carry, lp):
        x = carry
        h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
        y, (k, v) = attn_lib.attention(lp["self_attn"], cfg, h, positions,
                                       return_kv=True)
        x = x + y
        entry = attn_lib.init_cache(cfg, x.shape[0], max_len, dt)
        self_cache = attn_lib.prefill_into_cache(entry, k, v)
        h = L.rmsnorm(lp["ln_x"], x, cfg.norm_eps)
        ck, cv = attn_lib.project_cross_kv(lp["cross_attn"], cfg, memory)
        x = x + attn_lib.attention(lp["cross_attn"], cfg, h, positions,
                                   causal=False, rope=False,
                                   kv_override=(ck, cv))
        h = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
        x = x + L.mlp(lp["mlp"], cfg, h)
        x = constrain(x, "batch", "seq", "embed")
        return x, {"self": self_cache, "cross_k": ck.astype(dt),
                   "cross_v": cv.astype(dt)}

    x, cache = jax.lax.scan(step, x, params["dec_blocks"])
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], cfg, x[:, -1:])[:, 0]
    return constrain(logits, "batch", "vocab"), cache


def decode_step(params: dict, cfg: ModelConfig, token: Array, cache: dict,
                pos: Array) -> tuple[Array, dict]:
    x = L.embed_tokens(params["embed"], cfg, token[:, None])
    x = constrain(x, "batch", None, "embed")

    def step(carry, xs):
        x = carry
        lp, lc = xs
        h = L.rmsnorm(lp["ln1"], x, cfg.norm_eps)
        y, self_cache = attn_lib.decode_attention(lp["self_attn"], cfg, h,
                                                  lc["self"], pos)
        x = x + y
        h = L.rmsnorm(lp["ln_x"], x, cfg.norm_eps)
        y, _ = attn_lib.decode_attention(
            lp["cross_attn"], cfg, h,
            {"k": lc["cross_k"], "v": lc["cross_v"]},
            jnp.asarray(cfg.enc_seq - 1, jnp.int32),
            rope=False, update_cache=False)
        x = x + y
        h = L.rmsnorm(lp["ln2"], x, cfg.norm_eps)
        x = x + L.mlp(lp["mlp"], cfg, h)
        return x, {"self": self_cache, "cross_k": lc["cross_k"],
                   "cross_v": lc["cross_v"]}

    x, new_cache = jax.lax.scan(step, x, (params["dec_blocks"], cache))
    x = L.rmsnorm(params["final_norm"], x, cfg.norm_eps)
    logits = L.unembed(params["embed"], cfg, x)[:, 0]
    return constrain(logits, "batch", "vocab"), new_cache
