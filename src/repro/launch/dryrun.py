import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape) cell.

This is the proof that the distribution config is coherent without real
hardware: 512 placeholder CPU devices build the production meshes, every
cell's step function must ``.lower().compile()``, and the compiled artifact
yields ``memory_analysis()`` (fits?) and ``cost_analysis()`` (FLOPs/bytes for
the roofline).  Results are dumped as JSON for EXPERIMENTS.md.

Usage:
    PYTHONPATH=src python -m repro.launch.dryrun --arch tinyllama-1.1b \
        --shape train_4k --mesh both
    PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
"""

import argparse
import json
import re
import sys
import time
import traceback
from pathlib import Path

import jax

from repro import configs
from repro.launch import specs as specs_lib
from repro.launch.mesh import describe, make_production_mesh

# bytes moved per collective op are summed from the lowered stablehlo/HLO
_COLLECTIVE_RE = re.compile(
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)")

_DTYPE_BYTES = {"f64": 8, "f32": 4, "s64": 8, "u64": 8, "f16": 2, "bf16": 2,
                "s32": 4, "u32": 4, "s16": 2, "u16": 2, "pred": 1, "s8": 1,
                "u8": 1, "c64": 8, "c128": 16}

_SHAPE_RE = re.compile(r"(f64|f32|f16|bf16|s64|u64|s32|u32|s16|u16|s8|u8|pred|c64|c128)\[([0-9,]*)\]")


def _shape_bytes(text: str) -> int:
    total = 0
    for m in _SHAPE_RE.finditer(text):
        dt, dims = m.group(1), m.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Sum operand bytes of every collective op in the compiled HLO."""
    out = {"all-gather": 0, "all-reduce": 0, "reduce-scatter": 0,
           "all-to-all": 0, "collective-permute": 0, "ops": 0}
    for line in hlo_text.splitlines():
        s = line.strip()
        # match op kind in the instruction name, e.g. "%all-reduce.5 = ..."
        m = re.match(r"%?[\w.-]*\b(all-gather|all-reduce|reduce-scatter|"
                     r"all-to-all|collective-permute)[\w.-]*\s*=", s)
        if not m:
            continue
        if "-start" in s.split("=")[0] and "-done" not in s.split("=")[0]:
            pass  # async start carries the payload shape; done repeats it
        if "-done" in s.split("=")[0]:
            continue
        kind = m.group(1)
        # output shape(s) = bytes moved (per device)
        lhs = s.split("=", 1)[1]
        lhs = lhs.split("(")[0] if "(" in lhs else lhs
        out[kind] += _shape_bytes(lhs)
        out["ops"] += 1
    return out


def run_cell(arch: str, shape_name: str, mesh, multi_pod: bool,
             verbose: bool = True) -> dict:
    t0 = time.time()
    cell = specs_lib.build_cell(arch, shape_name, mesh, multi_pod=multi_pod)
    lowered = cell.lower()
    t_lower = time.time() - t0
    compiled = lowered.compile()
    t_compile = time.time() - t0 - t_lower

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    hlo = compiled.as_text()
    coll = collective_bytes(hlo)

    result = {
        "arch": arch, "shape": shape_name, "mesh": describe(mesh),
        "multi_pod": multi_pod,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "flops": cost.get("flops", 0.0) if cost else 0.0,
        "bytes_accessed": cost.get("bytes accessed", 0.0) if cost else 0.0,
        "argument_bytes": getattr(mem, "argument_size_in_bytes", 0),
        "output_bytes": getattr(mem, "output_size_in_bytes", 0),
        "temp_bytes": getattr(mem, "temp_size_in_bytes", 0),
        "peak_bytes": (getattr(mem, "argument_size_in_bytes", 0)
                       + getattr(mem, "temp_size_in_bytes", 0)),
        "collectives": coll,
    }
    if verbose:
        gb = 1 << 30
        print(f"  [OK] {arch} x {shape_name} on {describe(mesh)}: "
              f"lower {t_lower:.0f}s compile {t_compile:.0f}s | "
              f"args {result['argument_bytes']/gb:.2f}GiB "
              f"temp {result['temp_bytes']/gb:.2f}GiB | "
              f"flops/dev {result['flops']:.3g} | "
              f"coll ops {coll['ops']}")
    return result


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--mesh", choices=["single", "multi", "both"],
                    default="both")
    ap.add_argument("--out", default="experiments/dryrun")
    args = ap.parse_args(argv)

    if args.all:
        cells = configs.grid()
    else:
        if not args.arch:
            ap.error("--arch or --all required")
        shapes = [args.shape] if args.shape else configs.shapes_for(args.arch)
        cells = [(args.arch, s) for s in shapes]

    mesh_kinds = {"single": [False], "multi": [True],
                  "both": [False, True]}[args.mesh]
    outdir = Path(args.out)
    outdir.mkdir(parents=True, exist_ok=True)

    failures = []
    for multi_pod in mesh_kinds:
        mesh = make_production_mesh(multi_pod=multi_pod)
        print(f"== mesh {describe(mesh)} ({len(mesh.devices.flat)} chips) ==")
        for arch, shape in cells:
            tag = f"{arch}__{shape}__{'multi' if multi_pod else 'single'}"
            try:
                result = run_cell(arch, shape, mesh, multi_pod)
                (outdir / f"{tag}.json").write_text(json.dumps(result, indent=1))
            except Exception as e:  # a failure here is a sharding bug
                failures.append((tag, repr(e)))
                print(f"  [FAIL] {tag}: {e}")
                traceback.print_exc(limit=4)

    print(f"\n{len(cells) * len(mesh_kinds) - len(failures)} passed, "
          f"{len(failures)} failed")
    for tag, err in failures:
        print(f"  FAIL {tag}: {err[:200]}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
