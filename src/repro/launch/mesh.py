"""Production mesh factory.

A function (not a module-level constant) so importing this module never
touches jax device state.  Single pod: 16x16 = 256 chips over
("data", "model"); multi-pod: 2 pods x 256 = 512 chips with the leading
"pod" axis (DCI links between pods, ICI within).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def mesh_dp_shards(mesh) -> int:
    """Total data-parallel shards (pod x data)."""
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    return sizes.get("pod", 1) * sizes.get("data", 1)


def describe(mesh) -> str:
    return "x".join(f"{n}={s}" for n, s in
                    zip(mesh.axis_names, mesh.devices.shape))
