"""Per-(arch x shape) cell construction for the dry-run and benchmarks.

Builds, without allocating anything:
  * the runtime-adjusted ModelConfig (bf16, remat, expert groups, cache len);
  * the sharding rules profile for the shape kind (the long-context profile
    moves the DP axes from batch to the KV/cache sequence dim);
  * ShapeDtypeStruct stand-ins for every input (weak-type-correct, shardable);
  * NamedSharding pytrees for inputs/outputs;
  * the step function to lower (train_step / prefill / serve_step).
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro import configs
from repro.launch.mesh import mesh_dp_shards
from repro.models.api import Model
from repro.optim.adamw import AdamW
from repro.parallel import sharding as sh
from repro.train import step as train_step_lib

S = jax.ShapeDtypeStruct


# ---------------------------------------------------------------------------
# per-arch runtime profiles (memory/perf knobs, see EXPERIMENTS.md §Perf)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class ArchProfile:
    fsdp_params: bool = False        # shard param embed dims over data
    moment_dtype: str = "float32"
    grad_compression: bool = False
    accum_steps: int = 1
    remat: str = "full"              # train-time activation checkpointing
    #: train with pure data-parallelism over BOTH mesh axes (no TP).  For
    #: models whose params+opt fit on one chip, Megatron-TP activation
    #: all-reduces (~2.5GB/layer/step) cost far more ICI than the single
    #: gradient all-reduce pure DP needs (§Perf cell B).
    pure_dp_train: bool = False


PROFILES: dict[str, ArchProfile] = {
    # 400B: params cannot replicate over DP — FSDP the embed dims, compress
    # grads over DCI, bf16 moments.
    "llama4-maverick-400b-a17b": ArchProfile(
        fsdp_params=True, moment_dtype="bfloat16", grad_compression=True,
        accum_steps=4),
    "qwen2-moe-a2.7b": ArchProfile(fsdp_params=True),
    "tinyllama-1.1b": ArchProfile(
        pure_dp_train=True, moment_dtype="bfloat16", grad_compression=True,
        remat="outputs"),
}

_DEFAULT_PROFILE = ArchProfile()


def profile_for(arch: str) -> ArchProfile:
    return PROFILES.get(arch, _DEFAULT_PROFILE)


# ---------------------------------------------------------------------------
# rules per shape kind
# ---------------------------------------------------------------------------

def rules_for(arch: str, shape_name: str, multi_pod: bool) -> sh.ShardingRules:
    base = sh.default_rules(multi_pod).rules.copy()
    prof = profile_for(arch)
    kind = configs.SHAPES[shape_name].kind
    if prof.fsdp_params and kind != "decode":
        # parameters' embed dims shard over data (FSDP); activation
        # constraints dedupe "batch" vs "embed" automatically.  Decode is
        # excluded: re-gathering FSDP shards every decode step costs ~GBs of
        # ICI per token, while inference weights (no optimizer state) fit
        # replicated over data (see EXPERIMENTS.md §Perf cell C).
        base["mlp_embed"] = "data"
        base["embed"] = "data"
    if prof.pure_dp_train and kind == "train":
        gb = configs.SHAPES[shape_name].global_batch
        if multi_pod and gb % 512 == 0:
            dp_axes: tuple = ("pod", "data", "model")
        elif multi_pod:
            # batch doesn't divide 512: shard over 256 and replicate across
            # pods (grad AR still averages correctly; pods duplicate compute
            # — preferable to TP's per-layer activation ARs for tiny models)
            dp_axes = ("data", "model")
        else:
            dp_axes = ("data", "model")
        for name in ("heads", "kv_heads", "ffn", "vocab", "experts",
                     "expert_ffn", "ssm_heads", "conv_dim"):
            base[name] = None
        base["batch"] = dp_axes
        base["expert_group"] = dp_axes
        base["fsdp"] = dp_axes
    if shape_name == "long_500k":
        base["batch"] = None
        base["expert_group"] = None
        base["kv_seq"] = ("pod", "data") if multi_pod else ("data",)
    elif configs.SHAPES[shape_name].kind == "decode":
        # batched decode: batch over DP axes, cache *sequence* over "model"
        # (distributed flash-decode: GSPMD lowers the softmax/value reductions
        # over the sharded length to small all-reduces).  KV heads replicate —
        # sharding them fragments GSPMD propagation through the GQA reshape
        # and forces replicate-repartition copies of the whole cache.  Q/O
        # projection weights shard over heads (padded to 16); the tiny q
        # activation is re-replicated right after projection (decode_attention)
        # so the cache einsums stay in the seq-sharded layout.
        base["kv_seq"] = "model"
        base["kv_heads"] = None
    return sh.ShardingRules(rules=base)


def runtime_config(arch: str, shape_name: str, multi_pod: bool):
    cfg = configs.get_config(arch)
    shape = configs.SHAPES[shape_name]
    prof = profile_for(arch)
    dp = 32 if multi_pod else 16
    upd: dict[str, Any] = {
        "dtype": "bfloat16",
        "expert_groups": max(1, min(dp, shape.global_batch)),
        "remat": prof.remat if shape.kind == "train" else "none",
        "max_cache_len": shape.seq_len if shape.kind == "decode" else 0,
    }
    if cfg.vocab_size % 256:
        # pad the vocab (standard practice) so the vocab axis shards 16-way;
        # padded logits are masked to -inf in unembed (vocab_real).
        upd["vocab_size"] = -(-cfg.vocab_size // 256) * 256
        upd["vocab_real"] = cfg.vocab_size
    if cfg.n_heads > 1 and cfg.n_heads % 16 and cfg.n_kv_heads < cfg.n_heads:
        # (GQA/MQA only: padding an MHA arch (kv == heads) forces a KV
        # expansion gather whose backward costs more than the sharding win —
        # measured on whisper, §Perf notes)
        # pad q-heads with dead (masked, zero) heads so attention weights &
        # compute shard over the 16-way model axis instead of replicating
        # (§Perf: 40-head llama4 was reading ~100MB/layer of replicated
        # attention weights per device).  Semantics-preserving: the GQA
        # head->kv map keeps the original grouping for real heads.
        upd["head_pad"] = -(-cfg.n_heads // 16) * 16 - cfg.n_heads
    return dataclasses.replace(cfg, **upd), shape


# ---------------------------------------------------------------------------
# input specs
# ---------------------------------------------------------------------------

def _tokens_seq_len(cfg, shape) -> int:
    if cfg.family == "vlm":
        return shape.seq_len - cfg.n_vis_tokens
    return shape.seq_len


def batch_specs(cfg, shape) -> dict:
    """ShapeDtypeStruct stand-ins for one global batch."""
    b = shape.global_batch
    s = _tokens_seq_len(cfg, shape)
    specs = {"tokens": S((b, s), jnp.int32), "labels": S((b, s), jnp.int32)}
    if cfg.family == "vlm":
        specs["vis_embed"] = S((b, cfg.n_vis_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "encdec":
        specs["frames"] = S((b, cfg.enc_seq, cfg.d_model), jnp.bfloat16)
    return specs


def batch_shardings(mesh, rules) -> dict:
    def ns(*names):
        return NamedSharding(mesh, rules.spec(*names))
    return {"tokens": ns("batch", None), "labels": ns("batch", None),
            "vis_embed": ns("batch", None, None),
            "frames": ns("batch", None, None)}


def _axis_size(mesh, ax) -> int:
    sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
    if isinstance(ax, tuple):
        n = 1
        for a in ax:
            n *= sizes[a]
        return n
    return sizes[ax]


def _is_logical_leaf(t):
    return isinstance(t, tuple) and all(
        isinstance(i, (str, type(None))) for i in t)


def resolve_shardings(mesh, rules, spec_tree, shapes_tree):
    """Logical specs -> NamedShardings, dropping axes that don't divide.

    pjit requires argument shardings to divide the dimension exactly; any
    logical assignment that doesn't (e.g. 24 heads on a 16-way model axis)
    falls back to replication for that dim.  The roofline table makes such
    replication visible (it shows up as compute/memory waste to hillclimb).
    """
    def leaf(t, shape_struct):
        p = rules.spec(*t)
        dims = shape_struct.shape
        fixed = [ax if (ax is None or dims[i] % _axis_size(mesh, ax) == 0)
                 else None
                 for i, ax in enumerate(tuple(p) + (None,) * (len(dims) - len(p)))]
        return NamedSharding(mesh, P(*fixed))

    return jax.tree.map(leaf, spec_tree, shapes_tree,
                        is_leaf=_is_logical_leaf)


def logical_tree_to_shardings(mesh, rules, spec_tree):
    return jax.tree.map(
        lambda t: NamedSharding(mesh, rules.spec(*t)), spec_tree,
        is_leaf=_is_logical_leaf)


# ---------------------------------------------------------------------------
# cell: everything needed to lower one (arch x shape) on one mesh
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class Cell:
    arch: str
    shape_name: str
    cfg: Any
    model: Model
    rules: sh.ShardingRules
    mesh: Any
    fn: Callable
    args: tuple
    in_shardings: tuple
    out_shardings: Any
    donate: tuple[int, ...]

    def lower(self):
        with sh.use_mesh_and_rules(self.mesh, self.rules):
            jitted = jax.jit(self.fn, in_shardings=self.in_shardings,
                             out_shardings=self.out_shardings,
                             donate_argnums=self.donate)
            return jitted.lower(*self.args)


def build_cell(arch: str, shape_name: str, mesh, *, multi_pod: bool) -> Cell:
    cfg, shape = runtime_config(arch, shape_name, multi_pod)
    rules = rules_for(arch, shape_name, multi_pod)
    prof = profile_for(arch)
    model = Model(cfg)

    pspec_tree = model.param_specs()
    param_shapes = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    if shape.kind in ("prefill", "decode"):
        # serving holds bf16 weights: halves weight-read bytes (the decode
        # memory floor) and weight all-gather traffic vs f32 training params.
        param_shapes = jax.tree.map(
            lambda s: S(s.shape, jnp.bfloat16)
            if s.dtype == jnp.float32 else s, param_shapes)
    param_sh = resolve_shardings(mesh, rules, pspec_tree, param_shapes)

    logit_sh = NamedSharding(mesh, rules.spec("batch", "vocab"))

    if shape.kind == "train":
        opt = AdamW(lr=1e-4,
                    moment_dtype=getattr(jnp, prof.moment_dtype),
                    grad_compression=prof.grad_compression)
        tstep = train_step_lib.make_train_step(model, opt,
                                               accum_steps=prof.accum_steps)
        state_shapes = train_step_lib.TrainState(
            params=param_shapes,
            opt=jax.eval_shape(opt.init, param_shapes))
        state_sh = train_step_lib.TrainState(
            params=param_sh,
            opt=resolve_shardings(mesh, rules, opt.state_specs(pspec_tree),
                                  state_shapes.opt))
        bsh = {k: v for k, v in batch_shardings(mesh, rules).items()
               if k in batch_specs(cfg, shape)}
        return Cell(arch, shape_name, cfg, model, rules, mesh, tstep,
                    (state_shapes, batch_specs(cfg, shape)),
                    (state_sh, bsh), (state_sh, None), (0,))

    if shape.kind == "prefill":
        def prefill_fn(params, batch):
            return model.prefill(params, batch, max_len=shape.seq_len)
        bsh = {k: v for k, v in batch_shardings(mesh, rules).items()
               if k in batch_specs(cfg, shape)}
        cache_shapes = jax.eval_shape(
            functools.partial(model.init_cache, shape.global_batch,
                              shape.seq_len))
        cache_sh = resolve_shardings(mesh, rules, model.cache_specs(),
                                     cache_shapes)
        return Cell(arch, shape_name, cfg, model, rules, mesh, prefill_fn,
                    (param_shapes, batch_specs(cfg, shape)),
                    (param_sh, bsh), (logit_sh, cache_sh), ())

    # decode: one new token against a seq_len cache
    def serve_step(params, cache, token, pos):
        return model.decode_step(params, token, cache, pos)

    b = shape.global_batch
    cache_shapes = jax.eval_shape(
        functools.partial(model.init_cache, b, shape.seq_len))
    cache_sh = resolve_shardings(mesh, rules, model.cache_specs(),
                                 cache_shapes)
    token_spec = S((b,), jnp.int32)
    pos_spec = S((), jnp.int32)
    return Cell(arch, shape_name, cfg, model, rules, mesh, serve_step,
                (param_shapes, cache_shapes, token_spec, pos_spec),
                (param_sh, cache_sh, NamedSharding(mesh, rules.spec("batch")),
                 NamedSharding(mesh, P())),
                (logit_sh, cache_sh), (1,))
