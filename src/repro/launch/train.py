"""End-to-end training driver.

Runs a real training loop with the full substrate: deterministic resumable
data stream, jitted train step, async checkpointing, straggler monitoring
and (simulated) failure recovery via the elastic planner.  On CPU it runs
reduced configs; on a TPU fleet the same driver runs the full configs with
the production mesh (--multi-pod).

Examples:
    PYTHONPATH=src python -m repro.launch.train --arch tinyllama-1.1b \
        --reduced --steps 200 --batch 8 --seq 128
    PYTHONPATH=src python -m repro.launch.train --arch qwen2-moe-a2.7b \
        --reduced --steps 50 --resume --ckpt-dir /tmp/ck
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.data import TokenStream
from repro.models import Model
from repro.optim import AdamW, cosine_schedule
from repro.runtime import FailureInjector, StragglerMonitor, plan_recovery
from repro.runtime.failures import Failure
from repro.train import step as step_lib


def make_batch_arrays(cfg, raw, key):
    batch = {"tokens": jnp.asarray(raw["tokens"]),
             "labels": jnp.asarray(raw["labels"])}
    if cfg.family == "vlm":
        batch["vis_embed"] = 0.02 * jax.random.normal(
            key, (batch["tokens"].shape[0], cfg.n_vis_tokens, cfg.d_model))
    if cfg.family == "encdec":
        batch["frames"] = 0.02 * jax.random.normal(
            key, (batch["tokens"].shape[0], cfg.enc_seq, cfg.d_model))
    return batch


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--inject-straggler", type=int, default=-1,
                    help="simulate a straggler host from this step")
    ap.add_argument("--log-every", type=int, default=10)
    args = ap.parse_args(argv)

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    model = Model(cfg)
    opt = AdamW(lr=cosine_schedule(args.lr, 20, args.steps), weight_decay=0.01)
    train_step = jax.jit(step_lib.make_train_step(model, opt,
                                                  accum_steps=args.accum))

    stream = TokenStream(vocab_size=cfg.vocab_size, seq_len=args.seq,
                         global_batch=args.batch, seed=0)
    state = step_lib.init_state(model, opt, jax.random.PRNGKey(0))
    start_step = 0

    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    if mgr and args.resume and mgr.latest_step() is not None:
        restored, meta = mgr.restore(None, like=state)
        state, start_step = restored, meta["data_step"]
        print(f"resumed from step {meta['step']} (data step {start_step})")

    monitor = StragglerMonitor(num_hosts=4)
    injector = FailureInjector(
        [Failure(step=args.inject_straggler, kind="straggler", host=1)]
        if args.inject_straggler >= 0 else [])

    print(f"training {cfg.name}: {model.param_count()/1e6:.1f}M params, "
          f"{args.steps} steps")
    t_last = time.time()
    for step_i in range(start_step, args.steps):
        injector.at_step(step_i)
        raw = stream.batch(step_i)
        batch = make_batch_arrays(cfg, raw, jax.random.PRNGKey(step_i))
        state, metrics = train_step(state, batch)

        t_now = time.time()
        host_times = np.asarray([injector.step_time(h, t_now - t_last)
                                 for h in range(4)])
        monitor.observe(host_times)
        t_last = t_now
        if monitor.persistent():
            bad = monitor.persistent()
            plan = plan_recovery(512 - 4 * len(bad))
            print(f"[ft] persistent stragglers {bad}; recovery plan: "
                  f"mesh={plan.mesh_shape} accum x{plan.accum_multiplier}")
            if mgr:
                mgr.save_async(step_i + 1, state, data_step=step_i + 1)
            monitor = StragglerMonitor(num_hosts=4)  # fresh after re-mesh

        if step_i % args.log_every == 0 or step_i == args.steps - 1:
            loss = float(metrics["loss"])
            print(f"step {step_i:5d} loss {loss:.4f} "
                  f"gnorm {float(metrics['grad_norm']):.3f}")
            if not np.isfinite(loss):
                raise RuntimeError("loss diverged")
        if mgr and (step_i + 1) % args.ckpt_every == 0:
            mgr.save_async(step_i + 1, state, data_step=step_i + 1)

    if mgr:
        mgr.save(args.steps, state, data_step=args.steps)
        mgr.wait()
    print("done")
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
