"""Serving driver: prefill + batched decode with a KV cache.

Implements the serve path end to end: request batching, prefill to build
caches, greedy/temperature decode loop, and per-step latency stats.  On CPU
it serves reduced configs; the same step functions are what the dry-run
lowers for the production meshes.

Example:
    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
        --reduced --batch 4 --prompt-len 32 --gen 32
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import Model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--temperature", type=float, default=0.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args(argv)

    cfg = (configs.get_reduced(args.arch) if args.reduced
           else configs.get_config(args.arch))
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(args.seed))

    b, s = args.batch, args.prompt_len
    key = jax.random.PRNGKey(args.seed + 1)
    batch = {"tokens": jax.random.randint(key, (b, s), 0, cfg.vocab_size)}
    if cfg.family == "vlm":
        batch["vis_embed"] = 0.02 * jax.random.normal(
            key, (b, cfg.n_vis_tokens, cfg.d_model))
    if cfg.family == "encdec":
        batch["frames"] = 0.02 * jax.random.normal(
            key, (b, cfg.enc_seq, cfg.d_model))
    vis = cfg.n_vis_tokens if cfg.family == "vlm" else 0
    max_len = s + vis + args.gen + 1

    prefill = jax.jit(lambda p, bt: model.prefill(p, bt, max_len=max_len))
    decode = jax.jit(lambda p, t, c, pos: model.decode_step(p, t, c, pos))

    t0 = time.time()
    logits, cache = jax.block_until_ready(prefill(params, batch))
    t_prefill = time.time() - t0
    print(f"prefill[{b}x{s}] {t_prefill*1e3:.1f} ms")

    def sample(logits, key):
        if args.temperature <= 0:
            return jnp.argmax(logits, -1).astype(jnp.int32)
        return jax.random.categorical(key, logits / args.temperature, -1)

    tok = sample(logits, key)
    out_tokens = [np.asarray(tok)]
    lat = []
    for i in range(args.gen):
        t0 = time.time()
        logits, cache = jax.block_until_ready(
            decode(params, tok, cache, jnp.asarray(s + vis + i, jnp.int32)))
        lat.append(time.time() - t0)
        tok = sample(logits, jax.random.fold_in(key, i))
        out_tokens.append(np.asarray(tok))

    lat_ms = np.asarray(lat[1:]) * 1e3  # skip compile step
    print(f"decode: {len(lat)} steps, median {np.median(lat_ms):.2f} ms/tok, "
          f"p99 {np.percentile(lat_ms, 99):.2f} ms")
    gen = np.stack(out_tokens, axis=1)
    print(f"generated[{gen.shape[0]}x{gen.shape[1]}]: row0 = {gen[0][:16]}...")
    assert np.isfinite(lat_ms).all() and (gen >= 0).all()
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
