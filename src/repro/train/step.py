"""The pjit-able training step: loss -> grads -> (compressed) update.

Supports microbatched gradient accumulation (``accum_steps``): the global
batch is split along the batch axis and scanned, which divides activation
memory by the accumulation factor while keeping the same global batch
semantics — the standard memory/perf lever for the train_4k cells.

:func:`make_sgd_step` is the minibatch-SGD step shared by the paper
pipelines (the MNIST RFNN trains with it).  Gradients flow through
whatever backend the model's layers select — with ``backend="pallas"``
on the analog layers the backward pass runs the fused Pallas kernel VJPs
(``repro.kernels``), so training and inference share the same hot loop.
"""

from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models.api import Model
from repro.optim.adamw import AdamW, OptState


@dataclasses.dataclass
class TrainState:
    params: Any
    opt: OptState


jax.tree_util.register_pytree_node(
    TrainState,
    lambda s: ((s.params, s.opt), None),
    lambda aux, children: TrainState(*children))


def init_state(model: Model, optimizer: AdamW, key) -> TrainState:
    params = model.init(key)
    return TrainState(params=params, opt=optimizer.init(params))


def state_specs(model: Model, optimizer: AdamW):
    pspecs = model.param_specs()
    return TrainState(params=pspecs, opt=optimizer.state_specs(pspecs))


def make_sgd_step(loss_fn, lr: float, freeze: tuple[str, ...] = (),
                  mesh=None, data_axis: str = "data",
                  replicated_args: tuple[int, ...] = ()):
    """Plain minibatch-SGD step: ``step(params, *batch) -> (params, (loss, aux))``.

    ``loss_fn(params, *batch) -> (loss, aux)``; top-level param groups named
    in ``freeze`` get zeroed gradients (the paper's stage-2 "deployed
    device" training where the programmed mesh codes are held fixed).

    With ``mesh``, the step is data-parallel over ``mesh[data_axis]``: each
    device computes gradients on its batch shard (through whatever backend
    the model selects — the fused Pallas megakernels run per-shard), loss
    and gradients are ``pmean``-reduced, and the (replicated) update is
    applied in-shard — so the returned params stay identical on every
    device.  Batch args whose leading axis is *not* the batch (PRNG keys,
    scalars) are named in ``replicated_args`` by position.  The sharded
    batch axis must divide by the axis size.
    """

    def _apply(params, *batch, reduce_axis=None):
        (loss, aux), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, *batch)
        if reduce_axis is not None:
            loss = jax.lax.pmean(loss, reduce_axis)
            aux = jax.lax.pmean(aux, reduce_axis)
            grads = jax.lax.pmean(grads, reduce_axis)
        if freeze:
            grads = {k: (jax.tree.map(jnp.zeros_like, v) if k in freeze else v)
                     for k, v in grads.items()}
        params = jax.tree.map(lambda w, g: w - lr * g, params, grads)
        return params, (loss, aux)

    if mesh is None:
        return _apply

    from jax.sharding import PartitionSpec as P

    from repro.parallel.sharding import shard_map_compat

    def dp_step(params, *batch):
        specs = tuple(P() if i in replicated_args else P(data_axis)
                      for i in range(len(batch)))
        fn = shard_map_compat(
            lambda p, *b: _apply(p, *b, reduce_axis=data_axis),
            mesh=mesh, in_specs=(P(),) + specs,
            out_specs=(P(), (P(), P())))
        return fn(params, *batch)

    return dp_step


def make_train_step(model: Model, optimizer: AdamW, accum_steps: int = 1):
    """Build ``train_step(state, batch) -> (state, metrics)``."""

    def loss_fn(params, batch):
        return model.loss(params, batch)

    def grads_of(params, batch):
        (loss, metrics), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, batch)
        return grads, loss, metrics

    def train_step(state: TrainState, batch):
        params = state.params
        if accum_steps == 1:
            grads, loss, metrics = grads_of(params, batch)
            grads = optimizer.compress_grads(grads)
        else:
            def split(x):
                b = x.shape[0]
                assert b % accum_steps == 0, (b, accum_steps)
                return x.reshape((accum_steps, b // accum_steps) + x.shape[1:])

            micro = jax.tree.map(split, batch)

            def body(carry, mb):
                acc, loss_acc = carry
                grads, loss, _ = grads_of(params, mb)
                grads = optimizer.compress_grads(grads)
                acc = jax.tree.map(
                    lambda a, g: a + g.astype(jnp.float32), acc, grads)
                return (acc, loss_acc + loss), None

            zeros = jax.tree.map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, loss_sum), _ = jax.lax.scan(
                body, (zeros, jnp.zeros((), jnp.float32)), micro)
            grads = jax.tree.map(lambda g: g / accum_steps, gsum)
            loss = loss_sum / accum_steps
            metrics = {}

        new_params, new_opt, gnorm = optimizer.update(params, grads, state.opt)
        metrics = dict(metrics)
        metrics.update({"loss": loss, "grad_norm": gnorm,
                        "step": new_opt.step})
        return TrainState(params=new_params, opt=new_opt), metrics

    return train_step
