"""`ServableProgram` — the one surface the serving engine consumes.

PR 8 left three compiled-program variants (`CompiledProgram`,
`CompiledTiledProgram`, `CompiledDeepProgram`) with slightly different
apply conventions, and `AnalogTickBatcher._bind_apply` special-cased
each of them plus raw ``(model, params)`` pairs.  This module replaces
that dispatch with a protocol: anything with ``apply(x) -> y`` plus the
``n_in``/``n_out``/``placement`` metadata and a ``recover(dead_tiles)``
hook is servable, and :func:`as_servable` adapts the one remaining
legacy shape — a model applied with explicit ``params`` — onto it.

The protocol is structural (:func:`typing.runtime_checkable`), so the
three ``Compiled*Program`` classes implement it without importing this
module; ``isinstance(prog, ServableProgram)`` is the conformance test
used both by the engine and by the test suite.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Protocol, runtime_checkable

__all__ = ["BoundAnalogModel", "ServableProgram", "as_servable"]


@runtime_checkable
class ServableProgram(Protocol):
    """What the serving engine needs from a compiled analog program.

    ``apply`` must accept a ``[B, n_in]`` panel and return ``[B, n_out]``
    with *no* trace/pack work in steady state — compiled programs pre-pack
    coefficients at lower time, so `PACK_EVENTS` stays pinned across
    ticks.  ``recover`` swaps in a replacement program after a mid-stream
    ``tile_down`` failure and must return a new `ServableProgram` (the
    engine rebinds to it; the dead instance is discarded).
    """

    n_in: int
    n_out: int
    placement: Any

    def apply(self, x: Any) -> Any: ...

    def recover(self, dead_tiles: Any, **kw: Any) -> "ServableProgram": ...


@dataclasses.dataclass(frozen=True)
class BoundAnalogModel:
    """Adapt a bare analog model (optionally with ``params``) to the protocol.

    Covers the pre-compile serving path: reference models whose ``apply``
    is either ``apply(x)`` or ``apply(params, x)``.  Metadata is
    introspected from the usual attribute spellings; ``recover`` delegates
    to the model when it has one and refuses otherwise (a bare model has
    no placement/calibration state to re-lower from).
    """

    model: Any
    params: Any = None

    def _dim(self, names: tuple[str, ...]) -> int:
        for name in names:
            v = getattr(self.model, name, None)
            if v is not None:
                return int(v)
        raise AttributeError(
            f"{type(self.model).__name__} exposes none of {names}; "
            "cannot infer panel width for the serving engine")

    @property
    def n_in(self) -> int:
        return self._dim(("n_in", "in_dim", "n"))

    @property
    def n_out(self) -> int:
        return self._dim(("n_out", "out_dim", "n"))

    @property
    def placement(self) -> Any:
        return getattr(self.model, "placement", None)

    def apply(self, x: Any) -> Any:
        if self.params is None:
            return self.model.apply(x)
        return self.model.apply(self.params, x)

    def recover(self, dead_tiles: Any, **kw: Any) -> "ServableProgram":
        rec = getattr(self.model, "recover", None)
        if rec is None:
            raise ValueError(
                f"{type(self.model).__name__} has no recover(); compile it "
                "(repro.compile.lower_tiled) to get fault-tolerant serving, "
                "or pass recovery= to the engine")
        return as_servable(rec(dead_tiles, **kw))


def as_servable(program: Any, params: Any = None) -> ServableProgram:
    """Coerce ``program`` to a :class:`ServableProgram`.

    Programs that already satisfy the protocol (the ``Compiled*Program``
    classes, or a previous :class:`BoundAnalogModel`) pass through
    untouched when no ``params`` are supplied; anything else is wrapped.
    """
    if params is None and isinstance(program, ServableProgram):
        return program
    return BoundAnalogModel(program, params)
