"""The unified analog serving engine.

One engine now serves both request families that used to have separate
loops (`ContinuousBatcher` for LM decode, `AnalogTickBatcher` for analog
ticks), in the shape of MaxText's ``offline_inference.py``:

  * an (optional) background **dispatch thread** pulls from a bounded
    request queue and drives the device, so callers just ``submit()`` and
    wait on the request's result future;
  * a bounded **admission queue** with a choice of backpressure policy —
    ``"block"`` (submit waits for space) or ``"reject"`` (submit fails
    fast and the request completes as failed);
  * a fixed-slot **tick loop**: every tick admits queued requests into
    free slots and runs ONE fixed-shape device call — a single fused
    megakernel ``pallas_call`` for a compiled analog program, one decode
    step for the LM — then frees finished slots immediately (no
    head-of-line blocking);
  * per-request **SLO accounting** (:class:`repro.runtime.slo.SLOTracker`):
    deadlines, served/expired/rejected/recovered counters, p50/p99 tick
    latency, sustained QPS;
  * the mid-stream **failure-recovery** hooks from the fault-tolerance
    work: a fired ``tile_down`` swaps in a recovered program between
    ticks and in-flight requests keep draining.

The engine consumes any compiled program through the
:class:`~repro.serving.servable.ServableProgram` protocol — the three
``Compiled*Program`` classes, a ``TiledAnalogLinear``/``AnalogSequence``
with ``params``, or anything else with ``apply``/``n_in``/``n_out``.  A
model exposing ``decode_step`` is served through the LM slot family
instead; both families share the same admission queue, tick loop, SLO
tracker and failure hooks.

Tick ordering is load-bearing for deadline/recovery semantics and is
kept bit-identical to the retired ``AnalogTickBatcher``: failures are
polled and deadlines expired against the *pre-increment* tick counter,
then the counter advances, then admission and the device call happen.
A request submitted at tick t with ``deadline_ticks=k`` therefore
expires at the top of tick t+k+1 if still queued — the head of a
slots=1 queue gets exactly k service opportunities.
"""

from __future__ import annotations

import threading
import time
from collections import deque
from typing import Any, Callable

import jax.numpy as jnp
import numpy as np

from repro.runtime.slo import SLOTracker
from repro.serving.servable import ServableProgram, as_servable

__all__ = ["Request", "ServingEngine"]


class Request:
    """One unit of serving work — analog feature vector OR LM prompt.

    ``payload`` is the request body: a ``[d]`` float feature vector for
    an analog program, a ``[prompt_len]`` int32 token array for the LM.
    The ``features=`` / ``prompt=`` keywords are readable aliases for the
    same slot (exactly one of the three may be given).

    ``deadline_ticks``: optional per-request tick budget — a request
    still *queued* that many engine ticks after submission completes as
    failed instead of waiting forever behind an outage.

    The result is a future: ``wait()`` blocks until the engine completes
    the request (from the dispatch thread or a synchronous ``run()``),
    ``done`` is non-blocking.  On success ``result`` holds the output
    panel row (analog) or the generated token array (LM); on expiry or
    rejection ``failed`` is True and ``result`` stays None.
    """

    def __init__(self, rid: int, payload: Any = None, *,
                 features: Any = None, prompt: Any = None,
                 deadline_ticks: int | None = None,
                 max_new: int = 32, eos_id: int | None = None):
        given = [v for v in (payload, features, prompt) if v is not None]
        if len(given) != 1:
            raise ValueError(
                "Request takes exactly one of payload=/features=/prompt= "
                f"(got {len(given)})")
        self.rid = rid
        self.payload = given[0]
        self.deadline_ticks = deadline_ticks
        self.max_new = max_new
        self.eos_id = eos_id
        # filled by the engine:
        self.result: Any = None
        self.output: list[int] = []          # LM path: tokens as they decode
        self.failed = False
        self.submitted_tick = 0
        self.submitted_at: float | None = None
        self.completed_tick: int | None = None
        self._event = threading.Event()

    @property
    def features(self) -> Any:
        return self.payload

    @property
    def prompt(self) -> Any:
        return self.payload

    @property
    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: float | None = None) -> bool:
        """Block until the engine completes this request (True) or the
        timeout elapses (False)."""
        return self._event.wait(timeout)

    def _finish(self, failed: bool = False) -> None:
        if failed:
            self.failed = True
        self._event.set()

    def __repr__(self):
        state = ("failed" if self.failed else
                 "done" if self.done else "pending")
        return f"Request(rid={self.rid}, {state})"


# ---------------------------------------------------------------------------
# slot families: the per-tick device step for each request kind
# ---------------------------------------------------------------------------

class _AnalogSlots:
    """Fixed-slot panel ticks through a :class:`ServableProgram`.

    The analog network is stateless, so a tick is: pack up to
    ``n_slots`` admitted requests into a zero-padded ``[n_slots, n_in]``
    panel, ONE ``apply`` (a single megakernel ``pallas_call`` for a
    compiled program), scatter rows back, free every slot.  Unfilled
    slots ride as zero rows — the kernels' ragged-batch padding
    semantics.  With ``mesh=`` the same apply is sharded over the batch
    grid via :func:`repro.parallel.sharding.data_parallel`.
    """

    def __init__(self, servable: ServableProgram, n_slots: int, *,
                 mesh=None, data_axis: str = "data"):
        self.n_slots = n_slots
        self.mesh = mesh
        self.data_axis = data_axis
        self.active: list[Request] = []
        self.rebind(servable)

    def rebind(self, servable: ServableProgram) -> None:
        """(Re)bind the device call — also the mid-stream recovery swap."""
        self.servable = servable

        def apply(p, x):
            return servable.apply(x)

        if self.mesh is not None:
            from repro.parallel.sharding import data_parallel

            apply = data_parallel(apply, self.mesh,
                                  axis_name=self.data_axis)
        self._apply = apply

    def free_slots(self) -> int:
        return self.n_slots - len(self.active)

    def n_active(self) -> int:
        return len(self.active)

    def admit(self, req: Request) -> None:
        self.active.append(req)

    def step(self) -> list[Request]:
        active, self.active = self.active, []
        try:
            d = int(self.servable.n_in)
        except (AttributeError, TypeError):
            d = len(np.asarray(active[0].payload))
        panel = np.zeros((self.n_slots, d), np.float32)
        for i, req in enumerate(active):
            panel[i] = req.payload
        out = np.asarray(self._apply(None, jnp.asarray(panel)))
        for i, req in enumerate(active):
            req.result = out[i]
        return active


class _LMSlot:
    __slots__ = ("req", "pos", "pending")

    def __init__(self):
        self.req: Request | None = None
        self.pos = 0                # next cache position for this slot
        self.pending = 0            # last token, fed on the next tick


class _DecodeSlots:
    """Fixed-slot continuous batching over the LM decode step.

    Slot state lives host-side; the device state is the shared KV cache
    pytree.  Admission prefills the prompt slot-serially (decode_step is
    the uniform per-token primitive), the tick decodes one token for all
    active slots at the shared max position, and finished requests (eos,
    max tokens, cache full) free their slot immediately.
    """

    def __init__(self, model, params, n_slots: int, max_len: int,
                 sample: Callable | None = None):
        if max_len is None:
            raise ValueError("LM serving needs max_len= (KV cache length)")
        self.model = model
        self.n_slots = n_slots
        self.max_len = max_len
        self.sample = sample
        self.slots = [_LMSlot() for _ in range(n_slots)]
        self.cache = model.init_cache(n_slots, max_len)
        self._decode = model.bind_decode(params)

    def rebind(self, servable) -> None:
        raise ValueError("mid-stream program recovery is an analog-path "
                         "feature; the LM decode path has no tile grid")

    def free_slots(self) -> int:
        return sum(1 for s in self.slots if s.req is None)

    def n_active(self) -> int:
        return sum(1 for s in self.slots if s.req is not None)

    def admit(self, req: Request) -> None:
        i = next(j for j, s in enumerate(self.slots) if s.req is None)
        slot = self.slots[i]
        slot.req, slot.pos = req, 0
        prompt = np.asarray(req.payload, np.int32)
        for tok in prompt[:-1]:
            self._step_one(i, int(tok))
        # the last prompt token is fed on the next engine tick
        slot.pending = int(prompt[-1])

    def _step_one(self, i: int, token: int) -> None:
        """Advance a single slot by one position (prefill path)."""
        slot = self.slots[i]
        toks = np.zeros((self.n_slots,), np.int32)
        toks[i] = token
        _, self.cache = self._decode(
            jnp.asarray(toks), self.cache, jnp.asarray(slot.pos, jnp.int32))
        slot.pos += 1

    def step(self) -> list[Request]:
        active = [i for i, s in enumerate(self.slots) if s.req is not None]
        toks = np.zeros((self.n_slots,), np.int32)
        for i in active:
            slot = self.slots[i]
            toks[i] = slot.pending if slot.pos < self.max_len else 0
        # positions: slots advance in lockstep from the shared max offset
        # (prefill above is slot-serial, so admitted slots start aligned)
        pos = max(self.slots[i].pos for i in active)
        logits, self.cache = self._decode(
            jnp.asarray(toks), self.cache, jnp.asarray(pos, jnp.int32))
        arr = np.asarray(jnp.argmax(logits, -1)) if self.sample is None \
            else np.asarray(self.sample(logits))
        completed = []
        for i in active:
            slot = self.slots[i]
            slot.pos = pos + 1
            tok = int(arr[i])
            req = slot.req
            req.output.append(tok)
            slot.pending = tok
            if ((req.eos_id is not None and tok == req.eos_id)
                    or len(req.output) >= req.max_new
                    or slot.pos >= self.max_len - 1):
                req.result = np.asarray(req.output, np.int32)
                completed.append(req)
                slot.req = None   # slot freed immediately
        return completed


# ---------------------------------------------------------------------------
# the engine
# ---------------------------------------------------------------------------

class ServingEngine:
    """Continuous batching + async dispatch over one compiled program.

    ``program`` is anything servable: a compiled analog program
    (`CompiledProgram`/`CompiledTiledProgram`/`CompiledDeepProgram`), an
    analog model with ``params=``, or an LM :class:`repro.models.Model`
    (detected by its ``decode_step``; needs ``params=`` and ``max_len=``).

    Admission: ``max_queue=None`` leaves the queue unbounded; with a
    bound, ``admission="block"`` makes ``submit`` wait for space (up to
    its ``timeout=``) while ``admission="reject"`` fails the request
    fast.  Either way a refused request completes as failed and counts
    as ``rejected``.

    Synchronous use: ``submit(...)`` then ``run()`` drains the queue on
    the caller's thread.  Async use: ``start()`` (or the context
    manager) spins up the dispatch thread; ``submit`` from any thread
    and ``req.wait()`` for the result future; ``stop()`` drains and
    joins.

    Fault tolerance (analog path): with ``failure_injector=`` the engine
    polls the injector every tick; a fired ``tile_down`` swaps the
    program mid-stream — via the ``recovery(dead_tiles)`` callable when
    given, else the servable's own ``recover(dead_tiles)`` — and serving
    continues on the recovered grid.  ``events`` logs each swap.
    """

    def __init__(self, program, params=None, *, slots: int,
                 max_len: int | None = None,
                 sample: Callable | None = None,
                 max_queue: int | None = None,
                 admission: str = "block",
                 mesh=None, data_axis: str = "data",
                 failure_injector=None, recovery=None):
        if admission not in ("block", "reject"):
            raise ValueError(f"admission must be 'block' or 'reject', "
                             f"got {admission!r}")
        self.n_slots = slots
        self.max_queue = max_queue
        self.admission = admission
        self.injector = failure_injector
        self.recovery = recovery
        self.ticks = 0
        self.slo = SLOTracker()
        self.events: list[dict] = []
        if hasattr(program, "decode_step"):
            self._impl = _DecodeSlots(program, params, slots, max_len,
                                      sample=sample)
        else:
            self._impl = _AnalogSlots(as_servable(program, params), slots,
                                      mesh=mesh, data_axis=data_axis)
        self._pending: deque[Request] = deque()
        self._cond = threading.Condition()
        self._thread: threading.Thread | None = None
        self._stop = threading.Event()

    # -- admission ------------------------------------------------------
    def submit(self, req: Request, timeout: float | None = None) -> bool:
        """Enqueue a request; returns False if it was rejected.

        Thread-safe.  With a bounded queue, ``admission="block"`` waits
        up to ``timeout`` seconds for space (None = forever);
        ``admission="reject"`` returns immediately.  A refused request
        completes as failed so ``req.wait()`` never hangs on it.
        """
        with self._cond:
            if self.max_queue is not None:
                if self.admission == "reject":
                    if len(self._pending) >= self.max_queue:
                        return self._refuse(req)
                else:
                    ok = self._cond.wait_for(
                        lambda: len(self._pending) < self.max_queue,
                        timeout=timeout)
                    if not ok:
                        return self._refuse(req)
            req.submitted_tick = self.ticks
            req.submitted_at = time.perf_counter()
            self._pending.append(req)
            self.slo.count("submitted")
            self._cond.notify_all()
        return True

    def _refuse(self, req: Request) -> bool:
        self.slo.count("rejected")
        req._finish(failed=True)
        return False

    @property
    def queue_depth(self) -> int:
        with self._cond:
            return len(self._pending)

    # -- the tick loop --------------------------------------------------
    def _check_failures(self) -> None:
        """Poll the injector against the pre-increment tick counter; a
        fired ``tile_down`` swaps in the recovered program mid-stream."""
        if self.injector is None:
            return
        fired = self.injector.at_step(self.ticks)
        if not any(f.kind == "tile_down" for f in fired):
            return
        dead = tuple(sorted(self.injector.dead_tiles))
        if self.recovery is not None:
            prog = self.recovery(dead)
        else:
            prog = self._impl.servable.recover(dead)
        self._impl.rebind(as_servable(prog))
        self.slo.count("recovered")
        self.events.append({"tick": self.ticks, "kind": "tile_recovery",
                            "dead_tiles": dead})

    def _expire(self) -> None:
        """Complete overdue *queued* requests as failed, against the
        pre-increment tick counter (never silently stuck behind an
        outage)."""
        with self._cond:
            live: deque[Request] = deque()
            for req in self._pending:
                if (req.deadline_ticks is not None
                        and self.ticks - req.submitted_tick
                        >= req.deadline_ticks):
                    self.slo.count("expired")
                    req._finish(failed=True)
                else:
                    live.append(req)
            if len(live) != len(self._pending):
                self._pending = live
                self._cond.notify_all()   # queue shrank: wake blocked submits

    def tick(self) -> int:
        """One engine iteration; returns the number of requests completed.

        Ordering (load-bearing, see module docstring): poll failures and
        expire deadlines at the old tick number, advance the counter,
        admit into free slots, then one fixed-shape device call.
        """
        self._check_failures()
        self._expire()
        self.ticks += 1
        with self._cond:
            batch: list[Request] = []
            free = self._impl.free_slots()
            while free > 0 and self._pending:
                batch.append(self._pending.popleft())
                free -= 1
            if batch:
                self._cond.notify_all()   # queue shrank: wake blocked submits
        for req in batch:
            self._impl.admit(req)         # device work outside the lock
        if self._impl.n_active() == 0:
            return 0
        t0 = time.perf_counter()
        completed = self._impl.step()
        self.slo.record_tick(time.perf_counter() - t0)
        for req in completed:
            req.completed_tick = self.ticks
            self.slo.count("served")
            req._finish()
        return len(completed)

    def run(self, max_ticks: int = 10_000) -> None:
        """Drain synchronously: tick until every submitted request is
        done (served, or completed-as-failed past its deadline)."""
        for _ in range(max_ticks):
            served = self.tick()
            if served == 0 and not self._has_work():
                return
        raise RuntimeError("serving engine did not drain")

    # -- background dispatch -------------------------------------------
    def _has_work(self) -> bool:
        with self._cond:
            return bool(self._pending) or self._impl.n_active() > 0

    def _dispatch_loop(self) -> None:
        while True:
            if self._has_work():
                self.tick()
            elif self._stop.is_set():
                return
            else:
                with self._cond:
                    if not self._pending:
                        self._cond.wait(timeout=0.02)

    def start(self) -> "ServingEngine":
        """Spin up the background dispatch thread."""
        if self._thread is not None:
            raise RuntimeError("engine already started")
        self._stop.clear()
        self._thread = threading.Thread(target=self._dispatch_loop,
                                        name="serving-dispatch", daemon=True)
        self._thread.start()
        return self

    def stop(self, drain: bool = True) -> None:
        """Stop the dispatch thread; by default after draining the queue."""
        if self._thread is None:
            return
        if not drain:
            with self._cond:
                for req in self._pending:
                    self.slo.count("rejected")
                    req._finish(failed=True)
                self._pending.clear()
                self._cond.notify_all()
        self._stop.set()
        with self._cond:
            self._cond.notify_all()
        self._thread.join()
        self._thread = None

    def __enter__(self) -> "ServingEngine":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop(drain=exc == (None, None, None))

    # -- accounting -----------------------------------------------------
    @property
    def stats(self) -> dict:
        """SLO summary: counters, tick count, p50/p99 tick latency, qps,
        plus the current queue depth."""
        out = self.slo.summary()
        out["queue_depth"] = self.queue_depth
        return out
