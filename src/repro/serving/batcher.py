"""Continuous batching for the decode loop.

The production decode step is fixed-shape (batch B, cache length L); the
batcher multiplexes a dynamic request stream onto those fixed slots:

  * new requests are admitted into free slots (prompt prefilled into the
    slot's cache region via the slot-batched prefill);
  * every engine tick decodes one token for all active slots;
  * finished requests (eos or max tokens) free their slot immediately —
    no head-of-line blocking on long generations.

Slot state lives host-side; the device state is the shared KV cache pytree.
This is the vLLM-style scheduling loop reduced to its fixed-shape core (no
paging: slots own contiguous cache regions — an acceptable trade at the
cache lengths the assigned shapes use).
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass
class Request:
    rid: int
    prompt: np.ndarray          # [prompt_len] int32
    max_new: int = 32
    eos_id: int | None = None
    # filled by the engine:
    output: list = dataclasses.field(default_factory=list)
    done: bool = False


@dataclasses.dataclass
class _Slot:
    req: Request | None = None
    pos: int = 0                # next cache position for this slot


class ContinuousBatcher:
    """Multiplexes requests onto a fixed-batch decode engine."""

    def __init__(self, model, params, *, slots: int, max_len: int):
        self.model = model
        self.params = params
        self.n_slots = slots
        self.max_len = max_len
        self.slots = [_Slot() for _ in range(slots)]
        self.cache = model.init_cache(slots, max_len)
        self.queue: list[Request] = []
        self._decode = jax.jit(
            lambda p, t, c, pos: model.decode_step(p, t, c, pos))

    # ------------------------------------------------------------------
    def submit(self, req: Request):
        self.queue.append(req)

    def _admit(self):
        """Fill free slots; prefill by single-token decode over the prompt
        (slot-local — correct for any family since decode_step is the
        uniform per-token primitive)."""
        for i, slot in enumerate(self.slots):
            if slot.req is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            slot.req, slot.pos = req, 0
            for tok in req.prompt[:-1]:
                self._step_one_slot(i, int(tok))
            # the last prompt token is fed on the next engine tick
            slot.pending = int(req.prompt[-1])

    def _step_one_slot(self, i: int, token: int):
        """Advance a single slot by one position (prefill path)."""
        slot = self.slots[i]
        toks = np.zeros((self.n_slots,), np.int32)
        toks[i] = token
        logits, self.cache = self._decode(
            self.params, jnp.asarray(toks), self.cache,
            jnp.asarray(slot.pos, jnp.int32))
        slot.pos += 1

    # ------------------------------------------------------------------
    def tick(self, sample: Callable | None = None) -> int:
        """One engine iteration: admit, decode one token per active slot.

        NOTE positions: the fixed-shape decode step shares one position
        scalar; the batcher schedules slots so admitted requests advance in
        lockstep from their own offsets (prefill is slot-serial above).
        Returns the number of active slots after the tick."""
        self._admit()
        active = [i for i, s in enumerate(self.slots) if s.req is not None]
        if not active:
            return 0
        toks = np.zeros((self.n_slots,), np.int32)
        for i in active:
            slot = self.slots[i]
            toks[i] = getattr(slot, "pending", 0) if slot.pos < self.max_len \
                else 0
        pos = max(self.slots[i].pos for i in active)
        logits, self.cache = self._decode(
            self.params, jnp.asarray(toks), self.cache,
            jnp.asarray(pos, jnp.int32))
        arr = np.asarray(jnp.argmax(logits, -1)) if sample is None \
            else np.asarray(sample(logits))
        for i in active:
            slot = self.slots[i]
            slot.pos = pos + 1
            tok = int(arr[i])
            slot.req.output.append(tok)
            slot.pending = tok
            if ((slot.req.eos_id is not None and tok == slot.req.eos_id)
                    or len(slot.req.output) >= slot.req.max_new
                    or slot.pos >= self.max_len - 1):
                slot.req.done = True
                slot.req = None   # slot freed immediately
        return len([s for s in self.slots if s.req is not None])

    def run(self, max_ticks: int = 10_000):
        """Drain the queue; returns when all submitted requests finish."""
        for _ in range(max_ticks):
            n = self.tick()
            if n == 0 and not self.queue:
                return
        raise RuntimeError("batcher did not drain")


# ---------------------------------------------------------------------------
# Analog (RFNN) serving: stateless fixed-batch ticks through the megakernel
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class AnalogRequest:
    """One feature vector awaiting an analog-network forward.

    ``deadline_ticks``: optional per-request tick budget — a request
    still queued that many engine ticks after submission completes as
    *failed* (``failed=True``, no result) instead of sitting in the
    queue forever behind an outage.
    """

    rid: int
    features: np.ndarray        # [d] float
    result: np.ndarray | None = None
    deadline_ticks: int | None = None
    failed: bool = False
    submitted_tick: int = 0     # stamped by the batcher at submit()

    @property
    def done(self) -> bool:
        return self.failed or self.result is not None


class AnalogTickBatcher:
    """Multiplexes analog-inference requests onto fixed-shape engine ticks.

    The analog network is stateless (no KV cache), so serving reduces to:
    collect up to ``slots`` pending requests, run **one** forward over the
    fixed ``[slots, d]`` panel, scatter results back.  With an
    ``AnalogSequence(backend="pallas")`` model each tick is a single fused
    network-megakernel ``pallas_call``, and the model's coefficient-pack
    cache means steady-state ticks do zero packing work (the model's
    params never change between ticks).  Unfilled slots ride as zero rows
    — exactly the kernels' ragged-batch padding semantics.

    ``params=None`` serves a parameter-less model such as a
    :class:`repro.compile.CompiledProgram`, a tile-grid
    :class:`repro.compile.CompiledTiledProgram` or a multi-layer
    :class:`repro.compile.CompiledDeepProgram` (``model.apply(x)``): the
    program's megakernel tensors were already emitted through the pack
    cache at ``lower`` / ``lower_tiled`` / ``lower_deep`` time, so
    *every* tick — the first included — does zero packing work (a deep
    program's tick is ONE pallas_call for the whole cascade).  A
    :class:`repro.core.analog_linear.TiledAnalogLinear` with
    ``backend="pallas"`` serves the same way with ``params``: each tick
    is one tile-grid megakernel call, steady-state ticks repack nothing.

    ``mesh``: optional ``jax.sharding.Mesh`` — ticks are then sharded over
    the batch grid via :func:`repro.parallel.sharding.data_parallel`, the
    same megakernel running per-device.

    Fault tolerance: with a ``failure_injector``
    (:class:`repro.runtime.FailureInjector`) the batcher polls the
    injector's schedule at every tick; a fired ``tile_down`` marks the
    tick *failed* — the batcher calls ``recovery(dead_tiles)`` (which
    should run ``plan_tile_recovery`` + ``compile.recover_tiled`` and
    return the recompiled program), swaps the model in mid-stream, and
    serves the same tick on the recovered grid.  In-flight requests keep
    draining; only requests past their ``deadline_ticks`` complete as
    failed.  ``stats`` surfaces ``served`` / ``dropped`` / ``recovered``
    counters, ``events`` the recovery log.
    """

    def __init__(self, model, params=None, *, slots: int, mesh=None,
                 data_axis: str = "data", failure_injector=None,
                 recovery=None):
        self.model = model
        self.params = params
        self.n_slots = slots
        self.mesh = mesh
        self.data_axis = data_axis
        self.queue: list[AnalogRequest] = []
        self.injector = failure_injector
        self.recovery = recovery
        self.ticks = 0
        self.stats = {"served": 0, "dropped": 0, "recovered": 0}
        self.events: list[dict] = []
        self._bind_apply()

    def _bind_apply(self):
        model, params = self.model, self.params
        if params is None:
            self._apply = lambda p, x: model.apply(x)
        else:
            self._apply = lambda p, x: model.apply(p, x)
        if self.mesh is not None:
            from repro.parallel.sharding import data_parallel

            self._apply = data_parallel(self._apply, self.mesh,
                                        axis_name=self.data_axis)

    def submit(self, req: AnalogRequest):
        req.submitted_tick = self.ticks
        self.queue.append(req)

    def _expire(self):
        """Complete overdue queued requests as failed (never silently
        stuck in the queue behind an outage)."""
        live = []
        for req in self.queue:
            if (req.deadline_ticks is not None
                    and self.ticks - req.submitted_tick
                    >= req.deadline_ticks):
                req.failed = True
                self.stats["dropped"] += 1
            else:
                live.append(req)
        self.queue = live

    def _check_failures(self):
        """Poll the injector; a fired ``tile_down`` triggers mid-stream
        recovery — swap in the recompiled program, keep draining."""
        if self.injector is None:
            return
        fired = self.injector.at_step(self.ticks)
        if any(f.kind == "tile_down" for f in fired) and (
                self.recovery is not None):
            dead = tuple(sorted(self.injector.dead_tiles))
            self.model = self.recovery(dead)
            self._bind_apply()
            self.stats["recovered"] += 1
            self.events.append(
                {"tick": self.ticks, "kind": "tile_recovery",
                 "dead_tiles": dead})

    def tick(self) -> int:
        """Serve one engine tick; returns the number of requests served."""
        self._check_failures()
        self._expire()
        self.ticks += 1
        if not self.queue:
            return 0
        active, self.queue = (self.queue[: self.n_slots],
                              self.queue[self.n_slots:])
        panel = np.zeros((self.n_slots, len(active[0].features)), np.float32)
        for i, req in enumerate(active):
            panel[i] = req.features
        out = np.asarray(self._apply(self.params, jnp.asarray(panel)))
        for i, req in enumerate(active):
            req.result = out[i]
        self.stats["served"] += len(active)
        return len(active)

    def run(self, max_ticks: int = 10_000):
        """Drain the queue; returns when every submitted request is done
        (served, or completed-as-failed past its deadline)."""
        for _ in range(max_ticks):
            if self.tick() == 0 and not self.queue:
                return
        raise RuntimeError("analog batcher did not drain")
