"""Deprecated serving shims — use :mod:`repro.serving.engine`.

The two divergent serving loops that used to live here
(``ContinuousBatcher`` for LM decode, ``AnalogTickBatcher`` for analog
ticks) were fused into one :class:`repro.serving.ServingEngine`, and
``Request``/``AnalogRequest`` into one :class:`repro.serving.Request`.
These aliases keep old call sites importing for one release; they emit
``DeprecationWarning`` and will be removed.  CI greps tests/examples to
keep new code off them.
"""

from __future__ import annotations

import warnings

from repro.serving.engine import Request as _Request
from repro.serving.engine import ServingEngine

__all__ = ["AnalogRequest", "AnalogTickBatcher", "ContinuousBatcher",
           "Request"]

#: Deprecated alias — construct :class:`repro.serving.Request` directly.
Request = _Request


def _warn(old: str, new: str) -> None:
    warnings.warn(f"repro.serving.{old} is deprecated; use {new}",
                  DeprecationWarning, stacklevel=3)


class AnalogRequest(_Request):
    """Deprecated — ``repro.serving.Request(rid, features=...)``."""

    def __init__(self, rid, features=None, *, deadline_ticks=None, **kw):
        _warn("AnalogRequest", "repro.serving.Request(features=...)")
        super().__init__(rid, features=features,
                         deadline_ticks=deadline_ticks, **kw)


class AnalogTickBatcher(ServingEngine):
    """Deprecated — ``repro.serving.ServingEngine``.

    Same constructor; ``stats`` keeps the old three-counter shape
    (``dropped`` maps to the engine's ``expired``).
    """

    def __init__(self, model, params=None, *, slots, mesh=None,
                 data_axis="data", failure_injector=None, recovery=None):
        _warn("AnalogTickBatcher", "repro.serving.ServingEngine")
        super().__init__(model, params, slots=slots, mesh=mesh,
                         data_axis=data_axis,
                         failure_injector=failure_injector,
                         recovery=recovery)

    @property
    def stats(self):
        c = self.slo.counters
        return {"served": c["served"], "dropped": c["expired"],
                "recovered": c["recovered"]}


class ContinuousBatcher(ServingEngine):
    """Deprecated — ``repro.serving.ServingEngine`` (LM path)."""

    def __init__(self, model, params, *, slots, max_len):
        _warn("ContinuousBatcher", "repro.serving.ServingEngine")
        super().__init__(model, params, slots=slots, max_len=max_len)

    def tick(self, sample=None):
        if sample is not None:
            self._impl.sample = sample
        return super().tick()
