"""Serving runtime: continuous batching over the prefill/decode steps,
plus fixed-slot analog-network ticks through the fused megakernel."""

from repro.serving.batcher import (
    AnalogRequest,
    AnalogTickBatcher,
    ContinuousBatcher,
    Request,
)

__all__ = ["AnalogRequest", "AnalogTickBatcher", "ContinuousBatcher",
           "Request"]
