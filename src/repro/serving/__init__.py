"""Serving runtime: continuous batching over the prefill/decode steps."""

from repro.serving.batcher import ContinuousBatcher, Request

__all__ = ["ContinuousBatcher", "Request"]
