"""repro.serving — the unified analog serving engine.

Public API (``__all__``): :class:`ServingEngine` (continuous batching +
async dispatch over one compiled program), :class:`Request` (one request
type for analog features and LM prompts), the
:class:`ServableProgram` protocol, and :func:`as_servable`.

The retired batchers (``ContinuousBatcher``, ``AnalogTickBatcher``,
``AnalogRequest``) remain importable as deprecated shims for one
release via :mod:`repro.serving.batcher`; importing them through this
package emits ``DeprecationWarning``.
"""

from repro.serving.engine import Request, ServingEngine
from repro.serving.servable import ServableProgram, as_servable

__all__ = ["Request", "ServableProgram", "ServingEngine", "as_servable"]

_DEPRECATED = {"AnalogRequest", "AnalogTickBatcher", "ContinuousBatcher"}


def __getattr__(name):
    if name in _DEPRECATED:
        from repro.serving import batcher

        return getattr(batcher, name)
    raise AttributeError(f"module 'repro.serving' has no attribute {name!r}")


def __dir__():
    return sorted(set(__all__) | _DEPRECATED)
