"""Straggler mitigation: per-host step-latency tracking.

At multi-pod scale a slow host stalls every synchronous collective.  The
monitor keeps an EWMA + variance of per-host step times and flags hosts
whose latency exceeds ``mean + k * std`` (and a relative floor) for several
consecutive steps.  The driver's policy on a flagged host:

  1. log + alert (always);
  2. if persistent, treat as failed: checkpoint, drop the host, re-mesh via
     :mod:`repro.runtime.elastic` and restart from the last durable step.

This mirrors the babysitting loop of large TPU jobs; the decision logic is
fully unit-testable offline (tests feed synthetic timings).
"""

from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class StragglerMonitor:
    num_hosts: int
    alpha: float = 0.2            # EWMA weight
    k_sigma: float = 3.0          # flag threshold in std units
    rel_floor: float = 1.3        # and at least 30% slower than fleet mean
    patience: int = 3             # consecutive flags before "persistent"

    def __post_init__(self):
        self.mean = np.zeros(self.num_hosts)
        self.var = np.zeros(self.num_hosts)
        self.count = 0
        self.flags = np.zeros(self.num_hosts, np.int64)

    def observe(self, step_times: np.ndarray) -> list[int]:
        """Feed one step's per-host wall times; returns flagged host ids."""
        t = np.asarray(step_times, np.float64)
        if self.count == 0:
            self.mean[:] = t
        else:
            d = t - self.mean
            self.mean += self.alpha * d
            self.var = (1 - self.alpha) * (self.var + self.alpha * d * d)
        self.count += 1
        fleet = float(np.median(self.mean))
        sigma = float(np.sqrt(np.maximum(self.var.mean(), 1e-12)))
        flagged = []
        for h in range(self.num_hosts):
            slow = (self.mean[h] > fleet + self.k_sigma * sigma
                    and self.mean[h] > self.rel_floor * fleet
                    and self.count >= 3)
            self.flags[h] = self.flags[h] + 1 if slow else 0
            if slow:
                flagged.append(h)
        return flagged

    def persistent(self) -> list[int]:
        """Hosts flagged for >= patience consecutive steps (treat as failed)."""
        return [h for h in range(self.num_hosts)
                if self.flags[h] >= self.patience]
