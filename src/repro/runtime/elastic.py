"""Elastic scaling: plan a degraded mesh after host/pod failures.

Given the surviving chip count (and topology constraints), choose the
largest valid production mesh and the config adjustments needed to resume:

  * losing a full pod: 512 -> 256 drops the "pod" axis (the multi-pod mesh
    degrades to the single-pod mesh; DP halves, grad-accum doubles to keep
    the global batch);
  * losing k hosts inside a pod: the data axis shrinks to the largest
    divisor that the surviving hosts tile (model axis is kept at 16 — TP
    rewiring is a different physical ICI ring and not generally survivable);
  * below a floor, training pauses for operator intervention.

The plan is pure data — the driver applies it by rebuilding the mesh,
resharding the restored checkpoint (params are saved with logical specs, so
resharding is re-`device_put`), and resuming from the last durable step.
"""

from __future__ import annotations

import dataclasses

HOST_CHIPS = 4          # v5e: 4 chips per host
MODEL_AXIS = 16         # TP degree is fixed by the ICI ring


@dataclasses.dataclass(frozen=True)
class RecoveryPlan:
    mesh_shape: tuple[int, ...]
    mesh_axes: tuple[str, ...]
    dp_shards: int
    accum_multiplier: int      # scale grad-accum to preserve global batch
    dropped_chips: int
    viable: bool
    reason: str = ""

    @property
    def chips(self) -> int:
        n = 1
        for s in self.mesh_shape:
            n *= s
        return n


def plan_recovery(surviving_chips: int, *, original_chips: int = 512,
                  min_data: int = 4) -> RecoveryPlan:
    """Largest valid (pod, data, model) mesh within the surviving fleet."""
    if surviving_chips >= 512:
        return RecoveryPlan((2, 16, 16), ("pod", "data", "model"), 32, 1,
                            surviving_chips - 512, True)
    # try single-pod-equivalent meshes with shrinking data axis
    for data in (16, 12, 8, 6, 4):
        chips = data * MODEL_AXIS
        if chips <= surviving_chips and data >= min_data:
            dp = data
            accum = max(1, 32 // dp)  # original multi-pod DP was 32
            return RecoveryPlan((data, MODEL_AXIS), ("data", "model"), dp,
                                accum, surviving_chips - chips, True)
    return RecoveryPlan((), (), 0, 0, surviving_chips, False,
                        reason=f"only {surviving_chips} chips alive; "
                               f"need >= {min_data * MODEL_AXIS}")


def hosts_to_chips(surviving_hosts: int) -> int:
    return surviving_hosts * HOST_CHIPS
