"""Elastic scaling: plan a degraded mesh after host/pod failures.

Given the surviving chip count (and topology constraints), choose the
largest valid production mesh and the config adjustments needed to resume:

  * losing a full pod: 512 -> 256 drops the "pod" axis (the multi-pod mesh
    degrades to the single-pod mesh; DP halves, grad-accum doubles to keep
    the global batch);
  * losing k hosts inside a pod: the data axis shrinks to the largest
    divisor that the surviving hosts tile (model axis is kept at 16 — TP
    rewiring is a different physical ICI ring and not generally survivable);
  * below a floor, training pauses for operator intervention.

The plan is pure data — the driver applies it by rebuilding the mesh,
resharding the restored checkpoint (params are saved with logical specs, so
resharding is re-`device_put`), and resuming from the last durable step.
"""

from __future__ import annotations

import dataclasses

HOST_CHIPS = 4          # v5e: 4 chips per host
MODEL_AXIS = 16         # TP degree is fixed by the ICI ring


@dataclasses.dataclass(frozen=True)
class RecoveryPlan:
    mesh_shape: tuple[int, ...]
    mesh_axes: tuple[str, ...]
    dp_shards: int
    accum_multiplier: int      # scale grad-accum to preserve global batch
    dropped_chips: int
    viable: bool
    reason: str = ""

    @property
    def chips(self) -> int:
        n = 1
        for s in self.mesh_shape:
            n *= s
        return n


def plan_recovery(surviving_chips: int, *, original_chips: int = 512,
                  min_data: int = 4,
                  model_axis: int = MODEL_AXIS) -> RecoveryPlan:
    """Largest valid (pod, data, model) mesh within the surviving fleet.

    ``model_axis`` is the fixed TP degree (default the v5e ICI ring's 16)
    — a parameter so analog-grid and chip-level planning share this
    module without magic numbers.
    """
    if surviving_chips >= original_chips:
        pods = original_chips // (16 * model_axis)
        return RecoveryPlan((pods, 16, model_axis),
                            ("pod", "data", "model"), pods * 16, 1,
                            surviving_chips - original_chips, True)
    # try single-pod-equivalent meshes with shrinking data axis
    full_dp = original_chips // model_axis
    for data in (16, 12, 8, 6, 4):
        chips = data * model_axis
        if chips <= surviving_chips and data >= min_data:
            dp = data
            accum = max(1, full_dp // dp)  # preserve the global batch
            return RecoveryPlan((data, model_axis), ("data", "model"), dp,
                                accum, surviving_chips - chips, True)
    return RecoveryPlan((), (), 0, 0, surviving_chips, False,
                        reason=f"only {surviving_chips} chips alive; "
                               f"need >= {min_data * model_axis}")


def hosts_to_chips(surviving_hosts: int, *,
                   host_chips: int = HOST_CHIPS) -> int:
    return surviving_hosts * host_chips


# ---------------------------------------------------------------------------
# Analog tile-grid recovery: remap a (To x Ti) grid around dead tiles
# ---------------------------------------------------------------------------
#
# The chip-level plan above rebuilds a *mesh*; the analog analogue
# rebuilds a *placement*.  A dead tile (or a whole dead tile row) cannot
# shrink the kernel grid — the matrix still needs every logical block —
# but the row x column permutation freedom the block decomposition leaves
# open (compile/placement.py) can park the least-important logical tiles
# on the dead positions, where their contribution is blanked.  The plan
# is pure data, mirroring RecoveryPlan: the driver applies it with
# ``repro.compile.recover_tiled`` (re-place, blank, re-calibrate exactly
# the moved tiles, re-lower).


@dataclasses.dataclass(frozen=True)
class TileRecoveryPlan:
    grid_shape: tuple[int, int]            # (To, Ti) — kernel grid unchanged
    row_perm: tuple[int, ...]              # physical row -> logical row
    col_perm: tuple[int, ...]              # physical col -> logical col
    dead: tuple[tuple[int, int], ...]      # physical positions out of service
    recalibrate: tuple[tuple[int, int], ...]  # live positions needing re-trim
    dropped_mass: float                    # sensitivity fraction parked dead
    viable: bool
    reason: str = ""


def plan_tile_recovery(sensitivity, dead_tiles, *,
                       row_perm=None, col_perm=None,
                       max_dropped_mass: float = 0.05) -> TileRecoveryPlan:
    """Remap a degraded (To x Ti) tile grid around its dead positions.

    ``sensitivity``: ``[To, Ti]`` logical singular-value mass
    (``repro.compile.tile_sensitivities``).  ``dead_tiles``: physical
    ``(po, pi)`` positions out of service.  ``row_perm``/``col_perm``:
    the grid's current placement (identity when unplaced).

    The new permutation greedily parks low-mass logical rows/columns on
    the physical rows/columns with the most dead cells (stable sorts, so
    an undamaged axis keeps its current assignment).  Viability is an
    accuracy floor: the sensitivity mass parked on dead positions must
    stay within ``max_dropped_mass`` of the total — above it, the grid
    has lost too much of the matrix to recover digitally and the plan
    reports non-viable for operator intervention.  ``recalibrate`` lists
    the *live* positions whose hosted logical tile changed: exactly
    those re-trim against their new positions' hardware draws.
    """
    import numpy as np

    sens = np.asarray(sensitivity, np.float64)
    to, ti = sens.shape
    dead = {(int(o), int(i)) for o, i in dead_tiles}
    for o, i in dead:
        if not (0 <= o < to and 0 <= i < ti):
            raise ValueError(f"dead tile {(o, i)} outside {to}x{ti} grid")
    old_r = tuple(row_perm) if row_perm is not None else tuple(range(to))
    old_c = tuple(col_perm) if col_perm is not None else tuple(range(ti))

    # dead-cell counts per physical row/column drive the matching: the
    # most damaged physical rows get the least sensitive logical rows
    dead_rows = np.zeros(to)
    dead_cols = np.zeros(ti)
    for o, i in dead:
        dead_rows[o] += 1.0
        dead_cols[i] += 1.0

    def match(damage, mass, old):
        # uniformly damaged (or undamaged) axis: re-permuting cannot move
        # mass off dead cells, so keep the placement (zero recalibrations)
        if damage.max() == damage.min():
            return old
        phys = np.argsort(-damage, kind="stable")   # most damaged first
        logi = np.argsort(mass, kind="stable")      # least mass first
        perm = np.empty(len(phys), np.int64)
        perm[phys] = logi
        return tuple(int(v) for v in perm)

    new_r = match(dead_rows, sens.sum(1), old_r)
    new_c = match(dead_cols, sens.sum(0), old_c)

    total = float(sens.sum())
    dropped = sum(float(sens[new_r[o], new_c[i]]) for o, i in dead)
    frac = dropped / total if total > 0 else 0.0
    moved = tuple(sorted(
        (po, pi)
        for po in range(to) for pi in range(ti)
        if (po, pi) not in dead
        and (new_r[po], new_c[pi]) != (old_r[po], old_c[pi])))
    viable = frac <= max_dropped_mass
    return TileRecoveryPlan(
        grid_shape=(to, ti), row_perm=new_r, col_perm=new_c,
        dead=tuple(sorted(dead)), recalibrate=moved,
        dropped_mass=frac, viable=viable,
        reason="" if viable else (
            f"remap still parks {frac:.1%} of the sensitivity mass on "
            f"dead tiles (floor {max_dropped_mass:.1%})"))
