"""Fault-tolerance runtime: straggler monitor, elastic re-meshing, failure
injection for tests, and the supervised training driver."""

from repro.runtime.elastic import (
    RecoveryPlan,
    TileRecoveryPlan,
    hosts_to_chips,
    plan_recovery,
    plan_tile_recovery,
)
from repro.runtime.slo import SLOTracker
from repro.runtime.straggler import StragglerMonitor
from repro.runtime.failures import Failure, FailureInjector, tile_row_failures

__all__ = ["Failure", "FailureInjector", "RecoveryPlan", "SLOTracker",
           "StragglerMonitor", "TileRecoveryPlan", "hosts_to_chips",
           "plan_recovery", "plan_tile_recovery", "tile_row_failures"]
