"""Fault-tolerance runtime: straggler monitor, elastic re-meshing, failure
injection for tests, and the supervised training driver."""

from repro.runtime.elastic import RecoveryPlan, plan_recovery
from repro.runtime.straggler import StragglerMonitor
from repro.runtime.failures import FailureInjector

__all__ = ["RecoveryPlan", "plan_recovery", "StragglerMonitor",
           "FailureInjector"]
