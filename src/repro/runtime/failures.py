"""Failure injection for fault-tolerance tests.

Simulates the failure modes a 1000-node fleet actually sees, on a schedule,
so the driver's recovery path is exercised deterministically in CI:

  * ``host_down``  — a host stops heartbeating (drop its chips);
  * ``straggler``  — a host's step time inflates by a factor;
  * ``crash``      — the training process dies mid-step (tests restart
    from checkpoint + exact data-stream resume);
  * ``tile_down``  — a physical analog tile (or, scheduled per-cell, a
    whole tile row) drops out of the (To x Ti) grid: serving recovers by
    remapping the placement (``runtime.elastic.plan_tile_recovery`` +
    ``compile.recover_tiled``) instead of rebuilding a chip mesh.
"""

from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class Failure:
    step: int
    kind: str              # host_down | straggler | crash | tile_down
    host: int = 0
    factor: float = 5.0    # straggler slowdown
    tile: tuple[int, int] = (0, 0)   # tile_down: physical (row, col)


@dataclasses.dataclass
class FailureInjector:
    schedule: list[Failure]
    down_hosts: set = dataclasses.field(default_factory=set)
    slow_hosts: dict = dataclasses.field(default_factory=dict)
    dead_tiles: set = dataclasses.field(default_factory=set)

    def at_step(self, step: int) -> list[Failure]:
        fired = [f for f in self.schedule if f.step == step]
        for f in fired:
            if f.kind == "host_down":
                self.down_hosts.add(f.host)
            elif f.kind == "straggler":
                self.slow_hosts[f.host] = f.factor
            elif f.kind == "tile_down":
                self.dead_tiles.add(tuple(f.tile))
        return fired

    def step_time(self, host: int, base: float) -> float:
        return base * self.slow_hosts.get(host, 1.0)

    def alive(self, num_hosts: int) -> list[int]:
        return [h for h in range(num_hosts) if h not in self.down_hosts]


def tile_row_failures(step: int, row: int, ti: int) -> list[Failure]:
    """A whole physical tile row dying at once — the ISSUE's headline
    degraded-grid scenario — as per-tile ``tile_down`` failures."""
    return [Failure(step=step, kind="tile_down", tile=(row, i))
            for i in range(ti)]
