"""Per-request SLO accounting for the serving engine.

The serving contract the ROADMAP's "millions of users" story is measured
against is not a single batched call — it is *sustained* service under a
dynamic request stream: how many requests per second, at what tick
latency, and what happened to every request that did NOT get served
(expired past its deadline, rejected at admission, recovered mid-stream).
:class:`SLOTracker` is the one place those numbers accumulate; the
engine calls ``count``/``record_tick`` and everything else (tests, the
``serving_qps_n64`` benchmark row, operator dashboards) reads
``summary()``.

Latencies are recorded per engine *tick* — one fixed-shape device call —
because that is the quantum the slot loop schedules in: a request's
end-to-end latency is (queue wait in ticks) x (tick latency), and the
two factors are exactly the knobs an operator has (slots/admission vs
kernel/batch shape).
"""

from __future__ import annotations

import time

import numpy as np

#: counter names the tracker maintains (all start at 0):
#:   submitted — requests accepted into the queue;
#:   served    — requests completed with a result;
#:   expired   — requests that overran ``deadline_ticks`` while queued
#:               and completed as failed;
#:   rejected  — requests refused (or timed out) at admission because the
#:               bounded queue was full;
#:   recovered — mid-stream program swaps after a ``tile_down`` failure.
COUNTERS = ("submitted", "served", "expired", "rejected", "recovered")


class SLOTracker:
    """Counters + tick-latency percentiles for one serving engine."""

    def __init__(self):
        self.counters: dict[str, int] = dict.fromkeys(COUNTERS, 0)
        self.tick_latencies: list[float] = []   # seconds per engine tick
        self._t_first: float | None = None      # window of recorded ticks
        self._t_last: float | None = None

    # ------------------------------------------------------------------
    def count(self, name: str, k: int = 1) -> None:
        if name not in self.counters:
            raise KeyError(f"unknown SLO counter {name!r} "
                           f"(have {sorted(self.counters)})")
        self.counters[name] += k

    def record_tick(self, seconds: float) -> None:
        now = time.perf_counter()
        if self._t_first is None:
            self._t_first = now - seconds
        self._t_last = now
        self.tick_latencies.append(seconds)

    # ------------------------------------------------------------------
    def percentile_us(self, p: float) -> float | None:
        """``p``-th percentile tick latency in microseconds (None when no
        tick has been recorded yet)."""
        if not self.tick_latencies:
            return None
        return float(np.percentile(np.asarray(self.tick_latencies), p)) * 1e6

    @property
    def window_s(self) -> float | None:
        """Wall-clock span covered by the recorded ticks."""
        if self._t_first is None:
            return None
        return self._t_last - self._t_first

    def qps(self) -> float | None:
        """Served requests per second over the recorded tick window."""
        w = self.window_s
        if not w or not self.counters["served"]:
            return None
        return self.counters["served"] / w

    def summary(self) -> dict:
        """One flat dict: counters + ticks + p50/p99 tick latency + qps."""
        out = dict(self.counters)
        out["ticks"] = len(self.tick_latencies)
        out["p50_tick_us"] = self.percentile_us(50)
        out["p99_tick_us"] = self.percentile_us(99)
        out["qps"] = self.qps()
        return out
