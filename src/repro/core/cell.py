"""The 2x2 reconfigurable linear RF analog processor unit cell.

Implements the physics of the paper's unit cell (Fig. 2): two quadrature
(90 deg) hybrids and two phase shifters (theta between the hybrids on channel
1, phi at the output of channel 1).  The forward voltage transfer matrix is
paper Eq. (5):

    t(theta, phi) = j e^{-j theta/2} [ e^{-j phi} sin(th/2)  e^{-j phi} cos(th/2) ]
                                     [          cos(th/2)            -sin(th/2)  ]

with t t^H = I (Eq. 18), i.e. an element of U(2).

Everything here is pure JAX and differentiable w.r.t. (theta, phi); the
hardware-imperfect variant lives in :mod:`repro.core.hardware`.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

# ---------------------------------------------------------------------------
# Paper constants
# ---------------------------------------------------------------------------

#: Table I — discrete phase differences (degrees) of the six switched lines.
TABLE_I_PHASES_DEG: tuple[float, ...] = (29.0, 53.0, 75.0, 104.0, 135.0, 154.0)

#: Table I in radians, as a numpy array (used by the quantizer).
TABLE_I_PHASES_RAD: np.ndarray = np.deg2rad(np.asarray(TABLE_I_PHASES_DEG))

#: Design center frequency of the prototype (Hz).
F0_HZ: float = 2.0e9

#: Characteristic impedance of the transmission lines (ohm).
Z0_OHM: float = 50.0

#: Number of discrete states per phase shifter (SP6T switch pair).
N_DISCRETE_STATES: int = 6


# ---------------------------------------------------------------------------
# Ideal quadrature hybrid and cell transfer
# ---------------------------------------------------------------------------

def quadrature_hybrid() -> jnp.ndarray:
    """Forward 2x2 voltage block of an ideal 3-dB 90-degree hybrid.

    From the 4-port S-matrix (paper Eq. 3/4), keeping the forward path
    (P1, P4) -> (P2, P3):  (-1/sqrt(2)) [[j, 1], [1, j]].
    """
    return (-1.0 / jnp.sqrt(2.0)) * jnp.array([[1j, 1.0], [1.0, 1j]], dtype=jnp.complex64)


def phase_shifter(phase: jnp.ndarray) -> jnp.ndarray:
    """diag(e^{-j phase}, 1): a delay line on channel 1 (negative convention)."""
    one = jnp.ones_like(phase)
    e = jnp.exp(-1j * phase.astype(jnp.complex64))
    return jnp.stack(
        [jnp.stack([e, jnp.zeros_like(e)], axis=-1),
         jnp.stack([jnp.zeros_like(e), one.astype(jnp.complex64)], axis=-1)],
        axis=-2,
    )


def cell_matrix(theta: jnp.ndarray, phi: jnp.ndarray) -> jnp.ndarray:
    """t(theta, phi), paper Eq. (5).  Broadcasts over leading dims.

    Returns a complex64 array of shape ``theta.shape + (2, 2)``.
    """
    theta = jnp.asarray(theta, jnp.float32)
    phi = jnp.asarray(phi, jnp.float32)
    half = 0.5 * theta
    s, c = jnp.sin(half), jnp.cos(half)
    glob = 1j * jnp.exp(-0.5j * theta.astype(jnp.complex64))
    ephi = jnp.exp(-1j * phi.astype(jnp.complex64))
    row0 = jnp.stack([ephi * s, ephi * c], axis=-1)
    row1 = jnp.stack([c + 0j, -s + 0j], axis=-1)
    return glob[..., None, None] * jnp.stack([row0, row1], axis=-2)


def cell_matrix_structural(theta: jnp.ndarray, phi: jnp.ndarray) -> jnp.ndarray:
    """t(theta, phi) built structurally: Phi . H . Theta . H.

    Identical to :func:`cell_matrix` (validated in tests); kept as the
    physics-derivation form reused by the imperfect hardware model.
    """
    h = quadrature_hybrid()
    return phase_shifter(phi) @ h @ phase_shifter(theta) @ h


# ---------------------------------------------------------------------------
# S-parameters and power transfer (paper Eqs. 6-17)
# ---------------------------------------------------------------------------

def s_parameters(theta: jnp.ndarray, phi: jnp.ndarray) -> dict[str, jnp.ndarray]:
    """The four forward S-parameters of the cell, Eqs. (6)-(9)."""
    t = cell_matrix(theta, phi)
    return {"s21": t[..., 0, 0], "s24": t[..., 0, 1],
            "s31": t[..., 1, 0], "s34": t[..., 1, 1]}


def output_voltages(theta, phi, p1_w, p4_w, z0: float = Z0_OHM):
    """Complex output voltage phasors at (P2, P3) for in-phase power feeds.

    Paper Eqs. (10)-(13): V_nm = sqrt(2 Z0 P_m) S_nm, summed per port.
    ``p1_w``/``p4_w`` are input powers in watts.
    """
    v1 = jnp.sqrt(2.0 * z0 * jnp.asarray(p1_w, jnp.float32)).astype(jnp.complex64)
    v4 = jnp.sqrt(2.0 * z0 * jnp.asarray(p4_w, jnp.float32)).astype(jnp.complex64)
    t = cell_matrix(theta, phi)
    v2 = t[..., 0, 0] * v1 + t[..., 0, 1] * v4
    v3 = t[..., 1, 0] * v1 + t[..., 1, 1] * v4
    return v2, v3


def output_powers(theta, phi, p1_w, p4_w, z0: float = Z0_OHM):
    """Measured powers at (P2, P3), Eqs. (14)-(15)."""
    v2, v3 = output_voltages(theta, phi, p1_w, p4_w, z0)
    p2 = jnp.abs(v2) ** 2 / (2.0 * z0)
    p3 = jnp.abs(v3) ** 2 / (2.0 * z0)
    return p2, p3


def output_powers_closed_form(theta, p1_w, p4_w):
    """Closed-form Eqs. (16)-(17): P2=(P1+P4) sin^2(th/2+D), P3=(P1+P4) cos^2."""
    p1 = jnp.asarray(p1_w, jnp.float32)
    p4 = jnp.asarray(p4_w, jnp.float32)
    tot = p1 + p4
    delta = jnp.arccos(jnp.sqrt(p1 / jnp.maximum(tot, 1e-30)))
    p2 = tot * jnp.sin(0.5 * theta + delta) ** 2
    p3 = tot * jnp.cos(0.5 * theta + delta) ** 2
    return p2, p3


def is_unitary(t: jnp.ndarray, atol: float = 1e-5) -> jnp.ndarray:
    """Check t t^H = I over the trailing (2, 2) axes."""
    eye = jnp.eye(t.shape[-1], dtype=t.dtype)
    prod = t @ jnp.conj(jnp.swapaxes(t, -1, -2))
    return jnp.all(jnp.abs(prod - eye) < atol)
