"""Programming a mesh to realize a target unitary (paper Eqs. 27-30).

Two programmers are provided:

* :func:`reck_program` — exact analytic factorization.  For the paper's cell
  convention (phase shifter phi on the *output* of channel 1, Eq. 5), left
  multiplication by ``t^H`` embedded on an adjacent channel pair can null any
  matrix element, which yields a QR-by-adjacent-Givens sweep:

      t^H_K ... t^H_1 . U = D   =>   U = t_1 ... t_K . D

  so the physical cascade applies the diagonal phase screen D at the *input*,
  then cells in reverse nulling order.  (With this cell the exact
  factorization's screen lands on the input side; the paper draws Sigma at
  the output — both parameterize all of U(N), see DESIGN.md.)

* :func:`fit_program` — stochastic/gradient programming of an arbitrary
  layout (e.g. the paper-faithful Clements rectangle with *output* screen).
  The paper itself programs meshes this way: "the phase value of each
  processor can be calculated using stochastic optimization methods" (Sec.
  IV-B).

Both return parameters for :func:`repro.core.mesh.apply_mesh` and are
validated by reconstruction tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mesh as mesh_lib
from repro.core.cell import cell_matrix


def random_unitary(n: int, seed: int = 0) -> np.ndarray:
    """Haar-ish random unitary via QR of a complex Gaussian."""
    rng = np.random.default_rng(seed)
    z = rng.normal(size=(n, n)) + 1j * rng.normal(size=(n, n))
    q, r = np.linalg.qr(z)
    return (q * (np.diag(r) / np.abs(np.diag(r)))).astype(np.complex128)


def _cell_np(theta: float, phi: float) -> np.ndarray:
    half = 0.5 * theta
    s, c = np.sin(half), np.cos(half)
    glob = 1j * np.exp(-0.5j * theta)
    return glob * np.array(
        [[np.exp(-1j * phi) * s, np.exp(-1j * phi) * c], [c, -s]], np.complex128
    )


def reck_program(u: np.ndarray, atol: float = 1e-8):
    """Exact analytic mesh program realizing the unitary ``u``.

    Returns ``(plan, params)`` such that
    ``mesh_matrix(plan, params) ~= u`` with ``params`` containing
    ``theta``/``phi`` [C, P] and the input screen ``alpha_in`` [n].
    """
    u = np.asarray(u, np.complex128)
    n = u.shape[0]
    if u.shape != (n, n) or n % 2:
        raise ValueError(f"need even square unitary, got {u.shape}")
    err = np.abs(u @ u.conj().T - np.eye(n)).max()
    if err > 1e-6:
        raise ValueError(f"input is not unitary (err={err:.2e})")

    v = u.copy()
    nulled: list[tuple[int, float, float]] = []  # t^H application order
    for col in range(n - 1):
        for q in range(n - 1, col, -1):
            p = q - 1
            vp, vq = v[p, col], v[q, col]
            if abs(vq) < atol and abs(vp) < atol:
                continue
            theta = 2.0 * np.arctan2(abs(vp), abs(vq))
            if abs(vp) > atol and abs(vq) > atol:
                phi = float(np.angle(vq) - np.angle(vp))
            else:
                phi = 0.0
            th = _cell_np(theta, phi).conj().T  # t^H
            rows = np.stack([v[p, :], v[q, :]])
            v[p, :], v[q, :] = th @ rows
            nulled.append((p, theta, phi))
    d = np.diag(v).copy()
    if np.abs(np.abs(d) - 1.0).max() > 1e-6 or np.abs(v - np.diag(d)).max() > 1e-6:
        raise AssertionError("nulling did not reach a diagonal — bug")

    # Physical order: input screen D, then cells in reverse nulling order.
    cells_physical = list(reversed(nulled))
    plan, theta, phi = mesh_lib.pack_cells_to_columns(
        n, cells_physical, pad_to_columns=max(1, 2 * n - 3))
    alpha_in = jnp.asarray(-np.angle(d), jnp.float32)  # e^{-j a} = d
    params = {"theta": theta, "phi": phi, "alpha_in": alpha_in}
    return plan, params


def reconstruction_error(plan, params, target: np.ndarray) -> float:
    rec = np.asarray(mesh_lib.mesh_matrix(plan, params))
    return float(np.abs(rec - target).max())


def fit_program(
    target: np.ndarray,
    plan: mesh_lib.MeshPlan | None = None,
    *,
    steps: int = 3000,
    lr: float = 0.05,
    seed: int = 0,
    with_sigma: bool = True,
    with_input_screen: bool = True,
):
    """Gradient programming of ``target`` onto a mesh layout.

    Uses :class:`repro.optim.AdamW` on (theta, phi, alpha, alpha_in),
    minimizing the Frobenius error of the realized matrix — the paper's
    "stochastic optimization" programming path — with the whole step loop
    inside one jitted ``lax.scan`` (one compile, no per-step dispatch).
    NOTE (validated empirically, see DESIGN.md): because the paper's
    cell has a single external phase (phi on the output of channel 1), the
    rectangle with an *output-only* Sigma screen is not universal over U(N);
    an input phase screen restores exact universality, so it is on by
    default.  Returns ``(plan, params, final_error)``.
    """
    from repro.optim.adamw import AdamW

    target = jnp.asarray(target, jnp.complex64)
    n = target.shape[0]
    if plan is None:
        plan = mesh_lib.clements_plan(n)
    params = mesh_lib.init_mesh_params(jax.random.PRNGKey(seed), plan, with_sigma=with_sigma)
    if with_input_screen:
        params["alpha_in"] = jnp.zeros((n,), jnp.float32)

    def loss_fn(p):
        rec = mesh_lib.mesh_matrix(plan, p)
        return jnp.sum(jnp.abs(rec - target) ** 2)

    opt = AdamW(lr=lr, b1=0.9, b2=0.999, eps=1e-8, weight_decay=0.0,
                clip_norm=0.0)

    @jax.jit
    def run(params, state):
        def step(carry, _):
            p, s = carry
            loss, g = jax.value_and_grad(loss_fn)(p)
            p, s, _ = opt.update(p, g, s)
            return (p, s), loss
        (params, state), losses = jax.lax.scan(
            step, (params, state), None, length=steps)
        return params, losses

    params, _ = run(params, opt.init(params))
    err = reconstruction_error(plan, params, np.asarray(target))
    return plan, params, err
