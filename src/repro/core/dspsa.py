"""Discrete Simultaneous Perturbation Stochastic Approximation (DSPSA).

The paper's Algorithm I optimizes the *device biasing states* — integer
switch codes selecting one of the six Table-I lines per shifter — with DSPSA
(Wang & Spall 2011, ref [44]) while digital parameters use SGD.  DSPSA needs
only two loss evaluations per step regardless of dimension, which matches a
physical device where each evaluation is one hardware measurement pass.

State layout: a pytree of int32 code arrays plus a float "virtual" mirror
(the algorithm's continuous iterate); the device always sees the rounded
projection.

Each ``loss_fn`` evaluation is one device measurement pass — a pure
forward propagation.  With the analog layers' ``backend="pallas"`` those
passes run through the fused mesh kernels (hardware model included), so
in-situ DSPSA training is a kernel workload end-to-end; see
``paper.rfnn2x2.train_rfnn2x2`` and the MNIST refinement bursts.
"""

from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass
class DSPSAConfig:
    a: float = 0.6          # gain numerator
    big_a: float = 10.0     # stability constant A
    alpha: float = 0.602    # gain decay exponent (Spall's recommended value)
    n_states: int = 6       # codebook size (Table I -> 6)


@dataclasses.dataclass
class DSPSAState:
    virtual: dict           # float32 pytree, the continuous iterate
    step: int = 0


def init(codes) -> DSPSAState:
    return DSPSAState(virtual=jax.tree.map(
        lambda c: c.astype(jnp.float32), codes), step=0)


def project(state: DSPSAState, cfg: DSPSAConfig):
    """Integer device codes from the virtual iterate."""
    return jax.tree.map(
        lambda v: jnp.clip(jnp.round(v), 0, cfg.n_states - 1).astype(jnp.int32),
        state.virtual)


def step(key: Array, state: DSPSAState, loss_fn: Callable[[dict], Array],
         cfg: DSPSAConfig) -> tuple[DSPSAState, Array]:
    """One DSPSA update.  ``loss_fn`` maps integer codes -> scalar loss.

    Uses the two-measurement form: with Bernoulli(+-1) perturbation Delta,
    evaluate at pi(x) +- Delta where pi is the floor+1/2 lattice midpoint,
    and g_hat = (y+ - y-)/2 * Delta (Delta_i^2 = 1).
    """
    leaves, treedef = jax.tree.flatten(state.virtual)
    keys = jax.random.split(key, len(leaves))
    deltas = [jax.random.rademacher(k, l.shape, jnp.float32)
              for k, l in zip(keys, leaves)]
    delta_tree = jax.tree.unflatten(treedef, deltas)

    mid = jax.tree.map(lambda v: jnp.floor(v) + 0.5, state.virtual)

    def codes_at(sign: float):
        return jax.tree.map(
            lambda m, d: jnp.clip(jnp.round(m + sign * 0.5 * d), 0,
                                  cfg.n_states - 1).astype(jnp.int32),
            mid, delta_tree)

    y_plus = loss_fn(codes_at(+1.0))
    y_minus = loss_fn(codes_at(-1.0))
    gain = cfg.a / (state.step + 1 + cfg.big_a) ** cfg.alpha
    diff = (y_plus - y_minus) / 2.0

    new_virtual = jax.tree.map(
        lambda v, d: jnp.clip(v - gain * diff * d, -0.49, cfg.n_states - 0.51),
        state.virtual, delta_tree)
    new_state = DSPSAState(virtual=new_virtual, step=state.step + 1)
    return new_state, jnp.minimum(y_plus, y_minus)


def minimize(key: Array, codes0, loss_fn, cfg: DSPSAConfig, steps: int,
             *, measure_projection: bool = True):
    """Run DSPSA for ``steps`` iterations; returns (best codes, history).

    ``measure_projection=True`` (default) spends a third measurement per
    step evaluating the projected iterate, tracking the best codes seen —
    the form the repo has always used.  ``False`` is the paper-strict
    two-measurements-per-step budget (Algorithm I counts exactly two
    hardware passes per update): the history then records
    ``min(y+, y-)`` and the final projection is returned.
    """
    state = init(codes0)
    best_codes = project(state, cfg)
    if measure_projection:
        best_loss = loss_fn(best_codes)
        hist = [float(best_loss)]
    else:
        best_loss = None
        hist = []
    for i in range(steps):
        key, sub = jax.random.split(key)
        state, y_min = step(sub, state, loss_fn, cfg)
        if measure_projection:
            cand = project(state, cfg)
            loss = loss_fn(cand)
            hist.append(float(loss))
            if loss < best_loss:
                best_loss, best_codes = loss, cand
        else:
            hist.append(float(y_min))
    if not measure_projection:
        best_codes = project(state, cfg)
    return best_codes, hist
