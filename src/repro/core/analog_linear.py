"""Trainable analog linear layers backed by the RF processor (paper Sec. IV).

Three composable modules, all ``init(key) -> params`` / ``apply(params, x)``:

* :class:`AnalogUnitary` — an N x N mesh whose phases are trained directly
  (the paper's MNIST hidden layer: an 8x8 mesh of 28 cells, Fig. 14).
* :class:`AnalogLinear` — an arbitrary (out x in) matrix in SVD form
  V-mesh -> attenuation -> U-mesh with a digital scale gamma (Eq. 31 +
  Fig. 11 pre/post scaling).  Trainable, or programmed from a target matrix.
* :class:`TiledAnalogLinear` — a grid of tile-sized AnalogLinear cores
  implementing a large matmul as block sums; the scale-out path for LM-sized
  projections (Sec. V discusses 20x20 passive arrays).

Each supports Table-I discrete-phase quantization (straight-through
gradients) and the hardware-imperfection model, so "analog" training can be
made exactly as faithful as the prototype.

``backend="pallas"`` routes both inference *and* training through the fused
Pallas mesh kernels (``repro.kernels``), which carry custom VJPs — the
reference ``lax.scan`` path and the kernel path are interchangeable
gradient-for-gradient.  The kernel path covers the full configuration
space: ideal physics *and* the per-cell hardware-imperfection model
(imperfect hybrids, insertion loss, ``key``-sampled phase noise), on
rectangular Clements layouts *and* analytically programmed Reck programs
(re-scheduled into kernel parity columns by ``repro.kernels.schedule``).
There is no reference fallback — both backends consume the same keys, so
they are draw-for-draw comparable under noise.
"""

from __future__ import annotations

import dataclasses
from typing import Literal

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hardware as hw_lib
from repro.core import mesh as mesh_lib
from repro.core import quantize as q_lib
from repro.kernels import ops as kernel_ops

Array = jax.Array
OutputMode = Literal["abs", "real", "complex"]
Backend = Literal["reference", "pallas"]


def _as_complex(x: Array) -> Array:
    if jnp.iscomplexobj(x):
        return x.astype(jnp.complex64)
    return x.astype(jnp.float32).astype(jnp.complex64)


def _readout(y: Array, output: OutputMode, hw: hw_lib.HardwareModel | None,
             key: Array | None) -> Array:
    if output == "complex":
        return y
    if output == "abs":
        if hw is not None:
            return hw_lib.detect_magnitude(y, hw, key)
        return jnp.abs(y)
    return jnp.real(y)


@dataclasses.dataclass(frozen=True)
class AnalogUnitary:
    """N x N unitary mesh layer with directly trained phases."""

    n: int
    quantize: str | None = None      # None | "table1" | "uniform<bits>"
    hardware: hw_lib.HardwareModel | None = None
    output: OutputMode = "complex"
    backend: Backend = "reference"

    def __post_init__(self):
        object.__setattr__(self, "_plan", mesh_lib.clements_plan(self.n))

    @property
    def plan(self) -> mesh_lib.MeshPlan:
        return self._plan  # type: ignore[attr-defined]

    def codebook(self) -> Array | None:
        if self.quantize is None:
            return None
        if self.quantize == "table1":
            return q_lib.table_i_codebook()
        if self.quantize.startswith("uniform"):
            return q_lib.uniform_codebook(int(self.quantize[len("uniform"):]))
        raise ValueError(f"unknown quantize mode {self.quantize!r}")

    def init(self, key: Array) -> dict:
        return mesh_lib.init_mesh_params(key, self.plan, with_sigma=True)

    def effective_params(self, params: dict) -> dict:
        cb = self.codebook()
        if cb is None:
            return params
        return q_lib.quantize_mesh_params(params, cb, ste=True)

    def apply(self, params: dict, x: Array, *, key: Array | None = None) -> Array:
        p = self.effective_params(params)
        xc = _as_complex(x)
        kmesh, kdet = (jax.random.split(key)
                       if key is not None and self.hardware is not None
                       else (None, None))
        if self.backend == "pallas":
            y = kernel_ops.mesh_apply(p, xc, n=self.n, plan=self.plan,
                                      hardware=self.hardware, key=kmesh)
        elif self.hardware is not None:
            y = hw_lib.apply_mesh_hw(self.plan, p, xc, self.hardware, kmesh)
        else:
            y = mesh_lib.apply_mesh(self.plan, p, xc)
        return _readout(y, self.output, self.hardware, kdet)

    def matrix(self, params: dict) -> Array:
        return mesh_lib.mesh_matrix(self.plan, self.effective_params(params))

    def n_cells(self) -> int:
        return self.plan.n_cells


@dataclasses.dataclass(frozen=True)
class AnalogLinear:
    """Arbitrary (out x in) analog matrix in SVD mesh form."""

    in_dim: int
    out_dim: int
    quantize: str | None = None
    hardware: hw_lib.HardwareModel | None = None
    output: OutputMode = "real"
    backend: Backend = "reference"

    def __post_init__(self):
        n = max(self.in_dim, self.out_dim)
        n += n % 2
        object.__setattr__(self, "n", n)
        plan = mesh_lib.clements_plan(n)
        object.__setattr__(self, "_u_plan", plan)
        object.__setattr__(self, "_v_plan", plan)

    @property
    def u_plan(self) -> mesh_lib.MeshPlan:
        return self._u_plan  # type: ignore[attr-defined]

    @property
    def v_plan(self) -> mesh_lib.MeshPlan:
        return self._v_plan  # type: ignore[attr-defined]

    def init(self, key: Array) -> dict:
        ku, kv, ka, kg = jax.random.split(key, 4)
        n = self.n
        return {
            "u": mesh_lib.init_mesh_params(ku, self.u_plan, with_sigma=True),
            "v": mesh_lib.init_mesh_params(kv, self.v_plan, with_sigma=True),
            # attenuation in [0,1] via sigmoid of a free logit
            "atten_logit": jax.random.normal(ka, (n,)) * 0.5 + 1.0,
            # digital scale gamma, softplus-positive; init near Glorot scale
            "log_scale": jnp.full((), np.log(np.expm1(
                float(np.sqrt(2.0 / (self.in_dim + self.out_dim)) * np.sqrt(self.in_dim))))),
        }

    def _quant(self, mp: dict) -> dict:
        cb = AnalogUnitary.codebook(self)  # type: ignore[arg-type]
        if cb is None:
            return mp
        return q_lib.quantize_mesh_params(mp, cb, ste=True)

    def apply(self, params: dict, x: Array, *, key: Array | None = None) -> Array:
        xc = _as_complex(x)
        pad = self.n - x.shape[-1]
        if pad:
            xc = jnp.concatenate(
                [xc, jnp.zeros(xc.shape[:-1] + (pad,), xc.dtype)], axis=-1)
        u_p, v_p = self._quant(params["u"]), self._quant(params["v"])
        atten = jax.nn.sigmoid(params["atten_logit"]).astype(jnp.complex64)
        scale = jax.nn.softplus(params["log_scale"])
        kv, ku, kd = (jax.random.split(key, 3)
                      if key is not None and self.hardware is not None
                      else (None, None, None))
        if self.backend == "pallas":
            if self.output == "abs":
                # one fused kernel: V-mesh -> diag -> U-mesh -> |detect|;
                # detector noise/floor compose on the magnitudes outside
                y = kernel_ops.rfnn_linear(
                    v_p, atten, u_p, xc, n=self.n, scale=scale,
                    v_plan=self.v_plan, u_plan=self.u_plan,
                    hardware=self.hardware, key_v=kv, key_u=ku)
                # kernel output is the nonnegative magnitude, so the "abs"
                # readout (detector noise/floor included) applies directly
                return _readout(y[..., : self.out_dim], self.output,
                                self.hardware, kd)
            h = kernel_ops.mesh_apply(v_p, xc, n=self.n, plan=self.v_plan,
                                      hardware=self.hardware, key=kv)
            h = h * atten
            y = kernel_ops.mesh_apply(u_p, h, n=self.n, plan=self.u_plan,
                                      hardware=self.hardware, key=ku)
            y = scale * y[..., : self.out_dim]
            return _readout(y, self.output, self.hardware, kd)
        if self.hardware is not None:
            h = hw_lib.apply_mesh_hw(self.v_plan, v_p, xc, self.hardware, kv)
            h = h * atten
            y = hw_lib.apply_mesh_hw(self.u_plan, u_p, h, self.hardware, ku)
            y = scale * y[..., : self.out_dim]
            return _readout(y, self.output, self.hardware, kd)
        h = mesh_lib.apply_mesh(self.v_plan, v_p, xc)
        h = h * atten
        y = mesh_lib.apply_mesh(self.u_plan, u_p, h)
        y = scale * y[..., : self.out_dim]
        return _readout(y, self.output, None, None)

    def init_from_matrix(self, m: np.ndarray) -> dict:
        """Program the layer to realize a given matrix.

        Runs the compiler's ``synthesize`` + ``program`` passes (analytic
        Reck factorization) and adopts the resulting program's plans.
        """
        from repro import compile as compile_mod  # lazy: core <-> compile
        from repro.compile.passes import inv_softplus, logit

        prog = compile_mod.program(compile_mod.synthesize(m), method="reck")
        la = prog.layers[0]
        if la.n != self.n:
            raise ValueError(f"matrix pad size {la.n} != layer size {self.n}")
        # The analytic program lives on reck plans; adopt them (device
        # reprogramming changes the physical layout, not the API).
        params = {
            "u": dict(la.u_params),
            "v": dict(la.v_params),
            "atten_logit": logit(jnp.asarray(la.attenuation, jnp.float32)),
            "log_scale": inv_softplus(jnp.asarray(la.scale, jnp.float32)),
        }
        object.__setattr__(self, "_u_plan", la.u_plan)
        object.__setattr__(self, "_v_plan", la.v_plan)
        return params

    def n_cells(self) -> int:
        return self.u_plan.n_cells + self.v_plan.n_cells


@dataclasses.dataclass(frozen=True)
class AnalogSequence:
    """An L-deep stack of square n x n analog linear layers (the paper's
    multi-layer microwave ANN, Sec. V): per layer V-mesh -> attenuation ->
    U-mesh -> digital scale -> |detect|, the detected magnitude feeding the
    next layer.

    With ``backend="pallas"`` the **whole network** runs as one fused
    Pallas megakernel per direction (``repro.kernels.ops.rfnn_network``):
    inter-layer activations never round-trip through HBM, and packed
    coefficients are cached per parameter identity, so steady-state
    inference does zero packing work.  The reference backend composes the
    per-layer :class:`AnalogLinear` modules; both backends consume
    identical PRNG keys, so they agree draw-for-draw under phase noise.

    Inter-layer detection is the ideal magnitude ``|.|`` (the RF signal is
    re-modulated layer to layer); the detector chain's noise and
    sensitivity floor apply once, at the network readout (``output="abs"``
    with a hardware model).
    """

    n: int
    depth: int
    quantize: str | None = None
    hardware: hw_lib.HardwareModel | None = None
    output: OutputMode = "abs"
    backend: Backend = "reference"

    def __post_init__(self):
        if self.depth < 1:
            raise ValueError(f"depth must be >= 1, got {self.depth}")
        layer = AnalogLinear(in_dim=self.n, out_dim=self.n,
                             quantize=self.quantize, hardware=self.hardware,
                             output="complex", backend=self.backend)
        object.__setattr__(self, "_layer", layer)

    @property
    def layer(self) -> AnalogLinear:
        return self._layer  # type: ignore[attr-defined]

    def init(self, key: Array) -> dict:
        keys = jax.random.split(key, self.depth)
        return {"layers": tuple(self.layer.init(k) for k in keys)}

    def _keys(self, key: Array | None):
        """Per-layer keys + the readout key; the fused path splits each
        layer key exactly like ``AnalogLinear.apply`` (kv, ku, kd)."""
        if key is None or self.hardware is None:
            return (None,) * self.depth, None
        return (tuple(jax.random.fold_in(key, l) for l in range(self.depth)),
                jax.random.fold_in(key, self.depth))

    def apply(self, params: dict, x: Array, *, key: Array | None = None) -> Array:
        xc = _as_complex(x)
        layer_keys, kdet = self._keys(key)
        if self.backend == "pallas":
            layer_args = kernel_ops.memoize_by_leaf_ids(
                ("analog_sequence_args", self), (params["layers"], layer_keys),
                lambda: self._layer_args(params["layers"], layer_keys))
            y = kernel_ops.rfnn_network(layer_args, xc, n=self.n,
                                        hardware=self.hardware)
            return _readout(y, self.output, self.hardware, kdet)
        h = xc
        for l in range(self.depth):
            h = jnp.abs(self.layer.apply(params["layers"][l], h,
                                         key=layer_keys[l]))
        return _readout(h, self.output, self.hardware, kdet)

    def _layer_args(self, layer_params, layer_keys) -> tuple:
        args = []
        for p, k in zip(layer_params, layer_keys):
            la = {
                "v": self.layer._quant(p["v"]),
                "u": self.layer._quant(p["u"]),
                "atten": jax.nn.sigmoid(p["atten_logit"]),
                "scale": jax.nn.softplus(p["log_scale"]),
            }
            if k is not None:
                kv, ku, _ = jax.random.split(k, 3)
                la["key_v"], la["key_u"] = kv, ku
            args.append(la)
        return tuple(args)

    def n_cells(self) -> int:
        return self.depth * self.layer.n_cells()


@dataclasses.dataclass(frozen=True)
class TiledAnalogLinear:
    """A large (out x in) matmul as a grid of analog tile processors.

    The weight is a (To x Ti) grid of tile_size^2 analog SVD cores; tile
    row outputs are combined coherently (power combiner after matched lines)
    and the readout mode applies after combination.

    With ``backend="pallas"`` the **whole grid** runs as one fused
    tile-grid megakernel per direction (``repro.kernels.ops.tiled_apply``):
    every input tile sweeps through its row's meshes and the row combine
    happens in VMEM, instead of the To*Ti separate mesh applications the
    double-vmapped reference composition launches.  Packed coefficients
    are cached per parameter identity, so steady-state inference does zero
    packing work; gradients flow through the same kernel VJP the per-tile
    path uses (draw-for-draw, gradient-for-gradient interchangeable).
    """

    in_dim: int
    out_dim: int
    tile_size: int = 16
    quantize: str | None = None
    hardware: hw_lib.HardwareModel | None = None
    output: OutputMode = "real"
    backend: Backend = "reference"

    def __post_init__(self):
        t = self.tile_size
        if t % 2:
            raise ValueError("tile_size must be even")
        if self.in_dim % t or self.out_dim % t:
            raise ValueError(
                f"dims ({self.out_dim},{self.in_dim}) must be multiples of tile {t}")
        object.__setattr__(self, "_tile", AnalogLinear(
            in_dim=t, out_dim=t, quantize=self.quantize, hardware=None,
            output="complex", backend=self.backend))

    @property
    def tile(self) -> AnalogLinear:
        return self._tile  # type: ignore[attr-defined]

    def grid(self) -> tuple[int, int]:
        return (self.out_dim // self.tile_size, self.in_dim // self.tile_size)

    def init(self, key: Array) -> dict:
        to, ti = self.grid()
        keys = jax.random.split(key, to * ti).reshape(to, ti, 2)
        return jax.vmap(jax.vmap(self.tile.init))(keys)

    def apply(self, params: dict, x: Array, *, key: Array | None = None) -> Array:
        to, ti = self.grid()
        t = self.tile_size
        if self.backend == "pallas":
            # one fused tile-grid kernel per direction: all To*Ti meshes
            # swept and row-combined in VMEM; readout applies after the
            # combine, on the kernel's complex output (same as reference)
            tiles = kernel_ops.memoize_by_leaf_ids(
                ("tiled_analog_args", self), params,
                lambda: self._tile_args(params))
            # every tile shares the module's plan pair (init_from_matrix
            # may have repointed it onto Reck layouts)
            pair = (self.tile.v_plan, self.tile.u_plan)
            y = kernel_ops.tiled_apply(tiles, _as_complex(x), n=t,
                                       plans=((pair,) * ti,) * to)
        else:
            xt = x.reshape(x.shape[:-1] + (ti, t))  # [..., Ti, t]

            def one_tile(p, xin):
                return self.tile.apply(p, xin)

            # vmap over the input-tile axis, then the output-tile axis.
            def row(prow):
                ys = jax.vmap(one_tile, in_axes=(0, -2),
                              out_axes=-2)(prow, xt)
                return jnp.sum(ys, axis=-2)  # coherent combine over tiles

            y = jax.vmap(row, in_axes=0, out_axes=-2)(params)  # [..., To, t]
            y = y.reshape(y.shape[:-2] + (self.out_dim,))
        if self.hardware is not None and self.output == "abs":
            return hw_lib.detect_magnitude(y, self.hardware, key)
        return _readout(y, self.output, None, None)

    def _tile_args(self, params: dict) -> tuple:
        """Per-tile kernel argument dicts from the stacked [To, Ti, ...]
        parameter pytree — the same derivation the reference tile apply
        performs (quantized phases, sigmoid attenuation, softplus scale),
        memoized by parameter leaf identity so the downstream pack cache
        hits in the serving steady state."""
        to, ti = self.grid()
        rows = []
        for o in range(to):
            row = []
            for i in range(ti):
                p = jax.tree.map(lambda a, o=o, i=i: a[o, i], params)
                row.append({
                    "v": self.tile._quant(p["v"]),
                    "u": self.tile._quant(p["u"]),
                    "atten": jax.nn.sigmoid(p["atten_logit"]),
                    "scale": jax.nn.softplus(p["log_scale"]),
                })
            rows.append(tuple(row))
        return tuple(rows)

    def n_cells(self) -> int:
        to, ti = self.grid()
        return to * ti * self.tile.n_cells()
