"""Hardware-imperfection model of the RF analog processor (paper Sec. III/V).

Models the measured non-idealities the paper reports:

* imperfect quadrature hybrids (amplitude imbalance + phase error) — Fig. 6
  shows measured |S| peaks below the theoretical 1/sqrt(2) level;
* insertion loss per cell — Sec. V quotes ~0.25 dB per wavelength of
  microstrip with a ~1-wavelength unit cell;
* phase-shifter deviation from the nominal Table I values;
* power detection at the outputs: the detector reads |V| (the paper's
  natural ``abs`` activation) with a sensitivity floor (~-60 dBm) and
  additive measurement noise.

The model composes structurally: Phi_err . H_err . Theta_err . H_err with a
scalar loss factor, so it degrades exactly the quantities the paper measures
(unitarity, peak |S|, classification accuracy).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mesh as mesh_lib
from repro.core.cell import Z0_OHM

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class HardwareModel:
    """Imperfection parameters of one 2x2 cell and its readout chain."""

    #: hybrid amplitude imbalance epsilon: through/coupled amplitude ratio
    #: (1+eps)/(1-eps); 0 = ideal 3-dB split.
    hybrid_imbalance: float = 0.03
    #: hybrid quadrature phase error (radians) added to the 90-deg arm.
    hybrid_phase_err: float = np.deg2rad(2.0)
    #: insertion loss per cell (dB); Sec. V: ~0.25 dB/lambda, cell ~ 1 lambda.
    cell_loss_db: float = 0.25
    #: rms random deviation of each phase shifter from nominal (radians).
    phase_sigma: float = np.deg2rad(1.5)
    #: detector sensitivity floor (dBm) — readings below this are noise.
    detector_floor_dbm: float = -60.0
    #: relative rms detector noise on measured voltage magnitude.
    detector_sigma: float = 0.01

    @property
    def cell_gain(self):
        # no float() cast: fields may be traced arrays (Monte-Carlo yield
        # sweeps construct HardwareModel inside vmap)
        return 10.0 ** (-self.cell_loss_db / 20.0)


IDEAL = HardwareModel(hybrid_imbalance=0.0, hybrid_phase_err=0.0,
                      cell_loss_db=0.0, phase_sigma=0.0,
                      detector_floor_dbm=-300.0, detector_sigma=0.0)


def imperfect_hybrid(hw: HardwareModel) -> Array:
    """Forward block of a lossy, imbalanced quadrature hybrid."""
    e = jnp.asarray(hw.hybrid_imbalance, jnp.float32)
    thru = ((1.0 + e) * jnp.exp(1j * jnp.asarray(hw.hybrid_phase_err,
                                                 jnp.float32)) * 1j)
    coup = (1.0 - e).astype(jnp.complex64)
    # built with stacks (not jnp.array literals) so traced fields vmap
    m = jnp.stack([jnp.stack([thru, coup], -1),
                   jnp.stack([coup, thru], -1)], -2).astype(jnp.complex64)
    # keep passive: renormalize worst-case row power to <= 1, then 3-dB split
    scale = jnp.sqrt(jnp.max(jnp.sum(jnp.abs(m) ** 2, axis=1)))
    return -m / scale


def imperfect_cell_matrix(theta: Array, phi: Array, hw: HardwareModel,
                          key: Array | None = None) -> Array:
    """t(theta, phi) under the hardware model; broadcasts like cell_matrix."""
    theta = jnp.asarray(theta, jnp.float32)
    phi = jnp.asarray(phi, jnp.float32)
    if key is not None:
        # no Python bool on phase_sigma: the field may be traced (vmap'd
        # yield sweeps); sigma == 0 adds exact zeros, same numerics
        sigma = jnp.asarray(hw.phase_sigma, jnp.float32)
        k1, k2 = jax.random.split(key)
        theta = theta + sigma * jax.random.normal(k1, theta.shape)
        phi = phi + sigma * jax.random.normal(k2, phi.shape)
    h = imperfect_hybrid(hw)

    def shifter(p):
        e = jnp.exp(-1j * p.astype(jnp.complex64))
        z = jnp.zeros_like(e)
        o = jnp.ones_like(e)
        return jnp.stack([jnp.stack([e, z], -1), jnp.stack([z, o + 0j], -1)], -2)

    t = shifter(phi) @ h @ shifter(theta) @ h
    return hw.cell_gain * t


def apply_mesh_hw(plan: mesh_lib.MeshPlan, params: dict, x: Array,
                  hw: HardwareModel, key: Array | None = None) -> Array:
    """Propagate through the mesh with per-cell hardware imperfections."""
    if x.shape[-1] != plan.n:
        raise ValueError(f"expected trailing dim {plan.n}, got {x.shape}")
    x = x.astype(jnp.complex64)
    alpha_in = params.get("alpha_in")
    if alpha_in is not None:
        x = x * jnp.exp(-1j * alpha_in.astype(jnp.complex64))
    t_all = imperfect_cell_matrix(params["theta"], params["phi"], hw, key)
    eye = jnp.eye(2, dtype=t_all.dtype)
    t_all = jnp.where(jnp.asarray(plan.active)[..., None, None], t_all, eye)

    def step(carry, col):
        t2, tp, sl, rl = col
        return mesh_lib._apply_column(carry, t2, tp, sl, rl), None

    cols = (t_all, jnp.asarray(plan.top), jnp.asarray(plan.slot),
            jnp.asarray(plan.role))
    x, _ = jax.lax.scan(step, x, cols)
    alpha = params.get("alpha")
    if alpha is not None:
        x = x * jnp.exp(-1j * alpha.astype(jnp.complex64))
    return x


def detect_magnitude(v: Array, hw: HardwareModel, key: Array | None = None,
                     z0: float = Z0_OHM) -> Array:
    """Power-detector readout: measured |V| with floor and noise.

    This is the paper's ``abs`` activation as the hardware actually provides
    it (Sec. IV-A: "the absolute function is naturally applied").
    """
    mag = jnp.abs(v)
    if key is not None and hw.detector_sigma > 0:
        mag = mag * (1.0 + hw.detector_sigma * jax.random.normal(key, mag.shape))
    # sensitivity floor: power below floor reads as the floor's voltage.
    floor_w = 10.0 ** (hw.detector_floor_dbm / 10.0) * 1e-3
    v_floor = jnp.sqrt(2.0 * z0 * floor_w)
    return jnp.maximum(mag, v_floor)
