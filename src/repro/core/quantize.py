"""Discrete phase-shifter quantization (paper Sec. III, Table I).

The prototype's phase shifters are two SP6T switch-selected line lengths:
each shifter realizes one of six discrete phases (Table I), so a cell has
36 states.  Two trainable-quantization paths are provided:

* :func:`ste_quantize` — straight-through estimator: forward = nearest
  codebook value, backward = identity.  Used on the SGD path ("quantization
  aware" training of mesh phases).
* integer state codes + :mod:`repro.core.dspsa` — the paper's Algorithm I
  path, optimizing the discrete codes directly.

``uniform_codebook`` supports beyond-paper resolution studies (e.g. the
binary-neural-network remark in Sec. IV-A).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cell import TABLE_I_PHASES_RAD


def table_i_codebook() -> jnp.ndarray:
    """The six measured line phases of the prototype (radians)."""
    return jnp.asarray(TABLE_I_PHASES_RAD, jnp.float32)


def uniform_codebook(bits: int, lo: float = 0.0, hi: float = 2 * np.pi) -> jnp.ndarray:
    """2**bits uniformly spaced phases in [lo, hi)."""
    k = 2**bits
    return jnp.linspace(lo, hi, k, endpoint=False).astype(jnp.float32)


def nearest_code(phase: jax.Array, codebook: jax.Array) -> jax.Array:
    """Index of the nearest codebook entry (circular distance on phases)."""
    d = phase[..., None] - codebook
    d = jnp.abs(jnp.mod(d + np.pi, 2 * np.pi) - np.pi)
    return jnp.argmin(d, axis=-1).astype(jnp.int32)


def codes_to_phase(codes: jax.Array, codebook: jax.Array) -> jax.Array:
    return jnp.take(codebook, codes, axis=0)


@jax.custom_vjp
def ste_quantize(phase: jax.Array, codebook: jax.Array) -> jax.Array:
    """Nearest-codebook quantization with straight-through gradients."""
    return codes_to_phase(nearest_code(phase, codebook), codebook)


def _ste_fwd(phase, codebook):
    return ste_quantize(phase, codebook), None


def _ste_bwd(_, g):
    return g, None


ste_quantize.defvjp(_ste_fwd, _ste_bwd)


def quantize_mesh_params(params: dict, codebook: jax.Array, *, ste: bool = True) -> dict:
    """Quantize the phase entries (theta/phi/alpha*) of a mesh param dict."""
    fn = (lambda p: ste_quantize(p, codebook)) if ste else (
        lambda p: codes_to_phase(nearest_code(p, codebook), codebook))
    return {k: fn(v) if k in ("theta", "phi", "alpha", "alpha_in") else v
            for k, v in params.items()}


def mesh_params_to_codes(params: dict, codebook: jax.Array) -> dict:
    """Project continuous mesh phases onto integer state codes (device view)."""
    return {k: nearest_code(v, codebook)
            for k, v in params.items() if k in ("theta", "phi", "alpha", "alpha_in")}


def codes_to_mesh_params(codes: dict, codebook: jax.Array) -> dict:
    """Device view back to phase values."""
    return {k: codes_to_phase(v, codebook) for k, v in codes.items()}
