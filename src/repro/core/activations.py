"""Activation functions used by the paper's networks (Sec. IV).

The analog layer's activation is magnitude detection (``abs``) — it is what
the power detector physically measures.  All other activations run in digital
post-processing, exactly as in the paper.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def abs_detect(x: jax.Array) -> jax.Array:
    """Magnitude detection — the analog layer's natural activation."""
    return jnp.abs(x)


def sigmoid(x: jax.Array) -> jax.Array:
    return jax.nn.sigmoid(x)


def leaky_relu(x: jax.Array, slope: float = 0.01) -> jax.Array:
    return jax.nn.leaky_relu(x, slope)


def softmax(x: jax.Array, axis: int = -1) -> jax.Array:
    return jax.nn.softmax(x, axis=axis)


ACTIVATIONS = {
    "abs": abs_detect,
    "sigmoid": sigmoid,
    "leaky_relu": leaky_relu,
    "softmax": softmax,
    "relu": jax.nn.relu,
    "gelu": jax.nn.gelu,
    "silu": jax.nn.silu,
    "tanh": jnp.tanh,
    "identity": lambda x: x,
}


def get_activation(name: str):
    try:
        return ACTIVATIONS[name]
    except KeyError as e:
        raise KeyError(f"unknown activation {name!r}; have {sorted(ACTIVATIONS)}") from e
