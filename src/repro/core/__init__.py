"""Core library: the paper's RF analog processor as composable JAX modules."""

from repro.core.cell import (
    TABLE_I_PHASES_DEG,
    TABLE_I_PHASES_RAD,
    cell_matrix,
    output_powers,
    output_voltages,
    s_parameters,
)
from repro.core.mesh import (
    MeshPlan,
    apply_mesh,
    clements_plan,
    init_mesh_params,
    mesh_matrix,
    pack_cells_to_columns,
)
from repro.core.decompose import fit_program, random_unitary, reck_program
from repro.core.svd_synthesis import SynthesizedMatrix, synthesize
from repro.core.quantize import (
    ste_quantize,
    table_i_codebook,
    uniform_codebook,
)
from repro.core.hardware import IDEAL, HardwareModel, apply_mesh_hw, detect_magnitude
from repro.core.analog_linear import (
    AnalogLinear,
    AnalogSequence,
    AnalogUnitary,
    TiledAnalogLinear,
)
from repro.core.activations import abs_detect, get_activation

__all__ = [
    "TABLE_I_PHASES_DEG", "TABLE_I_PHASES_RAD", "cell_matrix", "output_powers",
    "output_voltages", "s_parameters", "MeshPlan", "apply_mesh",
    "clements_plan", "init_mesh_params", "mesh_matrix", "pack_cells_to_columns",
    "fit_program", "random_unitary", "reck_program", "SynthesizedMatrix",
    "synthesize", "ste_quantize", "table_i_codebook", "uniform_codebook",
    "IDEAL", "HardwareModel", "apply_mesh_hw", "detect_magnitude",
    "AnalogLinear", "AnalogSequence", "AnalogUnitary", "TiledAnalogLinear",
    "abs_detect", "get_activation",
]
