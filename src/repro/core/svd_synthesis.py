"""Arbitrary-matrix synthesis via SVD (paper Eq. 31, Sec. IV-B).

Any real or complex matrix M factors as M = U . D . V^H with U, V unitary and
D diagonal non-negative.  U and V^H are realized as cell meshes (programmed
analytically by :func:`repro.core.decompose.reck_program`); D is realized as
per-channel attenuation.  A passive network can only attenuate, so D is
normalized by the largest singular value and the overall scale is recovered
digitally — exactly the paper's pre/post scaling-factor gamma (Fig. 11).
Rectangular matrices are zero-padded to the enclosing even square.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import decompose, mesh as mesh_lib

Array = jax.Array


@dataclasses.dataclass
class SynthesizedMatrix:
    """A programmed analog realization of an arbitrary matrix."""

    out_dim: int
    in_dim: int
    n: int  # padded square size (even)
    u_plan: mesh_lib.MeshPlan
    u_params: dict
    v_plan: mesh_lib.MeshPlan
    v_params: dict
    attenuation: jnp.ndarray  # [n] in [0, 1] — diagonal D / sigma_max
    scale: float  # sigma_max, recovered in digital post-processing

    @property
    def n_cells(self) -> int:
        return self.u_plan.n_cells + self.v_plan.n_cells

    def apply(self, x: Array) -> Array:
        """y = M x for x[..., in_dim]; returns [..., out_dim] (complex)."""
        pad = self.n - x.shape[-1]
        if pad:
            x = jnp.concatenate(
                [x, jnp.zeros(x.shape[:-1] + (pad,), x.dtype)], axis=-1)
        h = mesh_lib.apply_mesh(self.v_plan, self.v_params, x)
        h = h * self.attenuation.astype(jnp.complex64)
        h = mesh_lib.apply_mesh(self.u_plan, self.u_params, h)
        return self.scale * h[..., : self.out_dim]

    def matrix(self) -> np.ndarray:
        eye = jnp.eye(self.in_dim, dtype=jnp.complex64)
        return np.asarray(self.apply(eye)).T


def _pad_even(k: int) -> int:
    return k + (k % 2)


def synthesize(m: np.ndarray) -> SynthesizedMatrix:
    """Program an analog realization of the (possibly rectangular) matrix m."""
    m = np.asarray(m)
    out_dim, in_dim = m.shape
    n = _pad_even(max(out_dim, in_dim))
    mp = np.zeros((n, n), np.complex128)
    mp[:out_dim, :in_dim] = m
    u, s, vh = np.linalg.svd(mp)
    smax = float(s.max()) if s.max() > 0 else 1.0
    u_plan, u_params = decompose.reck_program(u)
    v_plan, v_params = decompose.reck_program(vh)
    return SynthesizedMatrix(
        out_dim=out_dim, in_dim=in_dim, n=n,
        u_plan=u_plan, u_params=u_params,
        v_plan=v_plan, v_params=v_params,
        attenuation=jnp.asarray(s / smax, jnp.float32),
        scale=smax,
    )


def synthesis_error(m: np.ndarray, syn: SynthesizedMatrix) -> float:
    return float(np.abs(syn.matrix() - np.asarray(m)).max())
