"""Arbitrary-matrix synthesis via SVD (paper Eq. 31, Sec. IV-B).

Compatibility facade over the analog program compiler: the factorization
itself now lives in :mod:`repro.compile` (``synthesize`` + ``program``
passes), and :meth:`SynthesizedMatrix.apply` runs on the fused Pallas
mesh kernels (``repro.kernels.ops.mesh_apply``) — the pure-jnp reference
chain this module used to carry is gone.  Kept so existing call sites
(`synthesize(m)` -> programmed object -> `apply`/`matrix`) stay stable.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mesh as mesh_lib

Array = jax.Array


@dataclasses.dataclass
class SynthesizedMatrix:
    """A programmed analog realization of an arbitrary matrix."""

    out_dim: int
    in_dim: int
    n: int  # padded square size (even)
    u_plan: mesh_lib.MeshPlan
    u_params: dict
    v_plan: mesh_lib.MeshPlan
    v_params: dict
    attenuation: jnp.ndarray  # [n] in [0, 1] — diagonal D / sigma_max
    scale: float  # sigma_max, recovered in digital post-processing

    @property
    def n_cells(self) -> int:
        return self.u_plan.n_cells + self.v_plan.n_cells

    def apply(self, x: Array) -> Array:
        """y = M x for x[..., in_dim]; returns [..., out_dim] (complex).

        Runs V-mesh -> attenuation -> U-mesh through the fused Pallas
        kernels — the same path training and serving use; there is no
        reference fallback.
        """
        from repro.kernels import ops as kernel_ops

        if x.shape[-1] != self.in_dim:
            raise ValueError(
                f"expected trailing dim {self.in_dim}, got {x.shape}")
        pad = self.n - x.shape[-1]
        if pad:
            x = jnp.concatenate(
                [x, jnp.zeros(x.shape[:-1] + (pad,), x.dtype)], axis=-1)
        x = x.astype(jnp.complex64)
        h = kernel_ops.mesh_apply(self.v_params, x, n=self.n,
                                  plan=self.v_plan)
        h = h * self.attenuation.astype(jnp.complex64)
        h = kernel_ops.mesh_apply(self.u_params, h, n=self.n,
                                  plan=self.u_plan)
        return self.scale * h[..., : self.out_dim]

    def matrix(self) -> np.ndarray:
        eye = jnp.eye(self.in_dim, dtype=jnp.complex64)
        return np.asarray(self.apply(eye)).T


def synthesize(m: np.ndarray) -> SynthesizedMatrix:
    """Program an analog realization of the (possibly rectangular) matrix m.

    Delegates to the compiler's ``synthesize`` + ``program`` passes
    (analytic Reck factorization); use :mod:`repro.compile` directly for
    quantization, hardware calibration and megakernel lowering.
    """
    from repro import compile as compile_mod  # lazy: core <-> compile

    prog = compile_mod.program(compile_mod.synthesize(m), method="reck")
    la = prog.layers[0]
    return SynthesizedMatrix(
        out_dim=la.out_dim, in_dim=la.in_dim, n=la.n,
        u_plan=la.u_plan, u_params=la.u_params,
        v_plan=la.v_plan, v_params=la.v_params,
        attenuation=la.attenuation, scale=float(la.scale),
    )


def synthesis_error(m: np.ndarray, syn: SynthesizedMatrix) -> float:
    return float(np.abs(syn.matrix() - np.asarray(m)).max())
