"""N-channel meshes of 2x2 RF analog processor cells (paper Sec. IV-B, Fig. 13).

A mesh is a sequence of *columns*; each column applies a set of
non-overlapping 2x2 cells to adjacent channel pairs ``(p, p+1)``.  An N x N
unitary needs S = N(N-1)/2 cells (paper Eq. 28) plus a diagonal phase screen
``Sigma(N)`` (Eq. 27).

Two layouts are provided:

* ``clements`` — rectangular, N columns alternating pair offsets 0/1, depth N.
  This is the layout used when *training phases directly* (the paper's MNIST
  network trains the 8x8 mesh parameters directly rather than synthesizing a
  target matrix).
* ``reck`` — triangular, depth 2N-3; produced by the analytic programmer in
  :mod:`repro.core.decompose` when a *target* unitary must be realized.

The forward apply is a ``lax.scan`` over columns.  Each column update is
scatter-free: per-channel static role/slot maps select the new value from the
rotated pair values, which keeps the HLO O(1) in N and maps cleanly onto the
Pallas kernel in ``repro.kernels.givens_mesh`` (batch panel resident in VMEM).
"""

from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.cell import cell_matrix

Array = jax.Array

_ROLE_NONE, _ROLE_TOP, _ROLE_BOT = 0, 1, 2


# ---------------------------------------------------------------------------
# Mesh plan (static layout metadata)
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True, eq=False)
class MeshPlan:
    """Static layout of a cell mesh.

    Hashable *by content* (``n`` + the top/active layout bytes; slot/role
    are derived), so plans can key ``functools.lru_cache``-memoized
    schedule lowering and serve as jit statics: two independently
    constructed but identical plans hit the same caches.

    Attributes:
      n: number of channels (even).
      top: int32 [C, P] — top channel index of each pair slot per column.
      active: bool [C, P] — whether the slot holds a real cell.
      slot: int32 [C, n] — pair slot feeding each channel (0 when none).
      role: int8 [C, n] — 0 untouched / 1 top of pair / 2 bottom of pair.
    """

    n: int
    top: np.ndarray
    active: np.ndarray
    slot: np.ndarray
    role: np.ndarray

    def _key(self) -> tuple:
        return (self.n, self.top.shape,
                self.top.tobytes(), self.active.tobytes())

    def __eq__(self, other):
        if not isinstance(other, MeshPlan):
            return NotImplemented
        return self._key() == other._key()

    def __hash__(self):
        return hash(self._key())

    @property
    def n_columns(self) -> int:
        return self.top.shape[0]

    @property
    def pairs_per_column(self) -> int:
        return self.top.shape[1]

    @property
    def n_cells(self) -> int:
        return int(self.active.sum())

    def param_shape(self) -> tuple[int, int]:
        """Shape of the theta/phi parameter arrays."""
        return (self.n_columns, self.pairs_per_column)


def _make_plan(n: int, top: np.ndarray, active: np.ndarray) -> MeshPlan:
    """Derive the per-channel role/slot maps and build the plan."""
    c, _ = top.shape
    slot = np.zeros((c, n), np.int32)
    role = np.zeros((c, n), np.int8)
    for ci in range(c):
        for si in range(top.shape[1]):
            if not active[ci, si]:
                continue
            p = int(top[ci, si])
            if p < 0 or p + 1 >= n:
                raise ValueError(f"pair ({p},{p+1}) out of range for n={n}")
            if role[ci, p] != _ROLE_NONE or role[ci, p + 1] != _ROLE_NONE:
                raise ValueError(f"overlapping pairs in column {ci}")
            slot[ci, p] = si
            role[ci, p] = _ROLE_TOP
            slot[ci, p + 1] = si
            role[ci, p + 1] = _ROLE_BOT
    return MeshPlan(n=n, top=top, active=active, slot=slot, role=role)


@functools.lru_cache(maxsize=64)
def clements_plan(n: int) -> MeshPlan:
    """Rectangular mesh: N columns, alternating offsets; N(N-1)/2 cells."""
    if n < 2 or n % 2:
        raise ValueError(f"mesh size must be even and >= 2, got {n}")
    p = n // 2
    top = np.zeros((n, p), np.int32)
    active = np.zeros((n, p), bool)
    for c in range(n):
        off = c % 2
        starts = np.arange(off, n - 1, 2)
        top[c, : len(starts)] = starts
        active[c, : len(starts)] = True
    plan = _make_plan(n, top, active)
    assert plan.n_cells == n * (n - 1) // 2
    return plan


def pack_cells_to_columns(n: int, cells: list[tuple[int, float, float]],
                          pad_to_columns: int | None = None):
    """Greedy list-schedule of an ordered cell sequence into mesh columns.

    ``cells`` is a list of ``(p, theta, phi)`` applied in order (cell i acts
    before cell j for i < j when they share a channel).  Returns
    ``(MeshPlan, theta[C,P], phi[C,P])``.  ``pad_to_columns`` appends empty
    columns for shape stability across programs of the same size.
    """
    if n % 2:
        raise ValueError("mesh size must be even")
    free = np.zeros(n, np.int64)  # earliest column each channel is free at
    placed: list[list[tuple[int, float, float]]] = [[]]
    for p, th, ph in cells:
        col = int(max(free[p], free[p + 1]))
        while len(placed) <= col:
            placed.append([])
        placed[col].append((p, th, ph))
        free[p] = free[p + 1] = col + 1
    n_cols = len(placed)
    if pad_to_columns is not None:
        if n_cols > pad_to_columns:
            raise ValueError(f"packed {n_cols} columns > pad {pad_to_columns}")
        n_cols = pad_to_columns
    pmax = n // 2
    top = np.zeros((n_cols, pmax), np.int32)
    active = np.zeros((n_cols, pmax), bool)
    theta = np.zeros((n_cols, pmax), np.float32)
    phi = np.zeros((n_cols, pmax), np.float32)
    for c, col_cells in enumerate(placed):
        for k, (p, th, ph) in enumerate(sorted(col_cells)):
            top[c, k] = p
            active[c, k] = True
            theta[c, k] = th
            phi[c, k] = ph
    return _make_plan(n, top, active), jnp.asarray(theta), jnp.asarray(phi)


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def init_mesh_params(key: Array, plan: MeshPlan, *, with_sigma: bool = True):
    """Random mesh parameters: dict of theta, phi [C, P] and alpha [n]."""
    k1, k2, k3 = jax.random.split(key, 3)
    c, p = plan.param_shape()
    params = {
        "theta": jax.random.uniform(k1, (c, p), jnp.float32, 0.0, np.pi),
        "phi": jax.random.uniform(k2, (c, p), jnp.float32, 0.0, 2 * np.pi),
    }
    if with_sigma:
        params["alpha"] = jax.random.uniform(k3, (plan.n,), jnp.float32, 0.0, 2 * np.pi)
    return params


# ---------------------------------------------------------------------------
# Forward application
# ---------------------------------------------------------------------------

def _apply_column(x: Array, t2: Array, top: Array, slot: Array, role: Array) -> Array:
    """Apply one column of 2x2 cells to ``x[..., n]`` (complex), scatter-free.

    t2: [P, 2, 2] complex cells; top: [P] int32; slot/role: [n] channel maps.
    """
    a = jnp.take(x, top, axis=-1)          # [..., P] top channel value
    b = jnp.take(x, top + 1, axis=-1)      # [..., P] bottom channel value
    a2 = t2[..., 0, 0] * a + t2[..., 0, 1] * b
    b2 = t2[..., 1, 0] * a + t2[..., 1, 1] * b
    from_top = jnp.take(a2, slot, axis=-1)  # [..., n]
    from_bot = jnp.take(b2, slot, axis=-1)
    return jnp.where(role == _ROLE_TOP, from_top,
                     jnp.where(role == _ROLE_BOT, from_bot, x))


def apply_mesh(plan: MeshPlan, params: dict, x: Array) -> Array:
    """Propagate ``x[..., n]`` (complex64) through the mesh.

    Optionally applies an input phase screen ``alpha_in`` (used by the
    analytic Reck programmer, whose exact factorization places the diagonal
    at the input side for this cell convention), then every cell column in
    order, then the output phase screen ``Sigma = diag(e^{-j alpha})`` if
    ``alpha`` is present (paper Eq. 27, negative-delay convention).
    """
    if x.shape[-1] != plan.n:
        raise ValueError(f"expected trailing dim {plan.n}, got {x.shape}")
    x = x.astype(jnp.complex64)
    alpha_in = params.get("alpha_in")
    if alpha_in is not None:
        x = x * jnp.exp(-1j * alpha_in.astype(jnp.complex64))
    theta, phi = params["theta"], params["phi"]
    t_all = cell_matrix(theta, phi)  # [C, P, 2, 2]
    # Mask inactive slots to identity so parked parameters cannot leak in.
    eye = jnp.eye(2, dtype=t_all.dtype)
    t_all = jnp.where(jnp.asarray(plan.active)[..., None, None], t_all, eye)

    def step(carry, col):
        t2, tp, sl, rl = col
        return _apply_column(carry, t2, tp, sl, rl), None

    cols = (t_all, jnp.asarray(plan.top), jnp.asarray(plan.slot), jnp.asarray(plan.role))
    x, _ = jax.lax.scan(step, x, cols)
    alpha = params.get("alpha")
    if alpha is not None:
        x = x * jnp.exp(-1j * alpha.astype(jnp.complex64))
    return x


def mesh_matrix(plan: MeshPlan, params: dict) -> Array:
    """Materialize the N x N complex matrix realized by the mesh."""
    eye = jnp.eye(plan.n, dtype=jnp.complex64)
    cols = apply_mesh(plan, params, eye)  # row k of input -> T e_k
    return cols.T


def mesh_is_unitary(plan: MeshPlan, params: dict, atol: float = 1e-4) -> bool:
    u = mesh_matrix(plan, params)
    err = jnp.abs(u @ u.conj().T - jnp.eye(plan.n, dtype=u.dtype)).max()
    return bool(err < atol)
