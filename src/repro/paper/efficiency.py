"""The analytic efficiency model of paper Sec. V / Table II.

Reproduces the paper's estimates from its own assumptions:

  * reconfigurable power: 0.12 mW per RF switch, N(N+1) switches for an
    N x N unitary -> P = 0.12 * N * (N+1) mW;
  * passive energy/FLOP: detection rate f_d = 10 MHz performs 1e7
    N-dim MVMs/s = 2 N^2 * 1e7 FLOP/s; required output power ~ 1e-5 * N mW
    (-60 dBm detector sensitivity + 10 dB insertion loss) ->
    E/FLOP = P / (2 N^2 f_d) = 1/(2N) fJ/FLOP;
  * unit-cell length ~1 wavelength (12 mm at 10 GHz on eps_r=10 PCB),
    processor depth 2N+1 columns of cells + routing -> delay at light speed
    in the substrate (ns scale), vs us-scale digital dispatch.
"""

from __future__ import annotations

import dataclasses

import numpy as np

C0 = 299_792_458.0


@dataclasses.dataclass(frozen=True)
class RFNNPlatform:
    f0_hz: float = 10e9
    eps_eff: float = 6.7          # microstrip on eps_r=10
    detector_dbm: float = -60.0
    insertion_loss_db: float = 10.0
    detect_rate_hz: float = 10e6
    switch_power_mw: float = 0.12

    @property
    def wavelength_m(self) -> float:
        return C0 / np.sqrt(self.eps_eff) / self.f0_hz


def rfnn_energy_per_flop_fj(n: int, p: RFNNPlatform = RFNNPlatform()) -> float:
    """Passive design: minimum output power / computation rate."""
    out_power_w = n * 10 ** ((p.detector_dbm + p.insertion_loss_db) / 10) * 1e-3
    flops_per_s = 2 * n * n * p.detect_rate_hz
    return out_power_w / flops_per_s * 1e15


def rfnn_reconfig_power_mw(n: int, p: RFNNPlatform = RFNNPlatform()) -> float:
    return p.switch_power_mw * n * (n + 1)


def rfnn_length_cm(n: int, p: RFNNPlatform = RFNNPlatform()) -> float:
    # triangular mesh depth 2N-3 columns + Sigma column + feed lines
    cells = 2 * n - 1
    return cells * p.wavelength_m * 100


def rfnn_delay_ns(n: int, p: RFNNPlatform = RFNNPlatform()) -> float:
    return rfnn_length_cm(n) / 100 / (C0 / np.sqrt(p.eps_eff)) * 1e9


def table2_rows(n: int = 20) -> list[dict]:
    """Reproduce Table II (N=20): platform comparison."""
    p = RFNNPlatform()
    return [
        {"platform": "GPU (V100)", "length_cm": 30.0, "cell_len_lambda": None,
         "complexity": "O(N^2)", "fj_per_flop": 3.1e4, "cost": "medium",
         "delay": "us"},
        {"platform": "FPGA (Arria 10)", "length_cm": 24.0,
         "cell_len_lambda": None, "complexity": "O(N^2)",
         "fj_per_flop": 6.2e4, "cost": "medium", "delay": "us"},
        {"platform": "ONN", "length_cm": 0.76, "cell_len_lambda": 64,
         "complexity": "O(N)", "fj_per_flop": 0.25, "cost": "high",
         "delay": "ps"},
        {"platform": "RFNN (this work)", "length_cm": rfnn_length_cm(n, p),
         "cell_len_lambda": 1, "complexity": "O(N)",
         "fj_per_flop": rfnn_energy_per_flop_fj(n, p), "cost": "low",
         "delay": "ns"},
    ]
