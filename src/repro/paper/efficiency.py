"""The analytic efficiency model of paper Sec. V / Table II, plus a
Monte-Carlo manufacturing-yield sweep over sampled hardware draws.

Reproduces the paper's estimates from its own assumptions:

  * reconfigurable power: 0.12 mW per RF switch, N(N+1) switches for an
    N x N unitary -> P = 0.12 * N * (N+1) mW;
  * passive energy/FLOP: detection rate f_d = 10 MHz performs 1e7
    N-dim MVMs/s = 2 N^2 * 1e7 FLOP/s; required output power ~ 1e-5 * N mW
    (-60 dBm detector sensitivity + 10 dB insertion loss) ->
    E/FLOP = P / (2 N^2 f_d) = 1/(2N) fJ/FLOP;
  * unit-cell length ~1 wavelength (12 mm at 10 GHz on eps_r=10 PCB),
    processor depth 2N+1 columns of cells + routing -> delay at light speed
    in the substrate (ns scale), vs us-scale digital dispatch.
"""

from __future__ import annotations

import dataclasses

import numpy as np

C0 = 299_792_458.0


@dataclasses.dataclass(frozen=True)
class RFNNPlatform:
    f0_hz: float = 10e9
    eps_eff: float = 6.7          # microstrip on eps_r=10
    detector_dbm: float = -60.0
    insertion_loss_db: float = 10.0
    detect_rate_hz: float = 10e6
    switch_power_mw: float = 0.12

    @property
    def wavelength_m(self) -> float:
        return C0 / np.sqrt(self.eps_eff) / self.f0_hz


def rfnn_energy_per_flop_fj(n: int, p: RFNNPlatform = RFNNPlatform()) -> float:
    """Passive design: minimum output power / computation rate."""
    out_power_w = n * 10 ** ((p.detector_dbm + p.insertion_loss_db) / 10) * 1e-3
    flops_per_s = 2 * n * n * p.detect_rate_hz
    return out_power_w / flops_per_s * 1e15


def rfnn_reconfig_power_mw(n: int, p: RFNNPlatform = RFNNPlatform()) -> float:
    return p.switch_power_mw * n * (n + 1)


def rfnn_length_cm(n: int, p: RFNNPlatform = RFNNPlatform()) -> float:
    # triangular mesh depth 2N-3 columns + Sigma column + feed lines
    cells = 2 * n - 1
    return cells * p.wavelength_m * 100


def rfnn_delay_ns(n: int, p: RFNNPlatform = RFNNPlatform()) -> float:
    return rfnn_length_cm(n) / 100 / (C0 / np.sqrt(p.eps_eff)) * 1e9


# ---------------------------------------------------------------------------
# Monte-Carlo yield over sampled hardware draws (Sec. III/V robustness)
# ---------------------------------------------------------------------------

def sample_hardware_draws(key, n_draws: int, base=None, spread: float = 0.5):
    """Sample per-device imperfection parameters around a base model.

    Fabrication variation model: hybrid imbalance is |N(base, spread*base)|
    (a magnitude), quadrature phase error is N(base, spread*base)
    (sign-symmetric), per-cell insertion loss is N(base, spread*base)
    clipped at 0 dB.  Returns a dict of [n_draws] float32 arrays plus
    per-draw phase-noise keys.
    """
    import jax
    import jax.numpy as jnp

    if base is None:
        from repro.paper.prototype import PROTOTYPE
        base = PROTOTYPE
    k1, k2, k3, k4 = jax.random.split(key, 4)

    def around(k, mean):
        return mean * (1.0 + spread * jax.random.normal(k, (n_draws,)))

    return {
        "hybrid_imbalance": jnp.abs(around(k1, base.hybrid_imbalance)),
        "hybrid_phase_err": around(k2, base.hybrid_phase_err),
        "cell_loss_db": jnp.clip(around(k3, base.cell_loss_db), 0.0, None),
        "noise_key": jax.random.split(k4, n_draws),
    }


def monte_carlo_yield(n: int = 8, n_draws: int = 32, *, base=None,
                      spread: float = 0.5, error_threshold: float = 0.25,
                      seed: int = 0, backend: str = "pallas",
                      batch: int = 8, block_b: int = 8) -> dict:
    """Manufacturing-yield estimate: fraction of sampled devices in spec.

    A fixed seeded mesh program and probe batch are propagated through
    ``n_draws`` sampled hardware realizations (``jax.vmap`` over the draw
    axis — with ``backend="pallas"`` the vmap batches the fused kernel's
    grid, so the whole sweep is one kernel launch).  A device is *in spec*
    when the relative L2 error of its detected output against the ideal
    device stays below ``error_threshold``.
    """
    import jax
    import jax.numpy as jnp

    from repro.core import hardware as hw_lib
    from repro.core import mesh as mesh_lib
    from repro.kernels import ops

    if base is None:
        from repro.paper.prototype import PROTOTYPE
        base = PROTOTYPE
    plan = mesh_lib.clements_plan(n)
    kp, kx, kd = jax.random.split(jax.random.PRNGKey(seed), 3)
    params = mesh_lib.init_mesh_params(kp, plan, with_sigma=False)
    x = (jax.random.normal(kx, (batch, n))
         + 1j * jax.random.normal(jax.random.fold_in(kx, 1),
                                  (batch, n))).astype(jnp.complex64)
    # the ideal-device baseline rides the same backend as the draws: with
    # backend="pallas" the whole sweep — baseline included — never touches
    # the pure-jnp reference path
    if backend == "pallas":
        y_ideal = jnp.abs(ops.mesh_apply(params, x, n=n, block_b=block_b))
    else:
        y_ideal = jnp.abs(mesh_lib.apply_mesh(plan, params, x))
    draws = sample_hardware_draws(kd, n_draws, base=base, spread=spread)

    def device_error(eps, perr, loss_db, noise_key):
        hw = hw_lib.HardwareModel(
            hybrid_imbalance=eps, hybrid_phase_err=perr,
            cell_loss_db=loss_db, phase_sigma=base.phase_sigma,
            detector_floor_dbm=base.detector_floor_dbm,
            detector_sigma=base.detector_sigma)
        if backend == "pallas":
            t_all = hw_lib.imperfect_cell_matrix(
                params["theta"], params["phi"], hw, noise_key)
            y = ops.mesh_apply_cells(t_all, x, plan=plan, block_b=block_b)
        else:
            # same imperfect_cell_matrix call and key consumption inside
            y = hw_lib.apply_mesh_hw(plan, params, x, hw, noise_key)
        mag = jnp.abs(y)
        # digital post-scaling (the paper's gamma, Fig. 11) recovers any
        # overall insertion loss; yield therefore measures the residual
        # *distortion* after the optimal scalar compensation
        gamma = (jnp.vdot(mag, y_ideal)
                 / jnp.maximum(jnp.vdot(mag, mag), 1e-12)).real
        return (jnp.linalg.norm(gamma * mag - y_ideal)
                / jnp.maximum(jnp.linalg.norm(y_ideal), 1e-12))

    errors = jax.vmap(device_error)(
        draws["hybrid_imbalance"], draws["hybrid_phase_err"],
        draws["cell_loss_db"], draws["noise_key"])
    in_spec = errors <= error_threshold
    return {
        "n": n, "n_draws": n_draws, "spread": spread,
        "error_threshold": error_threshold,
        "errors": errors,
        "yield": float(jnp.mean(in_spec.astype(jnp.float32))),
        "mean_error": float(jnp.mean(errors)),
        "worst_error": float(jnp.max(errors)),
    }


def table2_rows(n: int = 20) -> list[dict]:
    """Reproduce Table II (N=20): platform comparison."""
    p = RFNNPlatform()
    return [
        {"platform": "GPU (V100)", "length_cm": 30.0, "cell_len_lambda": None,
         "complexity": "O(N^2)", "fj_per_flop": 3.1e4, "cost": "medium",
         "delay": "us"},
        {"platform": "FPGA (Arria 10)", "length_cm": 24.0,
         "cell_len_lambda": None, "complexity": "O(N^2)",
         "fj_per_flop": 6.2e4, "cost": "medium", "delay": "us"},
        {"platform": "ONN", "length_cm": 0.76, "cell_len_lambda": 64,
         "complexity": "O(N)", "fj_per_flop": 0.25, "cost": "high",
         "delay": "ps"},
        {"platform": "RFNN (this work)", "length_cm": rfnn_length_cm(n, p),
         "cell_len_lambda": 1, "complexity": "O(N)",
         "fj_per_flop": rfnn_energy_per_flop_fj(n, p), "cost": "low",
         "delay": "ns"},
    ]
