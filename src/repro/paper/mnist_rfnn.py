"""The 4-layer handwriting-recognition RFNN (paper Sec. IV-B, Figs. 14-16).

    784 -> 8        digital, leaky-ReLU
    8x8 analog mesh (28 unit cells, Table-I discrete phases, hardware
                     model from the measured prototype), activation = abs
                     (magnitude detection), no bias
    8 -> 10         digital, softmax

Trained with minibatch SGD (batch 10, lr 0.005) exactly as the paper; the
mesh phases train through the straight-through estimator over the Table-I
codebook (the deployed device then uses the projected discrete codes).
``analog=False`` swaps the mesh for an unconstrained 8x8 dense matrix — the
paper's "digital" baseline of Fig. 15.

Offline note: the real MNIST files are unavailable here, so the procedural
digits dataset stands in; the validation target is the analog-vs-digital
accuracy *gap* (paper: 93.1% vs 91.6% test; gap ~1.5 points).
"""

from __future__ import annotations

import dataclasses
import time
import warnings

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.analog_linear import AnalogSequence, AnalogUnitary
from repro.core.hardware import HardwareModel
from repro.paper.prototype import PROTOTYPE


@dataclasses.dataclass(frozen=True)
class MnistRFNN:
    analog: bool = True
    hardware: HardwareModel | None = None   # None -> noiseless mesh sim
    quantize: str | None = "table1"
    d_hidden: int = 8
    n_classes: int = 10
    #: depth of the analog section.  1 (the default) is the paper's Fig. 14
    #: network — a single 8x8 mesh between the digital layers.  >1 stacks
    #: full analog linear layers (V-mesh -> D -> U-mesh -> |detect|) into
    #: the Sec.-V multi-layer microwave ANN; with ``backend="pallas"`` the
    #: whole stack runs as one fused network megakernel per direction.
    analog_depth: int = 1
    #: "pallas" runs the analog section (fwd + bwd) through the fused
    #: kernels, with or without the hardware-imperfection model: non-ideal
    #: cell coefficients ride in the same VMEM-resident sweep, so the
    #: paper's hardware-in-the-loop training (and its DSPSA refinement
    #: bursts) is a kernel workload end-to-end.
    backend: str = "reference"

    def __post_init__(self):
        if self.analog_depth > 1:
            mesh = AnalogSequence(n=self.d_hidden, depth=self.analog_depth,
                                  quantize=self.quantize,
                                  hardware=self.hardware, output="abs",
                                  backend=self.backend)
        else:
            mesh = AnalogUnitary(n=self.d_hidden, quantize=self.quantize,
                                 hardware=self.hardware, output="abs",
                                 backend=self.backend)
        object.__setattr__(self, "mesh", mesh)

    def init(self, key):
        k1, k2, k3 = jax.random.split(key, 3)
        params = {
            "w1": jax.random.normal(k1, (784, self.d_hidden)) * 0.05,
            "b1": jnp.zeros((self.d_hidden,)),
            "w3": jax.random.normal(k3, (self.d_hidden, self.n_classes)) * 0.3,
            "b3": jnp.zeros((self.n_classes,)),
        }
        if self.analog:
            params["mesh"] = self.mesh.init(k2)
        elif self.analog_depth > 1:
            # digital stack mirroring the Sec.-V multi-layer analog section:
            # L free d x d matrices with |.| detection between them — the
            # source network of the digital->analog transfer (Fig. 11)
            params["w2"] = jax.random.normal(
                k2, (self.analog_depth, self.d_hidden, self.d_hidden)) * 0.3
        else:
            params["w2"] = jax.random.normal(k2, (self.d_hidden,
                                                  self.d_hidden)) * 0.3
        return params

    def apply(self, params, x, key=None):
        h1 = jax.nn.leaky_relu(x @ params["w1"] + params["b1"], 0.01)
        if self.analog:
            h2 = self.mesh.apply(params["mesh"], h1, key=key)  # abs detect
        elif params["w2"].ndim == 3:
            h2 = h1
            for l in range(params["w2"].shape[0]):
                h2 = jnp.abs(h2 @ params["w2"][l])  # per-layer |.| detect
        else:
            h2 = jnp.abs(h1 @ params["w2"])  # same activation, free matrix
        return h2 @ params["w3"] + params["b3"]  # logits (softmax in loss)

    def loss(self, params, x, y, key=None):
        logits = self.apply(params, x, key)
        logp = jax.nn.log_softmax(logits)
        nll = -jnp.take_along_axis(logp, y[:, None], axis=1).mean()
        acc = jnp.mean(jnp.argmax(logits, -1) == y)
        return nll, acc


def train_mnist(x_tr, y_tr, x_te, y_te, *, analog=True, hardware=PROTOTYPE,
                quantize="table1", epochs=100, batch=10, lr=0.005, seed=0,
                log_every=20, noisy_train=False, schedule="algorithm1",
                backend="reference", analog_depth=1):
    """Paper hyperparameters: minibatch 10, lr 0.005, 100 epochs, shuffled.

    schedule:
      'ste'        — straight-through quantized phases from the start;
      'algorithm1' — the paper's two-stage physics-aware flow: train the
                     mesh phases continuously against the hardware model
                     (the device-aware SGD phase), then program the nearest
                     Table-I codes onto the device and let the digital
                     layers adapt to the deployed discrete mesh (the
                     "update physical parameters on the physical device"
                     loop of Fig. 11, with DSPSA refinement available via
                     repro.core.dspsa).

    ``analog_depth > 1`` stacks the analog section into the Sec.-V
    multi-layer network (see :class:`MnistRFNN`); the DSPSA device-code
    refinement of Algorithm I addresses the single-mesh phase codes, so
    deep stacks train with the straight-through schedule instead.
    """
    if analog and analog_depth > 1 and schedule == "algorithm1":
        warnings.warn(
            "analog_depth > 1 does not support schedule='algorithm1' (the "
            "DSPSA refinement addresses single-mesh phase codes); falling "
            "back to the straight-through schedule", stacklevel=2)
        schedule = "ste"
    if analog and quantize and schedule == "algorithm1":
        # stage 1: continuous phases, hardware-in-the-loop
        stage1 = train_mnist(x_tr, y_tr, x_te, y_te, analog=True,
                             hardware=hardware, quantize=None,
                             epochs=max(1, epochs * 2 // 3), batch=batch,
                             lr=lr, seed=seed, log_every=log_every,
                             noisy_train=noisy_train, schedule="ste",
                             backend=backend)
        # stage 2: freeze mesh at nearest discrete codes; digital adapts,
        # alternating with DSPSA bursts on the device codes (Algorithm I:
        # "DSPSA -> dV; SGD optimizer -> dW" within each minibatch loop).
        model = MnistRFNN(analog=True, hardware=hardware, quantize=quantize,
                          backend=backend)
        params = dict(stage1["params"])
        stage2_epochs = max(1, epochs // 3)
        rounds = 3
        res = None
        hist = list(stage1["history"])
        for r in range(rounds):
            res = _train_loop(model, params, x_tr, y_tr, x_te, y_te,
                              epochs=max(1, stage2_epochs // rounds),
                              batch=batch, lr=lr, seed=seed + 1 + r,
                              log_every=log_every, noisy_train=noisy_train,
                              freeze=("mesh",))
            params = res["params"]
            hist += res["history"]
            if r < rounds - 1:
                params = _dspsa_refine(model, params, x_tr, y_tr,
                                       steps=25, seed=seed + 100 + r)
        res["params"] = params
        res["history"] = hist
        res["train_acc"] = float(_eval(model, params, x_tr, y_tr))
        res["test_acc"] = float(_eval(model, params, x_te, y_te))
        return res

    model = MnistRFNN(analog=analog, hardware=hardware if analog else None,
                      quantize=quantize, backend=backend,
                      analog_depth=analog_depth)
    params = model.init(jax.random.PRNGKey(seed))
    return _train_loop(model, params, x_tr, y_tr, x_te, y_te, epochs=epochs,
                       batch=batch, lr=lr, seed=seed, log_every=log_every,
                       noisy_train=noisy_train)


def _train_loop(model, params, x_tr, y_tr, x_te, y_te, *, epochs, batch, lr,
                seed, log_every, noisy_train, freeze=()):
    from repro.train.step import make_sgd_step

    def loss_fn(p, xi, yi, ki):
        return model.loss(p, xi, yi, ki if noisy_train else None)

    sgd_step = make_sgd_step(loss_fn, lr=lr, freeze=freeze)

    @jax.jit
    def epoch_fn(params, xb, yb, key):
        """One epoch: scan over pre-shuffled minibatches."""
        def step(p, inp):
            xi, yi, ki = inp
            return sgd_step(p, xi, yi, ki)
        n_batches = xb.shape[0]
        keys = jax.random.split(key, n_batches)
        params, (ls, accs) = jax.lax.scan(step, params, (xb, yb, keys))
        return params, ls.mean(), accs.mean()

    @jax.jit
    def eval_fn(params, x, y):
        return model.loss(params, x, y)[1]

    n = len(x_tr)
    n_batches = n // batch
    rng = np.random.default_rng(seed)
    history = []
    for ep in range(epochs):
        perm = rng.permutation(n)[: n_batches * batch]
        xb = jnp.asarray(x_tr[perm].reshape(n_batches, batch, -1))
        yb = jnp.asarray(y_tr[perm].reshape(n_batches, batch))
        params, l, a = epoch_fn(params, xb, yb, jax.random.PRNGKey(ep))
        if (ep + 1) % log_every == 0 or ep == 0:
            history.append({"epoch": ep + 1, "loss": float(l),
                            "train_acc": float(a)})
    train_acc = float(eval_fn(params, jnp.asarray(x_tr), jnp.asarray(y_tr)))
    test_acc = float(eval_fn(params, jnp.asarray(x_te), jnp.asarray(y_te)))
    return {"model": model, "params": params, "train_acc": train_acc,
            "test_acc": test_acc, "history": history}


def _eval(model, params, x, y):
    return jax.jit(lambda p: model.loss(p, jnp.asarray(x),
                                        jnp.asarray(y))[1])(params)


def _dspsa_refine(model, params, x, y, *, steps=25, seed=0, sample=512):
    """DSPSA on the 56 device phase codes (theta, phi of the 28 cells).

    Each loss evaluation is one 'hardware measurement pass' over a fixed
    calibration minibatch — the two-measurement form of Algorithm I.
    """
    from repro.core import dspsa as dspsa_lib
    from repro.core import quantize as q_lib

    cb = q_lib.table_i_codebook()
    rng = np.random.default_rng(seed)
    idx = rng.permutation(len(x))[:sample]
    xs, ys = jnp.asarray(x[idx]), jnp.asarray(y[idx])

    mesh0 = params["mesh"]
    codes0 = {"theta": q_lib.nearest_code(mesh0["theta"], cb),
              "phi": q_lib.nearest_code(mesh0["phi"], cb)}

    @jax.jit
    def loss_of(codes):
        mesh = dict(mesh0)
        mesh["theta"] = q_lib.codes_to_phase(codes["theta"], cb)
        mesh["phi"] = q_lib.codes_to_phase(codes["phi"], cb)
        p = dict(params)
        p["mesh"] = mesh
        return model.loss(p, xs, ys)[0]

    best, _hist = dspsa_lib.minimize(
        jax.random.PRNGKey(seed), codes0, loss_of,
        dspsa_lib.DSPSAConfig(a=0.8, n_states=6), steps=steps)
    mesh = dict(mesh0)
    mesh["theta"] = q_lib.codes_to_phase(best["theta"], cb)
    mesh["phi"] = q_lib.codes_to_phase(best["phi"], cb)
    out = dict(params)
    out["mesh"] = mesh
    return out


def digital_to_analog_transfer(
        x_tr, y_tr, x_te, y_te, *, depth=4, epochs=40, batch=10, lr=0.02,
        seed=0, hardware=PROTOTYPE,
        settings=("float", "table1", "uniform6", "hardware",
                  "hardware+calibrated"),
        program_method="reck", program_steps=1500, calibrate_steps=200,
        calibrate_lr=0.02, block_b=None):
    """The paper's Fig. 11/14 digital->analog transfer, end to end.

    Trains the digital source network (784 -> 8 digital front-end, then a
    ``depth``-layer stack of free 8x8 matrices with |.| detection between
    layers — the multi-layer microwave ANN's digital twin), compiles every
    8x8 weight matrix onto the mesh processor through the analog program
    compiler (:mod:`repro.compile`), and reports the digital->analog test
    accuracy drop per deployment ``setting``.

    Settings are ``+``-joined tokens: a codebook name (``table1`` /
    ``uniform<bits>``) turns on the quantize pass (STE masters),
    ``hardware`` binds the imperfection model (with frozen phase-noise
    draws), ``calibrated`` runs the hardware-in-the-loop residual fit;
    ``float`` is the ideal continuous-phase deployment.  Every compiled
    program serves through the network megakernel
    (``ops.rfnn_network``) — there is no reference fallback anywhere in
    the analog path.
    """
    from repro import compile as compile_mod

    digital = train_mnist(x_tr, y_tr, x_te, y_te, analog=False,
                          epochs=epochs, batch=batch, lr=lr, seed=seed,
                          quantize=None, schedule="ste",
                          analog_depth=depth)
    params = digital["params"]
    w2 = params["w2"]
    mats = ([np.asarray(w2[l]).T for l in range(depth)] if w2.ndim == 3
            else [np.asarray(w2).T])
    base = compile_mod.program(compile_mod.synthesize(mats),
                               method=program_method, steps=program_steps,
                               seed=seed)
    key = jax.random.PRNGKey(seed + 7)

    def compile_setting(setting):
        prog = base
        toks = setting.split("+")
        for t in toks:
            if t not in ("float", "hardware", "calibrated"):
                prog = compile_mod.quantize(prog, t, mode="ste")
        hw = hardware if "hardware" in toks else None
        if "calibrated" in toks:
            prog = compile_mod.calibrate(prog, hw, key=key,
                                         steps=calibrate_steps,
                                         lr=calibrate_lr)
        elif hw is not None:
            # bind the device (and its frozen noise draw) without trimming
            prog = compile_mod.calibrate(prog, hw, key=key, steps=0)
        return prog, compile_mod.lower(prog, block_b=block_b)

    w1, b1 = params["w1"], params["b1"]
    w3, b3 = params["w3"], params["b3"]

    def eval_acc(compiled, x, y):
        h1 = jax.nn.leaky_relu(jnp.asarray(x) @ w1 + b1, 0.01)
        h2 = compiled.apply(h1)   # fused megakernel: the whole analog stack
        logits = h2 @ w3 + b3
        return float(jnp.mean(jnp.argmax(logits, -1) == jnp.asarray(y)))

    results = {"digital_test_acc": digital["test_acc"], "depth": depth,
               "params": params, "program": base, "settings": {},
               "compiled": {}}
    for setting in settings:
        prog, compiled = compile_setting(setting)
        acc = eval_acc(compiled, x_te, y_te)
        results["settings"][setting] = {
            "test_acc": acc,
            "acc_drop": digital["test_acc"] - acc,
            "synthesis_error": compile_mod.program_error(prog),
        }
        results["compiled"][setting] = compiled
    return results


def confusion_matrix(model, params, x, y, n_classes=10):
    logits = model.apply(params, jnp.asarray(x))
    pred = np.asarray(jnp.argmax(logits, -1))
    cm = np.zeros((n_classes, n_classes), np.int64)
    for t, p in zip(np.asarray(y), pred):
        cm[t, p] += 1
    return cm
