"""The measured prototype emulation (paper Sec. III).

The unit cell is emulated with the hardware model calibrated to the
prototype's reported behaviour: Fig. 6 shows measured peak |S| a bit over a
dB below the ideal 1/sqrt(2) (-3 dB) "due to the loss and phase deviation
coming from the imperfect circuit fabrication".  We use ~1 dB in-circuit
insertion loss per cell, 5% hybrid imbalance and ~2 deg phase error, which
lands the simulated peak |S21| within the measured band.
"""

from __future__ import annotations

import numpy as np

from repro.core.hardware import HardwareModel

#: hardware model calibrated to the measured prototype
PROTOTYPE = HardwareModel(
    hybrid_imbalance=0.05,
    hybrid_phase_err=np.deg2rad(2.0),
    cell_loss_db=1.0,
    phase_sigma=np.deg2rad(1.5),
    detector_floor_dbm=-60.0,
    detector_sigma=0.01,
)

#: ideal-physics model (theory curves)
IDEAL_CELL = HardwareModel(
    hybrid_imbalance=0.0, hybrid_phase_err=0.0, cell_loss_db=0.0,
    phase_sigma=0.0, detector_floor_dbm=-300.0, detector_sigma=0.0)
