"""The 2x2 RFNN binary classifier (paper Sec. IV-A, Figs. 7-12).

Forward path (Eqs. 19-21):
    [z1, z2]^T = t(theta, phi) [x1, x2]^T      (the device)
    z_out = w1 |z1| + w2 |z2| + b              (post-processing)
    y_hat = sigmoid(z_out)

The device phases are the 36 discrete Table-I states; digital parameters
(w1, w2, b) train with SGD and the device biasing codes with either
exhaustive 6-state search over theta (what the trained network in Fig. 9/10
effectively selects) or DSPSA (Algorithm I).
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import dspsa as dspsa_lib
from repro.core.cell import TABLE_I_PHASES_RAD
from repro.core.hardware import HardwareModel, detect_magnitude, imperfect_cell_matrix
from repro.data.toys import GAMMA
from repro.kernels import ops as kernel_ops
from repro.paper.prototype import PROTOTYPE


@dataclasses.dataclass
class RFNN2x2:
    """The device + post-processing pipeline of Fig. 11."""

    hardware: HardwareModel = PROTOTYPE
    gamma: float = GAMMA
    #: "pallas" evaluates the cell as a 2-channel mesh via the fused kernel,
    #: for *any* hardware model: the generalized kernel carries the lossy,
    #: imbalanced cell coefficients directly, and phase-shifter noise plus
    #: the detector chain are sampled identically on both paths (same key
    #: consumption), so backends agree draw-for-draw.
    backend: str = "reference"

    def device_output(self, theta_code, phi_code, x, key=None):
        """Measured |V| at (P2, P3) for inputs x [N, 2] (volts, unscaled)."""
        theta = jnp.take(jnp.asarray(TABLE_I_PHASES_RAD, jnp.float32),
                         theta_code)
        phi = jnp.take(jnp.asarray(TABLE_I_PHASES_RAD, jnp.float32), phi_code)
        # feed V1+ = x[:,1] (y-axis), V4+ = x[:,0] (x-axis) per Fig. 9 axes
        vin = jnp.stack([x[:, 1], x[:, 0]], axis=-1).astype(jnp.complex64)
        vin = vin * self.gamma
        kdet = key if key is None else jax.random.fold_in(key, 1)
        if self.backend == "pallas":
            # sample phase noise on the scalar codes first (the exact key
            # consumption of imperfect_cell_matrix on the reference path),
            # then hand the noisy phases to the kernel's hardware packing
            if key is not None and self.hardware.phase_sigma > 0:
                k1, k2 = jax.random.split(key)
                theta = theta + self.hardware.phase_sigma * \
                    jax.random.normal(k1, jnp.shape(theta))
                phi = phi + self.hardware.phase_sigma * \
                    jax.random.normal(k2, jnp.shape(phi))
            # the single cell as a 2-channel mesh: column 0 holds the cell,
            # column 1 is the (inactive) odd column of the Clements rectangle
            params = {
                "theta": jnp.stack([jnp.reshape(theta, (1,)), jnp.zeros((1,))]),
                "phi": jnp.stack([jnp.reshape(phi, (1,)), jnp.zeros((1,))]),
            }
            vout = kernel_ops.mesh_apply(params, vin, n=2, block_b=8,
                                         hardware=self.hardware, key=None)
            mag = detect_magnitude(vout, self.hardware, kdet)
            return mag / self.gamma
        t = imperfect_cell_matrix(theta, phi, self.hardware, key)
        vout = vin @ t.T
        mag = detect_magnitude(vout, self.hardware, kdet)
        return mag / self.gamma  # post scaling back (Fig. 11)

    def predict(self, params, theta_code, phi_code, x, key=None):
        mag = self.device_output(theta_code, phi_code, x, key)
        z = mag @ params["w"] + params["b"]
        return jax.nn.sigmoid(z)


def _train_post(net, theta_code, phi_code, x, y, *, steps=500, lr=0.1,
                batch=32, seed=0):
    """Adaptive-gradient SGD on the digital post-processing (w1, w2, b) —
    the paper's stochastic optimization with dynamic learning-rate bound
    (refs [40][41])."""
    key = jax.random.PRNGKey(seed)
    params = {"w": 0.1 * jax.random.normal(key, (2,)), "b": jnp.zeros(())}
    mag = net.device_output(theta_code, phi_code, jnp.asarray(x))  # fixed dev

    def loss_fn(p, m, yy):
        z = m @ p["w"] + p["b"]
        yhat = jax.nn.sigmoid(z)
        eps = 1e-7
        return -jnp.mean(yy * jnp.log(yhat + eps)
                         + (1 - yy) * jnp.log(1 - yhat + eps))

    grad = jax.jit(jax.value_and_grad(loss_fn))
    m_t = jax.tree.map(jnp.zeros_like, params)
    v_t = jax.tree.map(jnp.zeros_like, params)
    b1, b2, eps = 0.9, 0.999, 1e-8
    n = len(x)
    rng = np.random.default_rng(seed)
    yj = jnp.asarray(y, jnp.float32)
    for s in range(steps):
        idx = rng.integers(0, n, size=batch)
        _, g = grad(params, mag[idx], yj[idx])
        m_t = jax.tree.map(lambda a, b: b1 * a + (1 - b1) * b, m_t, g)
        v_t = jax.tree.map(lambda a, b: b2 * a + (1 - b2) * b * b, v_t, g)
        t = s + 1.0
        params = jax.tree.map(
            lambda p, mm, vv: p - lr * (mm / (1 - b1**t))
            / (jnp.sqrt(vv / (1 - b2**t)) + eps), params, m_t, v_t)
    final_loss = float(loss_fn(params, mag, yj))
    return params, final_loss


def accuracy(net, params, theta_code, phi_code, x, y):
    yhat = net.predict(params, theta_code, phi_code, jnp.asarray(x))
    return float(jnp.mean((yhat >= 0.5) == jnp.asarray(y, bool)))


def train_rfnn2x2(x, y, *, method: str = "search", hardware=PROTOTYPE,
                  steps=300, seed=0, backend: str = "reference"):
    """Full Algorithm-I style training.  Returns (net, params, codes, info).

    method 'search': exhaustive over the 6 theta states (phi fixed at L6 as
    in Fig. 9); 'dspsa': discrete optimization over (theta, phi) codes with
    SGD-trained post-processing per evaluation (two-measurement DSPSA).
    With ``backend="pallas"`` every device measurement pass — including
    both loss evaluations of each DSPSA step — runs through the fused
    kernel, the in-situ-training workload of the paper's Algorithm I.
    """
    net = RFNN2x2(hardware=hardware, backend=backend)
    if method == "search":
        best = None
        for tc in range(6):
            params, loss = _train_post(net, tc, 5, x, y, steps=steps,
                                       seed=seed)
            acc = accuracy(net, params, tc, 5, x, y)
            if best is None or acc > best[0]:
                best = (acc, tc, params)
        acc, tc, params = best
        return net, params, {"theta": tc, "phi": 5}, {"train_acc": acc}

    # DSPSA over device codes; short SGD per loss evaluation
    def device_loss(codes):
        params, loss = _train_post(net, int(codes["theta"]), int(codes["phi"]),
                                   x, y, steps=80, seed=seed)
        return loss

    codes0 = {"theta": jnp.asarray(2, jnp.int32),
              "phi": jnp.asarray(2, jnp.int32)}
    best_codes, hist = dspsa_lib.minimize(
        jax.random.PRNGKey(seed), codes0, device_loss,
        dspsa_lib.DSPSAConfig(a=1.5, n_states=6), steps=12)
    tc, pc = int(best_codes["theta"]), int(best_codes["phi"])
    params, _ = _train_post(net, tc, pc, x, y, steps=steps, seed=seed)
    return net, params, {"theta": tc, "phi": pc}, {
        "train_acc": accuracy(net, params, tc, pc, x, y),
        "dspsa_history": hist}


def decision_map(net, params, theta_code, phi_code, lim=30.0, n=41):
    """y_hat over the input plane — the Fig. 9/10 maps."""
    g = np.linspace(0, lim, n)
    xx, yy = np.meshgrid(g, g)
    pts = np.stack([xx.reshape(-1), yy.reshape(-1)], axis=1).astype(np.float32)
    z = net.predict(params, theta_code, phi_code, jnp.asarray(pts))
    return g, np.asarray(z).reshape(n, n)
