"""Paper reproduction applications (Secs. III-V)."""

from repro.paper.rfnn2x2 import RFNN2x2, train_rfnn2x2
from repro.paper.mnist_rfnn import MnistRFNN, train_mnist
from repro.paper.efficiency import table2_rows

__all__ = ["RFNN2x2", "train_rfnn2x2", "MnistRFNN", "train_mnist",
           "table2_rows"]
