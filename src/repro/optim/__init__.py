"""Optimizers: AdamW (sharded states), SGD, schedules, DSPSA bridge."""

from repro.optim.adamw import AdamW, OptState
from repro.optim.schedules import cosine_schedule, linear_warmup

__all__ = ["AdamW", "OptState", "cosine_schedule", "linear_warmup"]
