"""AdamW with sharded optimizer state and optional gradient compression.

Distributed-optimization features:
  * moments inherit the parameter sharding; the ``moment_dtype`` knob
    (bf16 for the 400B config) halves optimizer memory;
  * optional bf16 gradient compression before the DP all-reduce (grads are
    cast before the psum GSPMD inserts, halving gradient collective bytes),
    accumulated back into f32 for the update;
  * global-norm clipping computed in f32.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

Array = jax.Array


@dataclasses.dataclass
class OptState:
    step: Array
    m: Any
    v: Any


jax.tree_util.register_pytree_node(
    OptState,
    lambda s: ((s.step, s.m, s.v), None),
    lambda aux, children: OptState(*children))


@dataclasses.dataclass(frozen=True)
class AdamW:
    lr: float | Callable[[Array], Array] = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.0
    clip_norm: float = 1.0
    moment_dtype: Any = jnp.float32
    grad_compression: bool = False  # bf16 grads across the DP all-reduce

    def init(self, params) -> OptState:
        zeros = lambda p: jnp.zeros(p.shape, self.moment_dtype)
        return OptState(step=jnp.zeros((), jnp.int32),
                        m=jax.tree.map(zeros, params),
                        v=jax.tree.map(zeros, params))

    def state_specs(self, param_specs):
        """Moment sharding = param sharding (ZeRO-style inherited specs)."""
        return OptState(step=(), m=param_specs, v=param_specs)

    def compress_grads(self, grads):
        if not self.grad_compression:
            return grads
        return jax.tree.map(lambda g: g.astype(jnp.bfloat16), grads)

    def update(self, params, grads, state: OptState):
        grads = jax.tree.map(lambda g: g.astype(jnp.float32), grads)
        if self.clip_norm > 0:
            gsq = jax.tree.reduce(
                lambda a, g: a + jnp.sum(g * g), grads, jnp.zeros((), jnp.float32))
            gnorm = jnp.sqrt(gsq)
            scale = jnp.minimum(1.0, self.clip_norm / jnp.maximum(gnorm, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
        else:
            gnorm = jnp.zeros((), jnp.float32)

        step = state.step + 1
        lr = self.lr(step) if callable(self.lr) else self.lr
        b1, b2 = self.b1, self.b2
        bc1 = 1.0 - b1 ** step.astype(jnp.float32)
        bc2 = 1.0 - b2 ** step.astype(jnp.float32)

        def upd(p, g, m, v):
            mf = m.astype(jnp.float32) * b1 + (1 - b1) * g
            vf = v.astype(jnp.float32) * b2 + (1 - b2) * g * g
            mh = mf / bc1
            vh = vf / bc2
            delta = mh / (jnp.sqrt(vh) + self.eps)
            if self.weight_decay:
                delta = delta + self.weight_decay * p.astype(jnp.float32)
            new_p = p.astype(jnp.float32) - lr * delta
            return (new_p.astype(p.dtype), mf.astype(self.moment_dtype),
                    vf.astype(self.moment_dtype))

        out = jax.tree.map(upd, params, grads, state.m, state.v)
        leaves, treedef = jax.tree.flatten(out, is_leaf=lambda x: isinstance(x, tuple))
        new_params = treedef.unflatten([l[0] for l in leaves])
        new_m = treedef.unflatten([l[1] for l in leaves])
        new_v = treedef.unflatten([l[2] for l in leaves])
        return new_params, OptState(step=step, m=new_m, v=new_v), gnorm
