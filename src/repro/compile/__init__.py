"""Analog program compiler: digital weights -> servable mesh programs.

The paper's digital->analog transfer (Sec. IV-B, Fig. 11) as a pass
pipeline over a small IR:

    prog = synthesize([w1, w2, ...])       # SVD factorization (Eq. 31)
    prog = program(prog, method="reck")    # or the kernel-backed "fit"
    prog = quantize(prog, "table1")        # Table-I phase snapping
    prog = calibrate(prog, PROTOTYPE, key=k)   # hardware-in-the-loop trim
    compiled = lower(prog)                 # megakernel tensors, pre-packed
    y = compiled.apply(x)                  # one fused pallas_call

Matrices larger than one mesh take the tiled pipeline (Sec. V scale-up):
a (To x Ti) grid of tile-sized processors, every pass running per tile,
lowered onto ONE tile-grid megakernel call:

    tp = synthesize_tiled(w64, tile=16)    # 64x64 -> 4x4 grid of 16x16
    tp = program_tiled(tp, method="reck")
    tp = quantize_tiled(tp, "table1")      # per-device codebook snap
    tp = calibrate_tiled(tp, PROTOTYPE, key=k)  # per-device hardware trim
    compiled = lower_tiled(tp)
    y = compiled.apply(x)                  # one fused pallas_call
"""

from repro.compile.passes import (
    calibrate,
    calibrate_tiled,
    lower,
    lower_tiled,
    program,
    program_tiled,
    quantize,
    quantize_tiled,
    resolve_codebook,
    synthesize,
    synthesize_tiled,
)
from repro.compile.program import (
    AnalogProgram,
    CompiledProgram,
    CompiledTiledProgram,
    ProgramLayer,
    TiledAnalogProgram,
    layer_matrix,
    program_error,
)

__all__ = [
    "AnalogProgram", "CompiledProgram", "CompiledTiledProgram",
    "ProgramLayer", "TiledAnalogProgram", "calibrate", "calibrate_tiled",
    "layer_matrix", "lower", "lower_tiled", "program", "program_tiled",
    "program_error", "quantize", "quantize_tiled", "resolve_codebook",
    "synthesize", "synthesize_tiled",
]
