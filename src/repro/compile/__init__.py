"""Analog program compiler: digital weights -> servable mesh programs.

The paper's digital->analog transfer (Sec. IV-B, Fig. 11) as a pass
pipeline over a small IR:

    prog = synthesize([w1, w2, ...])       # SVD factorization (Eq. 31)
    prog = program(prog, method="reck")    # or the kernel-backed "fit"
    prog = quantize(prog, "table1")        # Table-I phase snapping
    prog = calibrate(prog, PROTOTYPE, key=k)   # hardware-in-the-loop trim
    compiled = lower(prog)                 # megakernel tensors, pre-packed
    y = compiled.apply(x)                  # one fused pallas_call
"""

from repro.compile.passes import (
    calibrate,
    lower,
    program,
    quantize,
    resolve_codebook,
    synthesize,
)
from repro.compile.program import (
    AnalogProgram,
    CompiledProgram,
    ProgramLayer,
    layer_matrix,
    program_error,
)

__all__ = [
    "AnalogProgram", "CompiledProgram", "ProgramLayer", "calibrate",
    "layer_matrix", "lower", "program", "program_error", "quantize",
    "resolve_codebook", "synthesize",
]
