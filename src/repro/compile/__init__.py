"""Analog program compiler: digital weights -> servable mesh programs.

The paper's digital->analog transfer (Sec. IV-B, Fig. 11) as a pass
pipeline over a small IR:

    prog = synthesize([w1, w2, ...])       # SVD factorization (Eq. 31)
    prog = program(prog, method="reck")    # or the kernel-backed "fit"
    prog = quantize(prog, "table1")        # Table-I phase snapping
    prog = calibrate(prog, PROTOTYPE, key=k)   # hardware-in-the-loop trim
    compiled = lower(prog)                 # megakernel tensors, pre-packed
    y = compiled.apply(x)                  # one fused pallas_call

Matrices larger than one mesh take the tiled pipeline (Sec. V scale-up):
a (To x Ti) grid of tile-sized processors, every pass running per tile,
lowered onto ONE tile-grid megakernel call:

    tp = synthesize_tiled(w64, tile=16)    # 64x64 -> 4x4 grid of 16x16
    tp = program_tiled(tp, method="reck")
    tp = quantize_tiled(tp, "table1")      # per-device codebook snap
    tp = calibrate_tiled(tp, PROTOTYPE, key=k)  # per-device hardware trim
    compiled = lower_tiled(tp)
    y = compiled.apply(x)                  # one fused pallas_call

A multi-layer cascade of tile grids lowers onto ONE deep megakernel —
inter-layer detection happens in VMEM, no HBM round-trips between
layers (Sec. V depth scale-up):

    tps = [pipeline(w) for w in [w1, w2, w3, w4]]   # per-layer tiled passes
    compiled = lower_deep(tps)
    y = compiled.apply(x)                  # one pallas_call, L layers deep

Yield-aware fault tolerance (compile/placement.py + runtime/elastic.py):
place high-sensitivity tiles on high-yield physical positions before
calibration, and remap + re-trim the grid around dead tiles:

    scores = position_yield_scores(tp.to, tp.ti, PROTOTYPE, key=k, tile=16)
    tp = apply_placement(tp, plan_placement(tile_sensitivities(tp), scores))
    tp = calibrate_tiled(tp, PROTOTYPE, key=k)  # binds per-position draws
    compiled = lower_tiled(tp)                  # apply() undoes the perm
    # ... k tiles die in the field:
    plan = plan_tile_recovery(tile_sensitivities(tp), dead, ...)
    compiled = recover_tiled(tp, plan, PROTOTYPE, key=k)
"""

from repro.compile.placement import (
    TilePlacement,
    apply_placement,
    blank_tile,
    plan_placement,
    position_yield_scores,
    recover_tiled,
    tile_sensitivities,
    undo_placement,
)
from repro.compile.passes import (
    calibrate,
    calibrate_tiled,
    lower,
    lower_deep,
    lower_tiled,
    program,
    program_tiled,
    quantize,
    quantize_tiled,
    resolve_codebook,
    synthesize,
    synthesize_tiled,
)
from repro.compile.program import (
    AnalogProgram,
    CompiledDeepProgram,
    CompiledProgram,
    CompiledTiledProgram,
    ProgramLayer,
    TiledAnalogProgram,
    layer_matrix,
    program_error,
)

__all__ = [
    "AnalogProgram", "CompiledDeepProgram", "CompiledProgram",
    "CompiledTiledProgram",
    "ProgramLayer", "TiledAnalogProgram", "TilePlacement",
    "apply_placement", "blank_tile", "calibrate", "calibrate_tiled",
    "layer_matrix", "lower", "lower_deep", "lower_tiled", "plan_placement",
    "position_yield_scores", "program", "program_tiled", "program_error",
    "quantize", "quantize_tiled", "recover_tiled", "resolve_codebook",
    "synthesize", "synthesize_tiled", "tile_sensitivities",
    "undo_placement",
]
