"""The ``AnalogProgram`` IR: digital weights compiled onto the RF processor.

The paper's digital->analog transfer (Sec. IV-B, Fig. 11) is a compiler
pipeline: factor trained weight matrices (SVD, Eq. 31), program the two
unitary factors onto cell meshes, snap phases to the device codebook
(Table I), trim against the measured hardware, and hand the result to the
serving kernels.  This module holds the IR those passes transform:

* :class:`ProgramLayer` — one analog layer ``y = |gamma . U (D (V x))|``:
  the SVD targets, the diagonal attenuation + digital gamma, the mesh
  plans/params filled in by the ``program`` pass, the quantization state
  (codebook + integer device codes) and the hardware binding (model +
  frozen phase-noise draw keys) from ``calibrate``.
* :class:`AnalogProgram` — an L-layer stack of those (one entry for a
  single matrix).
* :class:`CompiledProgram` — the ``lower`` pass output: a static
  :class:`~repro.kernels.schedule.NetworkSchedule` plus the stacked
  ``[L, C, 8, P]`` megakernel coefficients, pre-emitted through the pack
  cache so ``apply`` is pure kernel execution with zero packing work.

The IR is deliberately host-side (frozen dataclasses, not pytrees): passes
return new programs, and only ``lower`` touches the device.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hardware as hw_lib
from repro.core import mesh as mesh_lib
from repro.core import quantize as q_lib
from repro.kernels import ops as kernel_ops
from repro.kernels.schedule import NetworkSchedule

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ProgramLayer:
    """One analog layer of the IR; passes fill in the optional fields."""

    n: int                      # padded square mesh size (even)
    out_dim: int
    in_dim: int
    target: np.ndarray          # [out_dim, in_dim] digital weight matrix
    target_u: np.ndarray        # [n, n] unitary (SVD left factor)
    target_vh: np.ndarray       # [n, n] unitary (SVD right factor, V^H)
    attenuation: Array          # [n] diagonal D / sigma_max, in [0, 1]
    scale: Array                # digital gamma (sigma_max), scalar f32
    # filled by the ``program`` pass
    v_plan: mesh_lib.MeshPlan | None = None
    v_params: dict | None = None
    u_plan: mesh_lib.MeshPlan | None = None
    u_params: dict | None = None
    # filled by the ``quantize`` pass
    codebook: Array | None = None
    quant_mode: str | None = None        # "nearest" | "ste"
    v_codes: dict | None = None          # integer device state codes
    u_codes: dict | None = None
    # filled by the ``calibrate`` pass
    hardware: hw_lib.HardwareModel | None = None
    key_v: Array | None = None           # frozen per-device noise draws
    key_u: Array | None = None

    @property
    def programmed(self) -> bool:
        return self.v_params is not None and self.u_params is not None

    def replace(self, **kw) -> "ProgramLayer":
        return dataclasses.replace(self, **kw)

    def device_params(self, which: str) -> dict:
        """The phases the device realizes: codebook-snapped when quantized.

        ``quant_mode="nearest"`` layers store snapped params already (the
        snap is then idempotent); ``"ste"`` layers keep continuous masters
        and snap here, at the device boundary.
        """
        params = self.v_params if which == "v" else self.u_params
        if params is None:
            raise ValueError(f"layer has no programmed {which!r} mesh — "
                             "run the `program` pass first")
        if self.codebook is None:
            return params
        return q_lib.quantize_mesh_params(params, self.codebook, ste=False)

    def padded_target(self) -> np.ndarray:
        """The [n, n] zero-padded complex target matrix."""
        t = np.zeros((self.n, self.n), np.complex128)
        t[: self.out_dim, : self.in_dim] = self.target
        return t


@dataclasses.dataclass(frozen=True)
class AnalogProgram:
    """An L-layer analog program (L == 1 for a single matrix)."""

    layers: tuple[ProgramLayer, ...]

    def __post_init__(self):
        if not self.layers:
            raise ValueError("an AnalogProgram needs at least one layer")
        n = self.layers[0].n
        if any(la.n != n for la in self.layers):
            raise ValueError(
                f"all layers must share the padded mesh size, got "
                f"{[la.n for la in self.layers]}")

    @property
    def n(self) -> int:
        return self.layers[0].n

    @property
    def depth(self) -> int:
        return len(self.layers)

    @property
    def in_dim(self) -> int:
        return self.layers[0].in_dim

    @property
    def out_dim(self) -> int:
        return self.layers[-1].out_dim

    @property
    def programmed(self) -> bool:
        return all(la.programmed for la in self.layers)

    def map_layers(self, fn) -> "AnalogProgram":
        return AnalogProgram(layers=tuple(fn(la) for la in self.layers))

    def n_cells(self) -> int:
        return sum(la.v_plan.n_cells + la.u_plan.n_cells
                   for la in self.layers if la.programmed)


def layer_matrix(layer: ProgramLayer, *, device: bool = True,
                 with_hardware: bool = True) -> np.ndarray:
    """The complex [out_dim, in_dim] matrix a programmed layer realizes.

    Runs the kernel path (two ``ops.mesh_apply`` probes over the identity
    batch).  ``device=True`` uses the codebook-snapped phases (what the
    hardware actually holds); ``with_hardware=True`` includes the layer's
    hardware binding and its frozen noise-draw keys, so the result is the
    as-fabricated matrix the ``calibrate`` pass fitted against.
    """
    if not layer.programmed:
        raise ValueError("layer is not programmed")
    vp = layer.device_params("v") if device else layer.v_params
    up = layer.device_params("u") if device else layer.u_params
    hw = layer.hardware if with_hardware else None
    kv = layer.key_v if with_hardware else None
    ku = layer.key_u if with_hardware else None
    probes = jnp.eye(layer.n, dtype=jnp.complex64)
    h = kernel_ops.mesh_apply(vp, probes, n=layer.n, plan=layer.v_plan,
                              hardware=hw, key=kv)
    h = h * layer.attenuation.astype(jnp.complex64)
    h = kernel_ops.mesh_apply(up, h, n=layer.n, plan=layer.u_plan,
                              hardware=hw, key=ku)
    rec = jnp.asarray(layer.scale, jnp.complex64) * h
    return np.asarray(rec).T[: layer.out_dim, : layer.in_dim]


def program_error(prog: AnalogProgram, *, device: bool = True,
                  with_hardware: bool = True) -> float:
    """Worst-case elementwise synthesis error across the program's layers."""
    return max(
        float(np.abs(layer_matrix(la, device=device,
                                  with_hardware=with_hardware)
                     - la.target).max())
        for la in prog.layers)


@dataclasses.dataclass(frozen=True)
class CompiledProgram:
    """The ``lower`` pass output: megakernel inputs, ready to serve.

    ``net``/``packed`` are the ``ops.pack_network`` result emitted at
    lower time; every ``apply`` hands them straight back to
    :func:`repro.kernels.ops.rfnn_network` (``packed=``), so serving does
    **zero** packing work — first tick included, and independent of the
    shared pack cache's eviction policy.  ``layer_args`` (with its stable
    parameter leaf identities, which also keep the cache entry exact) is
    retained as the program's kernel-level parameter view.
    """

    n: int
    in_dim: int
    out_dim: int
    depth: int
    plans: tuple
    layer_args: tuple
    hardware: hw_lib.HardwareModel | None
    net: NetworkSchedule
    packed: tuple                # (coef_v [L,C,8,P], coef_u, gains [L,12,P])
    block_b: int | None = None
    interpret: bool | None = None

    def apply(self, x: Array) -> Array:
        """``x[..., in_dim]`` -> detected magnitudes ``[..., out_dim]``.

        One fused network-megakernel ``pallas_call``: per layer
        ``|gamma_l . U_l (D_l (V_l .))|`` with the detected magnitude
        feeding the next layer, exactly the multi-layer microwave ANN.
        """
        if x.shape[-1] != self.in_dim:
            raise ValueError(
                f"expected trailing dim {self.in_dim}, got {x.shape}")
        if jnp.iscomplexobj(x):
            xc = x.astype(jnp.complex64)
        else:
            xc = jnp.asarray(x, jnp.float32).astype(jnp.complex64)
        pad = self.n - x.shape[-1]
        if pad:
            xc = jnp.concatenate(
                [xc, jnp.zeros(xc.shape[:-1] + (pad,), xc.dtype)], axis=-1)
        y = kernel_ops.rfnn_network(
            self.layer_args, xc, n=self.n, plans=self.plans,
            hardware=self.hardware, block_b=self.block_b,
            interpret=self.interpret, packed=(self.net, self.packed))
        return y[..., : self.out_dim]

    def n_cells(self) -> int:
        return sum(vp.n_cells + up.n_cells for vp, up in self.plans)
