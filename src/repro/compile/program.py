"""The ``AnalogProgram`` IR: digital weights compiled onto the RF processor.

The paper's digital->analog transfer (Sec. IV-B, Fig. 11) is a compiler
pipeline: factor trained weight matrices (SVD, Eq. 31), program the two
unitary factors onto cell meshes, snap phases to the device codebook
(Table I), trim against the measured hardware, and hand the result to the
serving kernels.  This module holds the IR those passes transform:

* :class:`ProgramLayer` — one analog layer ``y = |gamma . U (D (V x))|``:
  the SVD targets, the diagonal attenuation + digital gamma, the mesh
  plans/params filled in by the ``program`` pass, the quantization state
  (codebook + integer device codes) and the hardware binding (model +
  frozen phase-noise draw keys) from ``calibrate``.
* :class:`AnalogProgram` — an L-layer stack of those (one entry for a
  single matrix).
* :class:`CompiledProgram` — the ``lower`` pass output: a static
  L x 1 x 1 :class:`~repro.kernels.schedule.DeepGridSchedule` plus the
  stacked ``[L, 1, 1, C, 8, P]`` megakernel coefficients, pre-emitted
  through the pack cache so ``apply`` is pure kernel execution with zero
  packing work.
* :class:`TiledAnalogProgram` — a (To x Ti) grid of per-tile-SVD
  :class:`ProgramLayer`\\ s realizing one large matrix as block sums (the
  paper's Sec. V scale-up story); the per-tile passes
  (``program_tiled``/``quantize_tiled``/``calibrate_tiled``) map the
  single-layer pipeline over every tile independently.
* :class:`CompiledTiledProgram` — the ``lower_tiled`` output: a static
  1 x To x Ti :class:`~repro.kernels.schedule.DeepGridSchedule` plus the
  stacked ``[1, To, Ti, C, 8, P]`` tile-grid tensors; ``apply`` is one
  tile-grid megakernel call (all To*Ti meshes swept and row-combined in
  VMEM).
* :class:`CompiledDeepProgram` — the ``lower_deep`` output: an L-layer
  *cascade* of tile grids on one ``[L, To, Ti, C, 8, P]`` deep
  megakernel — ``apply`` is a single launch for the whole network,
  inter-layer detection in VMEM, placements folded into the launch.

The IR is deliberately host-side (frozen dataclasses, not pytrees): passes
return new programs, and only ``lower`` touches the device.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import hardware as hw_lib
from repro.core import mesh as mesh_lib
from repro.core import quantize as q_lib
from repro.kernels import ops as kernel_ops
from repro.kernels.schedule import DeepGridSchedule

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class ProgramLayer:
    """One analog layer of the IR; passes fill in the optional fields."""

    n: int                      # padded square mesh size (even)
    out_dim: int
    in_dim: int
    target: np.ndarray          # [out_dim, in_dim] digital weight matrix
    target_u: np.ndarray        # [n, n] unitary (SVD left factor)
    target_vh: np.ndarray       # [n, n] unitary (SVD right factor, V^H)
    attenuation: Array          # [n] diagonal D / sigma_max, in [0, 1]
    scale: Array                # digital gamma (sigma_max), scalar f32
    # filled by the ``program`` pass
    v_plan: mesh_lib.MeshPlan | None = None
    v_params: dict | None = None
    u_plan: mesh_lib.MeshPlan | None = None
    u_params: dict | None = None
    # filled by the ``quantize`` pass
    codebook: Array | None = None
    quant_mode: str | None = None        # "nearest" | "ste"
    v_codes: dict | None = None          # integer device state codes
    u_codes: dict | None = None
    # filled by the ``calibrate`` pass
    hardware: hw_lib.HardwareModel | None = None
    key_v: Array | None = None           # frozen per-device noise draws
    key_u: Array | None = None

    @property
    def programmed(self) -> bool:
        return self.v_params is not None and self.u_params is not None

    def replace(self, **kw) -> "ProgramLayer":
        return dataclasses.replace(self, **kw)

    def device_params(self, which: str) -> dict:
        """The phases the device realizes: codebook-snapped when quantized.

        ``quant_mode="nearest"`` layers store snapped params already (the
        snap is then idempotent); ``"ste"`` layers keep continuous masters
        and snap here, at the device boundary.
        """
        params = self.v_params if which == "v" else self.u_params
        if params is None:
            raise ValueError(f"layer has no programmed {which!r} mesh — "
                             "run the `program` pass first")
        if self.codebook is None:
            return params
        return q_lib.quantize_mesh_params(params, self.codebook, ste=False)

    def padded_target(self) -> np.ndarray:
        """The [n, n] zero-padded complex target matrix."""
        t = np.zeros((self.n, self.n), np.complex128)
        t[: self.out_dim, : self.in_dim] = self.target
        return t


@dataclasses.dataclass(frozen=True)
class AnalogProgram:
    """An L-layer analog program (L == 1 for a single matrix)."""

    layers: tuple[ProgramLayer, ...]

    def __post_init__(self):
        if not self.layers:
            raise ValueError("an AnalogProgram needs at least one layer")
        n = self.layers[0].n
        if any(la.n != n for la in self.layers):
            raise ValueError(
                f"all layers must share the padded mesh size, got "
                f"{[la.n for la in self.layers]}")

    @property
    def n(self) -> int:
        return self.layers[0].n

    @property
    def depth(self) -> int:
        return len(self.layers)

    @property
    def in_dim(self) -> int:
        return self.layers[0].in_dim

    @property
    def out_dim(self) -> int:
        return self.layers[-1].out_dim

    @property
    def programmed(self) -> bool:
        return all(la.programmed for la in self.layers)

    def map_layers(self, fn) -> "AnalogProgram":
        return AnalogProgram(layers=tuple(fn(la) for la in self.layers))

    def n_cells(self) -> int:
        return sum(la.v_plan.n_cells + la.u_plan.n_cells
                   for la in self.layers if la.programmed)


def layer_matrix(layer: ProgramLayer, *, device: bool = True,
                 with_hardware: bool = True) -> np.ndarray:
    """The complex [out_dim, in_dim] matrix a programmed layer realizes.

    Runs the kernel path (two ``ops.mesh_apply`` probes over the identity
    batch).  ``device=True`` uses the codebook-snapped phases (what the
    hardware actually holds); ``with_hardware=True`` includes the layer's
    hardware binding and its frozen noise-draw keys, so the result is the
    as-fabricated matrix the ``calibrate`` pass fitted against.
    """
    if not layer.programmed:
        raise ValueError("layer is not programmed")
    vp = layer.device_params("v") if device else layer.v_params
    up = layer.device_params("u") if device else layer.u_params
    hw = layer.hardware if with_hardware else None
    kv = layer.key_v if with_hardware else None
    ku = layer.key_u if with_hardware else None
    probes = jnp.eye(layer.n, dtype=jnp.complex64)
    h = kernel_ops.mesh_apply(vp, probes, n=layer.n, plan=layer.v_plan,
                              hardware=hw, key=kv)
    h = h * layer.attenuation.astype(jnp.complex64)
    h = kernel_ops.mesh_apply(up, h, n=layer.n, plan=layer.u_plan,
                              hardware=hw, key=ku)
    rec = jnp.asarray(layer.scale, jnp.complex64) * h
    return np.asarray(rec).T[: layer.out_dim, : layer.in_dim]


def program_error(prog: AnalogProgram, *, device: bool = True,
                  with_hardware: bool = True) -> float:
    """Worst-case elementwise synthesis error across the program's layers."""
    return max(
        float(np.abs(layer_matrix(la, device=device,
                                  with_hardware=with_hardware)
                     - la.target).max())
        for la in prog.layers)


@dataclasses.dataclass(frozen=True)
class TiledAnalogProgram:
    """A (To x Ti) grid of single-layer analog programs for one matrix.

    Each grid entry is a :class:`ProgramLayer` (n = tile, depth 1) whose
    target is the corresponding tile-sized block of the (zero-padded)
    ``[out_dim, in_dim]`` matrix; row sums of the realized tile matrices
    reconstruct the full matmul.  The tiled passes map the per-layer
    pipeline over the grid, so quantization and hardware calibration run
    per tile — exactly how a physical grid of 8x8 processors would be
    trimmed device by device.
    """

    out_dim: int
    in_dim: int
    tile: int
    grid: tuple[tuple[ProgramLayer, ...], ...]
    # logical -> physical grid permutation from the yield-aware placement
    # pass (compile/placement.py); None = grid is in logical order
    placement: "object | None" = None

    def __post_init__(self):
        if not self.grid or not self.grid[0]:
            raise ValueError("a TiledAnalogProgram needs at least one tile")
        ti = len(self.grid[0])
        if any(len(row) != ti for row in self.grid):
            raise ValueError("tile grid must be rectangular")
        if any(la.n != self.tile for row in self.grid for la in row):
            raise ValueError("every tile must have n == tile "
                             f"({self.tile}), got "
                             f"{sorted({la.n for row in self.grid for la in row})}")

    @property
    def to(self) -> int:
        return len(self.grid)

    @property
    def ti(self) -> int:
        return len(self.grid[0])

    @property
    def programmed(self) -> bool:
        return all(la.programmed for row in self.grid for la in row)

    def map_tiles(self, fn) -> "TiledAnalogProgram":
        """New program with ``fn(o, i, layer)`` applied to every tile."""
        return dataclasses.replace(self, grid=tuple(
            tuple(fn(o, i, la) for i, la in enumerate(row))
            for o, row in enumerate(self.grid)))

    def realized_matrix(self, *, device: bool = True,
                        with_hardware: bool = True) -> np.ndarray:
        """The full complex matrix the programmed grid realizes (block
        sums of :func:`layer_matrix` per tile), truncated to
        ``[out_dim, in_dim]``.  A placed grid reports the *logical*
        matrix: physical position ``(po, pi)`` holds logical block
        ``(row_perm[po], col_perm[pi])``."""
        t = self.tile
        m = np.zeros((self.to * t, self.ti * t), np.complex128)
        pl = self.placement
        for po, row in enumerate(self.grid):
            for pi, la in enumerate(row):
                o = pl.row_perm[po] if pl is not None else po
                i = pl.col_perm[pi] if pl is not None else pi
                m[o * t:(o + 1) * t, i * t:(i + 1) * t] = layer_matrix(
                    la, device=device, with_hardware=with_hardware)
        return m[: self.out_dim, : self.in_dim]

    def n_cells(self) -> int:
        return sum(la.v_plan.n_cells + la.u_plan.n_cells
                   for row in self.grid for la in row if la.programmed)


def _prep_input(x: Array, in_dim: int, padded_dim: int) -> Array:
    """Shared compiled-apply preamble: trailing-dim check, complex64 cast,
    zero-pad up to the mesh/grid width."""
    if x.shape[-1] != in_dim:
        raise ValueError(f"expected trailing dim {in_dim}, got {x.shape}")
    if jnp.iscomplexobj(x):
        xc = x.astype(jnp.complex64)
    else:
        xc = jnp.asarray(x, jnp.float32).astype(jnp.complex64)
    pad = padded_dim - in_dim
    if pad:
        xc = jnp.concatenate(
            [xc, jnp.zeros(xc.shape[:-1] + (pad,), xc.dtype)], axis=-1)
    return xc


@dataclasses.dataclass(frozen=True)
class CompiledProgram:
    """The ``lower`` pass output: megakernel inputs, ready to serve.

    ``net``/``packed`` are the ``ops.pack_network`` result emitted at
    lower time; every ``apply`` hands them straight back to
    :func:`repro.kernels.ops.rfnn_network` (``packed=``), so serving does
    **zero** packing work — first tick included, and independent of the
    shared pack cache's eviction policy.  ``layer_args`` (with its stable
    parameter leaf identities, which also keep the cache entry exact) is
    retained as the program's kernel-level parameter view.
    """

    n: int
    in_dim: int
    out_dim: int
    depth: int
    plans: tuple
    layer_args: tuple
    hardware: hw_lib.HardwareModel | None
    net: DeepGridSchedule        # L x 1 x 1 deep-grid schedule
    packed: tuple                # (coef_v [L,1,1,C,8,P], coef_u, gains)
    block_b: int | None = None
    interpret: bool | None = None
    # the AnalogProgram this was lowered from (recovery/introspection);
    # not part of the kernel contract
    source: "AnalogProgram | None" = dataclasses.field(
        default=None, repr=False, compare=False)

    # -- ServableProgram surface (repro.serving.servable) ---------------
    @property
    def n_in(self) -> int:
        return self.in_dim

    @property
    def n_out(self) -> int:
        return self.out_dim

    @property
    def placement(self):
        return None              # a single mesh has no tile placement

    def recover(self, dead_tiles, **kw) -> "CompiledProgram":
        raise ValueError(
            "CompiledProgram has no tile grid to remap around dead tiles; "
            "tile_down recovery needs a CompiledTiledProgram or "
            "CompiledDeepProgram")

    def apply(self, x: Array) -> Array:
        """``x[..., in_dim]`` -> detected magnitudes ``[..., out_dim]``.

        One fused network-megakernel ``pallas_call``: per layer
        ``|gamma_l . U_l (D_l (V_l .))|`` with the detected magnitude
        feeding the next layer, exactly the multi-layer microwave ANN.
        """
        xc = _prep_input(x, self.in_dim, self.n)
        y = kernel_ops.rfnn_network(
            self.layer_args, xc, n=self.n, plans=self.plans,
            hardware=self.hardware, block_b=self.block_b,
            interpret=self.interpret, packed=(self.net, self.packed))
        return y[..., : self.out_dim]

    def n_cells(self) -> int:
        return sum(vp.n_cells + up.n_cells for vp, up in self.plans)


@dataclasses.dataclass(frozen=True)
class CompiledTiledProgram:
    """The ``lower_tiled`` pass output: tile-grid kernel inputs, servable.

    ``grid``/``packed`` are the ``ops.pack_tile_grid`` result emitted at
    lower time — every ``apply`` hands them straight back to
    :func:`repro.kernels.ops.tiled_apply` (``packed=``), so serving does
    **zero** packing work, first tick included, independent of the shared
    pack cache's eviction policy.  ``tile_args`` (stable parameter leaf
    identities) is retained as the program's kernel-level parameter view.
    """

    out_dim: int
    in_dim: int
    tile: int
    to: int
    ti: int
    plans: tuple                 # [To][Ti] of (v_plan, u_plan)
    tile_args: tuple             # [To][Ti] of kernel argument dicts
    hardware: hw_lib.HardwareModel | None
    grid: "object"               # 1 x To x Ti DeepGridSchedule (static)
    packed: tuple                # (coef_v [To,Ti,8*,P], coef_u, gains)
    block_b: int | None = None
    interpret: bool | None = None
    # yield-aware placement (compile/placement.py): the kernel runs the
    # physically-permuted grid; apply() permutes the digital tile streams
    placement: "object | None" = None
    # optional (tile-row x batch) scale-out: with a 2-axis mesh every
    # apply shards through kernels/ops.tiled_apply's shard_map path
    mesh: "object | None" = None
    row_axis: str = "rows"
    data_axis: str = "data"
    # the TiledAnalogProgram this was lowered from — the recovery path
    # re-places/re-lowers it around dead tiles; not part of the kernel
    # contract
    source: "TiledAnalogProgram | None" = dataclasses.field(
        default=None, repr=False, compare=False)

    # -- ServableProgram surface (repro.serving.servable) ---------------
    @property
    def n_in(self) -> int:
        return self.in_dim

    @property
    def n_out(self) -> int:
        return self.out_dim

    def recover(self, dead_tiles, hardware: "hw_lib.HardwareModel | None"
                = None, *, key: Array | None = None, steps: int = 0,
                max_dropped_mass: float = 0.05,
                **calibrate_kw) -> "CompiledTiledProgram":
        """Recompile this program around dead physical tile positions.

        The full PR-6 recovery pipeline in one call: plan a remap that
        parks the least-sensitive logical tiles on the dead positions
        (:func:`repro.runtime.elastic.plan_tile_recovery`), re-place /
        blank / re-trim / re-lower (:func:`repro.compile.recover_tiled`),
        and carry this program's mesh scale-out settings onto the result.
        ``steps`` is the re-calibration budget for moved tiles (0 =
        re-bind draws only — the serving engine's mid-stream default;
        raise it for a full offline re-trim).  Raises when the remap
        would drop more than ``max_dropped_mass`` of the sensitivity
        mass, or when the program was built without its ``source``.
        """
        if self.source is None:
            raise ValueError(
                "this CompiledTiledProgram carries no source "
                "TiledAnalogProgram to re-place; re-lower it with "
                "repro.compile.lower_tiled or pass recovery= to the "
                "serving engine")
        from repro.compile import placement as place_lib
        from repro.runtime.elastic import plan_tile_recovery

        tp = self.source
        pl = tp.placement
        plan = plan_tile_recovery(
            place_lib.tile_sensitivities(place_lib.undo_placement(tp)),
            sorted({(int(o), int(i)) for o, i in dead_tiles}),
            row_perm=pl.row_perm if pl is not None else None,
            col_perm=pl.col_perm if pl is not None else None,
            max_dropped_mass=max_dropped_mass)
        if not plan.viable:
            raise ValueError(f"tile recovery is not viable: {plan.reason}")
        out = place_lib.recover_tiled(
            tp, plan, self.hardware if hardware is None else hardware,
            key=key, lower=True, block_b=self.block_b,
            interpret=self.interpret, steps=steps, **calibrate_kw)
        return dataclasses.replace(out, mesh=self.mesh,
                                   row_axis=self.row_axis,
                                   data_axis=self.data_axis)

    def apply(self, x: Array) -> Array:
        """``x[..., in_dim]`` -> detected magnitudes ``[..., out_dim]``.

        One fused tile-grid ``pallas_call``: every input tile sweeps
        through its row's meshes, rows combine coherently in VMEM, and
        the detector reads the combined magnitude — the paper's blocked
        scale-up of the 8x8 processor with zero per-tile launches.

        A placed program feeds physical column ``pi`` logical input tile
        ``col_perm[pi]`` and reads logical output row ``r`` from physical
        row ``inv_row_perm[r]`` — two index gathers on the digital tile
        streams, zero kernel changes.
        """
        xc = _prep_input(x, self.in_dim, self.ti * self.tile)
        pl = self.placement
        permuted = pl is not None and not pl.is_identity
        if permuted:
            xt = xc.reshape(xc.shape[:-1] + (self.ti, self.tile))
            xc = jnp.take(xt, jnp.asarray(pl.col_perm), axis=-2).reshape(
                xc.shape)
        y = kernel_ops.tiled_apply(
            self.tile_args, xc, n=self.tile, plans=self.plans,
            hardware=self.hardware, block_b=self.block_b,
            interpret=self.interpret, packed=(self.grid, self.packed),
            mesh=self.mesh, row_axis=self.row_axis,
            data_axis=self.data_axis)
        if permuted:
            yt = y.reshape(y.shape[:-1] + (self.to, self.tile))
            y = jnp.take(yt, jnp.asarray(pl.inv_row_perm),
                         axis=-2).reshape(y.shape)
        return jnp.abs(y)[..., : self.out_dim]

    def n_cells(self) -> int:
        return sum(vp.n_cells + up.n_cells
                   for row in self.plans for vp, up in row)


@dataclasses.dataclass(frozen=True)
class CompiledDeepProgram:
    """The ``lower_deep`` pass output: a whole multi-layer tiled network,
    one megakernel launch per direction.

    ``deep``/``packed`` are the ``ops.pack_deep_grid`` result emitted at
    lower time — every ``apply`` hands them straight back to
    :func:`repro.kernels.ops.deep_apply` (``packed=``), so serving does
    **zero** packing work, first tick included.  Inter-layer activations
    never leave VMEM: the kernel re-detects each layer's combined row
    magnitudes in place and feeds them to the next layer's tiles — the
    fully-analog cascade, with no digital stop between layers.

    Placements fold into the packed tensors: layer 0's column placement
    is undone by a digital input gather and the last layer's row
    placement by a digital output gather (exactly like
    :class:`CompiledTiledProgram`), while every *interior* boundary was
    resolved at pack time — each layer ``l >= 1`` packs its tile columns
    in the physical row order of layer ``l - 1``'s outputs, so the
    in-kernel handoff needs no permutation at all.  Per-tile calibration
    keys ride inside ``layer_args`` untouched.
    """

    out_dim: int
    in_dim: int
    tile: int
    depth: int
    to: int
    ti: int
    plans: tuple                 # [L][To][Ti] of (v_plan, u_plan)
    layer_args: tuple            # [L][To][Ti] of kernel argument dicts
    hardware: hw_lib.HardwareModel | None
    deep: "object"               # DeepGridSchedule (static)
    packed: tuple                # (coef_v [L,To,Ti,C,8,P], coef_u, gains)
    block_b: int | None = None
    interpret: bool | None = None
    # layer 0's placement (input gather) and the last layer's placement
    # (output gather); interior placements are already folded into packed
    in_placement: "object | None" = None
    out_placement: "object | None" = None
    # optional (tile-row x batch) scale-out through deep_apply's
    # shard_map path (depth runs as a chain of single-layer launches)
    mesh: "object | None" = None
    row_axis: str = "rows"
    data_axis: str = "data"
    # the per-layer TiledAnalogPrograms this was lowered from (logical
    # column order, placements still attached) — the recovery path
    # re-places one layer and re-lowers the cascade; not part of the
    # kernel contract
    sources: "tuple[TiledAnalogProgram, ...] | None" = dataclasses.field(
        default=None, repr=False, compare=False)

    # -- ServableProgram surface (repro.serving.servable) ---------------
    @property
    def n_in(self) -> int:
        return self.in_dim

    @property
    def n_out(self) -> int:
        return self.out_dim

    @property
    def placement(self):
        return self.out_placement

    def recover(self, dead_tiles, hardware: "hw_lib.HardwareModel | None"
                = None, *, layer: int = 0, key: Array | None = None,
                steps: int = 0, max_dropped_mass: float = 0.05,
                **calibrate_kw) -> "CompiledDeepProgram":
        """Recompile the cascade around dead tiles in one layer's grid.

        ``dead_tiles`` are physical ``(o, i)`` positions in layer
        ``layer``'s grid.  The damaged layer is re-placed/blanked/
        re-trimmed exactly like :meth:`CompiledTiledProgram.recover`
        (``lower=False``), then the whole cascade is re-lowered through
        ``lower_deep`` so the interior placement folding stays
        consistent.  Needs the program's ``sources``.
        """
        if self.sources is None:
            raise ValueError(
                "this CompiledDeepProgram carries no source layer programs "
                "to re-place; re-lower it with repro.compile.lower_deep or "
                "pass recovery= to the serving engine")
        if not 0 <= layer < len(self.sources):
            raise ValueError(f"layer {layer} outside depth "
                             f"{len(self.sources)} cascade")
        from repro.compile import passes as passes_lib
        from repro.compile import placement as place_lib
        from repro.runtime.elastic import plan_tile_recovery

        tp = self.sources[layer]
        pl = tp.placement
        plan = plan_tile_recovery(
            place_lib.tile_sensitivities(place_lib.undo_placement(tp)),
            sorted({(int(o), int(i)) for o, i in dead_tiles}),
            row_perm=pl.row_perm if pl is not None else None,
            col_perm=pl.col_perm if pl is not None else None,
            max_dropped_mass=max_dropped_mass)
        if not plan.viable:
            raise ValueError(f"tile recovery is not viable: {plan.reason}")
        recovered = place_lib.recover_tiled(
            tp, plan, self.hardware if hardware is None else hardware,
            key=key, lower=False, interpret=self.interpret, steps=steps,
            **calibrate_kw)
        srcs = (self.sources[:layer] + (recovered,)
                + self.sources[layer + 1:])
        return passes_lib.lower_deep(
            srcs, block_b=self.block_b, interpret=self.interpret,
            mesh=self.mesh, row_axis=self.row_axis,
            data_axis=self.data_axis)

    def apply(self, x: Array) -> Array:
        """``x[..., in_dim]`` -> detected magnitudes ``[..., out_dim]``.

        One fused deep-grid ``pallas_call``: every layer's tiles sweep,
        rows combine coherently, the detector reads each layer's rows in
        VMEM and feeds the next — the paper's multi-layer microwave ANN
        scale-up as a single forward (and a single backward) launch.
        """
        xc = _prep_input(x, self.in_dim, self.ti * self.tile)
        pin = self.in_placement
        if pin is not None and not pin.is_identity:
            xt = xc.reshape(xc.shape[:-1] + (self.ti, self.tile))
            xc = jnp.take(xt, jnp.asarray(pin.col_perm), axis=-2).reshape(
                xc.shape)
        y = kernel_ops.deep_apply(
            self.layer_args, xc, n=self.tile, plans=self.plans,
            hardware=self.hardware, block_b=self.block_b,
            interpret=self.interpret, packed=(self.deep, self.packed),
            readout="magnitude", mesh=self.mesh, row_axis=self.row_axis,
            data_axis=self.data_axis)
        pout = self.out_placement
        if pout is not None and not pout.is_identity:
            yt = y.reshape(y.shape[:-1] + (self.to, self.tile))
            y = jnp.take(yt, jnp.asarray(pout.inv_row_perm),
                         axis=-2).reshape(y.shape)
        return y[..., : self.out_dim]

    def n_cells(self) -> int:
        return sum(vp.n_cells + up.n_cells
                   for grid in self.plans for row in grid
                   for vp, up in row)
