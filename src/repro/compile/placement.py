"""Yield-aware tile placement: map logical tiles onto physical positions.

The paper's Sec. V scale-up composes one large matrix from a grid of
small physical processors; fabrication spread (Sec. III, the Monte-Carlo
yield sweep in ``paper/efficiency.monte_carlo_yield``) makes those
positions *unequal* — each physical position freezes its own phase-noise
draw at ``calibrate_tiled`` time.  This pass exploits the freedom the
block decomposition leaves open: any permutation of logical tile rows and
columns can be realized by permuting which physical position hosts which
logical block, then permuting the digital input/output tile streams to
match.  Placement therefore puts the *high-sensitivity* logical tiles
(largest singular-value mass — the blocks whose distortion moves the
realized matrix most) on the *high-yield* physical positions, and the
near-zero blocks on the lemons.

The permutation is pure digital bookkeeping:

* :func:`apply_placement` physically reorders the grid (so every physical
  position calibrates against its own draw, keys folded by *physical*
  position exactly as an unplaced grid would), and records the
  :class:`TilePlacement` on the program;
* :class:`~repro.compile.program.CompiledTiledProgram` undoes it in
  ``apply`` as index gathers on the input/output tile axes — the kernel
  itself is untouched (same megakernel, same schedule, zero new statics);
* :func:`recover_tiled` re-places a grid around dead positions from a
  :class:`~repro.runtime.elastic.TileRecoveryPlan`, blanks the dead
  positions (a passive grid's unpowered tile contributes nothing), and
  re-calibrates exactly the tiles whose physical position changed.

Placement is restricted to row x column permutations because the kernel's
in-VMEM row combine fixes which input tiles feed which output row — an
arbitrary tile-to-position bijection would need a different schedule,
i.e. kernel changes; row x column permutations compose with the existing
schedule for free.
"""

from __future__ import annotations

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np

from repro.compile.program import TiledAnalogProgram
from repro.core import hardware as hw_lib
from repro.core import mesh as mesh_lib
from repro.kernels import ops as kernel_ops

Array = jax.Array


@dataclasses.dataclass(frozen=True)
class TilePlacement:
    """A logical -> physical row x column permutation of the tile grid.

    Physical position ``(po, pi)`` hosts logical tile
    ``(row_perm[po], col_perm[pi])``.  Both perms are permutations of
    ``range(To)`` / ``range(Ti)``; the identity placement is a no-op
    everywhere (``apply`` skips the gathers entirely).
    """

    row_perm: tuple[int, ...]
    col_perm: tuple[int, ...]

    def __post_init__(self):
        for name, perm in (("row_perm", self.row_perm),
                           ("col_perm", self.col_perm)):
            if sorted(perm) != list(range(len(perm))):
                raise ValueError(f"{name} is not a permutation: {perm}")

    @classmethod
    def identity(cls, to: int, ti: int) -> "TilePlacement":
        return cls(tuple(range(to)), tuple(range(ti)))

    @property
    def is_identity(self) -> bool:
        return (self.row_perm == tuple(range(len(self.row_perm)))
                and self.col_perm == tuple(range(len(self.col_perm))))

    @property
    def inv_row_perm(self) -> tuple[int, ...]:
        """``inv[r]`` = the physical row hosting logical row ``r``."""
        inv = [0] * len(self.row_perm)
        for po, r in enumerate(self.row_perm):
            inv[r] = po
        return tuple(inv)

    @property
    def inv_col_perm(self) -> tuple[int, ...]:
        inv = [0] * len(self.col_perm)
        for pi, c in enumerate(self.col_perm):
            inv[c] = pi
        return tuple(inv)


def tile_sensitivities(tp: TiledAnalogProgram) -> np.ndarray:
    """``[To, Ti]`` singular-value mass per logical tile.

    ``scale * sum(attenuation)`` is the tile's total singular-value mass
    (sigma_max times the normalized diagonal) — the operator-norm budget
    the block contributes to the realized matrix.  Zero-padding blocks
    score 0 and gravitate to the worst (or dead) positions.
    """
    s = np.zeros((tp.to, tp.ti), np.float64)
    for o, row in enumerate(tp.grid):
        for i, la in enumerate(row):
            s[o, i] = float(np.asarray(la.scale)) * float(
                np.asarray(jnp.sum(la.attenuation)))
    return s


def position_yield_scores(to: int, ti: int,
                          hardware: hw_lib.HardwareModel, *,
                          key: Array, tile: int, seed: int = 0,
                          interpret: bool | None = None) -> np.ndarray:
    """``[To, Ti]`` yield score of every physical grid position.

    Probes each position with the *same* fixed seeded V/D/U tile the
    position would realize, under the phase-noise draw that position
    freezes at ``calibrate_tiled`` time (keys folded by physical position
    ``o*Ti + i``, then split exactly as ``calibrate`` splits them — so
    the score ranks the draws calibration will actually bind).  The
    metric mirrors ``paper/efficiency.monte_carlo_yield``: relative L2
    error of the detected output against the ideal device after the
    optimal scalar (digital gamma) compensation, negated so higher is
    better.
    """
    plan = mesh_lib.clements_plan(tile)
    kp, kq = jax.random.split(jax.random.PRNGKey(seed))
    params_v = mesh_lib.init_mesh_params(kp, plan, with_sigma=False)
    params_u = mesh_lib.init_mesh_params(kq, plan, with_sigma=False)
    probes = jnp.eye(tile, dtype=jnp.complex64)

    def chain(kv, ku, hw):
        h = kernel_ops.mesh_apply(params_v, probes, n=tile, plan=plan,
                                  hardware=hw, key=kv, interpret=interpret)
        h = kernel_ops.mesh_apply(params_u, h, n=tile, plan=plan,
                                  hardware=hw, key=ku, interpret=interpret)
        return jnp.abs(h)

    y_ideal = chain(None, None, None)
    # the exact key consumption of calibrate_tiled -> calibrate:
    # fold by physical position, fold by layer index (0), split into v/u
    kt = jax.vmap(lambda j: jax.random.fold_in(
        jax.random.fold_in(key, j), 0))(jnp.arange(to * ti))
    kvu = jax.vmap(jax.random.split)(kt)

    def error(kpair):
        mag = chain(kpair[0], kpair[1], hardware)
        gamma = (jnp.vdot(mag, y_ideal)
                 / jnp.maximum(jnp.vdot(mag, mag), 1e-12)).real
        return (jnp.linalg.norm(gamma * mag - y_ideal)
                / jnp.maximum(jnp.linalg.norm(y_ideal), 1e-12))

    errors = jax.vmap(error)(kvu)
    return -np.asarray(errors, np.float64).reshape(to, ti)


def plan_placement(sensitivity: np.ndarray,
                   scores: np.ndarray) -> TilePlacement:
    """Match high-sensitivity logical tiles to high-yield positions.

    Works on the row/column marginals (the only degrees of freedom a
    row x column permutation has): the most sensitive logical row is
    assigned to the best-scoring physical row, and likewise for columns.
    Sorting is stable, so equal-mass rows keep their logical order and a
    uniform grid yields the identity placement.
    """
    sens = np.asarray(sensitivity, np.float64)
    sc = np.asarray(scores, np.float64)
    if sens.shape != sc.shape:
        raise ValueError(f"shape mismatch: sensitivity {sens.shape} vs "
                         f"scores {sc.shape}")
    to, ti = sens.shape

    def match(sens_m, score_m):
        phys = np.argsort(-score_m, kind="stable")   # best position first
        logi = np.argsort(-sens_m, kind="stable")    # most sensitive first
        perm = np.empty(len(phys), np.int64)
        perm[phys] = logi
        return tuple(int(v) for v in perm)

    return TilePlacement(row_perm=match(sens.sum(1), sc.sum(1)),
                         col_perm=match(sens.sum(0), sc.sum(0)))


def apply_placement(tp: TiledAnalogProgram,
                    placement: TilePlacement) -> TiledAnalogProgram:
    """Physically reorder the grid so position ``(po, pi)`` holds logical
    tile ``(row_perm[po], col_perm[pi])``, recording the placement.

    Run *before* ``calibrate_tiled``: the moved tiles then calibrate
    against the draws of the positions they actually occupy (keys are
    folded by physical position).  Raises if the program already carries
    a placement — compose permutations via :func:`undo_placement` first.
    """
    if tp.placement is not None and not tp.placement.is_identity:
        raise ValueError("program already carries a placement — "
                         "undo_placement first")
    if (len(placement.row_perm), len(placement.col_perm)) != (tp.to, tp.ti):
        raise ValueError(
            f"placement is {len(placement.row_perm)}x"
            f"{len(placement.col_perm)} for a {tp.to}x{tp.ti} grid")
    grid = tuple(
        tuple(tp.grid[placement.row_perm[po]][placement.col_perm[pi]]
              for pi in range(tp.ti))
        for po in range(tp.to))
    return dataclasses.replace(tp, grid=grid, placement=placement)


def undo_placement(tp: TiledAnalogProgram) -> TiledAnalogProgram:
    """Back to logical order (tile state — calibration included — rides
    along with each tile)."""
    pl = tp.placement
    if pl is None or pl.is_identity:
        return dataclasses.replace(tp, placement=None)
    inv_r, inv_c = pl.inv_row_perm, pl.inv_col_perm
    grid = tuple(
        tuple(tp.grid[inv_r[o]][inv_c[i]] for i in range(tp.ti))
        for o in range(tp.to))
    return dataclasses.replace(tp, grid=grid, placement=None)


def blank_tile(la, *, scale_zero: float = 0.0):
    """A dead tile's program: zero digital gamma (an unpowered passive
    tile contributes nothing to its row's combine)."""
    return la.replace(scale=jnp.asarray(scale_zero, jnp.float32))


def recover_tiled(tp: TiledAnalogProgram, plan,
                  hardware: hw_lib.HardwareModel | None = None, *,
                  key: Array | None = None, lower: bool = True,
                  block_b: int | None = None,
                  interpret: bool | None = None, **calibrate_kw):
    """Rebuild a placed+calibrated grid around dead positions.

    ``plan`` is a :class:`repro.runtime.elastic.TileRecoveryPlan` (plain
    data): its permutations park the least-sensitive logical tiles on the
    dead positions, which are then blanked; the surviving tiles whose
    physical position changed re-``calibrate`` against their new
    positions' draws (``plan.recalibrate``), every other tile keeps its
    existing binding untouched.  Returns the recompiled
    :class:`~repro.compile.program.CompiledTiledProgram` (or the
    recovered :class:`TiledAnalogProgram` with ``lower=False``).
    """
    from repro.compile import passes

    if not plan.viable:
        raise ValueError(f"recovery plan is not viable: {plan.reason}")
    logical = undo_placement(tp)
    placed = apply_placement(
        logical, TilePlacement(plan.row_perm, plan.col_perm))
    dead = set(plan.dead)
    placed = placed.map_tiles(
        lambda o, i, la: blank_tile(la) if (o, i) in dead else la)
    if plan.recalibrate:
        placed = passes.calibrate_tiled(placed, hardware, key=key,
                                        only=plan.recalibrate,
                                        interpret=interpret, **calibrate_kw)
    if not lower:
        return placed
    return passes.lower_tiled(placed, block_b=block_b, interpret=interpret)
