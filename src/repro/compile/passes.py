"""Composable compiler passes over the :class:`AnalogProgram` IR.

The pipeline mirrors the paper's digital->analog transfer (Fig. 11):

    synthesize -> program -> [quantize] -> [calibrate] -> lower

* :func:`synthesize` — SVD-factor each digital weight matrix into
  ``U . D . V^H`` with the overall scale recovered digitally (Eq. 31);
  owns the factorization that used to live in ``core/svd_synthesis``.
* :func:`program` — fill in mesh plans/params for both unitary factors:
  analytically (:func:`repro.core.decompose.reck_program`) or by the
  kernel-backed gradient fit (the paper's "stochastic optimization"
  programming, Sec. IV-B) — identity probes swept through
  ``ops.mesh_apply`` columns under :class:`repro.optim.AdamW`, fully
  jitted, never touching the pure-jnp reference.
* :func:`quantize` — snap phases onto a discrete codebook (Table I or
  ``uniform<bits>``), either immediately (``nearest``) or keeping
  continuous masters for later quantization-aware fits (``ste``); records
  the integer device state codes either way.
* :func:`calibrate` — hardware-in-the-loop residual fit: re-fit phases
  (through the codebook's straight-through estimator when quantized) and
  the digital gains against the *imperfect* device, probing it through
  ``ops.mesh_apply(hardware=...)`` with frozen per-device noise-draw keys
  — the same ``imperfect_cell_matrix`` + key consumption as the reference
  path, so calibration and serving see the device draw-for-draw.
* :func:`lower` — emit the megakernel inputs (an L x 1 x 1
  ``DeepGridSchedule`` + stacked ``[L, 1, 1, C, 8, P]`` coefficients)
  through the existing ``ops.pack_network`` leaf-identity cache and
  return a :class:`CompiledProgram` whose ``apply`` is pure kernel
  execution.  :func:`lower_deep` lowers a *chain* of tiled programs onto
  one ``L x To x Ti`` deep megakernel (:class:`CompiledDeepProgram`).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

from repro.compile.program import (
    AnalogProgram,
    CompiledDeepProgram,
    CompiledProgram,
    CompiledTiledProgram,
    ProgramLayer,
    TiledAnalogProgram,
)
from repro.core import decompose
from repro.core import hardware as hw_lib
from repro.core import mesh as mesh_lib
from repro.core import quantize as q_lib
from repro.kernels import ops as kernel_ops
from repro.optim.adamw import AdamW

Array = jax.Array


def _pad_even(k: int) -> int:
    return k + (k % 2)


# ---------------------------------------------------------------------------
# synthesize
# ---------------------------------------------------------------------------

def synthesize(matrices, *, n: int | None = None) -> AnalogProgram:
    """SVD-factor digital weight matrices into analog layer specs.

    ``matrices``: one ``[out, in]`` array or a sequence of them (a layer
    stack).  Every layer is zero-padded to a common even mesh size ``n``
    (default: the enclosing square of the largest layer) so the stack can
    later lower onto one network megakernel.  The diagonal is normalized
    by the largest singular value — a passive network only attenuates —
    and the scale is recovered digitally (the paper's gamma, Fig. 11).
    """
    if not isinstance(matrices, (list, tuple)):
        matrices = [np.asarray(matrices)]
    elif matrices and np.ndim(matrices[0]) <= 1:
        matrices = [np.asarray(matrices)]   # one matrix as nested lists
    else:
        matrices = [np.asarray(m) for m in matrices]
    if not matrices:
        raise ValueError("need at least one matrix")
    if n is None:
        n = max(_pad_even(max(m.shape)) for m in matrices)
    if n < 2 or n % 2:
        raise ValueError(f"mesh size must be even and >= 2, got n={n}")
    layers = []
    for m in matrices:
        out_dim, in_dim = m.shape
        if max(out_dim, in_dim) > n:
            raise ValueError(f"matrix {m.shape} exceeds mesh size n={n}")
        mp = np.zeros((n, n), np.complex128)
        mp[:out_dim, :in_dim] = m
        u, s, vh = np.linalg.svd(mp)
        smax = float(s.max()) if s.max() > 0 else 1.0
        layers.append(ProgramLayer(
            n=n, out_dim=out_dim, in_dim=in_dim, target=m.copy(),
            target_u=u, target_vh=vh,
            attenuation=jnp.asarray(s / smax, jnp.float32),
            scale=jnp.asarray(smax, jnp.float32)))
    for prev, nxt in zip(layers, layers[1:]):
        if prev.out_dim != nxt.in_dim:
            raise ValueError(
                f"layer stack does not chain: out_dim {prev.out_dim} feeds "
                f"in_dim {nxt.in_dim} (extra channels would be dropped "
                "silently)")
    return AnalogProgram(layers=tuple(layers))


# ---------------------------------------------------------------------------
# program
# ---------------------------------------------------------------------------

@functools.partial(jax.jit,
                   static_argnames=("plan", "opt", "steps", "interpret"))
def _fit_run(params, state, target, probes, *, plan, opt, steps, interpret):
    """The fit-programming step loop, jitted once per (plan, opt, steps).

    Module-level so the trace cache is shared across layers and pass
    invocations — every layer of a stack reuses one compilation (targets
    and initializations are ordinary arguments).
    """
    def loss_fn(p):
        cols = kernel_ops.mesh_apply(p, probes, n=plan.n, plan=plan,
                                     interpret=interpret)
        return jnp.sum(jnp.abs(cols.T - target) ** 2)

    def step(carry, _):
        p, s = carry
        _, g = jax.value_and_grad(loss_fn)(p)
        p, s, _ = opt.update(p, g, s)
        return (p, s), None

    (params, _), _ = jax.lax.scan(step, (params, state), None, length=steps)
    return params


def _fit_unitary(target: np.ndarray, plan: mesh_lib.MeshPlan, *,
                 steps: int, lr: float, seed: int,
                 interpret: bool | None) -> dict:
    """Kernel-backed gradient programming of one unitary onto ``plan``.

    Identity probes swept through the fused ``ops.mesh_apply`` kernel
    reconstruct the realized matrix column-by-column; AdamW minimizes the
    Frobenius error in one jitted ``lax.scan`` (input phase screen on —
    required for universality of the single-phase cell, see DESIGN.md).
    """
    target = jnp.asarray(target, jnp.complex64)
    n = plan.n
    params = mesh_lib.init_mesh_params(jax.random.PRNGKey(seed), plan,
                                       with_sigma=True)
    params["alpha_in"] = jnp.zeros((n,), jnp.float32)
    probes = jnp.eye(n, dtype=jnp.complex64)
    opt = AdamW(lr=lr, b1=0.9, b2=0.999, weight_decay=0.0, clip_norm=0.0)
    if steps <= 0:
        return params
    return dict(_fit_run(params, opt.init(params), target, probes,
                         plan=plan, opt=opt, steps=steps,
                         interpret=interpret))


def program(prog: AnalogProgram, method: str = "reck", *,
            steps: int = 1500, lr: float = 0.05, seed: int = 0,
            interpret: bool | None = None) -> AnalogProgram:
    """Fill in mesh plans/params realizing each layer's unitary factors.

    ``method="reck"``: exact analytic factorization (triangular layout).
    ``method="fit"``: the paper's stochastic-optimization programming on
    the rectangular Clements layout, via the kernel-backed AdamW fit.
    """
    if method not in ("reck", "fit"):
        raise ValueError(f"unknown programming method {method!r}")

    def one(i, la):
        if method == "reck":
            u_plan, u_params = decompose.reck_program(la.target_u)
            v_plan, v_params = decompose.reck_program(la.target_vh)
        else:
            plan = mesh_lib.clements_plan(la.n)
            u_params = _fit_unitary(la.target_u, plan, steps=steps, lr=lr,
                                    seed=seed + 2 * i, interpret=interpret)
            v_params = _fit_unitary(la.target_vh, plan, steps=steps, lr=lr,
                                    seed=seed + 2 * i + 1,
                                    interpret=interpret)
            u_plan = v_plan = plan
        return la.replace(v_plan=v_plan, v_params=v_params,
                          u_plan=u_plan, u_params=u_params)

    return AnalogProgram(layers=tuple(
        one(i, la) for i, la in enumerate(prog.layers)))


# ---------------------------------------------------------------------------
# quantize
# ---------------------------------------------------------------------------

def resolve_codebook(codebook) -> Array:
    """``"table1"`` | ``"uniform<bits>"`` | explicit phase array."""
    if isinstance(codebook, str):
        if codebook == "table1":
            return q_lib.table_i_codebook()
        if codebook.startswith("uniform"):
            return q_lib.uniform_codebook(int(codebook[len("uniform"):]))
        raise ValueError(f"unknown codebook {codebook!r}")
    return jnp.asarray(codebook, jnp.float32)


def quantize(prog: AnalogProgram, codebook="table1", *,
             mode: str = "nearest") -> AnalogProgram:
    """Snap mesh phases onto the discrete device codebook (Table I).

    ``mode="nearest"`` stores the snapped phases directly; ``mode="ste"``
    keeps the continuous masters (snapping happens at the device boundary
    — ``lower`` and ``layer_matrix`` — and later gradient fits see the
    codebook through the straight-through estimator).  Both record the
    integer device state codes.
    """
    if mode not in ("nearest", "ste"):
        raise ValueError(f"unknown quantize mode {mode!r}")
    cb = resolve_codebook(codebook)

    def one(la: ProgramLayer) -> ProgramLayer:
        if not la.programmed:
            raise ValueError("quantize needs a programmed layer — run the "
                             "`program` pass first")
        vp, up = la.v_params, la.u_params
        if mode == "nearest":
            vp = q_lib.quantize_mesh_params(vp, cb, ste=False)
            up = q_lib.quantize_mesh_params(up, cb, ste=False)
        return la.replace(
            v_params=vp, u_params=up, codebook=cb, quant_mode=mode,
            v_codes=q_lib.mesh_params_to_codes(vp, cb),
            u_codes=q_lib.mesh_params_to_codes(up, cb))

    return prog.map_layers(one)


# ---------------------------------------------------------------------------
# calibrate
# ---------------------------------------------------------------------------

def logit(p: Array) -> Array:
    """Inverse sigmoid, clipped to (1e-6, 1 - 1e-6) — the link function for
    attenuation logits (shared with ``AnalogLinear.init_from_matrix``)."""
    p = jnp.clip(p, 1e-6, 1.0 - 1e-6)
    return jnp.log(p / (1.0 - p))


def inv_softplus(s: Array) -> Array:
    """Inverse softplus, guarded at 1e-6 — the link function for the
    digital-gamma log-scale (shared with ``AnalogLinear.init_from_matrix``)."""
    return jnp.log(jnp.expm1(jnp.maximum(s, 1e-6)))


@functools.partial(jax.jit,
                   static_argnames=("v_plan", "u_plan", "hardware", "opt",
                                    "steps", "fit_gains", "interpret"))
def _calibration_run(train, state, base_v, base_u, atten0, scale0, probes,
                     target, codebook, kv, ku, *, v_plan, u_plan, hardware,
                     opt, steps, fit_gains, interpret):
    """The calibration step loop, jitted once per (plans, opt, steps).

    Module-level so homogeneous layer stacks (equal-content plans hash to
    the same statics) share one compilation across layers and calls.
    Keeps the best-seen iterate: STE steps can hop phases across code
    boundaries non-monotonically, and the start point (the uncalibrated
    program) is evaluated first — so calibration never returns something
    worse than its input.
    """
    n = v_plan.n

    def realize(tr):
        vp = tr.get("v", base_v)
        up = tr.get("u", base_u)
        if codebook is not None:
            vp = q_lib.quantize_mesh_params(vp, codebook, ste=True)
            up = q_lib.quantize_mesh_params(up, codebook, ste=True)
        atten = jax.nn.sigmoid(tr["atten_logit"]) if fit_gains else atten0
        scale = jax.nn.softplus(tr["log_scale"]) if fit_gains else scale0
        h = kernel_ops.mesh_apply(vp, probes, n=n, plan=v_plan,
                                  hardware=hardware, key=kv,
                                  interpret=interpret)
        h = h * atten.astype(jnp.complex64)
        h = kernel_ops.mesh_apply(up, h, n=n, plan=u_plan,
                                  hardware=hardware, key=ku,
                                  interpret=interpret)
        return (scale.astype(jnp.complex64) * h).T

    def loss_fn(tr):
        return jnp.sum(jnp.abs(realize(tr) - target) ** 2)

    def step(carry, _):
        tr, st, best_tr, best_loss = carry
        loss, g = jax.value_and_grad(loss_fn)(tr)
        better = loss < best_loss
        best_tr = jax.tree.map(
            lambda b, c: jnp.where(better, c, b), best_tr, tr)
        best_loss = jnp.minimum(loss, best_loss)
        tr, st, _ = opt.update(tr, g, st)
        return (tr, st, best_tr, best_loss), None

    carry = (train, state, train, jnp.asarray(jnp.inf, jnp.float32))
    (tr, _, best_tr, best_loss), _ = jax.lax.scan(step, carry, None,
                                                  length=steps)
    final_loss = loss_fn(tr)
    take_final = final_loss < best_loss
    return jax.tree.map(lambda b, c: jnp.where(take_final, c, b),
                        best_tr, tr)


def calibrate(prog: AnalogProgram,
              hardware: hw_lib.HardwareModel | None = None, *,
              key: Array | None = None, steps: int = 200, lr: float = 0.02,
              fit_phases: bool = True, fit_gains: bool = True,
              interpret: bool | None = None) -> AnalogProgram:
    """Hardware-in-the-loop residual fit of each layer against its target.

    Probes the *imperfect* device (``ops.mesh_apply`` with ``hardware``,
    phase noise frozen per layer by keys folded from ``key`` — consumed
    exactly like the reference ``apply_mesh_hw`` path, so the calibrated
    program later serves against the identical draw) and re-fits the mesh
    phases and the digital gains (attenuation + gamma) to minimize the
    Frobenius error of the realized matrix.  Quantized layers fit their
    continuous masters through the codebook's straight-through estimator
    and keep updated device codes (``quant_mode`` becomes ``"ste"``).

    ``hardware=None`` calibrates against ideal cells — useful to trim
    pure quantization error.  Returns a program with the hardware model
    and draw keys *bound*, so ``lower`` serves the calibrated device.
    """
    def one(i, la: ProgramLayer) -> ProgramLayer:
        if not la.programmed:
            raise ValueError("calibrate needs a programmed layer — run the "
                             "`program` pass first")
        kv = ku = None
        if hardware is not None and key is not None:
            kv, ku = jax.random.split(jax.random.fold_in(key, i))
        target = jnp.asarray(la.padded_target(), jnp.complex64)
        probes = jnp.eye(la.n, dtype=jnp.complex64)

        train = {}
        if fit_phases:
            train["v"] = dict(la.v_params)
            train["u"] = dict(la.u_params)
        if fit_gains:
            train["atten_logit"] = logit(la.attenuation)
            train["log_scale"] = inv_softplus(
                jnp.asarray(la.scale, jnp.float32))

        opt = AdamW(lr=lr, b1=0.9, b2=0.999, weight_decay=0.0,
                    clip_norm=0.0)
        ran = bool(train) and steps > 0
        if ran:
            train = _calibration_run(
                train, opt.init(train), la.v_params, la.u_params,
                jnp.asarray(la.attenuation, jnp.float32),
                jnp.asarray(la.scale, jnp.float32), probes, target,
                la.codebook, kv, ku, v_plan=la.v_plan, u_plan=la.u_plan,
                hardware=hardware, opt=opt, steps=steps,
                fit_gains=fit_gains, interpret=interpret)
        # steps=0 binds the device without trimming: parameters (and the
        # gains' logit/softplus round trip) stay bit-identical
        vp = dict(train["v"]) if fit_phases and ran else la.v_params
        up = dict(train["u"]) if fit_phases and ran else la.u_params
        new = dict(
            v_params=vp, u_params=up,
            hardware=hardware, key_v=kv, key_u=ku)
        if fit_gains and ran:
            new["attenuation"] = jax.nn.sigmoid(train["atten_logit"])
            new["scale"] = jax.nn.softplus(train["log_scale"])
        if la.codebook is not None and ran:
            new["quant_mode"] = "ste"
            new["v_codes"] = q_lib.mesh_params_to_codes(vp, la.codebook)
            new["u_codes"] = q_lib.mesh_params_to_codes(up, la.codebook)
        return la.replace(**new)

    return AnalogProgram(layers=tuple(
        one(i, la) for i, la in enumerate(prog.layers)))


# ---------------------------------------------------------------------------
# lower
# ---------------------------------------------------------------------------

def lower(prog: AnalogProgram, *, block_b: int | None = None,
          interpret: bool | None = None) -> CompiledProgram:
    """Emit megakernel inputs and return a servable :class:`CompiledProgram`.

    Builds the per-layer kernel argument dicts (device-snapped phases,
    attenuation, digital gamma, bound noise keys), then emits the
    L x 1 x 1 :class:`DeepGridSchedule` and the stacked
    ``[L, 1, 1, C, 8, P]`` coefficient tensors through ``ops.pack_network`` — the same leaf-identity pack
    cache the serving path reads, so the tensors are packed exactly once,
    here, and every subsequent ``apply`` (and every serving tick) finds
    them already resident.
    """
    if not prog.programmed:
        raise ValueError("lower needs a fully programmed AnalogProgram — "
                         "run the `program` pass first")
    hardwares = {la.hardware for la in prog.layers}
    if len(hardwares) > 1:
        raise ValueError("all layers must share one hardware binding, got "
                         f"{hardwares}")
    hardware = next(iter(hardwares))
    layer_args = []
    plans = []
    for la in prog.layers:
        layer_args.append(_tile_kernel_args(la, hardware))
        plans.append((la.v_plan, la.u_plan))
    layer_args = tuple(layer_args)
    plans = tuple(plans)
    net, packed = kernel_ops.pack_network(layer_args, n=prog.n, plans=plans,
                                          hardware=hardware)
    return CompiledProgram(
        n=prog.n, in_dim=prog.in_dim, out_dim=prog.out_dim,
        depth=prog.depth, plans=plans, layer_args=layer_args,
        hardware=hardware, net=net, packed=packed,
        block_b=block_b, interpret=interpret, source=prog)


# ---------------------------------------------------------------------------
# Tiled pipeline: per-tile-SVD programs for matrices larger than one mesh
# ---------------------------------------------------------------------------
#
# The single-matrix pipeline above tops out at one mesh (the prototype's
# 8x8); the tiled pipeline scales past it the way the paper's Sec. V
# sketches: split the matrix into a (To x Ti) grid of tile-sized blocks,
# run the whole per-layer pipeline on every block *independently*
# (synthesize -> program -> quantize -> calibrate, each tile is its own
# physical processor with its own codebook snap and hardware trim) and
# lower the grid onto ONE tile-grid megakernel call — row outputs combine
# coherently in VMEM, the readout detects after combination.


def synthesize_tiled(matrix, tile: int) -> TiledAnalogProgram:
    """SVD-factor a large matrix into a (To x Ti) grid of tile programs.

    The ``[out, in]`` matrix is zero-padded up to multiples of ``tile``
    (even, >= 2) and each ``tile x tile`` block becomes a single-layer
    :class:`ProgramLayer` spec via :func:`synthesize`.  Row sums of the
    realized tiles reconstruct the full matmul; ``apply`` later truncates
    the padding back to ``out``.
    """
    m = np.asarray(matrix)
    if m.ndim != 2:
        raise ValueError(f"need one [out, in] matrix, got shape {m.shape}")
    if tile < 2 or tile % 2:
        raise ValueError(f"tile size must be even and >= 2, got {tile}")
    out_dim, in_dim = m.shape
    to = -(-out_dim // tile)
    ti = -(-in_dim // tile)
    mp = np.zeros((to * tile, ti * tile), m.dtype)
    mp[:out_dim, :in_dim] = m
    grid = []
    for o in range(to):
        row = []
        for i in range(ti):
            block = mp[o * tile:(o + 1) * tile, i * tile:(i + 1) * tile]
            row.append(synthesize(block, n=tile).layers[0])
        grid.append(tuple(row))
    return TiledAnalogProgram(out_dim=out_dim, in_dim=in_dim, tile=tile,
                              grid=tuple(grid))


def program_tiled(tp: TiledAnalogProgram, method: str = "reck",
                  **kw) -> TiledAnalogProgram:
    """:func:`program` mapped over every tile (independent meshes)."""
    return tp.map_tiles(lambda o, i, la: program(
        AnalogProgram((la,)), method, **kw).layers[0])


def quantize_tiled(tp: TiledAnalogProgram, codebook="table1", *,
                   mode: str = "nearest") -> TiledAnalogProgram:
    """:func:`quantize` mapped over every tile (per-device codebooks)."""
    return tp.map_tiles(lambda o, i, la: quantize(
        AnalogProgram((la,)), codebook, mode=mode).layers[0])


def calibrate_tiled(tp: TiledAnalogProgram,
                    hardware: hw_lib.HardwareModel | None = None, *,
                    key: Array | None = None, only=None,
                    **kw) -> TiledAnalogProgram:
    """:func:`calibrate` mapped over every tile.

    Each tile is its own physical device: the noise-draw key is folded
    per *physical* grid position (``o * Ti + i``) so every tile freezes
    an independent draw, and the residual fit trims each tile against
    its own block target through the imperfect kernel path.  On a placed
    grid (``compile/placement.py``) the folding therefore binds each
    tile to the draw of the position it actually occupies.

    ``only``: optional iterable of ``(o, i)`` physical positions — every
    other tile passes through untouched, keeping its existing binding
    bit-identical.  The degraded-grid recovery path uses this to re-trim
    exactly the tiles the remap moved.
    """
    only_set = None if only is None else {tuple(p) for p in only}

    def one(o, i, la):
        if only_set is not None and (o, i) not in only_set:
            return la
        kt = (jax.random.fold_in(key, o * tp.ti + i)
              if key is not None else None)
        return calibrate(AnalogProgram((la,)), hardware, key=kt,
                         **kw).layers[0]

    return tp.map_tiles(one)


def lower_tiled(tp: TiledAnalogProgram, *, block_b: int | None = None,
                interpret: bool | None = None, mesh=None,
                row_axis: str = "rows",
                data_axis: str = "data") -> CompiledTiledProgram:
    """Emit tile-grid kernel inputs; returns a servable
    :class:`CompiledTiledProgram` whose ``apply`` is ONE ``pallas_call``
    per direction over the whole (To x Ti) grid.

    Tensors are emitted through ``ops.pack_tile_grid``'s leaf-identity
    cache — packed exactly once, here — and handed back verbatim on every
    ``apply``, so serving (every tick, the first included) does zero
    packing work.  A placement on ``tp`` is carried onto the compiled
    program (its ``apply`` undoes it digitally); ``mesh`` (a 2-axis
    ``jax.sharding.Mesh``) makes every ``apply`` shard over
    ``(row_axis, data_axis)`` through the kernel's shard_map path.
    """
    if not tp.programmed:
        raise ValueError("lower_tiled needs a fully programmed tile grid — "
                         "run the `program_tiled` pass first")
    hardwares = {la.hardware for row in tp.grid for la in row}
    if len(hardwares) > 1:
        raise ValueError("all tiles must share one hardware binding, got "
                         f"{hardwares}")
    hardware = next(iter(hardwares))
    tile_args, plans = [], []
    for row in tp.grid:
        tile_args.append(tuple(_tile_kernel_args(la, hardware) for la in row))
        plans.append(tuple((la.v_plan, la.u_plan) for la in row))
    tile_args, plans = tuple(tile_args), tuple(plans)
    grid, packed = kernel_ops.pack_tile_grid(tile_args, n=tp.tile,
                                             plans=plans, hardware=hardware)
    return CompiledTiledProgram(
        out_dim=tp.out_dim, in_dim=tp.in_dim, tile=tp.tile,
        to=tp.to, ti=tp.ti, plans=plans, tile_args=tile_args,
        hardware=hardware, grid=grid, packed=packed,
        block_b=block_b, interpret=interpret, placement=tp.placement,
        mesh=mesh, row_axis=row_axis, data_axis=data_axis, source=tp)


# ---------------------------------------------------------------------------
# Deep pipeline: a multi-layer cascade of tile grids on ONE megakernel
# ---------------------------------------------------------------------------

def _tile_kernel_args(la: ProgramLayer, hardware) -> dict:
    """The kernel argument dict of one programmed tile (shared by the
    network / tile-grid / deep-grid lowerings)."""
    args = {
        "v": la.device_params("v"),
        "u": la.device_params("u"),
        "atten": jnp.asarray(la.attenuation, jnp.float32),
        "scale": jnp.asarray(la.scale, jnp.float32),
    }
    if hardware is not None and la.key_v is not None:
        args["key_v"], args["key_u"] = la.key_v, la.key_u
    return args


def lower_deep(progs, *, block_b: int | None = None,
               interpret: bool | None = None, mesh=None,
               row_axis: str = "rows",
               data_axis: str = "data") -> CompiledDeepProgram:
    """Lower a cascade of programmed tile grids onto ONE deep megakernel.

    ``progs`` is a sequence of :class:`TiledAnalogProgram` — layer ``l``'s
    ``To`` tile rows feed layer ``l+1``'s ``Ti`` input tiles, so adjacent
    layers must chain (``prev.to == next.ti``, ``prev.out_dim ==
    next.in_dim``) and every tile shares one tile size and one hardware
    binding.  The result's ``apply`` is a single ``pallas_call`` per
    direction over the whole ``L x To x Ti`` cascade: combined row
    outputs are power-detected and re-injected into the next layer's
    tiles inside VMEM, which is exactly the physical cascade — the
    intermediate channels ride analog, with no digital truncation or
    masking between layers (compose per-layer ``lower_tiled`` programs
    if you need that).

    Placements fold into the single launch instead of costing per-layer
    digital gathers: the first layer's column permutation becomes the
    input gather, the last layer's row permutation the output gather,
    and every *interior* boundary is resolved at pack time by re-ordering
    the next layer's packed tile columns into the previous layer's
    physical row order (each tile keeps its own calibration draw — the
    re-order is a compile-time re-placement of interior columns, not a
    re-trim).
    """
    progs = tuple(progs)
    if not progs:
        raise ValueError("lower_deep needs at least one tiled layer program")
    tile = progs[0].tile
    for l, tp in enumerate(progs):
        if not tp.programmed:
            raise ValueError(f"lower_deep: layer {l} is not fully programmed "
                             "— run the `program_tiled` pass first")
        if tp.tile != tile:
            raise ValueError("all layers must share one tile size, got "
                             f"{[t.tile for t in progs]}")
    for l in range(len(progs) - 1):
        prev, nxt = progs[l], progs[l + 1]
        if prev.to != nxt.ti:
            raise ValueError(
                f"deep program does not chain: layer {l} emits To={prev.to} "
                f"tile rows but layer {l + 1} expects Ti={nxt.ti} input tiles")
        if prev.out_dim != nxt.in_dim:
            raise ValueError(
                f"deep program does not chain: layer {l} out_dim "
                f"{prev.out_dim} feeds layer {l + 1} in_dim {nxt.in_dim}")
    hardwares = {la.hardware for tp in progs for row in tp.grid for la in row}
    if len(hardwares) > 1:
        raise ValueError("all tiles must share one hardware binding, got "
                         f"{hardwares}")
    hardware = next(iter(hardwares))

    layer_args, layer_plans = [], []
    prev_rows = None  # logical tile row carried by incoming physical block j
    for l, tp in enumerate(progs):
        pl = tp.placement
        if l == 0:
            order = list(range(tp.ti))
        else:
            # incoming physical block j carries the previous layer's logical
            # row prev_rows[j]; the tile consuming that logical column sits
            # at this layer's physical column inv_col_perm[prev_rows[j]]
            src = prev_rows if prev_rows is not None else list(range(tp.ti))
            inv_col = (list(pl.inv_col_perm) if pl is not None
                       else list(range(tp.ti)))
            order = [inv_col[c] for c in src]
        grid_args, grid_plans = [], []
        for row in tp.grid:
            grid_args.append(tuple(
                _tile_kernel_args(row[j], hardware) for j in order))
            grid_plans.append(tuple(
                (row[j].v_plan, row[j].u_plan) for j in order))
        layer_args.append(tuple(grid_args))
        layer_plans.append(tuple(grid_plans))
        prev_rows = list(pl.row_perm) if pl is not None else None
    layer_args = tuple(layer_args)
    layer_plans = tuple(layer_plans)
    deep, packed = kernel_ops.pack_deep_grid(layer_args, n=tile,
                                             plans=layer_plans,
                                             hardware=hardware)
    return CompiledDeepProgram(
        out_dim=progs[-1].out_dim, in_dim=progs[0].in_dim, tile=tile,
        depth=len(progs), to=progs[-1].to, ti=progs[0].ti,
        plans=layer_plans, layer_args=layer_args, hardware=hardware,
        deep=deep, packed=packed, block_b=block_b, interpret=interpret,
        in_placement=progs[0].placement, out_placement=progs[-1].placement,
        mesh=mesh, row_axis=row_axis, data_axis=data_axis, sources=progs)
