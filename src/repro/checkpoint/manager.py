"""Checkpoint manager: atomic, shard-per-host, async, with retention.

Layout (one directory per step):

    ckpt_dir/
      step_000100/
        manifest.json          # tree structure, shapes/dtypes, data step,
                               # compression codec
        host_000.ckpt          # this host's param/opt shards (headered,
                               # zstd- or zlib-compressed npz)
        ...
      LATEST                   # atomically updated pointer file

Fault-tolerance properties:
  * writes go to ``step_x.tmp`` then ``os.replace`` -> crash mid-save never
    corrupts a restorable checkpoint;
  * the LATEST pointer is written last, after all hosts' shards (multi-host
    barrier is the caller's collective; here each host owns its file);
  * ``save_async`` runs serialization on a worker thread so the train loop
    keeps stepping (the pytree is snapshotted to host memory first);
  * ``restore`` validates the manifest tree against the expected structure
    and resumes the deterministic data stream at ``data_step``;
  * ``keep`` retention deletes old steps only after a newer one is durable.

Compression: shards are zstd-compressed when ``zstandard`` is installed and
fall back to stdlib ``zlib`` otherwise, so importing and using this module
never requires the optional dependency.  Each shard carries a small header
recording the codec, and ``restore`` dispatches on it — checkpoints written
with either codec (including pre-header zstd shards) restore on any host
that has the matching decompressor.
"""

from __future__ import annotations

import io
import json
import os
import shutil
import threading
import zlib
from pathlib import Path

import jax
import numpy as np

try:  # optional: the container may not ship zstandard
    import zstandard
except ImportError:  # pragma: no cover - depends on the environment
    zstandard = None

#: shard header: magic + 4-byte codec tag, then the compressed payload
_MAGIC = b"RPCK"
_CODECS = ("zstd", "zlib")


def _default_codec() -> str:
    return "zstd" if zstandard is not None else "zlib"


def _compress(data: bytes, codec: str) -> bytes:
    if codec == "zstd":
        if zstandard is None:
            raise RuntimeError("codec 'zstd' requested but zstandard is "
                               "not installed; use codec='zlib'")
        payload = zstandard.ZstdCompressor(level=3).compress(data)
    elif codec == "zlib":
        payload = zlib.compress(data, 6)
    else:
        raise ValueError(f"unknown checkpoint codec {codec!r}")
    return _MAGIC + codec.encode("ascii").ljust(4, b"\0") + payload


def _decompress(blob: bytes) -> bytes:
    if blob[:4] == _MAGIC:
        codec = blob[4:8].rstrip(b"\0").decode("ascii")
        payload = blob[8:]
    else:  # legacy shard written before the codec header existed
        codec, payload = "zstd", blob
    if codec == "zstd":
        if zstandard is None:
            raise RuntimeError("checkpoint shard is zstd-compressed but "
                               "zstandard is not installed")
        return zstandard.ZstdDecompressor().decompress(payload)
    if codec == "zlib":
        return zlib.decompress(payload)
    raise ValueError(f"unknown checkpoint codec {codec!r}")


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(path), leaf) for path, leaf in flat]


class CheckpointManager:
    def __init__(self, directory: str | Path, *, keep: int = 3,
                 host_id: int = 0, num_hosts: int = 1,
                 codec: str | None = None):
        self.dir = Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self.host_id = host_id
        self.num_hosts = num_hosts
        self.codec = codec if codec is not None else _default_codec()
        if self.codec not in _CODECS:
            raise ValueError(f"unknown checkpoint codec {self.codec!r}")
        if self.codec == "zstd" and zstandard is None:
            # fail fast here: a late _compress error inside save_async's
            # worker thread would silently drop every checkpoint
            raise RuntimeError("codec 'zstd' requested but zstandard is "
                               "not installed; use codec='zlib'")
        self._worker: threading.Thread | None = None

    # ------------------------------------------------------------------
    def _step_dir(self, step: int) -> Path:
        return self.dir / f"step_{step:08d}"

    def save(self, step: int, tree, *, data_step: int | None = None):
        """Synchronous durable save of this host's shards."""
        self.wait()  # serialize against any in-flight async save
        host_tree = jax.tree.map(np.asarray, tree)  # device -> host
        self._write(step, host_tree, data_step if data_step is not None else step)

    def save_async(self, step: int, tree, *, data_step: int | None = None):
        """Snapshot to host memory now; serialize on a worker thread."""
        self.wait()  # one outstanding save at a time
        host_tree = jax.tree.map(np.asarray, tree)
        ds = data_step if data_step is not None else step
        self._worker = threading.Thread(
            target=self._write, args=(step, host_tree, ds), daemon=True)
        self._worker.start()

    def wait(self):
        if self._worker is not None:
            self._worker.join()
            self._worker = None

    def _write(self, step: int, host_tree, data_step: int):
        final = self._step_dir(step)
        if final.exists():
            return  # this step is already durable
        tmp = final.with_suffix(".tmp")
        if tmp.exists():
            shutil.rmtree(tmp)
        tmp.mkdir(parents=True)

        leaves = _flatten_with_paths(host_tree)
        buf = io.BytesIO()
        np.savez(buf, **{f"leaf_{i}": np.asarray(v)
                         for i, (_, v) in enumerate(leaves)})
        payload = _compress(buf.getvalue(), self.codec)
        # codec-neutral extension: the payload may be zstd or zlib (header
        # decides); a .zst name would mislabel zlib shards
        (tmp / f"host_{self.host_id:03d}.ckpt").write_bytes(payload)

        if self.host_id == 0:
            manifest = {
                "step": step,
                "data_step": data_step,
                "codec": self.codec,
                "num_hosts": self.num_hosts,
                "paths": [p for p, _ in leaves],
                "shapes": [list(np.shape(v)) for _, v in leaves],
                "dtypes": [str(np.asarray(v).dtype) for _, v in leaves],
            }
            (tmp / "manifest.json").write_text(json.dumps(manifest))

        os.replace(tmp, final)  # atomic publish
        if self.host_id == 0:
            latest_tmp = self.dir / "LATEST.tmp"
            latest_tmp.write_text(str(step))
            os.replace(latest_tmp, self.dir / "LATEST")
            self._apply_retention(step)

    def _apply_retention(self, newest_step: int):
        steps = sorted(self.all_steps())
        for s in steps[:-self.keep] if self.keep else []:
            if s != newest_step:
                shutil.rmtree(self._step_dir(s), ignore_errors=True)

    # ------------------------------------------------------------------
    def all_steps(self) -> list[int]:
        return sorted(int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
                      if p.is_dir() and not p.suffix)

    def latest_step(self) -> int | None:
        ptr = self.dir / "LATEST"
        if ptr.exists():
            s = int(ptr.read_text())
            if self._step_dir(s).exists():
                return s
        steps = self.all_steps()
        return steps[-1] if steps else None

    def restore(self, step: int | None, like):
        """Restore into the structure of ``like``; returns (tree, meta)."""
        if step is None:
            step = self.latest_step()
        if step is None:
            raise FileNotFoundError(f"no checkpoints in {self.dir}")
        d = self._step_dir(step)
        manifest = json.loads((d / "manifest.json").read_text())
        shard = d / f"host_{self.host_id:03d}.ckpt"
        if not shard.exists():  # legacy checkpoints used a .zst suffix
            shard = d / f"host_{self.host_id:03d}.zst"
        raw = _decompress(shard.read_bytes())
        data = np.load(io.BytesIO(raw))
        leaves = [data[f"leaf_{i}"] for i in range(len(manifest["paths"]))]

        expected = [p for p, _ in _flatten_with_paths(like)]
        if expected != manifest["paths"]:
            raise ValueError(
                "checkpoint tree mismatch:\n"
                f"  have {manifest['paths'][:4]}...\n  want {expected[:4]}...")
        treedef = jax.tree.structure(like)
        tree = jax.tree.unflatten(treedef, leaves)
        return tree, {"step": manifest["step"],
                      "data_step": manifest["data_step"]}
