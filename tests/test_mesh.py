"""Tests for mesh composition, programming and SVD synthesis (Sec. IV-B)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core import decompose, mesh, svd_synthesis

jax.config.update("jax_platform_name", "cpu")


@pytest.mark.parametrize("n", [2, 4, 8, 12])
def test_clements_plan_cell_count(n):
    plan = mesh.clements_plan(n)
    assert plan.n_cells == n * (n - 1) // 2
    assert plan.n_columns == n


def test_paper_8x8_uses_28_cells():
    """Paper Sec. IV-B: the 8x8 processor is built from 28 unit cells."""
    assert mesh.clements_plan(8).n_cells == 28


@pytest.mark.parametrize("n", [2, 4, 8, 16])
def test_random_mesh_is_unitary(n):
    plan = mesh.clements_plan(n)
    params = mesh.init_mesh_params(jax.random.PRNGKey(n), plan)
    assert mesh.mesh_is_unitary(plan, params)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_mesh_preserves_norm(seed):
    """Unitarity as energy conservation on random inputs."""
    plan = mesh.clements_plan(8)
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    params = mesh.init_mesh_params(k1, plan)
    x = jax.random.normal(k2, (3, 8)) + 1j * jax.random.normal(k2, (3, 8))
    y = mesh.apply_mesh(plan, params, x.astype(jnp.complex64))
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(y), axis=-1),
        np.linalg.norm(np.asarray(x), axis=-1), rtol=1e-4)


@pytest.mark.parametrize("n", [2, 4, 8, 16])
def test_reck_program_reconstructs(n):
    u = decompose.random_unitary(n, seed=n)
    plan, params = decompose.reck_program(u)
    assert plan.n_cells == n * (n - 1) // 2
    assert decompose.reconstruction_error(plan, params, u) < 5e-6


def test_reck_depth_is_triangular():
    plan, _ = decompose.reck_program(decompose.random_unitary(8, 1))
    assert plan.n_columns == 2 * 8 - 3


def test_reck_rejects_nonunitary():
    with pytest.raises(ValueError):
        decompose.reck_program(np.ones((4, 4)))


def test_fit_program_rectangle():
    """Clements rectangle programmed stochastically (the paper's method)."""
    u = decompose.random_unitary(4, seed=3)
    plan, params, err = decompose.fit_program(u, steps=2000, lr=0.05, seed=0)
    assert err < 1e-2
    assert "alpha" in params and "alpha_in" in params


def test_output_screen_only_is_not_universal():
    """Finding (DESIGN.md): the single-phase cell + output-only Sigma cannot
    realize an arbitrary unitary; the input screen restores universality."""
    u = decompose.random_unitary(4, seed=3)
    errs = [decompose.fit_program(u, steps=1200, lr=0.05, seed=s,
                                  with_input_screen=False)[2]
            for s in range(2)]
    assert min(errs) > 5e-2  # consistently stuck without the input screen


@pytest.mark.parametrize("shape", [(2, 2), (3, 5), (5, 3), (8, 8)])
def test_svd_synthesis_arbitrary_matrix(shape):
    rng = np.random.default_rng(0)
    m = rng.normal(size=shape)
    syn = svd_synthesis.synthesize(m)
    assert svd_synthesis.synthesis_error(m, syn) < 1e-4
    # attenuation realizable passively
    assert float(jnp.max(syn.attenuation)) <= 1.0 + 1e-6


def test_svd_synthesis_complex_matrix():
    rng = np.random.default_rng(1)
    m = rng.normal(size=(4, 4)) + 1j * rng.normal(size=(4, 4))
    syn = svd_synthesis.synthesize(m)
    assert svd_synthesis.synthesis_error(m, syn) < 1e-4


def test_apply_mesh_batch_shapes():
    plan = mesh.clements_plan(4)
    params = mesh.init_mesh_params(jax.random.PRNGKey(0), plan)
    for shape in [(4,), (3, 4), (2, 5, 4)]:
        y = mesh.apply_mesh(plan, params, jnp.ones(shape, jnp.complex64))
        assert y.shape == shape


def test_apply_mesh_rejects_bad_dim():
    plan = mesh.clements_plan(4)
    params = mesh.init_mesh_params(jax.random.PRNGKey(0), plan)
    with pytest.raises(ValueError):
        mesh.apply_mesh(plan, params, jnp.ones((3, 6), jnp.complex64))
