"""Shared test configuration: CPU-only JAX, deterministic seeds, markers.

The kernels run in Pallas interpret mode off-TPU (the ``ops`` wrappers
default to it), so forcing the CPU platform here gives every test module
the same interpret-mode defaults without per-file boilerplate.
"""

import os

import numpy as np
import pytest

# pin the platform before jax initializes any backend
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")


def pytest_configure(config):
    config.addinivalue_line(
        "markers", "slow: long-running test (deselect with -m 'not slow')")


@pytest.fixture(autouse=True)
def _deterministic_numpy_seed():
    """Reset the legacy numpy global RNG per test for reproducibility."""
    np.random.seed(0)
    yield


@pytest.fixture(autouse=True, scope="module")
def _drop_jit_caches_between_modules():
    """Release compiled executables when a test module finishes.

    The suite compiles hundreds of interpret-mode kernel programs; the jit
    caches keep every executable alive for the whole run, and on the CPU
    backend that accumulation eventually segfaults XLA's backend_compile on
    a later large program (deterministically ~320 tests in).  Per-module
    cache drops bound the live set; within-module caching (the no-retrace
    and single-pack-event tests) is unaffected.
    """
    yield
    jax.clear_caches()
