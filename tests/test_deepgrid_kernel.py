"""Deep tiled-network megakernel validation: the fused L x To x Ti cascade
vs the per-layer ``tiled_apply`` composition (differential,
property-based), mixed Reck/Clements identity-column padding, ragged
batches, degenerate-wrapper parity, schedule/pack memoization, the
``lower_deep`` compile path (placements, parked blank tiles, serving)
and the shard_map scale-out of the deep kernel."""

import dataclasses
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core import decompose, mesh as mesh_lib
from repro.kernels import ops
from repro.kernels.schedule import deep_grid_schedule

jax.config.update("jax_platform_name", "cpu")

REL_TOL = 1e-5


def _make_tiles(n, to, ti, *, seed=0, screens=False, plans=None):
    """A (to x ti) grid of per-tile kernel argument dicts."""
    rows = []
    for o in range(to):
        row = []
        for i in range(ti):
            pair = plans[o][i] if plans is not None else None
            v_plan = (pair[0] if pair is not None and pair[0] is not None
                      else mesh_lib.clements_plan(n))
            u_plan = (pair[1] if pair is not None and pair[1] is not None
                      else mesh_lib.clements_plan(n))
            k = jax.random.fold_in(jax.random.PRNGKey(seed), o * ti + i)
            kv, ku, ka, ks = jax.random.split(k, 4)
            vp = mesh_lib.init_mesh_params(kv, v_plan)
            up = mesh_lib.init_mesh_params(ku, u_plan)
            if screens:
                vp["alpha_in"] = jax.random.uniform(ks, (n,)) * 2 * np.pi
                up["alpha_in"] = jax.random.uniform(
                    jax.random.fold_in(ks, 1), (n,)) * 2 * np.pi
            row.append({
                "v": vp, "u": up,
                "atten": jax.random.uniform(ka, (n,), minval=0.2,
                                            maxval=0.9),
                "scale": 1.0 + 0.1 * (o + i),
            })
        rows.append(tuple(row))
    return tuple(rows)


def _make_deep(n, depth, to, ti, *, seed=0, screens=False, plans=None):
    """An L-deep stack of (to x ti) tile-argument grids."""
    return tuple(
        _make_tiles(n, to, ti, seed=seed + 101 * l, screens=screens,
                    plans=plans[l] if plans is not None else None)
        for l in range(depth))


def _per_layer(layers, x, n, *, plans=None, readout="magnitude"):
    """The unfused oracle: L separate tile-grid megakernel calls with
    power detection between layers in plain JAX."""
    y = x
    last = len(layers) - 1
    for l, tiles in enumerate(layers):
        pl = plans[l] if plans is not None else None
        y = ops.tiled_apply(tiles, y, n=n, plans=pl)
        if l < last or readout == "magnitude":
            y = jnp.abs(y)
    return y


def _rand_x(n, batch, seed=0):
    k = jax.random.PRNGKey(seed)
    xr = jax.random.normal(k, (batch, n))
    xi = jax.random.normal(jax.random.fold_in(k, 1), (batch, n))
    return (xr + 1j * xi).astype(jnp.complex64)


def _max_rel_err(got, want):
    scale = max(float(jnp.max(jnp.abs(g))) for g in jax.tree.leaves(want))
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)))
    return err / (scale + 1e-30)


# ---------------------------------------------------------------------------
# property-based differential: deep megakernel vs per-layer composition
# ---------------------------------------------------------------------------

@settings(max_examples=4, deadline=None)
@given(depth=st.integers(1, 3), g=st.integers(1, 2),
       tile=st.sampled_from([2, 4]), seed=st.integers(0, 10_000),
       screens=st.booleans())
def test_deepgrid_matches_per_layer_fwd_and_vjp(depth, g, tile, seed,
                                                screens):
    """Random depth / grid shapes / tile sizes / screens: the single-launch
    deep kernel must match the per-layer tiled_apply composition (detect
    between layers) to <= 1e-5 relative, forward and full VJP.

    Sizes are deliberately small: every example compiles a fresh fused
    L-layer backward, and this property runs on the CI fast leg."""
    if depth == 3 and g == 2:
        g = 1  # cap the deepest example's grid (runtime, CI fast leg)
    layers = _make_deep(tile, depth, g, g, seed=seed, screens=screens)
    x = _rand_x(g * tile, 5, seed=seed + 1)
    y_pl = _per_layer(layers, x, tile)
    y_k = ops.deep_apply(layers, x, n=tile)
    assert y_k.shape == (5, g * tile)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_pl),
                               atol=REL_TOL * 10 * max(1.0, g))

    w = 1.0 + jnp.arange(g * tile, dtype=jnp.float32)  # break degeneracies

    def loss_k(ls, xx):
        return jnp.sum(ops.deep_apply(ls, xx, n=tile) * w)

    def loss_pl(ls, xx):
        return jnp.sum(_per_layer(ls, xx, tile) * w)

    g_k = jax.jit(jax.grad(loss_k, argnums=(0, 1)))(layers, x)
    g_pl = jax.jit(jax.grad(loss_pl, argnums=(0, 1)))(layers, x)
    assert _max_rel_err(g_k, g_pl) <= REL_TOL


def test_deepgrid_mixed_reck_plans_identity_padding():
    """Reck tiles are deeper than Clements ones: a mixed deep stack
    exercises the network-wide identity-column padding, which must be an
    exact no-op in forward AND contribute exactly zero parameter grad."""
    n, depth, g = 4, 2, 2
    rplan, rparams = decompose.reck_program(
        decompose.random_unitary(n, seed=3))
    plans = (((None, (rplan, None)), (None, None)),
             (((None, rplan), None), (None, None)))
    layers = [[
        list(r) for r in _make_tiles(n, g, g, seed=5 + l, plans=plans[l])]
        for l in range(depth)]
    layers[0][0][1] = dict(layers[0][0][1], v=dict(rparams))
    layers[1][0][0] = dict(layers[1][0][0], u=dict(rparams))
    layers = tuple(tuple(tuple(r) for r in la) for la in layers)
    x = _rand_x(g * n, 6)
    y_pl = _per_layer(layers, x, n, plans=plans)
    y_k = ops.deep_apply(layers, x, n=n, plans=plans)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_pl), atol=1e-4)
    deep = deep_grid_schedule(n, depth, g, g, plans)
    assert deep.n_columns > deep.layers[0][1][1][0].n_columns  # padding used

    w = 1.0 + jnp.arange(g * n, dtype=jnp.float32)
    g_k = jax.grad(lambda ls: jnp.sum(
        ops.deep_apply(ls, x, n=n, plans=plans) * w))(layers)
    g_pl = jax.grad(lambda ls: jnp.sum(
        _per_layer(ls, x, n, plans=plans) * w))(layers)
    assert _max_rel_err(g_k, g_pl) <= REL_TOL


# ---------------------------------------------------------------------------
# ragged batches
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("batch", [1, 7, 130])
def test_deepgrid_ragged_batches(batch):
    """B need not divide the batch block: the tail block's zero-padded
    rows must stay exactly zero through every in-kernel detection (the
    zero-guarded |z| pullback) in forward and VJP."""
    n, depth, g = 4, 2, 2
    layers = _make_deep(n, depth, g, g, seed=2)
    x = _rand_x(g * n, batch)
    y_pl = _per_layer(layers, x, n)
    y_k = ops.deep_apply(layers, x, n=n, block_b=64)
    assert y_k.shape == (batch, g * n)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_pl), atol=1e-5)

    w = 1.0 + jnp.arange(g * n, dtype=jnp.float32)
    g_k = jax.grad(lambda ls: jnp.sum(
        ops.deep_apply(ls, x, n=n, block_b=64) * w))(layers)
    g_pl = jax.grad(lambda ls: jnp.sum(
        jnp.abs(_per_layer(ls, x, n)) * w))(layers)
    assert all(bool(jnp.all(jnp.isfinite(l))) for l in jax.tree.leaves(g_k))
    assert _max_rel_err(g_k, g_pl) <= REL_TOL


# ---------------------------------------------------------------------------
# degenerate wrappers: tiled_apply (L=1) and rfnn_network (To=Ti=1)
# ---------------------------------------------------------------------------

def test_deepgrid_degenerate_single_layer_is_tiled_apply():
    """L=1 with complex readout must be BIT-identical to the tiled_apply
    wrapper — same kernel, same op order."""
    n, to, ti = 4, 2, 3
    tiles = _make_tiles(n, to, ti, seed=4)
    x = _rand_x(ti * n, 5)
    y_t = ops.tiled_apply(tiles, x, n=n)
    y_d = ops.deep_apply((tiles,), x, n=n, readout="complex")
    np.testing.assert_array_equal(np.asarray(y_t), np.asarray(y_d))


def test_deepgrid_degenerate_network_is_rfnn_network():
    """To=Ti=1 deep stack with magnitude readout must be BIT-identical to
    the rfnn_network wrapper."""
    n, depth = 6, 3
    layers1d = tuple(_make_tiles(n, 1, 1, seed=20 + l)[0][0]
                     for l in range(depth))
    nested = tuple(((la,),) for la in layers1d)
    x = _rand_x(n, 5)
    y_net = ops.rfnn_network(layers1d, x, n=n)
    y_deep = ops.deep_apply(nested, x, n=n, readout="magnitude")
    np.testing.assert_array_equal(np.asarray(y_net), np.asarray(y_deep))


# ---------------------------------------------------------------------------
# memoization: schedule lowering + trace cache + pack cache + kernel path
# ---------------------------------------------------------------------------

def test_deepgrid_schedule_memoized_no_retrace():
    """Structurally equal deep stacks (fresh objects every call) must not
    re-trigger a jit trace of the kernel impl."""
    n, depth, g = 4, 2, 2
    layers = _make_deep(n, depth, g, g)
    x = _rand_x(g * n, 4)
    ops.deep_apply(layers, x, n=n)
    before = ops.TRACE_COUNTS["deep_apply"]
    ops.deep_apply(layers, x, n=n)  # fresh schedule build, equal content
    assert ops.TRACE_COUNTS["deep_apply"] == before  # no retrace


def test_deepgrid_pack_cache_single_pack_event():
    """Same (immutable) tile arrays -> exactly one PACK_EVENT ever; new
    arrays -> exactly one more.  The kernel path is actually taken."""
    n, depth, g = 4, 2, 2
    layers = _make_deep(n, depth, g, g, seed=9)
    x = _rand_x(g * n, 4)
    calls = ops.KERNEL_PATH_CALLS["deep_apply"]
    packs = ops.PACK_EVENTS["deep_apply"]
    ops.deep_apply(layers, x, n=n)  # populate (exactly one pack)
    assert ops.KERNEL_PATH_CALLS["deep_apply"] == calls + 1
    assert ops.PACK_EVENTS["deep_apply"] == packs + 1
    for _ in range(5):
        ops.deep_apply(layers, x, n=n)
    assert ops.PACK_EVENTS["deep_apply"] == packs + 1  # steady state

    bumped = ((((dict(layers[0][0][0], atten=layers[0][0][0]["atten"] + .01),)
                + layers[0][0][1:],) + layers[0][1:]),) + layers[1:]
    ops.deep_apply(bumped, x, n=n)
    assert ops.PACK_EVENTS["deep_apply"] == packs + 2


# ---------------------------------------------------------------------------
# lower_deep: the compile path — placements, parked tiles, serving
# ---------------------------------------------------------------------------

def _deep_progs(ws, tile, *, method="reck"):
    from repro import compile as comp
    return [comp.program_tiled(comp.synthesize_tiled(w, tile), method=method)
            for w in ws]


def test_lower_deep_matches_per_layer_compiled_apply():
    """lower_deep(...).apply == the composition of per-layer lower_tiled
    programs, placements and calibration draws included (the interior
    boundary resolves by pack-time column re-ordering)."""
    from repro import compile as comp
    from repro.paper.prototype import PROTOTYPE

    rng = np.random.default_rng(1)
    tile, depth, d = 4, 3, 8
    ws = [rng.normal(size=(d, d)).astype(np.float32) * 0.4
          for _ in range(depth)]
    key = jax.random.PRNGKey(3)
    perms = [((1, 0), (0, 1)), ((0, 1), (1, 0)), ((1, 0), (1, 0))]
    tps = []
    for l, w in enumerate(ws):
        tp = _deep_progs([w], tile)[0]
        tp = comp.quantize_tiled(tp, "table1")
        tp = comp.apply_placement(tp, comp.TilePlacement(*perms[l]))
        tp = comp.calibrate_tiled(tp, PROTOTYPE,
                                  key=jax.random.fold_in(key, l))
        tps.append(tp)
    cd = comp.lower_deep(tps)
    x = jnp.asarray(rng.normal(size=(5, d)).astype(np.float32))
    y_deep = cd.apply(x)
    y = x
    for tp in tps:
        y = comp.lower_tiled(tp).apply(y)
    np.testing.assert_allclose(np.asarray(y_deep), np.asarray(y),
                               atol=1e-5 * float(jnp.max(jnp.abs(y))))


def test_lower_deep_rejects_non_chaining_layers():
    from repro import compile as comp
    rng = np.random.default_rng(2)
    a = _deep_progs([rng.normal(size=(8, 8)).astype(np.float32)], 4)[0]
    b = _deep_progs([rng.normal(size=(12, 12)).astype(np.float32)], 4)[0]
    with pytest.raises(ValueError, match="does not chain"):
        comp.lower_deep([a, b])


def test_deepgrid_blank_tile_parked_zero_grad():
    """A parked (blank) tile inside a deep program: finite everywhere and
    EXACTLY zero gradient into the parked tile's mesh/attenuation
    parameters — scale==0 kills its contribution and the zero-guarded
    detection pullback keeps the zero exact instead of NaN."""
    from repro import compile as comp

    rng = np.random.default_rng(7)
    tile, depth, d = 4, 2, 8
    ws = [rng.normal(size=(d, d)).astype(np.float32) * 0.5
          for _ in range(depth)]
    tps = _deep_progs(ws, tile)
    grid = [list(r) for r in tps[1].grid]
    grid[0][1] = comp.blank_tile(grid[0][1])  # park one interior tile
    tps[1] = dataclasses.replace(tps[1], grid=tuple(tuple(r) for r in grid))
    cd = comp.lower_deep(tps)
    # ragged batch + a zero input row: padding and parked paths together
    x = jnp.asarray(rng.normal(size=(3, d)).astype(np.float32))
    x = x.at[1].set(0.0)
    assert bool(jnp.all(jnp.isfinite(cd.apply(x))))

    w = 1.0 + jnp.arange(d, dtype=jnp.float32)

    def loss(layer_args, xx):
        return jnp.sum(ops.deep_apply(layer_args, xx, n=tile,
                                      plans=cd.plans, block_b=8) * w)

    g_args, g_x = jax.jit(jax.grad(loss, argnums=(0, 1)))(cd.layer_args, x)
    assert all(bool(jnp.all(jnp.isfinite(le)))
               for le in jax.tree.leaves((g_args, g_x)))
    parked = g_args[1][0][1]
    for name in ("v", "u", "atten"):
        for leaf in jax.tree.leaves(parked[name]):
            np.testing.assert_array_equal(np.asarray(leaf), 0.0)


def test_engine_serves_compiled_deep_program():
    """params=None serving of a CompiledDeepProgram: tensors were emitted
    at lower_deep time, so NO tick — the first included — packs."""
    from repro import compile as comp
    from repro.serving import Request, ServingEngine

    rng = np.random.default_rng(11)
    tile, d = 4, 8
    ws = [rng.normal(size=(d, d)) / np.sqrt(d) for _ in range(2)]
    cd = comp.lower_deep(_deep_progs(ws, tile))
    engine = ServingEngine(cd, slots=3)
    packs = ops.PACK_EVENTS["deep_apply"]
    feats = rng.normal(size=(5, d)).astype(np.float32)
    reqs = [Request(rid=i, features=feats[i]) for i in range(5)]
    for r in reqs:
        engine.submit(r)
    engine.run()
    assert all(r.done for r in reqs)
    want = np.abs(np.abs(feats @ ws[0].T) @ ws[1].T)
    for r in reqs:
        np.testing.assert_allclose(r.result, want[r.rid], atol=1e-4)
    assert ops.PACK_EVENTS["deep_apply"] == packs  # zero, first tick incl.


# ---------------------------------------------------------------------------
# shard_map scale-out of the deep kernel (subprocess: forced 8-device host)
# ---------------------------------------------------------------------------

_SHARDED_PROGRAM = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import mesh as mesh_lib
from repro.kernels import ops

rng = np.random.default_rng(0)
n, g, depth, b = 4, 2, 2, 10        # ragged batch
plan = mesh_lib.clements_plan(n)
layers = []
for l in range(depth):
    rows = []
    for o in range(g):
        trow = []
        for i in range(g):
            kv, ku, ka = jax.random.split(jax.random.fold_in(
                jax.random.PRNGKey(7), (l * g + o) * g + i), 3)
            trow.append({
                "v": mesh_lib.init_mesh_params(kv, plan),
                "u": mesh_lib.init_mesh_params(ku, plan),
                "atten": jax.random.uniform(ka, (n,), minval=0.2,
                                            maxval=0.9),
                "scale": 1.0 + 0.05 * (o + i + l),
            })
        rows.append(tuple(trow))
    layers.append(tuple(rows))
layers = tuple(layers)
x = jnp.asarray(rng.normal(size=(b, g * n)).astype(np.float32))
w = jnp.asarray(rng.normal(size=(b, g * n)).astype(np.float32))


def loss(layers, x, mesh=None):
    return jnp.sum(ops.deep_apply(layers, x, n=n, mesh=mesh) * w)


y_ref = np.asarray(ops.deep_apply(layers, x, n=n))
g_ref = jax.grad(loss, argnums=(0, 1))(layers, x)

for shape in [(2, 4), (1, 8)]:
    nr, nd = shape
    mesh = Mesh(np.array(jax.devices()[: nr * nd]).reshape(nr, nd),
                ("rows", "data"))
    y_sh = np.asarray(ops.deep_apply(layers, x, n=n, mesh=mesh))
    rel = np.abs(y_sh - y_ref).max() / np.abs(y_ref).max()
    assert rel <= 1e-5, f"fwd {shape}: rel={rel}"
    g_sh = jax.grad(loss, argnums=(0, 1))(layers, x, mesh=mesh)
    for a, bb in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_sh)):
        a, bb = np.asarray(a), np.asarray(bb)
        rel = np.abs(a - bb).max() / max(np.abs(a).max(), 1e-12)
        assert rel <= 1e-5, f"grad {shape}: rel={rel}"

# the training-step shape: enclosing jit over raw tiles (packing traced)
mesh = Mesh(np.array(jax.devices()[:8]).reshape(2, 4), ("rows", "data"))
g_jit = jax.jit(jax.grad(lambda ls, xx: loss(ls, xx, mesh=mesh),
                         argnums=(0, 1)))(layers, x)
for a, bb in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_jit)):
    a, bb = np.asarray(a), np.asarray(bb)
    rel = np.abs(a - bb).max() / max(np.abs(a).max(), 1e-12)
    assert rel <= 1e-5, f"jit(grad) rel={rel}"

assert ops.KERNEL_PATH_CALLS["deep_apply_sharded"] > 0
print("DEEP_SHARDED_OK")
"""


@pytest.mark.slow
def test_sharded_deep_apply_matches_single_device():
    r = subprocess.run([sys.executable, "-c", _SHARDED_PROGRAM],
                       capture_output=True, text=True, timeout=900,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert "DEEP_SHARDED_OK" in r.stdout, r.stdout + r.stderr
