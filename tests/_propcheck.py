"""Offline-safe property-testing shim with a hypothesis-compatible surface.

The suite's property tests use ``given``/``settings``/``strategies``.  When
the real `hypothesis` package is installed it is used unchanged; otherwise
this module provides a tiny drop-in backed by seeded ``numpy.random`` so the
suite collects and runs in a fully offline container (no pip installs).

The shim draws ``max_examples`` pseudo-random examples per test with a seed
derived from the test name, so runs are deterministic and failures are
reproducible; on failure the falsifying example is included in the error.
Only the strategy surface this repo uses is implemented: ``integers``,
``floats``, ``sampled_from`` and ``booleans``.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings, strategies  # noqa: F401

    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

    import zlib

    import numpy as np

    _DEFAULT_MAX_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self._draw = draw

        def example(self, rng):
            return self._draw(rng)

    class _Strategies:
        """The subset of ``hypothesis.strategies`` the suite uses."""

        @staticmethod
        def integers(min_value=0, max_value=2**31 - 1):
            return _Strategy(
                lambda rng: int(rng.integers(min_value, max_value + 1)))

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, allow_nan=False,
                   allow_infinity=False, **_):
            return _Strategy(
                lambda rng: float(rng.uniform(min_value, max_value)))

        @staticmethod
        def sampled_from(elements):
            items = list(elements)
            return _Strategy(
                lambda rng: items[int(rng.integers(0, len(items)))])

        @staticmethod
        def booleans():
            return _Strategy(lambda rng: bool(rng.integers(0, 2)))

    strategies = _Strategies()

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, deadline=None, **_):
        """Decorator recording the example budget on the ``given`` runner."""

        def deco(fn):
            fn._propcheck_max_examples = max_examples
            return fn

        return deco

    def given(**strats):
        """Run the test over seeded pseudo-random draws of each strategy."""

        def deco(fn):
            def runner():
                n = getattr(runner, "_propcheck_max_examples",
                            _DEFAULT_MAX_EXAMPLES)
                rng = np.random.default_rng(
                    zlib.crc32(fn.__name__.encode("utf-8")))
                for i in range(n):
                    kwargs = {k: s.example(rng) for k, s in strats.items()}
                    try:
                        fn(**kwargs)
                    except Exception as exc:
                        raise AssertionError(
                            f"{fn.__name__} falsified on example {i}: "
                            f"{kwargs!r}") from exc

            # plain attribute copies: functools.wraps would leak the wrapped
            # signature and make pytest treat the draws as fixtures
            runner.__name__ = fn.__name__
            runner.__qualname__ = getattr(fn, "__qualname__", fn.__name__)
            runner.__doc__ = fn.__doc__
            runner.__module__ = fn.__module__
            return runner

        return deco
