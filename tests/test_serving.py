"""Serving-engine tests: both request families through one slot loop."""

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import Model
from repro.serving import Request, ServingEngine

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def engine():
    cfg = configs.get_reduced("tinyllama-1.1b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _reqs(cfg, n, seed=0, max_new=6):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=4)
                    .astype(np.int32),
                    max_new=max_new + i % 3) for i in range(n)]


def test_engine_drains_more_requests_than_slots(engine):
    cfg, model, params = engine
    eng = ServingEngine(model, params, slots=3, max_len=48)
    reqs = _reqs(cfg, 7)
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    assert all(len(r.output) == r.max_new for r in reqs)
    assert all(r.result is not None and len(r.result) == r.max_new
               for r in reqs)


def test_engine_no_head_of_line_blocking(engine):
    """A long generation must not stall short ones: slots free immediately."""
    cfg, model, params = engine
    eng = ServingEngine(model, params, slots=2, max_len=64)
    long_req = _reqs(cfg, 1, seed=1, max_new=20)[0]
    shorts = _reqs(cfg, 4, seed=2, max_new=3)
    eng.submit(long_req)
    for r in shorts:
        eng.submit(r)
    ticks = 0
    while any(not r.done for r in [long_req] + shorts):
        eng.tick()
        ticks += 1
        assert ticks < 200
    # all shorts completed well before the worst case of serial slots
    assert all(len(r.output) == r.max_new for r in shorts)


def test_engine_eos_stops_generation(engine):
    cfg, model, params = engine
    eng = ServingEngine(model, params, slots=1, max_len=48)
    req = _reqs(cfg, 1)[0]
    req.max_new = 10
    eng.submit(req)
    eng.run()
    first = req.output[0]
    # eos = the greedily decoded first token, so a rerun stops at 1 token
    req2 = Request(rid=99, prompt=req.prompt, max_new=10, eos_id=first)
    eng2 = ServingEngine(model, params, slots=1, max_len=48)
    eng2.submit(req2)
    eng2.run()
    assert req2.done and len(req2.output) == 1  # stopped at eos


# ---------------------------------------------------------------------------
# analog serving: fixed-slot ticks through the network megakernel
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def analog_engine():
    from repro.core.analog_linear import AnalogSequence

    n, depth = 8, 2
    ref_m = AnalogSequence(n=n, depth=depth, backend="reference")
    pal_m = AnalogSequence(n=n, depth=depth, backend="pallas")
    params = ref_m.init(jax.random.PRNGKey(0))
    return n, ref_m, pal_m, params


def _analog_reqs(n, count, seed=0):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, features=rng.normal(size=n).astype(np.float32))
            for i in range(count)]


def test_analog_engine_pallas_matches_reference(analog_engine):
    """Tick-loop smoke: pallas ticks == reference ticks, and the kernel
    path is actually taken (KERNEL_PATH_CALLS increments)."""
    from repro.kernels import ops

    n, ref_m, pal_m, params = analog_engine
    reqs_r = _analog_reqs(n, 7)
    reqs_p = _analog_reqs(n, 7)
    e_ref = ServingEngine(ref_m, params, slots=3)
    e_pal = ServingEngine(pal_m, params, slots=3)
    for r in reqs_r:
        e_ref.submit(r)
    for r in reqs_p:
        e_pal.submit(r)
    calls_before = ops.KERNEL_PATH_CALLS["rfnn_network"]
    e_ref.run()
    e_pal.run()
    assert ops.KERNEL_PATH_CALLS["rfnn_network"] > calls_before
    assert all(r.done for r in reqs_r) and all(r.done for r in reqs_p)
    for rr, rp in zip(reqs_r, reqs_p):
        np.testing.assert_allclose(rp.result, rr.result, atol=1e-5)


def test_analog_engine_steady_state_no_repacking(analog_engine):
    """Params don't change between ticks, so after the first tick the
    coefficient-pack cache must absorb all packing work."""
    from repro.kernels import ops

    n, _, pal_m, params = analog_engine
    eng = ServingEngine(pal_m, params, slots=4)
    reqs = _analog_reqs(n, 4, seed=1)
    for r in reqs:
        eng.submit(r)
    eng.run()  # first tick may pack (cold cache)
    packs = ops.PACK_EVENTS["rfnn_network"]
    for tick in range(3):
        more = _analog_reqs(n, 9, seed=2 + tick)
        for r in more:
            eng.submit(r)
        eng.run()
        assert all(r.done for r in more)
    assert ops.PACK_EVENTS["rfnn_network"] == packs  # zero packing work


# ---------------------------------------------------------------------------
# analog serving: tile-grid programs (TiledAnalogLinear + compiled)
# ---------------------------------------------------------------------------

def test_analog_engine_tiled_pallas_steady_state():
    """Serving a TiledAnalogLinear(backend="pallas"): every tick is one
    tile-grid megakernel call and steady-state ticks do zero packing."""
    from repro.core.analog_linear import TiledAnalogLinear
    from repro.kernels import ops

    ref_m = TiledAnalogLinear(in_dim=8, out_dim=8, tile_size=4,
                              output="real", backend="reference")
    pal_m = TiledAnalogLinear(in_dim=8, out_dim=8, tile_size=4,
                              output="real", backend="pallas")
    params = ref_m.init(jax.random.PRNGKey(5))
    e_ref = ServingEngine(ref_m, params, slots=3)
    e_pal = ServingEngine(pal_m, params, slots=3)
    reqs_r = _analog_reqs(8, 7, seed=3)
    reqs_p = _analog_reqs(8, 7, seed=3)
    for r in reqs_r:
        e_ref.submit(r)
    for r in reqs_p:
        e_pal.submit(r)
    calls = ops.KERNEL_PATH_CALLS["tiled_apply"]
    e_ref.run()
    e_pal.run()
    assert ops.KERNEL_PATH_CALLS["tiled_apply"] > calls  # kernel path taken
    for rr, rp in zip(reqs_r, reqs_p):
        np.testing.assert_allclose(rp.result, rr.result, atol=1e-5)
    # steady state: params unchanged between ticks -> zero packing work
    packs = ops.PACK_EVENTS["tiled_apply"]
    for tick in range(3):
        more = _analog_reqs(8, 5, seed=4 + tick)
        for r in more:
            e_pal.submit(r)
        e_pal.run()
        assert all(r.done for r in more)
    assert ops.PACK_EVENTS["tiled_apply"] == packs


def test_engine_serves_compiled_tiled_program():
    """Serving a CompiledTiledProgram: megakernel tensors were emitted at
    lower_tiled time, so NO tick — the first included — does any packing
    work."""
    from repro import compile as compile_mod
    from repro.kernels import ops

    w = np.random.default_rng(11).normal(size=(8, 8)) / np.sqrt(8)
    comp = compile_mod.lower_tiled(compile_mod.program_tiled(
        compile_mod.synthesize_tiled(w, tile=4), method="reck"))
    eng = ServingEngine(comp, slots=3)
    packs = ops.PACK_EVENTS["tiled_apply"]
    reqs = _analog_reqs(8, 5, seed=6)
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    for r in reqs:
        np.testing.assert_allclose(r.result, np.abs(r.features @ w.T),
                                   atol=1e-4)
    assert ops.PACK_EVENTS["tiled_apply"] == packs  # zero, first tick incl.


# ---------------------------------------------------------------------------
# fault tolerance: deadlines + mid-stream tile recovery
# ---------------------------------------------------------------------------

def _tiled_classifier(seed=12):
    """An 8x8 compiled tiled program whose mass lives entirely in logical
    tile row 0 (output rows 4..7 are zero) — recoverable from a row kill."""
    from repro import compile as compile_mod

    rng = np.random.default_rng(seed)
    w = np.zeros((8, 8), np.float32)
    w[:4] = rng.normal(size=(4, 8)).astype(np.float32) / np.sqrt(8)
    tp = compile_mod.program_tiled(
        compile_mod.synthesize_tiled(w, tile=4), method="reck")
    return w, tp, compile_mod.lower_tiled(tp)


def test_engine_deadline_expires_queued_requests():
    """slots=1 with a 2-tick deadline: the head of the queue serves, the
    tail completes as failed instead of waiting forever."""
    _, _, comp = _tiled_classifier()
    eng = ServingEngine(comp, slots=1)
    reqs = [Request(rid=i, features=np.ones(8, np.float32),
                    deadline_ticks=2) for i in range(5)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert all(r.done for r in reqs)
    served = [r for r in reqs if r.result is not None]
    expired = [r for r in reqs if r.failed]
    assert len(served) == 2 and len(expired) == 3
    assert eng.stats["served"] == 2
    assert eng.stats["expired"] == 3


def test_engine_recovers_from_midstream_tile_failure():
    """A tile row dies between ticks; the engine swaps in the recovered
    program and every in-flight request still completes with the correct
    result (acceptance: serving survives a mid-stream tile failure)."""
    from repro import compile as compile_mod
    from repro.runtime import (FailureInjector, plan_tile_recovery,
                               tile_row_failures)

    w, tp, comp = _tiled_classifier()

    def recovery(dead):
        plan = plan_tile_recovery(compile_mod.tile_sensitivities(tp), dead)
        assert plan.viable
        return compile_mod.recover_tiled(tp, plan, None, steps=0)

    inj = FailureInjector(schedule=tile_row_failures(step=2, row=0, ti=tp.ti))
    eng = ServingEngine(comp, slots=2, failure_injector=inj,
                        recovery=recovery)
    rng = np.random.default_rng(3)
    reqs = [Request(rid=i, features=rng.normal(size=8).astype(np.float32))
            for i in range(8)]
    for r in reqs:
        eng.submit(r)
    eng.run()

    # the failure fired and was recovered exactly once, mid-stream
    assert inj.dead_tiles == {(0, 0), (0, 1)}
    assert eng.stats["recovered"] == 1
    assert eng.events == [{"tick": 2, "kind": "tile_recovery",
                           "dead_tiles": ((0, 0), (0, 1))}]
    # every request completed, and requests served both before AND after
    # the swap carry the correct result (the remap parked the zero rows
    # on the dead positions, so the realized matrix survives the kill)
    assert all(r.done and not r.failed for r in reqs)
    assert eng.stats["served"] == len(reqs)
    for r in reqs:
        np.testing.assert_allclose(r.result, np.abs(r.features @ w.T),
                                   atol=1e-4)


def test_engine_recovers_via_program_recover():
    """No recovery= callable: the engine falls back to the servable's own
    recover() — the CompiledTiledProgram re-places/re-lowers itself."""
    from repro.runtime import FailureInjector, tile_row_failures

    w, tp, comp = _tiled_classifier()
    inj = FailureInjector(schedule=tile_row_failures(step=2, row=0, ti=tp.ti))
    eng = ServingEngine(comp, slots=2, failure_injector=inj)
    rng = np.random.default_rng(3)
    reqs = [Request(rid=i, features=rng.normal(size=8).astype(np.float32))
            for i in range(8)]
    for r in reqs:
        eng.submit(r)
    eng.run()
    assert eng.stats["recovered"] == 1
    assert eng.events == [{"tick": 2, "kind": "tile_recovery",
                           "dead_tiles": ((0, 0), (0, 1))}]
    assert all(r.done and not r.failed for r in reqs)
    for r in reqs:
        np.testing.assert_allclose(r.result, np.abs(r.features @ w.T),
                                   atol=1e-4)
