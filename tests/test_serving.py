"""Continuous-batching serving runtime tests."""

import jax
import numpy as np
import pytest

from repro import configs
from repro.models import Model
from repro.serving import ContinuousBatcher, Request

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(scope="module")
def engine():
    cfg = configs.get_reduced("tinyllama-1.1b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    return cfg, model, params


def _reqs(cfg, n, seed=0, max_new=6):
    rng = np.random.default_rng(seed)
    return [Request(rid=i,
                    prompt=rng.integers(0, cfg.vocab_size, size=4)
                    .astype(np.int32),
                    max_new=max_new + i % 3) for i in range(n)]


def test_batcher_drains_more_requests_than_slots(engine):
    cfg, model, params = engine
    b = ContinuousBatcher(model, params, slots=3, max_len=48)
    reqs = _reqs(cfg, 7)
    for r in reqs:
        b.submit(r)
    b.run()
    assert all(r.done for r in reqs)
    assert all(len(r.output) == r.max_new for r in reqs)


def test_batcher_no_head_of_line_blocking(engine):
    """A long generation must not stall short ones: slots free immediately."""
    cfg, model, params = engine
    b = ContinuousBatcher(model, params, slots=2, max_len=64)
    long_req = _reqs(cfg, 1, seed=1, max_new=20)[0]
    shorts = _reqs(cfg, 4, seed=2, max_new=3)
    b.submit(long_req)
    for r in shorts:
        b.submit(r)
    ticks = 0
    while any(not r.done for r in [long_req] + shorts):
        b.tick()
        ticks += 1
        assert ticks < 200
    # all shorts completed well before the worst case of serial slots
    assert all(len(r.output) == r.max_new for r in shorts)


def test_batcher_eos_stops_generation(engine):
    cfg, model, params = engine
    b = ContinuousBatcher(model, params, slots=1, max_len=48)
    # eos = every token (greedy argmax is in-vocab), so stops at 1 token
    req = _reqs(cfg, 1)[0]
    req.max_new = 10

    b.submit(req)
    b._admit()
    # force eos on the first decoded token
    n = b.tick()
    first = req.output[0]
    assert len(req.output) == 1 or n >= 0  # engine ran
    req2 = Request(rid=99, prompt=req.prompt, max_new=10, eos_id=first)
    b2 = ContinuousBatcher(model, params, slots=1, max_len=48)
    b2.submit(req2)
    b2.run()
    assert req2.done and len(req2.output) == 1  # stopped at eos
