"""Checkpoint codec coverage: zstd when available, zlib always.

``repro.checkpoint`` must import and roundtrip without the optional
``zstandard`` package (offline container); shards carry a codec header so
restore dispatches on what was actually written.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.checkpoint import manager as manager_lib

jax.config.update("jax_platform_name", "cpu")


def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 3)),
            "b": {"c": jnp.arange(5, dtype=jnp.int32)}}


def _roundtrip(tmp_path, codec):
    mgr = CheckpointManager(tmp_path, codec=codec)
    tree = _tree()
    mgr.save(7, tree, data_step=42)
    restored, meta = mgr.restore(None, like=jax.tree.map(jnp.zeros_like, tree))
    assert meta == {"step": 7, "data_step": 42}
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), tree, restored)
    return mgr


@pytest.mark.parametrize("codec", ["zlib", "zstd"])
def test_roundtrip_each_codec(tmp_path, codec):
    if codec == "zstd" and manager_lib.zstandard is None:
        pytest.skip("zstandard not installed in this environment")
    _roundtrip(tmp_path, codec)


def test_default_codec_roundtrips_without_zstandard(tmp_path):
    """The default codec always works: zstd if installed, else zlib."""
    mgr = _roundtrip(tmp_path, None)
    expected = "zstd" if manager_lib.zstandard is not None else "zlib"
    assert mgr.codec == expected


def test_shard_header_records_codec(tmp_path):
    mgr = CheckpointManager(tmp_path, codec="zlib")
    mgr.save(1, _tree())
    blob = (mgr._step_dir(1) / "host_000.ckpt").read_bytes()
    assert blob[:4] == manager_lib._MAGIC
    assert blob[4:8].rstrip(b"\0") == b"zlib"


def test_legacy_zst_suffix_still_restores(tmp_path):
    """Pre-rename checkpoints stored shards as host_NNN.zst."""
    mgr = CheckpointManager(tmp_path, codec="zlib")
    tree = _tree(5)
    mgr.save(2, tree)
    d = mgr._step_dir(2)
    (d / "host_000.ckpt").rename(d / "host_000.zst")
    restored, _ = mgr.restore(2, like=jax.tree.map(jnp.zeros_like, tree))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), tree, restored)


def test_zstd_without_library_fails_fast():
    """Explicit codec='zstd' on a host without zstandard must fail at
    construction, not silently inside save_async's worker thread."""
    if manager_lib.zstandard is not None:
        pytest.skip("zstandard installed; the fail-fast path is inert")
    with pytest.raises(RuntimeError, match="zstandard"):
        CheckpointManager("/tmp/unused-ckpt-dir", codec="zstd")


def test_manifest_records_codec(tmp_path):
    import json
    mgr = CheckpointManager(tmp_path, codec="zlib")
    mgr.save(1, _tree())
    manifest = json.loads((mgr._step_dir(1) / "manifest.json").read_text())
    assert manifest["codec"] == "zlib"


def test_cross_codec_restore(tmp_path):
    """A shard written with zlib restores through a default-codec manager
    (the header, not the manager setting, selects the decompressor)."""
    tree = _tree(3)
    CheckpointManager(tmp_path, codec="zlib").save(5, tree)
    restored, _ = CheckpointManager(tmp_path).restore(
        5, like=jax.tree.map(jnp.zeros_like, tree))
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), tree, restored)


def test_unknown_codec_rejected(tmp_path):
    with pytest.raises(ValueError, match="codec"):
        CheckpointManager(tmp_path, codec="lz4")
