"""Yield-aware tile placement: permutation algebra, scoring, and the
placed compiled program's digital gather correctness."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compile import (
    TilePlacement,
    apply_placement,
    lower_tiled,
    plan_placement,
    position_yield_scores,
    program_tiled,
    synthesize_tiled,
    tile_sensitivities,
    undo_placement,
)
from repro.runtime import plan_tile_recovery

jax.config.update("jax_platform_name", "cpu")


def _programmed(w, tile=4):
    return program_tiled(synthesize_tiled(w, tile=tile), method="reck")


# ---------------------------------------------------------------------------
# TilePlacement algebra
# ---------------------------------------------------------------------------

def test_placement_identity_and_inverse():
    pl = TilePlacement.identity(3, 4)
    assert pl.is_identity
    pl = TilePlacement((2, 0, 1), (1, 0))
    assert not pl.is_identity
    # inv[r] = physical row hosting logical r
    assert pl.inv_row_perm == (1, 2, 0)
    assert pl.inv_col_perm == (1, 0)
    for r in range(3):
        assert pl.row_perm[pl.inv_row_perm[r]] == r


def test_placement_rejects_non_permutation():
    with pytest.raises(ValueError):
        TilePlacement((0, 0, 1), (0, 1))


def test_apply_undo_placement_roundtrip():
    rng = np.random.default_rng(0)
    tp = _programmed(rng.normal(size=(8, 12)).astype(np.float32))
    pl = TilePlacement((1, 0), (2, 0, 1))
    placed = apply_placement(tp, pl)
    assert placed.placement is pl
    # physical (po, pi) hosts logical (row_perm[po], col_perm[pi])
    for po in range(tp.to):
        for pi in range(tp.ti):
            assert placed.grid[po][pi] is tp.grid[pl.row_perm[po]][
                pl.col_perm[pi]]
    back = undo_placement(placed)
    assert back.placement is None
    for o in range(tp.to):
        for i in range(tp.ti):
            assert back.grid[o][i] is tp.grid[o][i]
    # double placement must be rejected (compose via undo first)
    with pytest.raises(ValueError):
        apply_placement(placed, pl)


def test_realized_matrix_is_placement_invariant():
    rng = np.random.default_rng(1)
    w = rng.normal(size=(8, 12)).astype(np.float32)
    tp = _programmed(w)
    placed = apply_placement(tp, TilePlacement((1, 0), (2, 0, 1)))
    np.testing.assert_allclose(placed.realized_matrix(),
                               tp.realized_matrix(), atol=1e-6)


# ---------------------------------------------------------------------------
# sensitivity + yield scoring + matching
# ---------------------------------------------------------------------------

def test_tile_sensitivities_zero_blocks_score_zero():
    w = np.zeros((8, 8), np.float32)
    w[:4, :4] = np.eye(4)           # only the top-left tile carries mass
    s = tile_sensitivities(_programmed(w))
    assert s[0, 0] > 0
    assert s[0, 1] == s[1, 0] == s[1, 1] == 0.0


def test_position_yield_scores_deterministic_and_keyed():
    from repro.paper.prototype import PROTOTYPE
    k = jax.random.PRNGKey(0)
    s1 = position_yield_scores(2, 3, PROTOTYPE, key=k, tile=4)
    s2 = position_yield_scores(2, 3, PROTOTYPE, key=k, tile=4)
    assert s1.shape == (2, 3)
    np.testing.assert_array_equal(s1, s2)
    assert (s1 <= 0).all()          # negated error: ideal would be 0
    s3 = position_yield_scores(2, 3, PROTOTYPE,
                               key=jax.random.PRNGKey(9), tile=4)
    assert not np.array_equal(s1, s3)   # different draws, different ranks


def test_plan_placement_matches_mass_to_yield():
    sens = np.array([[9.0, 9.0], [1.0, 1.0], [5.0, 5.0]])
    scores = np.array([[-0.5, -0.1], [-0.05, -0.4], [-0.3, -0.2]])
    pl = plan_placement(sens, scores)
    # best physical row (1) gets the most sensitive logical row (0),
    # worst physical row gets the least sensitive
    row_score = scores.sum(1)
    row_mass = sens.sum(1)
    best_phys = int(np.argmax(row_score))
    worst_phys = int(np.argmin(row_score))
    assert pl.row_perm[best_phys] == int(np.argmax(row_mass))
    assert pl.row_perm[worst_phys] == int(np.argmin(row_mass))


def test_plan_placement_uniform_grid_is_identity():
    sens = np.ones((2, 3))
    scores = np.full((2, 3), -0.1)
    assert plan_placement(sens, scores).is_identity


# ---------------------------------------------------------------------------
# placed compiled program: gathers undo the permutation digitally
# ---------------------------------------------------------------------------

def test_placed_compiled_apply_matches_unplaced():
    rng = np.random.default_rng(2)
    w = rng.normal(size=(10, 16)).astype(np.float32)
    tp = _programmed(w)
    comp = lower_tiled(tp)
    placed = apply_placement(tp, TilePlacement((2, 0, 1), (3, 1, 0, 2)))
    comp_p = lower_tiled(placed)
    x = jnp.asarray(rng.normal(size=(5, 16)).astype(np.float32))
    np.testing.assert_allclose(np.asarray(comp_p.apply(x)),
                               np.asarray(comp.apply(x)), atol=1e-5)
    # and both match the digital matmul magnitude
    ref = np.abs(np.asarray(x).astype(np.complex64) @ w.T)
    np.testing.assert_allclose(np.asarray(comp_p.apply(x)), ref, atol=1e-3)


# ---------------------------------------------------------------------------
# plan_tile_recovery: pure-data remap planning
# ---------------------------------------------------------------------------

def test_tile_recovery_parks_low_mass_rows_on_dead_row():
    sens = np.zeros((4, 4))
    sens[0] = 10.0                  # only logical row 0 matters
    plan = plan_tile_recovery(sens, [(0, i) for i in range(4)])
    assert plan.viable
    assert plan.dropped_mass == 0.0
    # logical row 0 moved off the dead physical row 0
    assert plan.row_perm[0] != 0
    assert 0 in plan.row_perm
    # columns untouched (no dead cells concentrated in any column beyond
    # the uniform row kill -> every column equally damaged -> stable keep)
    assert (0, 0) in plan.dead and len(plan.dead) == 4
    # live positions that changed host need recalibration; dead ones don't
    assert all(p not in plan.dead for p in plan.recalibrate)


def test_tile_recovery_nonviable_when_mass_must_die():
    sens = np.ones((2, 2))          # every tile carries equal mass
    plan = plan_tile_recovery(sens, [(0, 0)], max_dropped_mass=0.05)
    assert not plan.viable
    assert "sensitivity mass" in plan.reason
    # a quarter of the mass is parked dead no matter the permutation
    assert abs(plan.dropped_mass - 0.25) < 1e-12


def test_tile_recovery_respects_existing_placement():
    sens = np.zeros((3, 2))
    sens[1] = 5.0
    # grid already placed: physical row 0 hosts logical 2, etc.
    plan = plan_tile_recovery(sens, [(0, 0), (0, 1)],
                              row_perm=(2, 1, 0), col_perm=(1, 0))
    assert plan.viable
    # the dead physical row must not host logical row 1 (the mass)
    assert plan.row_perm[0] != 1
    # undamaged column axis keeps its current assignment
    assert plan.col_perm == (1, 0)


def test_tile_recovery_rejects_out_of_range_dead():
    with pytest.raises(ValueError):
        plan_tile_recovery(np.ones((2, 2)), [(5, 0)])
