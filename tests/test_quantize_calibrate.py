"""Quantization codebook edge cases + the quantize->calibrate round trip.

Satellite coverage for the analog program compiler: circular phase
distance at the 0/2pi boundary (both codebooks), the two quantize-pass
modes, and the bound that hardware-in-the-loop calibration recovers
synthesis error introduced by codebook snapping / device imperfections.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro import compile as compile_mod
from repro.core import quantize as q_lib

jax.config.update("jax_platform_name", "cpu")

TWO_PI = 2 * np.pi


# ---------------------------------------------------------------------------
# nearest_code circular wrap
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codebook", ["table1", "uniform3"])
def test_nearest_code_wraps_at_two_pi(codebook):
    """Phases just below 2pi must snap to the codebook's *small* phases
    when those are circularly closer — linear distance would pick the
    largest code instead."""
    cb = compile_mod.resolve_codebook(codebook)
    lo = int(jnp.argmin(cb))
    phase = jnp.asarray([TWO_PI - 0.05])
    # circularly, 2pi - 0.05 is within 0.05 + min(cb) of the smallest code
    assert int(q_lib.nearest_code(phase, cb)[0]) == lo
    # and slightly negative phases likewise wrap to the small codes
    assert int(q_lib.nearest_code(jnp.asarray([-0.05]), cb)[0]) == lo


@settings(max_examples=25, deadline=None)
@given(seed=st.integers(0, 2**31 - 1))
def test_nearest_code_invariant_under_two_pi_shift(seed):
    for codebook in ("table1", "uniform4"):
        cb = compile_mod.resolve_codebook(codebook)
        rng = np.random.default_rng(seed)
        phases = jnp.asarray(rng.uniform(-TWO_PI, 2 * TWO_PI, size=16),
                             jnp.float32)
        base = q_lib.nearest_code(phases, cb)
        np.testing.assert_array_equal(
            np.asarray(q_lib.nearest_code(phases + TWO_PI, cb)),
            np.asarray(base))
        np.testing.assert_array_equal(
            np.asarray(q_lib.nearest_code(phases - TWO_PI, cb)),
            np.asarray(base))


def test_nearest_code_exact_codebook_values_roundtrip():
    for name in ("table1", "uniform6"):
        cb = compile_mod.resolve_codebook(name)
        codes = q_lib.nearest_code(cb, cb)
        np.testing.assert_array_equal(np.asarray(codes),
                                      np.arange(cb.shape[0]))


# ---------------------------------------------------------------------------
# quantize pass modes
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def programmed():
    m = np.random.default_rng(0).normal(size=(4, 4))
    return compile_mod.program(compile_mod.synthesize(m), method="reck")


def test_quantize_nearest_stores_snapped_params(programmed):
    q = compile_mod.quantize(programmed, "uniform6", mode="nearest")
    la = q.layers[0]
    cb = la.codebook
    for params, codes in ((la.v_params, la.v_codes),
                          (la.u_params, la.u_codes)):
        for k, v in codes.items():
            np.testing.assert_allclose(
                np.asarray(params[k]),
                np.asarray(q_lib.codes_to_phase(v, cb)), atol=1e-6)
    # snapping is idempotent: the device view equals the stored params
    np.testing.assert_allclose(np.asarray(la.device_params("v")["theta"]),
                               np.asarray(la.v_params["theta"]), atol=1e-6)


def test_quantize_ste_keeps_continuous_masters(programmed):
    q = compile_mod.quantize(programmed, "table1", mode="ste")
    la = q.layers[0]
    # masters untouched ...
    np.testing.assert_allclose(
        np.asarray(la.v_params["theta"]),
        np.asarray(programmed.layers[0].v_params["theta"]))
    # ... but the device boundary snaps
    dev = la.device_params("v")["theta"]
    snapped = q_lib.codes_to_phase(
        q_lib.nearest_code(la.v_params["theta"], la.codebook), la.codebook)
    np.testing.assert_allclose(np.asarray(dev), np.asarray(snapped))


# ---------------------------------------------------------------------------
# quantize -> calibrate round trip
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("codebook,min_gain", [("table1", 0.5),
                                               ("uniform6", 0.05)])
def test_quantize_calibrate_round_trip_bound(programmed, codebook, min_gain):
    """Calibration must recover a chunk of the quantization-induced
    synthesis error — and can never end worse than its input (the
    best-iterate guard evaluates the uncalibrated program first)."""
    q = compile_mod.quantize(programmed, codebook, mode="nearest")
    err_q = compile_mod.program_error(q)
    err_0 = compile_mod.program_error(programmed)
    assert err_q > err_0  # snapping really did cost accuracy
    cal = compile_mod.calibrate(q, None, steps=250, lr=0.02)
    err_c = compile_mod.program_error(cal)
    assert err_c <= err_q * (1.0 - min_gain)


def test_hardware_calibration_recovers_error(programmed):
    """Hardware-in-the-loop residual fit against the measured prototype."""
    from repro.paper.prototype import PROTOTYPE

    key = jax.random.PRNGKey(0)
    bound = compile_mod.calibrate(programmed, PROTOTYPE, key=key, steps=0)
    err_uncal = compile_mod.program_error(bound)
    cal = compile_mod.calibrate(programmed, PROTOTYPE, key=key, steps=200)
    err_cal = compile_mod.program_error(cal)
    assert err_cal < 0.3 * err_uncal


def test_calibrated_draw_parity_with_reference(programmed):
    """The bound noise keys are consumed exactly like the reference
    ``apply_mesh_hw`` path: the kernel-realized matrix of a calibrated
    layer matches the pure-jnp hardware chain draw-for-draw."""
    from repro.core import hardware as hw_lib
    from repro.paper.prototype import PROTOTYPE

    cal = compile_mod.calibrate(programmed, PROTOTYPE,
                                key=jax.random.PRNGKey(3), steps=20)
    la = cal.layers[0]
    got = compile_mod.layer_matrix(la)
    probes = jnp.eye(la.n, dtype=jnp.complex64)
    h = hw_lib.apply_mesh_hw(la.v_plan, la.device_params("v"), probes,
                             PROTOTYPE, la.key_v)
    h = h * la.attenuation.astype(jnp.complex64)
    h = hw_lib.apply_mesh_hw(la.u_plan, la.device_params("u"), h,
                             PROTOTYPE, la.key_u)
    want = np.asarray(jnp.asarray(la.scale, jnp.complex64) * h).T
    np.testing.assert_allclose(got, want[: la.out_dim, : la.in_dim],
                               atol=1e-5)
