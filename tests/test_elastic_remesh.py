"""Elastic recovery end-to-end: after simulated host loss, the planner's
degraded mesh must actually build and the training step must recompile on
it.  Runs in a subprocess so the placeholder device count doesn't leak into
other tests."""

import subprocess
import sys


_PROGRAM = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax
from repro.runtime import plan_recovery
from repro.launch import specs as specs_lib

# pod loss: 512 -> 256 chips -> single-pod mesh
plan = plan_recovery(256)
assert plan.mesh_shape == (16, 16) and plan.accum_multiplier == 2

# partial loss inside a pod: 140 chips survive -> (8, 16) mesh
plan = plan_recovery(140)
assert plan.mesh_shape == (8, 16), plan
devices = jax.devices()[: plan.chips]
mesh = jax.sharding.Mesh(
    __import__("numpy").array(devices).reshape(plan.mesh_shape),
    plan.mesh_axes)

cell = specs_lib.build_cell("tinyllama-1.1b", "train_4k", mesh,
                            multi_pod=False)
compiled = cell.lower().compile()
mem = compiled.memory_analysis()
assert mem.argument_size_in_bytes > 0
print("ELASTIC_OK", plan.mesh_shape, plan.accum_multiplier)
"""


def test_recovery_mesh_recompiles():
    # JAX_PLATFORMS=cpu: skip the minutes-long TPU metadata probe on hosts
    # that ship libtpu (the placeholder devices are host devices anyway).
    r = subprocess.run([sys.executable, "-c", _PROGRAM], capture_output=True,
                       text=True, timeout=560,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert "ELASTIC_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]


# ---------------------------------------------------------------------------
# planner edge cases: pure data, no mesh build needed
# ---------------------------------------------------------------------------

def test_plan_recovery_below_floor_is_nonviable():
    from repro.runtime import plan_recovery

    # one chip short of the smallest (4, 16) mesh
    plan = plan_recovery(63)
    assert not plan.viable
    assert plan.mesh_shape == () and plan.dp_shards == 0
    assert "63" in plan.reason and "64" in plan.reason


def test_plan_recovery_non_divisor_host_count_drops_remainder():
    from repro.runtime import hosts_to_chips, plan_recovery

    # 33 hosts x 4 chips = 132 chips: the largest tileable data axis is 8
    # (128 chips) and the 4 stragglers sit out
    plan = plan_recovery(hosts_to_chips(33))
    assert plan.viable
    assert plan.mesh_shape == (8, 16)
    assert plan.dropped_chips == 132 - 128


def test_plan_recovery_exact_boundaries():
    from repro.runtime import plan_recovery

    full = plan_recovery(512)
    assert full.viable and full.mesh_shape == (2, 16, 16)
    assert full.mesh_axes == ("pod", "data", "model")
    assert full.accum_multiplier == 1 and full.dropped_chips == 0

    pod = plan_recovery(256)
    assert pod.viable and pod.mesh_shape == (16, 16)
    assert pod.accum_multiplier == 2     # keep the global batch
    assert pod.dropped_chips == 0

    floor = plan_recovery(64)
    assert floor.viable and floor.mesh_shape == (4, 16)
    assert floor.accum_multiplier == 8


def test_plan_recovery_model_axis_parameter():
    from repro.runtime import plan_recovery

    # an 8-wide TP ring on a 64-chip fleet: half the fleet survives
    plan = plan_recovery(32, original_chips=64, model_axis=8)
    assert plan.viable
    assert plan.mesh_shape == (4, 8)
    assert plan.accum_multiplier == 2    # full dp 8 -> dp 4
    # the floor scales with the ring width too
    assert not plan_recovery(31, original_chips=64, model_axis=8).viable


def test_hosts_to_chips_host_chips_parameter():
    from repro.runtime import hosts_to_chips

    assert hosts_to_chips(10) == 40          # v5e default: 4 chips/host
    assert hosts_to_chips(10, host_chips=8) == 80
