"""Elastic recovery end-to-end: after simulated host loss, the planner's
degraded mesh must actually build and the training step must recompile on
it.  Runs in a subprocess so the placeholder device count doesn't leak into
other tests."""

import subprocess
import sys


_PROGRAM = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import jax
from repro.runtime import plan_recovery
from repro.launch import specs as specs_lib

# pod loss: 512 -> 256 chips -> single-pod mesh
plan = plan_recovery(256)
assert plan.mesh_shape == (16, 16) and plan.accum_multiplier == 2

# partial loss inside a pod: 140 chips survive -> (8, 16) mesh
plan = plan_recovery(140)
assert plan.mesh_shape == (8, 16), plan
devices = jax.devices()[: plan.chips]
mesh = jax.sharding.Mesh(
    __import__("numpy").array(devices).reshape(plan.mesh_shape),
    plan.mesh_axes)

cell = specs_lib.build_cell("tinyllama-1.1b", "train_4k", mesh,
                            multi_pod=False)
compiled = cell.lower().compile()
mem = compiled.memory_analysis()
assert mem.argument_size_in_bytes > 0
print("ELASTIC_OK", plan.mesh_shape, plan.accum_multiplier)
"""


def test_recovery_mesh_recompiles():
    # JAX_PLATFORMS=cpu: skip the minutes-long TPU metadata probe on hosts
    # that ship libtpu (the placeholder devices are host devices anyway).
    r = subprocess.run([sys.executable, "-c", _PROGRAM], capture_output=True,
                       text=True, timeout=560,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert "ELASTIC_OK" in r.stdout, r.stdout[-2000:] + r.stderr[-2000:]
