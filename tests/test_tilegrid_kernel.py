"""Tile-grid megakernel validation: the fused (To x Ti) grid sweep vs the
per-tile kernel composition (differential, property-based), ragged
batches, schedule memoization, the coefficient-pack cache, and the
``TiledAnalogLinear(backend="pallas")`` module wiring."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core import decompose, mesh as mesh_lib
from repro.core.analog_linear import TiledAnalogLinear
from repro.kernels import ops
from repro.kernels.schedule import tile_grid_schedule

jax.config.update("jax_platform_name", "cpu")

REL_TOL = 1e-5


def _make_tiles(n, to, ti, *, seed=0, screens=False, plans=None):
    """A (to x ti) grid of per-tile kernel argument dicts."""
    rows = []
    for o in range(to):
        row = []
        for i in range(ti):
            pair = plans[o][i] if plans is not None else None
            v_plan = (pair[0] if pair is not None and pair[0] is not None
                      else mesh_lib.clements_plan(n))
            u_plan = (pair[1] if pair is not None and pair[1] is not None
                      else mesh_lib.clements_plan(n))
            k = jax.random.fold_in(jax.random.PRNGKey(seed), o * ti + i)
            kv, ku, ka, ks = jax.random.split(k, 4)
            vp = mesh_lib.init_mesh_params(kv, v_plan)
            up = mesh_lib.init_mesh_params(ku, u_plan)
            if screens:
                vp["alpha_in"] = jax.random.uniform(ks, (n,)) * 2 * np.pi
                up["alpha_in"] = jax.random.uniform(
                    jax.random.fold_in(ks, 1), (n,)) * 2 * np.pi
            row.append({
                "v": vp, "u": up,
                "atten": jax.random.uniform(ka, (n,), minval=0.2,
                                            maxval=0.9),
                "scale": 1.0 + 0.1 * (o + i),
            })
        rows.append(tuple(row))
    return tuple(rows)


def _per_tile(tiles, x, n, *, plans=None, hardware=None):
    """The unfused oracle: To*Ti separate kernel mesh applications with
    the row combine in plain JAX — tile (r, i) contributes
    ``scale * U(atten * V(x_i))`` to output row r."""
    to, ti = len(tiles), len(tiles[0])
    xt = x.reshape(x.shape[:-1] + (ti, n))
    outs = []
    for o in range(to):
        acc = 0
        for i in range(ti):
            ta = tiles[o][i]
            pair = plans[o][i] if plans is not None else None
            vp, up = pair if pair is not None else (None, None)
            h = ops.mesh_apply(ta["v"], xt[..., i, :], n=n, plan=vp,
                               hardware=hardware, key=ta.get("key_v"))
            h = h * ta["atten"].astype(jnp.complex64)
            y = ops.mesh_apply(ta["u"], h, n=n, plan=up,
                               hardware=hardware, key=ta.get("key_u"))
            acc = acc + jnp.asarray(ta["scale"], jnp.complex64) * y
        outs.append(acc)
    return jnp.concatenate(outs, axis=-1)


def _rand_x(n, batch, seed=0):
    k = jax.random.PRNGKey(seed)
    xr = jax.random.normal(k, (batch, n))
    xi = jax.random.normal(jax.random.fold_in(k, 1), (batch, n))
    return (xr + 1j * xi).astype(jnp.complex64)


def _max_rel_err(got, want):
    scale = max(float(jnp.max(jnp.abs(g))) for g in jax.tree.leaves(want))
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)))
    return err / (scale + 1e-30)


# ---------------------------------------------------------------------------
# property-based differential: megakernel vs per-tile composition
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(to=st.integers(1, 3), ti=st.integers(1, 3),
       tile=st.sampled_from([2, 4, 6]), seed=st.integers(0, 10_000),
       screens=st.booleans())
def test_tilegrid_matches_per_tile_fwd_and_vjp(to, ti, tile, seed, screens):
    """Random grid shapes / tile sizes / screens: fwd and VJP must agree
    with the per-tile kernel composition to <= 1e-5 relative."""
    tiles = _make_tiles(tile, to, ti, seed=seed, screens=screens)
    x = _rand_x(ti * tile, 5, seed=seed + 1)
    y_pt = _per_tile(tiles, x, tile)
    y_k = ops.tiled_apply(tiles, x, n=tile)
    assert y_k.shape == (5, to * tile)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_pt),
                               atol=REL_TOL * 10 * max(1.0, ti))

    w = 1.0 + jnp.arange(to * tile, dtype=jnp.float32)  # break degeneracies

    def loss_k(ts, xx):
        return jnp.sum(jnp.abs(ops.tiled_apply(ts, xx, n=tile)) * w)

    def loss_pt(ts, xx):
        return jnp.sum(jnp.abs(_per_tile(ts, xx, tile)) * w)

    g_k = jax.jit(jax.grad(loss_k, argnums=(0, 1)))(tiles, x)
    g_pt = jax.jit(jax.grad(loss_pt, argnums=(0, 1)))(tiles, x)
    assert _max_rel_err(g_k, g_pt) <= REL_TOL


def test_tilegrid_mixed_reck_plans_identity_padding():
    """Per-tile Reck programs are deeper than Clements: a mixed grid
    exercises the grid-wide identity-column padding (exact no-op)."""
    n, to, ti = 4, 2, 2
    rplan, rparams = decompose.reck_program(
        decompose.random_unitary(n, seed=3))
    plans = ((None, (rplan, None)), ((None, rplan), None))
    tiles = [list(r) for r in _make_tiles(n, to, ti, seed=5, plans=plans)]
    tiles[0][1] = dict(tiles[0][1], v=dict(rparams))
    tiles[1][0] = dict(tiles[1][0], u=dict(rparams))
    tiles = tuple(tuple(r) for r in tiles)
    x = _rand_x(ti * n, 6)
    y_pt = _per_tile(tiles, x, n, plans=plans)
    y_k = ops.tiled_apply(tiles, x, n=n, plans=plans)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_pt), atol=1e-4)
    grid = tile_grid_schedule(n, to, ti, plans)
    assert grid.n_columns > grid.tiles[0][0][0].n_columns  # padding used


# ---------------------------------------------------------------------------
# ragged batches
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("batch", [1, 7, 130])
def test_tilegrid_ragged_batches(batch):
    """B need not divide the batch block: the tail block is zero-padded
    and masked in forward and VJP."""
    n, to, ti = 4, 2, 3
    tiles = _make_tiles(n, to, ti)
    x = _rand_x(ti * n, batch)
    y_pt = _per_tile(tiles, x, n)
    y_k = ops.tiled_apply(tiles, x, n=n, block_b=64)
    assert y_k.shape == (batch, to * n)
    np.testing.assert_allclose(np.asarray(y_k), np.asarray(y_pt), atol=1e-5)

    w = 1.0 + jnp.arange(to * n, dtype=jnp.float32)
    g_k = jax.grad(lambda ts: jnp.sum(jnp.abs(
        ops.tiled_apply(ts, x, n=n, block_b=64)) * w))(tiles)
    g_pt = jax.grad(lambda ts: jnp.sum(jnp.abs(
        _per_tile(ts, x, n)) * w))(tiles)
    assert _max_rel_err(g_k, g_pt) <= REL_TOL


# ---------------------------------------------------------------------------
# memoization: schedule lowering + trace cache + pack cache + kernel path
# ---------------------------------------------------------------------------

def test_tilegrid_schedule_memoized_no_retrace():
    """Structurally equal grids (fresh objects every call) must not
    re-trigger a jit trace of the kernel impl."""
    n, to, ti = 4, 2, 2
    tiles = _make_tiles(n, to, ti)
    x = _rand_x(ti * n, 4)
    ops.tiled_apply(tiles, x, n=n)
    before = ops.TRACE_COUNTS["tiled_apply"]
    ops.tiled_apply(tiles, x, n=n)  # fresh schedule build, equal content
    assert ops.TRACE_COUNTS["tiled_apply"] == before  # no retrace


def test_tilegrid_pack_cache_single_pack_event():
    """Same (immutable) tile arrays -> exactly one PACK_EVENT ever; new
    arrays -> exactly one more.  The kernel path is actually taken."""
    n, to, ti = 4, 2, 2
    tiles = _make_tiles(n, to, ti, seed=9)
    x = _rand_x(ti * n, 4)
    calls = ops.KERNEL_PATH_CALLS["tiled_apply"]
    packs = ops.PACK_EVENTS["tiled_apply"]
    ops.tiled_apply(tiles, x, n=n)  # populate (exactly one pack)
    assert ops.KERNEL_PATH_CALLS["tiled_apply"] == calls + 1
    assert ops.PACK_EVENTS["tiled_apply"] == packs + 1
    for _ in range(5):
        ops.tiled_apply(tiles, x, n=n)
    assert ops.PACK_EVENTS["tiled_apply"] == packs + 1  # steady state

    bumped = ((dict(tiles[0][0], atten=tiles[0][0]["atten"] + 0.01),)
              + tiles[0][1:],) + tiles[1:]
    ops.tiled_apply(bumped, x, n=n)
    assert ops.PACK_EVENTS["tiled_apply"] == packs + 2


# ---------------------------------------------------------------------------
# TiledAnalogLinear: backend equivalence end-to-end
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("quantize", [None, "table1"])
@pytest.mark.parametrize("output", ["real", "abs"])
def test_tiled_analog_linear_backends_match(quantize, output):
    ref_m = TiledAnalogLinear(in_dim=12, out_dim=8, tile_size=4,
                              quantize=quantize, output=output,
                              backend="reference")
    pal_m = TiledAnalogLinear(in_dim=12, out_dim=8, tile_size=4,
                              quantize=quantize, output=output,
                              backend="pallas")
    params = ref_m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (6, 12))
    np.testing.assert_allclose(np.asarray(pal_m.apply(params, x)),
                               np.asarray(ref_m.apply(params, x)),
                               atol=1e-5)
    w = 1.0 + jnp.arange(8, dtype=jnp.float32)
    g_r = jax.grad(lambda p: jnp.sum(ref_m.apply(p, x) * w))(params)
    g_p = jax.grad(lambda p: jnp.sum(pal_m.apply(p, x) * w))(params)
    assert _max_rel_err(g_p, g_r) <= REL_TOL


def test_tiled_analog_linear_steady_state_zero_packing():
    """Serving steady state (same params every call) must do zero packing
    work after the first apply — the derived-args memoization plus the
    pack cache absorb it all."""
    pal_m = TiledAnalogLinear(in_dim=8, out_dim=8, tile_size=4,
                              output="real", backend="pallas")
    params = pal_m.init(jax.random.PRNGKey(2))
    x = jax.random.normal(jax.random.PRNGKey(3), (5, 8))
    pal_m.apply(params, x)  # may pack (cold cache)
    packs = ops.PACK_EVENTS["tiled_apply"]
    for _ in range(4):
        pal_m.apply(params, x)
    assert ops.PACK_EVENTS["tiled_apply"] == packs  # zero packing work


def test_tiled_analog_linear_programmed_matches_dense_on_pallas():
    """Programmed tiles == dense matmul through the megakernel path."""
    rng = np.random.default_rng(1)
    tile = 4
    w = rng.normal(size=(8, 12))
    layer = TiledAnalogLinear(in_dim=12, out_dim=8, tile_size=tile,
                              output="real", backend="pallas")
    to, ti = layer.grid()
    tiles = [[layer.tile.init_from_matrix(
        w[i * tile:(i + 1) * tile, j * tile:(j + 1) * tile])
        for j in range(ti)] for i in range(to)]
    params = jax.tree.map(lambda *xs: jnp.stack(xs), *[
        jax.tree.map(lambda *ys: jnp.stack(ys), *row) for row in tiles])
    x = rng.normal(size=(3, 12)).astype(np.float32)
    y = layer.apply(params, jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(y), x @ w.T, atol=1e-4)
