"""Pipeline-parallel (GPipe over a mesh axis) correctness.

Runs in a subprocess with placeholder host devices so the ppermute ring is
real (the main test process keeps the default single device).
"""

import subprocess
import sys

import pytest

_PROGRAM = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.parallel.pipeline import pipeline_forward, bubble_fraction

mesh = jax.make_mesh((4,), ("stage",))
S, M, MB, D = 4, 8, 2, 16
key = jax.random.PRNGKey(0)
w = jax.random.normal(key, (S, D, D)) / np.sqrt(D)
params = {"w": w}

def block(p, x):
    return jnp.tanh(x @ p["w"])

x = jax.random.normal(jax.random.PRNGKey(1), (M, MB, D))
y = pipeline_forward(block, mesh, "stage", params, x)

# reference: apply all stages sequentially to each microbatch
ref = x
for s in range(S):
    ref = jnp.tanh(ref @ w[s])
np.testing.assert_allclose(np.asarray(y), np.asarray(ref), atol=1e-5)
assert abs(bubble_fraction(4, 8) - 3/11) < 1e-9
print("PIPELINE_OK")
"""


def test_gpipe_matches_sequential():
    # JAX_PLATFORMS=cpu: without it, a host that ships libtpu spends minutes
    # probing for TPU metadata inside the scrubbed subprocess environment.
    r = subprocess.run([sys.executable, "-c", _PROGRAM], capture_output=True,
                       text=True, timeout=300,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert "PIPELINE_OK" in r.stdout, r.stdout + r.stderr
