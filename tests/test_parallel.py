"""Tests for distribution machinery: sharding rules, head padding, floors."""

import dataclasses
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import Model, ModelConfig
from repro.parallel.sharding import ShardingRules, default_rules

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------

def test_rules_dedupe_axis_reuse():
    rules = default_rules(multi_pod=False)
    spec = rules.spec("batch", "kv_seq", "kv_heads", "head_dim")
    # batch takes "data"; kv_seq ("data") must be dropped; kv_heads model
    assert spec[0] == ("data",) or spec[0] == "data"
    assert spec[1] is None
    assert spec[2] == "model"


def test_rules_dedupe_tuple_overlap():
    rules = default_rules(multi_pod=True)
    spec = rules.spec("batch", "experts")  # batch=(pod,data), experts=data
    assert spec[1] is None  # "data" already used by batch


def test_unknown_logical_name_is_replicated():
    rules = ShardingRules(rules={"batch": ("data",)})
    spec = rules.spec("batch", "nonexistent")
    assert spec[1] is None


# ---------------------------------------------------------------------------
# head padding (perf cell C) must be exactly semantics-preserving
# ---------------------------------------------------------------------------

def _copy_into_padded(p_small, p_pad):
    """Copy unpadded attention weights into the padded param tree."""
    def visit(a, b):
        if a.shape == b.shape:
            return a
        # padded head axis: copy reals, keep zeros for pads
        out = jnp.zeros_like(b)
        sl = tuple(slice(0, s) for s in a.shape)
        return out.at[sl].set(a)
    return jax.tree.map(visit, p_small, p_pad)


def test_head_padding_preserves_forward():
    base = dict(n_layers=2, d_model=64, n_kv_heads=2, d_ff=128,
                vocab_size=97, attn_chunk=16, dtype="float32",
                n_heads=6, head_dim=16)
    cfg = ModelConfig(name="m", family="dense", **base)
    cfg_pad = dataclasses.replace(cfg, head_pad=2)  # 6 -> 8 heads
    m, mp = Model(cfg), Model(cfg_pad)
    params = m.init(jax.random.PRNGKey(0))
    params_pad = _copy_into_padded(params, mp.init(jax.random.PRNGKey(1)))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 24),
                                          0, 97)}
    y1, _ = m.forward(params, batch)
    y2, _ = mp.forward(params_pad, batch)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)


def test_head_padding_preserves_decode():
    base = dict(n_layers=2, d_model=64, n_kv_heads=2, d_ff=128,
                vocab_size=97, attn_chunk=16, dtype="float32",
                n_heads=6, head_dim=16)
    cfg = ModelConfig(name="m", family="dense", **base)
    cfg_pad = dataclasses.replace(cfg, head_pad=2)
    m, mp = Model(cfg), Model(cfg_pad)
    params = m.init(jax.random.PRNGKey(0))
    params_pad = _copy_into_padded(params, mp.init(jax.random.PRNGKey(1)))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(2), (2, 12),
                                          0, 97)}
    l1, c1 = m.prefill(params, batch, max_len=16)
    l2, c2 = mp.prefill(params_pad, batch, max_len=16)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-4)
    tok = jnp.argmax(l1, -1).astype(jnp.int32)
    d1, _ = m.decode_step(params, tok, c1, jnp.asarray(12, jnp.int32))
    d2, _ = mp.decode_step(params_pad, tok, c2, jnp.asarray(12, jnp.int32))
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=1e-4)


def test_head_padding_pads_stay_dead_under_training():
    """One SGD step must leave pad-head wq columns exactly zero-gradient
    through wo masking (wo pad rows receive grads but contribute nothing)."""
    base = dict(n_layers=1, d_model=32, n_kv_heads=1, d_ff=64,
                vocab_size=53, attn_chunk=8, dtype="float32",
                n_heads=3, head_dim=8)
    cfg = ModelConfig(name="m", family="dense", head_pad=1, **base)
    m = Model(cfg)
    params = m.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 16),
                                          0, 53),
             "labels": jax.random.randint(jax.random.PRNGKey(2), (2, 16),
                                          0, 53)}
    grads = jax.grad(lambda p: m.loss(p, batch)[0])(params)
    g_wo = np.asarray(grads["blocks"]["l0_dense"]["attn"]["wo"])[0]
    assert np.abs(g_wo[3]).max() == 0.0  # masked pad head: no gradient


# ---------------------------------------------------------------------------
# elastic planning consistency with the mesh factory
# ---------------------------------------------------------------------------

def test_runtime_profiles_resolve():
    from repro.launch import specs as specs_lib
    for arch in ("llama4-maverick-400b-a17b", "tinyllama-1.1b", "gemma-2b"):
        for shape in ("train_4k", "decode_32k"):
            cfg, _ = specs_lib.runtime_config(arch, shape, False)
            assert cfg.vocab_size % 256 == 0 or cfg.vocab_real == 0
            if cfg.head_pad:
                assert (cfg.n_heads + cfg.head_pad) % 16 == 0


# ---------------------------------------------------------------------------
# data-parallel shard_map over the network megakernel (placeholder devices)
# ---------------------------------------------------------------------------

_DP_PROGRAM = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.core.analog_linear import AnalogSequence
from repro.parallel.sharding import data_parallel
from repro.train.step import make_sgd_step

n, depth = 8, 2
seq = AnalogSequence(n=n, depth=depth, backend="pallas")
params = seq.init(jax.random.PRNGKey(0))
mesh = jax.make_mesh((4,), ("data",))

# forward: sharded == single-device, including a ragged batch (13 % 4 != 0)
x = jax.random.normal(jax.random.PRNGKey(1), (13, n))
dp_apply = data_parallel(lambda p, xx: seq.apply(p, xx), mesh)
np.testing.assert_allclose(np.asarray(dp_apply(params, x)),
                           np.asarray(seq.apply(params, x)), atol=1e-5)

# training: the data-parallel SGD step must match the serial step exactly
def loss_fn(p, xx, yy):
    l = jnp.mean((seq.apply(p, xx) - yy) ** 2)
    return l, l

xb = jax.random.normal(jax.random.PRNGKey(2), (16, n))
yb = jax.random.normal(jax.random.PRNGKey(3), (16, n)) ** 2
p1, (l1, _) = make_sgd_step(loss_fn, lr=0.05)(params, xb, yb)
pN, (lN, _) = make_sgd_step(loss_fn, lr=0.05, mesh=mesh)(params, xb, yb)
for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(pN)):
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)
np.testing.assert_allclose(float(l1), float(lN), atol=1e-6)
print("DP_OK")
"""


def test_data_parallel_megakernel_matches_single_device():
    # JAX_PLATFORMS=cpu: without it, a host that ships libtpu spends minutes
    # probing for TPU metadata inside the scrubbed subprocess environment.
    r = subprocess.run([sys.executable, "-c", _DP_PROGRAM],
                       capture_output=True, text=True, timeout=600,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert "DP_OK" in r.stdout, r.stdout + r.stderr
