"""Sharded tile-grid megakernel: shard_map scale-out over (rows x data).

The acceptance bar: on a forced 8-device host mesh with the tile-row axis
genuinely sharded (> 1 row device, so the backward's cross-device psum
row-combine actually runs), forward AND the full custom VJP match the
single-device megakernel to <= 1e-5 relative.
"""

import subprocess
import sys

import pytest

_SHARDED_PROGRAM = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import numpy as np
import jax, jax.numpy as jnp
from jax.sharding import Mesh

from repro.core import mesh as mesh_lib
from repro.kernels import ops

rng = np.random.default_rng(0)
n, to, ti, b = 4, 4, 2, 10          # ragged batch: 10 % (block*data) != 0
plan = mesh_lib.clements_plan(n)
tiles = []
for o in range(to):
    trow = []
    for i in range(ti):
        kv, ku, ka = jax.random.split(
            jax.random.fold_in(jax.random.PRNGKey(7), o * ti + i), 3)
        trow.append({
            "v": mesh_lib.init_mesh_params(kv, plan),
            "u": mesh_lib.init_mesh_params(ku, plan),
            "atten": jax.random.uniform(ka, (n,), minval=0.2, maxval=0.9),
            "scale": 1.0 + 0.05 * (o + i),
        })
    tiles.append(tuple(trow))
tiles = tuple(tiles)
x = jnp.asarray(rng.normal(size=(b, ti * n)).astype(np.float32))
w = jnp.asarray(rng.normal(size=(b, to * n)).astype(np.float32))


def loss(tiles, x, mesh=None):
    y = ops.tiled_apply(tiles, x, n=n, mesh=mesh)
    return jnp.sum(jnp.abs(y) * w)


y_ref = np.asarray(ops.tiled_apply(tiles, x, n=n))
g_ref = jax.grad(loss, argnums=(0, 1))(tiles, x)

# tile rows sharded 4-way AND batch sharded 2-way: both collectives run
for shape in [(4, 2), (2, 4)]:
    nr, nd = shape
    mesh = Mesh(np.array(jax.devices()[: nr * nd]).reshape(nr, nd),
                ("rows", "data"))
    y_sh = np.asarray(ops.tiled_apply(tiles, x, n=n, mesh=mesh))
    rel = np.abs(y_sh - y_ref).max() / np.abs(y_ref).max()
    assert rel <= 1e-5, f"fwd {shape}: rel={rel}"
    g_sh = jax.grad(loss, argnums=(0, 1))(tiles, x, mesh=mesh)
    for a, bb in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_sh)):
        a, bb = np.asarray(a), np.asarray(bb)
        rel = np.abs(a - bb).max() / max(np.abs(a).max(), 1e-12)
        assert rel <= 1e-5, f"grad {shape}: rel={rel}"

# under an ENCLOSING jit the packing runs traced (the training-step
# shape: jit(grad(loss)) over raw tiles) — this is the configuration
# that trips GSPMD mis-partitioning of concatenate-built operands
# feeding shard_map on this jax version, which the kernel's replicated
# coefficient specs work around; cover it explicitly
mesh = Mesh(np.array(jax.devices()[:8]).reshape(4, 2), ("rows", "data"))
g_jit = jax.jit(jax.grad(lambda ts, xx: loss(ts, xx, mesh=mesh),
                         argnums=(0, 1)))(tiles, x)
for a, bb in zip(jax.tree.leaves(g_ref), jax.tree.leaves(g_jit)):
    a, bb = np.asarray(a), np.asarray(bb)
    rel = np.abs(a - bb).max() / max(np.abs(a).max(), 1e-12)
    assert rel <= 1e-5, f"jit(grad) rel={rel}"

# the sharded path is instrumented separately from the single-device path
assert ops.KERNEL_PATH_CALLS["tiled_apply_sharded"] > 0

# validation: To must shard evenly over the row axis
mesh3 = Mesh(np.array(jax.devices()[:3]).reshape(3, 1), ("rows", "data"))
try:
    ops.tiled_apply(tiles, x, n=n, mesh=mesh3)
    raise SystemExit("expected a ValueError for To % rows != 0")
except ValueError:
    pass

# a mesh without the named axes is rejected up front
meshx = Mesh(np.array(jax.devices()[:4]).reshape(4, 1), ("r", "d"))
try:
    ops.tiled_apply(tiles, x, n=n, mesh=meshx)
    raise SystemExit("expected a ValueError for a missing mesh axis")
except ValueError:
    pass
print("SHARDED_OK")
"""


@pytest.mark.slow
def test_sharded_tiled_apply_matches_single_device():
    # JAX_PLATFORMS=cpu: without it, a host that ships libtpu spends minutes
    # probing for TPU metadata inside the scrubbed subprocess environment.
    r = subprocess.run([sys.executable, "-c", _SHARDED_PROGRAM],
                       capture_output=True, text=True, timeout=900,
                       env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin",
                            "JAX_PLATFORMS": "cpu"})
    assert "SHARDED_OK" in r.stdout, r.stdout + r.stderr
