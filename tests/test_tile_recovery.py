"""Degraded-grid recovery: kill k tiles of a noisy 64x64 tiled program and
recover classification accuracy by remap + recalibrate.

The acceptance bar (ISSUE 8): after ``tile_down`` failures kill a physical
tile row, accuracy with the recovery plan applied (remap the placement so
the zero-mass logical rows park on the dead positions, re-calibrate the
moved tiles, re-lower) must come back to within 2% of the pre-failure
calibrated accuracy AND stay strictly above the unrecovered degraded
grid — end-to-end on the Pallas tile-grid kernel path.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.compile import (
    blank_tile,
    calibrate_tiled,
    lower_tiled,
    program_tiled,
    recover_tiled,
    synthesize_tiled,
    tile_sensitivities,
)
from repro.paper.prototype import PROTOTYPE
from repro.runtime import FailureInjector, plan_tile_recovery, tile_row_failures

jax.config.update("jax_platform_name", "cpu")

N, TILE, N_CLASSES = 64, 16, 10


def _classifier_setup(seed=0):
    """A 10-way matched-filter classifier on a 64x64 grid: the class
    filters live in the first output tile row, rows 10..63 are zero (so
    three of the four logical tile rows carry no singular-value mass —
    the headroom recovery exploits)."""
    rng = np.random.default_rng(seed)
    q, _ = np.linalg.qr(rng.normal(size=(N, N)))
    w = np.zeros((N, N), np.float32)
    w[:N_CLASSES] = 3.0 * q[:N_CLASSES]
    labels = rng.integers(0, N_CLASSES, size=80)
    x = (q[labels] + 0.05 * rng.normal(size=(len(labels), N))).astype(
        np.float32)
    return w, jnp.asarray(x), labels


def _accuracy(compiled, x, labels) -> float:
    pred = np.argmax(np.asarray(compiled.apply(x))[:, :N_CLASSES], axis=1)
    return float(np.mean(pred == labels))


@pytest.mark.slow
def test_row_kill_recovery_restores_accuracy():
    w, x, labels = _classifier_setup()
    key = jax.random.PRNGKey(5)
    tp = program_tiled(synthesize_tiled(w, tile=TILE), method="reck")
    # bind every physical position's hardware draw (steps=0: calibration
    # freezes the noisy device without trimming — the "noisy" program)
    tp = calibrate_tiled(tp, PROTOTYPE, key=key, steps=0)
    compiled = lower_tiled(tp)
    acc_pre = _accuracy(compiled, x, labels)
    assert acc_pre >= 0.9, f"pre-failure accuracy {acc_pre} too low to test"

    # a whole physical tile row dies, injected as tile_down failures
    inj = FailureInjector(schedule=tile_row_failures(step=0, row=0,
                                                     ti=tp.ti))
    inj.at_step(0)
    dead = sorted(inj.dead_tiles)
    assert len(dead) == tp.ti

    # unrecovered: the dead tiles blank out and the class filters (which
    # live in logical row 0 = the dead physical row) go dark
    degraded = tp.map_tiles(
        lambda o, i, la: blank_tile(la) if (o, i) in inj.dead_tiles else la)
    acc_degraded = _accuracy(lower_tiled(degraded), x, labels)
    assert acc_degraded <= 0.5, (
        f"degraded accuracy {acc_degraded}: the kill did not bite")

    # remap + recalibrate + re-lower via the recovery plan
    sens = tile_sensitivities(tp)
    plan = plan_tile_recovery(sens, dead)
    assert plan.viable
    assert plan.dropped_mass == 0.0         # zero-mass rows park dead
    assert plan.row_perm[0] != 0            # class row moved off dead row
    recovered = recover_tiled(tp, plan, PROTOTYPE, key=key, steps=0)
    acc_rec = _accuracy(recovered, x, labels)

    assert acc_rec > acc_degraded, (
        f"recovery did not help: {acc_rec} vs degraded {acc_degraded}")
    assert acc_rec >= acc_pre - 0.02, (
        f"recovered accuracy {acc_rec} not within 2% of pre-failure "
        f"{acc_pre}")


@pytest.mark.slow
def test_recovery_plan_moves_only_what_it_must():
    """The recovery recalibrates exactly the live positions whose hosted
    logical tile changed — untouched tiles keep their binding
    bit-identical through the round trip."""
    w, _, _ = _classifier_setup(seed=3)
    key = jax.random.PRNGKey(7)
    tp = program_tiled(synthesize_tiled(w, tile=TILE), method="reck")
    tp = calibrate_tiled(tp, PROTOTYPE, key=key, steps=0)
    dead = [(0, i) for i in range(tp.ti)]
    plan = plan_tile_recovery(tile_sensitivities(tp), dead)
    # uniform row kill: the column axis keeps its assignment
    assert plan.col_perm == tuple(range(tp.ti))
    recovered = recover_tiled(tp, plan, PROTOTYPE, key=key, steps=0,
                              lower=False)
    # physical position (po, pi) hosts logical (row_perm[po], pi); a
    # position whose host did not move keeps the *same object* state
    for po in range(tp.to):
        for pi in range(tp.ti):
            la = recovered.grid[po][pi]
            src = tp.grid[plan.row_perm[po]][pi]
            if (po, pi) in set(dead):
                assert float(np.asarray(la.scale)) == 0.0
            elif (po, pi) in set(plan.recalibrate):
                # rebound to this position's draw: keys must match what
                # calibrate_tiled folds for (po, pi)
                kt = jax.random.fold_in(key, po * tp.ti + pi)
                kv, _ = jax.random.split(jax.random.fold_in(kt, 0))
                np.testing.assert_array_equal(np.asarray(la.key_v),
                                              np.asarray(kv))
            else:
                assert la is src
