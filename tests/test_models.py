"""Model-stack correctness: family smoke, decode consistency, SSD oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import Model, ModelConfig
from repro.models import ssm as ssm_lib

jax.config.update("jax_platform_name", "cpu")

BASE = dict(n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128,
            vocab_size=97, attn_chunk=16, dtype="float32")


def make_cfg(family: str) -> ModelConfig:
    extra = {
        "dense": {},
        # capacity_factor=8: no token dropping, so decode (which never
        # drops) is exactly consistent with the full forward pass.
        "moe": dict(n_experts=4, top_k=2, d_ff_expert=32, n_shared_experts=1,
                    d_ff_shared=32, moe_interleave=2, capacity_factor=8.0),
        "ssm": dict(ssm_state=16, ssm_headdim=16, ssm_chunk=8),
        "hybrid": dict(ssm_state=16, ssm_headdim=16, ssm_chunk=8,
                       attn_every=2),
        "vlm": dict(n_vis_tokens=8),
        "encdec": dict(n_enc_layers=2, enc_seq=24),
    }[family]
    return ModelConfig(name=family, family=family, **BASE, **extra)


def make_batch(cfg: ModelConfig, key, b=2, s=32):
    k1, k2, k3 = jax.random.split(key, 3)
    tokens = jax.random.randint(k1, (b, s), 0, cfg.vocab_size)
    labels = jnp.concatenate(
        [tokens[:, 1:], -jnp.ones((b, 1), jnp.int32)], axis=1)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.family == "vlm":
        batch["vis_embed"] = jax.random.normal(
            k2, (b, cfg.n_vis_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = jax.random.normal(
            k3, (b, cfg.enc_seq, cfg.d_model), jnp.float32)
    return batch


FAMILIES = ["dense", "moe", "ssm", "hybrid", "vlm", "encdec"]


@pytest.mark.parametrize("family", FAMILIES)
def test_family_loss_finite_and_grads(family):
    cfg = make_cfg(family)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    loss, metrics = model.loss(params, batch)
    assert np.isfinite(float(loss))
    grads = jax.grad(lambda p: model.loss(p, batch)[0])(params)
    gnorm = jax.tree.reduce(
        lambda a, g: a + float(jnp.sum(jnp.abs(g))), grads, 0.0)
    assert np.isfinite(gnorm) and gnorm > 0


@pytest.mark.parametrize("family", FAMILIES)
def test_param_specs_match_structure(family):
    cfg = make_cfg(family)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    specs = model.param_specs()
    # same tree structure; every spec rank == param rank
    def chk(p, s):
        assert isinstance(s, tuple) and len(s) == p.ndim, (p.shape, s)
    jax.tree.map(chk, params, specs,
                 is_leaf=lambda x: isinstance(x, tuple)
                 and all(isinstance(i, (str, type(None))) for i in x))


@pytest.mark.parametrize("family", FAMILIES)
def test_decode_matches_forward(family):
    """prefill + decode_step logits == full forward logits, token by token."""
    cfg = make_cfg(family)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    b, s = 2, 16
    batch = make_batch(cfg, jax.random.PRNGKey(1), b=b, s=s)
    logits_full, _ = model.forward(params, batch)
    if cfg.family == "vlm":
        logits_full = logits_full[:, cfg.n_vis_tokens:]

    prefix = s // 2
    pre_batch = dict(batch)
    pre_batch["tokens"] = batch["tokens"][:, :prefix]
    vis = cfg.n_vis_tokens if cfg.family == "vlm" else 0
    max_len = s + vis + 2
    logits_p, cache = model.prefill(params, pre_batch, max_len=max_len)
    np.testing.assert_allclose(np.asarray(logits_p),
                               np.asarray(logits_full[:, prefix - 1]),
                               atol=2e-2, rtol=2e-2)
    # feed true tokens and compare each step against the full forward
    for t in range(prefix, s):
        tok = batch["tokens"][:, t]
        logits_d, cache = model.decode_step(
            params, tok, cache, jnp.asarray(t + vis, jnp.int32))
        np.testing.assert_allclose(np.asarray(logits_d),
                                   np.asarray(logits_full[:, t]),
                                   atol=2e-2, rtol=2e-2)


def test_ssd_chunked_matches_naive_recurrence():
    """The chunked SSD dual form == naive per-step recurrence (oracle)."""
    cfg = make_cfg("ssm")
    key = jax.random.PRNGKey(0)
    params = ssm_lib.init_ssm(key, cfg)
    b, s = 2, 24
    u = jax.random.normal(jax.random.PRNGKey(1), (b, s, cfg.d_model),
                          jnp.float32)
    y_chunked = ssm_lib.ssm_block(params, cfg, u)

    # naive recurrence via repeated decode steps
    cache = ssm_lib.init_ssm_cache(cfg, b, jnp.float32)
    ys = []
    for t in range(s):
        y_t, cache = ssm_lib.ssm_decode_step(params, cfg, u[:, t:t + 1], cache)
        ys.append(y_t)
    y_naive = jnp.concatenate(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunked), np.asarray(y_naive),
                               atol=2e-4, rtol=2e-3)


def test_moe_padded_experts_receive_no_tokens():
    cfg = ModelConfig(name="m", family="moe", n_experts=8, n_experts_active=6,
                      top_k=2, d_ff_expert=32, moe_interleave=1, **BASE)
    from repro.models import moe as moe_lib
    params = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model))
    # route manually: check top-k never picks padded experts
    logits = jnp.einsum("bsd,de->bse", x, params["router"])
    logits = jnp.where(jnp.arange(8) >= 6, -1e30, logits)
    _, idx = jax.lax.top_k(jax.nn.softmax(logits), 2)
    assert int(idx.max()) < 6
    y, aux = moe_lib.moe_block(params, cfg, x)
    assert y.shape == x.shape and np.isfinite(float(aux))


def test_moe_identical_tokens_identical_outputs():
    """Routing determinism: same token -> same expert mix -> same output."""
    cfg = ModelConfig(name="m", family="moe", n_experts=4, top_k=1,
                      d_ff_expert=32, moe_interleave=1, capacity_factor=8.0,
                      **BASE)
    from repro.models import moe as moe_lib
    params = moe_lib.init_moe(jax.random.PRNGKey(0), cfg)
    tok = jax.random.normal(jax.random.PRNGKey(1), (1, 1, cfg.d_model))
    x = jnp.tile(tok, (2, 3, 1))
    y, _ = moe_lib.moe_block(params, cfg, x)
    ref = y[0, 0]
    np.testing.assert_allclose(np.asarray(y).reshape(-1, cfg.d_model),
                               np.tile(np.asarray(ref), (6, 1)), atol=1e-5)


def test_gqa_reduces_to_mha_and_mqa():
    for kv in (1, 4):
        cfg = ModelConfig(name="d", family="dense", **{**BASE,
                                                       "n_kv_heads": kv})
        model = Model(cfg)
        params = model.init(jax.random.PRNGKey(0))
        batch = make_batch(cfg, jax.random.PRNGKey(1))
        loss, _ = model.loss(params, batch)
        assert np.isfinite(float(loss))


def test_causality():
    """Changing future tokens must not change past logits."""
    cfg = make_cfg("dense")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits1, _ = model.forward(params, batch)
    toks2 = batch["tokens"].at[:, -1].set(
        (batch["tokens"][:, -1] + 7) % cfg.vocab_size)
    logits2, _ = model.forward(params, {**batch, "tokens": toks2})
    np.testing.assert_allclose(np.asarray(logits1[:, :-1]),
                               np.asarray(logits2[:, :-1]), atol=1e-5)


def test_ssm_causality():
    cfg = make_cfg("ssm")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits1, _ = model.forward(params, batch)
    toks2 = batch["tokens"].at[:, -1].set(
        (batch["tokens"][:, -1] + 7) % cfg.vocab_size)
    logits2, _ = model.forward(params, {**batch, "tokens": toks2})
    np.testing.assert_allclose(np.asarray(logits1[:, :-1]),
                               np.asarray(logits2[:, :-1]), atol=1e-5)
