"""Unit + property tests for the 2x2 cell physics (paper Sec. II)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core import cell

jax.config.update("jax_platform_name", "cpu")

angles = st.floats(min_value=0.0, max_value=2 * np.pi, allow_nan=False)


def test_structural_equals_closed_form():
    th = jnp.linspace(0, 2 * np.pi, 17)
    ph = jnp.linspace(0, 2 * np.pi, 17)
    t1 = cell.cell_matrix(th, ph)
    t2 = cell.cell_matrix_structural(th, ph)
    np.testing.assert_allclose(np.asarray(t1), np.asarray(t2), atol=1e-6)


@settings(max_examples=25, deadline=None)
@given(theta=angles, phi=angles)
def test_cell_is_unitary(theta, phi):
    t = cell.cell_matrix(jnp.float32(theta), jnp.float32(phi))
    assert bool(cell.is_unitary(t))


@settings(max_examples=25, deadline=None)
@given(theta=angles, phi=angles, p1=st.floats(1e-6, 1e-2), p4=st.floats(1e-6, 1e-2))
def test_power_conservation(theta, phi, p1, p4):
    """Eq. 16/17: P2 + P3 = P1 + P4 for the lossless cell."""
    p2, p3 = cell.output_powers(jnp.float32(theta), jnp.float32(phi), p1, p4)
    np.testing.assert_allclose(float(p2 + p3), p1 + p4, rtol=1e-4)


@settings(max_examples=25, deadline=None)
@given(theta=angles, p1=st.floats(1e-6, 1e-2), p4=st.floats(1e-6, 1e-2))
def test_closed_form_powers(theta, p1, p4):
    """Eqs. (14-15) computed from S-params match Eqs. (16-17)."""
    pa2, pa3 = cell.output_powers(jnp.float32(theta), 0.0, p1, p4)
    pb2, pb3 = cell.output_powers_closed_form(jnp.float32(theta), p1, p4)
    np.testing.assert_allclose(float(pa2), float(pb2), rtol=1e-3, atol=1e-9)
    np.testing.assert_allclose(float(pa3), float(pb3), rtol=1e-3, atol=1e-9)


def test_cross_and_bar_states():
    """theta=0 -> cross state (input 1 -> output 3); theta=pi -> bar state."""
    s = cell.s_parameters(jnp.float32(0.0), jnp.float32(0.0))
    assert abs(float(jnp.abs(s["s21"]))) < 1e-6      # no through
    assert abs(float(jnp.abs(s["s31"])) - 1.0) < 1e-6  # full cross
    s = cell.s_parameters(jnp.float32(np.pi), jnp.float32(0.0))
    assert abs(float(jnp.abs(s["s21"])) - 1.0) < 1e-6  # full through
    assert abs(float(jnp.abs(s["s31"]))) < 1e-6


def test_phi_only_shifts_port2_phase():
    """Paper: phi adds phase at P2 and does not affect magnitudes."""
    th = jnp.float32(1.1)
    s0 = cell.s_parameters(th, jnp.float32(0.0))
    s1 = cell.s_parameters(th, jnp.float32(0.7))
    for k in ("s21", "s24", "s31", "s34"):
        np.testing.assert_allclose(float(jnp.abs(s0[k])), float(jnp.abs(s1[k])),
                                   atol=1e-6)
    d21 = float(jnp.angle(s1["s21"]) - jnp.angle(s0["s21"]))
    d31 = float(jnp.angle(s1["s31"]) - jnp.angle(s0["s31"]))
    assert abs((d21 + 0.7 + np.pi) % (2 * np.pi) - np.pi) < 1e-5
    assert abs(d31) < 1e-6


def test_table_i_constants():
    assert len(cell.TABLE_I_PHASES_DEG) == cell.N_DISCRETE_STATES == 6
    assert cell.TABLE_I_PHASES_DEG[0] == 29.0
    assert cell.TABLE_I_PHASES_DEG[-1] == 154.0


def test_complementary_power_split():
    """Fig. 3(d): P2 max where P3 min, sweeping theta."""
    th = jnp.linspace(0, 2 * np.pi, 201)
    p2, p3 = cell.output_powers_closed_form(th, 0.5e-3, 1.5e-3)
    tot = np.asarray(p2 + p3)
    np.testing.assert_allclose(tot, 2e-3, rtol=1e-5)
    assert abs(int(jnp.argmax(p2)) - int(jnp.argmin(p3))) <= 1
