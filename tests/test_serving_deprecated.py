"""Deprecated-shim tests — the ONE file allowed to import the retired
batcher names (the CI grep guard excludes it).  Verifies the shims keep
old call sites working for one release, warn, and map onto the engine."""

import warnings

import jax
import numpy as np
import pytest

from repro.serving import Request, ServingEngine

jax.config.update("jax_platform_name", "cpu")


def test_retired_batchers_warn_on_access():
    from repro import serving

    for name in ("AnalogRequest", "AnalogTickBatcher", "ContinuousBatcher"):
        assert name not in serving.__all__
        assert name in dir(serving)   # still reachable, one release
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        serving.AnalogRequest(rid=0, features=np.ones(8, np.float32))
    assert any(w.category is DeprecationWarning for w in rec)


def _compiled_tiled(seed=11):
    from repro import compile as compile_mod

    w = np.random.default_rng(seed).normal(size=(8, 8)) / np.sqrt(8)
    return w, compile_mod.lower_tiled(compile_mod.program_tiled(
        compile_mod.synthesize_tiled(w, tile=4), method="reck"))


def test_analog_shims_serve_and_warn():
    from repro.serving import AnalogRequest, AnalogTickBatcher

    w, comp = _compiled_tiled()
    with warnings.catch_warnings(record=True) as rec:
        warnings.simplefilter("always")
        batcher = AnalogTickBatcher(comp, slots=2)
        reqs = [AnalogRequest(rid=i, features=np.full(8, 1.0, np.float32),
                              deadline_ticks=None) for i in range(3)]
    assert sum(1 for x in rec if x.category is DeprecationWarning) >= 2
    assert isinstance(batcher, ServingEngine)
    assert all(isinstance(r, Request) for r in reqs)
    for r in reqs:
        batcher.submit(r)
    batcher.run()
    assert all(r.done and not r.failed for r in reqs)
    for r in reqs:
        np.testing.assert_allclose(r.result, np.abs(r.features @ w.T),
                                   atol=1e-4)


def test_analog_shim_stats_keep_old_keys():
    """Old dashboards read served/dropped/recovered; `dropped` maps to
    the engine's `expired` counter."""
    from repro.serving import AnalogRequest, AnalogTickBatcher

    _, comp = _compiled_tiled()
    with warnings.catch_warnings():
        warnings.simplefilter("ignore", DeprecationWarning)
        batcher = AnalogTickBatcher(comp, slots=1)
        reqs = [AnalogRequest(rid=i, features=np.ones(8, np.float32),
                              deadline_ticks=2) for i in range(5)]
    for r in reqs:
        batcher.submit(r)
    batcher.run()
    assert batcher.stats == {"served": 2, "dropped": 3, "recovered": 0}


def test_lm_shim_serves_and_warns():
    from repro import configs
    from repro.models import Model
    from repro.serving import ContinuousBatcher

    cfg = configs.get_reduced("tinyllama-1.1b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    with pytest.warns(DeprecationWarning):
        b = ContinuousBatcher(model, params, slots=2, max_len=32)
    prompts = np.random.default_rng(1).integers(
        0, cfg.vocab_size, size=(3, 4)).astype(np.int32)
    reqs = [Request(rid=i, prompt=prompts[i], max_new=3) for i in range(3)]
    for r in reqs:
        b.submit(r)
    b.run()
    assert all(r.done and len(r.output) == 3 for r in reqs)
