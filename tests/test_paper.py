"""Reproduction tests for the paper's experiments (fast CI versions).

The full-size numbers live in the benchmark harness; these assert the same
claims at reduced scale.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.digits import load_digits
from repro.data.toys import make_toy_dataset, train_test_split
from repro.paper.efficiency import (
    rfnn_delay_ns,
    rfnn_energy_per_flop_fj,
    rfnn_reconfig_power_mw,
)
from repro.paper.mnist_rfnn import confusion_matrix, train_mnist
from repro.paper.prototype import IDEAL_CELL, PROTOTYPE
from repro.paper.rfnn2x2 import RFNN2x2, accuracy, decision_map, train_rfnn2x2

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# Sec. V efficiency model (Table II)
# ---------------------------------------------------------------------------

def test_energy_per_flop_matches_paper():
    """Paper: passive RFNN energy scales as 1/(2N) fJ/FLOP."""
    for n in (8, 20, 64):
        np.testing.assert_allclose(rfnn_energy_per_flop_fj(n), 1.0 / (2 * n),
                                   rtol=1e-6)


def test_reconfig_power_matches_paper():
    """Paper: 0.12 x N(N+1) mW of switch power."""
    np.testing.assert_allclose(rfnn_reconfig_power_mw(8), 0.12 * 8 * 9,
                               rtol=1e-6)


def test_delay_is_ns_scale():
    assert 0.1 < rfnn_delay_ns(20) < 100.0  # paper Table II: ns


# ---------------------------------------------------------------------------
# Sec. III prototype behaviour
# ---------------------------------------------------------------------------

def test_prototype_peak_below_theory():
    """Fig. 6: measured peak |S21| below theory due to loss/imperfection."""
    from repro.core.cell import TABLE_I_PHASES_RAD
    from repro.core.hardware import imperfect_cell_matrix
    th = jnp.asarray(TABLE_I_PHASES_RAD)
    phi = jnp.zeros_like(th)
    s_ideal = np.abs(np.asarray(
        imperfect_cell_matrix(th, phi, IDEAL_CELL)[..., 0, 0]))
    s_hw = np.abs(np.asarray(
        imperfect_cell_matrix(th, phi, PROTOTYPE)[..., 0, 0]))
    assert s_hw.max() < s_ideal.max()
    loss_db = 20 * np.log10(s_hw.max() / s_ideal.max())
    assert -3.0 < loss_db < -0.3  # around a dB of excess loss


# ---------------------------------------------------------------------------
# Sec. IV-A: 2x2 RFNN classification
# ---------------------------------------------------------------------------

def test_2x2_classifier_diag():
    x, y = make_toy_dataset("diag_up", n=240, seed=1)
    xtr, ytr, xte, yte = train_test_split(x, y)
    net, params, codes, info = train_rfnn2x2(xtr, ytr, steps=400, seed=0)
    te = accuracy(net, params, codes["theta"], codes["phi"], xte, yte)
    assert te > 0.9


def test_2x2_classifier_dspsa_path():
    """Algorithm I with DSPSA over the device codes also trains."""
    x, y = make_toy_dataset("corner", n=160, seed=2)
    net, params, codes, info = train_rfnn2x2(x, y, method="dspsa", steps=300,
                                             seed=0)
    assert info["train_acc"] > 0.8
    assert 0 <= codes["theta"] < 6 and 0 <= codes["phi"] < 6


def test_decision_map_is_wedge_like():
    """Fig. 8: the y_hat map contains both classes with a sharp transition."""
    x, y = make_toy_dataset("diag_up", n=240, seed=1)
    net, params, codes, _ = train_rfnn2x2(x, y, steps=400, seed=0)
    _, z = decision_map(net, params, codes["theta"], codes["phi"], n=21)
    assert z.min() < 0.2 and z.max() > 0.8  # both regions present


def test_device_output_uses_abs_activation():
    """The device readout is non-negative (magnitude detection)."""
    net = RFNN2x2()
    x = np.asarray([[3.0, 25.0], [20.0, 4.0]], np.float32)
    mag = net.device_output(2, 3, jnp.asarray(x))
    assert float(jnp.min(mag)) >= 0.0


# ---------------------------------------------------------------------------
# Sec. IV-B: MNIST-style RFNN (reduced size for CI)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def digits():
    return load_digits(n_train=800, n_test=300, seed=0)


@pytest.fixture(scope="module")
def digital_run(digits):
    return train_mnist(*digits, analog=False, epochs=40)


@pytest.fixture(scope="module")
def analog_run(digits):
    # hardware-in-the-loop training needs the paper's full step budget
    # (minibatch 10, lr 0.005, 100 epochs) to converge through the lossy
    # prototype model; trained once and shared by the assertions below.
    return train_mnist(*digits, analog=True, epochs=100,
                       schedule="algorithm1")


@pytest.mark.slow
def test_mnist_digital_baseline(digital_run):
    assert digital_run["test_acc"] > 0.85


@pytest.mark.slow
def test_mnist_analog_and_gap(digital_run, analog_run):
    assert analog_run["test_acc"] > 0.75
    gap = digital_run["test_acc"] - analog_run["test_acc"]
    assert gap < 0.15  # paper: 1.5 pts at full scale
    # the mesh really is discrete: phases from the Table-I codebook
    from repro.core.quantize import nearest_code, table_i_codebook
    cb = np.asarray(table_i_codebook())
    th = np.asarray(analog_run["params"]["mesh"]["theta"])
    assert np.isin(th.round(5), cb.round(5)).all()


@pytest.mark.slow
def test_mnist_confusion_diagonal(digits, analog_run):
    cm = confusion_matrix(analog_run["model"], analog_run["params"],
                          digits[2], digits[3])
    assert np.trace(cm) / cm.sum() > 0.7
