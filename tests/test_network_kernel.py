"""Network-megakernel validation: the fused L-layer sweep vs the
per-layer kernel composition (differential), ragged batches, schedule
memoization, and the coefficient-pack cache."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import decompose, mesh as mesh_lib
from repro.core.analog_linear import AnalogSequence
from repro.core.hardware import HardwareModel
from repro.kernels import ops
from repro.kernels.schedule import network_schedule

jax.config.update("jax_platform_name", "cpu")


def _make_layers(n, depth, *, seed=0, screens=False):
    plan = mesh_lib.clements_plan(n)
    layers = []
    for l in range(depth):
        kv, ku, ka, ks = jax.random.split(
            jax.random.fold_in(jax.random.PRNGKey(seed), l), 4)
        vp = mesh_lib.init_mesh_params(kv, plan)
        up = mesh_lib.init_mesh_params(ku, plan)
        if screens:
            vp["alpha_in"] = jax.random.uniform(ks, (n,)) * 2 * np.pi
            up["alpha_in"] = jax.random.uniform(
                jax.random.fold_in(ks, 1), (n,)) * 2 * np.pi
        layers.append({
            "v": vp, "u": up,
            "atten": jax.random.uniform(ka, (n,), minval=0.2, maxval=0.9),
            "scale": 1.0 + 0.1 * l,
        })
    return tuple(layers)


def _per_layer(layers, x, n, *, plans=None, hardware=None):
    h = x
    for i, la in enumerate(layers):
        vp, up = (plans[i] if plans is not None else (None, None))
        h = ops.rfnn_linear(la["v"], la["atten"], la["u"], h, n=n,
                            scale=la["scale"], v_plan=vp, u_plan=up,
                            hardware=hardware,
                            key_v=la.get("key_v"), key_u=la.get("key_u"))
    return h


def _rand_x(n, batch, seed=0, complex_=True):
    k = jax.random.PRNGKey(seed)
    xr = jax.random.normal(k, (batch, n))
    if not complex_:
        return xr
    xi = jax.random.normal(jax.random.fold_in(k, 1), (batch, n))
    return (xr + 1j * xi).astype(jnp.complex64)


def _max_rel_err(got, want):
    scale = max(float(jnp.max(jnp.abs(g))) for g in jax.tree.leaves(want))
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(want)))
    return err / (scale + 1e-30)


# ---------------------------------------------------------------------------
# differential: megakernel vs per-layer composition
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,depth", [(4, 1), (8, 3), (16, 4)])
def test_network_forward_matches_per_layer(n, depth):
    layers = _make_layers(n, depth, screens=True)
    x = _rand_x(n, 9)
    y_pl = _per_layer(layers, x, n)
    y_net = ops.rfnn_network(layers, x, n=n)
    np.testing.assert_allclose(np.asarray(y_net), np.asarray(y_pl),
                               atol=1e-5 * n)


def test_network_grads_match_per_layer():
    """The acceptance bar: megakernel grads == per-layer path ≤1e-5 rel."""
    n, depth = 16, 4
    layers = _make_layers(n, depth, screens=True)
    x = _rand_x(n, 32)
    w = 1.0 + jnp.arange(n, dtype=jnp.float32)  # break |.|-degeneracies

    def loss_net(ls, xx):
        return jnp.sum(ops.rfnn_network(ls, xx, n=n) * w)

    def loss_pl(ls, xx):
        return jnp.sum(_per_layer(ls, xx, n) * w)

    g_net = jax.jit(jax.grad(loss_net, argnums=(0, 1)))(layers, x)
    g_pl = jax.jit(jax.grad(loss_pl, argnums=(0, 1)))(layers, x)
    assert _max_rel_err(g_net, g_pl) <= 1e-5


def test_network_mixed_plans_identity_padding():
    """Reck programs are deeper than Clements: stacking both exercises the
    identity-column padding, which must be an exact no-op."""
    n = 8
    rplan, rparams = decompose.reck_program(
        decompose.random_unitary(n, seed=3))
    layers = list(_make_layers(n, 2, seed=5))
    layers[0] = dict(layers[0], v=dict(rparams))
    layers = tuple(layers)
    plans = ((rplan, None), (None, None))
    x = _rand_x(n, 7)
    y_pl = _per_layer(layers, x, n, plans=plans)
    y_net = ops.rfnn_network(layers, x, n=n, plans=plans)
    np.testing.assert_allclose(np.asarray(y_net), np.asarray(y_pl),
                               atol=1e-4)
    net = network_schedule(n, 2, plans)
    assert net.n_columns > net.layers[1][0].n_columns  # padding actually used


def test_network_hardware_draw_parity():
    """Non-ideal cells + phase-noise keys: megakernel and per-layer paths
    must consume keys identically (draw-for-draw agreement)."""
    n, depth = 8, 2
    hw = HardwareModel()
    base = _make_layers(n, depth, seed=2)
    key = jax.random.PRNGKey(11)
    layers = []
    for l, la in enumerate(base):
        kv, ku = jax.random.split(jax.random.fold_in(key, l))
        layers.append(dict(la, key_v=kv, key_u=ku))
    layers = tuple(layers)
    x = _rand_x(n, 6)
    y_pl = _per_layer(layers, x, n, hardware=hw)
    y_net = ops.rfnn_network(layers, x, n=n, hardware=hw)
    np.testing.assert_allclose(np.asarray(y_net), np.asarray(y_pl),
                               atol=1e-5)


# ---------------------------------------------------------------------------
# ragged batches
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("batch", [1, 7, 130])
def test_network_ragged_batches(batch):
    """B need not divide the batch block: the tail block is zero-padded and
    masked in forward and VJP."""
    n, depth = 8, 2
    layers = _make_layers(n, depth)
    x = _rand_x(n, batch)
    y_pl = _per_layer(layers, x, n)
    y_net = ops.rfnn_network(layers, x, n=n, block_b=64)
    assert y_net.shape == (batch, n)
    np.testing.assert_allclose(np.asarray(y_net), np.asarray(y_pl),
                               atol=1e-5)

    w = 1.0 + jnp.arange(n, dtype=jnp.float32)
    g_net = jax.grad(lambda ls: jnp.sum(
        ops.rfnn_network(ls, x, n=n, block_b=64) * w))(layers)
    g_pl = jax.grad(lambda ls: jnp.sum(_per_layer(ls, x, n) * w))(layers)
    assert _max_rel_err(g_net, g_pl) <= 1e-5


@pytest.mark.parametrize("batch", [1, 7, 130])
def test_mesh_apply_ragged_batches(batch):
    """The single-mesh kernel path under the same ragged sizes."""
    from repro.kernels import ref

    n = 8
    plan = mesh_lib.clements_plan(n)
    params = mesh_lib.init_mesh_params(jax.random.PRNGKey(0), plan)
    x = _rand_x(n, batch)
    y = ops.mesh_apply(params, x, n=n, block_b=64)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref.mesh_apply_ref(params, x, n)),
                               atol=1e-4)
    g_k = jax.grad(lambda p: jnp.sum(jnp.abs(
        ops.mesh_apply(p, x, n=n, block_b=64))))(params)
    g_r = jax.grad(lambda p: jnp.sum(jnp.abs(
        ref.mesh_apply_ref(p, x, n))))(params)
    assert _max_rel_err(g_k, g_r) <= 1e-4


# ---------------------------------------------------------------------------
# memoization: schedule lowering + trace cache + pack cache
# ---------------------------------------------------------------------------

def test_schedule_lowering_memoized_no_retrace():
    """Structurally equal plans (fresh objects) must reuse the same
    MeshSchedule and must NOT re-trigger a jit trace."""
    from repro.kernels.schedule import schedule_from_plan

    n = 8
    p1 = mesh_lib.clements_plan(n)
    p2 = mesh_lib._make_plan(n, p1.top.copy(), p1.active.copy())
    assert p2 is not p1 and p2 == p1
    assert schedule_from_plan(p1) is schedule_from_plan(p2)

    params = mesh_lib.init_mesh_params(jax.random.PRNGKey(0), p1)
    x = _rand_x(n, 4)
    ops.mesh_apply(params, x, n=n, plan=p1)
    before = ops.TRACE_COUNTS["mesh_apply"]
    ops.mesh_apply(params, x, n=n, plan=p2)
    assert ops.TRACE_COUNTS["mesh_apply"] == before  # no retrace


def test_network_schedule_memoized_no_retrace():
    n, depth = 8, 2
    layers = _make_layers(n, depth)
    x = _rand_x(n, 4)
    ops.rfnn_network(layers, x, n=n)
    before = ops.TRACE_COUNTS["rfnn_network"]
    ops.rfnn_network(layers, x, n=n)  # fresh schedule build, equal plans
    assert ops.TRACE_COUNTS["rfnn_network"] == before


def test_pack_cache_steady_state_zero_packing():
    """Same (immutable) params -> cached packed coefficients; new arrays
    -> exactly one new pack."""
    n, depth = 8, 2
    layers = _make_layers(n, depth, seed=7)
    x = _rand_x(n, 4)
    ops.rfnn_network(layers, x, n=n)  # populate
    before = ops.PACK_EVENTS["rfnn_network"]
    for _ in range(5):
        ops.rfnn_network(layers, x, n=n)
    assert ops.PACK_EVENTS["rfnn_network"] == before  # steady state

    bumped = (dict(layers[0], atten=layers[0]["atten"] + 0.01),) + layers[1:]
    ops.rfnn_network(bumped, x, n=n)
    assert ops.PACK_EVENTS["rfnn_network"] == before + 1


# ---------------------------------------------------------------------------
# AnalogSequence: backend equivalence end-to-end
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("quantize", [None, "table1"])
def test_analog_sequence_backends_match(quantize):
    n, depth = 8, 3
    ref_m = AnalogSequence(n=n, depth=depth, quantize=quantize,
                           backend="reference")
    pal_m = AnalogSequence(n=n, depth=depth, quantize=quantize,
                           backend="pallas")
    params = ref_m.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (9, n))
    np.testing.assert_allclose(np.asarray(pal_m.apply(params, x)),
                               np.asarray(ref_m.apply(params, x)),
                               atol=1e-5)
    w = 1.0 + jnp.arange(n, dtype=jnp.float32)
    g_r = jax.grad(lambda p: jnp.sum(ref_m.apply(p, x) * w))(params)
    g_p = jax.grad(lambda p: jnp.sum(pal_m.apply(p, x) * w))(params)
    assert _max_rel_err(g_p, g_r) <= 1e-5


def test_analog_sequence_hardware_key_parity():
    """Phase-noise draws must agree backend-for-backend under one key."""
    n, depth = 8, 2
    hw = HardwareModel(detector_sigma=0.0)
    ref_m = AnalogSequence(n=n, depth=depth, hardware=hw,
                           backend="reference")
    pal_m = AnalogSequence(n=n, depth=depth, hardware=hw, backend="pallas")
    params = ref_m.init(jax.random.PRNGKey(3))
    x = jax.random.normal(jax.random.PRNGKey(4), (5, n))
    key = jax.random.PRNGKey(42)
    np.testing.assert_allclose(
        np.asarray(pal_m.apply(params, x, key=key)),
        np.asarray(ref_m.apply(params, x, key=key)), atol=1e-5)
    # different keys must give different draws (noise actually applied)
    y1 = pal_m.apply(params, x, key=key)
    y2 = pal_m.apply(params, x, key=jax.random.PRNGKey(43))
    assert float(jnp.max(jnp.abs(y1 - y2))) > 1e-6


def test_mnist_rfnn_analog_depth_backends_match():
    from repro.paper.mnist_rfnn import MnistRFNN

    xs = jax.random.normal(jax.random.PRNGKey(1), (6, 784))
    ys = jnp.asarray([0, 1, 2, 3, 4, 5])
    m_ref = MnistRFNN(analog=True, hardware=None, quantize=None,
                      analog_depth=2, backend="reference")
    m_pal = MnistRFNN(analog=True, hardware=None, quantize=None,
                      analog_depth=2, backend="pallas")
    params = m_ref.init(jax.random.PRNGKey(0))
    l_ref, _ = m_ref.loss(params, xs, ys)
    l_pal, _ = m_pal.loss(params, xs, ys)
    assert abs(float(l_ref) - float(l_pal)) < 1e-5

    g_ref = jax.grad(lambda p: m_ref.loss(p, xs, ys)[0])(params)
    g_pal = jax.grad(lambda p: m_pal.loss(p, xs, ys)[0])(params)
    assert _max_rel_err(g_pal, g_ref) <= 1e-4
