"""DSPSA in-situ training convergence regression (paper Algorithm I).

The paper's key robustness claim: on-device discrete training (DSPSA over
the Table-I switch codes, two hardware measurement passes per step)
reaches the reported classification accuracy *despite* the measured
non-idealities.  This pins that behaviour: a seeded 2x2 run on the noisy
prototype hardware model must land in the paper's accuracy band (Fig. 12a
reports ~94% for the corner task) within the fixed step budget — on both
backends, since with the generalized kernels every DSPSA loss evaluation
is a pure forward pass through the fused Pallas path.
"""

import jax
import numpy as np
import pytest

from repro.data.toys import make_toy_dataset
from repro.kernels import ops
from repro.paper.rfnn2x2 import train_rfnn2x2

jax.config.update("jax_platform_name", "cpu")

# CI tiering: DSPSA convergence runs hundreds of two-measurement steps on
# both backends.  Fast leg deselects; full suite on every push to main.
pytestmark = pytest.mark.slow

#: paper band for the Fig. 12a corner task is ~94%; the reduced-size CI
#: dataset and budget land at 93.1% — gate a point below.
ACC_BAND = 0.90


@pytest.fixture(scope="module")
def corner_data():
    return make_toy_dataset("corner", n=160, seed=2)


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_dspsa_2x2_converges_on_noisy_hardware(corner_data, backend):
    x, y = corner_data
    calls_before = ops.KERNEL_PATH_CALLS["mesh_apply"]
    net, params, codes, info = train_rfnn2x2(
        x, y, method="dspsa", steps=200, seed=0, backend=backend)
    assert info["train_acc"] >= ACC_BAND, info
    assert 0 <= codes["theta"] < 6 and 0 <= codes["phi"] < 6
    # the DSPSA history is the two-measurement trace; it must exist and
    # never leave the finite range
    assert len(info["dspsa_history"]) >= 2
    assert np.isfinite(info["dspsa_history"]).all()
    calls = ops.KERNEL_PATH_CALLS["mesh_apply"] - calls_before
    if backend == "pallas":
        # every device measurement pass went through the kernel path
        assert calls > 0
    else:
        assert calls == 0


def test_dspsa_backends_agree_end_to_end(corner_data):
    """Same seed, same data: the discrete training trajectory (selected
    codes and final accuracy) is backend-invariant."""
    x, y = corner_data
    _, _, codes_r, info_r = train_rfnn2x2(x, y, method="dspsa", steps=120,
                                          seed=0, backend="reference")
    _, _, codes_p, info_p = train_rfnn2x2(x, y, method="dspsa", steps=120,
                                          seed=0, backend="pallas")
    assert codes_r == codes_p
    np.testing.assert_allclose(info_p["train_acc"], info_r["train_acc"],
                               atol=1e-3)
