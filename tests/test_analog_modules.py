"""Tests for quantization, hardware model, analog layers and DSPSA."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    AnalogLinear,
    AnalogUnitary,
    HardwareModel,
    IDEAL,
    TiledAnalogLinear,
    apply_mesh_hw,
    clements_plan,
    init_mesh_params,
    table_i_codebook,
    uniform_codebook,
)
from repro.core import dspsa, quantize

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# quantization
# ---------------------------------------------------------------------------

def test_table_i_codebook_values():
    cb = np.asarray(table_i_codebook())
    np.testing.assert_allclose(np.rad2deg(cb), [29, 53, 75, 104, 135, 154],
                               rtol=1e-5)


def test_nearest_code_roundtrip():
    cb = table_i_codebook()
    phases = jnp.asarray(np.deg2rad([30.0, 100.0, 150.0, 55.0]))
    codes = quantize.nearest_code(phases, cb)
    np.testing.assert_array_equal(np.asarray(codes), [0, 3, 5, 1])


def test_nearest_code_is_circular():
    cb = uniform_codebook(2)  # 0, pi/2, pi, 3pi/2
    code = quantize.nearest_code(jnp.asarray([2 * np.pi - 0.01]), cb)
    assert int(code[0]) == 0  # wraps to 0, not 3pi/2


def test_ste_gradient_is_identity():
    cb = table_i_codebook()
    g = jax.grad(lambda p: jnp.sum(quantize.ste_quantize(p, cb) ** 2))(
        jnp.asarray([1.0, 2.0]))
    q = quantize.ste_quantize(jnp.asarray([1.0, 2.0]), cb)
    np.testing.assert_allclose(np.asarray(g), 2 * np.asarray(q), atol=1e-6)


def test_quantized_mesh_still_unitary():
    """Discrete phases restrict, but never break, unitarity."""
    from repro.core import mesh as mesh_lib
    plan = clements_plan(8)
    params = init_mesh_params(jax.random.PRNGKey(0), plan)
    qp = quantize.quantize_mesh_params(params, table_i_codebook())
    assert mesh_lib.mesh_is_unitary(plan, qp)


# ---------------------------------------------------------------------------
# hardware model
# ---------------------------------------------------------------------------

def test_hardware_mesh_is_passive():
    plan = clements_plan(8)
    params = init_mesh_params(jax.random.PRNGKey(1), plan)
    hw = HardwareModel()
    u = apply_mesh_hw(plan, params, jnp.eye(8, dtype=jnp.complex64), hw).T
    row_power = jnp.sum(jnp.abs(u) ** 2, axis=1)
    assert float(row_power.max()) <= 1.0 + 1e-5


def test_hardware_loss_scales_with_depth():
    """More loss per cell -> lower total transmission."""
    plan = clements_plan(8)
    params = init_mesh_params(jax.random.PRNGKey(1), plan)
    powers = []
    for loss_db in (0.0, 0.25, 1.0):
        hw = HardwareModel(cell_loss_db=loss_db, hybrid_imbalance=0.0,
                           hybrid_phase_err=0.0, phase_sigma=0.0)
        u = apply_mesh_hw(plan, params, jnp.eye(8, dtype=jnp.complex64), hw).T
        powers.append(float(jnp.sum(jnp.abs(u) ** 2)))
    assert powers[0] > powers[1] > powers[2]
    np.testing.assert_allclose(powers[0], 8.0, rtol=1e-4)  # lossless = unitary


def test_ideal_hardware_matches_theory():
    from repro.core import mesh as mesh_lib
    plan = clements_plan(4)
    params = init_mesh_params(jax.random.PRNGKey(2), plan)
    x = jnp.ones((3, 4), jnp.complex64)
    y_hw = apply_mesh_hw(plan, params, x, IDEAL)
    y_th = mesh_lib.apply_mesh(plan, params, x)
    np.testing.assert_allclose(np.asarray(y_hw), np.asarray(y_th), atol=1e-5)


def test_detector_floor():
    from repro.core.hardware import detect_magnitude
    hw = HardwareModel(detector_floor_dbm=-60.0, detector_sigma=0.0)
    tiny = jnp.asarray([1e-9 + 0j])
    v = detect_magnitude(tiny, hw)
    floor_v = np.sqrt(2 * 50.0 * 10 ** (-60.0 / 10.0) * 1e-3)
    np.testing.assert_allclose(float(v[0]), floor_v, rtol=1e-5)


# ---------------------------------------------------------------------------
# analog layers
# ---------------------------------------------------------------------------

def test_analog_unitary_trains():
    """A few SGD steps reduce a matching loss through the analog layer.

    The target |U x| for a random other mesh U is realizable by the layer,
    so the loss has no structural floor and SGD must make real progress.
    """
    layer = AnalogUnitary(n=4, output="abs")
    params = layer.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 4))
    target_params = layer.init(jax.random.PRNGKey(2))
    target = layer.apply(target_params, x)

    def loss(p):
        return jnp.mean((layer.apply(p, x) - target) ** 2)

    l0 = float(loss(params))
    step = jax.jit(lambda p: jax.tree.map(
        lambda q, g: q - 0.2 * g, p, jax.grad(loss)(p)))
    for _ in range(150):
        params = step(params)
    assert float(loss(params)) < 0.5 * l0


def test_analog_linear_program_matches_matmul():
    rng = np.random.default_rng(0)
    for shape in [(4, 6), (6, 4), (8, 8)]:
        out_d, in_d = shape
        layer = AnalogLinear(in_dim=in_d, out_dim=out_d, output="real")
        w = rng.normal(size=shape)
        params = layer.init_from_matrix(w)
        x = rng.normal(size=(5, in_d)).astype(np.float32)
        y = layer.apply(params, jnp.asarray(x))
        np.testing.assert_allclose(np.asarray(y), x @ w.T, atol=1e-4)


def test_tiled_analog_linear_matches_dense():
    """Programmed tiles == dense matmul: the scale-out path is exact."""
    rng = np.random.default_rng(1)
    tile = 4
    w = rng.normal(size=(8, 12))
    layer = TiledAnalogLinear(in_dim=12, out_dim=8, tile_size=tile,
                              output="real")
    to, ti = layer.grid()
    tiles = [[layer.tile.init_from_matrix(
        w[i * tile:(i + 1) * tile, j * tile:(j + 1) * tile])
        for j in range(ti)] for i in range(to)]
    params = jax.tree.map(lambda *xs: jnp.stack(xs), *[
        jax.tree.map(lambda *ys: jnp.stack(ys), *row) for row in tiles])
    x = rng.normal(size=(3, 12)).astype(np.float32)
    y = layer.apply(params, jnp.asarray(x))
    np.testing.assert_allclose(np.asarray(y), x @ w.T, atol=1e-4)


def test_analog_unitary_quantized_tableI():
    layer = AnalogUnitary(n=8, quantize="table1", output="abs")
    params = layer.init(jax.random.PRNGKey(0))
    y = layer.apply(params, jnp.ones((2, 8)))
    assert y.shape == (2, 8) and bool(jnp.isfinite(y).all())
    # effective phases are all from Table I
    eff = layer.effective_params(params)
    cb = np.asarray(table_i_codebook())
    assert np.isin(np.asarray(eff["theta"]).round(5), cb.round(5)).all()


def test_analog_unitary_with_hardware_noise_reproducible():
    hw = HardwareModel()
    layer = AnalogUnitary(n=4, hardware=hw, output="abs")
    params = layer.init(jax.random.PRNGKey(0))
    k = jax.random.PRNGKey(42)
    y1 = layer.apply(params, jnp.ones((2, 4)), key=k)
    y2 = layer.apply(params, jnp.ones((2, 4)), key=k)
    np.testing.assert_array_equal(np.asarray(y1), np.asarray(y2))


# ---------------------------------------------------------------------------
# DSPSA (Algorithm I)
# ---------------------------------------------------------------------------

def test_dspsa_converges_on_quadratic():
    target = jnp.array([1, 4, 2, 0, 5, 3])

    def loss(codes):
        return jnp.sum((codes["c"].astype(jnp.float32) - target) ** 2)

    best, hist = dspsa.minimize(
        jax.random.PRNGKey(0), {"c": jnp.zeros(6, jnp.int32)}, loss,
        dspsa.DSPSAConfig(a=2.0), steps=200)
    assert min(hist) < hist[0]
    assert min(hist) <= 2.0  # near-exact recovery


def test_dspsa_two_measurement_budget():
    """measure_projection=False is the paper-strict Algorithm-I budget:
    exactly two loss evaluations (device passes) per step."""
    target = jnp.array([1, 4, 2, 0, 5, 3])
    calls = []

    def loss(codes):
        calls.append(1)
        return jnp.sum((codes["c"].astype(jnp.float32) - target) ** 2)

    steps = 50
    best, hist = dspsa.minimize(
        jax.random.PRNGKey(0), {"c": jnp.zeros(6, jnp.int32)}, loss,
        dspsa.DSPSAConfig(a=2.0), steps=steps, measure_projection=False)
    assert len(calls) == 2 * steps
    assert len(hist) == steps
    assert min(hist) < hist[0]  # still converges
    assert best["c"].shape == (6,)


def test_dspsa_codes_stay_in_range():
    cfg = dspsa.DSPSAConfig(a=50.0, n_states=6)  # aggressive gain
    state = dspsa.init({"c": jnp.full(8, 3, jnp.int32)})
    key = jax.random.PRNGKey(0)
    for _ in range(10):
        key, sub = jax.random.split(key)
        state, _ = dspsa.step(sub, state,
                              lambda c: jnp.sum(c["c"].astype(jnp.float32)),
                              cfg)
        codes = dspsa.project(state, cfg)
        assert int(codes["c"].min()) >= 0 and int(codes["c"].max()) <= 5
