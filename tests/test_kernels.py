"""Pallas kernel validation: shape/dtype sweeps vs the pure-jnp oracles."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.core import mesh as mesh_lib
from repro.kernels import ops, ref

jax.config.update("jax_platform_name", "cpu")


def _rand_x(key, batch, n, dtype):
    kr, ki = jax.random.split(key)
    if dtype == jnp.complex64:
        return (jax.random.normal(kr, batch + (n,))
                + 1j * jax.random.normal(ki, batch + (n,))).astype(dtype)
    return jax.random.normal(kr, batch + (n,), dtype)


@pytest.mark.parametrize("n", [2, 4, 8, 16, 32, 64, 128])
def test_mesh_kernel_shape_sweep(n):
    plan = mesh_lib.clements_plan(n)
    params = mesh_lib.init_mesh_params(jax.random.PRNGKey(n), plan)
    x = _rand_x(jax.random.PRNGKey(0), (6,), n, jnp.complex64)
    y_ref = ref.mesh_apply_ref(params, x, n)
    y_ker = ops.mesh_apply(params, x, n=n, block_b=4)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               atol=1e-5 * n)


@pytest.mark.parametrize("batch", [(1,), (3,), (2, 3), (4, 1, 2)])
def test_mesh_kernel_batch_shapes(batch):
    n = 8
    plan = mesh_lib.clements_plan(n)
    params = mesh_lib.init_mesh_params(jax.random.PRNGKey(0), plan)
    x = _rand_x(jax.random.PRNGKey(1), batch, n, jnp.complex64)
    y = ops.mesh_apply(params, x, n=n, block_b=4)
    assert y.shape == batch + (n,)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(ref.mesh_apply_ref(params, x, n)),
                               atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16, jnp.complex64])
def test_mesh_kernel_dtype_sweep(dtype):
    n = 16
    plan = mesh_lib.clements_plan(n)
    params = mesh_lib.init_mesh_params(jax.random.PRNGKey(0), plan)
    x = _rand_x(jax.random.PRNGKey(1), (5,), n, dtype)
    y_ker = ops.mesh_apply(params, x, n=n, block_b=4)
    y_ref = ref.mesh_apply_ref(params, x.astype(jnp.complex64), n)
    tol = 3e-2 if dtype == jnp.bfloat16 else 1e-4
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref), atol=tol)


def test_mesh_kernel_vs_core_apply():
    """Kernel semantics == core apply_mesh (independent implementations)."""
    n = 32
    plan = mesh_lib.clements_plan(n)
    params = mesh_lib.init_mesh_params(jax.random.PRNGKey(7), plan)
    x = _rand_x(jax.random.PRNGKey(8), (9,), n, jnp.complex64)
    y_core = mesh_lib.apply_mesh(plan, params, x)
    y_ker = ops.mesh_apply(params, x, n=n, block_b=8)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_core), atol=1e-4)


def test_mesh_kernel_unitarity():
    n = 16
    plan = mesh_lib.clements_plan(n)
    params = mesh_lib.init_mesh_params(jax.random.PRNGKey(3), plan)
    eye = jnp.eye(n, dtype=jnp.complex64)
    u = ops.mesh_apply(params, eye, n=n, block_b=8).T
    np.testing.assert_allclose(np.asarray(u @ u.conj().T), np.eye(n),
                               atol=1e-4)


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       n=st.sampled_from([4, 8, 16]),
       batch=st.integers(1, 9))
def test_mesh_kernel_property(seed, n, batch):
    plan = mesh_lib.clements_plan(n)
    k1, k2 = jax.random.split(jax.random.PRNGKey(seed))
    params = mesh_lib.init_mesh_params(k1, plan)
    x = _rand_x(k2, (batch,), n, jnp.complex64)
    y_ker = ops.mesh_apply(params, x, n=n, block_b=4)
    y_ref = ref.mesh_apply_ref(params, x, n)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref), atol=1e-4)
    # energy conservation through the kernel too
    np.testing.assert_allclose(np.linalg.norm(np.asarray(y_ker), axis=-1),
                               np.linalg.norm(np.asarray(x), axis=-1),
                               rtol=1e-3)


@pytest.mark.parametrize("n", [4, 16, 64])
def test_fused_rfnn_linear_kernel(n):
    plan = mesh_lib.clements_plan(n)
    vp = mesh_lib.init_mesh_params(jax.random.PRNGKey(0), plan)
    up = mesh_lib.init_mesh_params(jax.random.PRNGKey(1), plan)
    atten = jax.random.uniform(jax.random.PRNGKey(2), (n,))
    x = jax.random.normal(jax.random.PRNGKey(3), (7, n))
    y_ref = ref.rfnn_linear_ref(vp, atten, up, x.astype(jnp.complex64), n, 1.7)
    y_ker = ops.rfnn_linear(vp, atten, up, x, n=n, scale=1.7, block_b=4)
    np.testing.assert_allclose(np.asarray(y_ker), np.asarray(y_ref),
                               atol=1e-4 * n)


def test_fused_kernel_nonnegative_detection():
    """Detected magnitudes are physical: non-negative."""
    n = 8
    plan = mesh_lib.clements_plan(n)
    vp = mesh_lib.init_mesh_params(jax.random.PRNGKey(0), plan)
    up = mesh_lib.init_mesh_params(jax.random.PRNGKey(1), plan)
    y = ops.rfnn_linear(vp, jnp.ones(n), up,
                        -jnp.ones((3, n)), n=n, block_b=4)
    assert float(jnp.min(y)) >= 0.0
