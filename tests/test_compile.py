"""The analog program compiler: synthesize -> program -> lower -> serve.

Covers the tentpole contract: every stage of the digital->analog transfer
runs on the Pallas kernels (no reference fallback), lowering emits the
megakernel tensors exactly once through the pack cache, and serving a
compiled program performs zero packing work.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import compile as compile_mod
from repro.kernels import ops

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# synthesize + program
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("shape", [(3, 5), (5, 3), (8, 8)])
def test_synthesize_program_reck_realizes_matrix(shape):
    m = np.random.default_rng(0).normal(size=shape)
    prog = compile_mod.program(compile_mod.synthesize(m), method="reck")
    assert compile_mod.program_error(prog) < 1e-4
    assert float(jnp.max(prog.layers[0].attenuation)) <= 1.0 + 1e-6


def test_synthesize_stack_shares_mesh_size():
    mats = [np.ones((3, 5)), np.ones((8, 3))]
    prog = compile_mod.synthesize(mats)
    assert prog.n == 8 and prog.depth == 2
    assert prog.in_dim == 5 and prog.out_dim == 8


def test_synthesize_rejects_nonchaining_stack():
    with pytest.raises(ValueError, match="does not chain"):
        compile_mod.synthesize([np.ones((4, 6)), np.ones((8, 3))])


def test_synthesize_accepts_plain_nested_list():
    """The legacy svd_synthesis surface accepted a plain 2-D list."""
    prog = compile_mod.synthesize([[1.0, 0.0], [0.0, 1.0]])
    assert prog.depth == 1 and prog.layers[0].target.shape == (2, 2)


def test_program_fit_is_kernel_backed():
    """The gradient programming path sweeps identity probes through
    ``ops.mesh_apply`` — the paper's stochastic-optimization programming
    with no pure-jnp reference anywhere in the loss."""
    m = np.random.default_rng(1).normal(size=(4, 4))
    before = ops.KERNEL_PATH_CALLS["mesh_apply"]
    prog = compile_mod.program(compile_mod.synthesize(m), method="fit",
                               steps=1200, lr=0.05, seed=0)
    assert ops.KERNEL_PATH_CALLS["mesh_apply"] > before
    assert compile_mod.program_error(prog) < 2e-2


# ---------------------------------------------------------------------------
# lower + apply: megakernel path, packing exactly once
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def two_layer():
    rng = np.random.default_rng(2)
    mats = [rng.normal(size=(8, 8)) * 0.5 for _ in range(2)]
    prog = compile_mod.program(compile_mod.synthesize(mats), method="reck")
    return mats, prog


def test_lower_packs_once_apply_never_repacks(two_layer):
    mats, prog = two_layer
    packs = ops.PACK_EVENTS["rfnn_network"]
    compiled = compile_mod.lower(prog)
    assert ops.PACK_EVENTS["rfnn_network"] == packs + 1  # emitted at lower
    calls = ops.KERNEL_PATH_CALLS["rfnn_network"]
    x = jnp.asarray(np.random.default_rng(3).normal(size=(5, 8)),
                    jnp.float32)
    for _ in range(3):
        compiled.apply(x)
    assert ops.KERNEL_PATH_CALLS["rfnn_network"] == calls + 3  # megakernel
    assert ops.PACK_EVENTS["rfnn_network"] == packs + 1  # zero repacking


def test_compiled_apply_matches_digital_stack(two_layer):
    """|M2 |M1 x|| through the fused megakernel == the digital twin."""
    mats, prog = two_layer
    compiled = compile_mod.lower(prog)
    x = np.random.default_rng(4).normal(size=(6, 8)).astype(np.float32)
    want = np.abs(np.abs(x @ mats[0].T) @ mats[1].T)
    got = np.asarray(compiled.apply(jnp.asarray(x)))
    np.testing.assert_allclose(got, want, atol=1e-4)


def test_compiled_apply_pads_rectangular_input():
    m = np.random.default_rng(5).normal(size=(3, 5))
    compiled = compile_mod.lower(
        compile_mod.program(compile_mod.synthesize(m), method="reck"))
    x = np.random.default_rng(6).normal(size=(4, 5)).astype(np.float32)
    got = np.asarray(compiled.apply(jnp.asarray(x)))
    np.testing.assert_allclose(got, np.abs(x @ m.T), atol=1e-4)


def test_lower_rejects_unprogrammed_program():
    prog = compile_mod.synthesize(np.ones((4, 4)))
    with pytest.raises(ValueError):
        compile_mod.lower(prog)


def test_compiled_programs_survive_pack_cache_eviction():
    """A CompiledProgram carries its own emitted tensors (``packed=``), so
    serving many programs round-robin — more than the shared pack cache
    holds — still never repacks."""
    rng = np.random.default_rng(9)
    programs, mats = [], []
    for i in range(10):   # > _NETWORK_PACK_CACHE maxsize (8)
        m = rng.normal(size=(2, 2))
        mats.append(m)
        programs.append(compile_mod.lower(
            compile_mod.program(compile_mod.synthesize(m), method="reck")))
    packs = ops.PACK_EVENTS["rfnn_network"]
    x = jnp.asarray(rng.normal(size=(3, 2)), jnp.float32)
    for _ in range(2):
        for m, comp in zip(mats, programs):
            np.testing.assert_allclose(np.asarray(comp.apply(x)),
                                       np.abs(np.asarray(x) @ m.T),
                                       atol=1e-4)
    assert ops.PACK_EVENTS["rfnn_network"] == packs  # zero repacking


# ---------------------------------------------------------------------------
# the repointed legacy surfaces
# ---------------------------------------------------------------------------

def test_synthesized_matrix_apply_routes_through_kernels():
    """core.svd_synthesis is now a facade: apply = two kernel mesh sweeps,
    no pure-jnp reference chain left."""
    from repro.core import svd_synthesis

    m = np.random.default_rng(7).normal(size=(4, 4))
    syn = svd_synthesis.synthesize(m)
    before = ops.KERNEL_PATH_CALLS["mesh_apply"]
    assert svd_synthesis.synthesis_error(m, syn) < 1e-4
    assert ops.KERNEL_PATH_CALLS["mesh_apply"] == before + 2  # V and U


# ---------------------------------------------------------------------------
# serving a compiled program
# ---------------------------------------------------------------------------

def test_serving_compiled_program_zero_packing(two_layer):
    from repro.serving import Request, ServingEngine

    mats, prog = two_layer
    compiled = compile_mod.lower(prog)
    engine = ServingEngine(compiled, slots=3)
    packs = ops.PACK_EVENTS["rfnn_network"]
    rng = np.random.default_rng(8)
    for round_ in range(3):
        reqs = [Request(rid=i,
                        features=rng.normal(size=8).astype(np.float32))
                for i in range(7)]
        for r in reqs:
            engine.submit(r)
        engine.run()
        assert all(r.done for r in reqs)
        for r in reqs:
            want = np.abs(np.abs(r.features @ mats[0].T) @ mats[1].T)
            np.testing.assert_allclose(r.result, want, atol=1e-4)
    # the program was packed at lower time; serving never packs — first
    # tick included
    assert ops.PACK_EVENTS["rfnn_network"] == packs


# ---------------------------------------------------------------------------
# the end-to-end MNIST digital->analog transfer (acceptance scenario)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_mnist_digital_to_analog_transfer_on_megakernel():
    """4-layer 8x8 stack: train digital, compile every layer, serve on the
    network megakernel.  The float transfer is exact (no accuracy drop)
    and every analog evaluation is a megakernel call — KERNEL_PATH_CALLS
    pins that there is no reference fallback."""
    from repro.data import load_digits
    from repro.paper.mnist_rfnn import digital_to_analog_transfer

    x_tr, y_tr, x_te, y_te = load_digits(n_train=400, n_test=150, seed=0)
    settings = ("float", "uniform6")
    calls = ops.KERNEL_PATH_CALLS["rfnn_network"]
    res = digital_to_analog_transfer(
        x_tr, y_tr, x_te, y_te, depth=4, epochs=12, settings=settings)
    assert ops.KERNEL_PATH_CALLS["rfnn_network"] - calls == len(settings)
    f = res["settings"]["float"]
    assert f["synthesis_error"] < 1e-4
    assert abs(f["acc_drop"]) <= 0.01  # float transfer is (near-)exact
    assert res["compiled"]["float"].depth == 4
    # quantized deployment degrades synthesis but still serves end to end
    assert res["settings"]["uniform6"]["synthesis_error"] > f["synthesis_error"]


# ---------------------------------------------------------------------------
# tiled pipeline: per-tile-SVD grids on the tile-grid megakernel
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiled_prog():
    """A ragged 10x12 matrix on a 3x3 grid of 4x4 tiles, Reck-programmed."""
    w = np.random.default_rng(3).normal(size=(10, 12)) / np.sqrt(12)
    tp = compile_mod.program_tiled(
        compile_mod.synthesize_tiled(w, tile=4), method="reck")
    return w, tp


def test_synthesize_tiled_pads_and_realizes(tiled_prog):
    w, tp = tiled_prog
    assert (tp.to, tp.ti) == (3, 3)  # 10x12 zero-padded to 12x12
    assert tp.programmed
    assert np.abs(tp.realized_matrix() - w).max() < 1e-4


def test_lower_tiled_apply_is_one_kernel_call(tiled_prog):
    """Compiled tile-grid apply == |w @ x| via ONE tiled_apply call."""
    w, tp = tiled_prog
    comp = compile_mod.lower_tiled(tp)
    x = np.random.default_rng(4).normal(size=(5, 12)).astype(np.float32)
    calls = ops.KERNEL_PATH_CALLS["tiled_apply"]
    y = comp.apply(jnp.asarray(x))
    assert ops.KERNEL_PATH_CALLS["tiled_apply"] == calls + 1
    np.testing.assert_allclose(np.asarray(y), np.abs(x @ w.T), atol=1e-4)


def test_lower_tiled_packs_once_apply_never_repacks(tiled_prog):
    _, tp = tiled_prog
    packs = ops.PACK_EVENTS["tiled_apply"]
    comp = compile_mod.lower_tiled(tp)
    assert ops.PACK_EVENTS["tiled_apply"] <= packs + 1  # at most one emit
    packs = ops.PACK_EVENTS["tiled_apply"]
    x = jnp.asarray(np.random.default_rng(5).normal(size=(4, 12)),
                    jnp.float32)
    for _ in range(3):
        comp.apply(x)
    assert ops.PACK_EVENTS["tiled_apply"] == packs  # zero packing work


def test_quantize_calibrate_tiled_per_tile_devices(tiled_prog):
    """Quantize + hardware-calibrate per tile: every tile freezes its own
    noise draw, and calibration against the imperfect grid must not
    regress the quantized program's realization error."""
    from repro.core.hardware import HardwareModel

    w, tp = tiled_prog
    hw = HardwareModel(phase_sigma=0.01, detector_sigma=0.0)
    key = jax.random.PRNGKey(7)
    tq = compile_mod.quantize_tiled(tp, "uniform6")
    bound = compile_mod.calibrate_tiled(tq, hw, key=key, steps=0)
    # distinct per-tile draws actually bound
    keys = [la.key_v for row in bound.grid for la in row]
    assert len({tuple(np.asarray(k).ravel()) for k in keys}) == len(keys)
    err_bound = np.abs(bound.realized_matrix() - w).max()
    cal = compile_mod.calibrate_tiled(tq, hw, key=key, steps=40)
    err_cal = np.abs(cal.realized_matrix() - w).max()
    assert err_cal <= err_bound + 1e-6  # best-iterate guard, per tile
    # the calibrated grid lowers and serves on the same frozen draws
    comp = compile_mod.lower_tiled(cal)
    x = jnp.asarray(np.random.default_rng(6).normal(size=(3, 12)),
                    jnp.float32)
    assert comp.apply(x).shape == (3, 10)
