"""Gradient correctness of the Pallas mesh-kernel custom VJPs.

Three layers of evidence, all in interpret mode:
  * kernel-VJP gradients == reference-autodiff gradients (same loss, two
    independent backward implementations) across sizes and output modes;
  * finite-difference directional derivatives agree with the VJP;
  * the VJPs compose with the rest of the stack: STE phase quantization,
    the analog layer modules, and a real SGD training step.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import mesh as mesh_lib
from repro.core.analog_linear import AnalogLinear, AnalogUnitary
from repro.kernels import ops, ref

jax.config.update("jax_platform_name", "cpu")


def _assert_tree_close(a, b, atol):
    for la, lb in zip(jax.tree.leaves(a), jax.tree.leaves(b)):
        np.testing.assert_allclose(np.asarray(la), np.asarray(lb), atol=atol)


def _rand_cx(key, shape):
    kr, ki = jax.random.split(key)
    return (jax.random.normal(kr, shape)
            + 1j * jax.random.normal(ki, shape)).astype(jnp.complex64)


# ---------------------------------------------------------------------------
# kernel VJP vs reference autodiff
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n", [2, 8, 16])
def test_mesh_kernel_vjp_matches_reference(n):
    """grad through mesh_apply (complex output) == grad through the oracle."""
    plan = mesh_lib.clements_plan(n)
    params = mesh_lib.init_mesh_params(jax.random.PRNGKey(n), plan)
    x = _rand_cx(jax.random.PRNGKey(1), (5, n))
    wr = jax.random.normal(jax.random.PRNGKey(2), (5, n))
    wi = jax.random.normal(jax.random.PRNGKey(3), (5, n))

    def loss_k(p, xx):
        y = ops.mesh_apply(p, xx, n=n, block_b=4)
        return jnp.sum(wr * jnp.real(y) + wi * jnp.imag(y))

    def loss_r(p, xx):
        y = ref.mesh_apply_ref(p, xx, n)
        return jnp.sum(wr * jnp.real(y) + wi * jnp.imag(y))

    gk = jax.grad(loss_k, argnums=(0, 1))(params, x)
    gr = jax.grad(loss_r, argnums=(0, 1))(params, x)
    _assert_tree_close(gk, gr, atol=1e-4)


@pytest.mark.parametrize("n", [2, 8, 16])
def test_rfnn_linear_vjp_matches_reference(n):
    """grad through the fused |U D V x| kernel (abs output) == reference,
    w.r.t. both mesh params, attenuation, the digital scale and x."""
    plan = mesh_lib.clements_plan(n)
    vp = mesh_lib.init_mesh_params(jax.random.PRNGKey(0), plan)
    up = mesh_lib.init_mesh_params(jax.random.PRNGKey(1), plan)
    atten = jax.random.uniform(jax.random.PRNGKey(2), (n,), minval=0.1,
                               maxval=0.9)
    x = jax.random.normal(jax.random.PRNGKey(3), (7, n))
    w = jax.random.normal(jax.random.PRNGKey(4), (7, n))
    scale = jnp.asarray(1.7)

    def loss_k(v, a, u, s, xx):
        return jnp.sum(w * ops.rfnn_linear(v, a, u, xx, n=n, scale=s,
                                           block_b=4))

    def loss_r(v, a, u, s, xx):
        return jnp.sum(w * ref.rfnn_linear_ref(v, a, u,
                                               xx.astype(jnp.complex64),
                                               n, s))

    args = (vp, atten, up, scale, x)
    gk = jax.grad(loss_k, argnums=(0, 1, 2, 3, 4))(*args)
    gr = jax.grad(loss_r, argnums=(0, 1, 2, 3, 4))(*args)
    _assert_tree_close(gk, gr, atol=1e-4)


def test_mesh_vjp_respects_phase_screens():
    """alpha / alpha_in screens stay differentiable around the kernel."""
    n = 8
    plan = mesh_lib.clements_plan(n)
    params = mesh_lib.init_mesh_params(jax.random.PRNGKey(0), plan)
    params["alpha_in"] = jax.random.uniform(jax.random.PRNGKey(5), (n,))
    x = _rand_cx(jax.random.PRNGKey(1), (3, n))
    w = jax.random.normal(jax.random.PRNGKey(2), (3, n))

    def loss(apply_fn, p):
        return jnp.sum(w * jnp.abs(apply_fn(p)))

    gk = jax.grad(lambda p: loss(
        lambda q: ops.mesh_apply(q, x, n=n, block_b=4), p))(params)
    gr = jax.grad(lambda p: loss(
        lambda q: _ref_with_alpha_in(q, x, n), p))(params)
    _assert_tree_close(gk, gr, atol=1e-4)


def _ref_with_alpha_in(params, x, n):
    alpha_in = params.get("alpha_in")
    if alpha_in is not None:
        x = x * jnp.exp(-1j * alpha_in.astype(jnp.complex64))
    return ref.mesh_apply_ref(
        {k: v for k, v in params.items() if k != "alpha_in"}, x, n)


# ---------------------------------------------------------------------------
# finite differences
# ---------------------------------------------------------------------------

def _directional_fd_check(loss, params, key, n_dirs=2, eps=1e-3, rtol=2e-2):
    """<grad, d> vs central finite differences along random directions."""
    g = jax.grad(loss)(params)
    leaves, treedef = jax.tree.flatten(params)
    for i in range(n_dirs):
        k = jax.random.fold_in(key, i)
        dirs = [jax.random.normal(jax.random.fold_in(k, j), l.shape)
                for j, l in enumerate(leaves)]
        norm = jnp.sqrt(sum(jnp.sum(d * d) for d in dirs))
        dirs = [d / norm for d in dirs]
        d_tree = jax.tree.unflatten(treedef, dirs)
        shifted = lambda t: jax.tree.map(lambda p, d: p + t * d,
                                         params, d_tree)
        fd = (loss(shifted(eps)) - loss(shifted(-eps))) / (2 * eps)
        dot = sum(jnp.sum(a * b)
                  for a, b in zip(jax.tree.leaves(g), dirs))
        np.testing.assert_allclose(float(dot), float(fd), rtol=rtol,
                                   atol=5e-3)


@pytest.mark.parametrize("n", [2, 8])
def test_mesh_kernel_vjp_finite_difference(n):
    plan = mesh_lib.clements_plan(n)
    params = mesh_lib.init_mesh_params(jax.random.PRNGKey(n), plan)
    x = _rand_cx(jax.random.PRNGKey(1), (4, n))
    wr = jax.random.normal(jax.random.PRNGKey(2), (4, n))
    wi = jax.random.normal(jax.random.PRNGKey(3), (4, n))

    def loss(p):
        y = ops.mesh_apply(p, x, n=n, block_b=4)
        return jnp.sum(wr * jnp.real(y) + wi * jnp.imag(y))

    _directional_fd_check(loss, params, jax.random.PRNGKey(7))


@pytest.mark.parametrize("n", [2, 8])
def test_rfnn_linear_vjp_finite_difference(n):
    plan = mesh_lib.clements_plan(n)
    vp = mesh_lib.init_mesh_params(jax.random.PRNGKey(0), plan)
    up = mesh_lib.init_mesh_params(jax.random.PRNGKey(1), plan)
    atten = jax.random.uniform(jax.random.PRNGKey(2), (n,), minval=0.2,
                               maxval=0.8)
    x = jax.random.normal(jax.random.PRNGKey(3), (4, n))
    w = jax.random.normal(jax.random.PRNGKey(4), (4, n))
    params = {"v": vp, "u": up, "atten": atten}

    def loss(p):
        return jnp.sum(w * ops.rfnn_linear(p["v"], p["atten"], p["u"], x,
                                           n=n, block_b=4))

    _directional_fd_check(loss, params, jax.random.PRNGKey(9))


# ---------------------------------------------------------------------------
# composition with the analog layer stack
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("output", ["complex", "abs", "real"])
@pytest.mark.parametrize("quantize", [None, "table1"])
def test_analog_unitary_backend_grads_match(output, quantize):
    """pallas backend == reference backend for AnalogUnitary, including the
    straight-through quantizer composed outside the kernel."""
    layer_ref = AnalogUnitary(n=8, quantize=quantize, output=output)
    layer_pal = dataclasses.replace(layer_ref, backend="pallas")
    params = layer_ref.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (5, 8))
    w = jax.random.normal(jax.random.PRNGKey(2), (5, 8))

    def loss(layer, p):
        y = layer.apply(p, x)
        return jnp.sum(w * (jnp.abs(y) if output == "complex" else y))

    np.testing.assert_allclose(float(loss(layer_ref, params)),
                               float(loss(layer_pal, params)), atol=1e-4)
    g_ref = jax.grad(lambda p: loss(layer_ref, p))(params)
    g_pal = jax.grad(lambda p: loss(layer_pal, p))(params)
    _assert_tree_close(g_pal, g_ref, atol=1e-4)


@pytest.mark.parametrize("output", ["abs", "real"])
def test_analog_linear_backend_grads_match(output):
    layer_ref = AnalogLinear(in_dim=6, out_dim=4, output=output)
    layer_pal = dataclasses.replace(layer_ref, backend="pallas")
    params = layer_ref.init(jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (3, 6))
    w = jax.random.normal(jax.random.PRNGKey(2), (3, 4))

    def loss(layer, p):
        return jnp.sum(w * layer.apply(p, x))

    np.testing.assert_allclose(float(loss(layer_ref, params)),
                               float(loss(layer_pal, params)), atol=1e-4)
    g_ref = jax.grad(lambda p: loss(layer_ref, p))(params)
    g_pal = jax.grad(lambda p: loss(layer_pal, p))(params)
    _assert_tree_close(g_pal, g_ref, atol=1e-4)


def test_mnist_sgd_step_trains_through_kernels():
    """A real training step on the paper's MNIST RFNN runs fwd+bwd through
    the fused kernels and matches the reference step update-for-update."""
    from repro.paper.mnist_rfnn import MnistRFNN
    from repro.train.step import make_sgd_step

    x = jax.random.normal(jax.random.PRNGKey(0), (10, 784)) * 0.1
    y = jnp.arange(10) % 10

    def one_step(backend):
        model = MnistRFNN(analog=True, hardware=None, quantize="table1",
                          backend=backend)
        params = model.init(jax.random.PRNGKey(1))
        step = make_sgd_step(lambda p, xi, yi: model.loss(p, xi, yi),
                             lr=0.05)
        for _ in range(3):
            params, (loss, _) = step(params, x, y)
        return params, float(loss)

    p_ref, l_ref = one_step("reference")
    p_pal, l_pal = one_step("pallas")
    assert np.isfinite(l_pal)
    np.testing.assert_allclose(l_pal, l_ref, atol=1e-4)
    _assert_tree_close(p_pal, p_ref, atol=1e-4)
