"""Golden-value regression tests for the paper pipelines.

These pin seeded, deterministic forward numerics (interpret-mode kernels on
CPU) so future kernel or layer refactors cannot silently drift them:

  * the 2x2 RFNN decision map (paper Fig. 9/10 geometry), on the ideal
    device *and* on the measured-prototype hardware model (key=None, so the
    non-idealities are the deterministic ones: hybrid imbalance/phase
    error, insertion loss, detector floor);
  * the 8x8 MNIST RFNN forward logits (Table-I quantized mesh), noiseless
    *and* through the prototype hardware model.

Each golden also asserts the Pallas kernel backend reproduces the pinned
reference values, so both paths are locked to the same numbers — including
the non-ideal configurations that now run inside the generalized kernels.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.hardware import IDEAL
from repro.paper.mnist_rfnn import MnistRFNN
from repro.paper.prototype import PROTOTYPE
from repro.paper.rfnn2x2 import RFNN2x2, decision_map

jax.config.update("jax_platform_name", "cpu")

# CI tiering: the goldens sweep full decision maps / logits grids through
# both backends — minutes, not seconds.  The fast CI leg deselects them
# (-m "not slow"); the full suite runs them on every push to main.
pytestmark = pytest.mark.slow

# seeded reference output of decision_map(net, {w:[0.9,-1.1], b:0.2}, 3, 5)
# on the ideal device, 5x5 grid over [0, 30]^2 — regenerate only with a
# deliberate numerics change, never to quiet a failing diff.
_GOLDEN_2X2_MAP = np.array([
    [5.4983395e-01, 1.0476434e-01, 1.1087940e-02, 1.0731090e-03, 1.0291598e-04],
    [6.0822695e-01, 9.9973959e-01, 9.9728847e-01, 9.7240555e-01, 7.7149719e-01],
    [6.6367859e-01, 9.9998808e-01, 9.9999988e-01, 9.9999917e-01, 9.9999094e-01],
    [7.1495956e-01, 9.9999058e-01, 1.0000000e+00, 1.0000000e+00, 1.0000000e+00],
    [7.6123482e-01, 9.9999261e-01, 1.0000000e+00, 1.0000000e+00, 1.0000000e+00],
], np.float32)

# seeded MnistRFNN(analog, hardware=None, quantize="table1") logits for the
# deterministic probe batch in _mnist_probe(), params from PRNGKey(0).
_GOLDEN_MNIST_LOGITS = np.array([
    [0.14466727, 0.31066757, 0.06445355, 0.07684972, 0.1735543,
     0.23663029, -0.1232702, -0.04427556, -0.36877245, -0.03444829],
    [0.12656285, 0.31689885, 0.07373706, 0.1846167, 0.00510788,
     0.12414476, -0.1268139, -0.03884934, -0.31385484, -0.10867385],
    [-0.10084903, 0.05731747, -0.07090714, -0.00816226, 0.04118231,
     0.16818395, -0.09303912, -0.1364099, -0.29452023, 0.24051884],
    [0.04998757, 0.09912463, -0.26871666, 0.08813564, 0.24717318,
     0.30987012, -0.114132, -0.45671967, -0.64495933, 0.3314222],
], np.float32)

# decision_map(net, {w:[0.9,-1.1], b:0.2}, 3, 5) on the *prototype* device
# (PROTOTYPE hardware model, key=None): hybrid imbalance, quadrature phase
# error, 1 dB/cell insertion loss and the detector floor, deterministic.
_GOLDEN_2X2_MAP_PROTO = np.array([
    [5.4826808e-01, 1.1116987e-01, 1.2645924e-02, 1.3098384e-03, 1.3428832e-04],
    [5.7940334e-01, 9.9908483e-01, 9.9135733e-01, 9.2166746e-01, 5.4653698e-01],
    [6.0841370e-01, 9.9995613e-01, 9.9999893e-01, 9.9999046e-01, 9.9990714e-01],
    [6.3667744e-01, 9.9996173e-01, 1.0000000e+00, 1.0000000e+00, 1.0000000e+00],
    [6.6402835e-01, 9.9996626e-01, 1.0000000e+00, 1.0000000e+00, 1.0000000e+00],
], np.float32)

# MnistRFNN(analog, hardware=PROTOTYPE, quantize="table1") logits for the
# same probe batch and PRNGKey(0) params — the noisy-device snapshot.
_GOLDEN_MNIST_NOISY_LOGITS = np.array([
    [0.08993154, 0.14334643, 0.02779060, 0.02180109, 0.07138671,
     0.07839968, -0.02473305, -0.01916183, -0.13285044, -0.06138282],
    [0.08208840, 0.15944149, 0.04169676, 0.07027833, 0.00623865,
     0.04000926, -0.03337407, -0.00231315, -0.10898143, -0.08906460],
    [-0.02731646, 0.03858061, -0.02441731, 0.00695287, 0.00901289,
     0.06005629, -0.04363203, -0.05471498, -0.12011482, 0.07948878],
    [0.04760969, 0.07048423, -0.09745891, 0.06777238, 0.10435189,
     0.13688213, -0.05323088, -0.17417204, -0.26159111, 0.10937718],
], np.float32)

_2X2_PARAMS = {"w": jnp.asarray([0.9, -1.1]), "b": jnp.asarray(0.2)}


def _mnist_probe():
    return jnp.sin(
        jnp.arange(4 * 784, dtype=jnp.float32).reshape(4, 784) * 0.37) * 0.5


def test_rfnn2x2_decision_boundary_golden():
    net = RFNN2x2(hardware=IDEAL)
    grid, zmap = decision_map(net, _2X2_PARAMS, 3, 5, lim=30.0, n=5)
    np.testing.assert_allclose(grid, np.linspace(0.0, 30.0, 5), atol=0)
    np.testing.assert_allclose(zmap, _GOLDEN_2X2_MAP, atol=2e-5)


def test_rfnn2x2_pallas_backend_matches_golden():
    """The kernel-backed device reproduces the pinned ideal-device map."""
    net = RFNN2x2(hardware=IDEAL, backend="pallas")
    _, zmap = decision_map(net, _2X2_PARAMS, 3, 5, lim=30.0, n=5)
    np.testing.assert_allclose(zmap, _GOLDEN_2X2_MAP, atol=2e-5)


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_rfnn2x2_nonideal_decision_map_golden(backend):
    """The prototype-hardware decision map, pinned per-backend: the
    generalized kernel path carries the non-ideal cell exactly."""
    net = RFNN2x2(hardware=PROTOTYPE, backend=backend)
    grid, zmap = decision_map(net, _2X2_PARAMS, 3, 5, lim=30.0, n=5)
    np.testing.assert_allclose(grid, np.linspace(0.0, 30.0, 5), atol=0)
    np.testing.assert_allclose(zmap, _GOLDEN_2X2_MAP_PROTO, atol=2e-5)


@pytest.mark.parametrize("backend", ["reference", "pallas"])
def test_mnist_noisy_forward_logits_golden(backend):
    """8x8 noisy-MNIST logits snapshot (prototype hardware model), pinned
    per-backend."""
    model = MnistRFNN(analog=True, hardware=PROTOTYPE, quantize="table1",
                      backend=backend)
    params = model.init(jax.random.PRNGKey(0))
    logits = model.apply(params, _mnist_probe())
    np.testing.assert_allclose(np.asarray(logits),
                               _GOLDEN_MNIST_NOISY_LOGITS, atol=1e-4)


def test_mnist_forward_logits_golden():
    model = MnistRFNN(analog=True, hardware=None, quantize="table1")
    params = model.init(jax.random.PRNGKey(0))
    logits = model.apply(params, _mnist_probe())
    np.testing.assert_allclose(np.asarray(logits), _GOLDEN_MNIST_LOGITS,
                               atol=1e-4)


def test_mnist_forward_logits_pallas_matches_golden():
    model = MnistRFNN(analog=True, hardware=None, quantize="table1",
                      backend="pallas")
    params = model.init(jax.random.PRNGKey(0))
    logits = model.apply(params, _mnist_probe())
    np.testing.assert_allclose(np.asarray(logits), _GOLDEN_MNIST_LOGITS,
                               atol=1e-4)


def test_mnist_init_is_backend_invariant():
    """Params come from the same init regardless of backend (the backend is
    an execution detail, not a model change)."""
    p_ref = MnistRFNN(analog=True, hardware=None).init(jax.random.PRNGKey(0))
    p_pal = dataclasses.replace(
        MnistRFNN(analog=True, hardware=None), backend="pallas",
    ).init(jax.random.PRNGKey(0))
    for a, b in zip(jax.tree.leaves(p_ref), jax.tree.leaves(p_pal)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
