"""Substrate tests: optimizer, data pipeline, checkpointing, fault tolerance."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import TokenStream, load_digits, make_toy_dataset
from repro.optim import AdamW
from repro.runtime import (
    FailureInjector,
    RecoveryPlan,
    StragglerMonitor,
    plan_recovery,
)
from repro.runtime.failures import Failure

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------

def test_adamw_reduces_quadratic():
    opt = AdamW(lr=0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0, 1.0])}
    state = opt.init(params)
    for _ in range(100):
        grads = {"w": 2 * params["w"]}
        params, state, _ = opt.update(params, grads, state)
    assert float(jnp.abs(params["w"]).max()) < 0.1


def test_adamw_clip_norm():
    opt = AdamW(lr=0.0, clip_norm=1.0)
    params = {"w": jnp.zeros(3)}
    state = opt.init(params)
    _, _, gnorm = opt.update(params, {"w": jnp.asarray([3.0, 4.0, 0.0])}, state)
    np.testing.assert_allclose(float(gnorm), 5.0, rtol=1e-5)


def test_adamw_bf16_moments_and_compression():
    opt = AdamW(lr=0.01, moment_dtype=jnp.bfloat16, grad_compression=True)
    params = {"w": jnp.ones((4, 4))}
    state = opt.init(params)
    assert state.m["w"].dtype == jnp.bfloat16
    g = opt.compress_grads({"w": jnp.ones((4, 4))})
    assert g["w"].dtype == jnp.bfloat16
    params, state, _ = opt.update(params, g, state)
    assert bool(jnp.isfinite(params["w"]).all())


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------

def test_token_stream_deterministic_resume():
    """Restarting at step k reproduces exactly the same batch k."""
    a = TokenStream(vocab_size=100, seq_len=32, global_batch=4, seed=7)
    b = TokenStream(vocab_size=100, seq_len=32, global_batch=4, seed=7)
    for step in (0, 3, 11):
        np.testing.assert_array_equal(a.batch(step)["tokens"],
                                      b.batch(step)["tokens"])


def test_token_stream_host_sharding():
    full = TokenStream(vocab_size=100, seq_len=16, global_batch=8, seed=1)
    h0 = TokenStream(vocab_size=100, seq_len=16, global_batch=8, seed=1,
                     host_id=0, num_hosts=2)
    h1 = TokenStream(vocab_size=100, seq_len=16, global_batch=8, seed=1,
                     host_id=1, num_hosts=2)
    got = np.concatenate([h0.batch(5)["tokens"], h1.batch(5)["tokens"]])
    np.testing.assert_array_equal(got, full.batch(5)["tokens"])


def test_token_stream_labels_are_shifted():
    s = TokenStream(vocab_size=50, seq_len=16, global_batch=2)
    b = s.batch(0)
    rng = np.random.default_rng((0, 0))
    row = s._gen_row(rng)
    np.testing.assert_array_equal(b["tokens"][0], row[:-1])
    np.testing.assert_array_equal(b["labels"][0], row[1:])


def test_digits_dataset():
    x_tr, y_tr, x_te, y_te = load_digits(n_train=100, n_test=40, seed=0)
    assert x_tr.shape == (100, 784) and x_te.shape == (40, 784)
    assert 0.0 <= x_tr.min() and x_tr.max() <= 1.0
    assert set(np.unique(y_tr)) == set(range(10))
    # deterministic
    x2, *_ = load_digits(n_train=100, n_test=40, seed=0)
    np.testing.assert_array_equal(x_tr, x2)


@pytest.mark.parametrize("case", ["corner", "diag_up", "diag_down", "ring"])
def test_toy_datasets(case):
    x, y = make_toy_dataset(case, n=200)
    assert x.shape == (200, 2) and set(np.unique(y)) <= {0, 1}
    assert 0.05 < y.mean() < 0.95  # both classes present


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------

def _tree(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 3)),
            "b": {"c": jnp.arange(5, dtype=jnp.int32)}}


def test_checkpoint_roundtrip(tmp_path):
    mgr = CheckpointManager(tmp_path)
    tree = _tree()
    mgr.save(10, tree, data_step=123)
    restored, meta = mgr.restore(None, like=jax.tree.map(jnp.zeros_like, tree))
    assert meta == {"step": 10, "data_step": 123}
    jax.tree.map(lambda a, b: np.testing.assert_array_equal(
        np.asarray(a), np.asarray(b)), tree, restored)


def test_checkpoint_async_and_latest(tmp_path):
    mgr = CheckpointManager(tmp_path, keep=2)
    for s in (1, 2, 3):
        mgr.save_async(s, _tree(s))
    mgr.wait()
    assert mgr.latest_step() == 3
    assert mgr.all_steps() == [2, 3]  # retention dropped step 1


def test_checkpoint_detects_structure_mismatch(tmp_path):
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _tree())
    with pytest.raises(ValueError, match="mismatch"):
        mgr.restore(1, like={"different": jnp.zeros(3)})


def test_checkpoint_crash_mid_save_is_recoverable(tmp_path):
    """A stale .tmp dir (simulated crash) must not break save/restore."""
    mgr = CheckpointManager(tmp_path)
    mgr.save(1, _tree())
    # simulate a crash that left a partial tmp dir for step 2
    (tmp_path / "step_00000002.tmp").mkdir()
    (tmp_path / "step_00000002.tmp" / "garbage").write_text("x")
    assert mgr.latest_step() == 1
    mgr.save(2, _tree(2))  # must clean up and succeed
    assert mgr.latest_step() == 2


def test_checkpoint_resume_matches_uninterrupted_training(tmp_path):
    """Crash/restart: resumed run == uninterrupted run, bit-exact."""
    opt = AdamW(lr=0.05)
    stream = TokenStream(vocab_size=10, seq_len=4, global_batch=2, seed=3)

    def step_fn(params, state, batch):
        grads = {"w": params["w"] * 0.1
                 + jnp.float32(batch["tokens"].sum() % 7)}
        return opt.update(params, grads, state)[:2]

    # uninterrupted 6 steps
    params = {"w": jnp.ones(3)}
    state = opt.init(params)
    for i in range(6):
        params, state = step_fn(params, state, stream.batch(i))
    ref = np.asarray(params["w"])

    # interrupted at step 3 + restored
    mgr = CheckpointManager(tmp_path)
    params = {"w": jnp.ones(3)}
    state = opt.init(params)
    for i in range(3):
        params, state = step_fn(params, state, stream.batch(i))
    mgr.save(3, {"params": params, "opt": state}, data_step=3)
    del params, state
    restored, meta = mgr.restore(None, like={
        "params": {"w": jnp.zeros(3)},
        "opt": opt.init({"w": jnp.zeros(3)})})
    params, state = restored["params"], restored["opt"]
    for i in range(meta["data_step"], 6):
        params, state = step_fn(params, state, stream.batch(i))
    np.testing.assert_array_equal(np.asarray(params["w"]), ref)


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------

def test_straggler_monitor_flags_slow_host():
    mon = StragglerMonitor(num_hosts=8, patience=3)
    inj = FailureInjector([Failure(step=5, kind="straggler", host=2,
                                   factor=6.0)])
    persistent = []
    for step in range(12):
        inj.at_step(step)
        times = np.asarray([inj.step_time(h, 1.0 + 0.01 * h)
                            for h in range(8)])
        mon.observe(times)
        persistent = mon.persistent()
    assert persistent == [2]


def test_straggler_monitor_no_false_positives():
    mon = StragglerMonitor(num_hosts=8)
    rng = np.random.default_rng(0)
    for _ in range(20):
        mon.observe(1.0 + 0.05 * rng.random(8))
    assert mon.persistent() == []


def test_plan_recovery_pod_loss():
    plan = plan_recovery(256)
    assert plan.viable
    assert plan.mesh_shape == (16, 16)
    assert plan.accum_multiplier == 2  # keep the global batch


def test_plan_recovery_partial_host_loss():
    plan = plan_recovery(200)  # lost 3.5 hosts' worth from one pod
    assert plan.viable
    assert plan.mesh_shape == (12, 16)
    assert plan.chips <= 200


def test_plan_recovery_below_floor():
    plan = plan_recovery(48)
    assert not plan.viable
    assert "48" in plan.reason


def test_failure_injector_host_down():
    inj = FailureInjector([Failure(step=2, kind="host_down", host=1)])
    inj.at_step(0)
    assert inj.alive(4) == [0, 1, 2, 3]
    inj.at_step(2)
    assert inj.alive(4) == [0, 2, 3]
