"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes and no NaNs (full configs are exercised only by
the dry-run via ShapeDtypeStruct)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import Model

jax.config.update("jax_platform_name", "cpu")

ARCHS = configs.list_archs()


def make_batch(cfg, key, b=2, s=16):
    k1, k2, k3 = jax.random.split(key, 3)
    tokens = jax.random.randint(k1, (b, s), 0, cfg.vocab_size)
    labels = jnp.concatenate(
        [tokens[:, 1:], -jnp.ones((b, 1), jnp.int32)], axis=1)
    batch = {"tokens": tokens, "labels": labels}
    if cfg.family == "vlm":
        batch["vis_embed"] = 0.02 * jax.random.normal(
            k2, (b, cfg.n_vis_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "encdec":
        batch["frames"] = 0.02 * jax.random.normal(
            k3, (b, cfg.enc_seq, cfg.d_model), jnp.float32)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_forward_shapes_and_finite(arch):
    cfg = configs.get_reduced(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))
    logits, aux = model.forward(params, batch)
    s_out = batch["tokens"].shape[1] + (
        cfg.n_vis_tokens if cfg.family == "vlm" else 0)
    assert logits.shape == (2, s_out, cfg.vocab_size)
    assert bool(jnp.isfinite(logits.astype(jnp.float32)).all())
    assert np.isfinite(float(aux))


@pytest.mark.parametrize("arch", ARCHS)
def test_reduced_train_step(arch):
    """One SGD step must run and reduce nothing to NaN."""
    cfg = configs.get_reduced(arch)
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, jax.random.PRNGKey(1))

    def loss_fn(p):
        return model.loss(p, batch)[0]

    loss, grads = jax.value_and_grad(loss_fn)(params)
    assert np.isfinite(float(loss))
    new_params = jax.tree.map(lambda p, g: p - 1e-3 * g, params, grads)
    loss2 = float(loss_fn(new_params))
    assert np.isfinite(loss2)


@pytest.mark.parametrize("arch", ARCHS)
def test_full_config_metadata(arch):
    """Full configs: exact assigned hyperparameters, sane param counts."""
    cfg = configs.get_config(arch)
    assert cfg.name == arch
    expected = {
        "internvl2-2b": (24, 2048, 16, 8, 8192, 92553),
        "llama4-maverick-400b-a17b": (48, 5120, 40, 8, 8192, 202048),
        "qwen2-moe-a2.7b": (24, 2048, 16, 16, 1408, 151936),
        "mamba2-780m": (48, 1536, None, None, None, 50280),
        "whisper-large-v3": (32, 1280, 20, 20, 5120, 51866),
        "zamba2-1.2b": (38, 2048, 32, 32, 8192, 32000),
        "granite-3-2b": (40, 2048, 32, 8, 8192, 49155),
        "llama3.2-3b": (28, 3072, 24, 8, 8192, 128256),
        "gemma-2b": (18, 2048, 8, 1, 16384, 256000),
        "tinyllama-1.1b": (22, 2048, 32, 4, 5632, 32000),
    }[arch]
    layers, d, h, kv, ff, vocab = expected
    assert cfg.n_layers == layers and cfg.d_model == d
    assert cfg.vocab_size == vocab
    if h is not None:
        assert cfg.n_heads == h and cfg.n_kv_heads == kv
    if ff is not None:
        ff_actual = cfg.d_ff_expert if arch == "qwen2-moe-a2.7b" else cfg.d_ff
        assert ff_actual == ff


def test_param_counts_match_names():
    budgets = {  # (min, max) in billions, total params
        "tinyllama-1.1b": (1.0, 1.2),
        "zamba2-1.2b": (1.0, 1.4),
        "mamba2-780m": (0.75, 0.95),
        "gemma-2b": (2.0, 2.8),
        "granite-3-2b": (2.0, 2.8),
        "internvl2-2b": (1.6, 2.2),
        "llama3.2-3b": (3.0, 3.8),
        "whisper-large-v3": (1.4, 1.8),
        "llama4-maverick-400b-a17b": (380.0, 420.0),
    }
    for arch, (lo, hi) in budgets.items():
        n = configs.get_config(arch).param_count() / 1e9
        assert lo <= n <= hi, f"{arch}: {n:.2f}B outside [{lo},{hi}]"
    active = configs.get_config("qwen2-moe-a2.7b").active_param_count() / 1e9
    assert 2.4 <= active <= 3.0  # A2.7B
    active4 = configs.get_config(
        "llama4-maverick-400b-a17b").active_param_count() / 1e9
    assert 12.0 <= active4 <= 20.0  # A17B


def test_grid_has_32_live_cells():
    assert len(configs.grid()) == 32
    assert ("mamba2-780m", "long_500k") in configs.grid()
    assert ("gemma-2b", "long_500k") not in configs.grid()
