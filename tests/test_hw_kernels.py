"""Property-based differential tests: generalized kernels vs reference.

The Pallas path no longer falls back to the reference implementation for
any configuration — non-ideal ``HardwareModel`` cells and Reck layouts run
inside the same fused sweep as the ideal Clements case.  These tests drive
random layouts (Clements *and* analytic Reck programs), sizes
N in {2, 4, 8, 16} and random hardware draws (including the degenerate
ideal model, guarding the PR-1 reversed-unitarity backward) through both
paths and require agreement to <= 1e-5 relative error, forward and
gradient.  They also assert the kernel path is actually *taken*: the
fallback predicates are deleted from the modules and the
``ops.KERNEL_PATH_CALLS`` instrumentation ticks on every entry.
"""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
from _propcheck import given, settings, strategies as st

from repro.core import decompose
from repro.core import hardware as hw_lib
from repro.core import mesh as mesh_lib
from repro.kernels import ops

jax.config.update("jax_platform_name", "cpu")

REL_TOL = 1e-5


def _rel_err(a, b):
    a, b = np.asarray(a), np.asarray(b)
    return float(np.linalg.norm((a - b).ravel())
                 / max(np.linalg.norm(b.ravel()), 1e-12))


def _tree_rel_err(a, b):
    """Relative error over the concatenated tree (robust to leaves whose
    true gradient is identically zero, e.g. d|y|/d alpha)."""
    av = np.concatenate([np.asarray(la).ravel() for la in jax.tree.leaves(a)])
    bv = np.concatenate([np.asarray(lb).ravel() for lb in jax.tree.leaves(b)])
    return _rel_err(av, bv)


def _draw_hardware(rng, ideal: bool) -> hw_lib.HardwareModel:
    if ideal:
        return hw_lib.IDEAL
    return hw_lib.HardwareModel(
        hybrid_imbalance=float(rng.uniform(0.0, 0.08)),
        hybrid_phase_err=float(rng.uniform(0.0, np.deg2rad(4.0))),
        cell_loss_db=float(rng.uniform(0.0, 0.6)),
        phase_sigma=float(rng.uniform(0.0, np.deg2rad(2.0))),
        detector_floor_dbm=-300.0,
        detector_sigma=0.0,
    )


def _draw_layout(n: int, layout: str, seed: int):
    """(plan, params) for a random mesh of the requested layout family."""
    if layout == "clements":
        plan = mesh_lib.clements_plan(n)
        params = mesh_lib.init_mesh_params(jax.random.PRNGKey(seed), plan)
    else:
        plan, params = decompose.reck_program(
            decompose.random_unitary(n, seed=seed))
    return plan, params


def _rand_cx(key, shape):
    kr, ki = jax.random.split(key)
    return (jax.random.normal(kr, shape)
            + 1j * jax.random.normal(ki, shape)).astype(jnp.complex64)


def _reference_apply(plan, params, x, hw, key):
    if hw is None:
        return mesh_lib.apply_mesh(plan, params, x)
    return hw_lib.apply_mesh_hw(plan, params, x, hw, key)


# ---------------------------------------------------------------------------
# forward differential property
# ---------------------------------------------------------------------------

@settings(max_examples=12, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       n=st.sampled_from([2, 4, 8, 16]),
       layout=st.sampled_from(["clements", "reck"]),
       ideal=st.booleans(),
       with_key=st.booleans())
def test_mesh_forward_differential(seed, n, layout, ideal, with_key):
    rng = np.random.default_rng(seed)
    plan, params = _draw_layout(n, layout, seed % 1000)
    hw = _draw_hardware(rng, ideal)
    key = jax.random.PRNGKey(seed) if with_key else None
    x = _rand_cx(jax.random.PRNGKey(seed + 1), (5, n))

    before = ops.KERNEL_PATH_CALLS["mesh_apply"]
    y_k = ops.mesh_apply(params, x, n=n, plan=plan, hardware=hw, key=key,
                         block_b=8)
    y_r = _reference_apply(plan, params, x, hw, key)
    assert ops.KERNEL_PATH_CALLS["mesh_apply"] == before + 1
    assert _rel_err(y_k, y_r) <= REL_TOL, (n, layout, ideal)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       n=st.sampled_from([4, 8]),
       layout=st.sampled_from(["clements", "reck"]))
def test_mesh_forward_ideal_model_equals_no_model(seed, n, layout):
    """hardware=IDEAL through the kernel == no hardware model at all —
    the degenerate case that guards the unitary fast path's semantics."""
    plan, params = _draw_layout(n, layout, seed % 1000)
    x = _rand_cx(jax.random.PRNGKey(seed), (3, n))
    y_ideal_model = ops.mesh_apply(params, x, n=n, plan=plan,
                                   hardware=hw_lib.IDEAL, block_b=8)
    y_no_model = ops.mesh_apply(params, x, n=n, plan=plan, block_b=8)
    assert _rel_err(y_ideal_model, y_no_model) <= REL_TOL


# ---------------------------------------------------------------------------
# VJP differential property
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       n=st.sampled_from([2, 4, 8, 16]),
       layout=st.sampled_from(["clements", "reck"]),
       ideal=st.booleans())
def test_mesh_vjp_differential(seed, n, layout, ideal):
    rng = np.random.default_rng(seed)
    plan, params = _draw_layout(n, layout, seed % 1000)
    hw = _draw_hardware(rng, ideal)
    key = jax.random.PRNGKey(seed)
    x = _rand_cx(jax.random.PRNGKey(seed + 1), (4, n))
    w = jax.random.normal(jax.random.PRNGKey(seed + 2), (4, n))

    def loss_k(p, xx):
        y = ops.mesh_apply(p, xx, n=n, plan=plan, hardware=hw, key=key,
                           block_b=8)
        return jnp.sum(w * jnp.abs(y))

    def loss_r(p, xx):
        return jnp.sum(w * jnp.abs(_reference_apply(plan, p, xx, hw, key)))

    gk = jax.grad(loss_k, argnums=(0, 1))(params, x)
    gr = jax.grad(loss_r, argnums=(0, 1))(params, x)
    assert _tree_rel_err(gk, gr) <= REL_TOL, (n, layout, ideal)


@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       n=st.sampled_from([4, 8, 16]),
       ideal=st.booleans())
def test_fused_rfnn_linear_differential(seed, n, ideal):
    """The fused V->D->U->|detect| kernel vs the composite reference, with
    hardware cells in both meshes — forward and full parameter gradient."""
    rng = np.random.default_rng(seed)
    hw = _draw_hardware(rng, ideal)
    plan = mesh_lib.clements_plan(n)
    vp = mesh_lib.init_mesh_params(jax.random.PRNGKey(seed), plan)
    up = mesh_lib.init_mesh_params(jax.random.PRNGKey(seed + 1), plan)
    atten = jax.random.uniform(jax.random.PRNGKey(seed + 2), (n,),
                               minval=0.2, maxval=0.9)
    x = jax.random.normal(jax.random.PRNGKey(seed + 3), (5, n))
    w = jax.random.normal(jax.random.PRNGKey(seed + 4), (5, n))
    kv, ku = jax.random.split(jax.random.PRNGKey(seed + 5))
    scale = 1.3

    def fwd_k(v, a, u, xx):
        return ops.rfnn_linear(v, a, u, xx, n=n, scale=scale, hardware=hw,
                               key_v=kv, key_u=ku, block_b=8)

    def fwd_r(v, a, u, xx):
        h = _reference_apply(plan, v, xx.astype(jnp.complex64), hw, kv)
        h = h * a.astype(jnp.complex64)
        y = _reference_apply(plan, u, h, hw, ku)
        return jnp.abs(scale * y)

    args = (vp, atten, up, x)
    assert _rel_err(fwd_k(*args), fwd_r(*args)) <= REL_TOL

    gk = jax.grad(lambda *a: jnp.sum(w * fwd_k(*a)), argnums=(0, 1, 2, 3))(*args)
    gr = jax.grad(lambda *a: jnp.sum(w * fwd_r(*a)), argnums=(0, 1, 2, 3))(*args)
    assert _tree_rel_err(gk, gr) <= REL_TOL, (n, ideal)


def test_mesh_vjp_nonideal_deep_mesh():
    """Depth check for the inverse-based state recompute: at N=32 (32
    non-unitary columns, worst-of-band imperfections) the backward sweep's
    per-column inverse must not compound float32 error past the gate —
    the hybrid renormalization keeps cells near-unitary, so conditioning
    stays ~1 regardless of depth (measured ~1e-6 at N=64 too)."""
    n = 32
    hw = hw_lib.HardwareModel(
        hybrid_imbalance=0.08, hybrid_phase_err=np.deg2rad(4.0),
        cell_loss_db=0.6, phase_sigma=0.0, detector_sigma=0.0)
    plan = mesh_lib.clements_plan(n)
    params = mesh_lib.init_mesh_params(jax.random.PRNGKey(n), plan)
    x = _rand_cx(jax.random.PRNGKey(1), (6, n))
    w = jax.random.normal(jax.random.PRNGKey(2), (6, n))

    def loss_k(p):
        return jnp.sum(w * jnp.abs(ops.mesh_apply(
            p, x, n=n, plan=plan, hardware=hw, block_b=8)))

    def loss_r(p):
        return jnp.sum(w * jnp.abs(hw_lib.apply_mesh_hw(plan, p, x, hw)))

    gk = jax.grad(loss_k)(params)
    gr = jax.grad(loss_r)(params)
    assert _tree_rel_err(gk, gr) <= REL_TOL


def test_pack_cells_rejects_mismatched_plan():
    """mesh_apply_cells with a cell tensor from a different plan must fail
    loudly, not clamp indices onto identity cells."""
    from repro.kernels import schedule as sched_lib

    sched = sched_lib.clements_schedule(8)
    with np.testing.assert_raises(ValueError):
        sched_lib.pack_cells(
            sched, jnp.zeros((2, 4, 2, 2), jnp.complex64))  # too few columns
    with np.testing.assert_raises(ValueError):
        sched_lib.pack_cells(
            sched, jnp.zeros((8, 3, 2, 2), jnp.complex64))  # wrong pairs


def test_rfnn_linear_reck_plans_differential():
    """The fused kernel accepts analytic Reck programs for V and U."""
    n = 8
    uv = decompose.random_unitary(n, seed=0)
    uu = decompose.random_unitary(n, seed=1)
    v_plan, v_params = decompose.reck_program(uv)
    u_plan, u_params = decompose.reck_program(uu)
    atten = jax.random.uniform(jax.random.PRNGKey(2), (n,), minval=0.2,
                               maxval=0.9)
    x = jax.random.normal(jax.random.PRNGKey(3), (5, n))
    y_k = ops.rfnn_linear(v_params, atten, u_params, x, n=n, scale=1.7,
                          v_plan=v_plan, u_plan=u_plan, block_b=8)
    h = mesh_lib.apply_mesh(v_plan, v_params, x.astype(jnp.complex64))
    h = h * atten.astype(jnp.complex64)
    y_r = jnp.abs(1.7 * mesh_lib.apply_mesh(u_plan, u_params, h))
    assert _rel_err(y_k, y_r) <= REL_TOL


# ---------------------------------------------------------------------------
# the kernel path is taken (no fallback left)
# ---------------------------------------------------------------------------

def test_fallback_branches_are_gone():
    """The modules that used to gate the kernel path no longer carry their
    fallback predicates; pallas means pallas."""
    from repro.core import analog_linear
    from repro.paper.rfnn2x2 import RFNN2x2

    assert not hasattr(analog_linear, "_is_rect_clements")
    assert not hasattr(RFNN2x2, "_kernel_exact")
    assert not hasattr(analog_linear.AnalogLinear, "_plans_rect")


def test_analog_layers_route_hardware_through_kernels():
    """backend='pallas' + HardwareModel ticks the kernel instrumentation
    (it used to silently take the reference path)."""
    from repro.core.analog_linear import AnalogLinear, AnalogUnitary

    hw = hw_lib.HardwareModel()
    layer = AnalogUnitary(n=4, hardware=hw, output="abs", backend="pallas")
    params = layer.init(jax.random.PRNGKey(0))
    before = ops.KERNEL_PATH_CALLS["mesh_apply"]
    layer.apply(params, jnp.ones((2, 4)), key=jax.random.PRNGKey(1))
    assert ops.KERNEL_PATH_CALLS["mesh_apply"] == before + 1

    lin = AnalogLinear(in_dim=4, out_dim=4, hardware=hw, output="abs",
                       backend="pallas")
    lparams = lin.init(jax.random.PRNGKey(0))
    before = ops.KERNEL_PATH_CALLS["rfnn_linear"]
    lin.apply(lparams, jnp.ones((2, 4)), key=jax.random.PRNGKey(1))
    assert ops.KERNEL_PATH_CALLS["rfnn_linear"] == before + 1


def test_programmed_reck_layer_routes_through_kernels():
    """init_from_matrix adopts Reck plans; the pallas backend must keep the
    kernel path (this configuration used to flip `_plans_rect` off)."""
    from repro.core.analog_linear import AnalogLinear

    layer = AnalogLinear(in_dim=4, out_dim=4, output="real",
                         backend="pallas")
    w = np.random.default_rng(0).normal(size=(4, 4))
    params = layer.init_from_matrix(w)
    x = np.random.default_rng(1).normal(size=(3, 4)).astype(np.float32)
    before = ops.KERNEL_PATH_CALLS["mesh_apply"]
    y = layer.apply(params, jnp.asarray(x))
    assert ops.KERNEL_PATH_CALLS["mesh_apply"] == before + 2  # V and U mesh
    np.testing.assert_allclose(np.asarray(y), x @ w.T, atol=1e-4)


def test_noisy_hardware_sgd_step_matches_reference():
    """Hardware-in-the-loop MNIST training (prototype model, key-driven
    phase/detector noise) runs fwd+bwd through the fused kernels and
    matches the reference step update-for-update — the configuration that
    used to silently fall back."""
    from repro.paper.mnist_rfnn import MnistRFNN
    from repro.paper.prototype import PROTOTYPE
    from repro.train.step import make_sgd_step

    x = jax.random.normal(jax.random.PRNGKey(0), (10, 784)) * 0.1
    y = jnp.arange(10) % 10

    def run(backend):
        model = MnistRFNN(analog=True, hardware=PROTOTYPE,
                          quantize="table1", backend=backend)
        params = model.init(jax.random.PRNGKey(1))
        step = make_sgd_step(
            lambda p, xi, yi, ki: model.loss(p, xi, yi, ki), lr=0.05)
        for i in range(2):
            params, (loss, _) = step(params, x, y, jax.random.PRNGKey(i))
        return params, float(loss)

    p_ref, l_ref = run("reference")
    p_pal, l_pal = run("pallas")
    assert np.isfinite(l_pal)
    np.testing.assert_allclose(l_pal, l_ref, atol=1e-5)
    for a, b in zip(jax.tree.leaves(p_pal), jax.tree.leaves(p_ref)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_monte_carlo_yield_backends_agree():
    """The vmapped yield sweep produces identical per-draw errors on the
    kernel and reference paths (same draws, same physics)."""
    from repro.paper.efficiency import monte_carlo_yield

    r_p = monte_carlo_yield(n=4, n_draws=6, seed=0, backend="pallas")
    r_r = monte_carlo_yield(n=4, n_draws=6, seed=0, backend="reference")
    np.testing.assert_allclose(np.asarray(r_p["errors"]),
                               np.asarray(r_r["errors"]), atol=1e-5)
    assert 0.0 <= r_p["yield"] <= 1.0


# ---------------------------------------------------------------------------
# tile-grid megakernel: quantized / hardware draw parity
# ---------------------------------------------------------------------------

@settings(max_examples=6, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       ideal=st.booleans(),
       quantized=st.booleans())
def test_tiled_apply_hardware_draw_parity(seed, ideal, quantized):
    """The tile-grid kernel with per-tile hardware bindings and frozen
    phase-noise keys must match the pure-reference per-tile composition
    draw-for-draw — every tile is its own device consuming its own key —
    including Table-I-quantized tile phases (snapped masters feed both
    paths identically)."""
    from repro.core import quantize as q_lib

    rng = np.random.default_rng(seed)
    hw = _draw_hardware(rng, ideal)
    n, to, ti = 4, 2, 2
    plan = mesh_lib.clements_plan(n)
    cb = q_lib.table_i_codebook()
    key = jax.random.PRNGKey(seed)
    tiles = []
    for o in range(to):
        trow = []
        for i in range(ti):
            k = jax.random.fold_in(key, o * ti + i)
            kv, ku, kp, ka = jax.random.split(k, 4)
            vp = mesh_lib.init_mesh_params(kp, plan)
            up = mesh_lib.init_mesh_params(jax.random.fold_in(kp, 1), plan)
            if quantized:
                vp = q_lib.quantize_mesh_params(vp, cb, ste=False)
                up = q_lib.quantize_mesh_params(up, cb, ste=False)
            trow.append({
                "v": vp, "u": up,
                "atten": jax.random.uniform(ka, (n,), minval=0.2,
                                            maxval=0.9),
                "scale": 1.0 + 0.1 * (o + i),
                "key_v": kv, "key_u": ku,
            })
        tiles.append(tuple(trow))
    tiles = tuple(tiles)
    x = _rand_cx(jax.random.PRNGKey(seed + 1), (5, ti * n))

    before = ops.KERNEL_PATH_CALLS["tiled_apply"]
    y_k = ops.tiled_apply(tiles, x, n=n, hardware=hw)
    assert ops.KERNEL_PATH_CALLS["tiled_apply"] == before + 1

    xt = x.reshape(x.shape[:-1] + (ti, n))
    rows = []
    for o in range(to):
        acc = 0
        for i in range(ti):
            ta = tiles[o][i]
            h = hw_lib.apply_mesh_hw(plan, ta["v"], xt[..., i, :], hw,
                                     ta["key_v"])
            h = h * ta["atten"].astype(jnp.complex64)
            y = hw_lib.apply_mesh_hw(plan, ta["u"], h, hw, ta["key_u"])
            acc = acc + jnp.asarray(ta["scale"], jnp.complex64) * y
        rows.append(acc)
    y_r = jnp.concatenate(rows, axis=-1)
    assert _rel_err(y_k, y_r) <= REL_TOL, (ideal, quantized)
