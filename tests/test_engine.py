"""Unified serving-engine tests: protocol conformance, admission
backpressure, SLO accounting, async dispatch, and the public surface."""

import threading

import jax
import numpy as np
import pytest

import repro
from repro import serving
from repro.serving import Request, ServableProgram, ServingEngine, as_servable

jax.config.update("jax_platform_name", "cpu")


# ---------------------------------------------------------------------------
# fixtures: one small compiled program of each variant
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def tiled_prog():
    from repro import compile as compile_mod

    w = np.random.default_rng(11).normal(size=(8, 8)) / np.sqrt(8)
    tp = compile_mod.program_tiled(
        compile_mod.synthesize_tiled(w, tile=4), method="reck")
    return w, compile_mod.lower_tiled(tp)


@pytest.fixture(scope="module")
def all_compiled():
    from repro import compile as compile_mod

    rng = np.random.default_rng(7)
    w = rng.normal(size=(8, 8)) / np.sqrt(8)
    single = compile_mod.lower(compile_mod.program(
        compile_mod.synthesize(w, n=8), method="reck"))
    tp = compile_mod.program_tiled(
        compile_mod.synthesize_tiled(w, tile=4), method="reck")
    tiled = compile_mod.lower_tiled(tp)
    deep = compile_mod.lower_deep([tp, tp])
    return single, tiled, deep


# ---------------------------------------------------------------------------
# ServableProgram protocol
# ---------------------------------------------------------------------------

def test_all_compiled_programs_are_servable(all_compiled):
    """The three Compiled* variants present one apply/metadata surface."""
    for prog in all_compiled:
        assert isinstance(prog, ServableProgram), type(prog).__name__
        assert prog.n_in == 8 and prog.n_out == 8
        # placement is part of the metadata surface (None when unplaced)
        _ = prog.placement
        y = np.asarray(prog.apply(np.ones((2, 8), np.float32)))
        assert y.shape == (2, 8)


def test_as_servable_passthrough_and_wrap(all_compiled):
    from repro.core.analog_linear import AnalogSequence

    single, tiled, deep = all_compiled
    for prog in all_compiled:
        assert as_servable(prog) is prog   # already conformant: no wrapper
    model = AnalogSequence(n=8, depth=1, backend="reference")
    params = model.init(jax.random.PRNGKey(0))
    bound = as_servable(model, params)
    assert isinstance(bound, ServableProgram)
    assert bound.n_in == 8 and bound.n_out == 8
    x = np.ones((2, 8), np.float32)
    np.testing.assert_allclose(np.asarray(bound.apply(x)),
                               np.asarray(model.apply(params, x)))
    with pytest.raises(ValueError, match="recover"):
        bound.recover(((0, 0),))


def test_single_mesh_program_refuses_tile_recovery(all_compiled):
    single, _, _ = all_compiled
    with pytest.raises(ValueError, match="tile grid"):
        single.recover(((0, 0),))


# ---------------------------------------------------------------------------
# admission backpressure: bounded queue rejects vs blocks
# ---------------------------------------------------------------------------

def _feature_reqs(count, seed=0, **kw):
    rng = np.random.default_rng(seed)
    return [Request(rid=i, features=rng.normal(size=8).astype(np.float32),
                    **kw) for i in range(count)]


def test_bounded_queue_rejects_when_full(tiled_prog):
    _, comp = tiled_prog
    eng = ServingEngine(comp, slots=1, max_queue=2, admission="reject")
    reqs = _feature_reqs(4)
    accepted = [eng.submit(r) for r in reqs]
    assert accepted == [True, True, False, False]
    # a rejected request completes as failed — wait() never hangs on it
    assert reqs[2].failed and reqs[2].done and reqs[2].wait(timeout=0)
    assert eng.stats["rejected"] == 2
    eng.run()
    assert eng.stats["served"] == 2


def test_bounded_queue_blocks_until_space(tiled_prog):
    """admission="block": a full queue stalls submit until a tick drains
    it (here: the dispatch thread), instead of dropping the request."""
    _, comp = tiled_prog
    eng = ServingEngine(comp, slots=2, max_queue=2, admission="block")
    reqs = _feature_reqs(8)
    with eng:
        for r in reqs:
            assert eng.submit(r, timeout=30)
        assert all(r.wait(timeout=30) for r in reqs)
    assert eng.stats["served"] == 8
    assert eng.stats["rejected"] == 0


def test_blocking_submit_times_out_as_rejected(tiled_prog):
    _, comp = tiled_prog
    eng = ServingEngine(comp, slots=1, max_queue=1, admission="block")
    assert eng.submit(_feature_reqs(1)[0])
    late = _feature_reqs(1, seed=1)[0]
    # no dispatch thread is running, so the queue can never drain
    assert not eng.submit(late, timeout=0.05)
    assert late.failed and late.done
    assert eng.stats["rejected"] == 1


# ---------------------------------------------------------------------------
# SLO accounting
# ---------------------------------------------------------------------------

def test_stats_counters_and_latency_percentiles(tiled_prog):
    w, comp = tiled_prog
    eng = ServingEngine(comp, slots=2)
    reqs = _feature_reqs(5)
    for r in reqs:
        eng.submit(r)
    eng.run()
    s = eng.stats
    assert s["submitted"] == 5 and s["served"] == 5
    assert s["expired"] == 0 and s["rejected"] == 0 and s["recovered"] == 0
    assert s["ticks"] == 3 and s["queue_depth"] == 0
    assert s["p50_tick_us"] > 0 and s["p99_tick_us"] >= s["p50_tick_us"]
    assert s["qps"] > 0
    # arrival/completion metadata stamped per request
    assert all(r.submitted_at is not None for r in reqs)
    assert [r.completed_tick for r in reqs] == [1, 1, 2, 2, 3]


def test_unknown_counter_rejected():
    from repro.runtime import SLOTracker

    t = SLOTracker()
    with pytest.raises(KeyError):
        t.count("nope")
    assert t.percentile_us(50) is None and t.qps() is None


# ---------------------------------------------------------------------------
# async dispatch thread
# ---------------------------------------------------------------------------

def test_dispatch_thread_serves_submissions_from_other_threads(tiled_prog):
    w, comp = tiled_prog
    eng = ServingEngine(comp, slots=4)
    reqs = _feature_reqs(12, seed=2)

    def producer(chunk):
        for r in chunk:
            eng.submit(r)

    with eng:
        threads = [threading.Thread(target=producer, args=(reqs[i::3],))
                   for i in range(3)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        assert all(r.wait(timeout=30) for r in reqs)
    for r in reqs:
        np.testing.assert_allclose(r.result, np.abs(r.features @ w.T),
                                   atol=1e-4)
    assert eng.stats["served"] == 12


def test_stop_without_drain_fails_pending(tiled_prog):
    _, comp = tiled_prog
    eng = ServingEngine(comp, slots=1)
    reqs = _feature_reqs(3)
    # never started: stop(drain=False) must still fail queued requests
    for r in reqs:
        eng.submit(r)
    eng.start()
    eng.stop(drain=False)
    assert all(r.done for r in reqs)
    served = sum(1 for r in reqs if not r.failed)
    assert served + eng.stats["rejected"] == 3


# ---------------------------------------------------------------------------
# LM-vs-analog parity on the shared slot loop
# ---------------------------------------------------------------------------

def test_lm_and_analog_paths_share_slot_loop_semantics(tiled_prog):
    """Same engine class, same admission/deadline machinery: a queued
    request past its deadline expires identically on both paths."""
    from repro import configs
    from repro.models import Model

    _, comp = tiled_prog
    e_analog = ServingEngine(comp, slots=1)

    cfg = configs.get_reduced("tinyllama-1.1b")
    model = Model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    e_lm = ServingEngine(model, params, slots=1, max_len=32)

    prompts = np.random.default_rng(0).integers(
        0, cfg.vocab_size, size=(3, 4)).astype(np.int32)
    lm_reqs = [Request(rid=i, prompt=prompts[i], max_new=2,
                       deadline_ticks=2) for i in range(3)]
    an_reqs = _feature_reqs(3, deadline_ticks=2)
    for r in lm_reqs:
        e_lm.submit(r)
    for r in an_reqs:
        e_analog.submit(r)
    e_lm.run()
    e_analog.run()
    # slots=1: on both paths the first request serves and the last
    # expires; the LM path holds its slot for max_new=2 ticks, so its
    # queue drains slower and expires MORE — never fewer — requests
    for stats in (e_lm.stats, e_analog.stats):
        assert stats["served"] >= 1
        assert stats["served"] + stats["expired"] == 3
    assert e_lm.stats["expired"] >= e_analog.stats["expired"]
    assert all(r.done for r in lm_reqs + an_reqs)


# ---------------------------------------------------------------------------
# public surface audit
# ---------------------------------------------------------------------------

def test_serving_public_surface_is_exactly_the_engine_api():
    assert serving.__all__ == ["Request", "ServableProgram",
                               "ServingEngine", "as_servable"]
    for name in serving.__all__:
        assert getattr(serving, name) is not None
    assert "ServingEngine" in repro.__all__ and "Request" in repro.__all__
    assert repro.ServingEngine is ServingEngine
    assert repro.Request is Request

