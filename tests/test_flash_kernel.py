"""Pallas flash-attention kernel: shape/dtype/block sweeps vs dense oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _propcheck import given, settings, strategies as st

from repro.kernels.flash_attention import flash_attention
from repro.kernels.ref import flash_attention_ref

jax.config.update("jax_platform_name", "cpu")


def _qkv(key, b, h, s, hd, dtype=jnp.float32):
    k1, k2, k3 = jax.random.split(key, 3)
    return (jax.random.normal(k1, (b, h, s, hd), dtype),
            jax.random.normal(k2, (b, h, s, hd), dtype),
            jax.random.normal(k3, (b, h, s, hd), dtype))


@pytest.mark.parametrize("s,hd,bq,bk", [
    (64, 32, 16, 16), (128, 64, 32, 32), (128, 64, 64, 32),
    (256, 128, 128, 128),
])
def test_flash_matches_dense(s, hd, bq, bk):
    q, k, v = _qkv(jax.random.PRNGKey(s + hd), 2, 2, s, hd)
    out = flash_attention(q, k, v, causal=True, bq=bq, bk=bk)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_non_causal():
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, 2, 64, 32)
    out = flash_attention(q, k, v, causal=False, bq=16, bk=32)
    ref = flash_attention_ref(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_bf16():
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 2, 64, 32, jnp.bfloat16)
    out = flash_attention(q, k, v, causal=True, bq=16, bk=16)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), atol=3e-2)


def test_flash_causality():
    """Future keys must not influence earlier queries."""
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, 1, 64, 32)
    out1 = flash_attention(q, k, v, causal=True, bq=16, bk=16)
    k2 = k.at[:, :, -1].set(99.0)
    v2 = v.at[:, :, -1].set(-99.0)
    out2 = flash_attention(q, k2, v2, causal=True, bq=16, bk=16)
    np.testing.assert_allclose(np.asarray(out1[:, :, :-1]),
                               np.asarray(out2[:, :, :-1]), atol=1e-6)


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1),
       s=st.sampled_from([32, 64]),
       hd=st.sampled_from([16, 32]))
def test_flash_property(seed, s, hd):
    q, k, v = _qkv(jax.random.PRNGKey(seed), 1, 2, s, hd)
    out = flash_attention(q, k, v, causal=True, bq=16, bk=16)
    ref = flash_attention_ref(q, k, v, causal=True)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref), atol=2e-5)


def test_flash_rejects_bad_blocks():
    q, k, v = _qkv(jax.random.PRNGKey(3), 1, 1, 96, 32)
    with pytest.raises(ValueError):
        flash_attention(q, k, v, bq=64, bk=64)  # 96 % 64 != 0
