"""The paper's analog processor as a first-class LM linear backend
(``linear_impl="rfnn"``): MLP projections realized by tiled RF meshes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import Model, ModelConfig

jax.config.update("jax_platform_name", "cpu")

CFG = ModelConfig(name="rfnn-lm", family="dense", n_layers=2, d_model=64,
                  n_heads=4, n_kv_heads=2, d_ff=128, vocab_size=97,
                  attn_chunk=16, dtype="float32",
                  linear_impl="rfnn", rfnn_tile=16)


def _batch(key, b=2, s=16):
    toks = jax.random.randint(key, (b, s), 0, CFG.vocab_size)
    return {"tokens": toks,
            "labels": jnp.concatenate(
                [toks[:, 1:], -jnp.ones((b, 1), jnp.int32)], axis=1)}


def test_rfnn_lm_forward_and_grads():
    m = Model(CFG)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(jax.random.PRNGKey(1))
    loss, _ = m.loss(params, batch)
    assert np.isfinite(float(loss))
    g = jax.grad(lambda p: m.loss(p, batch)[0])(params)
    mesh_g = g["blocks"]["l0_dense"]["mlp"]["wi"]["u"]["theta"]
    assert float(jnp.abs(mesh_g).sum()) > 0  # phases receive gradients


def test_rfnn_lm_trains():
    m = Model(CFG)
    params = m.init(jax.random.PRNGKey(0))
    batch = _batch(jax.random.PRNGKey(1))

    @jax.jit
    def step(p):
        l, g = jax.value_and_grad(lambda q: m.loss(q, batch)[0])(p)
        return l, jax.tree.map(lambda w, gg: w - 0.05 * gg, p, g)

    l0, params = step(params)
    for _ in range(12):
        l, params = step(params)
    assert float(l) < float(l0)


def test_rfnn_lm_specs_match():
    m = Model(CFG)
    params = m.init(jax.random.PRNGKey(0))
    specs = m.param_specs()
    def chk(p, s):
        assert isinstance(s, tuple) and len(s) == p.ndim
    jax.tree.map(chk, params, specs,
                 is_leaf=lambda x: isinstance(x, tuple)
                 and all(isinstance(i, (str, type(None))) for i in x))


@pytest.mark.slow
def test_rfnn_lm_pallas_backend_matches_reference():
    """The tiled LM projections on the tile-grid megakernel: same loss,
    same gradients as the double-vmapped reference composition, and the
    kernel path is actually taken."""
    import dataclasses

    from repro.kernels import ops

    cfg_p = dataclasses.replace(CFG, rfnn_backend="pallas")
    m_ref, m_pal = Model(CFG), Model(cfg_p)
    params = m_ref.init(jax.random.PRNGKey(0))
    batch = _batch(jax.random.PRNGKey(1))
    calls = ops.KERNEL_PATH_CALLS["tiled_apply"]
    l_ref, _ = m_ref.loss(params, batch)
    l_pal, _ = m_pal.loss(params, batch)
    assert ops.KERNEL_PATH_CALLS["tiled_apply"] > calls
    np.testing.assert_allclose(float(l_pal), float(l_ref), atol=1e-5)
    g_ref = jax.grad(lambda p: m_ref.loss(p, batch)[0])(params)
    g_pal = jax.grad(lambda p: m_pal.loss(p, batch)[0])(params)
    scale = max(float(jnp.max(jnp.abs(g))) for g in jax.tree.leaves(g_ref))
    err = max(float(jnp.max(jnp.abs(a - b)))
              for a, b in zip(jax.tree.leaves(g_pal),
                              jax.tree.leaves(g_ref)))
    assert err / (scale + 1e-30) <= 1e-5
