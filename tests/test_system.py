"""End-to-end system tests: the real drivers, small scale.

These exercise the same code paths a cluster run uses: the training driver
(data stream -> jitted step -> checkpoint/resume -> straggler monitor) and
the serving driver (prefill -> batched decode).
"""

import jax

from repro.launch import serve as serve_cli
from repro.launch import train as train_cli

jax.config.update("jax_platform_name", "cpu")


def test_train_driver_end_to_end(tmp_path):
    rc = train_cli.main([
        "--arch", "tinyllama-1.1b", "--reduced", "--steps", "12",
        "--batch", "4", "--seq", "64", "--ckpt-dir", str(tmp_path),
        "--ckpt-every", "5", "--log-every", "5"])
    assert rc == 0
    # resume continues from the checkpoint
    rc = train_cli.main([
        "--arch", "tinyllama-1.1b", "--reduced", "--steps", "16",
        "--batch", "4", "--seq", "64", "--ckpt-dir", str(tmp_path),
        "--resume", "--log-every", "5"])
    assert rc == 0


def test_train_driver_straggler_path(tmp_path):
    """Injected straggler triggers the recovery-plan logging path."""
    rc = train_cli.main([
        "--arch", "granite-3-2b", "--reduced", "--steps", "10",
        "--batch", "2", "--seq", "32", "--inject-straggler", "2",
        "--ckpt-dir", str(tmp_path), "--log-every", "5"])
    assert rc == 0


def test_serve_driver_end_to_end():
    rc = serve_cli.main([
        "--arch", "granite-3-2b", "--reduced", "--batch", "2",
        "--prompt-len", "16", "--gen", "8"])
    assert rc == 0


def test_serve_driver_ssm():
    rc = serve_cli.main([
        "--arch", "mamba2-780m", "--reduced", "--batch", "2",
        "--prompt-len", "16", "--gen", "6"])
    assert rc == 0


def test_moe_train_driver():
    rc = train_cli.main([
        "--arch", "qwen2-moe-a2.7b", "--reduced", "--steps", "6",
        "--batch", "2", "--seq", "32", "--log-every", "2"])
    assert rc == 0
